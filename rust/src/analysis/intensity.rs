//! Arithmetic-intensity estimation.
//!
//! The paper's FPGA path narrows offload candidates with an "arithmetic
//! intensity analysis tool" (§3.4 B / §2): high-intensity loops amortize
//! the transfer and reconfiguration cost of the device. We compute the
//! classic proxy: arithmetic operations per memory access, scaled by the
//! estimated trip count — entirely static, from the AST.

use crate::parser::ast::*;

/// Static intensity report for a loop (nest).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct IntensityReport {
    /// Arithmetic ops (+,-,*,/,% and math calls) per iteration.
    pub flops_per_iter: u64,
    /// Array element reads+writes per iteration.
    pub mem_per_iter: u64,
    /// Estimated total iterations of the nest (None = symbolic bounds).
    pub trips: Option<u64>,
    /// flops / mem ratio (0 when no memory traffic).
    pub ratio: f64,
    /// ratio × trips — the ranking score used for FPGA narrowing.
    pub score: f64,
}

/// Count one expression node (callers walk the tree; `walk_exprs` visits
/// every node exactly once).
fn count_node(n: &Expr, flops: &mut u64, mem: &mut u64) {
    match &n.kind {
        ExprKind::Binary(op, ..) if op.is_arith() => *flops += 1,
        ExprKind::Call(name, _)
            if crate::interp::builtins::math1(name).is_some()
                || crate::interp::builtins::math2(name).is_some() =>
        {
            // A libm call is several flops; 4 is the conventional proxy.
            *flops += 4;
        }
        // Count one access per index *chain*: only the innermost link
        // (whose base is not itself an Index) so a[i][j] counts once.
        ExprKind::Index(base, _) if !matches!(base.kind, ExprKind::Index(..)) => {
            *mem += 1;
        }
        _ => {}
    }
}

/// Compute the intensity report for a `for` statement.
pub fn intensity_of_loop(s: &Stmt) -> IntensityReport {
    let StmtKind::For { body, .. } = &s.kind else {
        return IntensityReport::default();
    };
    let mut flops = 0u64;
    let mut mem = 0u64;
    // Count the innermost body once (per-iteration cost of the nest).
    let mut cur: &Stmt = body;
    loop {
        let inner = match &cur.kind {
            StmtKind::For { body, .. } => Some(body.as_ref()),
            StmtKind::Block(stmts) if stmts.len() == 1 => match &stmts[0].kind {
                StmtKind::For { body, .. } => Some(body.as_ref()),
                _ => None,
            },
            _ => None,
        };
        match inner {
            Some(b) => cur = b,
            None => break,
        }
    }
    cur.walk_exprs(&mut |e| count_node(e, &mut flops, &mut mem));

    let trips = super::loops::nest_trip_count(s);
    let ratio = if mem == 0 { flops as f64 } else { flops as f64 / mem as f64 };
    let score = ratio * trips.unwrap_or(1) as f64;
    IntensityReport { flops_per_iter: flops, mem_per_iter: mem, trips, ratio, score }
}

/// Rank loops by intensity score, highest first (FPGA narrowing order).
pub fn rank_by_intensity<'a>(loops: &[&'a Stmt]) -> Vec<(&'a Stmt, IntensityReport)> {
    let mut v: Vec<(&Stmt, IntensityReport)> =
        loops.iter().map(|s| (*s, intensity_of_loop(s))).collect();
    v.sort_by(|a, b| b.1.score.partial_cmp(&a.1.score).unwrap());
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parser::parse;

    fn first_loop(src: &str) -> Stmt {
        let prog = parse(src).unwrap();
        let f = prog.functions().next().unwrap();
        let mut found = None;
        f.body.as_ref().unwrap().walk(&mut |s| {
            if matches!(s.kind, StmtKind::For { .. }) && found.is_none() {
                found = Some(s.clone());
            }
        });
        found.unwrap()
    }

    #[test]
    fn counts_flops_and_mem() {
        let l = first_loop(
            "void f(double a[], double b[]) { for (int i = 0; i < 100; i++) a[i] = b[i] * 2.0 + 1.0; }",
        );
        let r = intensity_of_loop(&l);
        assert_eq!(r.flops_per_iter, 2); // * and +
        assert_eq!(r.mem_per_iter, 2); // a[i], b[i]
        assert_eq!(r.trips, Some(100));
        assert!(r.score > 0.0);
    }

    #[test]
    fn math_calls_weighted() {
        let l = first_loop(
            "void f(double a[]) { for (int i = 0; i < 10; i++) a[i] = sin(a[i]); }",
        );
        let r = intensity_of_loop(&l);
        assert!(r.flops_per_iter >= 4);
    }

    #[test]
    fn nest_counts_inner_body_with_product_trips() {
        let l = first_loop(
            "void f(double c[][32], double a[][32], double b[][32]) {
                for (int i = 0; i < 32; i++)
                    for (int j = 0; j < 32; j++)
                        c[i][j] = a[i][j] + b[i][j];
            }",
        );
        let r = intensity_of_loop(&l);
        assert_eq!(r.trips, Some(1024));
        assert_eq!(r.mem_per_iter, 3);
    }

    #[test]
    fn ranking_prefers_denser_loops() {
        let small = first_loop(
            "void f(double a[]) { for (int i = 0; i < 4; i++) a[i] = a[i] + 1.0; }",
        );
        let big = first_loop(
            "void g(double a[]) { for (int i = 0; i < 10000; i++) a[i] = sin(a[i]) * cos(a[i]); }",
        );
        let ranked = rank_by_intensity(&[&small, &big]);
        assert_eq!(ranked[0].1.trips, Some(10000));
    }
}
