//! Step-1 code analysis: the facts every later stage consumes.
//!
//! Mirrors the paper's use of Clang syntax analysis (§4.2): from one parse
//! we extract
//!
//! * **A-1 candidates** — calls to *external* functions (no local body),
//!   plus `#include` hints, to be matched against the code-pattern DB's
//!   library list;
//! * **A-2 candidates** — locally defined functions / structs (potential
//!   copied-code function blocks for the similarity detector);
//! * **loop inventory** — every `for` loop with nest depth, estimated trip
//!   count, parallelizability class, and arithmetic-intensity score (used
//!   by the GA loop baseline and the FPGA candidate narrowing).

pub mod intensity;
pub mod loops;

use std::collections::HashSet;

use crate::parser::ast::*;
use crate::parser::Span;

pub use intensity::{intensity_of_loop, IntensityReport};
pub use loops::{classify_loop, estimate_trip_count, LoopClass, LoopInfo};

/// A call site to a function with no body in this translation unit —
/// an external library call (paper processing A-1).
#[derive(Debug, Clone, PartialEq)]
pub struct ExternalCall {
    /// Name of the called function.
    pub callee: String,
    /// Source location of the call.
    pub span: Span,
    /// AST node id of the call expression.
    pub expr_id: NodeId,
    /// Name of the function the call appears in.
    pub in_function: String,
    /// Number of arguments at the call site.
    pub arg_count: usize,
}

/// A locally defined function block (paper processing A-2 candidate).
#[derive(Debug, Clone)]
pub struct DefinedBlock {
    /// Function name.
    pub name: String,
    /// Source location of the definition.
    pub span: Span,
    /// AST node id of the function definition.
    pub node_id: NodeId,
    /// Statements in the body (size proxy).
    pub stmt_count: usize,
    /// `for`/`while` loops in the body.
    pub loop_count: usize,
}

/// Full analysis result for one translation unit.
#[derive(Debug, Clone, Default)]
pub struct Analysis {
    /// A-1 candidates: calls to functions with no local body.
    pub external_calls: Vec<ExternalCall>,
    /// A-2 candidates: locally defined function blocks.
    pub defined_functions: Vec<DefinedBlock>,
    /// Struct names defined in the unit.
    pub struct_names: Vec<String>,
    /// `#include` hints (library-name evidence for A-1).
    pub includes: Vec<String>,
    /// Every `for` loop with depth, class, and trip estimate.
    pub loops: Vec<LoopInfo>,
}

impl Analysis {
    /// Loops eligible as GA genes: *maximal* offloadable loops — the
    /// bulk executor runs a whole eligible nest, so loops inside an
    /// offloadable ancestor are subsumed by the ancestor's gene.
    pub fn parallel_loops(&self) -> Vec<&LoopInfo> {
        self.loops
            .iter()
            .filter(|l| l.class != LoopClass::Sequential && !l.inside_offloadable)
            .collect()
    }

    /// Distinct external callee names (DB match keys).
    pub fn external_callees(&self) -> Vec<String> {
        let mut seen = HashSet::new();
        let mut out = Vec::new();
        for c in &self.external_calls {
            if seen.insert(c.callee.clone()) {
                out.push(c.callee.clone());
            }
        }
        out
    }
}

/// Analyze a parsed program (paper Step 1).
pub fn analyze(prog: &Program) -> Analysis {
    let defined: HashSet<&str> = prog.defined_names().into_iter().collect();
    let mut out = Analysis {
        includes: prog.includes.clone(),
        struct_names: prog.structs().map(|s| s.name.clone()).collect(),
        ..Default::default()
    };

    for f in prog.functions() {
        let Some(body) = &f.body else { continue };

        // External call sites (A-1).
        body.walk_exprs(&mut |e| {
            if let ExprKind::Call(name, args) = &e.kind {
                if !defined.contains(name.as_str())
                    && !crate::interp::builtins::is_builtin(name)
                {
                    out.external_calls.push(ExternalCall {
                        callee: name.clone(),
                        span: e.span,
                        expr_id: e.id,
                        in_function: f.name.clone(),
                        arg_count: args.len(),
                    });
                }
            }
        });

        // Defined blocks (A-2).
        let mut stmt_count = 0usize;
        let mut loop_count = 0usize;
        body.walk(&mut |s| {
            stmt_count += 1;
            if matches!(s.kind, StmtKind::For { .. } | StmtKind::While(..)) {
                loop_count += 1;
            }
        });
        out.defined_functions.push(DefinedBlock {
            name: f.name.clone(),
            span: f.span,
            node_id: f.id,
            stmt_count,
            loop_count,
        });

        // Loop inventory.
        loops::collect_loops(f, &mut out.loops);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parser::parse;

    const APP: &str = "
        #include <math.h>
        #include <nrfft.h>
        struct Sensor { double calib; int id; };
        void fft2d(double re[], double im[], int n);
        double window(double x) { return 0.5 - 0.5 * cos(x); }
        int main() {
            double re[64][64]; double im[64][64];
            for (int i = 0; i < 64; i++)
                for (int j = 0; j < 64; j++) {
                    re[i][j] = window(i * 0.1) * j;
                    im[i][j] = 0.0;
                }
            fft2d(re, im, 64);
            double s = 0.0;
            for (int i = 0; i < 64; i++)
                for (int j = 0; j < 64; j++)
                    s += re[i][j] * re[i][j] + im[i][j] * im[i][j];
            printf(\"%f\\n\", s);
            return 0;
        }";

    #[test]
    fn finds_external_calls_only() {
        let prog = parse(APP).unwrap();
        let a = analyze(&prog);
        // fft2d is extern (no body); window is defined; cos/printf builtin.
        assert_eq!(a.external_callees(), vec!["fft2d".to_string()]);
        assert_eq!(a.external_calls[0].arg_count, 3);
        assert_eq!(a.external_calls[0].in_function, "main");
    }

    #[test]
    fn records_defined_blocks_and_structs() {
        let prog = parse(APP).unwrap();
        let a = analyze(&prog);
        let names: Vec<_> = a.defined_functions.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["window", "main"]);
        assert_eq!(a.struct_names, vec!["Sensor"]);
        assert_eq!(a.includes, vec!["math.h", "nrfft.h"]);
    }

    #[test]
    fn loop_inventory_counts_and_depths() {
        let prog = parse(APP).unwrap();
        let a = analyze(&prog);
        // Two 2-deep nests = 4 for-loops.
        assert_eq!(a.loops.len(), 4);
        assert_eq!(a.loops.iter().filter(|l| l.depth == 0).count(), 2);
        // Top-level nests: first calls a user function (not offloadable by
        // the bulk executor => Sequential); second is a reduction.
        let top: Vec<_> = a.loops.iter().filter(|l| l.depth == 0).collect();
        assert_eq!(top[0].class, LoopClass::Sequential);
        assert_eq!(top[1].class, LoopClass::Reduction);
    }

    #[test]
    fn parallel_loops_excludes_sequential_and_nested() {
        let prog = parse(APP).unwrap();
        let a = analyze(&prog);
        let genes = a.parallel_loops();
        assert_eq!(genes.len(), 1);
        assert_eq!(genes[0].class, LoopClass::Reduction);
    }
}
