//! Loop inventory + parallelizability classification.
//!
//! The paper's loop baseline first narrows to *parallelizable* loops (a
//! compiler can prove the negative, not the positive — §3.2), then lets the
//! GA search over them. Our classifier asks the same question the bulk
//! executor will: does this loop (nest) compile to an offloadable form, and
//! if so is it elementwise or a reduction?

use crate::interp::offload_exec;
use crate::parser::ast::*;
use crate::parser::Span;

/// Parallelizability class of a `for` loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopClass {
    /// Independent iterations writing arrays (maps to `acc kernels`).
    Elementwise,
    /// Scalar accumulation (maps to `acc parallel reduction`).
    Reduction,
    /// Loop-carried dependence / unsupported shape — CPU only.
    Sequential,
}

/// One `for` loop in the program.
#[derive(Debug, Clone)]
pub struct LoopInfo {
    /// AST node id of the `for` statement.
    pub id: NodeId,
    /// Source location of the loop.
    pub span: Span,
    /// Name of the function containing the loop.
    pub in_function: String,
    /// 0 = outermost loop of a nest.
    pub depth: usize,
    /// Parallelizability class (gene eligibility).
    pub class: LoopClass,
    /// Static trip-count estimate of this loop alone (constant bounds), or
    /// None when bounds are symbolic.
    pub trip_count: Option<u64>,
    /// Trip count of the whole nest rooted here (product over levels that
    /// have constant bounds).
    pub nest_trip_count: Option<u64>,
    /// Statements in the body (size proxy).
    pub body_stmts: usize,
    /// True when an enclosing loop is itself offloadable — offloading the
    /// ancestor subsumes this loop, so it is not a separate GA gene.
    pub inside_offloadable: bool,
}

/// Classify one `for` statement by probing the bulk-executor compiler —
/// the single source of truth for "can the verification environment
/// actually offload this".
pub fn classify_loop(s: &Stmt) -> LoopClass {
    match offload_exec::compile_loop(s) {
        None => LoopClass::Sequential,
        Some(c) => {
            if c.reductions.is_empty() {
                LoopClass::Elementwise
            } else {
                LoopClass::Reduction
            }
        }
    }
}

/// Constant-fold a trip count from `for (i = a; i < b; i += c)` when all
/// three are integer literals.
pub fn estimate_trip_count(s: &Stmt) -> Option<u64> {
    let StmtKind::For { init, cond, step, .. } = &s.kind else {
        return None;
    };
    let lo = match init.as_deref() {
        Some(Stmt { kind: StmtKind::Decl(ds), .. }) if ds.len() == 1 => {
            const_int(ds[0].init.as_ref()?)?
        }
        Some(Stmt { kind: StmtKind::Expr(e), .. }) => match &e.kind {
            ExprKind::Assign(AssignOp::Set, _, r) => const_int(r)?,
            _ => return None,
        },
        _ => return None,
    };
    let (hi, inclusive) = match cond.as_ref()? {
        Expr { kind: ExprKind::Binary(op @ (BinOp::Lt | BinOp::Le), _, b), .. } => {
            (const_int(b)?, matches!(op, BinOp::Le))
        }
        _ => return None,
    };
    let by = match step.as_ref()? {
        Expr { kind: ExprKind::PostIncDec(_, true), .. }
        | Expr { kind: ExprKind::Unary(UnOp::PreInc, _), .. } => 1,
        Expr { kind: ExprKind::Assign(AssignOp::Add, _, r), .. } => const_int(r)?,
        _ => return None,
    };
    if by <= 0 {
        return None;
    }
    let end = if inclusive { hi + 1 } else { hi };
    if end <= lo {
        return Some(0);
    }
    Some(((end - lo + by - 1) / by) as u64)
}

fn const_int(e: &Expr) -> Option<i64> {
    match &e.kind {
        ExprKind::IntLit(v) => Some(*v),
        ExprKind::Unary(UnOp::Neg, inner) => Some(-const_int(inner)?),
        ExprKind::Binary(op, a, b) => {
            let (x, y) = (const_int(a)?, const_int(b)?);
            Some(match op {
                BinOp::Add => x + y,
                BinOp::Sub => x - y,
                BinOp::Mul => x * y,
                BinOp::Div if y != 0 => x / y,
                BinOp::Shl => x << y,
                BinOp::Shr => x >> y,
                _ => return None,
            })
        }
        _ => None,
    }
}

/// Collect every `for` loop in `f` into `out` with depth + class info.
pub fn collect_loops(f: &FuncDef, out: &mut Vec<LoopInfo>) {
    let Some(body) = &f.body else { return };
    walk_depth(body, 0, false, &f.name, out);
}

fn walk_depth(
    s: &Stmt,
    depth: usize,
    ancestor_offloadable: bool,
    func: &str,
    out: &mut Vec<LoopInfo>,
) {
    match &s.kind {
        StmtKind::For { body, .. } => {
            let mut body_stmts = 0usize;
            body.walk(&mut |_| body_stmts += 1);
            let trip = estimate_trip_count(s);
            let nest = nest_trip_count(s);
            let class = classify_loop(s);
            out.push(LoopInfo {
                id: s.id,
                span: s.span,
                in_function: func.to_string(),
                depth,
                class,
                trip_count: trip,
                nest_trip_count: nest,
                body_stmts,
                inside_offloadable: ancestor_offloadable,
            });
            let off = ancestor_offloadable || class != LoopClass::Sequential;
            walk_depth(body, depth + 1, off, func, out);
        }
        StmtKind::Block(stmts) => {
            for st in stmts {
                walk_depth(st, depth, ancestor_offloadable, func, out);
            }
        }
        StmtKind::If(_, t, e) => {
            walk_depth(t, depth, ancestor_offloadable, func, out);
            if let Some(e) = e {
                walk_depth(e, depth, ancestor_offloadable, func, out);
            }
        }
        StmtKind::While(_, b) | StmtKind::DoWhile(b, _) => {
            walk_depth(b, depth, ancestor_offloadable, func, out)
        }
        _ => {}
    }
}

/// Product of constant trip counts down a perfect nest rooted at `s`.
pub fn nest_trip_count(s: &Stmt) -> Option<u64> {
    let mut total = 1u64;
    let mut cur = s;
    loop {
        total = total.checked_mul(estimate_trip_count(cur)?)?;
        let StmtKind::For { body, .. } = &cur.kind else { unreachable!() };
        let inner = match &body.kind {
            StmtKind::For { .. } => Some(body.as_ref()),
            StmtKind::Block(stmts) if stmts.len() == 1 => match &stmts[0].kind {
                StmtKind::For { .. } => Some(&stmts[0]),
                _ => None,
            },
            _ => None,
        };
        match inner {
            Some(f) => cur = f,
            None => return Some(total),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parser::parse;

    fn first_loop(src: &str) -> Stmt {
        let prog = parse(src).unwrap();
        let f = prog.functions().next().unwrap();
        let mut found = None;
        f.body.as_ref().unwrap().walk(&mut |s| {
            if matches!(s.kind, StmtKind::For { .. }) && found.is_none() {
                found = Some(s.clone());
            }
        });
        found.unwrap()
    }

    #[test]
    fn trip_count_simple() {
        let l = first_loop("void f(double a[]) { for (int i = 0; i < 100; i++) a[i] = i; }");
        assert_eq!(estimate_trip_count(&l), Some(100));
    }

    #[test]
    fn trip_count_strided_and_inclusive() {
        let l = first_loop("void f(double a[]) { for (int i = 1; i <= 9; i += 2) a[i] = i; }");
        assert_eq!(estimate_trip_count(&l), Some(5));
    }

    #[test]
    fn trip_count_symbolic_is_none() {
        let l = first_loop("void f(double a[], int n) { for (int i = 0; i < n; i++) a[i] = i; }");
        assert_eq!(estimate_trip_count(&l), None);
    }

    #[test]
    fn classify_elementwise() {
        let l = first_loop(
            "void f(double a[], double b[]) { for (int i = 0; i < 10; i++) a[i] = 2.0 * b[i]; }",
        );
        assert_eq!(classify_loop(&l), LoopClass::Elementwise);
    }

    #[test]
    fn classify_reduction() {
        let l = first_loop(
            "double f(double a[]) { double s = 0.0; for (int i = 0; i < 10; i++) s += a[i]; return s; }",
        );
        assert_eq!(classify_loop(&l), LoopClass::Reduction);
    }

    #[test]
    fn classify_sequential_dependence() {
        let l =
            first_loop("void f(double a[]) { for (int i = 1; i < 10; i++) a[i] = a[i-1] + 1.0; }");
        assert_eq!(classify_loop(&l), LoopClass::Sequential);
    }

    #[test]
    fn nest_trip_count_product() {
        let l = first_loop(
            "void f(double a[][8]) { for (int i = 0; i < 4; i++) for (int j = 0; j < 8; j++) a[i][j] = 0.0; }",
        );
        assert_eq!(nest_trip_count(&l), Some(32));
    }
}
