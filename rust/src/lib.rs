//! `fbo` — automatic GPU / FPGA offloading of application **function blocks**.
//!
//! Reproduction of Yamato, *"Evaluation of Automatic GPU and FPGA Offloading
//! for Function Blocks of Applications"* (2020), built as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: source analysis,
//!   code-pattern DB matching, Deckard-style similarity detection, interface
//!   reconciliation, offload-pattern search with measured verification, and
//!   the GA loop-offload baseline of the prior work.
//! * **Layer 2 / Layer 1 (python/compile)** — JAX graphs + Pallas kernels
//!   standing in for cuFFT / cuSOLVER / cuBLAS, AOT-lowered to HLO text.
//! * **Runtime** — the [`runtime`] module loads `artifacts/*.hlo.txt` via the
//!   PJRT CPU client and executes them from the rust hot path. Python never
//!   runs at request time.
//! * **Service tier** — the [`service`] module turns the one-shot pipeline
//!   into a system: a worker pool of coordinators behind a job queue,
//!   fronted by a persistent content-addressed cache of verified offload
//!   decisions (the paper's expensive measured verification is a one-time
//!   cost; the cache is what makes it one-time across requests and
//!   restarts).
//!
//! * **Backend arbitration** — Step 3b ([`coordinator::backend`]) decides
//!   CPU vs GPU vs FPGA per block: the [`fpga`] substrate models the
//!   Arria10 device, the HLS toolchain's simulated hours, and the resource
//!   pre-check that narrows candidates before the hours-long compile
//!   (DESIGN.md "Backend arbitration").
//!
//! * **Telemetry** — the [`telemetry`] module makes the pipeline's own
//!   behavior observable without changing it: per-request trace spans
//!   and structured events (measurements, verdicts, cache probes) behind
//!   the [`coordinator::StageObserver`] seam, a JSONL sink + Chrome
//!   `trace_event` exporter, and a Prometheus-rendered metrics registry
//!   the service exposes via `fbo serve --metrics-addr` / `fbo stats`.
//!
//! * **Measurement fleet** — the [`fleet`] module distributes Step-3
//!   verification across remote worker processes (`fbo worker`): a
//!   versioned canonical-JSON wire protocol over TCP or spawned-child
//!   stdio, a capability-aware scheduler that deals a verify plan's
//!   independent measurements by estimated cost, and a failure matrix
//!   (death, timeout, no capable worker) that always falls back to the
//!   local executor — decisions stay byte-identical to serial verify.
//!
//! * **Staged pipeline API** — [`coordinator::pipeline`] is the public
//!   shape of the flow: [`coordinator::Coordinator::request`] builds an
//!   [`coordinator::OffloadRequest`] that advances through typed stage
//!   artifacts (`Parsed → Discovered → Reconciled → Verified → Arbitrated
//!   → Placed`), each inspectable, serializable, and resumable; failures
//!   cross the boundary as the structured [`coordinator::OffloadError`].
//!   [`coordinator::Coordinator::offload`] wraps all stages in one call.
//!
//! Start at [`coordinator::Coordinator`] for the end-to-end flow,
//! [`coordinator::OffloadRequest`] for the staged API,
//! [`service::OffloadService`] for the batch/serving tier, or the
//! `examples/` directory for runnable scenarios.

#![warn(missing_docs)]

pub mod analysis;
pub mod coordinator;
pub mod fleet;
pub mod fpga;
pub mod ga;
pub mod interp;
pub mod metrics;
pub mod parser;
pub mod patterndb;
pub mod runtime;
pub mod service;
pub mod similarity;
pub mod telemetry;
pub mod transform;

/// Crate-wide result type (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
