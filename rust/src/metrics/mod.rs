//! Measurement utilities for the verification environment.
//!
//! The paper's method is *measurement-driven*: every candidate pattern is
//! timed on the verification machine and the fastest wins. This module
//! provides robust repeated timing (median-of-k), speedup accounting, and
//! the plain-text report tables the benches print (Fig. 5 shape).

use std::time::{Duration, Instant};

/// Result of measuring one candidate pattern.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// What was measured.
    pub label: String,
    /// Median wall-clock of the repetitions.
    pub median: Duration,
    /// Fastest repetition.
    pub min: Duration,
    /// Slowest repetition.
    pub max: Duration,
    /// Number of measured repetitions.
    pub reps: usize,
}

impl Measurement {
    /// Median wall-clock in seconds.
    pub fn secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Time `f` `reps` times (after `warmup` unmeasured runs), keep the median.
pub fn measure<F: FnMut() -> anyhow::Result<()>>(
    label: &str,
    warmup: usize,
    reps: usize,
    mut f: F,
) -> anyhow::Result<Measurement> {
    for _ in 0..warmup {
        f()?;
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f()?;
        times.push(t0.elapsed());
    }
    times.sort();
    Ok(Measurement {
        label: label.to_string(),
        median: times[times.len() / 2],
        min: times[0],
        max: *times.last().unwrap(),
        reps: times.len(),
    })
}

/// Speedup of `baseline` relative to `candidate` (>1 = candidate faster).
pub fn speedup(baseline: &Measurement, candidate: &Measurement) -> f64 {
    baseline.secs() / candidate.secs().max(1e-12)
}

/// The p-th percentile (0..=100) of a sample set, by linear index
/// interpolation on the sorted samples (p50 of an odd-length set is the
/// median). Returns `None` for an empty set. Used by the service layer's
/// per-service p50/p95 latency counters.
pub fn percentile(samples: &[Duration], p: f64) -> Option<Duration> {
    if samples.is_empty() {
        return None;
    }
    let mut v: Vec<Duration> = samples.to_vec();
    v.sort();
    let pos = (p.clamp(0.0, 100.0) / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        return Some(v[lo]);
    }
    let frac = pos - lo as f64;
    let a = v[lo].as_secs_f64();
    let b = v[hi].as_secs_f64();
    Some(Duration::from_secs_f64(a + (b - a) * frac))
}

/// Fixed-width text table (the benches print Fig. 4 / Fig. 5 analogs).
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row (missing cells render empty).
    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    /// Render the table as aligned plain text.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                let cell = cells.get(i).map(|s| s.as_str()).unwrap_or("");
                line.push_str(&format!(" {cell:<w$} |", w = widths[i]));
            }
            line
        };
        let sep = {
            let mut s = String::from("|");
            for w in &widths {
                s.push_str(&format!("{}|", "-".repeat(w + 2)));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

/// Human-friendly duration (µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}µs")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1000.0)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

/// Human-friendly simulated toolchain time (minutes below one hour, else
/// hours) — the FPGA flow accounts HLS compiles in virtual hours.
pub fn fmt_hours(h: f64) -> String {
    if h < 1.0 {
        format!("{:.0}min", h * 60.0)
    } else {
        format!("{h:.1}h")
    }
}

/// Human-friendly byte count (B/KiB/MiB/GiB), for traffic and residency
/// budgets in reports.
pub fn fmt_bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = b as f64;
    if b < KIB {
        format!("{b:.0}B")
    } else if b < KIB * KIB {
        format!("{:.1}KiB", b / KIB)
    } else if b < KIB * KIB * KIB {
        format!("{:.1}MiB", b / (KIB * KIB))
    } else {
        format!("{:.2}GiB", b / (KIB * KIB * KIB))
    }
}

/// Format a speedup factor the way the paper's Fig. 5 does (2 significant
/// figures, no decimals above 10).
pub fn fmt_speedup(x: f64) -> String {
    if x >= 10.0 {
        format!("{:.0}", x)
    } else {
        format!("{:.1}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_median_of_reps() {
        let m = measure("t", 0, 5, || {
            std::thread::sleep(Duration::from_micros(100));
            Ok(())
        })
        .unwrap();
        assert_eq!(m.reps, 5);
        assert!(m.median >= Duration::from_micros(100));
        assert!(m.min <= m.median && m.median <= m.max);
    }

    #[test]
    fn byte_counts_pick_the_natural_unit() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert_eq!(fmt_bytes(64 << 20), "64.0MiB");
        assert_eq!(fmt_bytes(3 << 30), "3.00GiB");
    }

    #[test]
    fn speedup_ratio() {
        let a = Measurement {
            label: "a".into(),
            median: Duration::from_millis(100),
            min: Duration::ZERO,
            max: Duration::ZERO,
            reps: 1,
        };
        let b = Measurement {
            label: "b".into(),
            median: Duration::from_millis(10),
            min: Duration::ZERO,
            max: Duration::ZERO,
            reps: 1,
        };
        assert!((speedup(&a, &b) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let ms: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        assert_eq!(percentile(&ms, 0.0).unwrap(), Duration::from_millis(1));
        assert_eq!(percentile(&ms, 100.0).unwrap(), Duration::from_millis(100));
        // p50 of 1..=100 ms interpolates halfway between 50 and 51.
        let p50 = percentile(&ms, 50.0).unwrap();
        assert!(p50 >= Duration::from_millis(50) && p50 <= Duration::from_millis(51), "{p50:?}");
        let p95 = percentile(&ms, 95.0).unwrap();
        assert!(p95 >= Duration::from_millis(95) && p95 <= Duration::from_millis(96), "{p95:?}");
        // Odd-length set: p50 is the exact median.
        let odd: Vec<Duration> = [3u64, 1, 2].iter().map(|&m| Duration::from_millis(m)).collect();
        assert_eq!(percentile(&odd, 50.0).unwrap(), Duration::from_millis(2));
        assert_eq!(percentile(&[], 50.0), None);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "speedup"]);
        t.row(&["Fourier transform".to_string(), "730".to_string()]);
        t.row(&["Matrix calculation".to_string(), "130000".to_string()]);
        let s = t.render();
        assert!(s.contains("| Fourier transform  | 730     |"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_micros(12)), "12.0µs");
        assert_eq!(fmt_duration(Duration::from_millis(12)), "12.00ms");
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000s");
    }

    #[test]
    fn speedup_formatting() {
        assert_eq!(fmt_speedup(5.43), "5.4");
        assert_eq!(fmt_speedup(730.2), "730");
    }

    #[test]
    fn hours_formatting() {
        assert_eq!(fmt_hours(0.033), "2min");
        assert_eq!(fmt_hours(3.2), "3.2h");
        assert_eq!(fmt_hours(0.0), "0min");
    }
}
