//! Live exposition: a minimal HTTP server for the Prometheus text format.
//!
//! `fbo serve --metrics-addr HOST:PORT` starts one [`MetricsServer`] next
//! to the worker pool; every `GET /metrics` (or `/`) renders the service
//! registry on demand. No external HTTP crate — the exposition format
//! needs exactly one response shape, so a hand-rolled request loop keeps
//! the build offline (DESIGN.md "Substitutions").

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

/// A background thread serving Prometheus text exposition over HTTP/1.1.
///
/// The listener is non-blocking and polled, so [`MetricsServer::stop`]
/// (and `Drop`) shut it down within one poll interval without needing a
/// wake-up connection.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9090`, port 0 for ephemeral) and
    /// serve `render()` on every scrape.
    pub fn start(
        addr: &str,
        render: impl Fn() -> String + Send + 'static,
    ) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding metrics listener on {addr}"))?;
        let local = listener.local_addr().context("reading metrics listener address")?;
        listener.set_nonblocking(true).context("metrics listener nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let handle = thread::Builder::new()
            .name("fbo-metrics".to_string())
            .spawn(move || {
                while !stop_flag.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((conn, _)) => handle_conn(conn, &render),
                        Err(_) => thread::sleep(Duration::from_millis(25)),
                    }
                }
            })
            .context("spawning metrics server thread")?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the server and join its thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_conn(mut conn: TcpStream, render: &(impl Fn() -> String + Send + 'static)) {
    // Accepted sockets can inherit the listener's non-blocking mode on
    // some platforms; force blocking with a bounded read timeout.
    let _ = conn.set_nonblocking(false);
    let _ = conn.set_read_timeout(Some(Duration::from_secs(2)));
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        match conn.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let request = String::from_utf8_lossy(&buf);
    let path = request.split_whitespace().nth(1).unwrap_or("");
    let (status, body) = if path == "/metrics" || path == "/" {
        ("200 OK", render())
    } else {
        ("404 Not Found", "not found\n".to_string())
    };
    let response = format!(
        "HTTP/1.1 {status}\r\n\
         Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = conn.write_all(response.as_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scrape(addr: SocketAddr, path: &str) -> String {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        write!(conn, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        let _ = conn.read_to_string(&mut out);
        out
    }

    #[test]
    fn serves_metrics_and_404s_everything_else() {
        let server =
            MetricsServer::start("127.0.0.1:0", || "fbo_up 1\n".to_string()).unwrap();
        let ok = scrape(server.addr(), "/metrics");
        assert!(ok.starts_with("HTTP/1.1 200 OK"), "{ok}");
        assert!(ok.contains("text/plain; version=0.0.4"), "{ok}");
        assert!(ok.contains("fbo_up 1"), "{ok}");
        let root = scrape(server.addr(), "/");
        assert!(root.contains("fbo_up 1"), "{root}");
        let missing = scrape(server.addr(), "/nope");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");
        server.stop();
    }
}
