//! End-to-end offload **telemetry**: trace spans, a metrics registry,
//! and live service exposition.
//!
//! The paper's method is measurement-driven end to end — Step 3 times
//! every candidate pattern, Step 3b arbitrates on measured seconds, and
//! Step 6 performs operational verification before handing the offloaded
//! app over (arXiv:2005.04174; the function-block proposal
//! arXiv:2004.09883 makes the operational check explicit). This module
//! is the substrate that makes the pipeline's *own* behavior observable
//! the same way:
//!
//! * [`trace`] — every `OffloadRequest` gets a **trace id**, every stage
//!   a **span**, and structured instant events record each pattern
//!   measurement, power score, arbitration verdict, cache-tier probe,
//!   stage resume, and measurement fan-out. The [`TraceRecorder`] keeps a
//!   bounded ring, mirrors records to a JSONL sink (`--trace-out FILE`),
//!   and exports Chrome `trace_event` JSON for `chrome://tracing` /
//!   Perfetto.
//! * [`metrics`] — counters, gauges, and log-linear [`Histogram`]s with
//!   Prometheus text exposition; the service pool registers its job,
//!   cache-tier, queue-depth, worker-utilization, and per-stage latency
//!   series here.
//! * [`export`] — the `fbo serve --metrics-addr HOST:PORT` scrape
//!   endpoint.
//!
//! **Passivity invariant**: telemetry observes, it never decides. A
//! traced run's decisions, transformed source, and report JSON are
//! byte-identical to an untraced run, and [`TelemetryConfig`] is
//! deliberately excluded from every cache fingerprint (like
//! `verify_parallel`: it changes how the run is *watched*, never what it
//! computes). Tests and the `telemetry_trace` bench gate assert this.

pub mod export;
pub mod metrics;
pub mod trace;

use std::path::PathBuf;

pub use export::MetricsServer;
pub use metrics::{Counter, Gauge, Histogram, Registry};
pub use trace::{TraceEvent, TraceObserver, TraceRecord, TraceRecorder};

/// Default [`TraceRecorder`] ring capacity (records kept in memory).
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// Telemetry settings on a service config.
///
/// Strictly passive: this struct is excluded from every cache
/// fingerprint, so toggling tracing never invalidates (or forks) cached
/// decisions — asserted by the service pool's fingerprint tests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TelemetryConfig {
    /// JSONL sink every trace record is mirrored to (`--trace-out`);
    /// `None` keeps records in the in-memory ring only.
    pub trace_out: Option<PathBuf>,
    /// Ring-buffer capacity of the service's [`TraceRecorder`].
    pub ring_capacity: usize,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { trace_out: None, ring_capacity: DEFAULT_RING_CAPACITY }
    }
}
