//! Metrics registry: counters, gauges, log-linear histograms, and
//! Prometheus text exposition (format 0.0.4).
//!
//! The service pool registers its counters here instead of hand-rolling
//! them: per-stage and end-to-end latencies land in [`Histogram`]s (fixed
//! memory, lock-free recording — replacing the clone-and-sort percentile
//! path for service snapshots), cache probes land in labeled counter
//! families, and `fbo serve --metrics-addr` / `fbo stats --format prom`
//! render the whole registry with [`Registry::render`].

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time gauge (an `f64` that can move both ways).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Set the value.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    /// Add a (possibly negative) delta.
    pub fn add(&self, delta: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + delta).to_bits();
            match self.0.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }
}

/// Bucket upper bounds: octaves of 2 from 1 µs-ish (2¹⁰ ns) to ≈4.6 min
/// (2³⁸ ns), each octave split into 4 linear sub-buckets. Strictly
/// increasing; an implicit overflow (`+Inf`) bucket catches the rest.
fn bucket_bounds() -> Vec<u64> {
    let mut bounds = vec![1u64 << 10];
    for octave in 10..38 {
        let base = 1u64 << octave;
        for step in 1..=4u64 {
            bounds.push(base + (base / 4) * step);
        }
    }
    bounds
}

/// Log-linear latency histogram over nanosecond samples.
///
/// Recording is lock-free and O(log buckets); memory is fixed (113
/// bounds + overflow) regardless of sample count — this is what backs
/// the service latency percentiles instead of cloning and sorting the
/// full sample vector on every snapshot. Quantiles are read from the
/// bucket upper bound, so their error is at most one sub-bucket (≤ 25%
/// relative — plenty for operational p50/p95, not for benchmarking;
/// bench-side code keeps exact [`crate::metrics::percentile`]).
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<u64>,
    counts: Vec<AtomicU64>,
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram with the standard latency bounds.
    pub fn new() -> Histogram {
        let bounds = bucket_bounds();
        let counts = (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect();
        Histogram { bounds, counts, sum_ns: AtomicU64::new(0) }
    }

    /// Record one duration.
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    /// Record one nanosecond sample.
    pub fn record_ns(&self, ns: u64) {
        let idx = self.bounds.partition_point(|&b| b < ns);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Sum of all recorded samples.
    pub fn sum(&self) -> Duration {
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed))
    }

    /// Nearest-rank quantile (`q` in 0..=1), read from the bucket upper
    /// bound. `None` when nothing was recorded. Overflow samples report
    /// the largest finite bound.
    pub fn quantile(&self, q: f64) -> Option<Duration> {
        let total = self.count();
        if total == 0 {
            return None;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum >= rank {
                let bound = self.bounds.get(i).or_else(|| self.bounds.last());
                return bound.map(|&ns| Duration::from_nanos(ns));
            }
        }
        None
    }

    /// `(upper_bound_ns, cumulative_count)` per non-empty bucket, in
    /// order; `None` bound marks the overflow (`+Inf`) bucket.
    pub fn cumulative(&self) -> Vec<(Option<u64>, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            let n = c.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            cum += n;
            out.push((self.bounds.get(i).copied(), cum));
        }
        out
    }
}

#[derive(Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

struct Family {
    help: String,
    kind: &'static str,
    metrics: BTreeMap<String, Metric>,
}

/// A registry of metric families, each a set of label-distinguished
/// series. Registration is idempotent: asking for the same
/// (name, labels) again returns the existing handle, so every part of
/// the service can `counter(...)` its way to a shared series.
#[derive(Default)]
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
}

fn render_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut sorted: Vec<&(&str, &str)> = labels.iter().collect();
    sorted.sort();
    let parts: Vec<String> = sorted.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    format!("{{{}}}", parts.join(","))
}

fn with_le(labels: &str, le: &str) -> String {
    if labels.is_empty() {
        format!("{{le=\"{le}\"}}")
    } else {
        format!("{},le=\"{le}\"}}", &labels[..labels.len() - 1])
    }
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    fn slot(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Metric,
    ) -> Metric {
        let mut families = self.families.lock().unwrap();
        let family = families.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind: "",
            metrics: BTreeMap::new(),
        });
        let metric =
            family.metrics.entry(render_labels(labels)).or_insert_with(make).clone();
        if family.kind.is_empty() {
            family.kind = metric.kind();
        }
        assert_eq!(
            family.kind,
            metric.kind(),
            "metric family {name:?} registered with conflicting kinds"
        );
        metric
    }

    /// Get or create a counter series. Panics if `name` already holds a
    /// family of a different kind.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        match self.slot(name, help, labels, || Metric::Counter(Arc::new(Counter::default()))) {
            Metric::Counter(c) => c,
            _ => unreachable!("kind checked in slot"),
        }
    }

    /// Get or create a gauge series. Panics if `name` already holds a
    /// family of a different kind.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        match self.slot(name, help, labels, || Metric::Gauge(Arc::new(Gauge::default()))) {
            Metric::Gauge(g) => g,
            _ => unreachable!("kind checked in slot"),
        }
    }

    /// Get or create a histogram series. Panics if `name` already holds
    /// a family of a different kind.
    pub fn histogram(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        match self.slot(name, help, labels, || Metric::Histogram(Arc::new(Histogram::new()))) {
            Metric::Histogram(h) => h,
            _ => unreachable!("kind checked in slot"),
        }
    }

    /// Render every family in the Prometheus text exposition format
    /// (0.0.4). Histogram `le` bounds and sums are in **seconds**, per
    /// convention; only non-empty buckets are emitted (plus `+Inf`).
    pub fn render(&self) -> String {
        let families = self.families.lock().unwrap();
        let mut out = String::new();
        for (name, fam) in families.iter() {
            let _ = writeln!(out, "# HELP {name} {}", fam.help);
            let _ = writeln!(out, "# TYPE {name} {}", fam.kind);
            for (labels, metric) in &fam.metrics {
                match metric {
                    Metric::Counter(c) => {
                        let _ = writeln!(out, "{name}{labels} {}", c.get());
                    }
                    Metric::Gauge(g) => {
                        let _ = writeln!(out, "{name}{labels} {}", g.get());
                    }
                    Metric::Histogram(h) => {
                        for (bound, cum) in h.cumulative() {
                            if let Some(ns) = bound {
                                let le = format!("{}", ns as f64 / 1e9);
                                let _ =
                                    writeln!(out, "{name}_bucket{} {cum}", with_le(labels, &le));
                            }
                        }
                        let total = h.count();
                        let _ =
                            writeln!(out, "{name}_bucket{} {total}", with_le(labels, "+Inf"));
                        let _ = writeln!(
                            out,
                            "{name}_sum{labels} {}",
                            h.sum().as_nanos() as f64 / 1e9
                        );
                        let _ = writeln!(out, "{name}_count{labels} {total}");
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        assert_eq!(g.get(), 0.0);
        g.set(2.5);
        g.add(-1.0);
        assert!((g.get() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_bounds_are_strictly_increasing() {
        let bounds = bucket_bounds();
        assert_eq!(bounds.len(), 113);
        assert!(bounds.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(bounds[0], 1024);
        assert_eq!(*bounds.last().unwrap(), 1u64 << 38);
    }

    #[test]
    fn histogram_quantiles_bracket_the_samples() {
        let h = Histogram::new();
        assert!(h.quantile(0.5).is_none(), "empty histogram has no quantiles");
        for ms in [1u64, 2, 3, 4, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), Duration::from_millis(110));
        let p50 = h.quantile(0.5).unwrap();
        // The true median is 3 ms; the bucketed answer must be within one
        // sub-bucket (25%) above it and never below the sample.
        assert!(p50 >= Duration::from_millis(3), "p50 {p50:?}");
        assert!(p50 <= Duration::from_micros(3_750), "p50 {p50:?}");
        let p95 = h.quantile(0.95).unwrap();
        assert!(p95 >= Duration::from_millis(100), "p95 {p95:?}");
        assert!(p95 <= Duration::from_millis(125), "p95 {p95:?}");
        // Overflow samples clamp to the largest finite bound.
        h.record(Duration::from_secs(3600));
        assert_eq!(h.quantile(1.0).unwrap(), Duration::from_nanos(1 << 38));
    }

    #[test]
    fn registry_is_idempotent_and_shares_series() {
        let r = Registry::new();
        let a = r.counter("fbo_jobs_total", "jobs", &[]);
        let b = r.counter("fbo_jobs_total", "jobs", &[]);
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2, "same (name, labels) is the same series");
        let hit = r.counter("fbo_cache_total", "probes", &[("result", "hit")]);
        let miss = r.counter("fbo_cache_total", "probes", &[("result", "miss")]);
        hit.inc();
        assert_eq!(miss.get(), 0, "distinct labels are distinct series");
    }

    #[test]
    fn render_emits_prometheus_text_format() {
        let r = Registry::new();
        r.counter("fbo_jobs_total", "Jobs completed.", &[]).add(3);
        r.gauge("fbo_queue_depth", "Queue depth.", &[]).set(2.0);
        let h = r.histogram("fbo_job_seconds", "Job latency.", &[("stage", "verify")]);
        h.record(Duration::from_millis(2));
        let text = r.render();
        assert!(text.contains("# HELP fbo_jobs_total Jobs completed."), "{text}");
        assert!(text.contains("# TYPE fbo_jobs_total counter"), "{text}");
        assert!(text.contains("fbo_jobs_total 3"), "{text}");
        assert!(text.contains("fbo_queue_depth 2"), "{text}");
        assert!(text.contains("# TYPE fbo_job_seconds histogram"), "{text}");
        assert!(text.contains("fbo_job_seconds_bucket{stage=\"verify\",le=\""), "{text}");
        assert!(text.contains("fbo_job_seconds_bucket{stage=\"verify\",le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("fbo_job_seconds_count{stage=\"verify\"} 1"), "{text}");
        // Labels render sorted by key, so series names are canonical.
        assert_eq!(render_labels(&[("tier", "decision"), ("result", "hit")]),
            "{result=\"hit\",tier=\"decision\"}");
    }
}
