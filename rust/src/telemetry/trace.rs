//! Trace model and recorder: structured events, spans, and exporters.
//!
//! Every pipeline run is a **trace** (one id per `OffloadRequest` /
//! service job); every completed stage is a **span** (a
//! [`TraceEvent::StageCompleted`] record carrying the stage wall-clock);
//! everything else the pipeline decides — pattern measurements, power
//! scores, arbitration verdicts, cache probes, stage resumes, measurement
//! fan-out — is an instant event. Records are kept in a bounded ring
//! buffer, optionally mirrored line-by-line to a JSONL sink
//! (`--trace-out`), and exported to the Chrome `trace_event` format so a
//! run opens directly in `chrome://tracing` / Perfetto.
//!
//! The JSONL codec is canonical: objects serialize with sorted keys and
//! no whitespace ([`crate::patterndb::json::to_string_compact`]), so a
//! record round-trips byte-identically — the golden fixture under
//! `tests/fixtures/` pins the schema.

use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{Stage, StageObserver};
use crate::patterndb::json::{self, Json};

/// One structured telemetry event. The `"event"` JSON field is the
/// discriminator; every variant serializes flat (no nesting) so lines
/// stay grep-able and schema-stable.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A pipeline run began for `entry`.
    RequestStarted {
        /// Entry-point function name of the request.
        entry: String,
    },
    /// One pipeline stage completed: a span of `wall_ns` ending at the
    /// record's `ts_ns`.
    StageCompleted {
        /// Which stage completed.
        stage: Stage,
        /// Stage wall-clock in nanoseconds.
        wall_ns: u64,
    },
    /// The analytic estimator scored one discovered candidate against
    /// the active device profiles (before any measurement ran).
    EstimatorScored {
        /// Site label of the block (`call:fft2d`, `func:my_decomp`).
        label: String,
        /// Backend the estimate favors (`cpu`, `gpu`, `fpga`).
        backend: String,
        /// Predicted device wall-clock for the block (seconds).
        predicted_secs: f64,
        /// Predicted speedup over the CPU baseline for this block.
        speedup: f64,
        /// Whether the active prune policy withholds the block from
        /// measurement.
        pruned: bool,
    },
    /// Step 3 measured one candidate pattern (the baseline included).
    PatternMeasured {
        /// Pattern label (`all-CPU`, `only:<site>`, `combined-winners`).
        label: String,
        /// Repetitions measured.
        reps: u64,
        /// Median wall-clock across reps (ns).
        median_ns: u64,
        /// Fastest rep (ns).
        min_ns: u64,
        /// Slowest rep (ns).
        max_ns: u64,
        /// Bytes staged to the device per run.
        bytes_in: u64,
        /// Bytes read back from the device per run.
        bytes_out: u64,
        /// Device dispatches per run.
        dispatches: u64,
        /// Seconds spent on the device per run.
        device_secs: f64,
    },
    /// The power stage scored one pattern (or the all-CPU baseline).
    PowerScored {
        /// Pattern label (`all-CPU` for the baseline row).
        label: String,
        /// Average modeled draw across the run (W).
        watts: f64,
        /// Modeled energy per run (J).
        joules: f64,
        /// Energy-efficiency ratio vs the all-CPU baseline.
        efficiency: f64,
    },
    /// Step-3b arbitration decided one block.
    ArbitrationVerdict {
        /// Site label of the block.
        label: String,
        /// Winning backend name (`cpu`, `gpu`, `fpga`).
        winner: String,
        /// Closest losing backend (`none` when nothing competed).
        loser: String,
        /// Seconds between the loser's and winner's candidate times
        /// (0 when the two are not directly comparable).
        margin_secs: f64,
        /// Backend policy the arbitration ran under.
        policy: String,
    },
    /// The device data plane elided host<->device transfers for one
    /// arbitrated block (`--resident-bytes`). Emitted only when a
    /// nonzero residency budget shaped the run — an untraced or
    /// zero-budget pipeline never produces this event.
    ResidencyElided {
        /// Site label of the block.
        label: String,
        /// Host->device bytes elided per run (inputs already resident).
        elided_in: u64,
        /// Device->host bytes elided per run (outputs handed on-device).
        elided_out: u64,
        /// Modeled PCIe transfer seconds saved per run.
        saved_secs: f64,
    },
    /// The service probed one cache tier for a job.
    CacheProbe {
        /// Tier name: `decision`, `verified`, `reconciled`, `estimated`,
        /// or `power-scored`.
        tier: String,
        /// Whether the probe hit.
        hit: bool,
    },
    /// A corrupt cache artifact was detected (warn level): a file that
    /// claims the decision-cache format but cannot be loaded, or is not
    /// valid JSON at all. The entry degrades to a cache miss; this event
    /// (and `fbo_cache_corrupt_total`) make the rot visible instead of
    /// silently ignored. Recorded under trace id 0 — corruption belongs
    /// to the store, not to any one request.
    CacheCorrupt {
        /// Path of the offending file.
        path: String,
        /// Why it failed to load.
        detail: String,
    },
    /// A job resumed from a cached stage artifact: every stage up to and
    /// including `from` was skipped, so the trace carries spans only for
    /// the re-run stages.
    Resumed {
        /// Deepest cached stage the job resumed from.
        from: Stage,
    },
    /// The pooled verify executor dealt one measurement batch.
    MeasureDispatch {
        /// Measurements fanned out to idle sibling engines.
        fanned: u64,
        /// Measurements run on the local engine.
        local: u64,
    },
    /// The fleet scheduler finished one remote measure batch (span of
    /// `wall_ns` ending at the record's `ts_ns`).
    FleetBatch {
        /// Worker name (`tcp:host:port#i` / `stdio:prog#i`).
        worker: String,
        /// Patterns whose outcomes the batch delivered (0 on error or
        /// timeout).
        patterns: u64,
        /// Dispatch-to-outcome wall-clock in nanoseconds.
        wall_ns: u64,
        /// `ok`, `error` (worker died mid-batch), or `timeout`.
        outcome: String,
    },
    /// The fleet scheduler attempted to re-dial a dead TCP worker before
    /// dealing a batch (jittered exponential backoff, bounded attempts).
    FleetReconnect {
        /// Worker name (`tcp:host:port#i`).
        worker: String,
        /// 1-based re-dial attempt number for this outage.
        attempt: u64,
        /// Backoff delay slept before the attempt (milliseconds).
        delay_ms: u64,
        /// Whether the re-dial restored the worker.
        ok: bool,
    },
    /// A pipeline run finished.
    RequestCompleted {
        /// Whether the result came from the decision cache.
        from_cache: bool,
        /// Whether the run succeeded.
        ok: bool,
    },
}

impl TraceEvent {
    /// Canonical event name — the JSONL `"event"` discriminator.
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::RequestStarted { .. } => "request-started",
            TraceEvent::StageCompleted { .. } => "stage",
            TraceEvent::EstimatorScored { .. } => "estimate",
            TraceEvent::PatternMeasured { .. } => "pattern",
            TraceEvent::PowerScored { .. } => "power",
            TraceEvent::ArbitrationVerdict { .. } => "verdict",
            TraceEvent::ResidencyElided { .. } => "residency",
            TraceEvent::CacheProbe { .. } => "cache",
            TraceEvent::CacheCorrupt { .. } => "cache-corrupt",
            TraceEvent::Resumed { .. } => "resumed",
            TraceEvent::MeasureDispatch { .. } => "dispatch",
            TraceEvent::FleetBatch { .. } => "fleet",
            TraceEvent::FleetReconnect { .. } => "fleet-reconnect",
            TraceEvent::RequestCompleted { .. } => "request-completed",
        }
    }
}

/// One recorded telemetry event: the event payload plus the common
/// envelope every record carries (trace id, per-recorder sequence number,
/// nanoseconds since the recorder's epoch).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    /// Trace (request/job) id the event belongs to.
    pub trace: u64,
    /// Monotonic sequence number across the whole recorder.
    pub seq: u64,
    /// Nanoseconds since the recorder was created.
    pub ts_ns: u64,
    /// The event payload.
    pub event: TraceEvent,
}

fn as_bool(v: &Json) -> Result<bool> {
    match v {
        Json::Bool(b) => Ok(*b),
        other => bail!("expected JSON bool, got {other:?}"),
    }
}

fn get_u64(v: &Json, key: &str) -> Result<u64> {
    Ok(v.get(key)?.as_f64()? as u64)
}

fn get_f64(v: &Json, key: &str) -> Result<f64> {
    v.get(key)?.as_f64()
}

fn get_str(v: &Json, key: &str) -> Result<String> {
    Ok(v.get(key)?.as_str()?.to_string())
}

fn get_bool(v: &Json, key: &str) -> Result<bool> {
    as_bool(v.get(key)?)
}

impl TraceRecord {
    /// Serialize to the canonical (flat) JSON value.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("event", Json::str(self.event.name())),
            ("trace", Json::num(self.trace as f64)),
            ("seq", Json::num(self.seq as f64)),
            ("ts_ns", Json::num(self.ts_ns as f64)),
        ];
        match &self.event {
            TraceEvent::RequestStarted { entry } => {
                pairs.push(("entry", Json::str(entry)));
            }
            TraceEvent::StageCompleted { stage, wall_ns } => {
                pairs.push(("stage", Json::str(stage.as_str())));
                pairs.push(("wall_ns", Json::num(*wall_ns as f64)));
            }
            TraceEvent::EstimatorScored { label, backend, predicted_secs, speedup, pruned } => {
                pairs.push(("label", Json::str(label)));
                pairs.push(("backend", Json::str(backend)));
                pairs.push(("predicted_secs", Json::num(*predicted_secs)));
                pairs.push(("speedup", Json::num(*speedup)));
                pairs.push(("pruned", Json::Bool(*pruned)));
            }
            TraceEvent::PatternMeasured {
                label,
                reps,
                median_ns,
                min_ns,
                max_ns,
                bytes_in,
                bytes_out,
                dispatches,
                device_secs,
            } => {
                pairs.push(("label", Json::str(label)));
                pairs.push(("reps", Json::num(*reps as f64)));
                pairs.push(("median_ns", Json::num(*median_ns as f64)));
                pairs.push(("min_ns", Json::num(*min_ns as f64)));
                pairs.push(("max_ns", Json::num(*max_ns as f64)));
                pairs.push(("bytes_in", Json::num(*bytes_in as f64)));
                pairs.push(("bytes_out", Json::num(*bytes_out as f64)));
                pairs.push(("dispatches", Json::num(*dispatches as f64)));
                pairs.push(("device_secs", Json::num(*device_secs)));
            }
            TraceEvent::PowerScored { label, watts, joules, efficiency } => {
                pairs.push(("label", Json::str(label)));
                pairs.push(("watts", Json::num(*watts)));
                pairs.push(("joules", Json::num(*joules)));
                pairs.push(("efficiency", Json::num(*efficiency)));
            }
            TraceEvent::ArbitrationVerdict { label, winner, loser, margin_secs, policy } => {
                pairs.push(("label", Json::str(label)));
                pairs.push(("winner", Json::str(winner)));
                pairs.push(("loser", Json::str(loser)));
                pairs.push(("margin_secs", Json::num(*margin_secs)));
                pairs.push(("policy", Json::str(policy)));
            }
            TraceEvent::ResidencyElided { label, elided_in, elided_out, saved_secs } => {
                pairs.push(("label", Json::str(label)));
                pairs.push(("elided_in", Json::num(*elided_in as f64)));
                pairs.push(("elided_out", Json::num(*elided_out as f64)));
                pairs.push(("saved_secs", Json::num(*saved_secs)));
            }
            TraceEvent::CacheProbe { tier, hit } => {
                pairs.push(("tier", Json::str(tier)));
                pairs.push(("hit", Json::Bool(*hit)));
            }
            TraceEvent::CacheCorrupt { path, detail } => {
                pairs.push(("path", Json::str(path)));
                pairs.push(("detail", Json::str(detail)));
            }
            TraceEvent::Resumed { from } => {
                pairs.push(("from", Json::str(from.as_str())));
            }
            TraceEvent::MeasureDispatch { fanned, local } => {
                pairs.push(("fanned", Json::num(*fanned as f64)));
                pairs.push(("local", Json::num(*local as f64)));
            }
            TraceEvent::FleetBatch { worker, patterns, wall_ns, outcome } => {
                pairs.push(("worker", Json::str(worker)));
                pairs.push(("patterns", Json::num(*patterns as f64)));
                pairs.push(("wall_ns", Json::num(*wall_ns as f64)));
                pairs.push(("outcome", Json::str(outcome)));
            }
            TraceEvent::FleetReconnect { worker, attempt, delay_ms, ok } => {
                pairs.push(("worker", Json::str(worker)));
                pairs.push(("attempt", Json::num(*attempt as f64)));
                pairs.push(("delay_ms", Json::num(*delay_ms as f64)));
                pairs.push(("ok", Json::Bool(*ok)));
            }
            TraceEvent::RequestCompleted { from_cache, ok } => {
                pairs.push(("from_cache", Json::Bool(*from_cache)));
                pairs.push(("ok", Json::Bool(*ok)));
            }
        }
        Json::obj(pairs)
    }

    /// Decode from a JSON value (inverse of [`TraceRecord::to_json`]).
    pub fn from_json(v: &Json) -> Result<TraceRecord> {
        let name = v.get("event")?.as_str()?.to_string();
        let event = match name.as_str() {
            "request-started" => TraceEvent::RequestStarted { entry: get_str(v, "entry")? },
            "stage" => TraceEvent::StageCompleted {
                stage: Stage::parse(v.get("stage")?.as_str()?)?,
                wall_ns: get_u64(v, "wall_ns")?,
            },
            "estimate" => TraceEvent::EstimatorScored {
                label: get_str(v, "label")?,
                backend: get_str(v, "backend")?,
                predicted_secs: get_f64(v, "predicted_secs")?,
                speedup: get_f64(v, "speedup")?,
                pruned: get_bool(v, "pruned")?,
            },
            "pattern" => TraceEvent::PatternMeasured {
                label: get_str(v, "label")?,
                reps: get_u64(v, "reps")?,
                median_ns: get_u64(v, "median_ns")?,
                min_ns: get_u64(v, "min_ns")?,
                max_ns: get_u64(v, "max_ns")?,
                bytes_in: get_u64(v, "bytes_in")?,
                bytes_out: get_u64(v, "bytes_out")?,
                dispatches: get_u64(v, "dispatches")?,
                device_secs: get_f64(v, "device_secs")?,
            },
            "power" => TraceEvent::PowerScored {
                label: get_str(v, "label")?,
                watts: get_f64(v, "watts")?,
                joules: get_f64(v, "joules")?,
                efficiency: get_f64(v, "efficiency")?,
            },
            "verdict" => TraceEvent::ArbitrationVerdict {
                label: get_str(v, "label")?,
                winner: get_str(v, "winner")?,
                loser: get_str(v, "loser")?,
                margin_secs: get_f64(v, "margin_secs")?,
                policy: get_str(v, "policy")?,
            },
            "residency" => TraceEvent::ResidencyElided {
                label: get_str(v, "label")?,
                elided_in: get_u64(v, "elided_in")?,
                elided_out: get_u64(v, "elided_out")?,
                saved_secs: get_f64(v, "saved_secs")?,
            },
            "cache" => TraceEvent::CacheProbe {
                tier: get_str(v, "tier")?,
                hit: get_bool(v, "hit")?,
            },
            "cache-corrupt" => TraceEvent::CacheCorrupt {
                path: get_str(v, "path")?,
                detail: get_str(v, "detail")?,
            },
            "resumed" => TraceEvent::Resumed { from: Stage::parse(v.get("from")?.as_str()?)? },
            "dispatch" => TraceEvent::MeasureDispatch {
                fanned: get_u64(v, "fanned")?,
                local: get_u64(v, "local")?,
            },
            "fleet" => TraceEvent::FleetBatch {
                worker: get_str(v, "worker")?,
                patterns: get_u64(v, "patterns")?,
                wall_ns: get_u64(v, "wall_ns")?,
                outcome: get_str(v, "outcome")?,
            },
            "fleet-reconnect" => TraceEvent::FleetReconnect {
                worker: get_str(v, "worker")?,
                attempt: get_u64(v, "attempt")?,
                delay_ms: get_u64(v, "delay_ms")?,
                ok: get_bool(v, "ok")?,
            },
            "request-completed" => TraceEvent::RequestCompleted {
                from_cache: get_bool(v, "from_cache")?,
                ok: get_bool(v, "ok")?,
            },
            other => bail!("unknown trace event {other:?}"),
        };
        Ok(TraceRecord {
            trace: get_u64(v, "trace")?,
            seq: get_u64(v, "seq")?,
            ts_ns: get_u64(v, "ts_ns")?,
            event,
        })
    }

    /// Serialize to one canonical JSONL line (no trailing newline).
    pub fn to_jsonl_line(&self) -> String {
        json::to_string_compact(&self.to_json())
    }

    /// Decode one JSONL line (inverse of [`TraceRecord::to_jsonl_line`]).
    pub fn from_jsonl_line(line: &str) -> Result<TraceRecord> {
        Self::from_json(&json::parse(line.trim_end())?)
    }
}

struct RecorderState {
    ring: VecDeque<TraceRecord>,
    seq: u64,
    dropped: u64,
    sink: Option<BufWriter<File>>,
    sink_errors: u64,
}

/// Bounded, thread-safe telemetry recorder: a ring buffer of the most
/// recent records, an optional JSONL sink every record is mirrored to,
/// and a Chrome `trace_event` exporter.
///
/// Recording never fails and never blocks the pipeline on I/O errors —
/// sink failures are counted ([`TraceRecorder::sink_errors`]) and
/// otherwise ignored. Telemetry must stay strictly passive.
pub struct TraceRecorder {
    capacity: usize,
    epoch: Instant,
    state: Mutex<RecorderState>,
    next_trace: AtomicU64,
}

impl TraceRecorder {
    /// In-memory recorder keeping at most `capacity` records (clamped to
    /// at least 1).
    pub fn new(capacity: usize) -> TraceRecorder {
        TraceRecorder {
            capacity: capacity.max(1),
            epoch: Instant::now(),
            state: Mutex::new(RecorderState {
                ring: VecDeque::new(),
                seq: 0,
                dropped: 0,
                sink: None,
                sink_errors: 0,
            }),
            next_trace: AtomicU64::new(0),
        }
    }

    /// Recorder that additionally appends every record as one JSONL line
    /// to `path` (truncating any previous file).
    pub fn with_sink(capacity: usize, path: &Path) -> Result<TraceRecorder> {
        let file = File::create(path)
            .with_context(|| format!("creating trace sink {}", path.display()))?;
        let recorder = TraceRecorder::new(capacity);
        recorder.state.lock().unwrap().sink = Some(BufWriter::new(file));
        Ok(recorder)
    }

    /// Allocate the next trace id (ids start at 1).
    pub fn begin_trace(&self) -> u64 {
        self.next_trace.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Record one event under `trace`, stamping the sequence number and
    /// timestamp. Infallible by contract.
    pub fn record(&self, trace: u64, event: TraceEvent) {
        let ts_ns = self.epoch.elapsed().as_nanos() as u64;
        let mut st = self.state.lock().unwrap();
        st.seq += 1;
        let rec = TraceRecord { trace, seq: st.seq, ts_ns, event };
        if let Some(sink) = &mut st.sink {
            if writeln!(sink, "{}", rec.to_jsonl_line()).is_err() {
                st.sink_errors += 1;
            }
        }
        st.ring.push_back(rec);
        if st.ring.len() > self.capacity {
            st.ring.pop_front();
            st.dropped += 1;
        }
    }

    /// Snapshot of the retained records, oldest first.
    pub fn records(&self) -> Vec<TraceRecord> {
        self.state.lock().unwrap().ring.iter().cloned().collect()
    }

    /// Number of retained records.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().ring.len()
    }

    /// True when nothing was recorded (or everything was evicted).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Records evicted from the ring because the capacity was exceeded
    /// (the JSONL sink, when present, still has them).
    pub fn dropped(&self) -> u64 {
        self.state.lock().unwrap().dropped
    }

    /// Failed sink writes (the pipeline was never disturbed by them).
    pub fn sink_errors(&self) -> u64 {
        self.state.lock().unwrap().sink_errors
    }

    /// Flush the JSONL sink, if any.
    pub fn flush(&self) -> Result<()> {
        if let Some(sink) = &mut self.state.lock().unwrap().sink {
            sink.flush().context("flushing trace sink")?;
        }
        Ok(())
    }

    /// Export the retained records as a Chrome `trace_event` JSON
    /// document (open in `chrome://tracing` or <https://ui.perfetto.dev>).
    /// Stage spans become complete (`"X"`) events; everything else
    /// becomes a thread-scoped instant with the record's fields as args.
    /// Each trace id renders as its own track (`tid`).
    pub fn chrome_trace(&self) -> String {
        let events: Vec<Json> = self
            .records()
            .iter()
            .map(|r| {
                let mut args = match r.to_json() {
                    Json::Obj(m) => m,
                    _ => unreachable!("records serialize as objects"),
                };
                for k in ["event", "seq", "trace", "ts_ns"] {
                    args.remove(k);
                }
                let (name, ph, ts_us, dur_us) = match &r.event {
                    TraceEvent::StageCompleted { stage, wall_ns } => (
                        stage.as_str(),
                        "X",
                        r.ts_ns.saturating_sub(*wall_ns) / 1_000,
                        Some(*wall_ns / 1_000),
                    ),
                    e => (e.name(), "i", r.ts_ns / 1_000, None),
                };
                let mut pairs = vec![
                    ("name", Json::str(name)),
                    ("cat", Json::str("fbo")),
                    ("ph", Json::str(ph)),
                    ("ts", Json::num(ts_us as f64)),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num(r.trace as f64)),
                    ("args", Json::Obj(args)),
                ];
                if let Some(d) = dur_us {
                    pairs.push(("dur", Json::num(d as f64)));
                }
                if ph == "i" {
                    pairs.push(("s", Json::str("t")));
                }
                Json::obj(pairs)
            })
            .collect();
        json::to_string_pretty(&Json::obj(vec![("traceEvents", Json::Arr(events))]))
    }
}

/// A [`StageObserver`] that records everything the pipeline reports into
/// a [`TraceRecorder`] under one trace id, optionally forwarding to a
/// chained observer (so existing latency counters keep working).
pub struct TraceObserver {
    recorder: Arc<TraceRecorder>,
    trace: u64,
    chain: Option<Arc<dyn StageObserver>>,
}

impl TraceObserver {
    /// Start a new trace on `recorder` and emit its
    /// [`TraceEvent::RequestStarted`] record.
    pub fn begin(recorder: &Arc<TraceRecorder>, entry: &str) -> TraceObserver {
        let trace = recorder.begin_trace();
        recorder.record(trace, TraceEvent::RequestStarted { entry: entry.to_string() });
        TraceObserver { recorder: recorder.clone(), trace, chain: None }
    }

    /// Forward every observation to `chain` after recording it.
    pub fn with_chain(mut self, chain: Arc<dyn StageObserver>) -> TraceObserver {
        self.chain = Some(chain);
        self
    }

    /// The trace id this observer records under.
    pub fn trace_id(&self) -> u64 {
        self.trace
    }

    /// Emit the closing [`TraceEvent::RequestCompleted`] record.
    pub fn complete(&self, from_cache: bool, ok: bool) {
        self.recorder.record(self.trace, TraceEvent::RequestCompleted { from_cache, ok });
    }
}

impl StageObserver for TraceObserver {
    fn stage_completed(&self, stage: Stage, wall: Duration) {
        self.recorder.record(
            self.trace,
            TraceEvent::StageCompleted { stage, wall_ns: wall.as_nanos() as u64 },
        );
        if let Some(c) = &self.chain {
            c.stage_completed(stage, wall);
        }
    }

    fn stage_event(&self, event: &TraceEvent) {
        self.recorder.record(self.trace, event.clone());
        if let Some(c) = &self.chain {
            c.stage_event(event);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent::RequestStarted { entry: "main".into() },
            TraceEvent::StageCompleted { stage: Stage::Verify, wall_ns: 48_000 },
            TraceEvent::EstimatorScored {
                label: "call:fft2d".into(),
                backend: "gpu".into(),
                predicted_secs: 1.5e-4,
                speedup: 3.25,
                pruned: false,
            },
            TraceEvent::PatternMeasured {
                label: "only:call:fft2d".into(),
                reps: 3,
                median_ns: 90_000,
                min_ns: 88_000,
                max_ns: 91_000,
                bytes_in: 32_768,
                bytes_out: 16_384,
                dispatches: 4,
                device_secs: 0.25,
            },
            TraceEvent::PowerScored {
                label: "only:call:fft2d".into(),
                watts: 70.5,
                joules: 0.125,
                efficiency: 3.5,
            },
            TraceEvent::ArbitrationVerdict {
                label: "only:call:fft2d".into(),
                winner: "gpu".into(),
                loser: "fpga".into(),
                margin_secs: 0.0125,
                policy: "auto".into(),
            },
            TraceEvent::ResidencyElided {
                label: "call:matmul".into(),
                elided_in: 32_768,
                elided_out: 0,
                saved_secs: 5.46e-6,
            },
            TraceEvent::CacheProbe { tier: "decision".into(), hit: false },
            TraceEvent::CacheCorrupt {
                path: "decision_cache/00ff.json".into(),
                detail: "invalid JSON: unexpected end of input".into(),
            },
            TraceEvent::Resumed { from: Stage::Verify },
            TraceEvent::MeasureDispatch { fanned: 3, local: 2 },
            TraceEvent::FleetBatch {
                worker: "tcp:worker1:7070#0".into(),
                patterns: 4,
                wall_ns: 96_000,
                outcome: "ok".into(),
            },
            TraceEvent::FleetReconnect {
                worker: "tcp:worker1:7070#0".into(),
                attempt: 2,
                delay_ms: 400,
                ok: true,
            },
            TraceEvent::RequestCompleted { from_cache: false, ok: true },
        ]
    }

    #[test]
    fn every_event_round_trips_through_jsonl() {
        for (i, event) in sample_events().into_iter().enumerate() {
            let rec = TraceRecord { trace: 7, seq: i as u64 + 1, ts_ns: 123_456, event };
            let line = rec.to_jsonl_line();
            assert!(!line.contains('\n'), "one line per record: {line}");
            let back = TraceRecord::from_jsonl_line(&line).unwrap();
            assert_eq!(back, rec);
            assert_eq!(back.to_jsonl_line(), line, "codec must be byte-stable");
        }
    }

    #[test]
    fn unknown_event_names_are_rejected() {
        assert!(TraceRecord::from_jsonl_line(
            r#"{"event":"mystery","seq":1,"trace":1,"ts_ns":0}"#
        )
        .is_err());
        assert!(TraceRecord::from_jsonl_line("not json").is_err());
    }

    #[test]
    fn recorder_stamps_sequence_and_bounds_the_ring() {
        let rec = TraceRecorder::new(3);
        let t = rec.begin_trace();
        assert_eq!(t, 1);
        for _ in 0..5 {
            rec.record(t, TraceEvent::CacheProbe { tier: "decision".into(), hit: true });
        }
        assert_eq!(rec.len(), 3, "ring capacity");
        assert_eq!(rec.dropped(), 2);
        let records = rec.records();
        // The oldest two were evicted; sequence numbers keep counting.
        assert_eq!(records.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![3, 4, 5]);
        assert!(records.windows(2).all(|w| w[0].ts_ns <= w[1].ts_ns));
        assert_eq!(rec.begin_trace(), 2, "trace ids are sequential");
    }

    #[test]
    fn chrome_trace_renders_spans_and_instants() {
        let rec = TraceRecorder::new(64);
        let t = rec.begin_trace();
        rec.record(t, TraceEvent::StageCompleted { stage: Stage::Parse, wall_ns: 2_000 });
        rec.record(t, TraceEvent::CacheProbe { tier: "decision".into(), hit: false });
        let doc = json::parse(&rec.chrome_trace()).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("name").unwrap().as_str().unwrap(), "parse");
        assert_eq!(events[0].get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(events[0].get("dur").unwrap().as_usize().unwrap(), 2);
        assert_eq!(events[1].get("ph").unwrap().as_str().unwrap(), "i");
        assert_eq!(
            events[1].get("args").unwrap().get("tier").unwrap().as_str().unwrap(),
            "decision"
        );
    }

    #[test]
    fn sink_mirrors_every_record() {
        let path = std::env::temp_dir()
            .join(format!("fbo-tracetest-{}.jsonl", std::process::id()));
        let rec = TraceRecorder::with_sink(2, &path).unwrap();
        let t = rec.begin_trace();
        for _ in 0..4 {
            rec.record(t, TraceEvent::CacheProbe { tier: "verified".into(), hit: true });
        }
        rec.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4, "sink keeps evicted records too");
        for line in lines {
            TraceRecord::from_jsonl_line(line).unwrap();
        }
        assert_eq!(rec.sink_errors(), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn observer_records_completions_events_and_chains() {
        use std::sync::atomic::AtomicUsize;
        struct CountingObserver(AtomicUsize);
        impl StageObserver for CountingObserver {
            fn stage_completed(&self, _stage: Stage, _wall: Duration) {
                self.0.fetch_add(1, Ordering::Relaxed);
            }
        }
        let recorder = Arc::new(TraceRecorder::new(64));
        let chained = Arc::new(CountingObserver(AtomicUsize::new(0)));
        let obs = TraceObserver::begin(&recorder, "main").with_chain(chained.clone());
        obs.stage_completed(Stage::Parse, Duration::from_micros(5));
        obs.stage_event(&TraceEvent::CacheProbe { tier: "decision".into(), hit: false });
        obs.complete(false, true);
        assert_eq!(chained.0.load(Ordering::Relaxed), 1, "chain saw the span");
        let kinds: Vec<&str> = recorder.records().iter().map(|r| r.event.name()).collect();
        assert_eq!(kinds, vec!["request-started", "stage", "cache", "request-completed"]);
        assert!(recorder.records().iter().all(|r| r.trace == obs.trace_id()));
    }
}
