//! `fbo` — CLI for the function-block offloading coordinator.
//!
//! ```text
//! fbo analyze   <file.c>                         Step 1-2 analysis report
//! fbo offload   <file.c> [--entry main] [...]    full pipeline (Steps 1-3)
//! fbo stages    <file.c> [--dump DIR]            pipeline stage by stage
//! fbo ga        <file.c> [--pop 12 --gens 10]    GA loop-offload baseline
//! fbo flow      <file.c>                         Steps 1-7 incl. sizing/placement
//! fbo batch     <files...> [--jobs N]            service pool + decision cache
//! fbo serve     [--jobs N]                       long-running service on stdin
//! fbo stats     [files...] [--format text|prom|json]  service counters
//! fbo cache     <gc|stats> [--max-bytes N]       decision-cache maintenance
//! fbo calibrate [--cache DIR] [--write-profile F]  fit profiles from the cache
//! fbo worker    --listen ADDR | --stdio          fleet measurement worker
//! fbo gen-apps  [--n 256] [--dir apps]           materialize evaluation apps
//! fbo gen-db    [--out patterndb.json]           dump the built-in pattern DB
//! fbo artifacts [--dir artifacts]                list loaded PJRT artifacts
//! ```
//!
//! Argument parsing is hand-rolled (the build is fully offline; see
//! DESIGN.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use fbo::coordinator::{
    apps, estimate, flow, loop_offload, report_json, BackendPolicy, Coordinator, PatternExecutor,
    PowerPolicy, ProfileRegistry, PrunePolicy, SerialExecutor, Stage,
};
use fbo::fleet::{Backoff, Capabilities, FleetEndpoint, FleetExecutor, FleetRegistry, WorkerHost};
use fbo::ga::GaConfig;
use fbo::metrics;
use fbo::patterndb::PatternDb;
use fbo::service::{
    parse_byte_size, AdmissionConfig, CacheBudget, CacheTier, DecisionCache, JobRejected,
    MeasurePool, OffloadService, ServiceConfig, ShedReason,
};
use fbo::telemetry::{MetricsServer, TraceObserver, TraceRecorder, DEFAULT_RING_CAPACITY};
use fbo::transform::InterfacePolicy;
use fbo::{analysis, parser, runtime};

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

/// Flags that never take a value — without this list the generic rule
/// below would swallow the following argument as the flag's "value".
const BOOLEAN_FLAGS: &[&str] = &["no-cache-persist", "dry-run", "stdio"];

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if BOOLEAN_FLAGS.contains(&name) {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                    continue;
                }
                let value = argv.get(i + 1).cloned().unwrap_or_default();
                if value.starts_with("--") || value.is_empty() {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                } else {
                    flags.insert(name.to_string(), value);
                    i += 2;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    fn flag(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects a number")),
        }
    }

    fn flag_f64(&self, name: &str) -> Result<Option<f64>> {
        match self.flags.get(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).with_context(|| format!("--{name} expects a number")),
        }
    }
}

/// `--cache-max-bytes SIZE` / `--cache-max-entries N` (and the `fbo
/// cache` spellings `--max-bytes` / `--max-entries`): the standing cache
/// budget. Sizes accept binary suffixes (`64m`, `2g`).
fn budget_from(args: &Args, bytes_flag: &str, entries_flag: &str) -> Result<CacheBudget> {
    let max_bytes = match args.flags.get(bytes_flag) {
        None => None,
        Some(v) if v == "true" => bail!("--{bytes_flag} expects a size (e.g. 64m)"),
        Some(v) => Some(parse_byte_size(v)?),
    };
    let max_entries = match args.flag_usize(entries_flag, 0)? {
        0 => None,
        n => Some(n),
    };
    Ok(CacheBudget { max_bytes, max_entries })
}

fn read_source(path: &str) -> Result<String> {
    std::fs::read_to_string(path).with_context(|| format!("reading {path}"))
}

/// `--trace-out FILE`: the JSONL trace sink shared by offload, stages,
/// batch, and serve. The arg parser stores the sentinel "true" for a
/// valueless flag; never mistake it for a file actually called "true".
fn trace_out_path(args: &Args) -> Result<Option<PathBuf>> {
    match args.flags.get("trace-out") {
        Some(v) if v == "true" => bail!("--trace-out expects a file path"),
        Some(v) => Ok(Some(PathBuf::from(v))),
        None => Ok(None),
    }
}

/// The pipeline-shaping flags shared verbatim by `offload`, `stages`,
/// `batch`, and `serve` (and, where they apply, `flow`, `ga`, and
/// `stats`): parsed once here, applied to a [`Coordinator`]
/// (single-process commands) or a [`ServiceConfig`] (pooled commands).
/// One parse site means the four entry points cannot drift apart flag by
/// flag — a knob added here reaches all of them, with identical defaults
/// and identical error messages.
struct PipelineOpts {
    policy: InterfacePolicy,
    reps: usize,
    backend_policy: BackendPolicy,
    power_policy: PowerPolicy,
    profiles: ProfileRegistry,
    prune_policy: PrunePolicy,
    resident_bytes: u64,
    verify_parallel: usize,
    fleet: Option<Vec<FleetEndpoint>>,
    trace_out: Option<PathBuf>,
}

impl PipelineOpts {
    fn parse(args: &Args) -> Result<Self> {
        let policy = match args.flag("policy", "approve").as_str() {
            "approve" => InterfacePolicy::AutoApprove,
            "reject" => InterfacePolicy::AutoReject,
            other => bail!("unknown --policy {other:?} (approve|reject)"),
        };
        // --resident-bytes SIZE: device data-plane budget (0 = off, the
        // fingerprint-passive default). Binary suffixes as elsewhere.
        let resident_bytes = match args.flags.get("resident-bytes") {
            None => 0,
            Some(v) if v == "true" => bail!("--resident-bytes expects a size (e.g. 64m, 0 = off)"),
            Some(v) => parse_byte_size(v)?,
        };
        Ok(PipelineOpts {
            policy,
            reps: args.flag_usize("reps", 3)?,
            backend_policy: BackendPolicy::parse(&args.flag("target", "auto"))?,
            power_policy: PowerPolicy::parse(&args.flag("power-policy", "perf"))?,
            profiles: profiles_from(args)?,
            prune_policy: PrunePolicy::parse(&args.flag("prune-policy", "off"))?,
            resident_bytes,
            verify_parallel: args.flag_usize("verify-parallel", 1)?,
            fleet: fleet_endpoints(args)?,
            trace_out: trace_out_path(args)?,
        })
    }

    fn apply_to_coordinator(&self, c: &mut Coordinator) {
        c.policy = self.policy.clone();
        c.verify.reps = self.reps;
        c.backend_policy = self.backend_policy;
        c.power_policy = self.power_policy;
        c.profiles = self.profiles.clone();
        c.prune_policy = self.prune_policy;
        c.resident_bytes = self.resident_bytes;
    }

    fn apply_to_service(&self, cfg: &mut ServiceConfig) {
        cfg.policy = self.policy.clone();
        cfg.verify.reps = self.reps;
        cfg.backend_policy = self.backend_policy;
        cfg.power_policy = self.power_policy;
        cfg.profiles = self.profiles.clone();
        cfg.prune_policy = self.prune_policy;
        cfg.resident_bytes = self.resident_bytes;
        cfg.verify_parallel = self.verify_parallel;
        if let Some(endpoints) = &self.fleet {
            // Validated at parse time; the config carries the raw strings
            // so the service workers re-parse and connect themselves.
            cfg.fleet = endpoints.iter().map(FleetEndpoint::as_arg).collect();
        }
        cfg.telemetry.trace_out = self.trace_out.clone();
    }
}

/// Build a coordinator from the shared CLI flags. With `verify_pool`
/// set and `--verify-parallel N` (N > 1), also starts a pool of N-1
/// measure-only workers and installs the pooled executor, so the Verify
/// stage fans its independent pattern measurements out; the returned
/// pool must stay alive for the duration of the command. Commands that
/// never reach the Verify stage (`ga`) pass `verify_pool: false` so the
/// flag cannot spawn engines that would sit idle.
fn coordinator_from(args: &Args, verify_pool: bool) -> Result<(Coordinator, Option<MeasurePool>)> {
    let opts = PipelineOpts::parse(args)?;
    let dir = PathBuf::from(args.flag("artifacts", "artifacts"));
    let mut c = Coordinator::open(&dir)?;
    opts.apply_to_coordinator(&mut c);
    let pool = if verify_pool && opts.verify_parallel > 1 {
        let pool = MeasurePool::start(&dir, opts.verify_parallel - 1)?;
        c.executor =
            Some(std::rc::Rc::new(pool.executor(c.engine.clone(), opts.verify_parallel)));
        Some(pool)
    } else {
        None
    };
    // --fleet: wrap whatever local executor the flags built (pooled or
    // serial) as the fallback of a fleet executor. Like the pool, the
    // fleet only changes where measurements run, never what they decide.
    if verify_pool {
        if let Some(endpoints) = &opts.fleet {
            let fallback: std::rc::Rc<dyn PatternExecutor> = match c.executor.take() {
                Some(executor) => executor,
                None => std::rc::Rc::new(SerialExecutor::new(c.engine.clone())),
            };
            let registry = FleetRegistry::connect(endpoints);
            for reason in registry.rejected() {
                eprintln!("fleet: rejected {reason}");
            }
            eprintln!("fleet: {} of {} worker(s) live", registry.live_count(), endpoints.len());
            c.executor = Some(std::rc::Rc::new(FleetExecutor::new(registry, fallback)));
        }
    }
    Ok((c, pool))
}

/// `--device-profile FILE`: a device-profile registry JSON
/// (`fbo-device-profiles-v1`) replacing the built-in GPU/FPGA profiles
/// the estimate stage scores candidates against. The built-in registry
/// (the paper's GTX 1050 Ti + Arria 10) is the fingerprint-passive
/// default.
fn profiles_from(args: &Args) -> Result<ProfileRegistry> {
    match args.flags.get("device-profile") {
        Some(v) if v == "true" => bail!("--device-profile expects a JSON file path"),
        Some(path) => ProfileRegistry::load(Path::new(path)),
        None => Ok(ProfileRegistry::builtin()),
    }
}

/// `--fleet worker1:7070,stdio:fbo worker --stdio,...`: the endpoint
/// list shared by offload/stages (coordinator executor) and batch/serve
/// (service config). Parsed eagerly so a typo fails before any work.
fn fleet_endpoints(args: &Args) -> Result<Option<Vec<FleetEndpoint>>> {
    match args.flags.get("fleet") {
        Some(v) if v == "true" => {
            bail!("--fleet expects a comma-separated endpoint list (host:port or stdio:<command>)")
        }
        Some(v) => Ok(Some(FleetEndpoint::parse_list(v)?)),
        None => Ok(None),
    }
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let src = read_source(args.positional.first().context("usage: fbo analyze <file.c>")?)?;
    let prog = parser::parse(&src)?;
    let a = analysis::analyze(&prog);
    println!("includes: {:?}", a.includes);
    println!("structs: {:?}", a.struct_names);
    println!("defined functions:");
    for f in &a.defined_functions {
        println!("  {} ({} stmts, {} loops)", f.name, f.stmt_count, f.loop_count);
    }
    println!("external library calls (A-1 candidates):");
    for c in &a.external_calls {
        println!("  {} at {} in {} ({} args)", c.callee, c.span, c.in_function, c.arg_count);
    }
    println!("loops:");
    for l in &a.loops {
        println!(
            "  {} at {} depth={} class={:?} trips={:?} gene={}",
            l.in_function,
            l.span,
            l.depth,
            l.class,
            l.nest_trip_count,
            l.class != analysis::LoopClass::Sequential && !l.inside_offloadable
        );
    }
    Ok(())
}

fn cmd_offload(args: &Args) -> Result<()> {
    let path = args.positional.first().context("usage: fbo offload <file.c>")?;
    let src = read_source(path)?;
    let entry = args.flag("entry", "main");
    let (c, _measure_pool) = coordinator_from(args, true)?;
    let report = match trace_out_path(args)? {
        Some(out) => {
            let recorder = Arc::new(TraceRecorder::with_sink(DEFAULT_RING_CAPACITY, &out)?);
            let obs = Arc::new(TraceObserver::begin(&recorder, &entry));
            let result = c.request(&src, &entry).with_observer(obs.clone()).run();
            obs.complete(false, result.is_ok());
            recorder.flush()?;
            eprintln!("trace: {} event(s) -> {}", recorder.records().len(), out.display());
            result?
        }
        None => c.offload(&src, &entry)?,
    };
    print!("{}", c.render_report(&report));
    if let Some(out) = args.flags.get("out") {
        std::fs::write(out, &report.transformed_source)?;
        println!("transformed source written to {out}");
    }
    Ok(())
}

/// Observer backing the `fbo stages` table: records each stage's
/// wall-clock as the pipeline reports it.
#[derive(Default)]
struct StageWalls(std::sync::Mutex<Vec<Option<std::time::Duration>>>);

impl fbo::coordinator::StageObserver for StageWalls {
    fn stage_completed(&self, stage: Stage, wall: std::time::Duration) {
        let mut walls = self.0.lock().expect("stage walls lock");
        if walls.is_empty() {
            walls.resize(Stage::ALL.len(), None);
        }
        walls[stage.index()] = Some(wall);
    }
}

/// `fbo stages --resume DIR/verified.json`: re-enter the pipeline from a
/// saved Verify-stage artifact — the expensive measurements are reused
/// and only power scoring + arbitration re-run, under whatever
/// `--target` / `--power-policy` this invocation carries.
fn cmd_stages_resume(args: &Args, artifact: &str) -> Result<()> {
    let payload = std::fs::read_to_string(artifact)
        .with_context(|| format!("reading stage artifact {artifact}"))?;
    let verified = fbo::coordinator::Verified::from_json_str(&payload)
        .with_context(|| format!("loading verified stage artifact {artifact}"))?;
    // No verify pool: the Verify stage is exactly what resume skips.
    let (c, _measure_pool) = coordinator_from(args, false)?;
    let parsed = &verified.reconciled.discovered.parsed;
    let req = c.request(&parsed.source, &parsed.entry);
    println!(
        "resumed from {artifact}: {} pattern(s) reused; re-running power-score + arbitrate",
        verified.outcome.tried.len()
    );
    let scored = verified.power_score(&req)?;
    let arbitrated = scored.arbitrate(&req)?;
    let report = arbitrated.report();
    print!("{}", c.render_report(&report));
    if let Some(out) = args.flags.get("out") {
        std::fs::write(out, &report.transformed_source)?;
        println!("transformed source written to {out}");
    }
    Ok(())
}

fn cmd_stages(args: &Args) -> Result<()> {
    match args.flags.get("resume") {
        Some(v) if v == "true" => {
            bail!("--resume expects a stage artifact path (DIR/verified.json)")
        }
        Some(artifact) => return cmd_stages_resume(args, artifact),
        None => {}
    }
    let path = args.positional.first().context("usage: fbo stages <file.c> [--dump DIR]")?;
    let src = read_source(path)?;
    let entry = args.flag("entry", "main");
    let (c, _measure_pool) = coordinator_from(args, true)?;
    let walls = Arc::new(StageWalls::default());
    // With --trace-out, the trace observer wraps the walls observer (it
    // chains stage completions through), so the table and the trace see
    // identical timings.
    let trace = match trace_out_path(args)? {
        Some(out) => {
            let recorder = Arc::new(TraceRecorder::with_sink(DEFAULT_RING_CAPACITY, &out)?);
            let obs =
                Arc::new(TraceObserver::begin(&recorder, &entry).with_chain(walls.clone()));
            Some((obs, recorder, out))
        }
        None => None,
    };
    let observer: Arc<dyn fbo::coordinator::StageObserver> = match &trace {
        Some((obs, _, _)) => obs.clone(),
        None => walls.clone(),
    };
    let req = c.request(&src, &entry).with_observer(observer);

    let dump_dir = match args.flags.get("dump") {
        // The arg parser stores the sentinel "true" for a valueless flag;
        // never mistake it for a directory actually called "true".
        Some(v) if v == "true" => bail!("--dump expects a directory path"),
        Some(v) => Some(PathBuf::from(v)),
        None => None,
    };
    if let Some(dir) = &dump_dir {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating stage dump dir {}", dir.display()))?;
    }
    // Dumped artifacts are announced eagerly, so a mid-pipeline failure
    // still tells the user which stage artifacts landed on disk.
    let dump = |stage: &str, payload: String| -> Result<()> {
        if let Some(dir) = &dump_dir {
            let p = dir.join(format!("{stage}.json"));
            std::fs::write(&p, payload).with_context(|| format!("writing {}", p.display()))?;
            println!("artifact -> {}", p.display());
        }
        Ok(())
    };

    // Advance the pipeline, keeping one result line per stage; the table
    // below prints every stage in the fixed `Stage::ALL` order with its
    // observer-reported latency, so CI logs diff cleanly run to run — and
    // it prints even when a stage fails, showing how far the run got.
    let mut results: Vec<String> = vec!["-".to_string(); Stage::ALL.len()];
    let mut candidate_lines: Vec<String> = Vec::new();

    let mut advance = || -> Result<fbo::coordinator::Arbitrated> {
        let parsed = req.parse()?;
        results[Stage::Parse.index()] =
            format!("entry {} ({} top-level items)", parsed.entry, parsed.program.items.len());
        dump("parsed", parsed.to_json_string())?;

        let discovered = parsed.discover(&req)?;
        results[Stage::Discover.index()] = format!(
            "{} external callee(s), {} candidate block(s)",
            discovered.external_callees.len(),
            discovered.candidates.len()
        );
        for cand in &discovered.candidates {
            candidate_lines.push(format!("candidate {} via {:?}", cand.site.label(), cand.via));
        }
        dump("discovered", discovered.to_json_string())?;

        let reconciled = discovered.reconcile(&req)?;
        let accepted = reconciled.blocks.iter().filter(|b| b.accepted()).count();
        results[Stage::Reconcile.index()] =
            format!("{} accepted, {} rejected", accepted, reconciled.blocks.len() - accepted);
        dump("reconciled", reconciled.to_json_string())?;

        let estimated = reconciled.estimate(&req)?;
        let pruned = estimated.estimates.prune_mask().iter().filter(|&&p| p).count();
        results[Stage::Estimate.index()] = format!(
            "{} block(s) scored vs {} + {}, {} pruned under {}",
            estimated.estimates.blocks.len(),
            estimated.estimates.gpu_profile,
            estimated.estimates.fpga_profile,
            pruned,
            estimated.estimates.policy.render()
        );
        dump("estimated", estimated.to_json_string())?;

        let verified = estimated.verify(&req)?;
        results[Stage::Verify.index()] = format!(
            "{} pattern(s) measured, best speedup {}",
            verified.outcome.tried.len(),
            metrics::fmt_speedup(verified.outcome.best_speedup)
        );
        dump("verified", verified.to_json_string())?;

        let scored = verified.power_score(&req)?;
        let best_efficiency = scored
            .scores
            .blocks
            .iter()
            .filter_map(|b| b.gpu.as_ref().map(|e| e.efficiency))
            .fold(f64::NAN, f64::max);
        results[Stage::PowerScore.index()] = format!(
            "{} pattern(s) priced under {}, best efficiency {}",
            scored.scores.blocks.len(),
            scored.scores.policy.render(),
            if best_efficiency.is_nan() {
                "-".to_string()
            } else {
                format!("{best_efficiency:.1}x")
            }
        );
        dump("power_scored", scored.to_json_string())?;

        let arbitrated = scored.arbitrate(&req)?;
        results[Stage::Arbitrate.index()] = format!(
            "backend {} ({} simulated toolchain)",
            arbitrated.arbitration.backend.as_str(),
            metrics::fmt_hours(arbitrated.arbitration.simulated_hours)
        );
        dump("arbitrated", arbitrated.to_json_string())?;

        results[Stage::Place.index()] =
            "(not run here; `fbo flow` places the decision)".to_string();
        Ok(arbitrated)
    };
    let outcome = advance();
    if let Some((obs, recorder, out)) = &trace {
        obs.complete(false, outcome.is_ok());
        recorder.flush()?;
        eprintln!("trace: {} event(s) -> {}", recorder.records().len(), out.display());
    }

    let walls = walls.0.lock().expect("stage walls lock");
    let mut table = metrics::Table::new(&["stage", "wall", "result"]);
    for stage in Stage::ALL {
        let wall = walls
            .get(stage.index())
            .copied()
            .flatten()
            .map(metrics::fmt_duration)
            .unwrap_or_else(|| "-".to_string());
        table.row(&[stage.as_str().to_string(), wall, results[stage.index()].clone()]);
    }
    print!("{}", table.render());
    for line in &candidate_lines {
        println!("{line}");
    }

    let arbitrated = outcome?;
    let report = arbitrated.report();
    println!(
        "total {} (resume any stage from its dumped artifact)",
        metrics::fmt_duration(report.search_wall)
    );
    Ok(())
}

fn cmd_ga(args: &Args) -> Result<()> {
    let path = args.positional.first().context("usage: fbo ga <file.c>")?;
    let src = read_source(path)?;
    let entry = args.flag("entry", "main");
    let (c, _measure_pool) = coordinator_from(args, false)?;
    let prog = parser::parse(&src)?;
    let linked = c.link_cpu_libraries(&prog)?;
    let cfg = GaConfig {
        population: args.flag_usize("pop", 12)?,
        generations: args.flag_usize("gens", 10)?,
        ..Default::default()
    };
    let r = loop_offload::ga_loop_search(&linked, &entry, &cfg, 1, u64::MAX)?;
    println!("genes ({} parallelizable loops):", r.loop_ids.len());
    for (i, label) in r.loop_labels.iter().enumerate() {
        println!("  [{i}] {label}");
    }
    let mut table = metrics::Table::new(&["generation", "best speedup", "mean speedup", "trials"]);
    for g in &r.ga.history {
        table.row(&[
            g.generation.to_string(),
            format!("{:.2}", g.best_speedup),
            format!("{:.2}", g.mean_speedup),
            g.trials.to_string(),
        ]);
    }
    print!("{}", table.render());
    println!(
        "best gene: {:?} -> speedup {}",
        r.ga.best_gene,
        metrics::fmt_speedup(r.ga.best_speedup())
    );
    Ok(())
}

fn cmd_flow(args: &Args) -> Result<()> {
    let path = args.positional.first().context("usage: fbo flow <file.c>")?;
    let src = read_source(path)?;
    let entry = args.flag("entry", "main");
    let (c, _measure_pool) = coordinator_from(args, true)?;

    println!("-- Steps 1-3: analyze, extract, search --");
    let request = c.request(&src, &entry);
    let arbitrated = request
        .parse()?
        .discover(&request)?
        .reconcile(&request)?
        .verify(&request)?
        .arbitrate(&request)?;
    let report = arbitrated.report();
    print!("{}", c.render_report(&report));

    let req = flow::Requirements {
        target_rps: args.flag_usize("rps", 50)? as f64,
        max_latency_ms: 20.0,
        budget_per_month: 10_000.0,
        // --max-kwh: deployment-level monthly energy budget; enforceable
        // when a non-default --power-policy supplied per-instance watts.
        max_kwh_per_month: args.flag_f64("max-kwh")?,
    };
    let locations = vec![
        flow::Location {
            name: "edge-gw".into(),
            gpus: 1,
            fpgas: 1,
            cost_per_hour: 0.9,
            fpga_cost_per_hour: 0.35,
            energy_cost_per_kwh: 0.30,
            latency_ms: 3.0,
        },
        flow::Location {
            name: "regional-dc".into(),
            gpus: 8,
            fpgas: 4,
            cost_per_hour: 0.5,
            fpga_cost_per_hour: 0.2,
            energy_cost_per_kwh: 0.12,
            latency_ms: 12.0,
        },
        flow::Location {
            name: "central-cloud".into(),
            gpus: 64,
            fpgas: 32,
            cost_per_hour: 0.3,
            fpga_cost_per_hour: 0.12,
            energy_cost_per_kwh: 0.08,
            latency_ms: 45.0,
        },
    ];
    // Steps 4+5 are one stage: placement arbitrates the backend (falling
    // back to the generic all-CPU walk when nothing offloaded), and the
    // sizing printed for Step 4 is the one the chosen backend needs.
    let placed = arbitrated.place(&request, &req, &locations)?;
    println!("-- Step 4: resource sizing (for the arbitrated backend) --");
    println!(
        "  {} {} instance(s) at {:.1} rps each",
        placed.instances,
        placed.backend.as_str(),
        placed.rps_per_instance
    );
    println!("-- Step 5: placement (consumes the per-backend Step-3b times) --");
    println!(
        "  {} on {} x{} (${:.0}/month)",
        placed.location,
        placed.backend.as_str(),
        placed.instances,
        placed.monthly_cost
    );

    println!("-- Step 6: deploy + operational verification --");
    println!(
        "  deployed pattern re-verified: {} speedup, correct output",
        metrics::fmt_speedup(report.outcome.best_speedup)
    );
    println!("-- Step 7: reconfiguration hook armed (re-runs Step 5 on change) --");
    Ok(())
}

fn service_from(args: &Args) -> Result<OffloadService> {
    let opts = PipelineOpts::parse(args)?;
    let mut cfg = ServiceConfig::new(PathBuf::from(args.flag("artifacts", "artifacts")));
    opts.apply_to_service(&mut cfg);
    cfg.workers = args.flag_usize("jobs", 2)?;
    if let Some(dir) = args.flags.get("cache") {
        cfg.cache_dir = Some(PathBuf::from(dir));
    }
    if args.flag("no-cache-persist", "false") == "true" {
        cfg.persist = false;
    }
    cfg.admission = AdmissionConfig {
        queue_limit: args.flag_usize("queue-limit", 0)?,
        rate_per_client: args.flag_f64("rate-limit")?,
        burst: args.flag_f64("burst")?.unwrap_or(1.0),
    };
    cfg.cache_budget = budget_from(args, "cache-max-bytes", "cache-max-entries")?;
    OffloadService::start(cfg)
}

fn print_completed(label: &str, done: &fbo::service::CompletedJob) {
    println!(
        "{label}: best speedup {} on {} in {}{}",
        metrics::fmt_speedup(done.report.best_speedup()),
        done.report.backend().as_str(),
        metrics::fmt_duration(done.wall),
        if done.from_cache { "  [cached decision]" } else { "" },
    );
}

/// A rejection the client should back off and retry: the service shed
/// the job for load (queue full / rate limited), not because it is
/// shutting down or the job itself failed. Returns the server's
/// retry-after hint.
fn retryable_rejection(e: &anyhow::Error) -> Option<std::time::Duration> {
    let rejected = e.downcast_ref::<JobRejected>()?;
    match rejected.reason {
        ShedReason::QueueFull | ShedReason::RateLimited => Some(rejected.retry_after),
        ShedReason::ShuttingDown => None,
    }
}

fn cmd_batch(args: &Args) -> Result<()> {
    if args.positional.is_empty() {
        bail!("usage: fbo batch <file.c...> [--entry main] [--jobs N] [--cache DIR]");
    }
    let entry = args.flag("entry", "main");
    let max_retries = args.flag_usize("retries", 4)? as u32;
    let service = service_from(args)?;
    let sources: Vec<String> =
        args.positional.iter().map(|p| read_source(p)).collect::<Result<_>>()?;
    let n = sources.len();
    // Admission rejections (queue full, rate limited) are retried with a
    // jittered exponential backoff floored at the service's retry-after
    // hint; per-job seeds keep concurrent clients from retrying in
    // lockstep. Rounds keep the whole remaining set in flight together,
    // so retries still overlap across the worker pool.
    let mut outcomes: Vec<Option<std::result::Result<fbo::service::CompletedJob, anyhow::Error>>> =
        (0..n).map(|_| None).collect();
    let mut backoffs: Vec<Backoff> = (0..n)
        .map(|i| {
            Backoff::new(
                std::time::Duration::from_millis(100),
                std::time::Duration::from_secs(5),
                i as u64,
            )
        })
        .collect();
    let mut pending: Vec<usize> = (0..n).collect();
    loop {
        let jobs: Vec<(String, String)> =
            pending.iter().map(|&i| (sources[i].clone(), entry.clone())).collect();
        let handles = service.submit_batch(&jobs);
        let mut retry = Vec::new();
        let mut pause = std::time::Duration::ZERO;
        for (&i, handle) in pending.iter().zip(handles) {
            match handle.wait() {
                Ok(done) => outcomes[i] = Some(Ok(done)),
                Err(e) => match retryable_rejection(&e) {
                    Some(hint) if backoffs[i].attempts() < max_retries => {
                        let delay = backoffs[i].next_delay_after(hint);
                        eprintln!(
                            "{}: {e} (retry {} in {:.2}s)",
                            args.positional[i],
                            backoffs[i].attempts(),
                            delay.as_secs_f64()
                        );
                        pause = pause.max(delay);
                        retry.push(i);
                    }
                    _ => outcomes[i] = Some(Err(e)),
                },
            }
        }
        if retry.is_empty() {
            break;
        }
        std::thread::sleep(pause);
        pending = retry;
    }
    let mut failures = 0usize;
    for (path, outcome) in args.positional.iter().zip(outcomes) {
        match outcome.expect("every job resolves or fails") {
            Ok(done) => print_completed(path, &done),
            Err(e) => {
                failures += 1;
                eprintln!("{path}: error: {e:#}");
            }
        }
    }
    println!("{}", service.stats().render());
    if failures > 0 {
        bail!("{failures} of {} jobs failed", args.positional.len());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use std::io::BufRead;
    let service = service_from(args)?;
    if let Some(dir) = service.cache().dir() {
        eprintln!("decision cache: {} ({} entries)", dir.display(), service.cache().len());
    }
    // --metrics-addr HOST:PORT: live Prometheus exposition over the
    // service's registry ("/metrics"). The handle is Send + Sync, so the
    // accept loop reads counters while workers run.
    let metrics_server = match args.flags.get("metrics-addr") {
        Some(v) if v == "true" => bail!("--metrics-addr expects HOST:PORT"),
        Some(addr) => {
            let handle = service.metrics();
            let server = MetricsServer::start(addr, move || handle.render_prometheus())?;
            eprintln!("metrics: http://{}/metrics", server.addr());
            Some(server)
        }
        None => None,
    };
    // --stats-every N: print a counters snapshot to stderr every N
    // seconds while serving.
    let stats_every = args.flag_usize("stats-every", 0)?;
    let ticker = if stats_every > 0 {
        let handle = service.metrics();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let flag = stop.clone();
        let period = std::time::Duration::from_secs(stats_every as u64);
        let thread = std::thread::spawn(move || {
            let mut last = std::time::Instant::now();
            // Poll in short steps so shutdown never waits a full period.
            while !flag.load(std::sync::atomic::Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_millis(200));
                if last.elapsed() >= period {
                    last = std::time::Instant::now();
                    eprintln!("{}", handle.snapshot().render());
                }
            }
        });
        Some((stop, thread))
    } else {
        None
    };
    eprintln!(
        "serving offload requests from stdin, one per line: <file.c> [entry]  (Ctrl-D to stop)"
    );
    // The stdin loop only submits; a printer thread waits on each handle
    // (in submission order) and prints the moment it completes, so a
    // request/response client that blocks for output before sending its
    // next line is never deadlocked, and work still overlaps across the
    // --jobs workers for pipelined clients.
    let (done_tx, done_rx) = std::sync::mpsc::channel::<(String, fbo::service::JobHandle)>();
    let printer = std::thread::spawn(move || {
        let mut failed = 0u64;
        for (path, handle) in done_rx {
            match handle.wait() {
                Ok(done) => print_completed(&path, &done),
                Err(e) => {
                    failed += 1;
                    eprintln!("{path}: error: {e:#}");
                }
            }
        }
        failed
    });
    let mut read_failures = 0u64;
    for line in std::io::stdin().lock().lines() {
        let line = line?;
        let mut parts = line.split_whitespace();
        let Some(path) = parts.next() else { continue };
        let entry = parts.next().unwrap_or("main").to_string();
        match read_source(path) {
            Ok(src) => {
                let handle = service.submit(&src, &entry);
                if done_tx.send((path.to_string(), handle)).is_err() {
                    bail!("serve printer thread died");
                }
            }
            Err(e) => {
                read_failures += 1;
                eprintln!("{path}: error: {e:#}");
            }
        }
    }
    drop(done_tx); // EOF: let the printer drain and finish
    let printer_result = printer.join();
    if let Some((stop, thread)) = ticker {
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        let _ = thread.join();
    }
    if let Some(server) = metrics_server {
        server.stop();
    }
    // A printer panic means completed results were dropped on the floor:
    // propagate it as a hard failure instead of undercounting failures.
    let printed_failures = printer_result
        .map_err(|_| anyhow!("serve printer thread panicked; completed results were dropped"))?;
    let failed = printed_failures + read_failures;
    println!("{}", service.stats().render());
    if failed > 0 {
        bail!("{failed} request(s) failed");
    }
    Ok(())
}

/// `fbo worker`: host a measurement fleet worker. `--listen ADDR`
/// serves the `fbo-fleet-v1` protocol over TCP; `--stdio` serves the
/// worker's own stdin/stdout (for schedulers that spawn their fleet as
/// child processes). `--caps`, `--device`, and `--max-inflight` shape
/// the capabilities the worker announces in its hello frame.
fn cmd_worker(args: &Args) -> Result<()> {
    const USAGE: &str = "usage: fbo worker --listen HOST:PORT | --stdio [--artifacts DIR] \
                         [--caps gpu,fpga] [--device NAME] [--max-inflight N]";
    let (mut gpu, mut fpga) = (false, false);
    for tag in args.flag("caps", "gpu,fpga").split(',') {
        match tag.trim() {
            "gpu" => gpu = true,
            "fpga" => fpga = true,
            "" => {}
            other => bail!("unknown --caps tag {other:?} (gpu|fpga)"),
        }
    }
    let caps = Capabilities {
        gpu,
        fpga,
        device: args.flag("device", "pjrt-cpu"),
        max_inflight: args.flag_usize("max-inflight", 1)?.max(1),
    };
    let dir = PathBuf::from(args.flag("artifacts", "artifacts"));
    let host = WorkerHost::open(&dir, caps)?;
    let stdio = args.flag("stdio", "false") == "true";
    match args.flags.get("listen") {
        Some(v) if v == "true" => bail!("--listen expects HOST:PORT"),
        Some(_) if stdio => bail!("{USAGE} (pick one transport, not both)"),
        Some(addr) => {
            let listener = std::net::TcpListener::bind(addr)
                .with_context(|| format!("binding fleet worker listener on {addr}"))?;
            eprintln!(
                "fleet worker: listening on {} (device {}, max-inflight {})",
                listener.local_addr()?,
                host.caps().device,
                host.caps().max_inflight
            );
            host.serve_listener(&listener)
        }
        None if stdio => host.serve_stdio(),
        None => bail!(USAGE),
    }
}

/// `fbo stats`: run an optional batch of files through a service, then
/// print its counters in one of three formats. `text` is the multi-line
/// human rendering, `prom` the Prometheus text exposition the
/// `--metrics-addr` endpoint serves, `json` a canonical JSON document
/// (`fbo-stats-v1`).
fn cmd_stats(args: &Args) -> Result<()> {
    let format = args.flag("format", "text");
    if !matches!(format.as_str(), "text" | "prom" | "json") {
        bail!("unknown --format {format:?} (text|prom|json)");
    }
    let entry = args.flag("entry", "main");
    let service = service_from(args)?;
    if !args.positional.is_empty() {
        let jobs: Vec<(String, String)> = args
            .positional
            .iter()
            .map(|p| Ok((read_source(p)?, entry.clone())))
            .collect::<Result<_>>()?;
        for (path, result) in args.positional.iter().zip(service.run_batch(&jobs)) {
            match result {
                Ok(done) => eprintln!(
                    "{path}: {} on {}{}",
                    metrics::fmt_speedup(done.report.best_speedup()),
                    done.report.backend().as_str(),
                    if done.from_cache { "  [cached decision]" } else { "" },
                ),
                Err(e) => eprintln!("{path}: error: {e:#}"),
            }
        }
    }
    let handle = service.metrics();
    match format.as_str() {
        "text" => println!("{}", handle.snapshot().render_full()),
        "prom" => print!("{}", handle.render_prometheus()),
        _ => println!("{}", handle.snapshot().to_json().to_string_pretty()),
    }
    Ok(())
}

/// Cache-store resolution shared by `fbo cache gc|stats`: `--cache DIR`
/// wins, else the service default (`decision_cache/` next to the
/// artifacts dir — the same rule `ServiceConfig` applies).
fn cache_dir_from(args: &Args) -> PathBuf {
    match args.flags.get("cache") {
        Some(dir) => PathBuf::from(dir),
        None => {
            let artifacts = PathBuf::from(args.flag("artifacts", "artifacts"));
            artifacts.parent().unwrap_or_else(|| Path::new(".")).join("decision_cache")
        }
    }
}

fn fmt_bytes(b: u64) -> String {
    match b {
        0..=1023 => format!("{b} B"),
        1024..=1048575 => format!("{:.1} KiB", b as f64 / 1024.0),
        1048576..=1073741823 => format!("{:.1} MiB", b as f64 / 1048576.0),
        _ => format!("{:.2} GiB", b as f64 / 1073741824.0),
    }
}

/// `fbo cache stats|gc`: offline maintenance of a decision-cache
/// directory. `stats` prints per-tier occupancy; `gc` evicts down to a
/// budget (`--max-bytes`/`--max-entries`) in tier-priority-then-LRU
/// order, or previews the eviction with `--dry-run`.
fn cmd_cache(args: &Args) -> Result<()> {
    const USAGE: &str = "usage: fbo cache <stats|gc> [--cache DIR] [--artifacts DIR] \
                         [--max-bytes SIZE] [--max-entries N] [--dry-run]";
    let dir = cache_dir_from(args);
    let cache = DecisionCache::open(&dir)?;
    let usage = cache.usage();
    match args.positional.first().map(String::as_str) {
        Some("stats") => {
            println!("cache: {}", dir.display());
            let mut table = metrics::Table::new(&["tier", "entries", "bytes"]);
            for tier in CacheTier::ALL {
                table.row(&[
                    tier.as_str().to_string(),
                    usage.tier_entries[tier.rank()].to_string(),
                    fmt_bytes(usage.tier_bytes[tier.rank()]),
                ]);
            }
            table.row(&["total".to_string(), usage.entries.to_string(), fmt_bytes(usage.bytes)]);
            print!("{}", table.render());
            let corrupt = cache.stats().corrupt;
            if corrupt > 0 {
                println!("{corrupt} corrupt file(s) detected (each will recompute on use)");
            }
            Ok(())
        }
        Some("gc") => {
            let budget = budget_from(args, "max-bytes", "max-entries")?;
            if budget.is_unlimited() {
                bail!("cache gc needs a budget: --max-bytes SIZE and/or --max-entries N");
            }
            let dry_run = args.flag("dry-run", "false") == "true";
            let outcome = cache.gc(budget, dry_run)?;
            let verb = if dry_run { "would evict" } else { "evicted" };
            for e in &outcome.evicted {
                println!(
                    "{verb} {} ({}, {})",
                    e.key.file_stem(),
                    e.tier.as_str(),
                    fmt_bytes(e.bytes)
                );
            }
            println!(
                "{}: {} entries / {} -> {} entries / {}",
                if dry_run { "dry run" } else { "gc" },
                outcome.entries_before,
                fmt_bytes(outcome.bytes_before),
                outcome.entries_after,
                fmt_bytes(outcome.bytes_after),
            );
            Ok(())
        }
        _ => bail!(USAGE),
    }
}

/// `fbo calibrate`: fit per-profile estimator scale factors from the
/// decision cache. Every cached full decision whose report carries an
/// estimate residue (v4+) contributes its predicted-vs-measured pairs;
/// the fitted registry can be written back out with `--write-profile`
/// and fed to later runs via `--device-profile`.
fn cmd_calibrate(args: &Args) -> Result<()> {
    let dir = cache_dir_from(args);
    let cache = DecisionCache::open(&dir)?;
    let mut samples = Vec::new();
    let mut decisions = 0usize;
    let mut with_estimate = 0usize;
    for (_key, tier, payload) in cache.entries_snapshot() {
        if tier != CacheTier::Decision {
            continue;
        }
        // Corrupt or foreign payloads never abort a calibration pass.
        let Ok(report) = report_json::report_from_str(&payload) else {
            continue;
        };
        decisions += 1;
        if let Some(est) = &report.arbitration.estimate {
            with_estimate += 1;
            samples.extend(estimate::samples_from_decision(est));
        }
    }
    if samples.is_empty() {
        bail!(
            "no calibration samples in {} ({decisions} cached decision(s), {with_estimate} \
             with an estimate residue); run offloads through `fbo batch`/`fbo serve` with a \
             non-default --prune-policy or --device-profile so reports carry estimates",
            dir.display()
        );
    }
    let mut reg = profiles_from(args)?;
    let fit = estimate::calibrate(&mut reg, &samples)?;
    println!("calibrated from {} sample(s) in {}:", samples.len(), dir.display());
    println!(
        "  gpu  profile {:<20} scale {:.3}  ({} sample(s))",
        reg.active_gpu, fit.gpu_scale, fit.gpu_samples
    );
    println!(
        "  fpga profile {:<20} scale {:.3}  ({} sample(s))",
        reg.active_fpga, fit.fpga_scale, fit.fpga_samples
    );
    match args.flags.get("write-profile") {
        None => {}
        Some(v) if v == "true" => bail!("--write-profile expects a file path"),
        Some(path) => {
            std::fs::write(path, reg.to_json_string())
                .with_context(|| format!("writing fitted registry to {path}"))?;
            println!("fitted registry written to {path}");
        }
    }
    Ok(())
}

fn cmd_gen_apps(args: &Args) -> Result<()> {
    let n = args.flag_usize("n", 256)?;
    let dir = PathBuf::from(args.flag("dir", "apps"));
    let names = apps::write_all(&dir, n)?;
    println!("wrote {} app sources to {}:", names.len(), dir.display());
    for n in names {
        println!("  {n}");
    }
    Ok(())
}

fn cmd_gen_db(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.flag("out", "patterndb.json"));
    PatternDb::builtin().save(&out)?;
    println!("pattern DB written to {}", out.display());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.flag("dir", "artifacts"));
    let engine = runtime::Engine::open(&dir)?;
    for name in engine.artifact_names() {
        let meta = engine.meta(&name).unwrap();
        println!(
            "{name}: in={:?} out={:?}  {}",
            meta.inputs.iter().map(|s| s.shape.clone()).collect::<Vec<_>>(),
            meta.outputs.iter().map(|s| s.shape.clone()).collect::<Vec<_>>(),
            meta.description
        );
    }
    Ok(())
}

fn usage() -> &'static str {
    "fbo — automatic GPU/FPGA offloading of application function blocks\n\
     \n\
     usage: fbo <command> [args]\n\
     \n\
     commands:\n\
       analyze   <file.c>                 Step 1-2 analysis report\n\
       offload   <file.c> [--entry main] [--artifacts DIR] [--policy approve|reject]\n\
                 [--target gpu|fpga|auto] [--power-policy perf|perf-per-watt|cap:<watts>]\n\
                 [--device-profile FILE] [--prune-policy off|conservative:<margin>|aggressive]\n\
                 [--reps N] [--verify-parallel N] [--fleet LIST] [--trace-out FILE]\n\
                 [--resident-bytes SIZE] [--out transformed.c]\n\
       stages    <file.c> [--entry main] [--dump DIR] [--policy approve|reject]\n\
                 [--target gpu|fpga|auto] [--power-policy ...] [--reps N]\n\
                 [--device-profile FILE] [--prune-policy ...]\n\
                 [--verify-parallel N] [--fleet LIST] [--trace-out FILE]\n\
                 [--resident-bytes SIZE]\n\
                 run the pipeline stage by stage, printing a fixed-order\n\
                 per-stage table (--dump writes the JSON artifacts,\n\
                 including estimated.json and power_scored.json)\n\
       stages    --resume DIR/verified.json [--target ...] [--power-policy ...]\n\
                 re-enter from a dumped Verify artifact: measurements are\n\
                 reused, only power-score + arbitrate re-run\n\
       ga        <file.c> [--pop 12] [--gens 10] [--entry main]\n\
       flow      <file.c> [--rps 50] [--max-kwh KWH] [--target gpu|fpga|auto]\n\
                 [--power-policy ...] [--device-profile FILE] [--prune-policy ...]\n\
                 full Steps 1-7 (Step 5 places on the arbitrated backend;\n\
                 --max-kwh caps the deployment's monthly energy draw)\n\
       batch     <file.c...> [--entry main] [--jobs N] [--artifacts DIR]\n\
                 [--cache DIR] [--no-cache-persist] [--reps N]\n\
                 [--target gpu|fpga|auto] [--power-policy ...] [--verify-parallel N]\n\
                 [--device-profile FILE] [--prune-policy ...]\n\
                 [--fleet LIST] [--retries N] [--resident-bytes SIZE]\n\
                 [--trace-out FILE] [--cache-max-bytes SIZE] [--cache-max-entries N]\n\
                 offload many files through the service worker pool +\n\
                 persistent decision cache; admission rejections retry\n\
                 with jittered backoff honoring the retry-after hint\n\
       serve     [--jobs N] [--artifacts DIR] [--cache DIR]\n\
                 [--target gpu|fpga|auto] [--power-policy ...] [--verify-parallel N]\n\
                 [--device-profile FILE] [--prune-policy ...] [--fleet LIST]\n\
                 [--resident-bytes SIZE]\n\
                 [--trace-out FILE] [--metrics-addr HOST:PORT] [--stats-every N]\n\
                 [--queue-limit N] [--rate-limit R] [--burst B]\n\
                 [--cache-max-bytes SIZE] [--cache-max-entries N]\n\
                 long-running service; reads \"<file.c> [entry]\" lines\n\
                 from stdin, prints one decision per line + stats on EOF;\n\
                 --metrics-addr serves Prometheus metrics at /metrics and\n\
                 --stats-every prints a counters snapshot every N seconds\n\
       stats     [file.c...] [--format text|prom|json] [--jobs N] [--cache DIR] [...]\n\
                 run an optional batch, then print the service counters\n\
                 (text: human; prom: Prometheus exposition; json: fbo-stats-v1)\n\
       cache     <stats|gc> [--cache DIR] [--artifacts DIR]\n\
                 [--max-bytes SIZE] [--max-entries N] [--dry-run]\n\
                 offline decision-cache maintenance: stats prints per-tier\n\
                 occupancy; gc evicts down to the budget in tier-priority-\n\
                 then-LRU order (reconciled evicts first, verified last);\n\
                 --dry-run previews without deleting; SIZE accepts k/m/g\n\
       calibrate [--cache DIR] [--artifacts DIR] [--device-profile FILE]\n\
                 [--write-profile FILE]\n\
                 fit estimator scale factors from the decision cache:\n\
                 every cached decision with an estimate residue donates\n\
                 its predicted-vs-measured pairs; --write-profile saves\n\
                 the fitted registry for later --device-profile runs\n\
       worker    --listen HOST:PORT | --stdio [--artifacts DIR]\n\
                 [--caps gpu,fpga] [--device NAME] [--max-inflight N]\n\
                 host a fleet measurement worker speaking fbo-fleet-v1\n\
                 over TCP (--listen) or its own stdio pipe (--stdio)\n\
       gen-apps  [--n 256] [--dir apps]\n\
       gen-db    [--out patterndb.json]\n\
       artifacts [--dir artifacts]\n\
     \n\
     --trace-out FILE writes one JSON object per telemetry event (trace\n\
     spans, pattern measurements, arbitration verdicts, cache probes) to\n\
     FILE. Tracing is passive: the decisions and reports of a traced run\n\
     are byte-identical to an untraced one.\n\
     \n\
     --verify-parallel N measures up to N independent offload patterns of\n\
     one Step-3 search concurrently (N-1 sibling PJRT engines for\n\
     offload/stages; the pool's idle workers for batch/serve). The\n\
     decision is identical to a serial search, only faster.\n\
     \n\
     --fleet LIST deals the independent Step-3 measurements across remote\n\
     worker processes (comma-separated: host:port for a running\n\
     `fbo worker --listen`, or stdio:<command> to spawn one). Patterns a\n\
     worker cannot take (capabilities, death, timeout) fall back to the\n\
     local executor; like --verify-parallel, the decision is identical to\n\
     a serial search, only faster.\n\
     \n\
     --power-policy picks how Step-3b weighs power (arXiv:2110.11520):\n\
     perf (default) decides on time alone and is byte-identical to a\n\
     pipeline without power scoring; perf-per-watt decides on modeled\n\
     joules per run; cap:<watts> excludes backends drawing above the cap.\n\
     \n\
     --device-profile FILE loads a device-profile registry (JSON,\n\
     fbo-device-profiles-v1) for the analytic estimate stage, which\n\
     scores every candidate block against GPU/FPGA rooflines before any\n\
     measurement (arXiv:2004.09883's pre-verification sizing).\n\
     --prune-policy decides what the estimate may do to the verify plan:\n\
     off (default) is advisory only and byte-identical to a pipeline\n\
     without the stage; conservative:<margin> skips measuring blocks the\n\
     estimate predicts lose by more than the margin; aggressive skips\n\
     every predicted-losing block.\n\
     \n\
     --resident-bytes SIZE gives Step-3 measurement a device-resident\n\
     data plane with SIZE bytes of buffer budget (k/m/g suffixes):\n\
     tensors handed between adjacent offloaded blocks stay on the device\n\
     and repeated inputs skip their host->device staging, with LRU spill\n\
     of unpinned buffers past the budget. Reports gain a v5 residency\n\
     section crediting the elided PCIe transfers. Off (0) by default and\n\
     fingerprint-passive: a zero-budget run is byte-identical end to end\n\
     to a pipeline without the data plane.\n\
     \n\
     --queue-limit N bounds each worker queue, --rate-limit R meters each\n\
     client to R jobs/second (--burst B tokens of headroom): over-limit\n\
     submits fail fast with a structured rejection (and a retry hint)\n\
     instead of queueing without bound. --cache-max-bytes/--cache-max-\n\
     entries set a standing cache budget, enforced at startup and after\n\
     every insert with tier-aware LRU eviction. Like telemetry, none of\n\
     these flags changes any decision: throttled, budgeted, and unbounded\n\
     services replay each other's cached decisions byte-identically.\n"
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        eprint!("{}", usage());
        return ExitCode::FAILURE;
    };
    let args = match Args::parse(&argv[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd.as_str() {
        "analyze" => cmd_analyze(&args),
        "offload" => cmd_offload(&args),
        "stages" => cmd_stages(&args),
        "ga" => cmd_ga(&args),
        "flow" => cmd_flow(&args),
        "batch" => cmd_batch(&args),
        "serve" => cmd_serve(&args),
        "stats" => cmd_stats(&args),
        "cache" => cmd_cache(&args),
        "calibrate" => cmd_calibrate(&args),
        "worker" => cmd_worker(&args),
        "gen-apps" => cmd_gen_apps(&args),
        "gen-db" => cmd_gen_db(&args),
        "artifacts" => cmd_artifacts(&args),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{}", usage());
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
