//! Minimal JSON substrate (parser + writer).
//!
//! The published system stored the code-pattern DB in MySQL; our DB is a
//! JSON file (DESIGN.md "Substitutions") and the runtime reads the AOT
//! `artifacts/manifest.json`. No external crates are vendored for JSON, so
//! this is a small, strict RFC-8259 subset implementation: objects, arrays,
//! strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// A JSON value. Objects use BTreeMap for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (all JSON numbers are f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys -> canonical serialization).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// The string value, or an error for other kinds.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected JSON string, got {other:?}"),
        }
    }

    /// The numeric value, or an error for other kinds.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(v) => Ok(*v),
            other => bail!("expected JSON number, got {other:?}"),
        }
    }

    /// The numeric value as usize (truncating).
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// The array items, or an error for other kinds.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected JSON array, got {other:?}"),
        }
    }

    /// The object map, or an error for other kinds.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected JSON object, got {other:?}"),
        }
    }

    /// Object field access with a helpful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON field {key:?}"))
    }

    /// Optional object field.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        self.as_obj().ok().and_then(|m| m.get(key)).filter(|v| !matches!(v, Json::Null))
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Build a numeric value.
    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    /// Method form of [`to_string_pretty`].
    pub fn to_string_pretty(&self) -> String {
        to_string_pretty(self)
    }

    /// Method form of [`to_string_compact`].
    pub fn to_string_compact(&self) -> String {
        to_string_compact(self)
    }
}

// ---------------------------------------------------------------- parsing

struct P<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> P<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> u8 {
        *self.b.get(self.i).unwrap_or(&0)
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() != c {
            bail!(
                "JSON: expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek() as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            other => bail!("JSON: unexpected byte {:?} at {}", other as char, self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("JSON: bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => {
                    bail!("JSON: expected , or }} at byte {}, found {:?}", self.i, other as char)
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => {
                    bail!("JSON: expected , or ] at byte {}, found {:?}", self.i, other as char)
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            if self.i >= self.b.len() {
                bail!("JSON: unterminated string");
            }
            let c = self.b[self.i];
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.b.get(self.i).copied().unwrap_or(0);
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("JSON: truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => bail!("JSON: bad escape \\{}", other as char),
                    }
                }
                _ => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.b.len());
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == b'-' {
            self.i += 1;
        }
        while matches!(self.peek(), b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse()?))
    }
}

/// FNV-1a 64-bit hash. Used for content-addressed keys (decision cache,
/// pattern-DB fingerprint): stable across runs, platforms, and rustc
/// versions — unlike `std::hash::DefaultHasher`, whose output is
/// unspecified and must never be persisted.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Parse a JSON document.
pub fn parse(src: &str) -> Result<Json> {
    let mut p = P { b: src.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("JSON: trailing bytes at {}", p.i);
    }
    Ok(v)
}

// ---------------------------------------------------------------- writing

/// Serialize with 2-space indentation (stable ordering).
pub fn to_string_pretty(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, 0, &mut out);
    out
}

/// Serialize without any whitespace (stable ordering). One value fits on
/// one line, which is what the telemetry JSONL sink needs: one record per
/// line, canonical byte-for-byte across runs.
pub fn to_string_compact(v: &Json) -> String {
    let mut out = String::new();
    write_compact(v, &mut out);
    out
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_compact(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => write_num(*n, out),
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_compact(item, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_compact(val, out);
            }
            out.push('}');
        }
    }
}

fn write_value(v: &Json, indent: usize, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => write_num(*n, out),
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push_str("[\n");
            for (i, item) in items.iter().enumerate() {
                pad(indent + 1, out);
                write_value(item, indent + 1, out);
                if i + 1 < items.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            pad(indent, out);
            out.push(']');
        }
        Json::Obj(m) => {
            if m.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push_str("{\n");
            for (i, (k, val)) in m.iter().enumerate() {
                pad(indent + 1, out);
                write_string(k, out);
                out.push_str(": ");
                write_value(val, indent + 1, out);
                if i + 1 < m.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            pad(indent, out);
            out.push('}');
        }
    }
}

fn pad(indent: usize, out: &mut String) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = parse(src).unwrap();
        let printed = to_string_pretty(&v);
        assert_eq!(parse(&printed).unwrap(), v);
    }

    #[test]
    fn field_access() {
        let v = parse(r#"{"name": "fft", "n": 256}"#).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "fft");
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 256);
        assert!(v.get("missing").is_err());
        assert!(v.opt("missing").is_none());
    }

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{"format":"hlo-text","artifacts":[{"name":"fft2d_n64","file":"fft2d_n64.hlo.txt","inputs":[{"shape":[64,64],"dtype":"f32"}],"outputs":[{"shape":[64,64],"dtype":"f32"}]}]}"#;
        let v = parse(src).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 64);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""A\té 日本""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\té 日本");
    }

    #[test]
    fn fnv_is_stable_and_discriminating() {
        // Known FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_ne!(fnv1a64(b"abc"), fnv1a64(b"abd"));
    }

    #[test]
    fn compact_form_is_whitespace_free_and_round_trips() {
        let v = parse(r#"{"z": 1, "a": [1.5, -0.25, true, null], "s": "x y"}"#).unwrap();
        let compact = to_string_compact(&v);
        // No structural whitespace (the only spaces live inside "x y").
        assert_eq!(compact, r#"{"a":[1.5,-0.25,true,null],"s":"x y","z":1}"#);
        assert_eq!(parse(&compact).unwrap(), v);
        // Compact and pretty agree on number formatting.
        assert_eq!(to_string_compact(&Json::Num(3.0)), "3");
        assert_eq!(to_string_compact(&Json::Num(0.125)), "0.125");
    }

    #[test]
    fn canonical_form_is_reprint_stable() {
        // parse ∘ print must be the identity on printed output — the
        // decision cache relies on this for byte-identical warm reads.
        let v = parse(r#"{"z": 1, "a": [1.5, -0.25, 9007199254740991], "s": "xy"}"#).unwrap();
        let once = to_string_pretty(&v);
        let twice = to_string_pretty(&parse(&once).unwrap());
        assert_eq!(once, twice);
    }
}
