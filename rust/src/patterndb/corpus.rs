//! Comparison-code corpus registered in the code-pattern DB.
//!
//! Paper §4.1: "the correspondence to comparison code used to detect
//! libraries and IP cores with the similarity-detection technique is also
//! held" in the DB. These are the canonical CPU implementations (Numerical
//! Recipes in C style, adapted to the mini-C subset) that Deckard-style
//! similarity matches user code against (processing B-2).

/// NR-style radix-2 complex FFT (`four1`) + 2-D driver, the canonical CPU
/// Fourier transform block. `data` interleaves re/im, 1-offset like NR.
pub const NR_FFT2D: &str = r#"
void four1(double data[], int nn, int isign) {
    int n, mmax, m, j, istep, i;
    double wtemp, wr, wpr, wpi, wi, theta;
    double tempr, tempi;
    n = nn << 1;
    j = 1;
    for (i = 1; i < n; i += 2) {
        if (j > i) {
            tempr = data[j]; data[j] = data[i]; data[i] = tempr;
            tempr = data[j + 1]; data[j + 1] = data[i + 1]; data[i + 1] = tempr;
        }
        m = nn;
        while (m >= 2 && j > m) {
            j -= m;
            m >>= 1;
        }
        j += m;
    }
    mmax = 2;
    while (n > mmax) {
        istep = mmax << 1;
        theta = isign * (6.28318530717959 / mmax);
        wtemp = sin(0.5 * theta);
        wpr = -2.0 * wtemp * wtemp;
        wpi = sin(theta);
        wr = 1.0;
        wi = 0.0;
        for (m = 1; m < mmax; m += 2) {
            for (i = m; i <= n; i += istep) {
                j = i + mmax;
                tempr = wr * data[j] - wi * data[j + 1];
                tempi = wr * data[j + 1] + wi * data[j];
                data[j] = data[i] - tempr;
                data[j + 1] = data[i + 1] - tempi;
                data[i] += tempr;
                data[i + 1] += tempi;
            }
            wr = (wtemp = wr) * wpr - wi * wpi + wr;
            wi = wi * wpr + wtemp * wpi + wi;
        }
        mmax = istep;
    }
}

void fft2d_cpu(double re[], double im[], int n) {
    int i, j;
    double row[2 * n + 1];
    for (i = 0; i < n; i++) {
        for (j = 0; j < n; j++) {
            row[2 * j + 1] = re[i * n + j];
            row[2 * j + 2] = im[i * n + j];
        }
        four1(row, n, 1);
        for (j = 0; j < n; j++) {
            re[i * n + j] = row[2 * j + 1];
            im[i * n + j] = row[2 * j + 2];
        }
    }
    for (j = 0; j < n; j++) {
        for (i = 0; i < n; i++) {
            row[2 * i + 1] = re[i * n + j];
            row[2 * i + 2] = im[i * n + j];
        }
        four1(row, n, 1);
        for (i = 0; i < n; i++) {
            re[i * n + j] = row[2 * i + 1];
            im[i * n + j] = row[2 * i + 2];
        }
    }
}
"#;

/// NR-style LU decomposition without pivoting (Crout/right-looking,
/// adapted for diagonally-dominant input), the canonical CPU matrix block.
pub const NR_LUDCMP: &str = r#"
void ludcmp_nopiv(double a[], int n) {
    int i, j, k;
    double piv, factor;
    for (k = 0; k < n; k++) {
        piv = a[k * n + k];
        for (i = k + 1; i < n; i++) {
            factor = a[i * n + k] / piv;
            a[i * n + k] = factor;
            for (j = k + 1; j < n; j++) {
                a[i * n + j] = a[i * n + j] - factor * a[k * n + j];
            }
        }
    }
}
"#;

/// Triangular solve from the packed LU (getrs analog): solves `nrhs`
/// right-hand-side columns stored row-major in `b` (n x nrhs).
pub const NR_LUSOLVE: &str = r#"
void lubksb_nopiv(double a[], int n, double b[], int nrhs) {
    int i, j, r;
    double sum;
    for (r = 0; r < nrhs; r++) {
        for (i = 0; i < n; i++) {
            sum = b[i * nrhs + r];
            for (j = 0; j < i; j++) {
                sum -= a[i * n + j] * b[j * nrhs + r];
            }
            b[i * nrhs + r] = sum;
        }
        for (i = n - 1; i >= 0; i -= 1) {
            sum = b[i * nrhs + r];
            for (j = i + 1; j < n; j++) {
                sum -= a[i * n + j] * b[j * nrhs + r];
            }
            b[i * nrhs + r] = sum / a[i * n + i];
        }
    }
}
"#;

/// 2-D-array variant of the no-pivot LU (user code frequently copies the
/// textbook routine onto a `double a[N][N]` matrix). Registered as a second
/// comparison record so similarity detection covers both layouts.
pub const NR_LUDCMP_2D: &str = r#"
void ludcmp_grid(double a[][64], int n) {
    int i, j, k;
    double piv, factor;
    for (k = 0; k < n; k++) {
        piv = a[k][k];
        for (i = k + 1; i < n; i++) {
            factor = a[i][k] / piv;
            a[i][k] = factor;
            for (j = k + 1; j < n; j++) {
                a[i][j] = a[i][j] - factor * a[k][j];
            }
        }
    }
}
"#;

/// Triple-loop matrix multiply, the canonical CPU GEMM block.
pub const NR_MATMUL: &str = r#"
void matmul_cpu(double a[], double b[], double c[], int n) {
    int i, j, k;
    double sum;
    for (i = 0; i < n; i++) {
        for (j = 0; j < n; j++) {
            sum = 0.0;
            for (k = 0; k < n; k++) {
                sum += a[i * n + k] * b[k * n + j];
            }
            c[i * n + j] = sum;
        }
    }
}
"#;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parser::parse;

    #[test]
    fn corpus_sources_parse() {
        for (name, src) in [
            ("fft", NR_FFT2D),
            ("lu", NR_LUDCMP),
            ("lusolve", NR_LUSOLVE),
            ("matmul", NR_MATMUL),
        ] {
            let prog = parse(src).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(prog.functions().count() >= 1, "{name} has no functions");
        }
    }

    #[test]
    fn corpus_lu_is_numerically_correct() {
        // Factor a small diagonally-dominant matrix with the corpus code
        // under the interpreter, then verify L@U == A.
        let src = format!(
            "{NR_LUDCMP}
             double check() {{
                double a[9]; double orig[9];
                int n = 3;
                a[0]=4.0; a[1]=1.0; a[2]=2.0;
                a[3]=1.0; a[4]=5.0; a[5]=1.0;
                a[6]=2.0; a[7]=1.0; a[8]=6.0;
                for (int i = 0; i < 9; i++) orig[i] = a[i];
                ludcmp_nopiv(a, n);
                double maxerr = 0.0;
                for (int i = 0; i < n; i++) {{
                    for (int j = 0; j < n; j++) {{
                        double s = 0.0;
                        for (int k = 0; k < n; k++) {{
                            double l = 0.0;
                            double u = 0.0;
                            if (k < i) l = a[i * n + k];
                            if (k == i) l = 1.0;
                            if (k <= j) u = a[k * n + j];
                            s += l * u;
                        }}
                        double d = fabs(s - orig[i * n + j]);
                        if (d > maxerr) maxerr = d;
                    }}
                }}
                return maxerr;
             }}"
        );
        let prog = parse(&src).unwrap();
        let mut m = crate::interp::Interp::new(&prog).unwrap();
        let err = m.run("check", &[]).unwrap().as_num().unwrap();
        assert!(err < 1e-10, "LU reconstruction error {err}");
    }

    #[test]
    fn corpus_fft_matches_dft_on_small_input() {
        // four1 on an 8-point impulse: spectrum must be flat ones.
        let src = format!(
            "{NR_FFT2D}
             double check() {{
                double data[17];
                int nn = 8;
                for (int i = 1; i <= 16; i++) data[i] = 0.0;
                data[1] = 1.0;
                four1(data, nn, 1);
                double maxerr = 0.0;
                for (int k = 0; k < nn; k++) {{
                    double dre = fabs(data[2 * k + 1] - 1.0);
                    double dim = fabs(data[2 * k + 2]);
                    if (dre > maxerr) maxerr = dre;
                    if (dim > maxerr) maxerr = dim;
                }}
                return maxerr;
             }}"
        );
        let prog = parse(&src).unwrap();
        let mut m = crate::interp::Interp::new(&prog).unwrap();
        let err = m.run("check", &[]).unwrap().as_num().unwrap();
        assert!(err < 1e-10, "FFT impulse error {err}");
    }
}
