//! Code-pattern DB (paper §4.1, MySQL in the original — JSON file here).
//!
//! The DB holds, keyed by library name:
//! * the **external library list** used by analysis A-1 to recognize
//!   library calls,
//! * the replacement **GPU library / FPGA IP core** record (processing
//!   B-1): artifact name, usage recipe, OpenCL kernel code for IP cores,
//! * **comparison code** + expected signature for similarity detection
//!   (processing B-2),
//! * the declared interface of both sides, consumed by C-1/C-2.

pub mod corpus;
pub mod json;

use std::path::Path;

use anyhow::{Context, Result};

use json::Json;

/// Which device the replacement runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TargetKind {
    /// CUDA-library analog (cuFFT / cuSOLVER / cuBLAS) — PJRT artifact.
    GpuLibrary,
    /// FPGA IP core — OpenCL kernel compiled by the (simulated) HLS chain.
    FpgaIpCore,
}

impl TargetKind {
    fn as_str(self) -> &'static str {
        match self {
            TargetKind::GpuLibrary => "gpu_library",
            TargetKind::FpgaIpCore => "fpga_ip_core",
        }
    }
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "gpu_library" => TargetKind::GpuLibrary,
            "fpga_ip_core" => TargetKind::FpgaIpCore,
            other => anyhow::bail!("unknown target kind {other:?}"),
        })
    }
}

/// A parameter in a declared interface: name + C type string
/// (`"double[]"`, `"int"`, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    /// Parameter name.
    pub name: String,
    /// C type string (`"double[]"`, `"int"`, ...).
    pub ty: String,
    /// Optional parameters may be dropped without user confirmation (C-2).
    pub optional: bool,
}

/// Declared interface of a function block (either side of a replacement).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Signature {
    /// Declared parameters, in order.
    pub params: Vec<ParamSpec>,
    /// Return type string.
    pub ret: String,
}

impl Signature {
    /// Signature from `(name, type)` pairs (all required).
    pub fn new(params: &[(&str, &str)], ret: &str) -> Self {
        Signature {
            params: params
                .iter()
                .map(|(n, t)| ParamSpec { name: n.to_string(), ty: t.to_string(), optional: false })
                .collect(),
            ret: ret.to_string(),
        }
    }

    /// Mark the named parameter optional (C-2 droppable).
    pub fn with_optional(mut self, name: &str) -> Self {
        if let Some(p) = self.params.iter_mut().find(|p| p.name == name) {
            p.optional = true;
        }
        self
    }

    /// Number of non-optional parameters.
    pub fn required_count(&self) -> usize {
        self.params.iter().filter(|p| !p.optional).count()
    }
}

/// Dependent-pass structure of a streaming FPGA IP core: how many times
/// the fully pipelined datapath must stream the working set, as a function
/// of the block size `n`.
///
/// The paper treats IP cores as *existing know-how* held in the DB
/// (§4.1), so their pipelining structure is DB-registered alongside the
/// OpenCL text rather than inferred from it. The backend-arbitration
/// stage ([`crate::coordinator::backend`]) multiplies the streamed element
/// count by `passes(n)` to model execution time at `fmax`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PassModel {
    /// One pass: a pure elementwise map over the working set.
    Unit,
    /// `log2(n)` dependent passes (e.g. FFT butterfly stages).
    Log2N,
    /// `n / k` dependent wavefronts (e.g. LU pivot steps through `k`-way
    /// banked rows).
    NOver(u64),
}

impl PassModel {
    /// Number of dependent passes over the working set at block size `n`.
    pub fn passes(self, n: u64) -> u64 {
        match self {
            PassModel::Unit => 1,
            PassModel::Log2N => (63 - n.max(2).leading_zeros() as u64).max(1),
            PassModel::NOver(k) => (n / k.max(1)).max(1),
        }
    }

    fn as_str(self) -> String {
        match self {
            PassModel::Unit => "unit".to_string(),
            PassModel::Log2N => "log2n".to_string(),
            PassModel::NOver(k) => format!("n/{k}"),
        }
    }

    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "unit" => PassModel::Unit,
            "log2n" => PassModel::Log2N,
            other => match other.strip_prefix("n/") {
                Some(k) => PassModel::NOver(
                    k.parse().with_context(|| format!("bad pass model divisor {k:?}"))?,
                ),
                None => anyhow::bail!("unknown pass model {other:?}"),
            },
        })
    }
}

/// The replacement implementation registered for a block.
#[derive(Debug, Clone, PartialEq)]
pub struct Replacement {
    /// Human name, e.g. "cuFFT 2-D C2C (analog)".
    pub name: String,
    /// GPU library or FPGA IP core.
    pub kind: TargetKind,
    /// Artifact base name (runtime appends `_n{size}`), e.g. "fft2d".
    pub artifact: String,
    /// Interface of the replacement (what the artifact expects).
    pub signature: Signature,
    /// Usage recipe: how the host glue maps app arguments onto artifact
    /// inputs/outputs. Interpreted by `transform::glue`.
    pub usage: String,
    /// FPGA IP cores carry their OpenCL kernel code in the DB (paper C-1).
    pub opencl_code: Option<String>,
    /// FPGA IP cores also register their dependent-pass structure (how the
    /// streaming pipeline covers the working set); `None` for GPU records.
    pub pass_model: Option<PassModel>,
    /// Human-readable description of the implementation.
    pub description: String,
}

/// B-1 record: a callable library known to be replaceable.
#[derive(Debug, Clone)]
pub struct LibraryRecord {
    /// Primary callee-name key.
    pub library: String,
    /// Alternative callee names that match this record.
    pub aliases: Vec<String>,
    /// Interface of the *CPU* library being replaced.
    pub signature: Signature,
    /// The registered accelerator replacement.
    pub replacement: Replacement,
    /// CPU implementation source of the library (Numerical Recipes is
    /// distributed as source; the verification environment "links" this
    /// into the application for the all-CPU baseline) + its entry function.
    pub cpu_impl: Option<(String, String)>,
}

impl LibraryRecord {
    /// Does `callee` name this library (primary name or alias)?
    pub fn matches(&self, callee: &str) -> bool {
        self.library == callee || self.aliases.iter().any(|a| a == callee)
    }
}

/// B-2 record: comparison code for similarity detection.
#[derive(Debug, Clone)]
pub struct ComparisonRecord {
    /// Block label, e.g. "nr-four1-fft".
    pub block: String,
    /// Canonical CPU source held in the DB.
    pub code: String,
    /// Interface the matched user function is expected to have.
    pub signature: Signature,
    /// The registered accelerator replacement.
    pub replacement: Replacement,
}

/// The full code-pattern DB.
#[derive(Debug, Clone, Default)]
pub struct PatternDb {
    /// B-1 records: replaceable libraries by name.
    pub libraries: Vec<LibraryRecord>,
    /// B-2 records: comparison code for similarity detection.
    pub comparisons: Vec<ComparisonRecord>,
    /// Known external library names (A-1 list). Superset of `libraries`
    /// keys: includes libraries we know about but cannot accelerate.
    pub external_library_list: Vec<String>,
    /// FPGA IP-core alternatives, keyed by the artifact they accelerate
    /// (the environment-adaptation flow picks GPU or FPGA per placement;
    /// used by the FPGA narrowing path and its ablation bench).
    pub fpga_ip_cores: Vec<Replacement>,
}

impl PatternDb {
    /// B-1: find a replacement for a called library name.
    pub fn find_library(&self, callee: &str) -> Option<&LibraryRecord> {
        self.libraries.iter().find(|r| r.matches(callee))
    }

    /// Is this callee a *known* external library (A-1 list)?
    pub fn is_known_library(&self, callee: &str) -> bool {
        self.external_library_list.iter().any(|l| l == callee)
            || self.find_library(callee).is_some()
    }

    /// The built-in DB contents used by the evaluation (paper §5.1: the
    /// offloadable function blocks are prepared in the DB beforehand).
    pub fn builtin() -> Self {
        let fft_replacement = Replacement {
            name: "cuFFT 2-D C2C (analog)".into(),
            kind: TargetKind::GpuLibrary,
            artifact: "fft2d".into(),
            signature: Signature::new(
                &[("re", "double[]"), ("im", "double[]"), ("n", "int")],
                "void",
            ),
            usage: "inout:re:n*n;inout:im:n*n;size:n".into(),
            opencl_code: None,
            pass_model: None,
            description: "four-step FFT on MXU-shaped matmul stages; replaces \
                          NR four1-based 2-D FFT"
                .into(),
        };
        let lu_replacement = Replacement {
            name: "cuSOLVER getrf (analog)".into(),
            kind: TargetKind::GpuLibrary,
            artifact: "lu_factor".into(),
            signature: Signature::new(&[("a", "double[]"), ("n", "int")], "void"),
            usage: "inout:a:n*n;size:n".into(),
            opencl_code: None,
            pass_model: None,
            description: "blocked right-looking no-pivot LU; replaces NR ludcmp".into(),
        };
        let lusolve_replacement = Replacement {
            name: "cuSOLVER getrs (analog)".into(),
            kind: TargetKind::GpuLibrary,
            artifact: "lu_solve".into(),
            signature: Signature::new(
                &[("a", "double[]"), ("n", "int"), ("b", "double[]"), ("nrhs", "int")],
                "void",
            ),
            usage: "in:a:n*n;inout:b:n*nrhs;size:n".into(),
            opencl_code: None,
            pass_model: None,
            description: "triangular solve from packed LU".into(),
        };
        let mm_replacement = Replacement {
            name: "cuBLAS gemm (analog)".into(),
            kind: TargetKind::GpuLibrary,
            artifact: "matmul".into(),
            signature: Signature::new(
                &[("a", "double[]"), ("b", "double[]"), ("c", "double[]"), ("n", "int")],
                "void",
            ),
            usage: "in:a:n*n;in:b:n*n;out:c:n*n;size:n".into(),
            opencl_code: None,
            pass_model: None,
            description: "MXU-tiled dense matmul; replaces triple-loop GEMM".into(),
        };
        // FPGA twins of the same blocks: IP cores with OpenCL code in the DB
        // (paper C-1: OpenCL is held as IP-core-related information).
        let fft_fpga = Replacement {
            name: "2-D FFT IP core".into(),
            kind: TargetKind::FpgaIpCore,
            artifact: "fft2d".into(),
            signature: fft_replacement.signature.clone(),
            usage: fft_replacement.usage.clone(),
            opencl_code: Some(FFT_OPENCL.into()),
            pass_model: Some(PassModel::Log2N),
            description: "streaming radix-2 pipeline, II=1 butterfly stages".into(),
        };
        let lu_fpga = Replacement {
            name: "LU systolic IP core".into(),
            kind: TargetKind::FpgaIpCore,
            artifact: "lu_factor".into(),
            signature: lu_replacement.signature.clone(),
            usage: lu_replacement.usage.clone(),
            opencl_code: Some(LU_OPENCL.into()),
            pass_model: Some(PassModel::NOver(4)),
            description: "row-streaming LU with banked local memory".into(),
        };

        PatternDb {
            libraries: vec![
                LibraryRecord {
                    library: "fft2d".into(),
                    aliases: vec!["four2".into(), "nr_fft2d".into(), "fft2d_cpu".into()],
                    signature: Signature::new(
                        &[("re", "double[]"), ("im", "double[]"), ("n", "int")],
                        "void",
                    ),
                    replacement: fft_replacement.clone(),
                    cpu_impl: Some((corpus::NR_FFT2D.into(), "fft2d_cpu".into())),
                },
                LibraryRecord {
                    library: "ludcmp".into(),
                    aliases: vec!["ludcmp_nopiv".into(), "nr_ludcmp".into(), "lu_decompose".into()],
                    signature: Signature::new(&[("a", "double[]"), ("n", "int")], "void"),
                    replacement: lu_replacement.clone(),
                    cpu_impl: Some((corpus::NR_LUDCMP.into(), "ludcmp_nopiv".into())),
                },
                LibraryRecord {
                    library: "lubksb".into(),
                    aliases: vec!["lubksb_nopiv".into(), "lu_solve_vec".into()],
                    signature: Signature::new(
                        &[("a", "double[]"), ("n", "int"), ("b", "double[]"), ("nrhs", "int")],
                        "void",
                    ),
                    replacement: lusolve_replacement,
                    cpu_impl: Some((corpus::NR_LUSOLVE.into(), "lubksb_nopiv".into())),
                },
                LibraryRecord {
                    library: "matmul".into(),
                    aliases: vec!["matmul_cpu".into(), "dgemm_simple".into()],
                    signature: Signature::new(
                        &[("a", "double[]"), ("b", "double[]"), ("c", "double[]"), ("n", "int")],
                        "void",
                    ),
                    replacement: mm_replacement,
                    cpu_impl: Some((corpus::NR_MATMUL.into(), "matmul_cpu".into())),
                },
            ],
            comparisons: vec![
                ComparisonRecord {
                    block: "nr-four1-fft2d".into(),
                    code: corpus::NR_FFT2D.into(),
                    signature: Signature::new(
                        &[
                            ("re", "double[]"),
                            ("im", "double[]"),
                            ("n", "int"),
                            ("work", "double[]"),
                        ],
                        "void",
                    )
                    .with_optional("work"),
                    replacement: fft_replacement,
                },
                ComparisonRecord {
                    block: "nr-ludcmp".into(),
                    code: corpus::NR_LUDCMP.into(),
                    signature: Signature::new(&[("a", "double[]"), ("n", "int")], "void"),
                    replacement: lu_replacement.clone(),
                },
                ComparisonRecord {
                    block: "nr-ludcmp-2d".into(),
                    code: corpus::NR_LUDCMP_2D.into(),
                    signature: Signature::new(&[("a", "double[]"), ("n", "int")], "void"),
                    replacement: lu_replacement,
                },
                ComparisonRecord {
                    block: "nr-matmul".into(),
                    code: corpus::NR_MATMUL.into(),
                    signature: Signature::new(
                        &[("a", "double[]"), ("b", "double[]"), ("c", "double[]"), ("n", "int")],
                        "void",
                    ),
                    replacement: Replacement {
                        name: "cuBLAS gemm (analog)".into(),
                        kind: TargetKind::GpuLibrary,
                        artifact: "matmul".into(),
                        signature: Signature::new(
                            &[
                                ("a", "double[]"),
                                ("b", "double[]"),
                                ("c", "double[]"),
                                ("n", "int"),
                            ],
                            "void",
                        ),
                        usage: "in:a:n*n;in:b:n*n;out:c:n*n;size:n".into(),
                        opencl_code: None,
                        pass_model: None,
                        description: "MXU-tiled dense matmul".into(),
                    },
                },
            ],
            external_library_list: vec![
                "fft2d".into(),
                "four2".into(),
                "ludcmp".into(),
                "lubksb".into(),
                "matmul".into(),
                // Known-but-not-accelerated libraries (negative entries).
                "qsort".into(),
                "strcmp".into(),
            ],
            fpga_ip_cores: vec![fft_fpga, lu_fpga],
        }
    }

    /// FPGA IP core registered for an artifact, if any.
    pub fn find_ip_core(&self, artifact: &str) -> Option<&Replacement> {
        self.fpga_ip_cores.iter().find(|r| r.artifact == artifact)
    }

    // ------------------------------------------------------- persistence

    /// Serialize the DB to its canonical JSON value.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str("fbo-patterndb-v1")),
            (
                "external_library_list",
                Json::Arr(self.external_library_list.iter().map(Json::str).collect()),
            ),
            (
                "libraries",
                Json::Arr(
                    self.libraries
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("library", Json::str(&r.library)),
                                ("aliases", Json::Arr(r.aliases.iter().map(Json::str).collect())),
                                ("signature", sig_to_json(&r.signature)),
                                ("replacement", repl_to_json(&r.replacement)),
                                (
                                    "cpu_impl",
                                    r.cpu_impl
                                        .as_ref()
                                        .map(|(code, entry)| {
                                            Json::obj(vec![
                                                ("code", Json::str(code)),
                                                ("entry", Json::str(entry)),
                                            ])
                                        })
                                        .unwrap_or(Json::Null),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "fpga_ip_cores",
                Json::Arr(self.fpga_ip_cores.iter().map(repl_to_json).collect()),
            ),
            (
                "comparisons",
                Json::Arr(
                    self.comparisons
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("block", Json::str(&r.block)),
                                ("code", Json::str(&r.code)),
                                ("signature", sig_to_json(&r.signature)),
                                ("replacement", repl_to_json(&r.replacement)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Deserialize a DB from JSON (inverse of [`PatternDb::to_json`]).
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut db = PatternDb::default();
        for s in v.get("external_library_list")?.as_arr()? {
            db.external_library_list.push(s.as_str()?.to_string());
        }
        for r in v.get("libraries")?.as_arr()? {
            db.libraries.push(LibraryRecord {
                library: r.get("library")?.as_str()?.to_string(),
                aliases: r
                    .get("aliases")?
                    .as_arr()?
                    .iter()
                    .map(|a| Ok(a.as_str()?.to_string()))
                    .collect::<Result<_>>()?,
                signature: sig_from_json(r.get("signature")?)?,
                replacement: repl_from_json(r.get("replacement")?)?,
                cpu_impl: r
                    .opt("cpu_impl")
                    .map(|c| -> Result<(String, String)> {
                        Ok((
                            c.get("code")?.as_str()?.to_string(),
                            c.get("entry")?.as_str()?.to_string(),
                        ))
                    })
                    .transpose()?,
            });
        }
        if let Some(cores) = v.opt("fpga_ip_cores") {
            for r in cores.as_arr()? {
                db.fpga_ip_cores.push(repl_from_json(r)?);
            }
        }
        for r in v.get("comparisons")?.as_arr()? {
            db.comparisons.push(ComparisonRecord {
                block: r.get("block")?.as_str()?.to_string(),
                code: r.get("code")?.as_str()?.to_string(),
                signature: sig_from_json(r.get("signature")?)?,
                replacement: repl_from_json(r.get("replacement")?)?,
            });
        }
        Ok(db)
    }

    /// Write the DB as canonical JSON to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, json::to_string_pretty(&self.to_json()))
            .with_context(|| format!("writing pattern DB to {}", path.display()))
    }

    /// Load a DB from a JSON file.
    pub fn load(path: &Path) -> Result<Self> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading pattern DB from {}", path.display()))?;
        Self::from_json(&json::parse(&src)?)
    }

    /// Cheap content fingerprint: FNV-1a 64 over the canonical JSON
    /// serialization, as 16 hex digits. The decision cache embeds this in
    /// every key, so *any* DB change (new record, edited recipe, changed
    /// signature) invalidates previously verified offload decisions. The
    /// whole DB serializes in well under a millisecond — cheap enough to
    /// compute once per service start.
    pub fn fingerprint(&self) -> String {
        format!("{:016x}", json::fnv1a64(json::to_string_pretty(&self.to_json()).as_bytes()))
    }
}

/// Serialize a [`Signature`] (shared with the coordinator's stage codec).
pub fn sig_to_json(s: &Signature) -> Json {
    Json::obj(vec![
        (
            "params",
            Json::Arr(
                s.params
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("name", Json::str(&p.name)),
                            ("ty", Json::str(&p.ty)),
                            ("optional", Json::Bool(p.optional)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("ret", Json::str(&s.ret)),
    ])
}

/// Inverse of [`sig_to_json`].
pub fn sig_from_json(v: &Json) -> Result<Signature> {
    let mut params = Vec::new();
    for p in v.get("params")?.as_arr()? {
        params.push(ParamSpec {
            name: p.get("name")?.as_str()?.to_string(),
            ty: p.get("ty")?.as_str()?.to_string(),
            optional: matches!(p.opt("optional"), Some(Json::Bool(true))),
        });
    }
    Ok(Signature { params, ret: v.get("ret")?.as_str()?.to_string() })
}

/// Serialize a [`Replacement`] (shared with the coordinator's report codec).
pub fn repl_to_json(r: &Replacement) -> Json {
    Json::obj(vec![
        ("name", Json::str(&r.name)),
        ("kind", Json::str(r.kind.as_str())),
        ("artifact", Json::str(&r.artifact)),
        ("signature", sig_to_json(&r.signature)),
        ("usage", Json::str(&r.usage)),
        (
            "opencl_code",
            r.opencl_code.as_ref().map(Json::str).unwrap_or(Json::Null),
        ),
        (
            "pass_model",
            r.pass_model.map(|m| Json::str(m.as_str())).unwrap_or(Json::Null),
        ),
        ("description", Json::str(&r.description)),
    ])
}

/// Inverse of [`repl_to_json`].
pub fn repl_from_json(v: &Json) -> Result<Replacement> {
    Ok(Replacement {
        name: v.get("name")?.as_str()?.to_string(),
        kind: TargetKind::parse(v.get("kind")?.as_str()?)?,
        artifact: v.get("artifact")?.as_str()?.to_string(),
        signature: sig_from_json(v.get("signature")?)?,
        usage: v.get("usage")?.as_str()?.to_string(),
        opencl_code: v
            .opt("opencl_code")
            .map(|c| Ok::<_, anyhow::Error>(c.as_str()?.to_string()))
            .transpose()?,
        pass_model: v.opt("pass_model").map(|m| PassModel::parse(m.as_str()?)).transpose()?,
        description: v.get("description")?.as_str()?.to_string(),
    })
}

/// OpenCL kernel registered for the FFT IP core (DB-held, HLS-compiled).
const FFT_OPENCL: &str = r#"
__kernel void fft2d_ip(__global float2* restrict data, const int n) {
    // Streaming radix-2 stages with banked local memory; II=1 per butterfly.
    // Compiled by the (simulated) Intel HLS chain; resource model in fpga/.
}
"#;

/// OpenCL kernel registered for the LU IP core.
const LU_OPENCL: &str = r#"
__kernel void lu_ip(__global float* restrict a, const int n) {
    // Row-streaming LU: A read row-wise, B column-wise through banked
    // local memory (the paper's matrix-multiply locality example).
}
"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_has_all_eval_blocks() {
        let db = PatternDb::builtin();
        assert!(db.find_library("fft2d").is_some());
        assert!(db.find_library("ludcmp").is_some());
        assert!(db.find_library("matmul").is_some());
        assert!(db.find_library("lubksb").is_some());
        assert_eq!(db.comparisons.len(), 4);
    }

    #[test]
    fn alias_matching() {
        let db = PatternDb::builtin();
        assert!(db.find_library("ludcmp_nopiv").is_some());
        assert!(db.find_library("nr_fft2d").is_some());
        assert!(db.find_library("unknown_lib").is_none());
    }

    #[test]
    fn known_library_list_includes_negatives() {
        let db = PatternDb::builtin();
        assert!(db.is_known_library("qsort"));
        assert!(db.find_library("qsort").is_none()); // known, not accelerable
    }

    #[test]
    fn json_round_trip() {
        let db = PatternDb::builtin();
        let j = db.to_json();
        let back = PatternDb::from_json(&j).unwrap();
        assert_eq!(back.libraries.len(), db.libraries.len());
        assert_eq!(back.comparisons.len(), db.comparisons.len());
        assert_eq!(back.libraries[0].replacement, db.libraries[0].replacement);
        assert_eq!(
            back.comparisons[0].signature.required_count(),
            db.comparisons[0].signature.required_count()
        );
    }

    #[test]
    fn file_round_trip() {
        let db = PatternDb::builtin();
        let dir = std::env::temp_dir().join(format!("fbo-db-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        db.save(&path).unwrap();
        let back = PatternDb::load(&path).unwrap();
        assert_eq!(back.libraries.len(), db.libraries.len());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fpga_ip_cores_registered() {
        let db = PatternDb::builtin();
        assert!(db.find_ip_core("fft2d").is_some());
        assert!(db.find_ip_core("lu_factor").is_some());
        assert!(db.find_ip_core("matmul").is_none());
        let core = db.find_ip_core("fft2d").unwrap();
        assert_eq!(core.kind, TargetKind::FpgaIpCore);
        assert!(core.opencl_code.is_some());
        assert_eq!(core.pass_model, Some(PassModel::Log2N));
        // Round-trips through JSON (including the pass model).
        let back = PatternDb::from_json(&db.to_json()).unwrap();
        assert_eq!(back.fpga_ip_cores.len(), 2);
        assert_eq!(back.fpga_ip_cores[0].pass_model, db.fpga_ip_cores[0].pass_model);
        assert_eq!(back.fpga_ip_cores[1].pass_model, Some(PassModel::NOver(4)));
    }

    #[test]
    fn pass_model_counts_and_round_trips() {
        assert_eq!(PassModel::Unit.passes(1024), 1);
        assert_eq!(PassModel::Log2N.passes(64), 6);
        assert_eq!(PassModel::Log2N.passes(2), 1);
        assert_eq!(PassModel::NOver(4).passes(64), 16);
        assert_eq!(PassModel::NOver(0).passes(64), 64, "zero divisor is clamped");
        for m in [PassModel::Unit, PassModel::Log2N, PassModel::NOver(8)] {
            assert_eq!(PassModel::parse(&m.as_str()).unwrap(), m);
        }
        assert!(PassModel::parse("n/x").is_err());
        assert!(PassModel::parse("cubic").is_err());
    }

    #[test]
    fn fingerprint_tracks_content() {
        let db = PatternDb::builtin();
        let fp = db.fingerprint();
        assert_eq!(fp.len(), 16);
        assert_eq!(fp, PatternDb::builtin().fingerprint(), "must be deterministic");
        // Any content change flips the fingerprint.
        let mut edited = db.clone();
        edited.external_library_list.push("new_lib".into());
        assert_ne!(edited.fingerprint(), fp);
        let mut edited = db.clone();
        edited.libraries[0].replacement.usage.push_str(";pad:1");
        assert_ne!(edited.fingerprint(), fp);
    }

    #[test]
    fn optional_params_tracked() {
        let db = PatternDb::builtin();
        let fft_cmp = &db.comparisons[0];
        assert_eq!(fft_cmp.signature.params.len(), 4);
        assert_eq!(fft_cmp.signature.required_count(), 3);
    }
}
