//! Deckard-style code-similarity detection (processing B-2).
//!
//! Deckard (Jiang et al., ICSE'07; used by the paper as its similarity
//! tool) maps AST subtrees to **characteristic vectors** — occurrence
//! counts of node kinds — and clusters vectors by Euclidean proximity;
//! copied-then-edited code (renamed variables, changed comments, tweaked
//! constants) lands near the original because none of those edits move the
//! vector much. We implement the same mechanism over our mini-C AST:
//!
//! * [`CharVector::from_func`] — vector of a function definition,
//! * [`similarity`] — size-normalized Euclidean similarity in [0, 1],
//! * [`Detector`] — matches A-2 candidate functions against the comparison
//!   code registered in the pattern DB, applying the DB threshold.
//!
//! Per the paper, independently written code is *out of scope*: the tool
//! only claims copied/adapted code (§3.4 B-2), which is exactly what a
//! count-vector can catch.

use crate::parser::ast::*;
use crate::parser::parse;
use crate::patterndb::PatternDb;

use anyhow::Result;

/// Vector dimensionality (see `idx` for the layout).
pub const DIM: usize = 29;

/// Occurrence-count characteristic vector of an AST subtree.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CharVector {
    /// Occurrence counts, laid out per the internal `idx` map.
    pub counts: [u32; DIM],
}

// Dimension layout.
mod idx {
    pub const DECL: usize = 0;
    pub const EXPR_STMT: usize = 1;
    pub const BLOCK: usize = 2;
    pub const IF: usize = 3;
    pub const FOR: usize = 4;
    pub const WHILE: usize = 5;
    pub const DO_WHILE: usize = 6;
    pub const RETURN: usize = 7;
    pub const BREAK: usize = 8;
    pub const CONTINUE: usize = 9;
    pub const INT_LIT: usize = 10;
    pub const FLOAT_LIT: usize = 11;
    pub const IDENT: usize = 12;
    pub const ASSIGN_SET: usize = 13;
    pub const ASSIGN_COMPOUND: usize = 14;
    pub const CALL_MATH: usize = 15;
    pub const CALL_OTHER: usize = 16;
    pub const INDEX: usize = 17;
    pub const MEMBER: usize = 18;
    pub const TERNARY: usize = 19;
    pub const CAST: usize = 20;
    pub const UNARY: usize = 21;
    pub const POST_INC_DEC: usize = 22;
    pub const BIN_ADD_SUB: usize = 23;
    pub const BIN_MUL_DIV: usize = 24;
    pub const BIN_REM: usize = 25;
    pub const BIN_CMP: usize = 26;
    pub const BIN_LOGICAL: usize = 27;
    pub const BIN_BIT_SHIFT: usize = 28;
}

impl CharVector {
    /// Vector over a statement subtree.
    pub fn from_stmt(s: &Stmt) -> Self {
        let mut v = CharVector::default();
        s.walk(&mut |st| v.count_stmt(st));
        // walk_exprs visits every expression node exactly once.
        s.walk_exprs(&mut |e| v.count_expr_node(e));
        v
    }

    /// Vector over a function definition (body + one slot per parameter,
    /// so arity differences register slightly).
    pub fn from_func(f: &FuncDef) -> Self {
        let mut v = match &f.body {
            Some(b) => Self::from_stmt(b),
            None => CharVector::default(),
        };
        v.counts[idx::IDENT] += f.params.len() as u32;
        v
    }

    /// Merged vector of every function in a source snippet (comparison
    /// code may be split into helpers — NR fft2d = four1 + driver).
    pub fn from_source_merged(src: &str) -> Result<Self> {
        let prog = parse(src)?;
        let mut v = CharVector::default();
        for f in prog.functions() {
            v.add(&Self::from_func(f));
        }
        Ok(v)
    }

    /// Per-function vectors of a source snippet.
    pub fn from_source_functions(src: &str) -> Result<Vec<(String, Self)>> {
        let prog = parse(src)?;
        Ok(prog
            .functions()
            .filter(|f| f.body.is_some())
            .map(|f| (f.name.clone(), Self::from_func(f)))
            .collect())
    }

    fn count_stmt(&mut self, s: &Stmt) {
        let slot = match &s.kind {
            StmtKind::Decl(_) => idx::DECL,
            StmtKind::Expr(_) => idx::EXPR_STMT,
            StmtKind::Block(_) => idx::BLOCK,
            StmtKind::If(..) => idx::IF,
            StmtKind::For { .. } => idx::FOR,
            StmtKind::While(..) => idx::WHILE,
            StmtKind::DoWhile(..) => idx::DO_WHILE,
            StmtKind::Return(_) => idx::RETURN,
            StmtKind::Break => idx::BREAK,
            StmtKind::Continue => idx::CONTINUE,
            StmtKind::Empty => return,
        };
        self.counts[slot] += 1;
    }

    fn count_expr_node(&mut self, e: &Expr) {
        let slot = match &e.kind {
            ExprKind::IntLit(_) | ExprKind::CharLit(_) => idx::INT_LIT,
            ExprKind::FloatLit(_) => idx::FLOAT_LIT,
            ExprKind::StrLit(_) => idx::IDENT,
            ExprKind::Ident(_) => idx::IDENT,
            ExprKind::Assign(AssignOp::Set, ..) => idx::ASSIGN_SET,
            ExprKind::Assign(..) => idx::ASSIGN_COMPOUND,
            ExprKind::Call(name, _) => {
                if crate::interp::builtins::math1(name).is_some()
                    || crate::interp::builtins::math2(name).is_some()
                {
                    idx::CALL_MATH
                } else {
                    idx::CALL_OTHER
                }
            }
            ExprKind::Index(..) => idx::INDEX,
            ExprKind::Member(..) => idx::MEMBER,
            ExprKind::Ternary(..) => idx::TERNARY,
            ExprKind::Cast(..) => idx::CAST,
            ExprKind::SizeOf(_) => idx::CAST,
            ExprKind::Unary(..) => idx::UNARY,
            ExprKind::PostIncDec(..) => idx::POST_INC_DEC,
            ExprKind::Binary(op, ..) => match op {
                BinOp::Add | BinOp::Sub => idx::BIN_ADD_SUB,
                BinOp::Mul | BinOp::Div => idx::BIN_MUL_DIV,
                BinOp::Rem => idx::BIN_REM,
                op if op.is_comparison() => idx::BIN_CMP,
                BinOp::And | BinOp::Or => idx::BIN_LOGICAL,
                _ => idx::BIN_BIT_SHIFT,
            },
        };
        self.counts[slot] += 1;
    }

    /// Element-wise accumulate another vector.
    pub fn add(&mut self, other: &Self) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }

    /// Euclidean norm.
    pub fn norm(&self) -> f64 {
        self.counts.iter().map(|&c| (c as f64) * (c as f64)).sum::<f64>().sqrt()
    }

    /// Total node count (vector mass).
    pub fn total(&self) -> u32 {
        self.counts.iter().sum()
    }
}

/// Euclidean distance between two vectors.
pub fn distance(a: &CharVector, b: &CharVector) -> f64 {
    a.counts
        .iter()
        .zip(&b.counts)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Size-normalized similarity in [0, 1]: `1 - dist / (|a| + |b|)`.
/// Identical trees → 1; disjoint trees → near 0. This is Deckard's
/// size-scaled proximity test expressed as a score instead of a radius.
pub fn similarity(a: &CharVector, b: &CharVector) -> f64 {
    let denom = a.norm() + b.norm();
    if denom == 0.0 {
        return 1.0;
    }
    (1.0 - distance(a, b) / denom).max(0.0)
}

/// A similarity hit: user function ↔ DB comparison record.
#[derive(Debug, Clone)]
pub struct Match {
    /// Matched user-defined function name.
    pub function: String,
    /// DB block label that matched.
    pub block: String,
    /// Similarity score in [0, 1].
    pub score: f64,
    /// Index into `PatternDb::comparisons`.
    pub record: usize,
}

/// Similarity detector bound to a pattern DB.
pub struct Detector {
    /// Minimum score for a match.
    pub threshold: f64,
    /// (record index, block, per-function vectors, merged vector).
    records: Vec<(usize, String, Vec<CharVector>, CharVector)>,
}

/// Default detection threshold (paper: "judged by the tool's threshold").
pub const DEFAULT_THRESHOLD: f64 = 0.85;

impl Detector {
    /// Build a detector from the DB's comparison records.
    pub fn new(db: &PatternDb, threshold: f64) -> Result<Self> {
        let mut records = Vec::new();
        for (i, rec) in db.comparisons.iter().enumerate() {
            let per_fn: Vec<CharVector> = CharVector::from_source_functions(&rec.code)?
                .into_iter()
                .map(|(_, v)| v)
                .collect();
            let merged = CharVector::from_source_merged(&rec.code)?;
            records.push((i, rec.block.clone(), per_fn, merged));
        }
        Ok(Detector { threshold, records })
    }

    /// Score one user function against one DB record: best of per-function
    /// and merged comparisons (copied code may inline helpers or keep them
    /// split).
    pub fn score_record(&self, v: &CharVector, record: usize) -> f64 {
        let (_, _, per_fn, merged) = &self.records[record];
        let mut best = similarity(v, merged);
        for rv in per_fn {
            best = best.max(similarity(v, rv));
        }
        best
    }

    /// B-2: scan a program's defined functions for DB matches. Returns the
    /// best record per function, above threshold, best-score-first.
    pub fn detect(&self, prog: &Program) -> Vec<Match> {
        let mut out = Vec::new();
        for f in prog.functions().filter(|f| f.body.is_some()) {
            let v = CharVector::from_func(f);
            // Tiny functions (getters etc.) carry no copy signal.
            if v.total() < 20 {
                continue;
            }
            let mut best: Option<Match> = None;
            for (ri, block, _, _) in &self.records {
                let score = self.score_record(&v, *ri);
                if score >= self.threshold
                    && best.as_ref().map(|b| score > b.score).unwrap_or(true)
                {
                    best = Some(Match {
                        function: f.name.clone(),
                        block: block.clone(),
                        score,
                        record: *ri,
                    });
                }
            }
            if let Some(m) = best {
                out.push(m);
            }
        }
        out.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        out
    }
}

/// Convenience: detect with a record-set built from `db` at the default
/// threshold (paper evaluation conditions).
pub fn detect_blocks(prog: &Program, db: &PatternDb) -> Result<Vec<Match>> {
    Detector::new(db, DEFAULT_THRESHOLD).map(|d| d.detect(prog))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterndb::corpus;

    #[test]
    fn identical_code_scores_one() {
        let v = CharVector::from_source_merged(corpus::NR_LUDCMP).unwrap();
        assert!((similarity(&v, &v) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn renamed_copy_scores_above_threshold() {
        // Rename every identifier — the classic copied-code edit.
        let renamed = corpus::NR_LUDCMP
            .replace("ludcmp_nopiv", "my_decomp")
            .replace("piv", "pp")
            .replace("factor", "ff")
            .replace('a', "mtx") // crude but effective rename of the array
            .replace("int n", "int dim")
            .replace(" n;", " dim;")
            .replace("< n", "< dim")
            .replace("n +", "dim +")
            .replace("* n", "* dim");
        if let Ok(v2) = CharVector::from_source_merged(&renamed) {
            let v1 = CharVector::from_source_merged(corpus::NR_LUDCMP).unwrap();
            assert!(
                similarity(&v1, &v2) > DEFAULT_THRESHOLD,
                "renamed copy should stay similar: {}",
                similarity(&v1, &v2)
            );
        } else {
            // If the crude rename produced unparseable code, do a clean
            // variable-only rename instead.
            let renamed = corpus::NR_LUDCMP
                .replace("ludcmp_nopiv", "my_decomp")
                .replace("piv", "pp")
                .replace("factor", "ff");
            let v1 = CharVector::from_source_merged(corpus::NR_LUDCMP).unwrap();
            let v2 = CharVector::from_source_merged(&renamed).unwrap();
            assert!(similarity(&v1, &v2) > DEFAULT_THRESHOLD);
        }
    }

    #[test]
    fn different_algorithms_score_low() {
        let v_fft = CharVector::from_source_merged(corpus::NR_FFT2D).unwrap();
        let v_lu = CharVector::from_source_merged(corpus::NR_LUDCMP).unwrap();
        assert!(similarity(&v_fft, &v_lu) < DEFAULT_THRESHOLD);
    }

    #[test]
    fn small_edits_stay_close_big_rewrites_dont() {
        let original = corpus::NR_MATMUL;
        let edited = original.replace("sum += a[i * n + k] * b[k * n + j];",
                                      "sum = sum + a[i * n + k] * b[k * n + j] * 1.0;");
        let v1 = CharVector::from_source_merged(original).unwrap();
        let v2 = CharVector::from_source_merged(&edited).unwrap();
        assert!(similarity(&v1, &v2) > 0.9);
    }

    #[test]
    fn detector_finds_copied_lu_in_program() {
        let db = PatternDb::builtin();
        // A user program that copied ludcmp and renamed things.
        let app = corpus::NR_LUDCMP.replace("ludcmp_nopiv", "decompose_matrix")
            .replace("factor", "scale");
        let src = format!(
            "{app}
             int main() {{
                double a[16];
                for (int i = 0; i < 16; i++) a[i] = 1.0;
                for (int i = 0; i < 4; i++) a[i * 4 + i] = 10.0;
                decompose_matrix(a, 4);
                return 0;
             }}"
        );
        let prog = crate::parser::parse(&src).unwrap();
        let matches = detect_blocks(&prog, &db).unwrap();
        assert!(
            matches.iter().any(|m| m.function == "decompose_matrix" && m.block == "nr-ludcmp"),
            "matches: {matches:?}"
        );
        // main() must not match anything.
        assert!(!matches.iter().any(|m| m.function == "main"));
    }

    #[test]
    fn threshold_is_respected() {
        let db = PatternDb::builtin();
        let prog = crate::parser::parse(
            "double dot(double a[], double b[], int n) {
                double s = 0.0;
                for (int i = 0; i < n; i++) s += a[i] * b[i];
                return s;
            }
            int main() { double a[4]; double b[4]; return dot(a, b, 4); }",
        )
        .unwrap();
        // dot() shares surface features with matmul but is much smaller;
        // at a strict threshold it must not match.
        let det = Detector::new(&db, 0.95).unwrap();
        assert!(det.detect(&prog).is_empty());
    }

    #[test]
    fn vector_counts_are_sane() {
        let v = CharVector::from_source_merged(corpus::NR_MATMUL).unwrap();
        assert_eq!(v.counts[idx::FOR], 3); // triple loop
        assert!(v.counts[idx::INDEX] >= 3); // a, b, c element accesses
        assert!(v.total() > 20);
    }
}
