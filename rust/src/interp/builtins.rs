//! Builtin C library functions available to interpreted programs.
//!
//! Covers the `math.h` surface Numerical-Recipes-style code needs, plus
//! `printf` (captured into the interpreter's output buffer, so verification
//! runs are hermetic) and a few convenience intrinsics used by the sample
//! applications.

use anyhow::{bail, Result};

use super::eval::Interp;
use super::value::Value;

/// Math builtins: (name, arity).
const MATH_1: &[(&str, fn(f64) -> f64)] = &[
    ("sin", f64::sin),
    ("cos", f64::cos),
    ("tan", f64::tan),
    ("asin", f64::asin),
    ("acos", f64::acos),
    ("atan", f64::atan),
    ("exp", f64::exp),
    ("log", f64::ln),
    ("log10", f64::log10),
    ("sqrt", f64::sqrt),
    ("fabs", f64::abs),
    ("floor", f64::floor),
    ("ceil", f64::ceil),
];

const MATH_2: &[(&str, fn(f64, f64) -> f64)] = &[
    ("pow", f64::powf),
    ("atan2", f64::atan2),
    ("fmod", |a, b| a % b),
    ("fmax", f64::max),
    ("fmin", f64::min),
];

/// Unary libm builtin by name (`sin`, `cos`, `sqrt`, ...).
pub fn math1(name: &str) -> Option<fn(f64) -> f64> {
    MATH_1.iter().find(|(n, _)| *n == name).map(|(_, f)| *f)
}

/// Binary libm builtin by name (`pow`, `atan2`, ...).
pub fn math2(name: &str) -> Option<fn(f64, f64) -> f64> {
    MATH_2.iter().find(|(n, _)| *n == name).map(|(_, f)| *f)
}

/// Is `name` an interpreter builtin (libm or printf-family)?
pub fn is_builtin(name: &str) -> bool {
    math1(name).is_some()
        || math2(name).is_some()
        || matches!(name, "printf" | "abs" | "exit" | "assert_true")
}

/// Dispatch a builtin call with evaluated arguments.
pub fn call(interp: &mut Interp, name: &str, args: &[Value]) -> Result<Value> {
    if let Some(f) = math1(name) {
        if args.len() != 1 {
            bail!("{name} expects 1 argument, got {}", args.len());
        }
        return Ok(Value::Float(f(args[0].as_num()?)));
    }
    if let Some(f) = math2(name) {
        if args.len() != 2 {
            bail!("{name} expects 2 arguments, got {}", args.len());
        }
        return Ok(Value::Float(f(args[0].as_num()?, args[1].as_num()?)));
    }
    match name {
        "abs" => Ok(Value::Int(args[0].as_int()?.abs())),
        "printf" => {
            let out = format_printf(args)?;
            interp.output.push_str(&out);
            Ok(Value::Int(out.len() as i64))
        }
        "exit" => bail!(
            "program called exit({})",
            args.first().map(|v| v.as_int().unwrap_or(0)).unwrap_or(0)
        ),
        // Test helper: fails the run when the condition is false.
        "assert_true" => {
            if args[0].as_num()? == 0.0 {
                bail!("assert_true failed in interpreted program");
            }
            Ok(Value::Int(1))
        }
        _ => bail!("unknown builtin {name:?}"),
    }
}

/// Minimal printf: supports %d %ld %f %g %e %s %c and %% plus width/precision
/// qualifiers, which are accepted and approximated.
fn format_printf(args: &[Value]) -> Result<String> {
    let fmt = match args.first() {
        Some(Value::Str(s)) => s.clone(),
        _ => bail!("printf requires a format string"),
    };
    let mut out = String::new();
    let mut ai = 1usize;
    let bytes = fmt.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] != b'%' {
            out.push(bytes[i] as char);
            i += 1;
            continue;
        }
        i += 1;
        if i < bytes.len() && bytes[i] == b'%' {
            out.push('%');
            i += 1;
            continue;
        }
        // Skip flags/width/precision/length.
        let mut precision: Option<usize> = None;
        while i < bytes.len()
            && (bytes[i].is_ascii_digit()
                || bytes[i] == b'.'
                || bytes[i] == b'-'
                || bytes[i] == b'+'
                || bytes[i] == b'l'
                || bytes[i] == b'h')
        {
            if bytes[i] == b'.' {
                let mut p = 0usize;
                i += 1;
                while i < bytes.len() && bytes[i].is_ascii_digit() {
                    p = p * 10 + (bytes[i] - b'0') as usize;
                    i += 1;
                }
                precision = Some(p);
                continue;
            }
            i += 1;
        }
        if i >= bytes.len() {
            bail!("dangling %% conversion in printf format");
        }
        let conv = bytes[i] as char;
        i += 1;
        let arg = args.get(ai).cloned();
        ai += 1;
        match conv {
            'd' | 'i' | 'u' => {
                let v = arg.map(|v| v.as_int()).transpose()?.unwrap_or(0);
                out.push_str(&v.to_string());
            }
            'f' | 'F' => {
                let v = arg.map(|v| v.as_num()).transpose()?.unwrap_or(0.0);
                out.push_str(&format!("{:.*}", precision.unwrap_or(6), v));
            }
            'e' | 'E' => {
                let v = arg.map(|v| v.as_num()).transpose()?.unwrap_or(0.0);
                out.push_str(&format!("{:.*e}", precision.unwrap_or(6), v));
            }
            'g' | 'G' => {
                let v = arg.map(|v| v.as_num()).transpose()?.unwrap_or(0.0);
                out.push_str(&format!("{v}"));
            }
            's' => match arg {
                Some(Value::Str(s)) => out.push_str(&s),
                Some(other) => out.push_str(&format!("{other:?}")),
                None => {}
            },
            'c' => {
                let v = arg.map(|v| v.as_int()).transpose()?.unwrap_or(0);
                out.push((v as u8) as char);
            }
            other => bail!("unsupported printf conversion %{other}"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::rc::Rc;

    #[test]
    fn printf_formats() {
        let args = vec![
            Value::Str(Rc::new("x=%d y=%.2f s=%s %%".to_string())),
            Value::Int(7),
            Value::Float(1.234),
            Value::Str(Rc::new("ok".to_string())),
        ];
        assert_eq!(format_printf(&args).unwrap(), "x=7 y=1.23 s=ok %");
    }

    #[test]
    fn printf_rejects_missing_format() {
        assert!(format_printf(&[Value::Int(1)]).is_err());
    }

    #[test]
    fn math_tables() {
        assert!(math1("sqrt").is_some());
        assert!(math2("pow").is_some());
        assert!(is_builtin("printf"));
        assert!(!is_builtin("cufftExec"));
    }
}
