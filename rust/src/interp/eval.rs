//! Tree-walking evaluator — the "CPU execution" of the verification
//! environment.
//!
//! The paper's verification machine compiles the C application with gcc and
//! runs it on the CPU; our substitute executes the same parsed AST directly
//! (DESIGN.md "Substitutions"). Offloaded function blocks are dispatched to
//! registered *external functions* (PJRT artifacts installed by the
//! coordinator), and loops selected by the GA loop offloader run through the
//! bulk executor in [`super::offload_exec`].

use std::collections::{HashMap, HashSet};
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::parser::ast::*;
use super::builtins;
use super::offload_exec::{self, CompiledLoop};
use super::value::{Slice, SliceOrScalar, StructData, Value};

/// Statement-level control flow signal.
pub enum Flow {
    /// Fall through to the next statement.
    Normal,
    /// `break` out of the innermost loop.
    Break,
    /// `continue` the innermost loop.
    Continue,
    /// `return` (with the function's value).
    Return(Value),
}

/// External (offloaded) function: installed by the coordinator, backed by a
/// PJRT executable or the loop-offload executor.
pub type ExternalFn = Rc<dyn Fn(&[Value]) -> Result<Value>>;

/// Execution statistics for one run.
#[derive(Debug, Default, Clone)]
pub struct RunStats {
    /// Interpreter steps (statements + expression nodes evaluated).
    pub steps: u64,
    /// Calls dispatched to external (offloaded) functions.
    pub external_calls: u64,
    /// Loops executed through the bulk (GPU-simulating) executor.
    pub bulk_loops: u64,
    /// Bytes "transferred" to/from the simulated accelerator (paid only;
    /// residency-elided bytes are counted separately).
    pub transfer_bytes: u64,
    /// Bytes whose transfer was elided because the value was resident on
    /// the device (zero unless a data plane is installed).
    pub elided_transfer_bytes: u64,
}

/// The interpreter. One instance holds a parsed program plus the offload
/// configuration; `run` executes an entry function.
pub struct Interp {
    prog: Program,
    funcs: HashMap<String, Rc<FuncDef>>, // avoids per-call AST clones
    /// Installed external (offloaded) functions by dispatch name.
    pub externals: HashMap<String, ExternalFn>,
    /// Loop statements (by node id) that the GA marked as GPU-offloaded.
    pub offloaded_loops: HashSet<NodeId>,
    /// Per-launch transfer overhead in simulated bytes (PCIe model).
    pub stats: RunStats,
    /// Captured `printf` output of the last run.
    pub output: String,
    /// Execution fuel; `run` fails when exhausted (guards runaway loops).
    pub fuel: u64,
    /// Device-resident data plane shared with the engine; when installed,
    /// the bulk executor classifies loop transfers as paid or elided.
    /// Configuration, not run state: [`Interp::reset_run_state`] keeps it.
    pub data_plane: Option<Rc<crate::runtime::DataPlane>>,
    scopes: Vec<HashMap<String, Value>>,
    globals: HashMap<String, Value>,
    loop_cache: HashMap<NodeId, Option<Rc<CompiledLoop>>>,
    /// Per-block cache: does this block declare variables? Decl-free
    /// blocks (the common case inside loops) skip the scope push — a
    /// HashMap allocation per loop iteration otherwise.
    block_has_decl: HashMap<NodeId, bool>,
}

impl Interp {
    /// Build an interpreter over a parsed program.
    pub fn new(prog: &Program) -> Result<Self> {
        let mut funcs = HashMap::new();
        for item in &prog.items {
            if let Item::Func(f) = item {
                if f.body.is_some() {
                    funcs.insert(f.name.clone(), Rc::new(f.clone()));
                }
            }
        }
        let mut interp = Interp {
            prog: prog.clone(),
            funcs,
            externals: HashMap::new(),
            offloaded_loops: HashSet::new(),
            stats: RunStats::default(),
            output: String::new(),
            fuel: u64::MAX,
            data_plane: None,
            scopes: Vec::new(),
            globals: HashMap::new(),
            loop_cache: HashMap::new(),
            block_has_decl: HashMap::new(),
        };
        interp.init_globals()?;
        Ok(interp)
    }

    fn init_globals(&mut self) -> Result<()> {
        let items = self.prog.items.clone();
        for item in &items {
            if let Item::Global(decls) = item {
                for d in decls {
                    let v = self.make_decl_value(d)?;
                    self.globals.insert(d.name.clone(), v);
                }
            }
        }
        Ok(())
    }

    /// Register an external function (offload target).
    pub fn set_external(&mut self, name: &str, f: ExternalFn) {
        self.externals.insert(name.to_string(), f);
    }

    /// Mark a set of loops (node ids) for bulk offload execution.
    pub fn set_offloaded_loops(&mut self, loops: HashSet<NodeId>) {
        self.offloaded_loops = loops;
        self.loop_cache.clear();
    }

    /// Reset per-run state (stats, output) but keep configuration.
    pub fn reset_run_state(&mut self) -> Result<()> {
        self.stats = RunStats::default();
        self.output.clear();
        self.scopes.clear();
        self.globals.clear();
        self.init_globals()
    }

    /// Run a zero/N-arg entry function to completion.
    pub fn run(&mut self, entry: &str, args: &[Value]) -> Result<Value> {
        let fd = self
            .funcs
            .get(entry)
            .cloned()
            .ok_or_else(|| anyhow!("no function named {entry:?} with a body"))?;
        self.call_ast_function(&fd, args.to_vec())
    }

    fn step(&mut self) -> Result<()> {
        self.stats.steps += 1;
        if self.stats.steps > self.fuel {
            bail!("execution fuel exhausted after {} steps", self.fuel);
        }
        Ok(())
    }

    // ------------------------------------------------------------ scopes

    /// Look up a variable by name (used by the bulk executor at launch).
    pub fn lookup_value(&self, name: &str) -> Option<Value> {
        self.lookup(name).ok().cloned()
    }

    /// Store a scalar back into an existing variable (bulk-executor
    /// reduction write-back); preserves the slot's declared kind.
    pub fn store_scalar(&mut self, name: &str, v: f64) -> Result<()> {
        self.assign_var(name, Value::Float(v))
    }

    fn lookup(&self, name: &str) -> Result<&Value> {
        for scope in self.scopes.iter().rev() {
            if let Some(v) = scope.get(name) {
                return Ok(v);
            }
        }
        self.globals
            .get(name)
            .ok_or_else(|| anyhow!("undefined variable {name:?}"))
    }

    fn assign_var(&mut self, name: &str, v: Value) -> Result<()> {
        for scope in self.scopes.iter_mut().rev() {
            if let Some(slot) = scope.get_mut(name) {
                *slot = slot.coerce_like(v)?;
                return Ok(());
            }
        }
        if let Some(slot) = self.globals.get_mut(name) {
            *slot = slot.coerce_like(v)?;
            return Ok(());
        }
        bail!("assignment to undefined variable {name:?}")
    }

    fn declare(&mut self, name: &str, v: Value) {
        self.scopes
            .last_mut()
            .expect("declare outside scope")
            .insert(name.to_string(), v);
    }

    fn make_decl_value(&mut self, d: &VarDecl) -> Result<Value> {
        let is_int = !d.ty.base().map(|b| b.is_float()).unwrap_or(false);
        if !d.dims.is_empty() {
            let mut dims = Vec::with_capacity(d.dims.len());
            for e in &d.dims {
                let n = self.eval(e)?.as_int()?;
                if n <= 0 {
                    bail!("array dimension must be positive, got {n}");
                }
                dims.push(n as usize);
            }
            let slice = Slice::zeros(&dims, is_int && !d.ty.base().map_or(false, |b| b.is_float()));
            if let Some(init) = &d.init {
                // Array initialized from a call returning an array.
                let v = self.eval(init)?;
                if let Value::Arr(src) = v {
                    slice.copy_from(&src.to_vec())?;
                }
            }
            return Ok(Value::Arr(slice));
        }
        if let Ty::Struct(sname) = &d.ty {
            let sd = self
                .prog
                .structs()
                .find(|s| &s.name == sname)
                .ok_or_else(|| anyhow!("unknown struct {sname:?}"))?
                .clone();
            let mut fields = HashMap::new();
            for f in &sd.fields {
                let fv = self.make_decl_value(f)?;
                fields.insert(f.name.clone(), fv);
            }
            return Ok(Value::Struct(Rc::new(std::cell::RefCell::new(StructData {
                name: sname.clone(),
                fields,
            }))));
        }
        // Pointer declarations start null-ish; they must be assigned an
        // array before use.
        let mut v = if d.ty.base().map(|b| b.is_float()).unwrap_or(false) {
            Value::Float(0.0)
        } else {
            Value::Int(0)
        };
        if let Some(init) = &d.init {
            let iv = self.eval(init)?;
            v = match iv {
                Value::Arr(_) | Value::Struct(_) | Value::Str(_) => iv,
                other => v.coerce_like(other)?,
            };
        }
        Ok(v)
    }

    // ------------------------------------------------------------ functions

    pub(super) fn call_ast_function(&mut self, fd: &FuncDef, args: Vec<Value>) -> Result<Value> {
        if args.len() != fd.params.len() {
            bail!(
                "{} expects {} args, got {}",
                fd.name,
                fd.params.len(),
                args.len()
            );
        }
        let mut frame = HashMap::new();
        for (p, a) in fd.params.iter().zip(args) {
            // Scalars coerce to the parameter type; arrays/structs bind by
            // reference.
            let bound = match (&p.ty, p.array_dims, &a) {
                (_, 0, Value::Int(_) | Value::Float(_)) if !p.ty.is_ptr() => {
                    let proto = if p.ty.base().map(|b| b.is_float()).unwrap_or(false) {
                        Value::Float(0.0)
                    } else {
                        Value::Int(0)
                    };
                    proto.coerce_like(a.clone())?
                }
                _ => a.clone(),
            };
            frame.insert(p.name.clone(), bound);
        }
        let saved = std::mem::take(&mut self.scopes);
        self.scopes.push(frame);
        let body = fd.body.as_ref().expect("call of bodyless function");
        let flow = self.exec(body);
        self.scopes = saved;
        match flow? {
            // C coerces the returned value to the declared return type.
            Flow::Return(v) => match (fd.ret.base(), &v) {
                (Some(b), Value::Int(_) | Value::Float(_)) if !fd.ret.is_ptr() => {
                    if b.is_float() {
                        Ok(Value::Float(v.as_num()?))
                    } else if b == BaseTy::Void {
                        Ok(Value::Void)
                    } else {
                        Ok(Value::Int(v.as_int()?))
                    }
                }
                _ => Ok(v),
            },
            _ => Ok(Value::Void),
        }
    }

    fn call(&mut self, name: &str, arg_exprs: &[Expr]) -> Result<Value> {
        // Externals take precedence: the transformer redirects call sites to
        // `__fb_*` names, and tests may stub app functions.
        if self.externals.contains_key(name) {
            let mut args = Vec::with_capacity(arg_exprs.len());
            for a in arg_exprs {
                args.push(self.eval(a)?);
            }
            self.stats.external_calls += 1;
            let f = self.externals.get(name).unwrap().clone();
            return f(&args);
        }
        if let Some(fd) = self.funcs.get(name).cloned() {
            let mut args = Vec::with_capacity(arg_exprs.len());
            for a in arg_exprs {
                args.push(self.eval(a)?);
            }
            return self.call_ast_function(&fd, args);
        }
        // Builtins (math library, printf, ...).
        if builtins::is_builtin(name) {
            let mut args = Vec::with_capacity(arg_exprs.len());
            for a in arg_exprs {
                args.push(self.eval(a)?);
            }
            return builtins::call(self, name, &args);
        }
        bail!("call to undefined function {name:?} (not defined, extern, or builtin)")
    }

    // ------------------------------------------------------------ statements

    /// Execute one statement.
    pub fn exec(&mut self, s: &Stmt) -> Result<Flow> {
        self.step()?;
        match &s.kind {
            StmtKind::Empty => Ok(Flow::Normal),
            StmtKind::Block(stmts) => {
                let needs_scope = *self.block_has_decl.entry(s.id).or_insert_with(|| {
                    stmts.iter().any(|st| matches!(st.kind, StmtKind::Decl(_)))
                });
                if needs_scope {
                    self.scopes.push(HashMap::new());
                }
                let mut flow = Flow::Normal;
                for st in stmts {
                    flow = self.exec(st)?;
                    if !matches!(flow, Flow::Normal) {
                        break;
                    }
                }
                if needs_scope {
                    self.scopes.pop();
                }
                Ok(flow)
            }
            StmtKind::Decl(decls) => {
                for d in decls {
                    let v = self.make_decl_value(d)?;
                    self.declare(&d.name, v);
                }
                Ok(Flow::Normal)
            }
            StmtKind::Expr(e) => {
                self.eval(e)?;
                Ok(Flow::Normal)
            }
            StmtKind::If(cond, then, els) => {
                if self.eval(cond)?.truthy()? {
                    self.exec(then)
                } else if let Some(e) = els {
                    self.exec(e)
                } else {
                    Ok(Flow::Normal)
                }
            }
            StmtKind::While(cond, body) => {
                while self.eval(cond)?.truthy()? {
                    match self.exec(body)? {
                        Flow::Break => break,
                        Flow::Return(v) => return Ok(Flow::Return(v)),
                        _ => {}
                    }
                }
                Ok(Flow::Normal)
            }
            StmtKind::DoWhile(body, cond) => {
                loop {
                    match self.exec(body)? {
                        Flow::Break => break,
                        Flow::Return(v) => return Ok(Flow::Return(v)),
                        _ => {}
                    }
                    if !self.eval(cond)?.truthy()? {
                        break;
                    }
                }
                Ok(Flow::Normal)
            }
            StmtKind::For { init, cond, step, body } => {
                // GA-selected loops run on the bulk (simulated-GPU) executor
                // when their shape qualifies; otherwise interpret.
                if self.offloaded_loops.contains(&s.id) {
                    if let Some(flow) = self.try_bulk_loop(s)? {
                        return Ok(flow);
                    }
                }
                let needs_scope =
                    matches!(init.as_deref(), Some(Stmt { kind: StmtKind::Decl(_), .. }));
                if needs_scope {
                    self.scopes.push(HashMap::new());
                }
                let r = self.exec_for(init, cond, step, body);
                if needs_scope {
                    self.scopes.pop();
                }
                r
            }
            StmtKind::Return(e) => {
                let v = match e {
                    Some(e) => self.eval(e)?,
                    None => Value::Void,
                };
                Ok(Flow::Return(v))
            }
            StmtKind::Break => Ok(Flow::Break),
            StmtKind::Continue => Ok(Flow::Continue),
        }
    }

    fn exec_for(
        &mut self,
        init: &Option<Box<Stmt>>,
        cond: &Option<Expr>,
        step: &Option<Expr>,
        body: &Stmt,
    ) -> Result<Flow> {
        if let Some(i) = init {
            self.exec(i)?;
        }
        loop {
            if let Some(c) = cond {
                if !self.eval(c)?.truthy()? {
                    break;
                }
            }
            match self.exec(body)? {
                Flow::Break => break,
                Flow::Return(v) => return Ok(Flow::Return(v)),
                _ => {}
            }
            if let Some(st) = step {
                self.eval(st)?;
            }
        }
        Ok(Flow::Normal)
    }

    /// Attempt bulk (offloaded) execution of a for-loop. Returns Some(flow)
    /// if the loop ran on the bulk executor, None to fall back.
    fn try_bulk_loop(&mut self, s: &Stmt) -> Result<Option<Flow>> {
        let compiled = match self.loop_cache.get(&s.id) {
            Some(c) => c.clone(),
            None => {
                let c = offload_exec::compile_loop(s).map(Rc::new);
                self.loop_cache.insert(s.id, c.clone());
                c
            }
        };
        let Some(compiled) = compiled else {
            return Ok(None);
        };
        match offload_exec::run_bulk(self, &compiled)? {
            true => {
                self.stats.bulk_loops += 1;
                Ok(Some(Flow::Normal))
            }
            false => Ok(None),
        }
    }

    // ------------------------------------------------------------ expressions

    /// Evaluate one expression.
    pub fn eval(&mut self, e: &Expr) -> Result<Value> {
        self.step()?;
        match &e.kind {
            ExprKind::IntLit(v) => Ok(Value::Int(*v)),
            ExprKind::FloatLit(v) => Ok(Value::Float(*v)),
            ExprKind::StrLit(s) => Ok(Value::Str(Rc::new(s.clone()))),
            ExprKind::CharLit(c) => Ok(Value::Int(*c as i64)),
            ExprKind::Ident(n) => Ok(self.lookup(n)?.clone()),
            ExprKind::SizeOf(ty) => Ok(Value::Int(match ty.base() {
                Some(BaseTy::Double) | Some(BaseTy::Long) => 8,
                Some(BaseTy::Float) | Some(BaseTy::Int) => 4,
                Some(BaseTy::Char) => 1,
                _ => 8,
            })),
            ExprKind::Cast(ty, inner) => {
                let v = self.eval(inner)?;
                Ok(match ty.base() {
                    Some(b) if b.is_float() => Value::Float(v.as_num()?),
                    Some(BaseTy::Void) => Value::Void,
                    Some(_) => Value::Int(v.as_int()?),
                    None => v,
                })
            }
            ExprKind::Unary(op, inner) => self.eval_unary(*op, inner),
            ExprKind::PostIncDec(target, inc) => {
                let old = self.eval(target)?;
                let delta = if *inc { 1.0 } else { -1.0 };
                let new = match old {
                    Value::Int(v) => Value::Int(v + delta as i64),
                    Value::Float(v) => Value::Float(v + delta),
                    other => bail!("++/-- on non-numeric {}", other.type_name()),
                };
                self.store(target, new)?;
                Ok(old)
            }
            ExprKind::Binary(op, a, b) => self.eval_binary(*op, a, b),
            ExprKind::Ternary(c, t, els) => {
                if self.eval(c)?.truthy()? {
                    self.eval(t)
                } else {
                    self.eval(els)
                }
            }
            ExprKind::Assign(op, lhs, rhs) => {
                let rv = self.eval(rhs)?;
                let result = match op {
                    AssignOp::Set => rv,
                    _ => {
                        let old = self.eval(lhs)?;
                        let bin = match op {
                            AssignOp::Add => BinOp::Add,
                            AssignOp::Sub => BinOp::Sub,
                            AssignOp::Mul => BinOp::Mul,
                            AssignOp::Div => BinOp::Div,
                            AssignOp::Rem => BinOp::Rem,
                            AssignOp::Shl => BinOp::Shl,
                            AssignOp::Shr => BinOp::Shr,
                            AssignOp::Set => unreachable!(),
                        };
                        numeric_binop(bin, &old, &rv)?
                    }
                };
                self.store(lhs, result.clone())?;
                Ok(result)
            }
            ExprKind::Call(name, args) => self.call(name, args),
            ExprKind::Index(base, idx) => {
                // Direct recursive indexing: no chain collection, no
                // per-access allocation (hot path of every array program).
                let base_v = self.eval(base)?;
                let i = self.eval(idx)?.as_int()?;
                match base_v.as_arr()?.index(i)? {
                    SliceOrScalar::Slice(s) => Ok(Value::Arr(s)),
                    SliceOrScalar::Scalar(x, is_int) => Ok(if is_int {
                        Value::Int(x as i64)
                    } else {
                        Value::Float(x)
                    }),
                }
            }
            ExprKind::Member(base, field) => {
                let v = self.eval(base)?;
                match v {
                    Value::Struct(s) => {
                        let b = s.borrow();
                        b.fields
                            .get(field)
                            .cloned()
                            .ok_or_else(|| anyhow!("struct {} has no field {field:?}", b.name))
                    }
                    other => bail!("member access on non-struct {}", other.type_name()),
                }
            }
        }
    }

    fn eval_unary(&mut self, op: UnOp, inner: &Expr) -> Result<Value> {
        match op {
            UnOp::Neg => Ok(match self.eval(inner)? {
                Value::Int(v) => Value::Int(-v),
                Value::Float(v) => Value::Float(-v),
                other => bail!("negation of {}", other.type_name()),
            }),
            UnOp::Not => Ok(Value::Int(if self.eval(inner)?.truthy()? { 0 } else { 1 })),
            UnOp::BitNot => Ok(Value::Int(!self.eval(inner)?.as_int()?)),
            UnOp::Deref => {
                // *p == p[0] in this subset.
                let v = self.eval(inner)?;
                match v {
                    Value::Arr(s) => match s.index(0)? {
                        SliceOrScalar::Scalar(x, is_int) => Ok(if is_int {
                            Value::Int(x as i64)
                        } else {
                            Value::Float(x)
                        }),
                        SliceOrScalar::Slice(s) => Ok(Value::Arr(s)),
                    },
                    other => bail!("deref of {}", other.type_name()),
                }
            }
            UnOp::Addr => self.eval(inner), // arrays/structs are handles already
            UnOp::PreInc | UnOp::PreDec => {
                let delta = if matches!(op, UnOp::PreInc) { 1.0 } else { -1.0 };
                let old = self.eval(inner)?;
                let new = match old {
                    Value::Int(v) => Value::Int(v + delta as i64),
                    Value::Float(v) => Value::Float(v + delta),
                    other => bail!("++/-- on {}", other.type_name()),
                };
                self.store(inner, new.clone())?;
                Ok(new)
            }
        }
    }

    fn eval_binary(&mut self, op: BinOp, a: &Expr, b: &Expr) -> Result<Value> {
        // Short-circuit logical operators.
        match op {
            BinOp::And => {
                return Ok(Value::Int(
                    (self.eval(a)?.truthy()? && self.eval(b)?.truthy()?) as i64,
                ))
            }
            BinOp::Or => {
                return Ok(Value::Int(
                    (self.eval(a)?.truthy()? || self.eval(b)?.truthy()?) as i64,
                ))
            }
            _ => {}
        }
        let va = self.eval(a)?;
        let vb = self.eval(b)?;
        numeric_binop(op, &va, &vb)
    }

    /// Store `v` into the lvalue denoted by `target`.
    fn store(&mut self, target: &Expr, v: Value) -> Result<()> {
        match &target.kind {
            ExprKind::Ident(n) => self.assign_var(n, v),
            ExprKind::Index(base, idx) => {
                // Evaluate the base (possibly itself an index -> row view),
                // then store through the final index.
                let slice = match self.eval(base)? {
                    Value::Arr(s) => s,
                    other => bail!("indexing into {}", other.type_name()),
                };
                if slice.dims.len() != 1 {
                    bail!("partial-index store requires full index chain");
                }
                let i = self.eval(idx)?.as_int()?;
                slice.set_checked(i, v.as_num()?)
            }
            ExprKind::Member(base, field) => {
                let bv = self.eval(base)?;
                match bv {
                    Value::Struct(s) => {
                        let mut b = s.borrow_mut();
                        let slot = b
                            .fields
                            .get_mut(field)
                            .ok_or_else(|| anyhow!("no field {field:?}"))?;
                        *slot = slot.coerce_like(v)?;
                        Ok(())
                    }
                    other => bail!("member store on {}", other.type_name()),
                }
            }
            ExprKind::Unary(UnOp::Deref, inner) => {
                let arr = self.eval(inner)?;
                arr.as_arr()?.set_checked(0, v.as_num()?)
            }
            other => bail!("invalid assignment target: {other:?}"),
        }
    }
}

impl Slice {
    /// Bounds-checked leading-dim store used by the evaluator.
    fn set_checked(&self, i: i64, v: f64) -> Result<()> {
        if i < 0 || (i as usize) >= self.dims[0] {
            bail!("store index {i} out of bounds for dim {}", self.dims[0]);
        }
        self.set(i as usize, v)
    }
}

/// Shared numeric binary-op semantics (also used by the bulk executor).
pub fn numeric_binop(op: BinOp, a: &Value, b: &Value) -> Result<Value> {
    let int_mode = matches!((a, b), (Value::Int(_), Value::Int(_)));
    if int_mode {
        let (x, y) = (a.as_int()?, b.as_int()?);
        let v = match op {
            BinOp::Add => x.wrapping_add(y),
            BinOp::Sub => x.wrapping_sub(y),
            BinOp::Mul => x.wrapping_mul(y),
            BinOp::Div => {
                if y == 0 {
                    bail!("integer division by zero");
                }
                x / y
            }
            BinOp::Rem => {
                if y == 0 {
                    bail!("integer remainder by zero");
                }
                x % y
            }
            BinOp::Eq => (x == y) as i64,
            BinOp::Ne => (x != y) as i64,
            BinOp::Lt => (x < y) as i64,
            BinOp::Gt => (x > y) as i64,
            BinOp::Le => (x <= y) as i64,
            BinOp::Ge => (x >= y) as i64,
            BinOp::BitAnd => x & y,
            BinOp::BitOr => x | y,
            BinOp::BitXor => x ^ y,
            BinOp::Shl => x.wrapping_shl(y as u32),
            BinOp::Shr => x.wrapping_shr(y as u32),
            BinOp::And | BinOp::Or => unreachable!("short-circuit handled earlier"),
        };
        return Ok(Value::Int(v));
    }
    let (x, y) = (a.as_num()?, b.as_num()?);
    Ok(match op {
        BinOp::Add => Value::Float(x + y),
        BinOp::Sub => Value::Float(x - y),
        BinOp::Mul => Value::Float(x * y),
        BinOp::Div => Value::Float(x / y),
        BinOp::Rem => Value::Float(x % y),
        BinOp::Eq => Value::Int((x == y) as i64),
        BinOp::Ne => Value::Int((x != y) as i64),
        BinOp::Lt => Value::Int((x < y) as i64),
        BinOp::Gt => Value::Int((x > y) as i64),
        BinOp::Le => Value::Int((x <= y) as i64),
        BinOp::Ge => Value::Int((x >= y) as i64),
        BinOp::BitAnd | BinOp::BitOr | BinOp::BitXor | BinOp::Shl | BinOp::Shr => {
            bail!("bitwise op on float operands")
        }
        BinOp::And | BinOp::Or => unreachable!(),
    })
}

/// Flatten `a[i][j]...` into (base expression, [index expressions]).
pub fn collect_index_chain(e: &Expr) -> Result<(&Expr, Vec<&Expr>)> {
    let mut indices = Vec::new();
    let mut cur = e;
    while let ExprKind::Index(base, idx) = &cur.kind {
        indices.push(idx.as_ref());
        cur = base;
    }
    indices.reverse();
    Ok((cur, indices))
}
