//! AST interpreter + simulated-accelerator execution substrate.
//!
//! The paper's verification environment compiles the candidate offload
//! pattern and *measures* it; this module is our measurable execution
//! substrate (DESIGN.md "Substitutions"):
//!
//! * [`eval::Interp`] — tree-walking evaluator = the all-CPU baseline,
//! * [`offload_exec`] — bulk loop executor = GPU *loop* offload ([33]),
//! * external functions (`Interp::set_external`) — dispatch points where
//!   the transformer splices in PJRT **function-block** artifacts.

pub mod builtins;
pub mod eval;
pub mod offload_exec;
pub mod value;

pub use eval::{ExternalFn, Flow, Interp, RunStats};
pub use value::{Slice, Value};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parser::parse;
    use std::collections::HashSet;
    use std::rc::Rc;

    fn run_main(src: &str) -> (Value, Interp) {
        let prog = parse(src).expect("parse");
        let mut m = Interp::new(&prog).expect("interp");
        let v = m.run("main", &[]).expect("run");
        (v, m)
    }

    #[test]
    fn arithmetic_and_control_flow() {
        let (v, _) = run_main(
            "int main() {
                int s = 0;
                for (int i = 0; i < 10; i++) { if (i % 2 == 0) s += i; }
                return s;
            }",
        );
        assert!(matches!(v, Value::Int(20)));
    }

    #[test]
    fn float_promotion_and_math() {
        let (v, _) = run_main(
            "double main() {
                double x = 2.0;
                return sqrt(x * 8.0);
            }",
        );
        match v {
            Value::Float(f) => assert!((f - 4.0).abs() < 1e-12),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn arrays_and_views() {
        let (v, _) = run_main(
            "double main() {
                double m[3][4];
                for (int i = 0; i < 3; i++)
                    for (int j = 0; j < 4; j++)
                        m[i][j] = i * 10 + j;
                return m[2][3];
            }",
        );
        assert!(matches!(v, Value::Float(f) if f == 23.0));
    }

    #[test]
    fn arrays_pass_by_reference() {
        let (v, _) = run_main(
            "void fill(double a[], int n) { for (int i = 0; i < n; i++) a[i] = i; }
             double main() { double a[5]; fill(a, 5); return a[4]; }",
        );
        assert!(matches!(v, Value::Float(f) if f == 4.0));
    }

    #[test]
    fn while_do_while_break_continue() {
        let (v, _) = run_main(
            "int main() {
                int i = 0, s = 0;
                while (1) { i++; if (i > 5) break; if (i == 2) continue; s += i; }
                do { s += 100; } while (0);
                return s;
            }",
        );
        assert!(matches!(v, Value::Int(113)));
    }

    #[test]
    fn struct_fields() {
        let (v, _) = run_main(
            "struct P { double x; double y; };
             double main() { struct P p; p.x = 3.0; p.y = 4.0; return sqrt(p.x*p.x + p.y*p.y); }",
        );
        assert!(matches!(v, Value::Float(f) if (f - 5.0).abs() < 1e-12));
    }

    #[test]
    fn printf_captured() {
        let (_, m) = run_main(
            "int main() { printf(\"v=%d %.1f\\n\", 3, 2.5); return 0; }",
        );
        assert_eq!(m.output, "v=3 2.5\n");
    }

    #[test]
    fn int_semantics_division_truncation() {
        let (v, _) = run_main("int main() { int a = 7 / 2; int b = -7 / 2; return a * 100 + b; }");
        assert!(matches!(v, Value::Int(297))); // 3*100 + (-3)
    }

    #[test]
    fn globals_initialized() {
        let (v, _) =
            run_main("int N = 6; double tbl[4]; int main() { tbl[2] = N; return tbl[2]; }");
        assert!(matches!(v, Value::Int(6)));
    }

    #[test]
    fn external_function_dispatch() {
        let prog = parse(
            "double main() { double a[4]; a[0] = 2.0; return __fb_double_it(a); }",
        )
        .unwrap();
        let mut m = Interp::new(&prog).unwrap();
        m.set_external(
            "__fb_double_it",
            Rc::new(|args: &[Value]| {
                let s = args[0].as_arr()?;
                Ok(Value::Float(s.get(0)? * 2.0))
            }),
        );
        let v = m.run("main", &[]).unwrap();
        assert!(matches!(v, Value::Float(f) if f == 4.0));
        assert_eq!(m.stats.external_calls, 1);
    }

    #[test]
    fn fuel_guards_infinite_loops() {
        let prog = parse("int main() { while (1) {} return 0; }").unwrap();
        let mut m = Interp::new(&prog).unwrap();
        m.fuel = 10_000;
        assert!(m.run("main", &[]).is_err());
    }

    #[test]
    fn out_of_bounds_is_error_not_ub() {
        let prog = parse("int main() { double a[2]; a[5] = 1.0; return 0; }").unwrap();
        let mut m = Interp::new(&prog).unwrap();
        assert!(m.run("main", &[]).is_err());
    }

    #[test]
    fn call_to_unknown_function_errors() {
        let prog = parse("int main() { return mystery(); }").unwrap();
        let mut m = Interp::new(&prog).unwrap();
        let err = m.run("main", &[]).unwrap_err().to_string();
        assert!(err.contains("mystery"), "{err}");
    }

    #[test]
    fn recursion_works() {
        let (v, _) = run_main(
            "int fib(int n) { if (n < 2) return n; return fib(n-1) + fib(n-2); }
             int main() { return fib(12); }",
        );
        assert!(matches!(v, Value::Int(144)));
    }

    #[test]
    fn nr_style_fft_bit_reversal_runs() {
        // The data-shuffle prologue of NR four1 — heavy while/if logic.
        let (v, _) = run_main(
            "int main() {
                int nn = 8; int n = nn << 1; int j = 1; int count = 0;
                double data[17];
                for (int i = 1; i < n; i += 2) {
                    if (j > i) { double t = data[j]; data[j] = data[i]; data[i] = t; count++; }
                    int m = nn;
                    while (m >= 2 && j > m) { j -= m; m = m >> 1; }
                    j += m;
                }
                return count;
            }",
        );
        // Known swap count for n=8 complex bit-reversal.
        assert!(matches!(v, Value::Int(c) if c > 0));
    }

    // ---------------------------------------------------- bulk executor

    const SAXPY: &str = "
        int main() {
            int n = 1000;
            double x[1000]; double y[1000];
            for (int i = 0; i < n; i++) { x[i] = i; y[i] = 2 * i; }
            for (int i = 0; i < n; i++) { y[i] = y[i] + 3.0 * x[i]; }
            double s = 0.0;
            for (int i = 0; i < n; i++) { s = s + y[i]; }
            return s;
        }";

    fn loop_ids(src: &str) -> Vec<crate::parser::NodeId> {
        let prog = parse(src).unwrap();
        let mut ids = Vec::new();
        for f in prog.functions() {
            if let Some(b) = &f.body {
                b.walk(&mut |s| {
                    if matches!(s.kind, crate::parser::StmtKind::For { .. }) {
                        ids.push(s.id);
                    }
                });
            }
        }
        ids
    }

    #[test]
    fn bulk_executor_matches_interpreter() {
        let prog = parse(SAXPY).unwrap();
        // Plain run.
        let mut m1 = Interp::new(&prog).unwrap();
        let v1 = m1.run("main", &[]).unwrap().as_num().unwrap();
        // All loops offloaded.
        let mut m2 = Interp::new(&prog).unwrap();
        m2.set_offloaded_loops(loop_ids(SAXPY).into_iter().collect());
        let v2 = m2.run("main", &[]).unwrap().as_num().unwrap();
        assert_eq!(v1, v2);
        assert!(m2.stats.bulk_loops >= 3, "bulk loops: {}", m2.stats.bulk_loops);
        assert!(m2.stats.transfer_bytes > 0);
    }

    #[test]
    fn bulk_2d_nest_matches_interpreter() {
        let src = "
            int main() {
                double a[32][32]; double b[32][32];
                for (int i = 0; i < 32; i++)
                    for (int j = 0; j < 32; j++)
                        a[i][j] = i + j;
                for (int i = 0; i < 32; i++)
                    for (int j = 0; j < 32; j++)
                        b[i][j] = 2.0 * a[i][j] + sin(0.0);
                double s = 0.0;
                for (int i = 0; i < 32; i++)
                    for (int j = 0; j < 32; j++)
                        s += b[i][j];
                return s;
            }";
        let prog = parse(src).unwrap();
        let mut m1 = Interp::new(&prog).unwrap();
        let v1 = m1.run("main", &[]).unwrap().as_num().unwrap();
        let mut m2 = Interp::new(&prog).unwrap();
        m2.set_offloaded_loops(loop_ids(src).into_iter().collect());
        let v2 = m2.run("main", &[]).unwrap().as_num().unwrap();
        assert_eq!(v1, v2);
        assert!(m2.stats.bulk_loops >= 2);
    }

    #[test]
    fn sequential_loop_falls_back_to_interpreter() {
        // Loop-carried dependence: prefix sum. Must NOT run bulk.
        let src = "
            int main() {
                double a[100];
                for (int i = 0; i < 100; i++) a[i] = 1.0;
                for (int i = 1; i < 100; i++) a[i] = a[i] + a[i-1];
                return a[99];
            }";
        let prog = parse(src).unwrap();
        let mut m = Interp::new(&prog).unwrap();
        m.set_offloaded_loops(loop_ids(src).into_iter().collect());
        let v = m.run("main", &[]).unwrap().as_num().unwrap();
        assert_eq!(v, 100.0);
        // First loop bulk-eligible, second must fall back.
        assert_eq!(m.stats.bulk_loops, 1);
    }

    #[test]
    fn compile_loop_rejects_user_calls() {
        let src = "
            double f(double x) { return x * 2.0; }
            int main() {
                double a[10];
                for (int i = 0; i < 10; i++) a[i] = f(i);
                return 0;
            }";
        let prog = parse(src).unwrap();
        let main = prog.find_function("main").unwrap();
        let mut found = None;
        main.body.as_ref().unwrap().walk(&mut |s| {
            if matches!(s.kind, crate::parser::StmtKind::For { .. }) && found.is_none() {
                found = Some(s.clone());
            }
        });
        assert!(offload_exec::compile_loop(&found.unwrap()).is_none());
    }

    #[test]
    fn self_referential_temp_terminates_and_runs_correctly() {
        // `sum += ...` on a per-iteration temp compiles to a
        // self-referential definition; the dependence analysis must
        // terminate (depth cap) and bulk execution must match the
        // interpreter (regression: stack overflow on the NR matmul corpus).
        let src = "
            int main() {
                double a[16]; double b[16]; double c[16];
                int n = 4;
                for (int i = 0; i < 16; i++) { a[i] = i; b[i] = 2.0 * i; }
                for (int i = 0; i < n; i++) {
                    for (int j = 0; j < n; j++) {
                        double sum = 0.0;
                        sum = 0.0;
                        for (int k = 0; k < n; k++) {
                            sum += a[i * n + k] * b[k * n + j];
                        }
                        c[i * n + j] = sum;
                    }
                }
                double t = 0.0;
                for (int i = 0; i < 16; i++) t += c[i];
                return t;
            }";
        let prog = parse(src).unwrap();
        let mut plain = Interp::new(&prog).unwrap();
        let expected = plain.run("main", &[]).unwrap().as_num().unwrap();
        let mut bulk = Interp::new(&prog).unwrap();
        bulk.set_offloaded_loops(loop_ids(src).into_iter().collect());
        let got = bulk.run("main", &[]).unwrap().as_num().unwrap();
        assert_eq!(got, expected);
    }

    #[test]
    fn reset_run_state_reinitializes_globals() {
        let prog = parse("int g = 5; int main() { g = g + 1; return g; }").unwrap();
        let mut m = Interp::new(&prog).unwrap();
        assert!(matches!(m.run("main", &[]).unwrap(), Value::Int(6)));
        m.reset_run_state().unwrap();
        assert!(matches!(m.run("main", &[]).unwrap(), Value::Int(6)));
    }
}
