//! Bulk loop executor — the simulated **GPU loop offload** backend.
//!
//! This is the substrate for the prior-work baseline ([33]): when the GA
//! marks a parallelizable `for` loop as offloaded, the verification
//! environment executes it here instead of the tree-walking evaluator.
//! The model mirrors what `#pragma acc kernels` gives a real GPU:
//!
//! * **compile**: the loop nest is lowered once into a resolved symbolic
//!   program (no name lookups in the hot loop) — the analog of PGI
//!   generating a GPU kernel;
//! * **transfer**: every bound array is physically copied in and out of a
//!   scratch "device" buffer, so offload cost scales with data size exactly
//!   like PCIe traffic, plus a fixed per-launch latency (spin-wait, not
//!   sleep, for determinism);
//! * **execute**: the body runs over the scratch buffers with direct slot
//!   addressing — much faster than interpretation, the way a GPU kernel is
//!   much faster than single-thread C.
//!
//! The net effect reproduces the paper's loop-offload economics: big
//! arithmetic-dense loops win, small loops lose to transfer+launch cost,
//! and the GA has a real measured signal to optimize.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::parser::ast::*;
use super::builtins;
use super::eval::Interp;
use super::value::Value;

/// Fixed per-launch overhead of the simulated accelerator (kernel launch +
/// driver latency). Spin-waited for determinism.
pub const LAUNCH_OVERHEAD: Duration = Duration::from_micros(20);

/// Symbolic, name-resolved expression (no AST, no hash lookups).
///
/// NOTE: `PartialEq` compares `Call1`/`Call2` by function pointer — for the
/// dependence checker that is exactly the syntactic-equality question being
/// asked (same builtin), so the lint is suppressed deliberately.
#[allow(unpredictable_function_pointer_comparisons)]
#[derive(Debug, Clone, PartialEq)]
pub enum Sym {
    /// Constant operand.
    Const(f64),
    /// Loop variable at nest depth `k`.
    LoopVar(usize),
    /// Loop-invariant scalar, bound at launch time (slot index).
    Scalar(usize),
    /// Array element read: (array slot, index expressions).
    Read(usize, Vec<Sym>),
    /// Binary arithmetic/comparison on two operands.
    Bin(BinOp, Box<Sym>, Box<Sym>),
    /// Arithmetic negation.
    Neg(Box<Sym>),
    /// Truncation toward zero (int cast).
    Trunc(Box<Sym>),
    /// Unary libm call (by function pointer).
    Call1(fn(f64) -> f64, Box<Sym>),
    /// Binary libm call (by function pointer).
    Call2(fn(f64, f64) -> f64, Box<Sym>, Box<Sym>),
    /// `c ? t : e` select.
    Ternary(Box<Sym>, Box<Sym>, Box<Sym>),
    /// Per-iteration scalar temporary (defined by `BulkStmt::LetTmp`
    /// earlier in the same iteration).
    Tmp(usize),
}

/// One loop level of the compiled nest.
#[derive(Debug, Clone)]
pub struct LoopSpec {
    /// Loop-variable slot in the device's `loop_vals`.
    pub var: usize,
    /// Lower bound (inclusive).
    pub lo: Sym,
    /// Upper bound (see `inclusive`).
    pub hi: Sym,
    /// True for `<=` loops, false for `<`.
    pub inclusive: bool,
    /// Constant stride (negative = downward).
    pub step: i64,
}

/// Body statements of the compiled nest. Loops may nest arbitrarily and
/// mix with other statements (imperfect nests — the NR LU panel shape).
#[derive(Debug, Clone)]
pub enum BulkStmt {
    /// `arr[indices] op= value`.
    Store { arr: usize, indices: Vec<Sym>, op: AssignOp, value: Sym },
    /// `acc op= value` — reduction into a scalar accumulator.
    Reduce { acc: usize, op: AssignOp, value: Sym },
    /// `t = value` — per-iteration scalar temporary (NR-style
    /// `j = i + mmax; tempr = ...` bodies).
    LetTmp { slot: usize, value: Sym },
    /// A nested loop with its own body.
    Loop { spec: LoopSpec, body: Vec<BulkStmt> },
}

/// A loop nest compiled for bulk execution. `body` holds the root loop
/// (a single `BulkStmt::Loop`).
#[derive(Debug, Clone)]
pub struct CompiledLoop {
    /// Total loop-variable slots across the whole (possibly imperfect) nest.
    pub n_vars: usize,
    /// Root statements (a single `BulkStmt::Loop`).
    pub body: Vec<BulkStmt>,
    /// Array names bound at launch.
    pub arrays: Vec<String>,
    /// Loop-invariant scalar names bound at launch.
    pub scalars: Vec<String>,
    /// Reduction accumulator names (written back after the launch).
    pub reductions: Vec<String>,
    /// Per-iteration temporary names (slot-indexed).
    pub temps: Vec<String>,
}

impl CompiledLoop {
    /// True when the compiled nest performs a scalar reduction.
    pub fn is_reduction(&self) -> bool {
        !self.reductions.is_empty()
    }
}

// ===================================================================
// Compilation (AST -> CompiledLoop)
// ===================================================================

struct Compiler {
    /// Names of loop variables currently in scope (innermost last).
    visible_loop_vars: Vec<String>,
    /// Slot allocated for each visible loop var (parallel to the above).
    visible_slots: Vec<usize>,
    /// Total slots allocated so far.
    n_vars: usize,
    arrays: Vec<String>,
    scalars: Vec<String>,
    reductions: Vec<String>,
    /// Per-iteration temporaries: (name, defining expression).
    temps: Vec<(String, Sym)>,
}

impl Compiler {
    fn arr_slot(&mut self, name: &str) -> usize {
        if let Some(i) = self.arrays.iter().position(|a| a == name) {
            i
        } else {
            self.arrays.push(name.to_string());
            self.arrays.len() - 1
        }
    }

    fn scalar_slot(&mut self, name: &str) -> usize {
        if let Some(i) = self.scalars.iter().position(|a| a == name) {
            i
        } else {
            self.scalars.push(name.to_string());
            self.scalars.len() - 1
        }
    }

    fn compile_expr(&mut self, e: &Expr) -> Option<Sym> {
        Some(match &e.kind {
            ExprKind::IntLit(v) => Sym::Const(*v as f64),
            ExprKind::FloatLit(v) => Sym::Const(*v),
            ExprKind::Ident(n) => {
                if let Some(k) = self.visible_loop_vars.iter().rposition(|v| v == n) {
                    // Map visible-name -> its allocated slot.
                    Sym::LoopVar(self.visible_slots[k])
                } else if let Some(k) = self.temps.iter().position(|(t, _)| t == n) {
                    Sym::Tmp(k)
                } else if self.reductions.iter().any(|r| r == n) {
                    // Reduction accumulators may not feed other expressions.
                    return None;
                } else {
                    Sym::Scalar(self.scalar_slot(n))
                }
            }
            ExprKind::Binary(op, a, b) => {
                if matches!(op, BinOp::And | BinOp::Or) {
                    return None; // short-circuit semantics not vectorizable here
                }
                Sym::Bin(
                    *op,
                    Box::new(self.compile_expr(a)?),
                    Box::new(self.compile_expr(b)?),
                )
            }
            ExprKind::Unary(UnOp::Neg, a) => Sym::Neg(Box::new(self.compile_expr(a)?)),
            ExprKind::Cast(ty, a) => {
                let inner = self.compile_expr(a)?;
                match ty.base() {
                    Some(b) if b.is_float() => inner,
                    Some(_) => Sym::Trunc(Box::new(inner)),
                    None => return None,
                }
            }
            ExprKind::Ternary(c, t, f) => Sym::Ternary(
                Box::new(self.compile_expr(c)?),
                Box::new(self.compile_expr(t)?),
                Box::new(self.compile_expr(f)?),
            ),
            ExprKind::Call(name, args) => {
                if let Some(f) = builtins::math1(name) {
                    if args.len() != 1 {
                        return None;
                    }
                    Sym::Call1(f, Box::new(self.compile_expr(&args[0])?))
                } else if let Some(f) = builtins::math2(name) {
                    if args.len() != 2 {
                        return None;
                    }
                    Sym::Call2(
                        f,
                        Box::new(self.compile_expr(&args[0])?),
                        Box::new(self.compile_expr(&args[1])?),
                    )
                } else {
                    return None; // user calls can't run on the device
                }
            }
            ExprKind::Index(..) => {
                let (base, idx) = super::eval::collect_index_chain(e).ok()?;
                let name = match &base.kind {
                    ExprKind::Ident(n) => n.clone(),
                    _ => return None,
                };
                if self.visible_loop_vars.iter().any(|v| *v == name) {
                    return None;
                }
                let slot = self.arr_slot(&name);
                let mut indices = Vec::with_capacity(idx.len());
                for i in idx {
                    indices.push(self.compile_expr(i)?);
                }
                Sym::Read(slot, indices)
            }
            _ => return None,
        })
    }

    fn compile_stmt(&mut self, s: &Stmt, out: &mut Vec<BulkStmt>) -> Option<()> {
        match &s.kind {
            StmtKind::Block(stmts) => {
                for st in stmts {
                    self.compile_stmt(st, out)?;
                }
                Some(())
            }
            StmtKind::Empty => Some(()),
            // Nested loop (perfect or imperfect): compile recursively with
            // a fresh loop-variable slot.
            StmtKind::For { .. } => {
                let (var, spec, body) = self.compile_for(s)?;
                self.visible_loop_vars.push(var);
                self.visible_slots.push(spec.var);
                let mut inner = Vec::new();
                let ok = self.compile_stmt(body, &mut inner);
                self.visible_loop_vars.pop();
                self.visible_slots.pop();
                ok?;
                if inner.is_empty() {
                    return None;
                }
                out.push(BulkStmt::Loop { spec, body: inner });
                Some(())
            }
            StmtKind::Expr(e) => match &e.kind {
                ExprKind::Assign(op, lhs, rhs) => {
                    match &lhs.kind {
                        ExprKind::Index(..) => {
                            let (base, idx) = super::eval::collect_index_chain(lhs).ok()?;
                            let name = match &base.kind {
                                ExprKind::Ident(n) => n.clone(),
                                _ => return None,
                            };
                            let slot = self.arr_slot(&name);
                            let mut indices = Vec::with_capacity(idx.len());
                            for i in idx {
                                indices.push(self.compile_expr(i)?);
                            }
                            let value = self.compile_expr(rhs)?;
                            out.push(BulkStmt::Store { arr: slot, indices, op: *op, value });
                            Some(())
                        }
                        ExprKind::Ident(name) => {
                            // Scalar write: either a reduction (acc += v /
                            // acc = acc + v) or a per-iteration temporary
                            // (t = expr not involving t from a previous
                            // iteration) — NR bodies use both.
                            let reduction: Option<(AssignOp, &Expr)> = match op {
                                AssignOp::Add | AssignOp::Sub => Some((*op, rhs.as_ref())),
                                AssignOp::Set => match &rhs.kind {
                                    ExprKind::Binary(BinOp::Add, a, b) => {
                                        if matches!(&a.kind, ExprKind::Ident(n) if n == name) {
                                            Some((AssignOp::Add, b.as_ref()))
                                        } else if matches!(&b.kind, ExprKind::Ident(n) if n == name)
                                        {
                                            Some((AssignOp::Add, a.as_ref()))
                                        } else {
                                            None
                                        }
                                    }
                                    _ => None,
                                },
                                _ => None,
                            };
                            let is_known_temp =
                                self.temps.iter().any(|(t, _)| t == name);
                            if let (Some((rop, value_expr)), false) = (reduction, is_known_temp) {
                                if !self.reductions.iter().any(|r| r == name) {
                                    // Accumulator must not already be a read scalar.
                                    if self.scalars.iter().any(|r| r == name) {
                                        return None;
                                    }
                                    self.reductions.push(name.clone());
                                }
                                let acc =
                                    self.reductions.iter().position(|r| r == name).unwrap();
                                let value = self.compile_expr(value_expr)?;
                                out.push(BulkStmt::Reduce { acc, op: rop, value });
                                return Some(());
                            }
                            // Temporary definition / redefinition.
                            if self.reductions.iter().any(|r| r == name) {
                                return None; // mixing reduction + temp roles
                            }
                            if !is_known_temp && self.scalars.iter().any(|r| r == name) {
                                // Read earlier in the body before this write:
                                // cross-iteration value flow — not offloadable.
                                return None;
                            }
                            if *op == AssignOp::Set {
                                let value = self.compile_expr(rhs)?;
                                let slot = match self.temps.iter().position(|(t, _)| t == name) {
                                    Some(k) => {
                                        self.temps[k].1 = value.clone();
                                        k
                                    }
                                    None => {
                                        self.temps.push((name.clone(), value.clone()));
                                        self.temps.len() - 1
                                    }
                                };
                                out.push(BulkStmt::LetTmp { slot, value });
                                return Some(());
                            }
                            // Compound op on an existing temp: t op= v.
                            if is_known_temp {
                                let slot =
                                    self.temps.iter().position(|(t, _)| t == name).unwrap();
                                let bin = match op {
                                    AssignOp::Add => BinOp::Add,
                                    AssignOp::Sub => BinOp::Sub,
                                    AssignOp::Mul => BinOp::Mul,
                                    AssignOp::Div => BinOp::Div,
                                    _ => return None,
                                };
                                let rhs_sym = self.compile_expr(rhs)?;
                                let value = Sym::Bin(
                                    bin,
                                    Box::new(Sym::Tmp(slot)),
                                    Box::new(rhs_sym),
                                );
                                self.temps[slot].1 = value.clone();
                                out.push(BulkStmt::LetTmp { slot, value });
                                return Some(());
                            }
                            None
                        }
                        _ => None,
                    }
                }
                _ => None,
            },
            _ => None,
        }
    }

    /// Parse one `for` header into (loop var name, LoopSpec with a freshly
    /// allocated slot) + body reference.
    fn compile_for<'a>(&mut self, s: &'a Stmt) -> Option<(String, LoopSpec, &'a Stmt)> {
        let StmtKind::For { init, cond, step, body } = &s.kind else {
            return None;
        };
        // Loop variable + lower bound.
        let (var, lo) = match init.as_deref() {
            Some(Stmt { kind: StmtKind::Decl(ds), .. }) if ds.len() == 1 => {
                let d = &ds[0];
                if !d.dims.is_empty() {
                    return None;
                }
                (d.name.clone(), self.compile_expr(d.init.as_ref()?)?)
            }
            Some(Stmt { kind: StmtKind::Expr(e), .. }) => match &e.kind {
                ExprKind::Assign(AssignOp::Set, l, r) => match &l.kind {
                    ExprKind::Ident(n) => (n.clone(), self.compile_expr(r)?),
                    _ => return None,
                },
                _ => return None,
            },
            _ => return None,
        };
        // Upper bound: `var < e` or `var <= e`.
        let (hi, inclusive) = match cond.as_ref()? {
            Expr { kind: ExprKind::Binary(op @ (BinOp::Lt | BinOp::Le), a, b), .. } => {
                match &a.kind {
                    ExprKind::Ident(n) if *n == var => {
                        (self.compile_expr(b)?, matches!(op, BinOp::Le))
                    }
                    _ => return None,
                }
            }
            _ => return None,
        };
        // Step: `var++`, `++var`, `var += c`, `var = var + c`.
        let step_by = match step.as_ref()? {
            Expr { kind: ExprKind::PostIncDec(t, true), .. } => match &t.kind {
                ExprKind::Ident(n) if *n == var => 1,
                _ => return None,
            },
            Expr { kind: ExprKind::Unary(UnOp::PreInc, t), .. } => match &t.kind {
                ExprKind::Ident(n) if *n == var => 1,
                _ => return None,
            },
            Expr { kind: ExprKind::Assign(AssignOp::Add, l, r), .. } => {
                match (&l.kind, &r.kind) {
                    (ExprKind::Ident(n), ExprKind::IntLit(c)) if *n == var && *c > 0 => *c,
                    _ => return None,
                }
            }
            Expr { kind: ExprKind::Assign(AssignOp::Set, l, r), .. } => {
                match (&l.kind, &r.kind) {
                    (ExprKind::Ident(n), ExprKind::Binary(BinOp::Add, a, b)) if *n == var => {
                        match (&a.kind, &b.kind) {
                            (ExprKind::Ident(m), ExprKind::IntLit(c)) if *m == var && *c > 0 => *c,
                            _ => return None,
                        }
                    }
                    _ => return None,
                }
            }
            _ => return None,
        };
        let slot = self.n_vars;
        self.n_vars += 1;
        Some((var, LoopSpec { var: slot, lo, hi, inclusive, step: step_by }, body))
    }
}

/// Try to compile a `for` statement (possibly a nest) for bulk execution.
/// Returns `None` when the loop shape is not offloadable — callers fall back
/// to interpretation (and the analysis pass will not have produced a gene
/// for such loops in the first place).
pub fn compile_loop(s: &Stmt) -> Option<CompiledLoop> {
    let mut c = Compiler {
        visible_loop_vars: Vec::new(),
        visible_slots: Vec::new(),
        n_vars: 0,
        arrays: Vec::new(),
        scalars: Vec::new(),
        reductions: Vec::new(),
        temps: Vec::new(),
    };
    let mut body_out = Vec::new();
    c.compile_stmt(s, &mut body_out)?;
    if body_out.is_empty() {
        return None;
    }

    // Dependence check: collect every store in the (possibly nested)
    // body; every read of a written array must be independence-provable
    // or at uniform symbolic distance (PGI-style assumption; the
    // verification environment re-checks outputs after offload anyway).
    let temp_defs: Vec<Sym> = c.temps.iter().map(|(_, d)| d.clone()).collect();
    let n_loops = c.n_vars;
    let mut writes: Vec<(usize, Vec<Sym>)> = Vec::new();
    collect_stores(&body_out, &mut writes);
    for (arr, widx) in &writes {
        if body_conflicts(&body_out, *arr, widx, n_loops, &temp_defs) {
            return None;
        }
    }
    Some(CompiledLoop {
        n_vars: c.n_vars,
        body: body_out,
        arrays: c.arrays,
        scalars: c.scalars,
        reductions: c.reductions,
        temps: c.temps.into_iter().map(|(n, _)| n).collect(),
    })
}

fn collect_stores(body: &[BulkStmt], out: &mut Vec<(usize, Vec<Sym>)>) {
    for st in body {
        match st {
            BulkStmt::Store { arr, indices, .. } => out.push((*arr, indices.clone())),
            BulkStmt::Loop { body, .. } => collect_stores(body, out),
            _ => {}
        }
    }
}

fn body_conflicts(
    body: &[BulkStmt],
    arr: usize,
    widx: &[Sym],
    n_loops: usize,
    temp_defs: &[Sym],
) -> bool {
    for st in body {
        match st {
            BulkStmt::Store { value, indices, arr: a2, .. } => {
                // Another write to the same array at a different index is a
                // hazard unless provably distinct per iteration.
                if *a2 == arr
                    && indices != widx
                    && !indices_independent(widx, indices, n_loops, temp_defs)
                {
                    return true;
                }
                if reads_conflict(value, arr, widx, n_loops, temp_defs) {
                    return true;
                }
                for i in indices {
                    if reads_conflict(i, arr, widx, n_loops, temp_defs) {
                        return true;
                    }
                }
            }
            BulkStmt::Reduce { value, .. } | BulkStmt::LetTmp { value, .. } => {
                if reads_conflict(value, arr, widx, n_loops, temp_defs) {
                    return true;
                }
            }
            BulkStmt::Loop { spec, body } => {
                if reads_conflict(&spec.lo, arr, widx, n_loops, temp_defs)
                    || reads_conflict(&spec.hi, arr, widx, n_loops, temp_defs)
                {
                    return true;
                }
                if body_conflicts(body, arr, widx, n_loops, temp_defs) {
                    return true;
                }
            }
        }
    }
    false
}

/// Affine decomposition of an index expression over the nest's loop
/// variables: `sum(coeffs[k] * loopvar_k) + konst + sum(symbolic terms)`.
#[derive(Debug, Clone, PartialEq)]
struct Affine {
    coeffs: Vec<f64>,
    konst: f64,
    /// Loop-invariant symbolic terms, normalized as (debug-string, coeff),
    /// sorted for order-insensitive comparison.
    terms: Vec<(String, f64)>,
}

impl Affine {
    fn konst_only(n: usize, c: f64) -> Self {
        Affine { coeffs: vec![0.0; n], konst: c, terms: vec![] }
    }

    fn is_const(&self) -> bool {
        self.coeffs.iter().all(|&c| c == 0.0) && self.terms.is_empty()
    }

    fn add(mut self, other: &Affine, sign: f64) -> Affine {
        for (a, b) in self.coeffs.iter_mut().zip(&other.coeffs) {
            *a += sign * b;
        }
        self.konst += sign * other.konst;
        for (t, c) in &other.terms {
            match self.terms.iter_mut().find(|(s, _)| s == t) {
                Some((_, acc)) => *acc += sign * c,
                None => self.terms.push((t.clone(), sign * c)),
            }
        }
        self.terms.retain(|(_, c)| *c != 0.0);
        self.terms.sort_by(|a, b| a.0.cmp(&b.0));
        self
    }

    fn scale(mut self, f: f64) -> Affine {
        for c in self.coeffs.iter_mut() {
            *c *= f;
        }
        self.konst *= f;
        for (_, c) in self.terms.iter_mut() {
            *c *= f;
        }
        self.terms.retain(|(_, c)| *c != 0.0);
        self
    }
}

/// Max temp-substitution depth for the dependence analysis. Temps may be
/// self-referential (`sum += ...` compiles to `sum = sum + ...`), which is
/// fine to *execute* (in-order per iteration) but must not be chased
/// forever during analysis — beyond the cap we answer conservatively.
const MAX_SUBST_DEPTH: usize = 64;

/// True if `sym` depends on any loop variable (after temp substitution).
fn loop_dependent(sym: &Sym, temp_defs: &[Sym]) -> bool {
    loop_dependent_d(sym, temp_defs, 0)
}

fn loop_dependent_d(sym: &Sym, temp_defs: &[Sym], depth: usize) -> bool {
    if depth > MAX_SUBST_DEPTH {
        return true; // conservative: treat as loop-dependent
    }
    match sym {
        Sym::LoopVar(_) => true,
        Sym::Tmp(k) => loop_dependent_d(&temp_defs[*k], temp_defs, depth + 1),
        Sym::Const(_) | Sym::Scalar(_) => false,
        Sym::Bin(_, a, b) | Sym::Call2(_, a, b) => {
            loop_dependent_d(a, temp_defs, depth + 1) || loop_dependent_d(b, temp_defs, depth + 1)
        }
        Sym::Neg(a) | Sym::Trunc(a) | Sym::Call1(_, a) => {
            loop_dependent_d(a, temp_defs, depth + 1)
        }
        Sym::Ternary(c, t, f) => {
            loop_dependent_d(c, temp_defs, depth + 1)
                || loop_dependent_d(t, temp_defs, depth + 1)
                || loop_dependent_d(f, temp_defs, depth + 1)
        }
        Sym::Read(_, idx) => idx.iter().any(|i| loop_dependent_d(i, temp_defs, depth + 1)),
    }
}

/// Decompose an index expression into affine form (temps substituted).
/// `None` = not affine in the loop variables.
fn affine(sym: &Sym, n: usize, temp_defs: &[Sym]) -> Option<Affine> {
    affine_d(sym, n, temp_defs, 0)
}

fn affine_d(sym: &Sym, n: usize, temp_defs: &[Sym], depth: usize) -> Option<Affine> {
    if depth > MAX_SUBST_DEPTH {
        return None; // conservative: not analyzable
    }
    match sym {
        Sym::Const(c) => Some(Affine::konst_only(n, *c)),
        Sym::LoopVar(k) => {
            let mut a = Affine::konst_only(n, 0.0);
            a.coeffs[*k] = 1.0;
            Some(a)
        }
        Sym::Tmp(k) => affine_d(&temp_defs[*k], n, temp_defs, depth + 1),
        Sym::Scalar(_) => Some(Affine {
            coeffs: vec![0.0; n],
            konst: 0.0,
            terms: vec![(format!("{sym:?}"), 1.0)],
        }),
        Sym::Neg(a) => Some(affine_d(a, n, temp_defs, depth + 1)?.scale(-1.0)),
        Sym::Bin(BinOp::Add, a, b) => {
            let fa = affine_d(a, n, temp_defs, depth + 1)?;
            let fb = affine_d(b, n, temp_defs, depth + 1)?;
            Some(fa.add(&fb, 1.0))
        }
        Sym::Bin(BinOp::Sub, a, b) => {
            let fa = affine_d(a, n, temp_defs, depth + 1)?;
            let fb = affine_d(b, n, temp_defs, depth + 1)?;
            Some(fa.add(&fb, -1.0))
        }
        Sym::Bin(BinOp::Mul, a, b) => {
            let fa = affine_d(a, n, temp_defs, depth + 1)?;
            let fb = affine_d(b, n, temp_defs, depth + 1)?;
            if fa.is_const() {
                return Some(fb.scale(fa.konst));
            }
            if fb.is_const() {
                return Some(fa.scale(fb.konst));
            }
            // Product of non-constant parts: loop-invariant => opaque term;
            // loop-dependent => non-affine.
            if loop_dependent(sym, temp_defs) {
                None
            } else {
                Some(Affine {
                    coeffs: vec![0.0; n],
                    konst: 0.0,
                    terms: vec![(format!("{sym:?}"), 1.0)],
                })
            }
        }
        // Anything else: loop-invariant => opaque; loop-dependent => not
        // affine.
        other => {
            if loop_dependent(other, temp_defs) {
                None
            } else {
                Some(Affine {
                    coeffs: vec![0.0; n],
                    konst: 0.0,
                    terms: vec![(format!("{other:?}"), 1.0)],
                })
            }
        }
    }
}

/// Can iterations run concurrently given a write at `widx` and another
/// access at `ridx` of the same array?
///
/// * non-affine or mismatched loop-var coefficients → **conflict**,
/// * identical index expressions → same element each iteration → safe,
/// * equal symbolic parts but different constants → definite nonzero
///   loop-carried distance (prefix-sum shape) → **conflict**,
/// * differing loop-invariant symbolic parts (`a[i*n+j]` vs `a[k*n+j]`) →
///   assumed disjoint, the PGI-style assumption; the verification
///   environment re-validates outputs after offload.
fn indices_independent(
    widx: &[Sym],
    ridx: &[Sym],
    n_loops: usize,
    temp_defs: &[Sym],
) -> bool {
    if widx.len() != ridx.len() {
        return false;
    }
    let mut all_same = true;
    let mut symbolic_diff = false;
    for (w, r) in widx.iter().zip(ridx) {
        let (Some(aw), Some(ar)) = (affine(w, n_loops, temp_defs), affine(r, n_loops, temp_defs))
        else {
            return false;
        };
        if aw.coeffs != ar.coeffs {
            return false;
        }
        if aw.terms != ar.terms {
            symbolic_diff = true;
            all_same = false;
        } else if aw.konst != ar.konst {
            all_same = false;
            // constant distance in this dimension — only safe if another
            // dimension separates them symbolically.
        }
    }
    all_same || symbolic_diff
}

/// True if `e` reads `arr` at indices that conflict with a write at
/// `write_idx` (loop-carried dependence ⇒ not parallelizable).
fn reads_conflict(
    e: &Sym,
    arr: usize,
    write_idx: &[Sym],
    n_loops: usize,
    temp_defs: &[Sym],
) -> bool {
    reads_conflict_d(e, arr, write_idx, n_loops, temp_defs, 0)
}

fn reads_conflict_d(
    e: &Sym,
    arr: usize,
    write_idx: &[Sym],
    n_loops: usize,
    temp_defs: &[Sym],
    depth: usize,
) -> bool {
    if depth > MAX_SUBST_DEPTH {
        return true; // conservative: assume a conflict
    }
    match e {
        Sym::Read(a, idx) => {
            if *a == arr && !indices_independent(write_idx, idx, n_loops, temp_defs) {
                return true;
            }
            idx.iter()
                .any(|i| reads_conflict_d(i, arr, write_idx, n_loops, temp_defs, depth + 1))
        }
        Sym::Bin(_, a, b) | Sym::Call2(_, a, b) => {
            reads_conflict_d(a, arr, write_idx, n_loops, temp_defs, depth + 1)
                || reads_conflict_d(b, arr, write_idx, n_loops, temp_defs, depth + 1)
        }
        Sym::Neg(a) | Sym::Trunc(a) | Sym::Call1(_, a) => {
            reads_conflict_d(a, arr, write_idx, n_loops, temp_defs, depth + 1)
        }
        Sym::Ternary(c, t, f) => {
            reads_conflict_d(c, arr, write_idx, n_loops, temp_defs, depth + 1)
                || reads_conflict_d(t, arr, write_idx, n_loops, temp_defs, depth + 1)
                || reads_conflict_d(f, arr, write_idx, n_loops, temp_defs, depth + 1)
        }
        // Temps are substituted at definition sites; a Tmp reference here
        // reads the already-checked definition (self-referential defs are
        // cut off by the depth cap).
        Sym::Tmp(k) => {
            reads_conflict_d(&temp_defs[*k], arr, write_idx, n_loops, temp_defs, depth + 1)
        }
        _ => false,
    }
}

// ===================================================================
// Execution
// ===================================================================

struct Device {
    /// Scratch copies of the bound arrays ("device memory").
    bufs: Vec<Vec<f64>>,
    dims: Vec<Vec<usize>>,
    scalars: Vec<f64>,
    accs: Vec<f64>,
    temps: Vec<f64>,
    loop_vals: Vec<i64>,
}

impl Device {
    fn eval(&mut self, e: &Sym) -> Result<f64> {
        Ok(match e {
            Sym::Const(v) => *v,
            Sym::LoopVar(k) => self.loop_vals[*k] as f64,
            Sym::Scalar(k) => self.scalars[*k],
            Sym::Tmp(k) => self.temps[*k],
            Sym::Neg(a) => -self.eval(a)?,
            Sym::Trunc(a) => self.eval(a)?.trunc(),
            Sym::Call1(f, a) => f(self.eval(a)?),
            Sym::Call2(f, a, b) => {
                let x = self.eval(a)?;
                let y = self.eval(b)?;
                f(x, y)
            }
            Sym::Ternary(c, t, f) => {
                if self.eval(c)? != 0.0 {
                    self.eval(t)?
                } else {
                    self.eval(f)?
                }
            }
            Sym::Bin(op, a, b) => {
                let x = self.eval(a)?;
                let y = self.eval(b)?;
                match op {
                    BinOp::Add => x + y,
                    BinOp::Sub => x - y,
                    BinOp::Mul => x * y,
                    BinOp::Div => x / y,
                    BinOp::Rem => x % y,
                    BinOp::Eq => (x == y) as i64 as f64,
                    BinOp::Ne => (x != y) as i64 as f64,
                    BinOp::Lt => (x < y) as i64 as f64,
                    BinOp::Gt => (x > y) as i64 as f64,
                    BinOp::Le => (x <= y) as i64 as f64,
                    BinOp::Ge => (x >= y) as i64 as f64,
                    BinOp::BitAnd => ((x as i64) & (y as i64)) as f64,
                    BinOp::BitOr => ((x as i64) | (y as i64)) as f64,
                    BinOp::BitXor => ((x as i64) ^ (y as i64)) as f64,
                    BinOp::Shl => ((x as i64) << (y as i64)) as f64,
                    BinOp::Shr => ((x as i64) >> (y as i64)) as f64,
                    BinOp::And | BinOp::Or => bail!("logical op on device"),
                }
            }
            Sym::Read(slot, idx) => {
                let flat = self.flat_index(*slot, idx)?;
                self.bufs[*slot][flat]
            }
        })
    }

    fn flat_index(&mut self, slot: usize, idx: &[Sym]) -> Result<usize> {
        let ndim = self.dims[slot].len();
        if idx.len() != ndim {
            bail!("array indexed with {} of {} dims on device", idx.len(), ndim);
        }
        let mut flat = 0usize;
        for (k, ix) in idx.iter().enumerate() {
            let v = self.eval(ix)? as i64;
            let dims = &self.dims[slot];
            if v < 0 || (v as usize) >= dims[k] {
                bail!("device index {v} out of bounds for dim {}", dims[k]);
            }
            flat = flat * self.dims[slot][k] + v as usize;
        }
        Ok(flat)
    }
}

/// Execute a compiled nest. Returns Ok(false) if launch-time binding fails
/// (caller falls back to interpretation).
pub fn run_bulk(interp: &mut Interp, c: &CompiledLoop) -> Result<bool> {
    // --- bind ---------------------------------------------------------
    let mut slices = Vec::with_capacity(c.arrays.len());
    for name in &c.arrays {
        match interp_lookup(interp, name) {
            Some(Value::Arr(s)) => slices.push(s),
            _ => return Ok(false),
        }
    }
    let mut scalars = Vec::with_capacity(c.scalars.len());
    for name in &c.scalars {
        match interp_lookup(interp, name) {
            Some(Value::Int(v)) => scalars.push(v as f64),
            Some(Value::Float(v)) => scalars.push(v),
            _ => return Ok(false),
        }
    }
    let mut accs = Vec::with_capacity(c.reductions.len());
    for name in &c.reductions {
        match interp_lookup(interp, name) {
            Some(Value::Int(v)) => accs.push(v as f64),
            Some(Value::Float(v)) => accs.push(v),
            _ => return Ok(false),
        }
    }

    // --- launch + H2D transfer ----------------------------------------
    spin_wait(LAUNCH_OVERHEAD);
    let mut dev = Device {
        bufs: slices.iter().map(|s| s.to_vec()).collect(),
        dims: slices.iter().map(|s| s.dims.clone()).collect(),
        scalars,
        accs,
        temps: vec![0.0; c.temps.len()],
        loop_vals: vec![0; c.n_vars],
    };
    match interp.data_plane.clone() {
        None => {
            let bytes: u64 = dev.bufs.iter().map(|b| (b.len() * 8) as u64).sum();
            interp.stats.transfer_bytes += bytes * 2; // in + out
        }
        Some(plane) => {
            // Residency-aware accounting: each staged buffer pays only if
            // its value is not already resident on the device; the D2H
            // half is classified after execution, below.
            for buf in &dev.bufs {
                let h = crate::runtime::BufferHandle::of_f64(buf);
                if plane.stage_in(&h) {
                    interp.stats.elided_transfer_bytes += h.bytes;
                } else {
                    interp.stats.transfer_bytes += h.bytes;
                }
            }
        }
    }

    // --- execute --------------------------------------------------------
    exec_body(&mut dev, &c.body)?;

    if let Some(plane) = interp.data_plane.clone() {
        for buf in &dev.bufs {
            let h = crate::runtime::BufferHandle::of_f64(buf);
            if plane.read_back(&h) {
                interp.stats.elided_transfer_bytes += h.bytes;
            } else {
                interp.stats.transfer_bytes += h.bytes;
            }
        }
    }

    // --- D2H transfer + write-back -------------------------------------
    for (slice, buf) in slices.iter().zip(&dev.bufs) {
        slice.copy_from(buf)?;
    }
    for (name, v) in c.reductions.iter().zip(&dev.accs) {
        interp_store_scalar(interp, name, *v)?;
    }
    Ok(true)
}

fn exec_body(dev: &mut Device, body: &[BulkStmt]) -> Result<()> {
    for st in body {
        match st {
            BulkStmt::Store { arr, indices, op, value } => {
                let v = dev.eval(value)?;
                let flat = dev.flat_index(*arr, indices)?;
                let slot = &mut dev.bufs[*arr][flat];
                *slot = apply_assign(*op, *slot, v)?;
            }
            BulkStmt::Reduce { acc, op, value } => {
                let v = dev.eval(value)?;
                let slot = &mut dev.accs[*acc];
                *slot = apply_assign(*op, *slot, v)?;
            }
            BulkStmt::LetTmp { slot, value } => {
                let v = dev.eval(value)?;
                dev.temps[*slot] = v;
            }
            BulkStmt::Loop { spec, body } => {
                let lo = dev.eval(&spec.lo)? as i64;
                let hi = dev.eval(&spec.hi)? as i64;
                let end = if spec.inclusive { hi + 1 } else { hi };
                let mut i = lo;
                while i < end {
                    dev.loop_vals[spec.var] = i;
                    exec_body(dev, body)?;
                    i += spec.step;
                }
            }
        }
    }
    Ok(())
}

fn apply_assign(op: AssignOp, old: f64, v: f64) -> Result<f64> {
    Ok(match op {
        AssignOp::Set => v,
        AssignOp::Add => old + v,
        AssignOp::Sub => old - v,
        AssignOp::Mul => old * v,
        AssignOp::Div => old / v,
        AssignOp::Rem => old % v,
        AssignOp::Shl => ((old as i64) << (v as i64)) as f64,
        AssignOp::Shr => ((old as i64) >> (v as i64)) as f64,
    })
}

fn spin_wait(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

// Small helpers reaching into the interpreter's scopes without exposing its
// internals publicly.
fn interp_lookup(interp: &Interp, name: &str) -> Option<Value> {
    interp.lookup_value(name)
}

fn interp_store_scalar(interp: &mut Interp, name: &str, v: f64) -> Result<()> {
    interp.store_scalar(name, v)
}
