//! Runtime values for the mini-C interpreter.
//!
//! Arrays are flat `f64` buffers behind `Rc<RefCell<..>>` with a dims
//! vector; a [`Slice`] is a (buffer, offset, dims) view so `a[i]` of a 2-D
//! array yields a row view and arrays pass to callees by reference, exactly
//! like C decay. Integer arrays share the `f64` buffer with store-time
//! truncation (documented divergence: 53-bit exact integer range, ample for
//! index/loop math in numeric kernels).

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::rc::Rc;

use anyhow::{bail, Result};

/// Backing storage of an array object.
#[derive(Debug)]
pub struct ArrayData {
    /// Flat element storage (integral arrays store truncated values).
    pub data: Vec<f64>,
    /// True when the declared element type was integral.
    pub is_int: bool,
}

/// Shared handle to array storage.
pub type ArrRef = Rc<RefCell<ArrayData>>;

/// A view into an array: `(buffer, element offset, remaining dims)`.
#[derive(Clone)]
pub struct Slice {
    /// Backing buffer.
    pub arr: ArrRef,
    /// Element offset of this view into the buffer.
    pub offset: usize,
    /// Remaining dimensions of the view (outermost first).
    pub dims: Vec<usize>,
}

impl Slice {
    /// New owning view over fresh storage.
    pub fn new(data: Vec<f64>, dims: Vec<usize>, is_int: bool) -> Self {
        Slice {
            arr: Rc::new(RefCell::new(ArrayData { data, is_int })),
            offset: 0,
            dims,
        }
    }

    /// Zero-filled array of the given shape.
    pub fn zeros(dims: &[usize], is_int: bool) -> Self {
        let len: usize = dims.iter().product();
        Slice::new(vec![0.0; len], dims.to_vec(), is_int)
    }

    /// Total elements in this view.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    /// True when the view covers no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read element at flat position `i` within the view.
    pub fn get(&self, i: usize) -> Result<f64> {
        let idx = self.offset + i;
        let b = self.arr.borrow();
        match b.data.get(idx) {
            Some(v) => Ok(*v),
            None => bail!("array index {i} out of bounds (len {})", b.data.len()),
        }
    }

    /// Write element at flat position `i` within the view.
    pub fn set(&self, i: usize, v: f64) -> Result<()> {
        let idx = self.offset + i;
        let mut b = self.arr.borrow_mut();
        let is_int = b.is_int;
        match b.data.get_mut(idx) {
            Some(slot) => {
                *slot = if is_int { v.trunc() } else { v };
                Ok(())
            }
            None => bail!("array index {i} out of bounds (len {})", b.data.len()),
        }
    }

    /// Sub-view after applying one index on the leading dimension.
    pub fn index(&self, i: i64) -> Result<SliceOrScalar> {
        if self.dims.is_empty() {
            bail!("cannot index a scalar view");
        }
        let d0 = self.dims[0];
        if i < 0 || (i as usize) >= d0 {
            bail!("index {i} out of bounds for dimension of size {d0}");
        }
        let stride: usize = self.dims[1..].iter().product();
        let offset = self.offset + (i as usize) * stride.max(1);
        if self.dims.len() == 1 {
            let b = self.arr.borrow();
            Ok(SliceOrScalar::Scalar(b.data[offset], b.is_int))
        } else {
            Ok(SliceOrScalar::Slice(Slice {
                arr: self.arr.clone(),
                offset,
                dims: self.dims[1..].to_vec(),
            }))
        }
    }

    /// Copy the viewed elements out.
    pub fn to_vec(&self) -> Vec<f64> {
        let b = self.arr.borrow();
        b.data[self.offset..self.offset + self.len()].to_vec()
    }

    /// Copy the viewed elements out as f32 (PJRT boundary).
    pub fn to_vec_f32(&self) -> Vec<f32> {
        let b = self.arr.borrow();
        b.data[self.offset..self.offset + self.len()]
            .iter()
            .map(|&v| v as f32)
            .collect()
    }

    /// Overwrite the viewed elements from f32 data (PJRT boundary).
    pub fn copy_from_f32(&self, src: &[f32]) -> Result<()> {
        let n = self.len();
        if src.len() != n {
            bail!("copy_from_f32 length mismatch: view {n}, src {}", src.len());
        }
        let mut b = self.arr.borrow_mut();
        for (dst, s) in b.data[self.offset..self.offset + n].iter_mut().zip(src) {
            *dst = *s as f64;
        }
        Ok(())
    }

    /// Overwrite the viewed elements from f64 data.
    pub fn copy_from(&self, src: &[f64]) -> Result<()> {
        let n = self.len();
        if src.len() != n {
            bail!("copy_from length mismatch: view {n}, src {}", src.len());
        }
        let mut b = self.arr.borrow_mut();
        b.data[self.offset..self.offset + n].copy_from_slice(src);
        Ok(())
    }
}

impl fmt::Debug for Slice {
    // Debug intentionally avoids dumping potentially huge buffers.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Slice(offset={}, dims={:?}, len={})",
            self.offset,
            self.dims,
            self.len()
        )
    }
}

/// Result of indexing a slice: another view, or a scalar read.
pub enum SliceOrScalar {
    /// A sub-array view (more dimensions remain).
    Slice(Slice),
    /// A scalar element (no dimensions remain); the flag marks integral storage.
    Scalar(f64, bool /* is_int */),
}

/// Struct instance (reference semantics; see module doc).
#[derive(Debug)]
pub struct StructData {
    /// Struct type name.
    pub name: String,
    /// Field values by name.
    pub fields: HashMap<String, Value>,
}

/// Shared handle to a struct instance.
pub type StructRef = Rc<RefCell<StructData>>;

/// A runtime value.
#[derive(Clone, Debug)]
pub enum Value {
    /// Integer scalar.
    Int(i64),
    /// Floating scalar.
    Float(f64),
    /// Array view.
    Arr(Slice),
    /// Struct instance (reference semantics).
    Struct(StructRef),
    /// String literal value.
    Str(Rc<String>),
    /// Absence of a value (`void` returns).
    Void,
}

impl Value {
    /// Numeric coercion (int or float).
    pub fn as_num(&self) -> Result<f64> {
        match self {
            Value::Int(v) => Ok(*v as f64),
            Value::Float(v) => Ok(*v),
            other => bail!("expected numeric value, got {}", other.type_name()),
        }
    }

    /// Integer coercion (floats truncate).
    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(v) => Ok(*v),
            Value::Float(v) => Ok(*v as i64),
            other => bail!("expected integer value, got {}", other.type_name()),
        }
    }

    /// The array view, or an error for non-arrays.
    pub fn as_arr(&self) -> Result<&Slice> {
        match self {
            Value::Arr(s) => Ok(s),
            other => bail!("expected array value, got {}", other.type_name()),
        }
    }

    /// C truthiness of a numeric value.
    pub fn truthy(&self) -> Result<bool> {
        Ok(self.as_num()? != 0.0)
    }

    /// Human-readable type name for diagnostics.
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Int(_) => "int",
            Value::Float(_) => "float",
            Value::Arr(_) => "array",
            Value::Struct(_) => "struct",
            Value::Str(_) => "string",
            Value::Void => "void",
        }
    }

    /// Coerce `v` to the kind of `self` (assignment into a typed slot).
    pub fn coerce_like(&self, v: Value) -> Result<Value> {
        Ok(match self {
            Value::Int(_) => Value::Int(v.as_int()?),
            Value::Float(_) => Value::Float(v.as_num()?),
            _ => v,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_roundtrip() {
        let s = Slice::zeros(&[4, 3], false);
        s.set(5, 2.5).unwrap();
        assert_eq!(s.get(5).unwrap(), 2.5);
        assert_eq!(s.len(), 12);
    }

    #[test]
    fn int_arrays_truncate() {
        let s = Slice::zeros(&[2], true);
        s.set(0, 2.9).unwrap();
        assert_eq!(s.get(0).unwrap(), 2.0);
    }

    #[test]
    fn row_view_shares_storage() {
        let s = Slice::zeros(&[3, 4], false);
        match s.index(1).unwrap() {
            SliceOrScalar::Slice(row) => {
                row.set(2, 7.0).unwrap();
            }
            _ => panic!("expected slice"),
        }
        assert_eq!(s.get(1 * 4 + 2).unwrap(), 7.0);
    }

    #[test]
    fn last_dim_index_yields_scalar() {
        let s = Slice::new(vec![1.0, 2.0, 3.0], vec![3], false);
        match s.index(2).unwrap() {
            SliceOrScalar::Scalar(v, _) => assert_eq!(v, 3.0),
            _ => panic!("expected scalar"),
        }
    }

    #[test]
    fn bounds_checked() {
        let s = Slice::zeros(&[2], false);
        assert!(s.get(5).is_err());
        assert!(s.index(2).is_err());
        assert!(s.index(-1).is_err());
    }

    #[test]
    fn f32_boundary_roundtrip() {
        let s = Slice::zeros(&[3], false);
        s.copy_from_f32(&[1.0, 2.0, 3.0]).unwrap();
        assert_eq!(s.to_vec_f32(), vec![1.0f32, 2.0, 3.0]);
        assert!(s.copy_from_f32(&[1.0]).is_err());
    }

    #[test]
    fn coercion_follows_slot_type() {
        let slot = Value::Int(0);
        assert!(matches!(slot.coerce_like(Value::Float(2.7)).unwrap(), Value::Int(2)));
        let slot = Value::Float(0.0);
        assert!(matches!(slot.coerce_like(Value::Int(3)).unwrap(), Value::Float(v) if v == 3.0));
    }
}
