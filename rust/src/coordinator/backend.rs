//! Step-3b backend arbitration: CPU vs GPU vs FPGA, per offloaded block.
//!
//! The paper's method covers both accelerators, but GPU and FPGA sit at
//! opposite ends of the verification-cost spectrum: a GPU pattern is
//! *measured* directly (minutes on the verification machine), while an
//! FPGA pattern hides an hours-long HLS compile behind every candidate.
//! The companion FPGA papers (arXiv:2004.08548, arXiv:2002.09541)
//! therefore narrow candidates *before* compiling — by arithmetic
//! intensity and by a fast resource pre-check — and only then pay for the
//! compile. This module reproduces that flow on top of the Step-3 search
//! results:
//!
//! 1. **IP-core lookup** — a block is FPGA-eligible only if the pattern DB
//!    registers an IP core for its artifact (paper §4.1: IP cores are
//!    existing know-how, OpenCL text held in the DB);
//! 2. **intensity narrowing** — the DB's CPU implementation of the block
//!    is statically scored (flops/byte × trip estimate at the observed
//!    size); low-intensity blocks never reach the toolchain;
//! 3. **resource pre-check** — the static [`fpga::ResourceEstimate`] is
//!    checked against the target [`fpga::Device`] (minutes of simulated
//!    time, "errors early when the resource amount is over");
//! 4. **estimate vs measurement** — the survivors' execution time is
//!    modeled from the device (`fmax`, pipeline passes, PCIe) and compared
//!    against the **measured** PJRT device seconds of the same block;
//! 5. **commit** — a block that picks FPGA charges the full simulated HLS
//!    compile to the [`fpga::VirtualClock`].
//!
//! The decision table lives in DESIGN.md ("Backend arbitration"). The
//! outcome is part of the [`super::OffloadReport`] (serialized by
//! [`super::report_json`], fingerprinted by the service's decision cache).

use anyhow::{bail, Result};

use crate::analysis;
use crate::fpga::{self, HlsCompiler, KernelSpec, ResourceEstimate};
use crate::parser::{self, StmtKind};
use crate::patterndb::{PassModel, PatternDb};
use crate::telemetry::TraceEvent;
use crate::transform::{glue, PlannedReplacement};

use super::power::{self, PowerOutcome, PowerPolicy};
use super::verify::SearchOutcome;

/// Where a block (or a whole winning pattern) runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Stay on the CPU (no accelerator wins, or none is usable).
    Cpu,
    /// PJRT artifact — the paper's CUDA-library path.
    Gpu,
    /// DB-registered IP core through the (simulated) HLS chain.
    Fpga,
}

impl Backend {
    /// Canonical lowercase name (CLI and report JSON).
    pub fn as_str(self) -> &'static str {
        match self {
            Backend::Cpu => "cpu",
            Backend::Gpu => "gpu",
            Backend::Fpga => "fpga",
        }
    }

    /// Inverse of [`Backend::as_str`].
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "cpu" => Backend::Cpu,
            "gpu" => Backend::Gpu,
            "fpga" => Backend::Fpga,
            other => bail!("unknown backend {other:?} (cpu|gpu|fpga)"),
        })
    }
}

/// Which backends arbitration may choose (CLI `--target`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendPolicy {
    /// GPU only: skip the FPGA path entirely (the paper's evaluated
    /// configuration).
    Gpu,
    /// FPGA where possible: every block with a pre-check-passing IP core
    /// goes to the FPGA; a block whose core fails the pre-check is a hard
    /// error (fail fast, before any compile hours are charged).
    Fpga,
    /// Pick the fastest backend per block from estimate vs measurement.
    #[default]
    Auto,
}

impl BackendPolicy {
    /// Canonical lowercase name (CLI and cache fingerprint).
    pub fn as_str(self) -> &'static str {
        match self {
            BackendPolicy::Gpu => "gpu",
            BackendPolicy::Fpga => "fpga",
            BackendPolicy::Auto => "auto",
        }
    }

    /// Inverse of [`BackendPolicy::as_str`].
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "gpu" => BackendPolicy::Gpu,
            "fpga" => BackendPolicy::Fpga,
            "auto" => BackendPolicy::Auto,
            other => bail!("unknown --target {other:?} (gpu|fpga|auto)"),
        })
    }
}

/// Owned, serializable copy of the FPGA device model an arbitration ran
/// against. ([`fpga::Device`] itself carries a `&'static str` name, which
/// cannot round-trip through the report codec.)
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceModel {
    /// Device name, e.g. "Intel Arria10 GX 1150".
    pub name: String,
    /// Adaptive logic modules available.
    pub alms: u64,
    /// DSP blocks available.
    pub dsps: u64,
    /// M20K BRAM blocks available.
    pub m20ks: u64,
    /// Achievable pipeline clock (Hz).
    pub fmax: f64,
}

impl From<&fpga::Device> for DeviceModel {
    fn from(d: &fpga::Device) -> Self {
        DeviceModel {
            name: d.name.to_string(),
            alms: d.alms,
            dsps: d.dsps,
            m20ks: d.m20ks,
            fmax: d.fmax,
        }
    }
}

/// FPGA evaluation of one block: what the narrowing, pre-check, and
/// timing model said. Present only when the DB registers an IP core for
/// the block's artifact (and the policy allows the FPGA path).
#[derive(Debug, Clone, PartialEq)]
pub struct FpgaEstimate {
    /// IP-core name from the DB (e.g. "2-D FFT IP core").
    pub core: String,
    /// Narrowing score: innermost flops/byte ratio of the DB's CPU
    /// implementation × estimated trips at the observed block size.
    pub intensity_score: f64,
    /// True when intensity narrowing cut this core before the pre-check
    /// (no simulated toolchain time was charged at all).
    pub narrowed_out: bool,
    /// Static resource estimate of the core.
    pub resources: ResourceEstimate,
    /// Scarcest-resource utilization on the target device.
    pub utilization: f64,
    /// Did the fast resource pre-check pass? (`false` for narrowed-out
    /// cores, which never ran it.)
    pub precheck_ok: bool,
    /// Modeled execution seconds per run (all dispatches of the block),
    /// comparable to the measured `traffic.device_secs`. Zero when the
    /// core was narrowed out or rejected.
    pub est_secs: f64,
    /// Simulated HLS hours charged for this core (pre-check minutes, plus
    /// the full compile when the block committed to FPGA).
    pub compile_hours: f64,
}

/// Arbitration result for one discovered block.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockArbitration {
    /// Site label of the block (matches the Step-3 pattern labels).
    pub label: String,
    /// Chosen backend for this block.
    pub backend: Backend,
    /// Measured whole-pattern seconds with only this block enabled
    /// (`None` when the GPU pattern lost or failed verification).
    pub gpu_secs: Option<f64>,
    /// Measured PJRT device seconds per run for this block.
    pub gpu_device_secs: f64,
    /// FPGA evaluation, when an IP core exists and the policy allows it.
    pub fpga: Option<FpgaEstimate>,
}

/// Outcome of the whole arbitration stage for one application.
#[derive(Debug, Clone, PartialEq)]
pub struct ArbitrationOutcome {
    /// Policy the arbitration ran under.
    pub policy: BackendPolicy,
    /// Device model the FPGA path was evaluated against.
    pub device: DeviceModel,
    /// Per-block decisions, aligned with the accepted-block order (and
    /// with `SearchOutcome::best_enabled`).
    pub blocks: Vec<BlockArbitration>,
    /// Overall backend of the deployment this arbitration recommends:
    /// `Fpga` if any block chose the FPGA (including a block rescued from
    /// a GPU-losing pattern), else `Gpu` if any Step-3-winning block runs
    /// on the GPU, else `Cpu`.
    pub backend: Backend,
    /// Total simulated toolchain hours charged (pre-checks + compiles).
    pub simulated_hours: f64,
    /// Estimated per-request seconds of an all-GPU deployment (the
    /// measured Step-3 best time). `None` when the winning pattern
    /// offloads nothing.
    pub gpu_request_secs: Option<f64>,
    /// Estimated per-request seconds of an all-FPGA deployment: every
    /// pre-check-passing core enabled, each block's per-pattern
    /// improvement (projected from swapping its measured device seconds
    /// for the FPGA estimate) applied to the CPU baseline, combined the
    /// way Step 3 combines winners (independent savings). `None` when no
    /// block passed the pre-check.
    pub fpga_request_secs: Option<f64>,
    /// Power residue of the decision: present exactly when a non-default
    /// `--power-policy` decided backends (and then the report serializes
    /// as v3 with per-block energies); `None` under the default `perf`
    /// policy, keeping its report bytes identical to time-only
    /// arbitration.
    pub power: Option<power::PowerDecision>,
    /// Analytic-estimator residue: per-block predicted-vs-measured error,
    /// present exactly when a non-default estimator configuration shaped
    /// the search (and then the report serializes as v4); `None` under
    /// the default configuration, keeping its bytes unchanged. Attached
    /// by the pipeline's arbitration step — [`arbitrate`] itself never
    /// sets it.
    pub estimate: Option<super::estimate::EstimateDecision>,
    /// Residency residue: per-block elided host<->device bytes and the
    /// PCIe transfer time they saved, present exactly when a nonzero
    /// `--resident-bytes` budget installed a data plane (and then the
    /// report serializes as v5); `None` when residency is off, keeping
    /// the report bytes unchanged. Attached by the pipeline's arbitration
    /// step — [`arbitrate`] itself never sets it.
    pub residency: Option<super::residency::ResidencyDecision>,
}

/// Default intensity-narrowing floor: a block must amortize the ≈3 h
/// compile, so its (flops/byte × trips) score has to clear this bar
/// before the toolchain is even pre-checked. The DB-registered eval
/// blocks score ≥10⁵ at the evaluation sizes; a sub-10³ score marks a
/// block that moves more bytes than it computes.
pub const NARROW_MIN_SCORE: f64 = 1000.0;

/// Parallel streaming lanes assumed per IP core: the datapath replicates
/// its innermost stage 4× (well within the Arria10 resource estimates),
/// so modeled trips are `elements × passes / 4`.
pub const STREAM_LANES: u64 = 4;

/// Run backend arbitration over the Step-3 search results.
///
/// `accepted` must be the same accepted-block slice the search ran over
/// (per-block patterns `outcome.tried[i]` correspond to `accepted[i]`).
/// `min_intensity` is the narrowing floor (callers pass
/// [`NARROW_MIN_SCORE`]; tests raise it to exercise narrowing).
///
/// `power` is the `PowerScore` stage result: under the default
/// [`PowerPolicy::Perf`] it is inert (time decides, byte-identical to
/// pre-power arbitration); under `perf-per-watt` the per-block
/// comparisons weigh modeled joules instead of seconds, and under
/// `cap:<watts>` backends whose modeled active draw exceeds the cap are
/// excluded (the CPU always remains as the fallback).
///
/// Fails only under [`BackendPolicy::Fpga`], when a block's IP core flunks
/// the resource pre-check — deliberately *before* any compile hours are
/// charged, mirroring the paper's early resource error.
pub fn arbitrate(
    db: &PatternDb,
    policy: BackendPolicy,
    device: fpga::Device,
    min_intensity: f64,
    accepted: &[PlannedReplacement],
    outcome: &SearchOutcome,
    power: &PowerOutcome,
) -> Result<ArbitrationOutcome> {
    if outcome.tried.len() < accepted.len() {
        bail!(
            "arbitration needs one measured pattern per accepted block \
             ({} patterns for {} blocks)",
            outcome.tried.len(),
            accepted.len()
        );
    }
    let hls = HlsCompiler::new(device);
    let model = &power.model;
    let power_policy = power.policy;
    let cap_allows = |b: Backend| match power_policy {
        PowerPolicy::Cap(w) => model.for_backend(b).active_watts <= w,
        _ => true,
    };
    let mut blocks = Vec::with_capacity(accepted.len());
    let mut projections: Vec<Option<f64>> = Vec::with_capacity(accepted.len());
    let mut energies: Vec<(Option<f64>, Option<f64>)> = Vec::with_capacity(accepted.len());
    // Energy coherence of the per-backend deployments under perf-per-watt:
    // only blocks that actually save energy on a backend are part of that
    // backend's deployment option, so Step 5 neither ships a backend the
    // policy rejected nor sizes from a pattern the shipped (filtered)
    // program cannot reproduce. Per-block time savings over the baseline
    // combine independently — the same assumption Step 3's combine phase
    // and the all-FPGA projection below make.
    let mut ppw_gpu_savings: Vec<f64> = Vec::new();

    for (i, plan) in accepted.iter().enumerate() {
        let label = plan.site.label();
        let pattern = &outcome.tried[i];
        let gpu_ok = pattern.output_ok && pattern.speedup > 1.0;
        let gpu_secs = gpu_ok.then(|| pattern.time.secs());
        let gpu_device_secs = pattern.traffic.device_secs;

        let core = match policy {
            BackendPolicy::Gpu => None,
            _ => db.find_ip_core(&plan.replacement.artifact),
        };
        // The FPGA path needs correctness evidence (the artifact semantics
        // are shared, so the measured pattern's output check transfers —
        // winning on *time* is not required) and an observed dispatch to
        // size the model from.
        let fpga = match core {
            Some(core) if pattern.output_ok && pattern.traffic.dispatches > 0 => {
                Some(evaluate_fpga(
                    db,
                    &hls,
                    core.clone(),
                    &pattern.traffic,
                    policy,
                    min_intensity,
                )?)
            }
            _ => None,
        };

        // Projected whole-pattern time with this block's device seconds
        // swapped for the FPGA estimate: lets the FPGA rescue a block that
        // is correct but transfer-dominated on the GPU (the case FPGA
        // offload is motivated by).
        let fpga_pattern_secs =
            |est: f64| (pattern.time.secs() - gpu_device_secs + est).max(0.0);

        // Power-aware comparisons. Under the default `perf` policy every
        // closure below reduces to the original time-only rule; under
        // `perf-per-watt` modeled joules decide (arXiv:2110.11520's
        // selection criterion); the wattage `cap` only excludes backends.
        let tsecs = power::transfer_secs(&pattern.traffic);
        let gpu_block_j = power::device_energy(&model.gpu, gpu_device_secs, tsecs);
        let fpga_block_j =
            |est: f64| power::device_energy(&model.fpga, est, tsecs);
        let gpu_pattern_j = power::pattern_energy(
            model,
            &model.gpu,
            pattern.time.secs(),
            gpu_device_secs,
            &pattern.traffic,
        );
        let fpga_pattern_j = |est: f64| {
            power::pattern_energy(
                model,
                &model.fpga,
                fpga_pattern_secs(est),
                est,
                &pattern.traffic,
            )
        };
        let gpu_wins_on_policy = match power_policy {
            // Offload when it saves energy for the same work, not (only)
            // time — a slower-but-frugal pattern stays rejected because a
            // slower pattern on a hotter device always burns more joules.
            PowerPolicy::PerfPerWatt => {
                pattern.output_ok && gpu_pattern_j < power.baseline.energy_j
            }
            _ => gpu_ok,
        };
        let gpu_wins_cpu = gpu_wins_on_policy && cap_allows(Backend::Gpu);
        let fpga_wins = |est: &FpgaEstimate| {
            if !cap_allows(Backend::Fpga) {
                return false;
            }
            // With the GPU capped out, the FPGA competes against the CPU
            // baseline alone.
            let beats_gpu = !cap_allows(Backend::Gpu)
                || match power_policy {
                    PowerPolicy::PerfPerWatt => fpga_block_j(est.est_secs) < gpu_block_j,
                    _ => est.est_secs < gpu_device_secs,
                };
            let beats_baseline = match power_policy {
                PowerPolicy::PerfPerWatt => {
                    fpga_pattern_j(est.est_secs) < power.baseline.energy_j
                }
                _ => fpga_pattern_secs(est.est_secs) < outcome.baseline.secs(),
            };
            beats_gpu && beats_baseline
        };

        let backend = match policy {
            BackendPolicy::Gpu => {
                if gpu_wins_cpu {
                    Backend::Gpu
                } else {
                    Backend::Cpu
                }
            }
            BackendPolicy::Fpga => match &fpga {
                Some(est) if est.precheck_ok && cap_allows(Backend::Fpga) => Backend::Fpga,
                _ => Backend::Cpu,
            },
            BackendPolicy::Auto => match &fpga {
                Some(est) if est.precheck_ok && fpga_wins(est) => Backend::Fpga,
                _ if gpu_wins_cpu => Backend::Gpu,
                _ => Backend::Cpu,
            },
        };
        energies.push((
            (pattern.traffic.dispatches > 0).then_some(gpu_block_j),
            fpga.as_ref().filter(|est| est.precheck_ok).map(|est| fpga_block_j(est.est_secs)),
        ));
        let in_best = outcome.best_enabled.get(i).copied().unwrap_or(false);
        if in_best && gpu_wins_on_policy {
            ppw_gpu_savings.push((outcome.baseline.secs() - pattern.time.secs()).max(0.0));
        }

        // Committing to the FPGA pays the full simulated compile.
        let fpga = fpga.map(|mut est| {
            if backend == Backend::Fpga {
                let before = hls.clock.elapsed_hours();
                // The pre-check passed, so the compile cannot fail here.
                let spec = KernelSpec {
                    name: est.core.clone(),
                    resources: est.resources,
                    trips: 0,
                    ii: 1,
                    transfer_bytes: 0,
                };
                let _ = hls.compile(&spec);
                est.compile_hours += hls.clock.elapsed_hours() - before;
            }
            est
        });

        // Projected per-pattern time with this block on the FPGA (used
        // for the all-FPGA request-time estimate below). Under
        // perf-per-watt, a core whose projected pattern loses on joules
        // is excluded from the all-FPGA deployment option too.
        let projection = fpga
            .as_ref()
            .filter(|est| est.precheck_ok)
            .filter(|est| match power_policy {
                PowerPolicy::PerfPerWatt => {
                    fpga_pattern_j(est.est_secs) < power.baseline.energy_j
                }
                _ => true,
            })
            .map(|est| fpga_pattern_secs(est.est_secs));
        projections.push(projection);
        blocks.push(BlockArbitration { label, backend, gpu_secs, gpu_device_secs, fpga });
    }

    // Overall backend: the deployment arbitration recommends. FPGA
    // decisions count even when the block's GPU pattern lost Step 3 (the
    // rescue / forced cases); GPU counts only for Step-3-winning blocks.
    let winning_gpu = blocks
        .iter()
        .zip(&outcome.best_enabled)
        .any(|(b, &on)| on && b.backend == Backend::Gpu);
    let backend = if blocks.iter().any(|b| b.backend == Backend::Fpga) {
        Backend::Fpga
    } else if winning_gpu {
        Backend::Gpu
    } else {
        Backend::Cpu
    };

    // Per-backend request times for Step 5. GPU: the measured winning
    // pattern. FPGA: enable every pre-check-passing core; each block's
    // projected per-pattern improvement over the CPU baseline combines
    // independently (the same assumption Step 3's combine phase makes).
    let offloads = outcome.best_enabled.iter().any(|&on| on);
    let base = outcome.baseline.secs();
    let fpga_savings: Vec<f64> = projections
        .iter()
        .flatten()
        .map(|&p| base - p)
        .collect();
    // A policy-excluded backend is excluded from deployment entirely: its
    // request time must not reach Step-5 placement, or the placement walk
    // would happily ship the service on a backend the cap forbade (or
    // that perf-per-watt rejected on joules for every block). Under
    // perf-per-watt the GPU request time is rebuilt from the coherent
    // blocks' combined savings — `best_time` was measured with *every*
    // time-winner offloaded, including the energy losers the emitted
    // deployment drops. Under `perf` both paths are the pre-power ones.
    let gpu_request_secs = match power_policy {
        PowerPolicy::PerfPerWatt => {
            let deployable = !ppw_gpu_savings.is_empty() && cap_allows(Backend::Gpu);
            deployable.then(|| (base - ppw_gpu_savings.iter().sum::<f64>()).max(1e-9))
        }
        _ => (offloads && cap_allows(Backend::Gpu)).then(|| outcome.best_time.secs()),
    };
    let fpga_request_secs = (!fpga_savings.is_empty() && cap_allows(Backend::Fpga))
        .then(|| (base - fpga_savings.iter().sum::<f64>()).max(1e-9));

    // Power residue: recorded only when a non-default policy decided, so
    // the default report bytes stay identical to time-only arbitration.
    let power_decision = (!power_policy.is_default()).then(|| power::PowerDecision {
        policy: power_policy,
        gpu_watts: model.gpu.active_watts,
        fpga_watts: model.fpga.active_watts,
        blocks: blocks
            .iter()
            .zip(&energies)
            .map(|(b, &(gpu_energy_j, fpga_energy_j))| power::BlockEnergy {
                label: b.label.clone(),
                gpu_energy_j,
                fpga_energy_j,
            })
            .collect(),
    });

    Ok(ArbitrationOutcome {
        policy,
        device: DeviceModel::from(&device),
        blocks,
        backend,
        simulated_hours: hls.clock.elapsed_hours(),
        gpu_request_secs,
        fpga_request_secs,
        power: power_decision,
        estimate: None,
        residency: None,
    })
}

/// Structured telemetry events of one arbitration: a verdict per block
/// naming the winner, the closest losing backend, and the seconds between
/// them. Built lazily by the pipeline only when a
/// [`crate::coordinator::StageObserver`] is installed.
pub fn arbitration_events(outcome: &ArbitrationOutcome) -> Vec<TraceEvent> {
    outcome
        .blocks
        .iter()
        .map(|b| {
            let gpu = b.gpu_secs;
            let fpga = b
                .fpga
                .as_ref()
                .filter(|f| f.precheck_ok && !f.narrowed_out)
                .map(|f| f.est_secs);
            // The loser is the best backend the winner displaced; its
            // seconds (when it had any) set the margin.
            let (loser, loser_secs): (&str, Option<f64>) = match b.backend {
                Backend::Gpu => match fpga {
                    Some(f) => ("fpga", Some(f)),
                    None => ("cpu", None),
                },
                Backend::Fpga => match gpu {
                    Some(g) => ("gpu", Some(g)),
                    None => ("cpu", None),
                },
                Backend::Cpu => match (gpu, fpga) {
                    (Some(g), Some(f)) if f < g => ("fpga", Some(f)),
                    (Some(g), _) => ("gpu", Some(g)),
                    (None, Some(f)) => ("fpga", Some(f)),
                    (None, None) => ("none", None),
                },
            };
            let winner_secs = match b.backend {
                Backend::Gpu => gpu,
                Backend::Fpga => fpga,
                Backend::Cpu => None,
            };
            let margin_secs = match (winner_secs, loser_secs) {
                (Some(w), Some(l)) => (l - w).abs(),
                _ => 0.0,
            };
            TraceEvent::ArbitrationVerdict {
                label: b.label.clone(),
                winner: b.backend.as_str().to_string(),
                loser: loser.to_string(),
                margin_secs,
                policy: outcome.policy.as_str().to_string(),
            }
        })
        .collect()
}

/// Evaluate one IP core: narrowing, pre-check, timing model. Bails (fail
/// fast) when the policy is [`BackendPolicy::Fpga`] and the pre-check
/// rejects the core.
fn evaluate_fpga(
    db: &PatternDb,
    hls: &HlsCompiler,
    core: crate::patterndb::Replacement,
    traffic: &super::verify::DeviceTraffic,
    policy: BackendPolicy,
    min_intensity: f64,
) -> Result<FpgaEstimate> {
    let resources =
        fpga::estimate_ip_core_resources(core.opencl_code.as_deref().unwrap_or(""));
    let utilization = resources.utilization(&hls.device);

    // Size the model from the observed traffic: per-invocation streamed
    // elements across the input-side buffers, and n from the (square)
    // per-buffer working set — the block artifacts are n×n (DESIGN.md).
    let usage = glue::UsageSpec::parse(&core.usage)?;
    let in_bufs = usage
        .bufs
        .iter()
        .filter(|b| matches!(b.mode, glue::Mode::In | glue::Mode::InOut))
        .count()
        .max(1) as u64;
    // Sizing uses paid *plus* elided bytes: residency changes what the
    // PCIe bus moves, not the working set the kernel streams, so the
    // inferred n (and with it trips, passes, intensity) must not shrink
    // when a data plane elides transfers. `transfer_bytes` below stays
    // paid-only — the FPGA path benefits from the same residency the
    // measured GPU path did (both exemplar snippets persist data on the
    // device), so its modeled PCIe cost prices only what is still moved.
    let elems_in = (traffic.bytes_in + traffic.elided_in) / 4 / traffic.dispatches;
    let n = ((elems_in / in_bufs) as f64).sqrt().round().max(1.0) as u64;

    let intensity_score = block_intensity(db, &core.artifact, n);
    // Narrowing happens before any toolchain interaction — skipping even
    // the minutes-scale pre-check is the point (the Fpga policy is an
    // explicit user override and skips narrowing instead).
    if policy != BackendPolicy::Fpga && intensity_score < min_intensity {
        return Ok(FpgaEstimate {
            core: core.name,
            intensity_score,
            narrowed_out: true,
            resources,
            utilization,
            precheck_ok: false,
            est_secs: 0.0,
            compile_hours: 0.0,
        });
    }

    let passes = core.pass_model.unwrap_or(PassModel::Unit).passes(n);
    let spec = KernelSpec {
        name: core.name.clone(),
        resources,
        trips: (elems_in * passes + STREAM_LANES - 1) / STREAM_LANES,
        ii: 1,
        transfer_bytes: (traffic.bytes_in + traffic.bytes_out) / traffic.dispatches,
    };
    let before = hls.clock.elapsed_hours();
    let precheck = hls.precheck(&spec);
    let compile_hours = hls.clock.elapsed_hours() - before;
    if let Err(e) = &precheck {
        if policy == BackendPolicy::Fpga {
            // Report the per-block delta, not the cumulative clock: earlier
            // blocks in the same arbitration may have charged full compiles.
            bail!(
                "--target fpga: {e} — rejected by the resource pre-check after {compile_hours:.2} \
                 simulated hours, before any compile was attempted for this core"
            );
        }
        return Ok(FpgaEstimate {
            core: core.name,
            intensity_score,
            narrowed_out: false,
            resources,
            utilization,
            precheck_ok: false,
            est_secs: 0.0,
            compile_hours,
        });
    }

    // Per-run estimate: the model is per invocation; the block dispatched
    // `dispatches` times per run.
    let est_secs = fpga::modeled_exec_secs(&spec, &hls.device) * traffic.dispatches as f64;
    Ok(FpgaEstimate {
        core: core.name,
        intensity_score,
        narrowed_out: false,
        resources,
        utilization,
        precheck_ok: true,
        est_secs,
        compile_hours,
    })
}

/// Static narrowing score of a DB-registered block at size `n`: the
/// innermost flops/byte ratio of the DB's CPU implementation times the
/// estimated trip count `n^depth` of its deepest loop nest. The paper's
/// intensity tool runs on application source; our blocks are DB-known, so
/// the registered implementation is the equivalent text.
fn block_intensity(db: &PatternDb, artifact: &str, n: u64) -> f64 {
    let code = db
        .comparisons
        .iter()
        .find(|c| c.replacement.artifact == artifact)
        .map(|c| c.code.as_str())
        .or_else(|| {
            db.libraries
                .iter()
                .find(|l| l.replacement.artifact == artifact)
                .and_then(|l| l.cpu_impl.as_ref().map(|(code, _)| code.as_str()))
        });
    let Some(code) = code else { return 0.0 };
    let Ok(prog) = parser::parse(code) else { return 0.0 };
    let a = analysis::analyze(&prog);
    let levels = a.loops.iter().map(|l| l.depth + 1).max().unwrap_or(0);
    let mut ratio = 0.0f64;
    for f in prog.functions() {
        let Some(body) = &f.body else { continue };
        body.walk(&mut |s| {
            if matches!(s.kind, StmtKind::For { .. }) {
                let r = analysis::intensity_of_loop(s);
                if r.ratio > ratio {
                    ratio = r.ratio;
                }
            }
        });
    }
    ratio * (n as f64).powi(levels as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::power::PowerModel;
    use crate::coordinator::verify::{DeviceTraffic, PatternResult, SearchOutcome};
    use crate::metrics::Measurement;
    use crate::transform::{Reconciliation, Site};
    use std::time::Duration;

    /// The inert default: time decides, as before the power stage existed.
    fn perf_power(outcome: &SearchOutcome) -> PowerOutcome {
        power::score(&PowerModel::builtin(), PowerPolicy::Perf, outcome)
    }

    fn measurement(label: &str, us: u64) -> Measurement {
        Measurement {
            label: label.to_string(),
            median: Duration::from_micros(us),
            min: Duration::from_micros(us),
            max: Duration::from_micros(us),
            reps: 1,
        }
    }

    /// One accepted fft2d block + a synthetic search outcome where the GPU
    /// pattern won with the given measured device seconds.
    fn fft_case(device_secs: f64) -> (Vec<PlannedReplacement>, SearchOutcome) {
        let db = PatternDb::builtin();
        let plan = PlannedReplacement {
            site: Site::LibraryCall { callee: "fft2d".into() },
            replacement: db.libraries[0].replacement.clone(),
            reconciliation: Reconciliation::Exact,
        };
        let n = 64u64;
        let traffic = DeviceTraffic {
            bytes_in: 2 * n * n * 4,
            bytes_out: 2 * n * n * 4,
            dispatches: 1,
            device_secs,
            ..Default::default()
        };
        let outcome = SearchOutcome {
            baseline: measurement("all-CPU", 100_000),
            tried: vec![PatternResult {
                enabled: vec![true],
                label: "only:call:fft2d".into(),
                time: measurement("only:call:fft2d", 2_000),
                speedup: 50.0,
                output_ok: true,
                traffic,
            }],
            best_enabled: vec![true],
            best_time: measurement("only:call:fft2d", 2_000),
            best_speedup: 50.0,
        };
        (vec![plan], outcome)
    }

    #[test]
    fn auto_picks_fpga_when_estimate_beats_measurement() {
        let db = PatternDb::builtin();
        let (accepted, outcome) = fft_case(0.010); // 10 ms measured on PJRT
        let out = arbitrate(
            &db,
            BackendPolicy::Auto,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &outcome,
            &perf_power(&outcome),
        )
        .unwrap();
        assert_eq!(out.backend, Backend::Fpga);
        let b = &out.blocks[0];
        assert_eq!(b.backend, Backend::Fpga);
        let est = b.fpga.as_ref().unwrap();
        assert!(est.precheck_ok && !est.narrowed_out);
        assert!(est.est_secs > 0.0 && est.est_secs < 0.010, "est {}", est.est_secs);
        // Committing to FPGA paid for a full compile (≥3 simulated hours).
        assert!(out.simulated_hours >= 3.0, "hours {}", out.simulated_hours);
        // Request-time estimates feed Step 5.
        assert!(out.gpu_request_secs.unwrap() > out.fpga_request_secs.unwrap());
    }

    #[test]
    fn auto_keeps_gpu_when_measurement_wins() {
        let db = PatternDb::builtin();
        let (accepted, outcome) = fft_case(1e-7); // PJRT was near-free
        let out = arbitrate(
            &db,
            BackendPolicy::Auto,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &outcome,
            &perf_power(&outcome),
        )
        .unwrap();
        assert_eq!(out.backend, Backend::Gpu);
        let est = out.blocks[0].fpga.as_ref().unwrap();
        assert!(est.precheck_ok, "losing on time is not a resource rejection");
        // Only the pre-check was charged — no compile for a losing core.
        assert!(out.simulated_hours < 1.0, "hours {}", out.simulated_hours);
    }

    #[test]
    fn residency_split_keeps_fpga_sizing_and_credits_paid_transfers_only() {
        let db = PatternDb::builtin();
        let (accepted, outcome) = fft_case(0.010);
        // Same physical working set, but the data plane elided 3/4 of the
        // staging: paid + elided must equal the all-paid traffic.
        let (_, mut resident) = fft_case(0.010);
        let t = &mut resident.tried[0].traffic;
        t.elided_in = t.bytes_in / 4 * 3;
        t.bytes_in /= 4;
        t.elided_out = t.bytes_out / 2;
        t.bytes_out /= 2;
        let args = |o: &SearchOutcome| {
            arbitrate(
                &db,
                BackendPolicy::Auto,
                fpga::ARRIA10_GX,
                NARROW_MIN_SCORE,
                &accepted,
                o,
                &perf_power(o),
            )
            .unwrap()
        };
        let paid = args(&outcome);
        let split = args(&resident);
        let (pe, se) =
            (paid.blocks[0].fpga.as_ref().unwrap(), split.blocks[0].fpga.as_ref().unwrap());
        // The kernel model is sized from paid+elided bytes: identical
        // intensity and narrowing/pre-check verdicts either way.
        assert_eq!(pe.intensity_score, se.intensity_score);
        assert_eq!(pe.narrowed_out, se.narrowed_out);
        assert_eq!(pe.precheck_ok, se.precheck_ok);
        // ...but the modeled FPGA time prices only the still-paid PCIe
        // bytes, so residency credits the estimate too.
        assert!(se.est_secs < pe.est_secs, "{} !< {}", se.est_secs, pe.est_secs);
    }

    #[test]
    fn narrowing_skips_low_intensity_blocks_before_the_toolchain() {
        let db = PatternDb::builtin();
        let (accepted, outcome) = fft_case(0.010);
        let out = arbitrate(
            &db,
            BackendPolicy::Auto,
            fpga::ARRIA10_GX,
            f64::INFINITY, // nothing clears the bar
            &accepted,
            &outcome,
            &perf_power(&outcome),
        )
        .unwrap();
        assert_eq!(out.backend, Backend::Gpu);
        let est = out.blocks[0].fpga.as_ref().unwrap();
        assert!(est.narrowed_out && !est.precheck_ok);
        assert!(est.intensity_score > 0.0);
        assert_eq!(out.simulated_hours, 0.0, "narrowed cores never touch the toolchain");
    }

    #[test]
    fn gpu_policy_never_evaluates_fpga() {
        let db = PatternDb::builtin();
        let (accepted, outcome) = fft_case(0.010);
        let out = arbitrate(
            &db,
            BackendPolicy::Gpu,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &outcome,
            &perf_power(&outcome),
        )
        .unwrap();
        assert_eq!(out.backend, Backend::Gpu);
        assert!(out.blocks[0].fpga.is_none());
        assert_eq!(out.simulated_hours, 0.0);
    }

    #[test]
    fn fpga_policy_fails_fast_on_resource_overflow() {
        // An IP core whose OpenCL text implies an over-device footprint:
        // estimate_ip_core_resources scales with the kernel text.
        let mut db = PatternDb::builtin();
        db.fpga_ip_cores[0].opencl_code = Some("x".repeat(20_000));
        let (accepted, outcome) = fft_case(0.010);
        let err = arbitrate(
            &db,
            BackendPolicy::Fpga,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &outcome,
            &perf_power(&outcome),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("pre-check"), "{err}");
        // Fail-fast contract: hours are in the message and far below one
        // compile (the pre-check costs simulated minutes).
        let hours: f64 = err
            .split("rejected by the resource pre-check after ")
            .nth(1)
            .and_then(|s| s.split(' ').next())
            .and_then(|s| s.parse().ok())
            .expect("hours in message");
        assert!(hours < 1.0, "{err}");
    }

    #[test]
    fn fpga_policy_forces_fpga_even_when_slower() {
        let db = PatternDb::builtin();
        let (accepted, outcome) = fft_case(1e-7); // GPU would win on time
        let out = arbitrate(
            &db,
            BackendPolicy::Fpga,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &outcome,
            &perf_power(&outcome),
        )
        .unwrap();
        assert_eq!(out.backend, Backend::Fpga);
        assert!(out.simulated_hours >= 3.0, "forced FPGA still pays the compile");
    }

    #[test]
    fn fpga_can_rescue_a_correct_but_slow_gpu_pattern() {
        // The pattern is correct but the PJRT path lost to the CPU
        // baseline (transfer-dominated small block) — exactly the case
        // FPGA offload is motivated by. Eligibility is correctness, not
        // GPU profitability.
        let db = PatternDb::builtin();
        // 10.5 ms of the 11 ms pattern is device time: the block itself is
        // what loses on the GPU. Shape the outcome the way search_patterns
        // actually reports a losing pattern: best stays the baseline.
        let (accepted, mut outcome) = fft_case(0.0105);
        outcome.baseline = measurement("all-CPU", 1_000); // 1 ms baseline
        outcome.tried[0].time = measurement("only:call:fft2d", 11_000); // 11 ms, loses
        outcome.tried[0].speedup = 1_000.0 / 11_000.0;
        outcome.best_enabled = vec![false];
        outcome.best_time = outcome.baseline.clone();
        outcome.best_speedup = 1.0;
        let out = arbitrate(
            &db,
            BackendPolicy::Auto,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &outcome,
            &perf_power(&outcome),
        )
        .unwrap();
        // Projection: 11 ms - 10.5 ms device + ~63 µs est < 1 ms baseline.
        assert_eq!(out.blocks[0].backend, Backend::Fpga);
        assert!(out.blocks[0].gpu_secs.is_none(), "GPU pattern lost on time");
        // The rescue surfaces end-to-end: overall backend and the Step-5
        // FPGA request time, with no GPU deployment on offer.
        assert_eq!(out.backend, Backend::Fpga);
        assert!(out.gpu_request_secs.is_none());
        let fpga_req = out.fpga_request_secs.unwrap();
        assert!(fpga_req < outcome.baseline.secs(), "req {fpga_req}");
        // Forcing the FPGA also works without GPU profitability.
        let forced = arbitrate(
            &db,
            BackendPolicy::Fpga,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &outcome,
            &perf_power(&outcome),
        )
        .unwrap();
        assert_eq!(forced.blocks[0].backend, Backend::Fpga);
    }

    #[test]
    fn block_without_ip_core_stays_gpu_under_every_policy() {
        let db = PatternDb::builtin();
        let plan = PlannedReplacement {
            site: Site::LibraryCall { callee: "matmul".into() },
            // matmul has no registered IP core.
            replacement: db.libraries[3].replacement.clone(),
            reconciliation: Reconciliation::Exact,
        };
        let outcome = SearchOutcome {
            baseline: measurement("all-CPU", 100_000),
            tried: vec![PatternResult {
                enabled: vec![true],
                label: "only:call:matmul".into(),
                time: measurement("only:call:matmul", 2_000),
                speedup: 50.0,
                output_ok: true,
                traffic: DeviceTraffic {
                    bytes_in: 2 * 64 * 64 * 4,
                    bytes_out: 64 * 64 * 4,
                    dispatches: 1,
                    device_secs: 0.010,
                    ..Default::default()
                },
            }],
            best_enabled: vec![true],
            best_time: measurement("only:call:matmul", 2_000),
            best_speedup: 50.0,
        };
        for policy in [BackendPolicy::Auto, BackendPolicy::Fpga, BackendPolicy::Gpu] {
            let out = arbitrate(
                &db,
                policy,
                fpga::ARRIA10_GX,
                NARROW_MIN_SCORE,
                &[plan.clone()],
                &outcome,
                &perf_power(&outcome),
            )
            .unwrap();
            assert!(out.blocks[0].fpga.is_none(), "{policy:?}");
            let want = if policy == BackendPolicy::Fpga { Backend::Cpu } else { Backend::Gpu };
            assert_eq!(out.blocks[0].backend, want, "{policy:?}");
        }
    }

    #[test]
    fn perf_per_watt_flips_a_gpu_time_winner_to_fpga() {
        // Pick a measured device time *below* the FPGA estimate, so time-
        // only arbitration keeps the GPU — then show that the ~75 W vs
        // ~40 W draw asymmetry flips the block to the FPGA once joules
        // decide. First extract the estimate under the default policy.
        let db = PatternDb::builtin();
        let (accepted, probe_outcome) = fft_case(0.010);
        let probe = arbitrate(
            &db,
            BackendPolicy::Auto,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &probe_outcome,
            &perf_power(&probe_outcome),
        )
        .unwrap();
        let est = probe.blocks[0].fpga.as_ref().unwrap().est_secs;
        assert!(est > 0.0);

        // Measured GPU seconds at 80% of the estimate: time says GPU, but
        // gpu joules ≈ 75 W × 0.8·est > fpga joules ≈ 40 W × est.
        let (accepted, outcome) = fft_case(est * 0.8);
        let model = PowerModel::builtin();
        let perf = arbitrate(
            &db,
            BackendPolicy::Auto,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &outcome,
            &power::score(&model, PowerPolicy::Perf, &outcome),
        )
        .unwrap();
        assert_eq!(perf.blocks[0].backend, Backend::Gpu, "time-only keeps the GPU");
        assert!(perf.power.is_none(), "default policy records no power residue");

        let ppw = arbitrate(
            &db,
            BackendPolicy::Auto,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &outcome,
            &power::score(&model, PowerPolicy::PerfPerWatt, &outcome),
        )
        .unwrap();
        assert_eq!(ppw.blocks[0].backend, Backend::Fpga, "joules flip the block");
        assert_eq!(ppw.backend, Backend::Fpga);
        // The v3 power residue records the per-block energy comparison.
        let residue = ppw.power.as_ref().unwrap();
        assert_eq!(residue.policy, PowerPolicy::PerfPerWatt);
        let block = &residue.blocks[0];
        let (gpu_j, fpga_j) =
            (block.gpu_energy_j.unwrap(), block.fpga_energy_j.unwrap());
        assert!(fpga_j < gpu_j, "fpga {fpga_j} J vs gpu {gpu_j} J");
        assert!((residue.gpu_watts - model.gpu.active_watts).abs() < 1e-9);
    }

    #[test]
    fn wattage_cap_excludes_hot_backends() {
        let db = PatternDb::builtin();
        let model = PowerModel::builtin();
        // The FPGA estimate loses on time (measured PJRT near-free), so
        // uncapped auto keeps the GPU; capping below the GPU's 75 W draw
        // excludes it, and the FPGA — the only backend under the cap —
        // must still beat the CPU baseline to win the block.
        let (accepted, outcome) = fft_case(1e-7);
        let capped = arbitrate(
            &db,
            BackendPolicy::Auto,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &outcome,
            &power::score(&model, PowerPolicy::Cap(50.0), &outcome),
        )
        .unwrap();
        assert_eq!(capped.blocks[0].backend, Backend::Fpga, "GPU capped out");
        assert!(capped.power.is_some(), "cap is a non-default policy: residue recorded");
        // The exclusion reaches Step-5: no GPU deployment may be offered.
        assert!(capped.gpu_request_secs.is_none(), "capped-out GPU must not reach placement");
        assert!(capped.fpga_request_secs.is_some());

        // A cap below every accelerator leaves only the CPU.
        let starved = arbitrate(
            &db,
            BackendPolicy::Auto,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &outcome,
            &power::score(&model, PowerPolicy::Cap(30.0), &outcome),
        )
        .unwrap();
        assert_eq!(starved.blocks[0].backend, Backend::Cpu);
        assert_eq!(starved.backend, Backend::Cpu);
        assert!(starved.gpu_request_secs.is_none());
        assert!(starved.fpga_request_secs.is_none());

        // Even a forced --target fpga respects the hard cap.
        let forced = arbitrate(
            &db,
            BackendPolicy::Fpga,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &outcome,
            &power::score(&model, PowerPolicy::Cap(30.0), &outcome),
        )
        .unwrap();
        assert_eq!(forced.blocks[0].backend, Backend::Cpu);
    }

    #[test]
    fn perf_per_watt_sends_an_energy_losing_time_winner_back_to_cpu() {
        // A 1.05x time win that burns more joules than the all-CPU run:
        // 95 ms pattern (5 ms on the device) vs a 100 ms baseline — the
        // hotter GPU + host draw outweighs the small time saving, and the
        // modeled FPGA projection loses on pattern energy too.
        let db = PatternDb::builtin();
        let model = PowerModel::builtin();
        let (accepted, mut outcome) = fft_case(0.005);
        outcome.tried[0].time = measurement("only:call:fft2d", 95_000);
        outcome.tried[0].speedup = 100_000.0 / 95_000.0;
        outcome.best_time = outcome.tried[0].time.clone();
        outcome.best_speedup = outcome.tried[0].speedup;
        let out = arbitrate(
            &db,
            BackendPolicy::Auto,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &outcome,
            &power::score(&model, PowerPolicy::PerfPerWatt, &outcome),
        )
        .unwrap();
        assert_eq!(out.blocks[0].backend, Backend::Cpu, "energy loser stays on the CPU");
        assert_eq!(out.backend, Backend::Cpu);
        // The policy-incoherent deployments are withheld from Step 5
        // entirely: placement can never ship a backend the policy
        // rejected for every block.
        assert!(out.gpu_request_secs.is_none());
        assert!(out.fpga_request_secs.is_none());
    }

    #[test]
    fn perf_per_watt_rejects_a_slower_pattern_outright() {
        // A pattern slower than the baseline burns more joules than the
        // baseline on any device: perf-per-watt must not "rescue" it onto
        // the GPU.
        let db = PatternDb::builtin();
        let model = PowerModel::builtin();
        let (accepted, mut outcome) = fft_case(1e-7);
        outcome.baseline = measurement("all-CPU", 1_000);
        outcome.tried[0].time = measurement("only:call:fft2d", 11_000);
        outcome.tried[0].speedup = 1_000.0 / 11_000.0;
        outcome.best_enabled = vec![false];
        outcome.best_time = outcome.baseline.clone();
        outcome.best_speedup = 1.0;
        let out = arbitrate(
            &db,
            BackendPolicy::Auto,
            fpga::ARRIA10_GX,
            NARROW_MIN_SCORE,
            &accepted,
            &outcome,
            &power::score(&model, PowerPolicy::PerfPerWatt, &outcome),
        )
        .unwrap();
        assert_ne!(out.blocks[0].backend, Backend::Gpu);
    }

    #[test]
    fn intensity_scores_rank_lu_above_fft() {
        // LU streams n³ work over n² data; FFT n²·log n — both clear the
        // narrowing floor at n=64, LU by more.
        let db = PatternDb::builtin();
        let lu = block_intensity(&db, "lu_factor", 64);
        let fft = block_intensity(&db, "fft2d", 64);
        assert!(lu > NARROW_MIN_SCORE, "lu {lu}");
        assert!(fft > NARROW_MIN_SCORE, "fft {fft}");
        assert!(lu > fft, "lu {lu} vs fft {fft}");
    }
}
