//! The coordinator — the paper's function-block offloading system.
//!
//! Orchestrates the full pipeline of Fig. 2 on one application source:
//!
//! 1. **Step 1** — parse + analyze ([`crate::analysis`]),
//! 2. **Step 2** — discover offloadable blocks: A-1/B-1 library-name
//!    matching against the pattern DB, A-2/B-2 Deckard-style similarity
//!    over defined functions,
//! 3. **C-1/C-2** — reconcile interfaces (auto-cast / optional-drop / user
//!    confirmation via [`InterfacePolicy`]),
//! 4. **Step 3** — measured pattern search in the verification
//!    environment ([`verify`]), individual blocks then combined winners,
//! 5. emit the transformed source + report (and optionally feed Steps 4–7
//!    in [`flow`]).
//!
//! The pipeline itself is staged ([`pipeline`]): [`Coordinator::request`]
//! builds an [`OffloadRequest`] that advances through typed artifacts
//! (`Parsed → Discovered → Reconciled → Estimated → Verified → Arbitrated
//! → Placed`),
//! each inspectable, serializable, and resumable in isolation.
//! [`Coordinator::offload`] is the thin compatibility wrapper that runs
//! every stage in one call.
//!
//! The GA loop-offload baseline of the prior work lives in
//! [`loop_offload`]; the evaluation applications in [`apps`].

pub mod apps;
pub mod backend;
pub mod estimate;
pub mod flow;
pub mod loop_offload;
pub mod pipeline;
pub mod power;
pub mod profile;
pub mod report_json;
pub mod residency;
pub mod verify;

use std::path::Path;
use std::rc::Rc;
use std::time::Duration;

use anyhow::Result;

use crate::analysis::{self, Analysis};
use crate::parser::Program;
use crate::patterndb::PatternDb;
use crate::runtime::Engine;
use crate::similarity;
use crate::transform::{InterfacePolicy, PlannedReplacement, Reconciliation};

pub use backend::{ArbitrationOutcome, Backend, BackendPolicy};
pub use estimate::{EstimateDecision, EstimateOutcome, PrunePolicy};
pub use pipeline::{
    Arbitrated, Candidate, Discovered, Estimated, OffloadError, OffloadRequest, Parsed, Placed,
    PowerScored, Reconciled, Stage, StageObserver, Verified,
};
pub use power::{PowerModel, PowerOutcome, PowerPolicy};
pub use profile::ProfileRegistry;
pub use residency::{BlockResidency, ResidencyDecision};
pub use verify::{
    MeasuredPattern, PatternExecutor, PatternSpec, ResultProbe, SearchOutcome, SerialExecutor,
    VerifyConfig, VerifyContext, VerifyPlan,
};

/// How a block was discovered.
#[derive(Debug, Clone, PartialEq)]
pub enum DiscoveryPath {
    /// A-1/B-1: external call matched a DB library record by name.
    LibraryMatch { library: String },
    /// A-2/B-2: defined function matched DB comparison code.
    Similarity { block: String, score: f64 },
}

/// One discovered (and reconciled) offload candidate.
#[derive(Debug, Clone)]
pub struct DiscoveredBlock {
    /// Discovery provenance (A-1/B-1 name match or A-2/B-2 similarity).
    pub via: DiscoveryPath,
    /// The planned replacement, including the reconciled interface.
    pub plan: PlannedReplacement,
}

impl DiscoveredBlock {
    /// True when the interface reconciliation did not reject the block.
    pub fn accepted(&self) -> bool {
        self.plan.reconciliation.accepted()
    }
}

/// Full offload report for one application.
#[derive(Debug, Clone)]
pub struct OffloadReport {
    /// Entry-point function the pipeline ran from.
    pub entry: String,
    /// Distinct external callee names found by Step-1 analysis.
    pub external_callees: Vec<String>,
    /// Every discovered block with its discovery provenance.
    pub blocks: Vec<DiscoveredBlock>,
    /// Step-3 measured pattern-search outcome.
    pub outcome: SearchOutcome,
    /// Step-3b backend arbitration: CPU/GPU/FPGA per block, and the
    /// overall backend of the winning pattern.
    pub arbitration: ArbitrationOutcome,
    /// The winning transformed source (paper Step 3 output).
    pub transformed_source: String,
    /// Wall-clock of the whole discovery + search.
    pub search_wall: Duration,
}

impl OffloadReport {
    /// Speedup of the winning pattern over the all-CPU baseline.
    pub fn best_speedup(&self) -> f64 {
        self.outcome.best_speedup
    }

    /// Overall backend of the winning pattern (Step-3b decision).
    pub fn backend(&self) -> Backend {
        self.arbitration.backend
    }
}

/// The coordinator configuration + handles.
pub struct Coordinator {
    /// Code-pattern DB (libraries, comparison code, FPGA IP cores).
    pub db: PatternDb,
    /// PJRT engine executing the AOT artifacts.
    pub engine: Rc<Engine>,
    /// Interface-reconciliation policy (C-1/C-2 confirmations).
    pub policy: InterfacePolicy,
    /// Deckard-style similarity threshold for copied-code discovery.
    pub similarity_threshold: f64,
    /// Verification-measurement settings (Step 3).
    pub verify: VerifyConfig,
    /// Which backends Step-3b arbitration may choose (CLI `--target`).
    pub backend_policy: BackendPolicy,
    /// FPGA device model the arbitration evaluates IP cores against.
    pub device: crate::fpga::Device,
    /// How arbitration weighs power (CLI `--power-policy`): the default
    /// `perf` decides on time alone, exactly as before the power stage.
    pub power_policy: PowerPolicy,
    /// Per-device wattage models (CPU baseline, GPU, FPGA) the power
    /// stage scores candidates against, registered alongside `device`.
    pub power_model: PowerModel,
    /// Device-profile registry the estimate stage scores candidates
    /// against (CLI `--device-profile`): the built-in registry matches
    /// the paper's measurement hardware.
    pub profiles: ProfileRegistry,
    /// How the analytic estimate prunes the verify plan (CLI
    /// `--prune-policy`): the default `off` computes and traces estimates
    /// but never changes what is measured.
    pub prune_policy: PrunePolicy,
    /// Resident-set byte budget for the device data plane (CLI
    /// `--resident-bytes`). The default `0` leaves residency off — no
    /// plane is installed and the pipeline is byte-identical to the
    /// pre-residency one, decisions and cache fingerprints included. A
    /// nonzero budget installs a [`crate::runtime::DataPlane`] on the
    /// engine before Step 3 so adjacent offloaded blocks hand tensors
    /// device-side and hot inputs stay pinned across service requests.
    pub resident_bytes: u64,
    /// Pattern executor the Verify stage measures with. `None` means the
    /// serial default (one engine, patterns back to back); the service
    /// tier and CLI `--verify-parallel` install a pooled executor that
    /// fans independent patterns across sibling engines. The choice never
    /// changes the [`SearchOutcome`] — only how fast it is produced.
    pub executor: Option<std::rc::Rc<dyn PatternExecutor>>,
}

impl Coordinator {
    /// Open with the built-in DB and an artifact directory.
    pub fn open(artifacts: &Path) -> Result<Self> {
        Ok(Coordinator {
            db: PatternDb::builtin(),
            engine: Engine::open(artifacts)?,
            policy: InterfacePolicy::AutoApprove,
            similarity_threshold: similarity::DEFAULT_THRESHOLD,
            verify: VerifyConfig::default(),
            backend_policy: BackendPolicy::Auto,
            device: crate::fpga::ARRIA10_GX,
            power_policy: PowerPolicy::default(),
            power_model: PowerModel::builtin(),
            profiles: ProfileRegistry::builtin(),
            prune_policy: PrunePolicy::default(),
            resident_bytes: 0,
            executor: None,
        })
    }

    /// "Link" CPU implementations of DB-known external libraries into the
    /// program, the way the paper's verification machine compiles the app
    /// against the NR sources: the all-CPU baseline needs runnable bodies.
    pub fn link_cpu_libraries(&self, prog: &Program) -> Result<Program> {
        pipeline::link_cpu_libraries(&self.db, prog)
    }

    /// Step 2 + C: discover offloadable blocks and reconcile interfaces
    /// (the Discover + Reconcile stages over an already-parsed program).
    pub fn discover(&self, prog: &Program) -> Result<(Analysis, Vec<DiscoveredBlock>)> {
        let a = analysis::analyze(prog);
        let candidates = pipeline::discover_candidates(
            &self.db,
            self.similarity_threshold,
            prog,
            &a.external_callees(),
        )?;
        let blocks = pipeline::reconcile_candidates(&candidates, &self.policy);
        Ok((a, blocks))
    }

    /// Build a staged [`OffloadRequest`] for one source, seeded with this
    /// coordinator's handles and policies. Advance it stage by stage, or
    /// [`OffloadRequest::run`] all of them.
    pub fn request(&self, src: &str, entry: &str) -> OffloadRequest {
        OffloadRequest::from_coordinator(self, src, entry)
    }

    /// The full pipeline on one source (paper Steps 1–3b): a thin
    /// compatibility wrapper that builds a request and runs every stage.
    /// Use [`Coordinator::request`] to drive (or resume) stages
    /// individually and to get the structured [`OffloadError`] directly.
    pub fn offload(&self, src: &str, entry: &str) -> Result<OffloadReport> {
        Ok(self.request(src, entry).run()?)
    }

    /// Render a human-readable report (CLI output).
    pub fn render_report(&self, r: &OffloadReport) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(out, "== function-block offload report ==");
        let _ = writeln!(out, "externals: {:?}", r.external_callees);
        for b in &r.blocks {
            let status = match &b.plan.reconciliation {
                Reconciliation::Rejected(why) => format!("rejected ({why})"),
                other => format!("{other:?}"),
            };
            let _ = writeln!(out, "  block {} via {:?}: {}", b.plan.site.label(), b.via, status);
        }
        let _ = writeln!(
            out,
            "baseline (all-CPU): {}",
            crate::metrics::fmt_duration(r.outcome.baseline.median)
        );
        for p in &r.outcome.tried {
            let _ = writeln!(
                out,
                "  pattern {:<28} {:>12}  speedup {:>8}  correct:{}",
                p.label,
                crate::metrics::fmt_duration(p.time.median),
                crate::metrics::fmt_speedup(p.speedup),
                p.output_ok
            );
        }
        let _ = writeln!(
            out,
            "best: speedup {} in {}",
            crate::metrics::fmt_speedup(r.outcome.best_speedup),
            crate::metrics::fmt_duration(r.search_wall),
        );
        let arb = &r.arbitration;
        let _ = writeln!(
            out,
            "backend arbitration (--target {}, device {}):",
            arb.policy.as_str(),
            arb.device.name
        );
        for b in &arb.blocks {
            let fpga = match &b.fpga {
                None => "no IP core".to_string(),
                Some(f) if f.narrowed_out => {
                    format!("narrowed out (intensity {:.0})", f.intensity_score)
                }
                Some(f) if !f.precheck_ok => format!(
                    "pre-check rejected ({:.0}% of scarcest resource)",
                    f.utilization * 100.0
                ),
                Some(f) => format!(
                    "est {} ({:.0}% util, {} toolchain)",
                    crate::metrics::fmt_duration(std::time::Duration::from_secs_f64(f.est_secs)),
                    f.utilization * 100.0,
                    crate::metrics::fmt_hours(f.compile_hours),
                ),
            };
            let _ = writeln!(
                out,
                "  block {:<24} -> {:<4}  gpu(measured) {}  fpga: {fpga}",
                b.label,
                b.backend.as_str(),
                crate::metrics::fmt_duration(std::time::Duration::from_secs_f64(
                    b.gpu_device_secs
                )),
            );
        }
        if let Some(p) = &arb.power {
            let _ = writeln!(
                out,
                "power arbitration (--power-policy {}, gpu {:.0} W / fpga {:.0} W per instance):",
                p.policy.render(),
                p.gpu_watts,
                p.fpga_watts,
            );
            for b in &p.blocks {
                let j = |v: Option<f64>| match v {
                    Some(j) => format!("{:.2} mJ", j * 1e3),
                    None => "-".to_string(),
                };
                let _ = writeln!(
                    out,
                    "  block {:<24} gpu {}  fpga {}",
                    b.label,
                    j(b.gpu_energy_j),
                    j(b.fpga_energy_j),
                );
            }
        }
        if let Some(e) = &arb.estimate {
            let _ = writeln!(
                out,
                "analytic estimate (--prune-policy {}, gpu {} / fpga {}):",
                e.policy.render(),
                e.gpu_profile,
                e.fpga_profile,
            );
            for b in &e.blocks {
                let measured = match b.measured_secs {
                    Some(m) => crate::metrics::fmt_duration(Duration::from_secs_f64(m)),
                    None => "-".to_string(),
                };
                let err = match b.error {
                    Some(err) => format!("{:+.0}%", err * 100.0),
                    None => "-".to_string(),
                };
                let _ = writeln!(
                    out,
                    "  block {:<24} {:<4} predicted {}  measured {}  error {}",
                    b.label,
                    b.backend.as_str(),
                    crate::metrics::fmt_duration(Duration::from_secs_f64(b.predicted_secs)),
                    measured,
                    err,
                );
            }
            if let Some(mape) = e.mape {
                let _ = writeln!(out, "  estimator MAPE {:.0}%", mape * 100.0);
            }
        }
        if let Some(res) = &arb.residency {
            let _ = writeln!(
                out,
                "device residency (--resident-bytes {}):",
                crate::metrics::fmt_bytes(res.budget_bytes),
            );
            for b in &res.blocks {
                let _ = writeln!(
                    out,
                    "  block {:<24} elided {} in / {} out  saved {}",
                    b.label,
                    crate::metrics::fmt_bytes(b.elided_in),
                    crate::metrics::fmt_bytes(b.elided_out),
                    crate::metrics::fmt_duration(Duration::from_secs_f64(b.saved_transfer_secs)),
                );
            }
            let _ = writeln!(
                out,
                "  total transfer credit {} per run",
                crate::metrics::fmt_duration(Duration::from_secs_f64(
                    res.total_saved_transfer_secs
                )),
            );
        }
        let _ = writeln!(
            out,
            "chosen backend: {} ({} simulated toolchain time)",
            arb.backend.as_str(),
            crate::metrics::fmt_hours(arb.simulated_hours),
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parser;
    use std::path::PathBuf;

    fn coord() -> Coordinator {
        let mut c = Coordinator::open(
            &PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        )
        .unwrap();
        c.verify.reps = 1;
        c
    }

    #[test]
    fn lib_variant_fft_discovered_and_accelerated() {
        let c = coord();
        let r = c.offload(&apps::fft_app_lib(64), "main").unwrap();
        assert_eq!(r.external_callees, vec!["fft2d".to_string()]);
        assert!(r.blocks.iter().any(|b| matches!(
            &b.via,
            DiscoveryPath::LibraryMatch { library } if library == "fft2d"
        )));
        assert!(
            r.best_speedup() > 3.0,
            "fft lib speedup {} (tried: {:?})",
            r.best_speedup(),
            r.outcome.tried.iter().map(|t| (&t.label, t.speedup)).collect::<Vec<_>>()
        );
        assert!(r.transformed_source.contains("__fb_fft2d"));
    }

    #[test]
    fn copy_variant_fft_found_by_similarity() {
        let c = coord();
        let r = c.offload(&apps::fft_app_copy(64), "main").unwrap();
        assert!(
            r.blocks.iter().any(|b| matches!(
                &b.via,
                DiscoveryPath::Similarity { block, .. } if block == "nr-four1-fft2d"
            )),
            "blocks: {:?}",
            r.blocks.iter().map(|b| &b.via).collect::<Vec<_>>()
        );
        assert!(r.best_speedup() > 3.0, "fft copy speedup {}", r.best_speedup());
        assert!(r.transformed_source.contains("__fb_fft2d"));
    }

    #[test]
    fn lib_variant_lu_discovered_and_accelerated() {
        let c = coord();
        let r = c.offload(&apps::lu_app_lib(64), "main").unwrap();
        assert!(
            r.best_speedup() > 10.0,
            "lu lib speedup {} (tried: {:?})",
            r.best_speedup(),
            r.outcome.tried.iter().map(|t| (&t.label, t.speedup)).collect::<Vec<_>>()
        );
        assert!(r.transformed_source.contains("__fb_lu_factor"));
    }

    #[test]
    fn copy_variant_lu_found_by_similarity() {
        let c = coord();
        let r = c.offload(&apps::lu_app_copy(64), "main").unwrap();
        assert!(
            r.blocks.iter().any(|b| matches!(
                &b.via,
                DiscoveryPath::Similarity { block, .. } if block.starts_with("nr-ludcmp")
            )),
            "blocks: {:?}",
            r.blocks.iter().map(|b| &b.via).collect::<Vec<_>>()
        );
        assert!(r.best_speedup() > 10.0, "lu copy speedup {}", r.best_speedup());
    }

    #[test]
    fn linking_gives_runnable_baseline() {
        let c = coord();
        let prog = parser::parse(&apps::fft_app_lib(16)).unwrap();
        // Unlinked: fft2d has no body -> run fails.
        let mut m = crate::interp::Interp::new(&prog).unwrap();
        assert!(m.run("main", &[]).is_err());
        // Linked: runs.
        let linked = c.link_cpu_libraries(&prog).unwrap();
        let mut m = crate::interp::Interp::new(&linked).unwrap();
        let v = m.run("main", &[]).unwrap();
        assert!(v.as_num().unwrap().is_finite());
    }

    #[test]
    fn offloaded_output_matches_cpu_output() {
        let c = coord();
        let r = c.offload(&apps::lu_app_lib(64), "main").unwrap();
        for p in &r.outcome.tried {
            if p.speedup > 1.0 {
                assert!(p.output_ok, "winning pattern produced wrong output: {}", p.label);
            }
        }
    }

    #[test]
    fn report_renders() {
        let c = coord();
        let r = c.offload(&apps::matmul_app(64), "main").unwrap();
        let text = c.render_report(&r);
        assert!(text.contains("function-block offload report"));
        assert!(text.contains("speedup"));
        assert!(text.contains("backend arbitration"), "{text}");
        assert!(text.contains("chosen backend:"), "{text}");
        // matmul has no registered IP core: never FPGA.
        assert_ne!(r.backend(), Backend::Fpga);
    }

    #[test]
    fn resident_budget_attaches_the_residency_residue_and_elides_traffic() {
        let mut c = coord();
        c.resident_bytes = 64 << 20;
        let r = c.offload(&apps::sensor_fusion_app(64), "main").unwrap();
        let res = r.arbitration.residency.as_ref().expect("nonzero budget must attach residue");
        assert_eq!(res.budget_bytes, 64 << 20);
        assert_eq!(res.blocks.len(), r.blocks.iter().filter(|b| b.accepted()).count());
        // fft2d's spectrum feeds matmul and every rep re-touches the same
        // frames: the plane must elide transfers somewhere.
        let elided: u64 = res.blocks.iter().map(|b| b.elided_in + b.elided_out).sum();
        assert!(elided > 0, "residency elided no bytes: {res:?}");
        assert!(res.total_saved_transfer_secs > 0.0);
        let text = c.render_report(&r);
        assert!(text.contains("device residency"), "{text}");
        assert!(text.contains("total transfer credit"), "{text}");
        // Off by default: no residue, no section.
        let c0 = coord();
        let r0 = c0.offload(&apps::sensor_fusion_app(64), "main").unwrap();
        assert!(r0.arbitration.residency.is_none());
        assert!(!c0.render_report(&r0).contains("device residency"));
    }

    #[test]
    fn zero_budget_is_passive_even_on_an_engine_warmed_by_a_resident_run() {
        // PRs 5–9 discipline: the feature off must be byte-identical to a
        // build without it. Measured medians are wall-clock and so not
        // comparable across runs, but every byte *count* is deterministic
        // — compare those, plus the decisions.
        let mut c = coord();
        c.resident_bytes = 16 << 20;
        let _warm = c.offload(&apps::sensor_fusion_app(64), "main").unwrap();
        assert!(c.engine.data_plane().is_some(), "resident run installs the plane");
        c.resident_bytes = 0;
        let off = c.offload(&apps::sensor_fusion_app(64), "main").unwrap();
        assert!(c.engine.data_plane().is_none(), "zero budget uninstalls the plane");
        assert!(off.arbitration.residency.is_none());

        let fresh = coord().offload(&apps::sensor_fusion_app(64), "main").unwrap();
        assert_eq!(off.outcome.best_enabled, fresh.outcome.best_enabled);
        assert_eq!(off.outcome.tried.len(), fresh.outcome.tried.len());
        for (a, b) in off.outcome.tried.iter().zip(&fresh.outcome.tried) {
            assert_eq!(a.label, b.label);
            assert_eq!((a.traffic.elided_in, a.traffic.elided_out), (0, 0), "{}", a.label);
            assert_eq!(a.traffic.bytes_in, b.traffic.bytes_in, "{}", a.label);
            assert_eq!(a.traffic.bytes_out, b.traffic.bytes_out, "{}", a.label);
            assert_eq!(a.traffic.dispatches, b.traffic.dispatches, "{}", a.label);
        }
        for (a, b) in off.arbitration.blocks.iter().zip(&fresh.arbitration.blocks) {
            assert_eq!(a.backend, b.backend, "{}", a.label);
        }
    }

    #[test]
    fn strict_policy_rejects_mismatched_interfaces_but_exact_ones_pass() {
        let mut c = coord();
        c.policy = InterfacePolicy::AutoReject;
        // Exact-interface library path still works under strict policy.
        let r = c.offload(&apps::lu_app_lib(64), "main").unwrap();
        assert!(r.best_speedup() > 1.0);
    }
}
