//! Staged pipeline API: typed stage artifacts behind a builder facade.
//!
//! The paper's Fig. 2 flow is explicitly staged — Step 1 analysis, Step 2
//! discovery, C-1/C-2 reconciliation, Step 3 measured search, Step 3b
//! arbitration, Steps 4–7 placement — and its companion proposal paper
//! (arXiv:2004.09883) frames each step as an independently re-runnable
//! phase of environment-adaptive software. This module makes that shape
//! the public API:
//!
//! * [`OffloadRequest`] — a builder carrying the source, entry point, and
//!   every policy/handle the pipeline needs (pattern DB, PJRT engine,
//!   interface policy, verification settings, backend target, FPGA device
//!   model).
//! * Typed stage artifacts — [`Parsed`] → [`Discovered`] → [`Reconciled`]
//!   → [`Estimated`] → [`Verified`] → [`PowerScored`] → [`Arbitrated`] →
//!   [`Placed`]. Each is a plain value
//!   you can inspect, serialize ([`Parsed::to_json_string`] etc.), and
//!   resume from ([`Parsed::from_json_str`] etc.): deserialize a stage on
//!   another process — or under a different policy — and advance it from
//!   there. The service tier uses exactly this to cache per-stage results
//!   (see `service::pool`), and `examples/staged_pipeline.rs` shows the
//!   inspect-and-resume loop.
//! * [`OffloadError`] — a structured error at the public boundary: one
//!   variant per stage, each carrying the last good artifact, so a caller
//!   that fails in Step 3 still holds the reconciled blocks of Steps 1–2.
//! * [`StageObserver`] — a per-stage completion hook; the service pool
//!   installs one to keep per-stage latency counters.
//!
//! [`super::Coordinator::offload`] is a thin compatibility wrapper that
//! builds a request and runs every stage.
//!
//! Design note: stage methods take `&self` and each artifact owns its
//! predecessor by value. That costs a clone per transition (and one DB
//! clone per request) — deliberately: every stage is dwarfed by the
//! measured Step-3 verification, and `&self` is what lets one artifact
//! be advanced repeatedly (arbitrate the same [`Verified`] under several
//! targets) without re-deserializing.

use std::fmt;
use std::rc::Rc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::analysis;
use crate::fpga;
use crate::parser::{self, Item, Program};
use crate::patterndb::json::{self, Json};
use crate::patterndb::{
    repl_from_json, repl_to_json, sig_from_json, sig_to_json, PatternDb, Replacement, Signature,
};
use crate::runtime::Engine;
use crate::similarity;
use crate::telemetry::TraceEvent;
use crate::transform::{self, reconcile, signature_of, InterfacePolicy, PlannedReplacement, Site};

use super::backend::{self, Backend, BackendPolicy};
use super::estimate::{self, EstimateOutcome, PrunePolicy};
use super::flow;
use super::power::{self, PowerModel, PowerPolicy};
use super::profile::ProfileRegistry;
use super::report_json;
use super::residency;
use super::verify::{self, PatternExecutor, SearchOutcome, SerialExecutor, VerifyConfig};
use super::{Coordinator, DiscoveredBlock, DiscoveryPath, OffloadReport};

// ---------------------------------------------------------------- stages

/// The pipeline stages, in execution order (paper Fig. 2 / Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Step 1: parse the application source (and canonicalize it).
    Parse,
    /// Step 2: discover offloadable blocks (A-1/B-1 name match, A-2/B-2
    /// similarity).
    Discover,
    /// C-1/C-2: reconcile block interfaces under the interface policy.
    Reconcile,
    /// Analytic estimation: score every accepted candidate against the
    /// device-profile registry before anything is measured
    /// (arXiv:2004.09883's suitability narrowing).
    Estimate,
    /// Step 3: measured pattern search in the verification environment.
    Verify,
    /// Power scoring: energy/performance-per-watt of every surviving
    /// measured pattern under the wattage models (arXiv:2110.11520).
    PowerScore,
    /// Step 3b: CPU/GPU/FPGA backend arbitration.
    Arbitrate,
    /// Steps 4–5: resource sizing + placement.
    Place,
}

impl Stage {
    /// Every stage, in execution order.
    pub const ALL: [Stage; 8] = [
        Stage::Parse,
        Stage::Discover,
        Stage::Reconcile,
        Stage::Estimate,
        Stage::Verify,
        Stage::PowerScore,
        Stage::Arbitrate,
        Stage::Place,
    ];

    /// Canonical lowercase name (CLI and counters).
    pub fn as_str(self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::Discover => "discover",
            Stage::Reconcile => "reconcile",
            Stage::Estimate => "estimate",
            Stage::Verify => "verify",
            Stage::PowerScore => "power-score",
            Stage::Arbitrate => "arbitrate",
            Stage::Place => "place",
        }
    }

    /// Inverse of [`Stage::as_str`] (trace decoding and CLI).
    pub fn parse(s: &str) -> Result<Stage> {
        Stage::ALL
            .into_iter()
            .find(|stage| stage.as_str() == s)
            .ok_or_else(|| anyhow::anyhow!("unknown stage {s:?}"))
    }

    /// Position in [`Stage::ALL`] (stable index for per-stage counters).
    pub fn index(self) -> usize {
        match self {
            Stage::Parse => 0,
            Stage::Discover => 1,
            Stage::Reconcile => 2,
            Stage::Estimate => 3,
            Stage::Verify => 4,
            Stage::PowerScore => 5,
            Stage::Arbitrate => 6,
            Stage::Place => 7,
        }
    }
}

/// Hook called as pipeline stages complete — the service pool installs one
/// to keep per-stage latency counters; embedders can trace or log.
pub trait StageObserver: Send + Sync {
    /// One stage finished successfully after `wall` of work.
    fn stage_completed(&self, stage: Stage, wall: Duration);

    /// One structured telemetry event fired from inside a stage (pattern
    /// measurements, power scores, arbitration verdicts). Default: ignore
    /// — observers that only track stage latency need not care, and the
    /// pipeline builds the events only when an observer is installed.
    fn stage_event(&self, event: &TraceEvent) {
        let _ = event;
    }
}

// ---------------------------------------------------------------- errors

/// Structured pipeline error: which stage failed, why, and the last good
/// stage artifact (so partial progress is never thrown away at the public
/// boundary).
#[derive(Debug)]
pub enum OffloadError {
    /// Step 1 failed: the source did not parse, or the entry point is not
    /// defined in it.
    Parse {
        /// Entry point the request named.
        entry: String,
        /// What went wrong.
        message: String,
    },
    /// Step 2 discovery failed; the parsed artifact survives.
    Discovery {
        /// The successful Step-1 artifact.
        parsed: Box<Parsed>,
        /// What went wrong.
        message: String,
    },
    /// C-1/C-2 reconciliation failed; the discovery artifact survives.
    /// Currently reserved: the built-in [`InterfacePolicy`] answers are
    /// infallible, so [`Discovered::reconcile`] never produces this —
    /// it exists so an interactive/remote confirmation policy can fail
    /// without changing the public error shape.
    Reconcile {
        /// The successful Step-2 artifact.
        discovered: Box<Discovered>,
        /// What went wrong.
        message: String,
    },
    /// Analytic estimation failed (an invalid profile registry); the
    /// reconciled artifact — and through it the discovery — survives. The
    /// built-in registry is always valid: this fires only for
    /// caller-supplied `--device-profile` registries.
    Estimating {
        /// The successful reconciliation artifact.
        reconciled: Box<Reconciled>,
        /// What went wrong.
        message: String,
    },
    /// Step 3 verification failed; the reconciled artifact survives.
    Verify {
        /// The successful reconciliation artifact.
        reconciled: Box<Reconciled>,
        /// What went wrong.
        message: String,
    },
    /// Power scoring failed (an invalid wattage model); the verified
    /// artifact survives. The built-in model is always valid — this fires
    /// only for caller-supplied models.
    PowerScoring {
        /// The successful Step-3 artifact.
        verified: Box<Verified>,
        /// What went wrong.
        message: String,
    },
    /// Step 3b arbitration failed; the verified artifact survives (the
    /// power scores are derived from it in microseconds, so the variant
    /// carries the measured artifact rather than the scored wrapper).
    Arbitrate {
        /// The successful Step-3 artifact.
        verified: Box<Verified>,
        /// What went wrong.
        message: String,
    },
    /// Steps 4–5 placement failed; the arbitrated artifact survives.
    Placement {
        /// The successful Step-3b artifact.
        arbitrated: Box<Arbitrated>,
        /// What went wrong.
        message: String,
    },
}

impl OffloadError {
    /// The stage that failed.
    pub fn stage(&self) -> Stage {
        match self {
            OffloadError::Parse { .. } => Stage::Parse,
            OffloadError::Discovery { .. } => Stage::Discover,
            OffloadError::Reconcile { .. } => Stage::Reconcile,
            OffloadError::Estimating { .. } => Stage::Estimate,
            OffloadError::Verify { .. } => Stage::Verify,
            OffloadError::PowerScoring { .. } => Stage::PowerScore,
            OffloadError::Arbitrate { .. } => Stage::Arbitrate,
            OffloadError::Placement { .. } => Stage::Place,
        }
    }

    /// The underlying failure message.
    pub fn message(&self) -> &str {
        match self {
            OffloadError::Parse { message, .. }
            | OffloadError::Discovery { message, .. }
            | OffloadError::Reconcile { message, .. }
            | OffloadError::Estimating { message, .. }
            | OffloadError::Verify { message, .. }
            | OffloadError::PowerScoring { message, .. }
            | OffloadError::Arbitrate { message, .. }
            | OffloadError::Placement { message, .. } => message,
        }
    }
}

impl fmt::Display for OffloadError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "offload {} stage failed: {}", self.stage().as_str(), self.message())
    }
}

impl std::error::Error for OffloadError {}

// --------------------------------------------------------------- request

/// Builder facade for one offload run: the source, the entry point, and
/// every policy/handle the stages consume. Construct one with
/// [`Coordinator::request`], tweak it with the `with_*` methods, then
/// either [`OffloadRequest::run`] all stages or advance artifact by
/// artifact.
///
/// ```no_run
/// use fbo::coordinator::{BackendPolicy, Coordinator};
///
/// # fn main() -> anyhow::Result<()> {
/// let coordinator = Coordinator::open(std::path::Path::new("artifacts"))?;
/// let request = coordinator
///     .request("void ludcmp(double a[], int n);\
///               int main() { double a[4]; ludcmp(a, 2); return 0; }", "main")
///     .with_target(BackendPolicy::Auto);
///
/// // Stage by stage: every artifact is a value to inspect and serialize.
/// let parsed = request.parse()?;
/// let verified = parsed.discover(&request)?.reconcile(&request)?.verify(&request)?;
/// println!("{} patterns measured", verified.outcome.tried.len());
///
/// let report = verified.arbitrate(&request)?.report();
/// println!("best speedup {} on {}", report.best_speedup(), report.backend().as_str());
/// # Ok(())
/// # }
/// ```
pub struct OffloadRequest {
    src: String,
    entry: String,
    /// Code-pattern DB (libraries, comparison code, FPGA IP cores).
    pub db: PatternDb,
    /// PJRT engine executing the AOT artifacts during verification.
    pub engine: Rc<Engine>,
    /// Interface-reconciliation policy (C-1/C-2 confirmations).
    pub policy: InterfacePolicy,
    /// Deckard-style similarity threshold for copied-code discovery.
    pub similarity_threshold: f64,
    /// Verification-measurement settings (Step 3).
    pub verify: VerifyConfig,
    /// Which backends Step-3b arbitration may choose (CLI `--target`).
    pub backend_policy: BackendPolicy,
    /// FPGA device model the arbitration evaluates IP cores against.
    pub device: fpga::Device,
    /// How arbitration weighs power (CLI `--power-policy`).
    pub power_policy: PowerPolicy,
    /// Per-device wattage models the power stage scores against,
    /// registered alongside the FPGA device model.
    pub power_model: PowerModel,
    /// Device-profile registry the estimate stage scores candidates
    /// against (CLI `--device-profile`).
    pub profiles: ProfileRegistry,
    /// How the estimate prunes candidates before measurement
    /// (CLI `--prune-policy`).
    pub prune_policy: PrunePolicy,
    /// Resident-set byte budget for the device data plane (CLI
    /// `--resident-bytes`). `0` (the default) keeps residency off and the
    /// pipeline byte-identical to the pre-residency one; a nonzero budget
    /// installs a [`crate::runtime::DataPlane`] on the engine before
    /// Step 3 and attaches the v5 residency residue to arbitration.
    pub resident_bytes: u64,
    observer: Option<Arc<dyn StageObserver>>,
    executor: Option<Rc<dyn PatternExecutor>>,
}

/// True when the estimator configuration is the inert default: estimates
/// are computed and traced, but nothing downstream — pruning, fleet cost
/// hints, report residue, cache fingerprints — may depend on them.
/// Decisions and bytes must match a pipeline without the stage.
pub(crate) fn estimate_is_default(req: &OffloadRequest) -> bool {
    req.prune_policy.is_default() && req.profiles == ProfileRegistry::builtin()
}

impl OffloadRequest {
    /// Build a request from a coordinator's handles + policies.
    pub(super) fn from_coordinator(c: &Coordinator, src: &str, entry: &str) -> OffloadRequest {
        OffloadRequest {
            src: src.to_string(),
            entry: entry.to_string(),
            db: c.db.clone(),
            engine: c.engine.clone(),
            policy: c.policy.clone(),
            similarity_threshold: c.similarity_threshold,
            verify: c.verify.clone(),
            backend_policy: c.backend_policy,
            device: c.device,
            power_policy: c.power_policy,
            power_model: c.power_model.clone(),
            profiles: c.profiles.clone(),
            prune_policy: c.prune_policy,
            resident_bytes: c.resident_bytes,
            observer: None,
            executor: c.executor.clone(),
        }
    }

    /// The raw application source this request offloads.
    pub fn src(&self) -> &str {
        &self.src
    }

    /// The entry-point function name.
    pub fn entry(&self) -> &str {
        &self.entry
    }

    /// Override the interface-reconciliation policy.
    pub fn with_interface_policy(mut self, policy: InterfacePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Override the verification settings.
    pub fn with_verify(mut self, verify: VerifyConfig) -> Self {
        self.verify = verify;
        self
    }

    /// Override the similarity threshold for copied-code discovery.
    pub fn with_similarity_threshold(mut self, threshold: f64) -> Self {
        self.similarity_threshold = threshold;
        self
    }

    /// Override the backend-arbitration target (CLI `--target`).
    pub fn with_target(mut self, policy: BackendPolicy) -> Self {
        self.backend_policy = policy;
        self
    }

    /// Override the FPGA device model.
    pub fn with_device(mut self, device: fpga::Device) -> Self {
        self.device = device;
        self
    }

    /// Override the power policy arbitration weighs backends under
    /// (CLI `--power-policy`).
    pub fn with_power_policy(mut self, policy: PowerPolicy) -> Self {
        self.power_policy = policy;
        self
    }

    /// Override the per-device wattage models.
    pub fn with_power_model(mut self, model: PowerModel) -> Self {
        self.power_model = model;
        self
    }

    /// Override the device-profile registry the estimate stage scores
    /// against (CLI `--device-profile`).
    pub fn with_profiles(mut self, profiles: ProfileRegistry) -> Self {
        self.profiles = profiles;
        self
    }

    /// Override the pruning policy the estimate applies to the verify
    /// plan (CLI `--prune-policy`).
    pub fn with_prune_policy(mut self, policy: PrunePolicy) -> Self {
        self.prune_policy = policy;
        self
    }

    /// Override the resident-set byte budget of the device data plane
    /// (CLI `--resident-bytes`). `0` keeps residency off.
    pub fn with_resident_bytes(mut self, budget: u64) -> Self {
        self.resident_bytes = budget;
        self
    }

    /// Install a per-stage completion observer.
    pub fn with_observer(mut self, observer: Arc<dyn StageObserver>) -> Self {
        self.observer = Some(observer);
        self
    }

    /// Install the [`PatternExecutor`] the Verify stage measures patterns
    /// with. Defaults to a [`SerialExecutor`] over the request's engine
    /// (the paper's serial Step 3); the service tier installs its pooled
    /// executor here to fan independent patterns across idle sibling
    /// engines. The executor affects only *how fast* the measurements run
    /// — the reduced [`SearchOutcome`] is identical either way.
    pub fn with_executor(mut self, executor: Rc<dyn PatternExecutor>) -> Self {
        self.executor = Some(executor);
        self
    }

    fn observe(&self, stage: Stage, wall: Duration) {
        if let Some(o) = &self.observer {
            o.stage_completed(stage, wall);
        }
    }

    /// Feed structured telemetry events to the observer. Takes a closure
    /// so untraced runs never build the event vector at all — telemetry
    /// is strictly passive and must cost nothing when off.
    fn observe_events(&self, events: impl FnOnce() -> Vec<TraceEvent>) {
        if let Some(o) = &self.observer {
            for event in events() {
                o.stage_event(&event);
            }
        }
    }

    /// Stage 1: parse the source and canonicalize it. Fails when the
    /// source does not parse or the entry point is not defined.
    pub fn parse(&self) -> std::result::Result<Parsed, OffloadError> {
        let t0 = Instant::now();
        let parse_err = |message: String| OffloadError::Parse {
            entry: self.entry.clone(),
            message,
        };
        let program = parser::parse(&self.src)
            .map_err(|e| parse_err(format!("Step 1: parsing application source: {e:#}")))?;
        if program.find_function(&self.entry).is_none() {
            return Err(parse_err(format!(
                "entry function {:?} is not defined in the source",
                self.entry
            )));
        }
        let source = parser::print_program(&program);
        let wall = t0.elapsed();
        self.observe(Stage::Parse, wall);
        Ok(Parsed { entry: self.entry.clone(), source, program, wall })
    }

    /// Run every stage through arbitration and assemble the report —
    /// what [`Coordinator::offload`] wraps.
    pub fn run(&self) -> std::result::Result<OffloadReport, OffloadError> {
        Ok(self
            .parse()?
            .discover(self)?
            .reconcile(self)?
            .estimate(self)?
            .verify(self)?
            .arbitrate(self)?
            .report())
    }
}

// ------------------------------------------------------------- artifacts

/// Format tag of a serialized [`Parsed`] artifact.
pub const STAGE_PARSED_FORMAT: &str = "fbo-stage-parsed-v1";
/// Format tag of a serialized [`Discovered`] artifact.
pub const STAGE_DISCOVERED_FORMAT: &str = "fbo-stage-discovered-v1";
/// Format tag of a serialized [`Reconciled`] artifact.
pub const STAGE_RECONCILED_FORMAT: &str = "fbo-stage-reconciled-v1";
/// Format tag of a serialized [`Estimated`] artifact.
pub const STAGE_ESTIMATED_FORMAT: &str = "fbo-stage-estimated-v1";
/// Format tag of a serialized [`Verified`] artifact.
pub const STAGE_VERIFIED_FORMAT: &str = "fbo-stage-verified-v1";
/// Format tag of a serialized [`PowerScored`] artifact.
pub const STAGE_POWER_SCORED_FORMAT: &str = "fbo-stage-power-scored-v1";
/// Format tag of a serialized [`Arbitrated`] artifact.
pub const STAGE_ARBITRATED_FORMAT: &str = "fbo-stage-arbitrated-v1";
/// Format tag of a serialized [`Placed`] artifact.
pub const STAGE_PLACED_FORMAT: &str = "fbo-stage-placed-v1";

fn check_format(v: &Json, want: &str) -> Result<()> {
    let format = v.get("format")?.as_str()?;
    if format != want {
        bail!("unsupported stage artifact format {format:?} (want {want:?})");
    }
    Ok(())
}

/// Stage-1 artifact: the parsed (and canonically re-printed) program.
#[derive(Debug, Clone)]
pub struct Parsed {
    /// Entry-point function name.
    pub entry: String,
    /// Canonically re-printed source — whitespace- and comment-free, the
    /// same form the service's cache keys hash.
    pub source: String,
    /// The parsed program (re-parsed from `source` when decoding).
    pub program: Program,
    /// Wall-clock this stage took.
    pub wall: Duration,
}

impl Parsed {
    /// Stage 2: discover offloadable blocks (A-1/B-1 library-name match,
    /// A-2/B-2 similarity over defined functions).
    pub fn discover(&self, req: &OffloadRequest) -> std::result::Result<Discovered, OffloadError> {
        let t0 = Instant::now();
        let a = analysis::analyze(&self.program);
        let external_callees = a.external_callees();
        let candidates = discover_candidates(
            &req.db,
            req.similarity_threshold,
            &self.program,
            &external_callees,
        )
        .map_err(|e| OffloadError::Discovery {
            parsed: Box::new(self.clone()),
            message: format!("{e:#}"),
        })?;
        let wall = t0.elapsed();
        req.observe(Stage::Discover, wall);
        Ok(Discovered { parsed: self.clone(), external_callees, candidates, wall })
    }

    /// Serialize to the canonical JSON value.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str(STAGE_PARSED_FORMAT)),
            ("entry", Json::str(&self.entry)),
            ("source", Json::str(&self.source)),
            ("wall_ns", report_json::duration_to_json(self.wall)),
        ])
    }

    /// Decode from a JSON value (re-parses the canonical source).
    pub fn from_json(v: &Json) -> Result<Parsed> {
        check_format(v, STAGE_PARSED_FORMAT)?;
        let source = v.get("source")?.as_str()?.to_string();
        let program = parser::parse(&source)
            .context("re-parsing the canonical source of a Parsed artifact")?;
        Ok(Parsed {
            entry: v.get("entry")?.as_str()?.to_string(),
            source,
            program,
            wall: report_json::duration_from_json(v.get("wall_ns")?)?,
        })
    }

    /// Serialize to the canonical pretty-printed string.
    pub fn to_json_string(&self) -> String {
        json::to_string_pretty(&self.to_json())
    }

    /// Decode from the string form.
    pub fn from_json_str(s: &str) -> Result<Parsed> {
        Self::from_json(&json::parse(s)?)
    }
}

/// One discovered offload candidate, before interface reconciliation.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Discovery provenance (A-1/B-1 name match or A-2/B-2 similarity).
    pub via: DiscoveryPath,
    /// Where the block lives.
    pub site: Site,
    /// The accelerator implementation the DB registers for it.
    pub replacement: Replacement,
    /// The caller-side interface reconciliation will compare against.
    pub caller_signature: Signature,
}

fn candidate_to_json(c: &Candidate) -> Json {
    Json::obj(vec![
        ("via", report_json::via_to_json(&c.via)),
        ("site", report_json::site_to_json(&c.site)),
        ("replacement", repl_to_json(&c.replacement)),
        ("caller_signature", sig_to_json(&c.caller_signature)),
    ])
}

fn candidate_from_json(v: &Json) -> Result<Candidate> {
    Ok(Candidate {
        via: report_json::via_from_json(v.get("via")?)?,
        site: report_json::site_from_json(v.get("site")?)?,
        replacement: repl_from_json(v.get("replacement")?)?,
        caller_signature: sig_from_json(v.get("caller_signature")?)?,
    })
}

/// Stage-2 artifact: discovered candidates plus the analysis facts the
/// report carries forward.
#[derive(Debug, Clone)]
pub struct Discovered {
    /// The Step-1 artifact this stage advanced from.
    pub parsed: Parsed,
    /// Distinct external callee names found by Step-1 analysis.
    pub external_callees: Vec<String>,
    /// Offload candidates, library-path entries first.
    pub candidates: Vec<Candidate>,
    /// Wall-clock this stage took.
    pub wall: Duration,
}

impl Discovered {
    /// C-1/C-2: reconcile every candidate's interface under the request's
    /// interface policy. With the built-in policies this cannot fail; the
    /// `Result` (and [`OffloadError::Reconcile`]) keep the stage signature
    /// uniform for policies that ask an external confirmer.
    pub fn reconcile(&self, req: &OffloadRequest) -> std::result::Result<Reconciled, OffloadError> {
        let t0 = Instant::now();
        let blocks = reconcile_candidates(&self.candidates, &req.policy);
        let wall = t0.elapsed();
        req.observe(Stage::Reconcile, wall);
        Ok(Reconciled { discovered: self.clone(), blocks, wall })
    }

    /// Serialize to the canonical JSON value.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str(STAGE_DISCOVERED_FORMAT)),
            ("parsed", self.parsed.to_json()),
            (
                "external_callees",
                Json::Arr(self.external_callees.iter().map(Json::str).collect()),
            ),
            (
                "candidates",
                Json::Arr(self.candidates.iter().map(candidate_to_json).collect()),
            ),
            ("wall_ns", report_json::duration_to_json(self.wall)),
        ])
    }

    /// Decode from a JSON value.
    pub fn from_json(v: &Json) -> Result<Discovered> {
        check_format(v, STAGE_DISCOVERED_FORMAT)?;
        Ok(Discovered {
            parsed: Parsed::from_json(v.get("parsed")?)?,
            external_callees: v
                .get("external_callees")?
                .as_arr()?
                .iter()
                .map(|s| Ok(s.as_str()?.to_string()))
                .collect::<Result<_>>()?,
            candidates: v
                .get("candidates")?
                .as_arr()?
                .iter()
                .map(candidate_from_json)
                .collect::<Result<_>>()?,
            wall: report_json::duration_from_json(v.get("wall_ns")?)?,
        })
    }

    /// Serialize to the canonical pretty-printed string.
    pub fn to_json_string(&self) -> String {
        json::to_string_pretty(&self.to_json())
    }

    /// Decode from the string form.
    pub fn from_json_str(s: &str) -> Result<Discovered> {
        Self::from_json(&json::parse(s)?)
    }
}

/// Stage-C artifact: every candidate with its interface reconciliation.
#[derive(Debug, Clone)]
pub struct Reconciled {
    /// The Step-2 artifact this stage advanced from.
    pub discovered: Discovered,
    /// Every discovered block with its reconciliation outcome, aligned
    /// with the candidate order.
    pub blocks: Vec<DiscoveredBlock>,
    /// Wall-clock this stage took.
    pub wall: Duration,
}

impl Reconciled {
    /// The accepted replacement plans, in block order — the slice Step 3
    /// searches over and Step 3b arbitrates.
    pub fn accepted(&self) -> Vec<PlannedReplacement> {
        self.blocks.iter().filter(|b| b.accepted()).map(|b| b.plan.clone()).collect()
    }

    /// Analytic estimation: score every accepted candidate against the
    /// request's device-profile registry before anything is measured
    /// (arXiv:2004.09883's offload-suitability narrowing). Infallible with
    /// the built-in registry; a caller-supplied `--device-profile`
    /// registry that fails validation errors here, carrying this artifact.
    pub fn estimate(&self, req: &OffloadRequest) -> std::result::Result<Estimated, OffloadError> {
        let t0 = Instant::now();
        let accepted = self.accepted();
        let estimates = estimate::score(&req.db, &accepted, &req.profiles, req.prune_policy)
            .map_err(|e| OffloadError::Estimating {
                reconciled: Box::new(self.clone()),
                message: format!("{e:#}"),
            })?;
        let wall = t0.elapsed();
        req.observe_events(|| estimate::estimator_events(&estimates));
        req.observe(Stage::Estimate, wall);
        Ok(Estimated { reconciled: self.clone(), estimates, wall })
    }

    /// Step 3 via the estimate stage: [`Reconciled::estimate`] always runs
    /// first (the analytic stage is part of the pipeline proper), then the
    /// measured search. Drive [`Reconciled::estimate`] explicitly to
    /// inspect or serialize the intermediate artifact.
    pub fn verify(&self, req: &OffloadRequest) -> std::result::Result<Verified, OffloadError> {
        self.estimate(req)?.verify(req)
    }

    /// Serialize to the canonical JSON value.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str(STAGE_RECONCILED_FORMAT)),
            ("discovered", self.discovered.to_json()),
            (
                "blocks",
                Json::Arr(self.blocks.iter().map(report_json::block_to_json).collect()),
            ),
            ("wall_ns", report_json::duration_to_json(self.wall)),
        ])
    }

    /// Decode from a JSON value.
    pub fn from_json(v: &Json) -> Result<Reconciled> {
        check_format(v, STAGE_RECONCILED_FORMAT)?;
        Ok(Reconciled {
            discovered: Discovered::from_json(v.get("discovered")?)?,
            blocks: v
                .get("blocks")?
                .as_arr()?
                .iter()
                .map(report_json::block_from_json)
                .collect::<Result<_>>()?,
            wall: report_json::duration_from_json(v.get("wall_ns")?)?,
        })
    }

    /// Serialize to the canonical pretty-printed string.
    pub fn to_json_string(&self) -> String {
        json::to_string_pretty(&self.to_json())
    }

    /// Decode from the string form.
    pub fn from_json_str(s: &str) -> Result<Reconciled> {
        Self::from_json(&json::parse(s)?)
    }
}

/// Estimate-stage artifact: every accepted candidate scored analytically
/// against the device-profile registry, between [`Reconciled`] and
/// [`Verified`]. Nothing here touched hardware — the estimates come from
/// the roofline/streaming models in [`super::estimate`] — which is exactly
/// why the stage is cheap enough to always run: its scores gate the
/// measured search only under a non-default `--prune-policy` or
/// `--device-profile`.
#[derive(Debug, Clone)]
pub struct Estimated {
    /// The reconciliation artifact this stage advanced from.
    pub reconciled: Reconciled,
    /// Analytic per-block estimates under the request's registry.
    pub estimates: EstimateOutcome,
    /// Wall-clock this stage took.
    pub wall: Duration,
}

impl Estimated {
    /// Step 3: link CPU library bodies, then run the measured pattern
    /// search — consuming the estimate (prune mask + fleet cost hints)
    /// only when the estimator configuration is non-default. Under the
    /// default configuration the search, its outcome, and the resulting
    /// [`Verified`] bytes are identical to a pipeline without this stage.
    pub fn verify(&self, req: &OffloadRequest) -> std::result::Result<Verified, OffloadError> {
        let t0 = Instant::now();
        let default_estimate = estimate_is_default(req);
        if req.resident_bytes > 0 {
            // Install (or re-budget) the device data plane before any
            // measurement. Reinstalling only on a budget change keeps the
            // resident set warm across service requests on the same
            // engine — the whole point of pinning hot inputs.
            let budget_differs = req
                .engine
                .data_plane()
                .map_or(true, |p| p.budget() != req.resident_bytes);
            if budget_differs {
                let plane = Rc::new(crate::runtime::DataPlane::new(req.resident_bytes));
                req.engine.install_data_plane(plane);
            }
        } else if req.engine.data_plane().is_some() {
            // Passivity: a zero-budget request on an engine warmed by a
            // resident one must measure the exact pre-residency traffic.
            req.engine.uninstall_data_plane();
        }
        let search = || -> Result<SearchOutcome> {
            let linked = link_cpu_libraries(&req.db, &self.reconciled.discovered.parsed.program)?;
            let accepted = self.reconciled.accepted();
            // The request's executor decides how the independent pattern
            // measurements run (serial on this engine, or fanned out by
            // the service pool) — never what the outcome is.
            let serial;
            let executor: &dyn PatternExecutor = match &req.executor {
                Some(e) => e.as_ref(),
                None => {
                    serial = SerialExecutor::new(req.engine.clone());
                    &serial
                }
            };
            let (hints, pruned) = if default_estimate {
                (Vec::new(), Vec::new())
            } else {
                (self.estimates.cost_hints(), self.estimates.prune_mask())
            };
            verify::search_patterns_full(
                &linked,
                &self.reconciled.discovered.parsed.entry,
                &accepted,
                &req.verify,
                executor,
                &hints,
                &pruned,
            )
        };
        let outcome = search().map_err(|e| OffloadError::Verify {
            reconciled: Box::new(self.reconciled.clone()),
            message: format!("{e:#}"),
        })?;
        let wall = t0.elapsed();
        req.observe_events(|| verify::measurement_events(&outcome));
        req.observe(Stage::Verify, wall);
        Ok(Verified {
            reconciled: self.reconciled.clone(),
            outcome,
            estimates: (!default_estimate).then(|| self.estimates.clone()),
            wall,
        })
    }

    /// Serialize to the canonical JSON value.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str(STAGE_ESTIMATED_FORMAT)),
            ("reconciled", self.reconciled.to_json()),
            ("estimates", estimate::outcome_to_json(&self.estimates)),
            ("wall_ns", report_json::duration_to_json(self.wall)),
        ])
    }

    /// Decode from a JSON value.
    pub fn from_json(v: &Json) -> Result<Estimated> {
        check_format(v, STAGE_ESTIMATED_FORMAT)?;
        Ok(Estimated {
            reconciled: Reconciled::from_json(v.get("reconciled")?)?,
            estimates: estimate::outcome_from_json(v.get("estimates")?)?,
            wall: report_json::duration_from_json(v.get("wall_ns")?)?,
        })
    }

    /// Serialize to the canonical pretty-printed string.
    pub fn to_json_string(&self) -> String {
        json::to_string_pretty(&self.to_json())
    }

    /// Decode from the string form.
    pub fn from_json_str(s: &str) -> Result<Estimated> {
        Self::from_json(&json::parse(s)?)
    }
}

/// Stage-3 artifact: the measured pattern-search outcome.
#[derive(Debug, Clone)]
pub struct Verified {
    /// The reconciliation artifact this stage advanced from.
    pub reconciled: Reconciled,
    /// Step-3 measured pattern-search outcome.
    pub outcome: SearchOutcome,
    /// The analytic estimates the search consumed — `Some` only under a
    /// non-default estimator configuration (so default-path bytes are
    /// unchanged), carried forward for the v4 report's
    /// predicted-vs-measured residue.
    pub estimates: Option<EstimateOutcome>,
    /// Wall-clock this stage took.
    pub wall: Duration,
}

impl Verified {
    /// Validate the wattage model, score the outcome, and report the
    /// stage to the observer — shared by [`Verified::power_score`] (which
    /// materializes the artifact) and [`Verified::arbitrate`] (which
    /// scores transiently, avoiding an extra artifact clone).
    fn score_outcome(
        &self,
        req: &OffloadRequest,
    ) -> std::result::Result<(power::PowerOutcome, Duration), OffloadError> {
        let t0 = Instant::now();
        req.power_model.validate().map_err(|e| OffloadError::PowerScoring {
            verified: Box::new(self.clone()),
            message: format!("{e:#}"),
        })?;
        let scores = power::score(&req.power_model, req.power_policy, &self.outcome);
        let wall = t0.elapsed();
        req.observe_events(|| power::power_events(&scores));
        req.observe(Stage::PowerScore, wall);
        Ok((scores, wall))
    }

    /// Power scoring: price every surviving measured pattern in modeled
    /// joules and performance-per-watt under the request's wattage models
    /// (arXiv:2110.11520). Infallible with the built-in model; a
    /// caller-supplied model with non-finite or non-positive wattages
    /// fails here, carrying this artifact.
    pub fn power_score(
        &self,
        req: &OffloadRequest,
    ) -> std::result::Result<PowerScored, OffloadError> {
        let (scores, wall) = self.score_outcome(req)?;
        Ok(PowerScored { verified: self.clone(), scores, wall })
    }

    /// Step 3b through the power stage: score, then arbitrate. Kept as the
    /// one-call path so `Coordinator::offload` (and saved `Verified`
    /// artifacts) advance without naming the intermediate stage; drive
    /// [`Verified::power_score`] explicitly to inspect or serialize it.
    /// Scores transiently, so this path still costs one artifact clone
    /// per call — the same as arbitration before the power stage existed.
    pub fn arbitrate(&self, req: &OffloadRequest) -> std::result::Result<Arbitrated, OffloadError> {
        let (scores, _) = self.score_outcome(req)?;
        arbitrate_scored(self, &scores, req)
    }

    /// Serialize to the canonical JSON value. The `estimates` key is
    /// emitted only when the search consumed a non-default estimate —
    /// default-configuration artifacts stay byte-identical to pipelines
    /// without the estimate stage.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("format", Json::str(STAGE_VERIFIED_FORMAT)),
            ("reconciled", self.reconciled.to_json()),
            ("outcome", report_json::outcome_to_json(&self.outcome)),
            ("wall_ns", report_json::duration_to_json(self.wall)),
        ];
        if let Some(est) = &self.estimates {
            fields.push(("estimates", estimate::outcome_to_json(est)));
        }
        Json::obj(fields)
    }

    /// Decode from a JSON value.
    pub fn from_json(v: &Json) -> Result<Verified> {
        check_format(v, STAGE_VERIFIED_FORMAT)?;
        Ok(Verified {
            reconciled: Reconciled::from_json(v.get("reconciled")?)?,
            outcome: report_json::outcome_from_json(v.get("outcome")?, false)?,
            estimates: v.opt("estimates").map(estimate::outcome_from_json).transpose()?,
            wall: report_json::duration_from_json(v.get("wall_ns")?)?,
        })
    }

    /// Serialize to the canonical pretty-printed string.
    pub fn to_json_string(&self) -> String {
        json::to_string_pretty(&self.to_json())
    }

    /// Decode from the string form.
    pub fn from_json_str(s: &str) -> Result<Verified> {
        Self::from_json(&json::parse(s)?)
    }
}

/// Power-stage artifact: every surviving measured pattern scored on
/// modeled energy and performance-per-watt, between [`Verified`] and
/// [`Arbitrated`]. Like every stage artifact it serializes and resumes:
/// the service caches it under the power-tier fingerprint, so a
/// `--target` change replays the scores and only re-arbitrates, while a
/// `--power-policy` change re-scores from the cached [`Verified`]
/// without re-measuring.
#[derive(Debug, Clone)]
pub struct PowerScored {
    /// The Step-3 artifact this stage advanced from.
    pub verified: Verified,
    /// Energy / performance-per-watt scores of the baseline and every
    /// measured pattern.
    pub scores: power::PowerOutcome,
    /// Wall-clock this stage took.
    pub wall: Duration,
}

impl PowerScored {
    /// Step 3b: arbitrate CPU/GPU/FPGA per block against the measured
    /// search results — weighing time or joules per the power policy the
    /// scores carry — and emit the winning transformed source.
    pub fn arbitrate(&self, req: &OffloadRequest) -> std::result::Result<Arbitrated, OffloadError> {
        arbitrate_scored(&self.verified, &self.scores, req)
    }

    /// Serialize to the canonical JSON value.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str(STAGE_POWER_SCORED_FORMAT)),
            ("verified", self.verified.to_json()),
            ("scores", power::outcome_to_json(&self.scores)),
            ("wall_ns", report_json::duration_to_json(self.wall)),
        ])
    }

    /// Decode from a JSON value.
    pub fn from_json(v: &Json) -> Result<PowerScored> {
        check_format(v, STAGE_POWER_SCORED_FORMAT)?;
        Ok(PowerScored {
            verified: Verified::from_json(v.get("verified")?)?,
            scores: power::outcome_from_json(v.get("scores")?)?,
            wall: report_json::duration_from_json(v.get("wall_ns")?)?,
        })
    }

    /// Serialize to the canonical pretty-printed string.
    pub fn to_json_string(&self) -> String {
        json::to_string_pretty(&self.to_json())
    }

    /// Decode from the string form.
    pub fn from_json_str(s: &str) -> Result<PowerScored> {
        Self::from_json(&json::parse(s)?)
    }
}

/// The shared Step-3b body behind [`Verified::arbitrate`] and
/// [`PowerScored::arbitrate`]: run the backend arbitration, then emit the
/// winning transformed source.
fn arbitrate_scored(
    verified: &Verified,
    scores: &power::PowerOutcome,
    req: &OffloadRequest,
) -> std::result::Result<Arbitrated, OffloadError> {
    let t0 = Instant::now();
    let go = || -> Result<(backend::ArbitrationOutcome, String)> {
        let accepted = verified.reconciled.accepted();
        let mut arbitration = backend::arbitrate(
            &req.db,
            req.backend_policy,
            req.device,
            backend::NARROW_MIN_SCORE,
            &accepted,
            &verified.outcome,
            scores,
        )?;
        // Join the analytic predictions against the measured search so
        // the report carries per-block predicted-vs-measured error (the
        // v4 residue). Present only under a non-default estimator
        // configuration — the default report stays v2/v3.
        arbitration.estimate =
            verified.estimates.as_ref().map(|e| estimate::decision(e, &verified.outcome));
        // Attach the residency residue (the v5 section) exactly when a
        // nonzero budget installed a data plane — `--resident-bytes 0`
        // leaves the report at its earlier version, byte-identical.
        if req.resident_bytes > 0 {
            arbitration.residency = Some(residency::decision(
                req.resident_bytes,
                &verified.outcome,
                accepted.len(),
            ));
        }
        // Emit the winning transformed source (on the *user's* program,
        // not the linked one — what the paper hands back for deployment).
        // Under a non-default power policy a time-winning block the
        // arbitration sent back to the CPU (energy loser, or capped out)
        // must not stay replaced: the emitted deployment has to match the
        // recorded decision. Under the default `perf` policy a winning
        // block always holds an accelerator, so the filter is inert.
        let winning: Vec<PlannedReplacement> = accepted
            .iter()
            .enumerate()
            .zip(&verified.outcome.best_enabled)
            .filter(|((i, _), &on)| {
                on && (scores.policy.is_default()
                    || arbitration.blocks[*i].backend != Backend::Cpu)
            })
            .map(|((_, p), _)| p.clone())
            .collect();
        let transformed =
            transform::apply(&verified.reconciled.discovered.parsed.program, &winning)?;
        Ok((arbitration, parser::print_program(&transformed)))
    };
    let (arbitration, transformed_source) = go().map_err(|e| OffloadError::Arbitrate {
        verified: Box::new(verified.clone()),
        message: format!("{e:#}"),
    })?;
    let wall = t0.elapsed();
    req.observe_events(|| {
        let mut events = backend::arbitration_events(&arbitration);
        if let Some(res) = &arbitration.residency {
            events.extend(residency::residency_events(res));
        }
        events
    });
    req.observe(Stage::Arbitrate, wall);
    Ok(Arbitrated { verified: verified.clone(), arbitration, transformed_source, wall })
}

/// Stage-3b artifact: the backend decision plus the winning transformed
/// source — everything [`OffloadReport`] carries.
#[derive(Debug, Clone)]
pub struct Arbitrated {
    /// The Step-3 artifact this stage advanced from.
    pub verified: Verified,
    /// Step-3b backend arbitration outcome.
    pub arbitration: backend::ArbitrationOutcome,
    /// The winning transformed source (paper Step 3 output).
    pub transformed_source: String,
    /// Wall-clock this stage took.
    pub wall: Duration,
}

impl Arbitrated {
    /// Assemble the full offload report. `search_wall` is the sum of the
    /// stage wall-clocks that produced this artifact.
    pub fn report(&self) -> OffloadReport {
        let discovered = &self.verified.reconciled.discovered;
        OffloadReport {
            entry: discovered.parsed.entry.clone(),
            external_callees: discovered.external_callees.clone(),
            blocks: self.verified.reconciled.blocks.clone(),
            outcome: self.verified.outcome.clone(),
            arbitration: self.arbitration.clone(),
            transformed_source: self.transformed_source.clone(),
            search_wall: discovered.parsed.wall
                + discovered.wall
                + self.verified.reconciled.wall
                + self.verified.wall
                + self.wall,
        }
    }

    /// Steps 4–5: size the arbitrated backend from its request time and
    /// pick the cheapest feasible location. When nothing was offloaded,
    /// the all-CPU pattern is sized and placed with the generic
    /// capacity/price walk instead.
    pub fn place(
        &self,
        req: &OffloadRequest,
        requirements: &flow::Requirements,
        locations: &[flow::Location],
    ) -> std::result::Result<Placed, OffloadError> {
        let t0 = Instant::now();
        let go = || -> Result<Placed> {
            let times = flow::BackendTimes::from_arbitration(&self.arbitration);
            if times.gpu_secs.is_none() && times.fpga_secs.is_none() {
                // No accelerator deployment on offer: the service runs the
                // all-CPU baseline, so size from the *baseline* time. When
                // nothing offloaded this equals best_time (the search
                // keeps the baseline as best); when a power policy
                // excluded every accelerator, best_time would be the
                // accelerated pattern the deployment cannot actually run.
                let plan =
                    flow::plan_resources(self.verified.outcome.baseline.secs(), requirements)?;
                let p = flow::plan_placement(&plan, requirements, locations)?;
                Ok(Placed {
                    backend: Backend::Cpu,
                    instances: plan.instances,
                    rps_per_instance: plan.rps_per_instance,
                    location: p.location,
                    monthly_cost: p.monthly_cost,
                    wall: Duration::ZERO,
                })
            } else {
                let p = flow::plan_backend_placement(&times, requirements, locations)?;
                Ok(Placed {
                    backend: p.backend,
                    instances: p.plan.instances,
                    rps_per_instance: p.plan.rps_per_instance,
                    location: p.location,
                    monthly_cost: p.monthly_cost,
                    wall: Duration::ZERO,
                })
            }
        };
        let mut placed = go().map_err(|e| OffloadError::Placement {
            arbitrated: Box::new(self.clone()),
            message: format!("{e:#}"),
        })?;
        placed.wall = t0.elapsed();
        req.observe(Stage::Place, placed.wall);
        Ok(placed)
    }

    /// Serialize to the canonical JSON value.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str(STAGE_ARBITRATED_FORMAT)),
            ("verified", self.verified.to_json()),
            ("arbitration", report_json::arbitration_to_json(&self.arbitration)),
            ("transformed_source", Json::str(&self.transformed_source)),
            ("wall_ns", report_json::duration_to_json(self.wall)),
        ])
    }

    /// Decode from a JSON value.
    pub fn from_json(v: &Json) -> Result<Arbitrated> {
        check_format(v, STAGE_ARBITRATED_FORMAT)?;
        Ok(Arbitrated {
            verified: Verified::from_json(v.get("verified")?)?,
            arbitration: report_json::arbitration_from_json(v.get("arbitration")?)?,
            transformed_source: v.get("transformed_source")?.as_str()?.to_string(),
            wall: report_json::duration_from_json(v.get("wall_ns")?)?,
        })
    }

    /// Serialize to the canonical pretty-printed string.
    pub fn to_json_string(&self) -> String {
        json::to_string_pretty(&self.to_json())
    }

    /// Decode from the string form.
    pub fn from_json_str(s: &str) -> Result<Arbitrated> {
        Self::from_json(&json::parse(s)?)
    }
}

/// Steps 4–5 artifact: where the arbitrated deployment runs and what it
/// costs.
#[derive(Debug, Clone)]
pub struct Placed {
    /// Backend the deployment runs on (`Cpu` when nothing was offloaded).
    pub backend: Backend,
    /// Accelerator (or CPU) instances to provision (Step 4).
    pub instances: usize,
    /// Predicted per-instance throughput (requests/s).
    pub rps_per_instance: f64,
    /// Chosen location name (Step 5).
    pub location: String,
    /// Projected monthly cost ($).
    pub monthly_cost: f64,
    /// Wall-clock this stage took.
    pub wall: Duration,
}

impl Placed {
    /// Serialize to the canonical JSON value.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str(STAGE_PLACED_FORMAT)),
            ("backend", Json::str(self.backend.as_str())),
            ("instances", Json::num(self.instances as f64)),
            ("rps_per_instance", Json::num(self.rps_per_instance)),
            ("location", Json::str(&self.location)),
            ("monthly_cost", Json::num(self.monthly_cost)),
            ("wall_ns", report_json::duration_to_json(self.wall)),
        ])
    }

    /// Decode from a JSON value.
    pub fn from_json(v: &Json) -> Result<Placed> {
        check_format(v, STAGE_PLACED_FORMAT)?;
        Ok(Placed {
            backend: Backend::parse(v.get("backend")?.as_str()?)?,
            instances: v.get("instances")?.as_usize()?,
            rps_per_instance: v.get("rps_per_instance")?.as_f64()?,
            location: v.get("location")?.as_str()?.to_string(),
            monthly_cost: v.get("monthly_cost")?.as_f64()?,
            wall: report_json::duration_from_json(v.get("wall_ns")?)?,
        })
    }

    /// Serialize to the canonical pretty-printed string.
    pub fn to_json_string(&self) -> String {
        json::to_string_pretty(&self.to_json())
    }

    /// Decode from the string form.
    pub fn from_json_str(s: &str) -> Result<Placed> {
        Self::from_json(&json::parse(s)?)
    }
}

// ------------------------------------------------------- shared plumbing

/// Step-2 discovery over an analyzed program: A-1/B-1 library calls by
/// name, then A-2/B-2 similarity-detected copied code (skipping functions
/// already claimed by the library path).
pub(crate) fn discover_candidates(
    db: &PatternDb,
    similarity_threshold: f64,
    prog: &Program,
    external_callees: &[String],
) -> Result<Vec<Candidate>> {
    let mut out = Vec::new();

    // A-1 / B-1: library calls by name. The DB registered the CPU
    // library's interface; reconciliation compares it to the
    // replacement's (registered pairs normally agree — C-1).
    for callee in external_callees {
        let Some(rec) = db.find_library(callee) else { continue };
        out.push(Candidate {
            via: DiscoveryPath::LibraryMatch { library: rec.library.clone() },
            site: Site::LibraryCall { callee: callee.clone() },
            replacement: rec.replacement.clone(),
            caller_signature: rec.signature.clone(),
        });
    }

    // A-2 / B-2: similarity-detected copied code.
    let detector = similarity::Detector::new(db, similarity_threshold)?;
    for m in detector.detect(prog) {
        // Skip functions already handled through the library path.
        if out.iter().any(|c| match &c.site {
            Site::LibraryCall { callee } => *callee == m.function,
            Site::FunctionBody { function } => *function == m.function,
        }) {
            continue;
        }
        let rec = &db.comparisons[m.record];
        let f = prog
            .find_function(&m.function)
            .ok_or_else(|| anyhow::anyhow!("matched function {} vanished", m.function))?;
        out.push(Candidate {
            via: DiscoveryPath::Similarity { block: m.block.clone(), score: m.score },
            site: Site::FunctionBody { function: m.function.clone() },
            replacement: rec.replacement.clone(),
            caller_signature: signature_of(f),
        });
    }
    Ok(out)
}

/// C-1/C-2 reconciliation of every candidate. Each candidate consults a
/// fresh clone of the policy, so scripted answers apply per block.
pub(crate) fn reconcile_candidates(
    candidates: &[Candidate],
    policy: &InterfacePolicy,
) -> Vec<DiscoveredBlock> {
    candidates
        .iter()
        .map(|c| {
            let mut policy = policy.clone();
            let reconciliation =
                reconcile(&c.caller_signature, &c.replacement.signature, &mut policy);
            DiscoveredBlock {
                via: c.via.clone(),
                plan: PlannedReplacement {
                    site: c.site.clone(),
                    replacement: c.replacement.clone(),
                    reconciliation,
                },
            }
        })
        .collect()
}

/// "Link" CPU implementations of DB-known external libraries into the
/// program, the way the paper's verification machine compiles the app
/// against the NR sources: the all-CPU baseline needs runnable bodies.
pub fn link_cpu_libraries(db: &PatternDb, prog: &Program) -> Result<Program> {
    let a = analysis::analyze(prog);
    let mut out = prog.clone();
    for callee in a.external_callees() {
        if prog.find_function(&callee).map(|f| f.body.is_some()).unwrap_or(false) {
            continue;
        }
        let Some(rec) = db.find_library(&callee) else { continue };
        let Some((code, entry)) = &rec.cpu_impl else { continue };
        let lib = parser::parse(code)
            .with_context(|| format!("parsing CPU impl of {callee:?}"))?;
        for item in lib.items {
            if let Item::Func(mut f) = item {
                // Skip if a function of that name already exists with a
                // body (user code wins).
                if out.find_function(&f.name).map(|g| g.body.is_some()).unwrap_or(false)
                    && f.name != *entry
                {
                    continue;
                }
                if f.name == *entry {
                    f.name = callee.clone();
                }
                out.items.push(Item::Func(f));
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_enum_is_ordered_and_named() {
        assert_eq!(Stage::ALL.len(), 8);
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(s.index(), i);
        }
        assert_eq!(Stage::Estimate.as_str(), "estimate");
        assert_eq!(Stage::Verify.as_str(), "verify");
        assert_eq!(Stage::PowerScore.as_str(), "power-score");
        for s in Stage::ALL {
            assert_eq!(Stage::parse(s.as_str()).unwrap(), s, "parse inverts as_str");
        }
        assert!(Stage::parse("compile").is_err());
        assert!(Stage::Estimate.index() > Stage::Reconcile.index());
        assert!(Stage::Estimate.index() < Stage::Verify.index());
        assert!(Stage::PowerScore.index() > Stage::Verify.index());
        assert!(Stage::PowerScore.index() < Stage::Arbitrate.index());
    }

    #[test]
    fn error_reports_stage_and_message() {
        let e = OffloadError::Parse { entry: "main".into(), message: "boom".into() };
        assert_eq!(e.stage(), Stage::Parse);
        assert_eq!(e.message(), "boom");
        assert!(e.to_string().contains("parse stage failed: boom"));
    }

    #[test]
    fn parsed_artifact_round_trips() {
        let src = "int main() { return 40 + 2; }";
        let program = parser::parse(src).unwrap();
        let parsed = Parsed {
            entry: "main".into(),
            source: parser::print_program(&program),
            program,
            wall: Duration::from_micros(12),
        };
        let s = parsed.to_json_string();
        let back = Parsed::from_json_str(&s).unwrap();
        assert_eq!(back.entry, parsed.entry);
        assert_eq!(back.source, parsed.source);
        assert_eq!(back.wall, parsed.wall);
        assert_eq!(back.to_json_string(), s, "stage codec must be byte-stable");
    }

    #[test]
    fn placed_artifact_round_trips() {
        let placed = Placed {
            backend: Backend::Fpga,
            instances: 8,
            rps_per_instance: 5.0,
            location: "regional-dc".into(),
            monthly_cost: 1152.0,
            wall: Duration::from_micros(3),
        };
        let s = placed.to_json_string();
        let back = Placed::from_json_str(&s).unwrap();
        assert_eq!(back.backend, placed.backend);
        assert_eq!(back.instances, placed.instances);
        assert_eq!(back.location, placed.location);
        assert_eq!(back.to_json_string(), s);
    }

    #[test]
    fn wrong_format_tag_is_rejected() {
        let src = "int main() { return 0; }";
        let program = parser::parse(src).unwrap();
        let parsed = Parsed {
            entry: "main".into(),
            source: parser::print_program(&program),
            program,
            wall: Duration::ZERO,
        };
        let tampered = parsed.to_json_string().replace(STAGE_PARSED_FORMAT, "something-else");
        assert!(Parsed::from_json_str(&tampered).is_err());
        assert!(Discovered::from_json_str(&parsed.to_json_string()).is_err());
    }
}
