//! JSON codec for [`OffloadReport`] — the substrate of the service layer's
//! persistent decision cache.
//!
//! The paper's Step 3 (measured pattern search) is the expensive part of
//! the pipeline by design; its output is a *verified decision* worth
//! keeping. This codec round-trips the full report — discovery provenance,
//! every measured pattern, the winning transformed source — through the
//! in-tree [`crate::patterndb::json`] substrate so the service layer can
//! persist decisions and replay them without re-running pattern search or
//! measurement.
//!
//! The printed form is **canonical** (object keys are sorted by `BTreeMap`,
//! numbers print in shortest-round-trip form), so
//! `report_to_string ∘ report_from_str` is the identity on its own output.
//! The decision cache relies on that for byte-identical warm reads.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::backend::{
    ArbitrationOutcome, Backend, BackendPolicy, BlockArbitration, DeviceModel, FpgaEstimate,
};
use crate::coordinator::estimate;
use crate::coordinator::power;
use crate::coordinator::residency;
use crate::coordinator::verify::{DeviceTraffic, PatternResult, SearchOutcome};
use crate::coordinator::{DiscoveredBlock, DiscoveryPath, OffloadReport};
use crate::fpga::ResourceEstimate;
use crate::metrics::Measurement;
use crate::patterndb::json::{self, Json};
use crate::patterndb::{repl_from_json, repl_to_json};
use crate::transform::{PlannedReplacement, Reconciliation, Site};

/// Format tag of a report arbitrated under the default (`perf`) power
/// policy. v2 added the backend arbitration section (`backend`,
/// `arbitration`) and per-pattern device traffic. A report whose
/// arbitration carries a power residue (non-default `--power-policy`)
/// serializes as [`REPORT_FORMAT_V3`] instead; emitting v2 bytes for the
/// default keeps every pre-power cached decision byte-identical on
/// replay.
pub const REPORT_FORMAT: &str = "fbo-offload-report-v2";

/// Format tag of a report whose arbitration ran under a non-default
/// `--power-policy`: the arbitration section additionally carries the
/// `power` residue (policy, per-instance deployment watts, per-block
/// energy comparisons). v3 documents **must** carry that section and
/// v2/v1 documents must not — the format tag and the payload shape agree
/// by construction, so re-encoding any decoded report reproduces its
/// canonical bytes.
pub const REPORT_FORMAT_V3: &str = "fbo-offload-report-v3";

/// Format tag of a report whose search was shaped by a non-default
/// analytic-estimator configuration (`--prune-policy` / a custom
/// `--device-profile` registry): the arbitration section additionally
/// carries the `estimate` residue (per-block predicted-vs-measured error
/// and the estimator MAPE). v4 documents **must** carry that section and
/// earlier formats must not; the power residue remains optional inside a
/// v4 document (a pruned search may or may not also weigh power).
/// Default-configuration reports keep emitting v2/v3 bytes, so every
/// cached pre-estimator decision replays byte-identically.
pub const REPORT_FORMAT_V4: &str = "fbo-offload-report-v4";

/// Format tag of a report whose pipeline ran with a device-resident data
/// plane (`--resident-bytes > 0`): the arbitration section additionally
/// carries the `residency` residue (per-block elided host<->device bytes
/// and the PCIe transfer seconds they saved), and per-pattern traffic may
/// carry `elided_in`/`elided_out` keys. v5 documents **must** carry that
/// section and earlier formats must not; the power and estimate residues
/// remain optional inside a v5 document. Residency-off reports keep
/// emitting v2/v3/v4 bytes, so every cached pre-residency decision
/// replays byte-identically.
pub const REPORT_FORMAT_V5: &str = "fbo-offload-report-v5";

/// The previous report format: no `backend`/`arbitration` sections and no
/// per-pattern device traffic. v1 reports still **decode** (the archived
/// decisions of pre-arbitration deployments stay readable): traffic reads
/// as zero and the arbitration section is synthesized for the GPU-only
/// policy the v1 pipeline effectively ran under. Re-encoding always emits
/// v2 bytes, so the byte-identical replay guarantee of the decision cache
/// applies only to v2 entries — v1-era cache entries can never match a
/// current decision fingerprint and therefore re-verify rather than
/// replay.
pub const REPORT_FORMAT_V1: &str = "fbo-offload-report-v1";

/// Serialize a report to the canonical JSON value (v2; v3 when the
/// arbitration carries a power residue; v4 when it carries an estimate
/// residue; v5 when it carries a residency residue — see
/// [`REPORT_FORMAT_V3`] / [`REPORT_FORMAT_V4`] / [`REPORT_FORMAT_V5`]).
pub fn report_to_json(r: &OffloadReport) -> Json {
    let format = if r.arbitration.residency.is_some() {
        REPORT_FORMAT_V5
    } else if r.arbitration.estimate.is_some() {
        REPORT_FORMAT_V4
    } else if r.arbitration.power.is_some() {
        REPORT_FORMAT_V3
    } else {
        REPORT_FORMAT
    };
    Json::obj(vec![
        ("format", Json::str(format)),
        ("entry", Json::str(&r.entry)),
        (
            "external_callees",
            Json::Arr(r.external_callees.iter().map(Json::str).collect()),
        ),
        ("blocks", Json::Arr(r.blocks.iter().map(block_to_json).collect())),
        ("outcome", outcome_to_json(&r.outcome)),
        // The overall backend is lifted to the top level so consumers can
        // route on it without walking the arbitration detail.
        ("backend", Json::str(r.arbitration.backend.as_str())),
        ("arbitration", arbitration_to_json(&r.arbitration)),
        ("transformed_source", Json::str(&r.transformed_source)),
        ("search_wall_ns", duration_to_json(r.search_wall)),
    ])
}

/// Serialize a report to the canonical pretty-printed string.
pub fn report_to_string(r: &OffloadReport) -> String {
    json::to_string_pretty(&report_to_json(r))
}

/// Deserialize a report from a JSON value (v5, v4, v3, v2, or v1 upgraded
/// on the fly — see [`REPORT_FORMAT_V1`]).
pub fn report_from_json(v: &Json) -> Result<OffloadReport> {
    let format = v.get("format")?.as_str()?;
    let (v1, v3, v4, v5) = match format {
        REPORT_FORMAT => (false, false, false, false),
        REPORT_FORMAT_V3 => (false, true, false, false),
        REPORT_FORMAT_V4 => (false, false, true, false),
        REPORT_FORMAT_V5 => (false, false, false, true),
        REPORT_FORMAT_V1 => (true, false, false, false),
        other => bail!(
            "unsupported offload-report format {other:?} \
             (want {REPORT_FORMAT_V5:?}, {REPORT_FORMAT_V4:?}, {REPORT_FORMAT_V3:?}, \
             {REPORT_FORMAT:?}, or {REPORT_FORMAT_V1:?})"
        ),
    };
    let outcome = outcome_from_json(v.get("outcome")?, v1)?;
    let arbitration = if v1 {
        v1_arbitration(&outcome)
    } else {
        let arbitration = arbitration_from_json(v.get("arbitration")?)?;
        // Tag ↔ payload agreement keeps the canonical re-encode stable:
        // a decoded report always serializes back to its own format. Each
        // format's newest residue is its marker; older residues are
        // mandatory markers only below the format that freed them — the
        // residency residue is exactly the v5 marker, the estimate
        // residue marks v4 (and is free inside v5), the power residue
        // marks v3 (and is free inside v4/v5).
        if arbitration.residency.is_some() != v5 {
            bail!(
                "corrupt report: format {format:?} disagrees with the presence \
                 of the arbitration residency section"
            );
        }
        if !v5 && arbitration.estimate.is_some() != v4 {
            bail!(
                "corrupt report: format {format:?} disagrees with the presence \
                 of the arbitration estimate section"
            );
        }
        if !v5 && !v4 && arbitration.power.is_some() != v3 {
            bail!(
                "corrupt report: format {format:?} disagrees with the presence \
                 of the arbitration power section"
            );
        }
        arbitration
    };
    let report = OffloadReport {
        entry: v.get("entry")?.as_str()?.to_string(),
        external_callees: v
            .get("external_callees")?
            .as_arr()?
            .iter()
            .map(|s| Ok(s.as_str()?.to_string()))
            .collect::<Result<_>>()?,
        blocks: v
            .get("blocks")?
            .as_arr()?
            .iter()
            .map(block_from_json)
            .collect::<Result<_>>()?,
        outcome,
        arbitration,
        transformed_source: v.get("transformed_source")?.as_str()?.to_string(),
        search_wall: duration_from_json(v.get("search_wall_ns")?)?,
    };
    if !v1 {
        // The lifted top-level backend must agree with the arbitration detail.
        let top = Backend::parse(v.get("backend")?.as_str()?)?;
        if top != report.arbitration.backend {
            bail!(
                "corrupt report: top-level backend {:?} disagrees with arbitration {:?}",
                top.as_str(),
                report.arbitration.backend.as_str()
            );
        }
    }
    Ok(report)
}

/// Synthesize the arbitration section a v1 report predates: the v1
/// pipeline never ran Step 3b, which is the paper's evaluated GPU-only
/// configuration. No per-block detail exists, no toolchain hours were
/// charged, and the overall backend is GPU exactly when the winning
/// pattern offloads anything.
fn v1_arbitration(outcome: &SearchOutcome) -> ArbitrationOutcome {
    let offloads = outcome.best_enabled.iter().any(|&on| on);
    ArbitrationOutcome {
        policy: BackendPolicy::Gpu,
        device: DeviceModel {
            name: "pre-arbitration (v1 report)".to_string(),
            alms: 0,
            dsps: 0,
            m20ks: 0,
            fmax: 0.0,
        },
        blocks: Vec::new(),
        backend: if offloads { Backend::Gpu } else { Backend::Cpu },
        simulated_hours: 0.0,
        gpu_request_secs: offloads.then(|| outcome.best_time.secs()),
        fpga_request_secs: None,
        power: None,
        estimate: None,
        residency: None,
    }
}

/// Deserialize a report from its string form.
pub fn report_from_str(s: &str) -> Result<OffloadReport> {
    report_from_json(&json::parse(s)?)
}

// ------------------------------------------------------------- components

pub(crate) fn duration_to_json(d: Duration) -> Json {
    // Nanoseconds fit f64 exactly up to 2^53 ns ≈ 104 days; searches are
    // minutes at worst.
    Json::num(d.as_nanos() as f64)
}

pub(crate) fn duration_from_json(v: &Json) -> Result<Duration> {
    Ok(Duration::from_nanos(v.as_f64()? as u64))
}

pub(crate) fn measurement_to_json(m: &Measurement) -> Json {
    Json::obj(vec![
        ("label", Json::str(&m.label)),
        ("median_ns", duration_to_json(m.median)),
        ("min_ns", duration_to_json(m.min)),
        ("max_ns", duration_to_json(m.max)),
        ("reps", Json::num(m.reps as f64)),
    ])
}

pub(crate) fn measurement_from_json(v: &Json) -> Result<Measurement> {
    Ok(Measurement {
        label: v.get("label")?.as_str()?.to_string(),
        median: duration_from_json(v.get("median_ns")?)?,
        min: duration_from_json(v.get("min_ns")?)?,
        max: duration_from_json(v.get("max_ns")?)?,
        reps: v.get("reps")?.as_usize()?,
    })
}

pub(crate) fn via_to_json(via: &DiscoveryPath) -> Json {
    match via {
        DiscoveryPath::LibraryMatch { library } => Json::obj(vec![
            ("path", Json::str("library_match")),
            ("library", Json::str(library)),
        ]),
        DiscoveryPath::Similarity { block, score } => Json::obj(vec![
            ("path", Json::str("similarity")),
            ("block", Json::str(block)),
            ("score", Json::num(*score)),
        ]),
    }
}

pub(crate) fn via_from_json(v: &Json) -> Result<DiscoveryPath> {
    Ok(match v.get("path")?.as_str()? {
        "library_match" => DiscoveryPath::LibraryMatch {
            library: v.get("library")?.as_str()?.to_string(),
        },
        "similarity" => DiscoveryPath::Similarity {
            block: v.get("block")?.as_str()?.to_string(),
            score: v.get("score")?.as_f64()?,
        },
        other => bail!("unknown discovery path {other:?}"),
    })
}

pub(crate) fn site_to_json(site: &Site) -> Json {
    match site {
        Site::LibraryCall { callee } => Json::obj(vec![
            ("kind", Json::str("library_call")),
            ("callee", Json::str(callee)),
        ]),
        Site::FunctionBody { function } => Json::obj(vec![
            ("kind", Json::str("function_body")),
            ("function", Json::str(function)),
        ]),
    }
}

pub(crate) fn site_from_json(v: &Json) -> Result<Site> {
    Ok(match v.get("kind")?.as_str()? {
        "library_call" => Site::LibraryCall { callee: v.get("callee")?.as_str()?.to_string() },
        "function_body" => {
            Site::FunctionBody { function: v.get("function")?.as_str()?.to_string() }
        }
        other => bail!("unknown site kind {other:?}"),
    })
}

fn reconciliation_to_json(r: &Reconciliation) -> Json {
    match r {
        Reconciliation::Exact => Json::obj(vec![("kind", Json::str("exact"))]),
        Reconciliation::AutoCast => Json::obj(vec![("kind", Json::str("auto_cast"))]),
        Reconciliation::DropOptional(dropped) => Json::obj(vec![
            ("kind", Json::str("drop_optional")),
            ("dropped", Json::Arr(dropped.iter().map(|&i| Json::num(i as f64)).collect())),
        ]),
        Reconciliation::Confirmed(q) => {
            Json::obj(vec![("kind", Json::str("confirmed")), ("question", Json::str(q))])
        }
        Reconciliation::Rejected(q) => {
            Json::obj(vec![("kind", Json::str("rejected")), ("question", Json::str(q))])
        }
    }
}

fn reconciliation_from_json(v: &Json) -> Result<Reconciliation> {
    Ok(match v.get("kind")?.as_str()? {
        "exact" => Reconciliation::Exact,
        "auto_cast" => Reconciliation::AutoCast,
        "drop_optional" => Reconciliation::DropOptional(
            v.get("dropped")?.as_arr()?.iter().map(|i| i.as_usize()).collect::<Result<_>>()?,
        ),
        "confirmed" => Reconciliation::Confirmed(v.get("question")?.as_str()?.to_string()),
        "rejected" => Reconciliation::Rejected(v.get("question")?.as_str()?.to_string()),
        other => bail!("unknown reconciliation kind {other:?}"),
    })
}

pub(crate) fn block_to_json(b: &DiscoveredBlock) -> Json {
    Json::obj(vec![
        ("via", via_to_json(&b.via)),
        ("site", site_to_json(&b.plan.site)),
        ("replacement", repl_to_json(&b.plan.replacement)),
        ("reconciliation", reconciliation_to_json(&b.plan.reconciliation)),
    ])
}

pub(crate) fn block_from_json(v: &Json) -> Result<DiscoveredBlock> {
    Ok(DiscoveredBlock {
        via: via_from_json(v.get("via")?)?,
        plan: PlannedReplacement {
            site: site_from_json(v.get("site")?)?,
            replacement: repl_from_json(v.get("replacement")?)?,
            reconciliation: reconciliation_from_json(v.get("reconciliation")?)?,
        },
    })
}

/// Nested [`PlannedReplacement`] codec — the shape the fleet wire protocol
/// ships reconciled blocks in (the stage-artifact block codec above stays
/// flat for format stability).
pub(crate) fn plan_to_json(p: &PlannedReplacement) -> Json {
    Json::obj(vec![
        ("site", site_to_json(&p.site)),
        ("replacement", repl_to_json(&p.replacement)),
        ("reconciliation", reconciliation_to_json(&p.reconciliation)),
    ])
}

/// Inverse of [`plan_to_json`].
pub(crate) fn plan_from_json(v: &Json) -> Result<PlannedReplacement> {
    Ok(PlannedReplacement {
        site: site_from_json(v.get("site")?)?,
        replacement: repl_from_json(v.get("replacement")?)?,
        reconciliation: reconciliation_from_json(v.get("reconciliation")?)?,
    })
}

pub(crate) fn traffic_to_json(t: &DeviceTraffic) -> Json {
    let mut pairs = vec![
        ("bytes_in", Json::num(t.bytes_in as f64)),
        ("bytes_out", Json::num(t.bytes_out as f64)),
        ("dispatches", Json::num(t.dispatches as f64)),
        ("device_secs", Json::num(t.device_secs)),
    ];
    // Elided bytes exist only when a data plane elided something (a v5
    // report); emitting the keys conditionally keeps every residency-off
    // traffic section byte-identical to its v2-v4 form.
    if t.elided_in > 0 {
        pairs.push(("elided_in", Json::num(t.elided_in as f64)));
    }
    if t.elided_out > 0 {
        pairs.push(("elided_out", Json::num(t.elided_out as f64)));
    }
    Json::obj(pairs)
}

pub(crate) fn traffic_from_json(v: &Json) -> Result<DeviceTraffic> {
    let opt_bytes = |key: &str| -> Result<u64> {
        Ok(v.opt(key).map(|n| n.as_f64()).transpose()?.unwrap_or(0.0) as u64)
    };
    Ok(DeviceTraffic {
        bytes_in: v.get("bytes_in")?.as_f64()? as u64,
        bytes_out: v.get("bytes_out")?.as_f64()? as u64,
        dispatches: v.get("dispatches")?.as_f64()? as u64,
        device_secs: v.get("device_secs")?.as_f64()?,
        elided_in: opt_bytes("elided_in")?,
        elided_out: opt_bytes("elided_out")?,
    })
}

fn pattern_to_json(p: &PatternResult) -> Json {
    Json::obj(vec![
        ("enabled", Json::Arr(p.enabled.iter().map(|&b| Json::Bool(b)).collect())),
        ("label", Json::str(&p.label)),
        ("time", measurement_to_json(&p.time)),
        ("speedup", Json::num(p.speedup)),
        ("output_ok", Json::Bool(p.output_ok)),
        ("traffic", traffic_to_json(&p.traffic)),
    ])
}

/// `v1` relaxes the schema to the pre-arbitration report format, where
/// patterns carried no device-traffic section (it reads as zero).
fn pattern_from_json(v: &Json, v1: bool) -> Result<PatternResult> {
    let traffic = if v1 {
        v.opt("traffic").map(traffic_from_json).transpose()?.unwrap_or_default()
    } else {
        traffic_from_json(v.get("traffic")?)?
    };
    Ok(PatternResult {
        enabled: bools_from_json(v.get("enabled")?)?,
        label: v.get("label")?.as_str()?.to_string(),
        time: measurement_from_json(v.get("time")?)?,
        speedup: v.get("speedup")?.as_f64()?,
        output_ok: bool_from_json(v.get("output_ok")?)?,
        traffic,
    })
}

// ------------------------------------------------- backend arbitration

fn bool_from_json(v: &Json) -> Result<bool> {
    match v {
        Json::Bool(b) => Ok(*b),
        other => bail!("expected JSON bool, got {other:?}"),
    }
}

fn opt_num_to_json(v: Option<f64>) -> Json {
    v.map(Json::num).unwrap_or(Json::Null)
}

fn opt_num_from_json(v: &Json, key: &str) -> Result<Option<f64>> {
    v.opt(key).map(|n| n.as_f64()).transpose()
}

fn device_to_json(d: &DeviceModel) -> Json {
    Json::obj(vec![
        ("name", Json::str(&d.name)),
        ("alms", Json::num(d.alms as f64)),
        ("dsps", Json::num(d.dsps as f64)),
        ("m20ks", Json::num(d.m20ks as f64)),
        ("fmax", Json::num(d.fmax)),
    ])
}

fn device_from_json(v: &Json) -> Result<DeviceModel> {
    Ok(DeviceModel {
        name: v.get("name")?.as_str()?.to_string(),
        alms: v.get("alms")?.as_f64()? as u64,
        dsps: v.get("dsps")?.as_f64()? as u64,
        m20ks: v.get("m20ks")?.as_f64()? as u64,
        fmax: v.get("fmax")?.as_f64()?,
    })
}

fn fpga_estimate_to_json(f: &FpgaEstimate) -> Json {
    Json::obj(vec![
        ("core", Json::str(&f.core)),
        ("intensity_score", Json::num(f.intensity_score)),
        ("narrowed_out", Json::Bool(f.narrowed_out)),
        ("alms", Json::num(f.resources.alms as f64)),
        ("dsps", Json::num(f.resources.dsps as f64)),
        ("m20ks", Json::num(f.resources.m20ks as f64)),
        ("utilization", Json::num(f.utilization)),
        ("precheck_ok", Json::Bool(f.precheck_ok)),
        ("est_secs", Json::num(f.est_secs)),
        ("compile_hours", Json::num(f.compile_hours)),
    ])
}

fn fpga_estimate_from_json(v: &Json) -> Result<FpgaEstimate> {
    Ok(FpgaEstimate {
        core: v.get("core")?.as_str()?.to_string(),
        intensity_score: v.get("intensity_score")?.as_f64()?,
        narrowed_out: bool_from_json(v.get("narrowed_out")?)?,
        resources: ResourceEstimate {
            alms: v.get("alms")?.as_f64()? as u64,
            dsps: v.get("dsps")?.as_f64()? as u64,
            m20ks: v.get("m20ks")?.as_f64()? as u64,
        },
        utilization: v.get("utilization")?.as_f64()?,
        precheck_ok: bool_from_json(v.get("precheck_ok")?)?,
        est_secs: v.get("est_secs")?.as_f64()?,
        compile_hours: v.get("compile_hours")?.as_f64()?,
    })
}

fn block_arbitration_to_json(b: &BlockArbitration) -> Json {
    Json::obj(vec![
        ("label", Json::str(&b.label)),
        ("backend", Json::str(b.backend.as_str())),
        ("gpu_secs", opt_num_to_json(b.gpu_secs)),
        ("gpu_device_secs", Json::num(b.gpu_device_secs)),
        (
            "fpga",
            b.fpga.as_ref().map(fpga_estimate_to_json).unwrap_or(Json::Null),
        ),
    ])
}

fn block_arbitration_from_json(v: &Json) -> Result<BlockArbitration> {
    Ok(BlockArbitration {
        label: v.get("label")?.as_str()?.to_string(),
        backend: Backend::parse(v.get("backend")?.as_str()?)?,
        gpu_secs: opt_num_from_json(v, "gpu_secs")?,
        gpu_device_secs: v.get("gpu_device_secs")?.as_f64()?,
        fpga: v.opt("fpga").map(fpga_estimate_from_json).transpose()?,
    })
}

pub(crate) fn arbitration_to_json(a: &ArbitrationOutcome) -> Json {
    let mut pairs = vec![
        ("policy", Json::str(a.policy.as_str())),
        ("device", device_to_json(&a.device)),
        ("blocks", Json::Arr(a.blocks.iter().map(block_arbitration_to_json).collect())),
        ("backend", Json::str(a.backend.as_str())),
        ("simulated_hours", Json::num(a.simulated_hours)),
        ("gpu_request_secs", opt_num_to_json(a.gpu_request_secs)),
        ("fpga_request_secs", opt_num_to_json(a.fpga_request_secs)),
    ];
    // The power residue only exists under a non-default --power-policy —
    // a default (`perf`) arbitration emits exactly the v2 key set, so its
    // bytes stay identical to pre-power reports.
    if let Some(p) = &a.power {
        pairs.push(("power", power::decision_to_json(p)));
    }
    // Likewise the estimate residue exists only under a non-default
    // estimator configuration (the v4 marker).
    if let Some(e) = &a.estimate {
        pairs.push(("estimate", estimate::decision_to_json(e)));
    }
    // And the residency residue only under a nonzero `--resident-bytes`
    // budget (the v5 marker).
    if let Some(r) = &a.residency {
        pairs.push(("residency", residency::decision_to_json(r)));
    }
    Json::obj(pairs)
}

pub(crate) fn arbitration_from_json(v: &Json) -> Result<ArbitrationOutcome> {
    Ok(ArbitrationOutcome {
        policy: BackendPolicy::parse(v.get("policy")?.as_str()?)?,
        device: device_from_json(v.get("device")?)?,
        blocks: v
            .get("blocks")?
            .as_arr()?
            .iter()
            .map(block_arbitration_from_json)
            .collect::<Result<_>>()?,
        backend: Backend::parse(v.get("backend")?.as_str()?)?,
        simulated_hours: v.get("simulated_hours")?.as_f64()?,
        gpu_request_secs: opt_num_from_json(v, "gpu_request_secs")?,
        fpga_request_secs: opt_num_from_json(v, "fpga_request_secs")?,
        power: v.opt("power").map(power::decision_from_json).transpose()?,
        estimate: v.opt("estimate").map(estimate::decision_from_json).transpose()?,
        residency: v.opt("residency").map(residency::decision_from_json).transpose()?,
    })
}

pub(crate) fn outcome_to_json(o: &SearchOutcome) -> Json {
    Json::obj(vec![
        ("baseline", measurement_to_json(&o.baseline)),
        ("tried", Json::Arr(o.tried.iter().map(pattern_to_json).collect())),
        ("best_enabled", Json::Arr(o.best_enabled.iter().map(|&b| Json::Bool(b)).collect())),
        ("best_time", measurement_to_json(&o.best_time)),
        ("best_speedup", Json::num(o.best_speedup)),
    ])
}

/// `v1` relaxes the per-pattern schema — see [`pattern_from_json`].
pub(crate) fn outcome_from_json(v: &Json, v1: bool) -> Result<SearchOutcome> {
    Ok(SearchOutcome {
        baseline: measurement_from_json(v.get("baseline")?)?,
        tried: v
            .get("tried")?
            .as_arr()?
            .iter()
            .map(|p| pattern_from_json(p, v1))
            .collect::<Result<_>>()?,
        best_enabled: bools_from_json(v.get("best_enabled")?)?,
        best_time: measurement_from_json(v.get("best_time")?)?,
        best_speedup: v.get("best_speedup")?.as_f64()?,
    })
}

fn bools_from_json(v: &Json) -> Result<Vec<bool>> {
    v.as_arr()?
        .iter()
        .map(|b| match b {
            Json::Bool(x) => Ok(*x),
            other => bail!("expected JSON bool, got {other:?}"),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterndb::PatternDb;

    /// A synthetic report exercising every enum arm — no engine or
    /// artifacts needed.
    fn sample_report() -> OffloadReport {
        let db = PatternDb::builtin();
        let m = |label: &str, us: u64| Measurement {
            label: label.to_string(),
            median: Duration::from_micros(us),
            min: Duration::from_micros(us / 2),
            max: Duration::from_micros(us * 3),
            reps: 3,
        };
        let blocks = vec![
            DiscoveredBlock {
                via: DiscoveryPath::LibraryMatch { library: "fft2d".into() },
                plan: PlannedReplacement {
                    site: Site::LibraryCall { callee: "fft2d".into() },
                    replacement: db.libraries[0].replacement.clone(),
                    reconciliation: Reconciliation::Exact,
                },
            },
            DiscoveredBlock {
                via: DiscoveryPath::Similarity { block: "nr-ludcmp".into(), score: 0.8725 },
                plan: PlannedReplacement {
                    site: Site::FunctionBody { function: "my_decomp".into() },
                    replacement: db.libraries[1].replacement.clone(),
                    reconciliation: Reconciliation::DropOptional(vec![2, 3]),
                },
            },
            DiscoveredBlock {
                via: DiscoveryPath::Similarity { block: "nr-matmul".into(), score: 0.51 },
                plan: PlannedReplacement {
                    site: Site::FunctionBody { function: "mm".into() },
                    replacement: db.libraries[3].replacement.clone(),
                    reconciliation: Reconciliation::Rejected("user said no".into()),
                },
            },
        ];
        OffloadReport {
            entry: "main".into(),
            external_callees: vec!["fft2d".into(), "qsort".into()],
            blocks,
            outcome: SearchOutcome {
                baseline: m("all-CPU", 1000),
                tried: vec![
                    PatternResult {
                        enabled: vec![true, false],
                        label: "only:call:fft2d".into(),
                        time: m("only:call:fft2d", 120),
                        speedup: 8.333,
                        output_ok: true,
                        traffic: DeviceTraffic {
                            bytes_in: 32768,
                            bytes_out: 32768,
                            dispatches: 1,
                            device_secs: 6.25e-5,
                            ..Default::default()
                        },
                    },
                    PatternResult {
                        enabled: vec![false, true],
                        label: "only:func:my_decomp [failed: boom]".into(),
                        time: m("all-CPU", 1000),
                        speedup: 0.0,
                        output_ok: false,
                        traffic: DeviceTraffic::default(),
                    },
                ],
                best_enabled: vec![true, false],
                best_time: m("only:call:fft2d", 120),
                best_speedup: 8.333,
            },
            arbitration: ArbitrationOutcome {
                policy: BackendPolicy::Auto,
                device: DeviceModel {
                    name: "Intel Arria10 GX 1150".into(),
                    alms: 427_200,
                    dsps: 1_518,
                    m20ks: 2_713,
                    fmax: 240.0e6,
                },
                blocks: vec![
                    BlockArbitration {
                        label: "call:fft2d".into(),
                        backend: Backend::Fpga,
                        gpu_secs: Some(1.2e-4),
                        gpu_device_secs: 9.5e-5,
                        fpga: Some(FpgaEstimate {
                            core: "2-D FFT IP core".into(),
                            intensity_score: 7821.5,
                            narrowed_out: false,
                            resources: ResourceEstimate {
                                alms: 26_280,
                                dsps: 83,
                                m20ks: 109,
                            },
                            utilization: 0.0615,
                            precheck_ok: true,
                            est_secs: 6.25e-5,
                            compile_hours: 3.23,
                        }),
                    },
                    BlockArbitration {
                        label: "func:my_decomp".into(),
                        backend: Backend::Cpu,
                        gpu_secs: None,
                        gpu_device_secs: 0.0,
                        fpga: None,
                    },
                ],
                backend: Backend::Fpga,
                simulated_hours: 3.27,
                gpu_request_secs: Some(1.2e-4),
                fpga_request_secs: Some(8.75e-5),
                power: None,
                estimate: None,
                residency: None,
            },
            transformed_source: "#include <math.h>\nint main() {\n    return 0;\n}\n".into(),
            search_wall: Duration::from_millis(47),
        }
    }

    #[test]
    fn round_trip_preserves_everything() {
        let r = sample_report();
        let s = report_to_string(&r);
        let back = report_from_str(&s).unwrap();
        assert_eq!(back.entry, r.entry);
        assert_eq!(back.external_callees, r.external_callees);
        assert_eq!(back.transformed_source, r.transformed_source);
        assert_eq!(back.search_wall, r.search_wall);
        assert_eq!(back.blocks.len(), r.blocks.len());
        for (a, b) in back.blocks.iter().zip(&r.blocks) {
            assert_eq!(a.via, b.via);
            assert_eq!(a.plan.site, b.plan.site);
            assert_eq!(a.plan.replacement, b.plan.replacement);
            assert_eq!(a.plan.reconciliation, b.plan.reconciliation);
        }
        assert_eq!(back.outcome.best_enabled, r.outcome.best_enabled);
        assert_eq!(back.outcome.best_speedup, r.outcome.best_speedup);
        assert_eq!(back.outcome.tried.len(), r.outcome.tried.len());
        assert_eq!(back.outcome.tried[0].speedup, r.outcome.tried[0].speedup);
        assert_eq!(back.outcome.tried[0].traffic, r.outcome.tried[0].traffic);
        assert_eq!(back.outcome.tried[1].output_ok, false);
        assert_eq!(back.outcome.tried[1].traffic, DeviceTraffic::default());
        assert_eq!(back.outcome.baseline.median, r.outcome.baseline.median);
        assert_eq!(back.outcome.baseline.reps, r.outcome.baseline.reps);
        // v2: the backend-arbitration section round-trips in full.
        assert_eq!(back.arbitration, r.arbitration);
        assert_eq!(back.backend(), Backend::Fpga);
    }

    #[test]
    fn top_level_backend_must_agree_with_arbitration() {
        let r = sample_report();
        let tampered = report_to_string(&r).replace(
            "\"backend\": \"fpga\",\n  \"blocks\"",
            "\"backend\": \"gpu\",\n  \"blocks\"",
        );
        assert_ne!(tampered, report_to_string(&r), "tamper point must exist");
        assert!(report_from_str(&tampered).is_err());
    }

    #[test]
    fn serialization_is_byte_stable() {
        // to_string ∘ from_str must be the identity on serialized output:
        // the decision cache's byte-identical guarantee rests on this.
        let once = report_to_string(&sample_report());
        let twice = report_to_string(&report_from_str(&once).unwrap());
        assert_eq!(once, twice);
    }

    #[test]
    fn rejects_other_formats() {
        assert!(report_from_str(r#"{"format": "something-else"}"#).is_err());
        assert!(report_from_str("not json").is_err());
    }

    #[test]
    fn power_residue_upgrades_the_report_to_v3() {
        use crate::coordinator::power::{BlockEnergy, PowerDecision, PowerPolicy};

        // The default report is v2 with no power section at all.
        let perf = sample_report();
        let perf_text = report_to_string(&perf);
        assert!(perf_text.contains(REPORT_FORMAT));
        assert!(!perf_text.contains("\"power\""), "{perf_text}");

        // A non-default power policy lifts the format to v3 and records
        // the per-block energies; the codec stays byte-stable.
        let mut powered = sample_report();
        powered.arbitration.power = Some(PowerDecision {
            policy: PowerPolicy::PerfPerWatt,
            gpu_watts: 75.0,
            fpga_watts: 40.0,
            blocks: vec![
                BlockEnergy {
                    label: "call:fft2d".into(),
                    gpu_energy_j: Some(7.125e-3),
                    fpga_energy_j: Some(2.5e-3),
                },
                BlockEnergy {
                    label: "func:my_decomp".into(),
                    gpu_energy_j: None,
                    fpga_energy_j: None,
                },
            ],
        });
        let text = report_to_string(&powered);
        assert!(text.contains(REPORT_FORMAT_V3));
        assert!(text.contains("\"power\""));
        assert!(text.contains("fpga_energy_j"));
        let back = report_from_str(&text).unwrap();
        assert_eq!(back.arbitration, powered.arbitration);
        assert_eq!(report_to_string(&back), text, "v3 must be byte-stable");

        // Tag ↔ payload agreement is enforced both ways.
        let tag_without_power = perf_text.replace(REPORT_FORMAT, REPORT_FORMAT_V3);
        assert!(report_from_str(&tag_without_power).is_err());
        let power_without_tag = text.replace(REPORT_FORMAT_V3, REPORT_FORMAT);
        assert!(report_from_str(&power_without_tag).is_err());
    }

    #[test]
    fn estimate_residue_upgrades_the_report_to_v4() {
        use crate::coordinator::estimate::{BlockPrediction, EstimateDecision, PrunePolicy};

        // The default report carries no estimate section at all.
        let plain = sample_report();
        let plain_text = report_to_string(&plain);
        assert!(!plain_text.contains("\"estimate\""), "{plain_text}");

        // A non-default estimator configuration lifts the format to v4
        // and records per-block predicted-vs-measured error; the codec
        // stays byte-stable.
        let mut estimated = sample_report();
        estimated.arbitration.estimate = Some(EstimateDecision {
            policy: PrunePolicy::Conservative(0.5),
            gpu_profile: "gtx-1050-ti".into(),
            fpga_profile: "arria10-gx-1150".into(),
            mape: Some(0.35),
            blocks: vec![
                BlockPrediction {
                    label: "call:fft2d".into(),
                    backend: Backend::Gpu,
                    predicted_secs: 1.5e-4,
                    measured_secs: Some(1.2e-4),
                    error: Some(0.25),
                },
                BlockPrediction {
                    label: "func:my_decomp".into(),
                    backend: Backend::Cpu,
                    predicted_secs: 2.0e-3,
                    measured_secs: None,
                    error: None,
                },
            ],
        });
        let text = report_to_string(&estimated);
        assert!(text.contains(REPORT_FORMAT_V4));
        assert!(text.contains("\"estimate\""));
        assert!(text.contains("predicted_secs"));
        let back = report_from_str(&text).unwrap();
        assert_eq!(back.arbitration, estimated.arbitration);
        assert_eq!(report_to_string(&back), text, "v4 must be byte-stable");

        // Tag ↔ payload agreement is enforced both ways.
        let tag_without_estimate = plain_text.replace(REPORT_FORMAT, REPORT_FORMAT_V4);
        assert!(report_from_str(&tag_without_estimate).is_err());
        let estimate_without_tag = text.replace(REPORT_FORMAT_V4, REPORT_FORMAT);
        assert!(report_from_str(&estimate_without_tag).is_err());

        // A v4 report may also carry the power residue: both survive.
        let mut both = estimated.clone();
        both.arbitration.power = Some(power::PowerDecision {
            policy: power::PowerPolicy::PerfPerWatt,
            gpu_watts: 75.0,
            fpga_watts: 40.0,
            blocks: Vec::new(),
        });
        let both_text = report_to_string(&both);
        assert!(both_text.contains(REPORT_FORMAT_V4));
        assert!(both_text.contains("\"power\""));
        let both_back = report_from_str(&both_text).unwrap();
        assert_eq!(both_back.arbitration, both.arbitration);
        assert_eq!(report_to_string(&both_back), both_text);
    }

    #[test]
    fn residency_residue_upgrades_the_report_to_v5() {
        use crate::coordinator::residency::{BlockResidency, ResidencyDecision};

        // The default report carries no residency section at all.
        let plain = sample_report();
        let plain_text = report_to_string(&plain);
        assert!(!plain_text.contains("\"residency\""), "{plain_text}");
        assert!(!plain_text.contains("elided_in"), "{plain_text}");

        // A nonzero resident-bytes budget lifts the format to v5, records
        // per-block elided traffic + the transfer credit, and the traffic
        // sections gain their elided keys; the codec stays byte-stable.
        let mut resident = sample_report();
        resident.outcome.tried[0].traffic.elided_in = 16384;
        resident.outcome.tried[0].traffic.elided_out = 32768;
        resident.arbitration.residency = Some(ResidencyDecision {
            budget_bytes: 64 << 20,
            blocks: vec![BlockResidency {
                label: "only:call:fft2d".into(),
                elided_in: 16384,
                elided_out: 32768,
                saved_transfer_secs: 8.192e-6,
            }],
            total_saved_transfer_secs: 8.192e-6,
        });
        let text = report_to_string(&resident);
        assert!(text.contains(REPORT_FORMAT_V5));
        assert!(text.contains("\"residency\""));
        assert!(text.contains("saved_transfer_secs"));
        assert!(text.contains("\"elided_in\""));
        let back = report_from_str(&text).unwrap();
        assert_eq!(back.arbitration, resident.arbitration);
        assert_eq!(back.outcome.tried[0].traffic, resident.outcome.tried[0].traffic);
        assert_eq!(report_to_string(&back), text, "v5 must be byte-stable");

        // Tag ↔ payload agreement is enforced both ways.
        let tag_without_residency = plain_text.replace(REPORT_FORMAT, REPORT_FORMAT_V5);
        assert!(report_from_str(&tag_without_residency).is_err());
        let residency_without_tag = text.replace(REPORT_FORMAT_V5, REPORT_FORMAT);
        assert!(report_from_str(&residency_without_tag).is_err());

        // A v5 report may also carry the power and estimate residues.
        use crate::coordinator::estimate::{EstimateDecision, PrunePolicy};
        let mut all = resident.clone();
        all.arbitration.power = Some(power::PowerDecision {
            policy: power::PowerPolicy::PerfPerWatt,
            gpu_watts: 75.0,
            fpga_watts: 40.0,
            blocks: Vec::new(),
        });
        all.arbitration.estimate = Some(EstimateDecision {
            policy: PrunePolicy::Aggressive,
            gpu_profile: "gtx-1050-ti".into(),
            fpga_profile: "arria10-gx-1150".into(),
            mape: None,
            blocks: Vec::new(),
        });
        let all_text = report_to_string(&all);
        assert!(all_text.contains(REPORT_FORMAT_V5));
        assert!(all_text.contains("\"power\"") && all_text.contains("\"estimate\""));
        let all_back = report_from_str(&all_text).unwrap();
        assert_eq!(all_back.arbitration, all.arbitration);
        assert_eq!(report_to_string(&all_back), all_text);
    }

    #[test]
    fn v1_reports_still_decode_and_upgrade() {
        // Shape a v1 document from the sample: same blocks/outcome, no
        // backend/arbitration sections, no per-pattern traffic.
        let r = sample_report();
        let mut top = report_to_json(&r).as_obj().unwrap().clone();
        top.insert("format".to_string(), Json::str(REPORT_FORMAT_V1));
        top.remove("backend");
        top.remove("arbitration");
        if let Some(Json::Obj(outcome)) = top.get_mut("outcome") {
            if let Some(Json::Arr(tried)) = outcome.get_mut("tried") {
                for p in tried {
                    if let Json::Obj(po) = p {
                        po.remove("traffic");
                    }
                }
            }
        }
        let v1_text = json::to_string_pretty(&Json::Obj(top));

        let back = report_from_str(&v1_text).unwrap();
        assert_eq!(back.entry, r.entry);
        assert_eq!(back.outcome.best_speedup, r.outcome.best_speedup);
        assert_eq!(back.outcome.tried[0].traffic, DeviceTraffic::default());
        // Synthesized arbitration: GPU-only policy, no per-block detail,
        // overall backend from the winning pattern.
        assert_eq!(back.arbitration.policy, BackendPolicy::Gpu);
        assert_eq!(back.backend(), Backend::Gpu);
        assert!(back.arbitration.blocks.is_empty());
        assert_eq!(back.arbitration.simulated_hours, 0.0);
        // Re-encoding upgrades to v2 and is then byte-stable.
        let upgraded = report_to_string(&back);
        assert!(upgraded.contains(REPORT_FORMAT));
        assert_eq!(report_to_string(&report_from_str(&upgraded).unwrap()), upgraded);
    }
}
