//! GA loop-offload search over a real application (the [33] baseline).
//!
//! Bridges the GA to the verification environment: genes are the
//! parallelizable loops found by analysis, fitness is the measured
//! wall-clock of the interpreted application with the selected loops
//! running on the bulk (simulated-GPU) executor.

use std::collections::HashSet;
use std::time::Duration;

use anyhow::Result;

use crate::analysis;
use crate::ga::{self, GaConfig, GaResult};
use crate::interp::Interp;
use crate::parser::{NodeId, Program};

/// Outcome of the GA search, with gene→loop mapping for reporting.
#[derive(Debug, Clone)]
pub struct LoopSearchResult {
    /// The GA search result (best gene, history).
    pub ga: GaResult,
    /// NodeIds of the loops, index-aligned with genes.
    pub loop_ids: Vec<NodeId>,
    /// Human labels ("function:line") per gene.
    pub loop_labels: Vec<String>,
}

impl LoopSearchResult {
    /// Loop ids selected by the best gene.
    pub fn best_loops(&self) -> HashSet<NodeId> {
        self.loop_ids
            .iter()
            .zip(&self.ga.best_gene)
            .filter(|(_, &on)| on)
            .map(|(id, _)| *id)
            .collect()
    }
}

/// Run the GA loop-offload search on `prog`/`entry`.
///
/// `reps` measured repetitions per individual (the paper uses one
/// verification run per individual; median-of-k is available for noisy
/// hosts).
pub fn ga_loop_search(
    prog: &Program,
    entry: &str,
    cfg: &GaConfig,
    reps: usize,
    fuel: u64,
) -> Result<LoopSearchResult> {
    let a = analysis::analyze(prog);
    let genes: Vec<_> = a.parallel_loops().into_iter().cloned().collect();
    let loop_ids: Vec<NodeId> = genes.iter().map(|l| l.id).collect();
    let loop_labels: Vec<String> = genes
        .iter()
        .map(|l| format!("{}:{} ({:?})", l.in_function, l.span, l.class))
        .collect();

    let mut interp = Interp::new(prog)?;
    interp.fuel = fuel;

    let mut fitness = |gene: &[bool]| -> Result<Duration> {
        let selected: HashSet<NodeId> = loop_ids
            .iter()
            .zip(gene)
            .filter(|(_, &on)| on)
            .map(|(id, _)| *id)
            .collect();
        interp.set_offloaded_loops(selected);
        let mut times = Vec::with_capacity(reps.max(1));
        for _ in 0..reps.max(1) {
            interp.reset_run_state()?;
            let t0 = std::time::Instant::now();
            interp.run(entry, &[])?;
            times.push(t0.elapsed());
        }
        times.sort();
        Ok(times[times.len() / 2])
    };

    let ga = ga::run(loop_ids.len(), cfg, &mut fitness)?;
    Ok(LoopSearchResult { ga, loop_ids, loop_labels })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parser::parse;

    /// App with one big offload-friendly loop nest and one tiny loop where
    /// transfer+launch overhead dominates.
    const APP: &str = "
        int main() {
            double a[96][96]; double b[96][96];
            double small[8];
            for (int i = 0; i < 96; i++)
                for (int j = 0; j < 96; j++)
                    a[i][j] = sin(0.01 * i) * cos(0.01 * j) + 1.0;
            for (int i = 0; i < 96; i++)
                for (int j = 0; j < 96; j++)
                    b[i][j] = sqrt(a[i][j]) * 2.0 + a[i][j] * a[i][j];
            for (int k = 0; k < 8; k++)
                small[k] = k * 2.0;
            double s = 0.0;
            for (int i = 0; i < 96; i++)
                for (int j = 0; j < 96; j++)
                    s += b[i][j];
            return s;
        }";

    #[test]
    fn ga_search_finds_loops_and_improves() {
        let prog = parse(APP).unwrap();
        let cfg = GaConfig { population: 8, generations: 5, ..Default::default() };
        let r = ga_loop_search(&prog, "main", &cfg, 1, u64::MAX).unwrap();
        assert!(r.loop_ids.len() >= 3, "genes: {:?}", r.loop_labels);
        // The measured best must beat (or match) the all-CPU baseline.
        assert!(
            r.ga.best_speedup() >= 1.0,
            "best speedup {}",
            r.ga.best_speedup()
        );
        assert_eq!(r.ga.history.len(), 5);
    }

    #[test]
    fn best_loops_maps_genes_to_ids() {
        let prog = parse(APP).unwrap();
        let cfg = GaConfig { population: 6, generations: 3, ..Default::default() };
        let r = ga_loop_search(&prog, "main", &cfg, 1, u64::MAX).unwrap();
        let best = r.best_loops();
        for id in &best {
            assert!(r.loop_ids.contains(id));
        }
    }
}
