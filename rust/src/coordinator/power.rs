//! Power model for the power-aware arbitration stage.
//!
//! The source paper motivates GPU/FPGA offloading with power efficiency
//! relative to CPUs, and its companion power study (Yamato, *Power Saving
//! Evaluation with Automatic Offloading*, arXiv:2110.11520) makes the
//! selection criterion explicit: automatic offloading should place a
//! block where its **performance-per-watt** is best, measured as the
//! ratio of baseline CPU energy to offloaded energy for the same work.
//! This module supplies the wattage models and energy arithmetic the
//! pipeline's `PowerScore` stage (between `Verified` and `Arbitrated`)
//! and the Step-3b arbitration consume:
//!
//! * [`DevicePower`] / [`PowerModel`] — per-device wattage models (CPU
//!   baseline, GPU, FPGA), registered alongside the FPGA device model on
//!   the coordinator and the service config;
//! * [`PowerPolicy`] — the CLI `--power-policy` knob: `perf` (default,
//!   byte-identical to time-only arbitration), `perf-per-watt` (energy
//!   decides), `cap:<watts>` (backends over the cap are excluded);
//! * [`EnergyEstimate`] / [`PowerOutcome`] — the scored result: energy =
//!   watts × measured `exec_secs`, plus idle and transfer overheads, for
//!   the all-CPU baseline and every surviving measured pattern.
//!
//! Energy figures are *modeled*, the same substitution discipline as the
//! simulated HLS chain (DESIGN.md "Substitutions"): measured seconds are
//! real, watts come from the device model. Relative comparisons (the
//! paper's power-efficiency ratios) carry over; absolute joules are not
//! lab measurements.

use anyhow::{bail, Result};

use crate::patterndb::json::Json;
use crate::telemetry::TraceEvent;

use super::backend::Backend;
use super::verify::{DeviceTraffic, SearchOutcome};

/// Wattage model of one device class.
#[derive(Debug, Clone, PartialEq)]
pub struct DevicePower {
    /// Device name (diagnostics and fingerprints).
    pub name: String,
    /// Draw while idle but powered (W).
    pub idle_watts: f64,
    /// Draw while executing a block (W).
    pub active_watts: f64,
    /// Additional draw while moving data over PCIe (W); zero for the
    /// host CPU, which has no staging phase.
    pub transfer_watts: f64,
}

/// Per-device wattage models the power stage scores against — registered
/// alongside the FPGA device model on the coordinator / service config,
/// and folded into the power-tier cache fingerprint.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerModel {
    /// The all-CPU baseline host.
    pub cpu: DevicePower,
    /// The measured PJRT ("GPU") path.
    pub gpu: DevicePower,
    /// The modeled FPGA path.
    pub fpga: DevicePower,
}

impl PowerModel {
    /// Built-in model calibrated to the paper's hardware class: a
    /// Xeon-class verification host, the GeForce GTX 1050 Ti (75 W TDP)
    /// standing behind the measured PJRT path, and the Arria10 PAC card
    /// (≈40 W under load — the power asymmetry arXiv:2110.11520 measures).
    pub fn builtin() -> PowerModel {
        PowerModel {
            cpu: DevicePower {
                name: "Xeon-class host".to_string(),
                idle_watts: 15.0,
                active_watts: 65.0,
                transfer_watts: 0.0,
            },
            gpu: DevicePower {
                name: "GeForce GTX 1050 Ti".to_string(),
                idle_watts: 8.0,
                active_watts: 75.0,
                transfer_watts: 10.0,
            },
            fpga: DevicePower {
                name: "Intel PAC Arria10 GX".to_string(),
                idle_watts: 12.0,
                active_watts: 40.0,
                transfer_watts: 8.0,
            },
        }
    }

    /// The wattage model of one backend.
    pub fn for_backend(&self, backend: Backend) -> &DevicePower {
        match backend {
            Backend::Cpu => &self.cpu,
            Backend::Gpu => &self.gpu,
            Backend::Fpga => &self.fpga,
        }
    }

    /// Stable digest blob for the cache fingerprints (name + the three
    /// wattages per device, in fixed order).
    pub fn fingerprint_blob(&self) -> String {
        let one = |d: &DevicePower| {
            format!("{}/{}/{}/{}", d.name, d.idle_watts, d.active_watts, d.transfer_watts)
        };
        format!("cpu:{}|gpu:{}|fpga:{}", one(&self.cpu), one(&self.gpu), one(&self.fpga))
    }

    /// Every wattage must be finite and non-negative, and active draws
    /// strictly positive (energy ratios divide by them).
    pub fn validate(&self) -> Result<()> {
        for d in [&self.cpu, &self.gpu, &self.fpga] {
            let all = [d.idle_watts, d.active_watts, d.transfer_watts];
            if all.iter().any(|w| !w.is_finite() || *w < 0.0) || d.active_watts <= 0.0 {
                bail!(
                    "power model for {:?} needs finite non-negative wattages \
                     and a positive active draw",
                    d.name
                );
            }
        }
        Ok(())
    }
}

/// How arbitration weighs power (CLI `--power-policy`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum PowerPolicy {
    /// Time decides, exactly as before this stage existed. The default:
    /// decisions and cached report bytes are identical to a pipeline
    /// without power scoring.
    #[default]
    Perf,
    /// Performance-per-watt decides: a backend wins a block when it costs
    /// less energy for the same work (arXiv:2110.11520's selection rule).
    PerfPerWatt,
    /// Hard wattage cap: backends whose modeled active draw exceeds the
    /// cap are excluded; time decides among the rest (CPU always remains
    /// as the fallback — the work has to run somewhere).
    Cap(f64),
}

impl PowerPolicy {
    /// Canonical rendering (CLI and cache fingerprint): `perf`,
    /// `perf-per-watt`, or `cap:<watts>`.
    pub fn render(&self) -> String {
        match self {
            PowerPolicy::Perf => "perf".to_string(),
            PowerPolicy::PerfPerWatt => "perf-per-watt".to_string(),
            PowerPolicy::Cap(w) => format!("cap:{w}"),
        }
    }

    /// Inverse of [`PowerPolicy::render`].
    pub fn parse(s: &str) -> Result<Self> {
        if let Some(w) = s.strip_prefix("cap:") {
            let watts: f64 = w
                .parse()
                .map_err(|_| anyhow::anyhow!("--power-policy cap expects a number, got {w:?}"))?;
            if !watts.is_finite() || watts <= 0.0 {
                bail!("--power-policy cap expects a positive wattage, got {w:?}");
            }
            return Ok(PowerPolicy::Cap(watts));
        }
        Ok(match s {
            "perf" => PowerPolicy::Perf,
            "perf-per-watt" => PowerPolicy::PerfPerWatt,
            other => bail!("unknown --power-policy {other:?} (perf|perf-per-watt|cap:<watts>)"),
        })
    }

    /// True for the default (`perf`) policy, which must leave decisions,
    /// report bytes, and cache fingerprints untouched.
    pub fn is_default(&self) -> bool {
        matches!(self, PowerPolicy::Perf)
    }
}

/// Modeled energy of one pattern run on one backend.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyEstimate {
    /// Average draw across the run (W).
    pub watts: f64,
    /// Energy per run (J): watts × measured seconds, idle and transfer
    /// overheads included.
    pub energy_j: f64,
    /// Power-efficiency ratio vs the all-CPU baseline — baseline joules
    /// over this run's joules (arXiv:2110.11520's metric; >1 means the
    /// offload saves energy for the same work).
    pub efficiency: f64,
    /// Performance-per-watt: the pattern's speedup divided by its average
    /// draw (runs/s/W, normalized to the baseline's runtime).
    pub perf_per_watt: f64,
}

/// Power scores of one measured pattern (one surviving candidate block).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockPowerScore {
    /// Pattern label (matches `SearchOutcome::tried`).
    pub label: String,
    /// Modeled energy of the measured pattern run. `None` when the
    /// pattern never dispatched (nothing to attribute device energy to).
    pub gpu: Option<EnergyEstimate>,
}

/// The `PowerScore` stage result: every surviving measured pattern scored
/// on performance-per-watt against the all-CPU baseline.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerOutcome {
    /// Policy the downstream arbitration will weigh power under.
    pub policy: PowerPolicy,
    /// Wattage models the scores were computed from.
    pub model: PowerModel,
    /// Energy of one all-CPU baseline run.
    pub baseline: EnergyEstimate,
    /// Per-pattern scores, aligned with `SearchOutcome::tried`.
    pub blocks: Vec<BlockPowerScore>,
}

/// Modeled device-side energy of one block execution: active draw over
/// the executing seconds plus transfer draw over the PCIe-staging
/// seconds. Used symmetrically for the measured GPU seconds and the
/// modeled FPGA estimate.
pub fn device_energy(device: &DevicePower, exec_secs: f64, transfer_secs: f64) -> f64 {
    device.active_watts * exec_secs + device.transfer_watts * transfer_secs
}

/// PCIe-staging seconds implied by a pattern's observed per-run traffic.
/// Counts *paid* bytes only — residency-elided bytes never enter, which is
/// exactly how arbitration credits transfers the data plane saved (the
/// residency residue prices the elided bytes with this same constant).
pub fn transfer_secs(traffic: &DeviceTraffic) -> f64 {
    (traffic.bytes_in + traffic.bytes_out) as f64 / crate::fpga::PCIE_BYTES_PER_SEC
}

/// Modeled energy of one whole pattern run: the host draws its active
/// wattage for the non-device portion, the accelerator draws its active
/// wattage for `device_secs` (plus transfer draw for the staging time)
/// and idles for the host portion.
pub fn pattern_energy(
    model: &PowerModel,
    device: &DevicePower,
    pattern_secs: f64,
    device_secs: f64,
    traffic: &DeviceTraffic,
) -> f64 {
    let host_secs = (pattern_secs - device_secs).max(0.0);
    model.cpu.active_watts * host_secs
        + device.idle_watts * host_secs
        + device_energy(device, device_secs, transfer_secs(traffic))
}

fn estimate(
    baseline_j: f64,
    baseline_secs: f64,
    pattern_secs: f64,
    energy_j: f64,
) -> EnergyEstimate {
    let secs = pattern_secs.max(1e-12);
    let watts = energy_j / secs;
    EnergyEstimate {
        watts,
        energy_j,
        efficiency: baseline_j / energy_j.max(1e-12),
        perf_per_watt: (baseline_secs / secs) / watts.max(1e-12),
    }
}

/// Score a measured search outcome: the all-CPU baseline plus every tried
/// pattern, each as modeled joules per run and performance-per-watt. The
/// `policy` is carried through for the arbitration stage; scoring itself
/// is policy-independent.
pub fn score(model: &PowerModel, policy: PowerPolicy, outcome: &SearchOutcome) -> PowerOutcome {
    let baseline_secs = outcome.baseline.secs();
    let baseline_j = model.cpu.active_watts * baseline_secs;
    let baseline = estimate(baseline_j, baseline_secs, baseline_secs, baseline_j);
    let blocks = outcome
        .tried
        .iter()
        .map(|p| BlockPowerScore {
            label: p.label.clone(),
            gpu: (p.traffic.dispatches > 0).then(|| {
                let secs = p.time.secs();
                let j = pattern_energy(
                    model,
                    &model.gpu,
                    secs,
                    p.traffic.device_secs,
                    &p.traffic,
                );
                estimate(baseline_j, baseline_secs, secs, j)
            }),
        })
        .collect();
    PowerOutcome { policy, model: model.clone(), baseline, blocks }
}

/// Structured telemetry events of one `PowerScore` stage: the all-CPU
/// baseline energy first, then every scored pattern that dispatched.
/// Built lazily by the pipeline only when a
/// [`crate::coordinator::StageObserver`] is installed.
pub fn power_events(scores: &PowerOutcome) -> Vec<TraceEvent> {
    let one = |label: &str, e: &EnergyEstimate| TraceEvent::PowerScored {
        label: label.to_string(),
        watts: e.watts,
        joules: e.energy_j,
        efficiency: e.efficiency,
    };
    let mut out = vec![one("all-CPU", &scores.baseline)];
    out.extend(
        scores.blocks.iter().filter_map(|b| b.gpu.as_ref().map(|e| one(&b.label, e))),
    );
    out
}

// ------------------------------------------------- arbitration residue

/// Per-block energy record the arbitration writes into the (v3) report
/// when a non-default power policy decided backends.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockEnergy {
    /// Site label of the block (matches the arbitration blocks).
    pub label: String,
    /// Modeled joules per run of the block on the measured GPU path
    /// (`None` when the pattern never dispatched).
    pub gpu_energy_j: Option<f64>,
    /// Modeled joules per run of the block on the FPGA estimate (`None`
    /// without a pre-check-passing IP core).
    pub fpga_energy_j: Option<f64>,
}

/// The power residue of one arbitration run under a non-default policy:
/// which policy decided, the deployment draw per backend instance, and
/// the per-block energies the decision compared. Serialized into the v3
/// report; absent (and the report stays v2) under the default `perf`
/// policy.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerDecision {
    /// The non-default policy that decided.
    pub policy: PowerPolicy,
    /// Modeled draw of one GPU deployment instance (W).
    pub gpu_watts: f64,
    /// Modeled draw of one FPGA deployment instance (W).
    pub fpga_watts: f64,
    /// Per-block energy comparisons, aligned with the arbitration blocks.
    pub blocks: Vec<BlockEnergy>,
}

// ----------------------------------------------------------- JSON codec

fn opt_num_to_json(v: Option<f64>) -> Json {
    v.map(Json::num).unwrap_or(Json::Null)
}

fn device_power_to_json(d: &DevicePower) -> Json {
    Json::obj(vec![
        ("name", Json::str(&d.name)),
        ("idle_watts", Json::num(d.idle_watts)),
        ("active_watts", Json::num(d.active_watts)),
        ("transfer_watts", Json::num(d.transfer_watts)),
    ])
}

fn device_power_from_json(v: &Json) -> Result<DevicePower> {
    Ok(DevicePower {
        name: v.get("name")?.as_str()?.to_string(),
        idle_watts: v.get("idle_watts")?.as_f64()?,
        active_watts: v.get("active_watts")?.as_f64()?,
        transfer_watts: v.get("transfer_watts")?.as_f64()?,
    })
}

/// Serialize a wattage model (stage artifacts and the v3 report).
pub fn model_to_json(m: &PowerModel) -> Json {
    Json::obj(vec![
        ("cpu", device_power_to_json(&m.cpu)),
        ("gpu", device_power_to_json(&m.gpu)),
        ("fpga", device_power_to_json(&m.fpga)),
    ])
}

/// Inverse of [`model_to_json`].
pub fn model_from_json(v: &Json) -> Result<PowerModel> {
    Ok(PowerModel {
        cpu: device_power_from_json(v.get("cpu")?)?,
        gpu: device_power_from_json(v.get("gpu")?)?,
        fpga: device_power_from_json(v.get("fpga")?)?,
    })
}

fn energy_to_json(e: &EnergyEstimate) -> Json {
    Json::obj(vec![
        ("watts", Json::num(e.watts)),
        ("energy_j", Json::num(e.energy_j)),
        ("efficiency", Json::num(e.efficiency)),
        ("perf_per_watt", Json::num(e.perf_per_watt)),
    ])
}

fn energy_from_json(v: &Json) -> Result<EnergyEstimate> {
    Ok(EnergyEstimate {
        watts: v.get("watts")?.as_f64()?,
        energy_j: v.get("energy_j")?.as_f64()?,
        efficiency: v.get("efficiency")?.as_f64()?,
        perf_per_watt: v.get("perf_per_watt")?.as_f64()?,
    })
}

/// Serialize a stage outcome (the `PowerScored` artifact payload).
pub fn outcome_to_json(o: &PowerOutcome) -> Json {
    Json::obj(vec![
        ("policy", Json::str(&o.policy.render())),
        ("model", model_to_json(&o.model)),
        ("baseline", energy_to_json(&o.baseline)),
        (
            "blocks",
            Json::Arr(
                o.blocks
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("label", Json::str(&b.label)),
                            (
                                "gpu",
                                b.gpu.as_ref().map(energy_to_json).unwrap_or(Json::Null),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Inverse of [`outcome_to_json`].
pub fn outcome_from_json(v: &Json) -> Result<PowerOutcome> {
    Ok(PowerOutcome {
        policy: PowerPolicy::parse(v.get("policy")?.as_str()?)?,
        model: model_from_json(v.get("model")?)?,
        baseline: energy_from_json(v.get("baseline")?)?,
        blocks: v
            .get("blocks")?
            .as_arr()?
            .iter()
            .map(|b| {
                Ok(BlockPowerScore {
                    label: b.get("label")?.as_str()?.to_string(),
                    gpu: b.opt("gpu").map(energy_from_json).transpose()?,
                })
            })
            .collect::<Result<_>>()?,
    })
}

/// Serialize the arbitration's power residue (v3 report section).
pub fn decision_to_json(d: &PowerDecision) -> Json {
    Json::obj(vec![
        ("policy", Json::str(&d.policy.render())),
        ("gpu_watts", Json::num(d.gpu_watts)),
        ("fpga_watts", Json::num(d.fpga_watts)),
        (
            "blocks",
            Json::Arr(
                d.blocks
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("label", Json::str(&b.label)),
                            ("gpu_energy_j", opt_num_to_json(b.gpu_energy_j)),
                            ("fpga_energy_j", opt_num_to_json(b.fpga_energy_j)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Inverse of [`decision_to_json`].
pub fn decision_from_json(v: &Json) -> Result<PowerDecision> {
    let opt_num = |b: &Json, key: &str| -> Result<Option<f64>> {
        b.opt(key).map(|n| n.as_f64()).transpose()
    };
    Ok(PowerDecision {
        policy: PowerPolicy::parse(v.get("policy")?.as_str()?)?,
        gpu_watts: v.get("gpu_watts")?.as_f64()?,
        fpga_watts: v.get("fpga_watts")?.as_f64()?,
        blocks: v
            .get("blocks")?
            .as_arr()?
            .iter()
            .map(|b| {
                Ok(BlockEnergy {
                    label: b.get("label")?.as_str()?.to_string(),
                    gpu_energy_j: opt_num(b, "gpu_energy_j")?,
                    fpga_energy_j: opt_num(b, "fpga_energy_j")?,
                })
            })
            .collect::<Result<_>>()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::verify::PatternResult;
    use crate::metrics::Measurement;
    use crate::patterndb::json;
    use std::time::Duration;

    fn outcome(pattern_us: u64, device_secs: f64) -> SearchOutcome {
        let m = |label: &str, us: u64| Measurement {
            label: label.to_string(),
            median: Duration::from_micros(us),
            min: Duration::from_micros(us),
            max: Duration::from_micros(us),
            reps: 1,
        };
        SearchOutcome {
            baseline: m("all-CPU", 100_000),
            tried: vec![PatternResult {
                enabled: vec![true],
                label: "only:call:fft2d".into(),
                time: m("only:call:fft2d", pattern_us),
                speedup: 100_000.0 / pattern_us as f64,
                output_ok: true,
                traffic: DeviceTraffic {
                    bytes_in: 1 << 20,
                    bytes_out: 1 << 20,
                    dispatches: 1,
                    device_secs,
                    ..Default::default()
                },
            }],
            best_enabled: vec![true],
            best_time: m("only:call:fft2d", pattern_us),
            best_speedup: 100_000.0 / pattern_us as f64,
        }
    }

    #[test]
    fn policy_renders_and_parses() {
        for p in [PowerPolicy::Perf, PowerPolicy::PerfPerWatt, PowerPolicy::Cap(47.5)] {
            assert_eq!(PowerPolicy::parse(&p.render()).unwrap(), p);
        }
        assert!(PowerPolicy::Perf.is_default());
        assert!(!PowerPolicy::PerfPerWatt.is_default());
        assert!(PowerPolicy::parse("cap:0").is_err(), "cap must be positive");
        assert!(PowerPolicy::parse("cap:-3").is_err());
        assert!(PowerPolicy::parse("cap:watts").is_err());
        assert!(PowerPolicy::parse("speed").is_err());
    }

    #[test]
    fn builtin_model_validates_and_orders_draws() {
        let m = PowerModel::builtin();
        m.validate().unwrap();
        // The power asymmetry the paper measures: FPGA draws far less than
        // the GPU under load; the host sits in between.
        assert!(m.fpga.active_watts < m.cpu.active_watts);
        assert!(m.cpu.active_watts < m.gpu.active_watts);
        let mut bad = m.clone();
        bad.gpu.active_watts = 0.0;
        assert!(bad.validate().is_err());
        let mut neg = PowerModel::builtin();
        neg.fpga.idle_watts = -1.0;
        assert!(neg.validate().is_err());
    }

    #[test]
    fn fingerprint_blob_tracks_every_wattage() {
        let base = PowerModel::builtin().fingerprint_blob();
        let mut m = PowerModel::builtin();
        m.fpga.active_watts += 1.0;
        assert_ne!(m.fingerprint_blob(), base);
        assert_eq!(PowerModel::builtin().fingerprint_blob(), base, "deterministic");
    }

    #[test]
    fn scoring_prices_energy_and_efficiency() {
        let model = PowerModel::builtin();
        // 100 ms baseline, 2 ms pattern with 1 ms on the device: a huge
        // speedup must also be a huge efficiency gain.
        let o = outcome(2_000, 0.001);
        let scored = score(&model, PowerPolicy::PerfPerWatt, &o);
        assert_eq!(scored.baseline.efficiency, 1.0);
        assert!((scored.baseline.watts - model.cpu.active_watts).abs() < 1e-9);
        let gpu = scored.blocks[0].gpu.as_ref().unwrap();
        assert!(gpu.energy_j < scored.baseline.energy_j);
        assert!(gpu.efficiency > 10.0, "efficiency {}", gpu.efficiency);
        assert!(gpu.perf_per_watt > scored.baseline.perf_per_watt);

        // A pattern *slower* than the baseline burns more joules than it.
        let slow = score(&model, PowerPolicy::Perf, &outcome(200_000, 0.15));
        let gpu = slow.blocks[0].gpu.as_ref().unwrap();
        assert!(gpu.efficiency < 1.0, "efficiency {}", gpu.efficiency);
    }

    #[test]
    fn undispatched_patterns_have_no_gpu_score() {
        let model = PowerModel::builtin();
        let mut o = outcome(2_000, 0.001);
        o.tried[0].traffic = DeviceTraffic::default();
        let scored = score(&model, PowerPolicy::Perf, &o);
        assert!(scored.blocks[0].gpu.is_none());
    }

    #[test]
    fn outcome_codec_round_trips() {
        let scored = score(
            &PowerModel::builtin(),
            PowerPolicy::Cap(50.0),
            &outcome(2_000, 0.001),
        );
        let s = json::to_string_pretty(&outcome_to_json(&scored));
        let back = outcome_from_json(&json::parse(&s).unwrap()).unwrap();
        assert_eq!(back, scored);
        assert_eq!(json::to_string_pretty(&outcome_to_json(&back)), s, "byte-stable");
    }

    #[test]
    fn decision_codec_round_trips() {
        let d = PowerDecision {
            policy: PowerPolicy::PerfPerWatt,
            gpu_watts: 75.0,
            fpga_watts: 40.0,
            blocks: vec![
                BlockEnergy {
                    label: "call:fft2d".into(),
                    gpu_energy_j: Some(0.75),
                    fpga_energy_j: Some(0.0025),
                },
                BlockEnergy { label: "func:mm".into(), gpu_energy_j: None, fpga_energy_j: None },
            ],
        };
        let s = json::to_string_pretty(&decision_to_json(&d));
        let back = decision_from_json(&json::parse(&s).unwrap()).unwrap();
        assert_eq!(back, d);
        assert_eq!(json::to_string_pretty(&decision_to_json(&back)), s);
    }
}
