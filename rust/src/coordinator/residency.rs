//! Residency residue of one arbitration run (the v5 report section).
//!
//! When a device-resident data plane is active (`--resident-bytes > 0`),
//! Step 3 measures each pattern's traffic split into paid and elided
//! bytes ([`DeviceTraffic`]). This module turns that split into the
//! arbitration-level claim the report carries: how many host<->device
//! bytes the residency map elided per block, and how much PCIe staging
//! time that saves — priced with the same
//! [`crate::fpga::PCIE_BYTES_PER_SEC`] constant the power model already
//! uses for paid transfers ([`crate::coordinator::power::transfer_secs`]),
//! so the credit and the cost share one arithmetic.
//!
//! The residue is `None` (and the report stays at its pre-residency
//! version) whenever the plane is off — the same passivity discipline as
//! the power and estimate residues.

use anyhow::Result;

use crate::patterndb::json::Json;
use crate::telemetry::TraceEvent;

use super::verify::{DeviceTraffic, SearchOutcome};

/// Per-block residency record, aligned with the arbitration blocks.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockResidency {
    /// Site label of the block (matches the arbitration blocks).
    pub label: String,
    /// Host -> device bytes per run elided by residency.
    pub elided_in: u64,
    /// Device -> host bytes per run elided by residency.
    pub elided_out: u64,
    /// PCIe staging seconds per run those elided bytes would have cost.
    pub saved_transfer_secs: f64,
}

/// The residency residue of one arbitration run under a nonzero
/// `--resident-bytes` budget: the budget, the per-block elided traffic,
/// and the total transfer time credited. Serialized into the v5 report;
/// absent (and the report keeps its earlier version) when the plane is
/// off.
#[derive(Debug, Clone, PartialEq)]
pub struct ResidencyDecision {
    /// The resident-set byte budget the plane spilled under.
    pub budget_bytes: u64,
    /// Per-block elided traffic, aligned with the arbitration blocks.
    pub blocks: Vec<BlockResidency>,
    /// Total PCIe staging seconds per run credited across all blocks.
    pub total_saved_transfer_secs: f64,
}

/// PCIe staging seconds the elided bytes of one pattern's traffic would
/// have cost — the flip side of [`crate::coordinator::power::transfer_secs`],
/// same constant, elided bytes instead of paid ones.
pub fn saved_transfer_secs(traffic: &DeviceTraffic) -> f64 {
    (traffic.elided_in + traffic.elided_out) as f64 / crate::fpga::PCIE_BYTES_PER_SEC
}

/// Build the residue from a Step-3 search outcome: one record per
/// phase-1 block pattern (the first `block_count` entries of `tried`,
/// index-aligned with the block list by construction).
pub fn decision(
    budget_bytes: u64,
    outcome: &SearchOutcome,
    block_count: usize,
) -> ResidencyDecision {
    let blocks: Vec<BlockResidency> = outcome
        .tried
        .iter()
        .take(block_count)
        .map(|p| BlockResidency {
            label: p.label.clone(),
            elided_in: p.traffic.elided_in,
            elided_out: p.traffic.elided_out,
            saved_transfer_secs: saved_transfer_secs(&p.traffic),
        })
        .collect();
    let total = blocks.iter().map(|b| b.saved_transfer_secs).sum();
    ResidencyDecision { budget_bytes, blocks, total_saved_transfer_secs: total }
}

/// Telemetry events for one residency residue: one
/// [`TraceEvent::ResidencyElided`] per block. Built only when an observer
/// is installed (the pipeline wraps the call in its lazy event closure),
/// and only when residency shaped the run — the events mirror the v5
/// report section, so a zero-budget run emits nothing.
pub fn residency_events(d: &ResidencyDecision) -> Vec<TraceEvent> {
    d.blocks
        .iter()
        .map(|b| TraceEvent::ResidencyElided {
            label: b.label.clone(),
            elided_in: b.elided_in,
            elided_out: b.elided_out,
            saved_secs: b.saved_transfer_secs,
        })
        .collect()
}

// ----------------------------------------------------------- JSON codec

/// Serialize the arbitration's residency residue (v5 report section).
pub fn decision_to_json(d: &ResidencyDecision) -> Json {
    Json::obj(vec![
        ("budget_bytes", Json::num(d.budget_bytes as f64)),
        (
            "blocks",
            Json::Arr(
                d.blocks
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("label", Json::str(&b.label)),
                            ("elided_in", Json::num(b.elided_in as f64)),
                            ("elided_out", Json::num(b.elided_out as f64)),
                            ("saved_transfer_secs", Json::num(b.saved_transfer_secs)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("total_saved_transfer_secs", Json::num(d.total_saved_transfer_secs)),
    ])
}

/// Inverse of [`decision_to_json`].
pub fn decision_from_json(v: &Json) -> Result<ResidencyDecision> {
    Ok(ResidencyDecision {
        budget_bytes: v.get("budget_bytes")?.as_f64()? as u64,
        blocks: v
            .get("blocks")?
            .as_arr()?
            .iter()
            .map(|b| {
                Ok(BlockResidency {
                    label: b.get("label")?.as_str()?.to_string(),
                    elided_in: b.get("elided_in")?.as_f64()? as u64,
                    elided_out: b.get("elided_out")?.as_f64()? as u64,
                    saved_transfer_secs: b.get("saved_transfer_secs")?.as_f64()?,
                })
            })
            .collect::<Result<_>>()?,
        total_saved_transfer_secs: v.get("total_saved_transfer_secs")?.as_f64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::verify::PatternResult;
    use crate::metrics::Measurement;
    use crate::patterndb::json;
    use std::time::Duration;

    fn m(label: &str, us: u64) -> Measurement {
        Measurement {
            label: label.to_string(),
            median: Duration::from_micros(us),
            min: Duration::from_micros(us),
            max: Duration::from_micros(us),
            reps: 1,
        }
    }

    fn outcome_with_elision() -> SearchOutcome {
        let traffic = DeviceTraffic {
            bytes_in: 1 << 20,
            bytes_out: 1 << 19,
            dispatches: 1,
            device_secs: 0.001,
            elided_in: 3 << 20,
            elided_out: 1 << 20,
        };
        SearchOutcome {
            baseline: m("all-CPU", 100_000),
            tried: vec![PatternResult {
                enabled: vec![true],
                label: "only:call:fft2d".into(),
                time: m("only:call:fft2d", 2_000),
                speedup: 50.0,
                output_ok: true,
                traffic,
            }],
            best_enabled: vec![true],
            best_time: m("only:call:fft2d", 2_000),
            best_speedup: 50.0,
        }
    }

    #[test]
    fn credit_prices_elided_bytes_with_the_power_constant() {
        let o = outcome_with_elision();
        let d = decision(64 << 20, &o, 1);
        assert_eq!(d.blocks.len(), 1);
        let b = &d.blocks[0];
        assert_eq!((b.elided_in, b.elided_out), (3 << 20, 1 << 20));
        let want = ((3 << 20) as f64 + (1 << 20) as f64) / crate::fpga::PCIE_BYTES_PER_SEC;
        assert!((b.saved_transfer_secs - want).abs() < 1e-15);
        assert!((d.total_saved_transfer_secs - want).abs() < 1e-15);
        // The credit is exactly what transfer_secs would have charged for
        // those bytes had they been paid.
        let as_paid = DeviceTraffic {
            bytes_in: o.tried[0].traffic.elided_in,
            bytes_out: o.tried[0].traffic.elided_out,
            ..Default::default()
        };
        assert!(
            (b.saved_transfer_secs - super::super::power::transfer_secs(&as_paid)).abs() < 1e-15
        );
    }

    #[test]
    fn decision_codec_round_trips() {
        let d = decision(64 << 20, &outcome_with_elision(), 1);
        let s = json::to_string_pretty(&decision_to_json(&d));
        let back = decision_from_json(&json::parse(&s).unwrap()).unwrap();
        assert_eq!(back, d);
        assert_eq!(json::to_string_pretty(&decision_to_json(&back)), s, "byte-stable");
    }
}
