//! Steps 1–7 of the environment-adaptation flow (paper Fig. 1).
//!
//! The paper's full concept wraps the offload search (Steps 1–3) with
//! resource sizing (Step 4), placement (Step 5), deployment + operational
//! verification (Step 6) and in-operation reconfiguration (Step 7). The
//! paper evaluates Steps 1–3; the rest are part of the concept and modeled
//! here so the flow is complete end-to-end: sizing and placement are
//! driven by the *measured* block time from Step 3, deployment re-runs the
//! chosen pattern as the operational check, and reconfiguration re-enters
//! Step 5 when the environment changes.

use anyhow::{bail, Result};

use super::backend::Backend;
use super::estimate::EstimateDecision;
use super::OffloadReport;

/// Billing (and energy-budget) hours in a month: 24 × 30.
const HOURS_PER_MONTH: f64 = 24.0 * 30.0;

/// A candidate deployment location (commercial environment).
#[derive(Debug, Clone)]
pub struct Location {
    /// Location name (e.g. "regional-dc").
    pub name: String,
    /// GPU instances available here.
    pub gpus: usize,
    /// FPGA instances available here.
    pub fpgas: usize,
    /// $/hour for one GPU instance here.
    pub cost_per_hour: f64,
    /// $/hour for one FPGA instance here (the paper's motivation for FPGA
    /// offload is exactly this asymmetry: FPGAs draw far less power, so
    /// operators price them below GPUs).
    pub fpga_cost_per_hour: f64,
    /// $/kWh for metered electricity at this location. Charged on top of
    /// the instance price when the arbitration supplied per-instance
    /// wattage (a non-default `--power-policy`); locations that fold
    /// power into the instance price set it to zero.
    pub energy_cost_per_kwh: f64,
    /// Network RTT from the clients (ms).
    pub latency_ms: f64,
}

impl Location {
    /// Instance capacity for one backend.
    fn capacity(&self, backend: Backend) -> usize {
        match backend {
            Backend::Gpu => self.gpus,
            Backend::Fpga => self.fpgas,
            Backend::Cpu => 0,
        }
    }

    /// Hourly price of one instance of a backend.
    fn hourly(&self, backend: Backend) -> f64 {
        match backend {
            Backend::Gpu => self.cost_per_hour,
            Backend::Fpga => self.fpga_cost_per_hour,
            Backend::Cpu => f64::INFINITY,
        }
    }
}

/// What the user needs from the deployment.
#[derive(Debug, Clone)]
pub struct Requirements {
    /// Requests/second the deployment must sustain.
    pub target_rps: f64,
    /// Max acceptable end-to-end latency (ms).
    pub max_latency_ms: f64,
    /// Monthly budget cap ($).
    pub budget_per_month: f64,
    /// Deployment-level energy budget: the most kWh the whole provisioned
    /// fleet may draw per month (instances × watts/1000 × 720 h), `None`
    /// for uncapped. Enforceable only for candidates whose arbitration
    /// supplied per-instance watts (a non-default `--power-policy`);
    /// backends with unknown draw are not excluded by the cap.
    pub max_kwh_per_month: Option<f64>,
}

/// Step-4 output: how many accelerator instances to provision.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourcePlan {
    /// Accelerator instances to provision.
    pub instances: usize,
    /// Predicted per-instance throughput (requests/s).
    pub rps_per_instance: f64,
}

/// Step-5 output: where to run.
#[derive(Debug, Clone)]
pub struct PlacementPlan {
    /// Chosen location name.
    pub location: String,
    /// Projected monthly cost ($).
    pub monthly_cost: f64,
}

/// Per-backend request times feeding Step-5 placement, produced by the
/// Step-3b arbitration: `None` means that backend is not usable for this
/// application (no winning offload pattern, or no pre-check-passing IP
/// core).
#[derive(Debug, Clone, Copy, Default)]
pub struct BackendTimes {
    /// Measured per-request seconds of the winning pattern on GPUs.
    pub gpu_secs: Option<f64>,
    /// Estimated per-request seconds with FPGA-capable blocks on FPGAs.
    pub fpga_secs: Option<f64>,
    /// Modeled draw of one GPU instance (W) — `Some` only when a
    /// non-default `--power-policy` arbitrated, making placement charge
    /// metered electricity on top of the instance price.
    pub gpu_watts: Option<f64>,
    /// Modeled draw of one FPGA instance (W); see `gpu_watts`.
    pub fpga_watts: Option<f64>,
}

impl BackendTimes {
    /// Extract the per-backend times (and, when a power policy decided,
    /// per-instance watts) from a Step-3b arbitration outcome — the one
    /// place the report fields map onto placement inputs.
    pub fn from_arbitration(a: &super::backend::ArbitrationOutcome) -> Self {
        BackendTimes {
            gpu_secs: a.gpu_request_secs,
            fpga_secs: a.fpga_request_secs,
            gpu_watts: a.power.as_ref().map(|p| p.gpu_watts),
            fpga_watts: a.power.as_ref().map(|p| p.fpga_watts),
        }
    }

    /// Extract the placement inputs from an offload report. When the
    /// report carries an estimate residue (non-default estimator
    /// configuration), a backend the measured search left unpriced
    /// borrows the analytic prediction — see
    /// [`BackendTimes::fill_from_estimate`].
    pub fn from_report(r: &OffloadReport) -> Self {
        let mut t = Self::from_arbitration(&r.arbitration);
        if let Some(est) = &r.arbitration.estimate {
            t.fill_from_estimate(est);
        }
        t
    }

    /// Fill per-backend request seconds the measurements left `None` from
    /// the estimate residue: the backend's best (fastest) predicted
    /// pattern seconds over the blocks the estimator scored for it.
    /// Measured times always win over predicted ones — an estimate never
    /// overrides a measurement, it only lets Step-5 price a placement the
    /// search never measured (e.g. a pruned or pre-check-skipped FPGA
    /// path).
    pub fn fill_from_estimate(&mut self, est: &EstimateDecision) {
        let predicted = |backend: Backend| -> Option<f64> {
            est.blocks
                .iter()
                .filter(|b| b.backend == backend && b.predicted_secs > 0.0)
                .map(|b| b.predicted_secs)
                .min_by(f64::total_cmp)
        };
        if self.gpu_secs.is_none() {
            self.gpu_secs = predicted(Backend::Gpu);
        }
        if self.fpga_secs.is_none() {
            self.fpga_secs = predicted(Backend::Fpga);
        }
    }

    /// Per-instance draw for one backend, when known.
    fn watts(&self, backend: Backend) -> Option<f64> {
        match backend {
            Backend::Gpu => self.gpu_watts,
            Backend::Fpga => self.fpga_watts,
            Backend::Cpu => None,
        }
    }
}

/// Step-5 output when placement arbitrates backends: where to run *and on
/// what*.
#[derive(Debug, Clone)]
pub struct BackendPlacement {
    /// Chosen accelerator backend.
    pub backend: Backend,
    /// Resource plan sized from that backend's request time.
    pub plan: ResourcePlan,
    /// Chosen location name.
    pub location: String,
    /// Projected monthly cost ($).
    pub monthly_cost: f64,
}

/// Size resources from the measured request time (Step 4): the paper's
/// flow tunes resource amounts so the performance target holds.
pub fn plan_resources(measured_request_secs: f64, req: &Requirements) -> Result<ResourcePlan> {
    if measured_request_secs <= 0.0 {
        bail!("measured request time must be positive");
    }
    let rps_per_instance = 1.0 / measured_request_secs;
    let instances = (req.target_rps / rps_per_instance).ceil().max(1.0) as usize;
    Ok(ResourcePlan { instances, rps_per_instance })
}

/// Choose the cheapest location satisfying latency + capacity + budget
/// (Step 5).
pub fn plan_placement(
    plan: &ResourcePlan,
    req: &Requirements,
    locations: &[Location],
) -> Result<PlacementPlan> {
    let mut best: Option<PlacementPlan> = None;
    for loc in locations {
        if loc.latency_ms > req.max_latency_ms {
            continue;
        }
        if loc.gpus + loc.fpgas < plan.instances {
            continue;
        }
        let monthly = loc.cost_per_hour * plan.instances as f64 * HOURS_PER_MONTH;
        if monthly > req.budget_per_month {
            continue;
        }
        if best.as_ref().map(|b| monthly < b.monthly_cost).unwrap_or(true) {
            best = Some(PlacementPlan { location: loc.name.clone(), monthly_cost: monthly });
        }
    }
    best.ok_or_else(|| {
        anyhow::anyhow!(
            "no location satisfies latency<={}ms, {} instances, budget ${}/mo",
            req.max_latency_ms,
            plan.instances,
            req.budget_per_month
        )
    })
}

/// Step-5 with backend arbitration: size each usable backend from its own
/// request time and pick the cheapest (backend, location) pair satisfying
/// latency + per-backend capacity + budget. This is where the Step-3b
/// times pay off commercially: a GPU-fastest block still deploys on
/// FPGAs when every GPU option busts the budget. When the arbitration
/// supplied per-instance watts (a non-default `--power-policy`), the
/// monthly cost additionally meters electricity at each location's
/// $/kWh — so a power-hungry backend can lose a location it would win on
/// instance price alone (the paper's power/cost motivation, priced).
pub fn plan_backend_placement(
    times: &BackendTimes,
    req: &Requirements,
    locations: &[Location],
) -> Result<BackendPlacement> {
    let candidates = [
        (Backend::Gpu, times.gpu_secs),
        (Backend::Fpga, times.fpga_secs),
    ];
    let mut best: Option<BackendPlacement> = None;
    for (backend, secs) in candidates {
        let Some(secs) = secs else { continue };
        let plan = plan_resources(secs, req)?;
        // Deployment-level energy budget (location-independent): the
        // whole provisioned fleet's monthly draw must fit the cap.
        if let (Some(cap), Some(watts)) = (req.max_kwh_per_month, times.watts(backend)) {
            let kwh = watts / 1000.0 * HOURS_PER_MONTH * plan.instances as f64;
            if kwh > cap {
                continue;
            }
        }
        for loc in locations {
            if loc.latency_ms > req.max_latency_ms {
                continue;
            }
            if loc.capacity(backend) < plan.instances {
                continue;
            }
            let energy_hourly = times
                .watts(backend)
                .map(|w| w / 1000.0 * loc.energy_cost_per_kwh)
                .unwrap_or(0.0);
            let monthly =
                (loc.hourly(backend) + energy_hourly) * plan.instances as f64 * HOURS_PER_MONTH;
            if monthly > req.budget_per_month {
                continue;
            }
            if best.as_ref().map(|b| monthly < b.monthly_cost).unwrap_or(true) {
                best = Some(BackendPlacement {
                    backend,
                    plan: plan.clone(),
                    location: loc.name.clone(),
                    monthly_cost: monthly,
                });
            }
        }
    }
    best.ok_or_else(|| {
        anyhow::anyhow!(
            "no (backend, location) pair satisfies latency<={}ms and budget ${}/mo \
             (gpu {:?}s, fpga {:?}s per request)",
            req.max_latency_ms,
            req.budget_per_month,
            times.gpu_secs,
            times.fpga_secs
        )
    })
}

/// Step-7 trigger: re-plan placement when the environment changes (a
/// location is drained, prices move, latency degrades).
pub fn replan_on_change(
    plan: &ResourcePlan,
    req: &Requirements,
    new_locations: &[Location],
    current: &PlacementPlan,
) -> Result<Option<PlacementPlan>> {
    let fresh = plan_placement(plan, req, new_locations)?;
    if fresh.location != current.location
        || (fresh.monthly_cost - current.monthly_cost).abs() > 1e-9
    {
        Ok(Some(fresh))
    } else {
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn locations() -> Vec<Location> {
        vec![
            Location {
                name: "edge-gw".into(),
                gpus: 1,
                fpgas: 1,
                cost_per_hour: 0.9,
                fpga_cost_per_hour: 0.35,
                energy_cost_per_kwh: 0.30,
                latency_ms: 3.0,
            },
            Location {
                name: "regional-dc".into(),
                gpus: 8,
                fpgas: 4,
                cost_per_hour: 0.5,
                fpga_cost_per_hour: 0.2,
                energy_cost_per_kwh: 0.12,
                latency_ms: 12.0,
            },
            Location {
                name: "central-cloud".into(),
                gpus: 64,
                fpgas: 32,
                cost_per_hour: 0.3,
                fpga_cost_per_hour: 0.12,
                energy_cost_per_kwh: 0.08,
                latency_ms: 45.0,
            },
        ]
    }

    fn req() -> Requirements {
        Requirements {
            target_rps: 40.0,
            max_latency_ms: 20.0,
            budget_per_month: 5000.0,
            max_kwh_per_month: None,
        }
    }

    #[test]
    fn sizing_from_measured_time() {
        // 100 ms per request -> 10 rps/instance -> 4 instances for 40 rps.
        let p = plan_resources(0.1, &req()).unwrap();
        assert_eq!(p.instances, 4);
        assert!((p.rps_per_instance - 10.0).abs() < 1e-9);
    }

    #[test]
    fn placement_picks_cheapest_feasible() {
        let plan = ResourcePlan { instances: 4, rps_per_instance: 10.0 };
        let pl = plan_placement(&plan, &req(), &locations()).unwrap();
        // central-cloud is cheapest but violates 20ms latency; edge has
        // too few instances; regional wins.
        assert_eq!(pl.location, "regional-dc");
    }

    #[test]
    fn placement_fails_when_infeasible() {
        let plan = ResourcePlan { instances: 100, rps_per_instance: 1.0 };
        assert!(plan_placement(&plan, &req(), &locations()).is_err());
    }

    #[test]
    fn budget_is_enforced() {
        let tight = Requirements { budget_per_month: 100.0, ..req() };
        let plan = ResourcePlan { instances: 4, rps_per_instance: 10.0 };
        assert!(plan_placement(&plan, &tight, &locations()).is_err());
    }

    #[test]
    fn backend_placement_prefers_cheapest_feasible_pair() {
        // Both backends usable and equally fast: the FPGA's lower hourly
        // price wins at the same (latency-feasible) location.
        let times =
            BackendTimes { gpu_secs: Some(0.1), fpga_secs: Some(0.1), ..Default::default() };
        let p = plan_backend_placement(&times, &req(), &locations()).unwrap();
        assert_eq!(p.backend, Backend::Fpga);
        assert_eq!(p.location, "regional-dc");
        assert_eq!(p.plan.instances, 4);
    }

    #[test]
    fn fpga_location_chosen_when_gpu_locations_violate_budget() {
        // The Step-5 scenario from the paper's cost motivation: GPU
        // placement is feasible on capacity and latency but every GPU
        // option busts the monthly budget; the FPGA estimate (slower per
        // request, cheaper per hour) is what ships.
        let times =
            BackendTimes { gpu_secs: Some(0.1), fpga_secs: Some(0.2), ..Default::default() };
        // 40 rps: GPU needs 4 instances, FPGA needs 8.
        let tight = Requirements { budget_per_month: 1300.0, ..req() };
        // GPU at regional-dc: 4 × $0.5 × 720 = $1440 > budget.
        // FPGA at regional-dc lacks capacity (4 < 8); edge-gw too.
        let mut locs = locations();
        locs[1].fpgas = 16;
        // FPGA at regional-dc: 8 × $0.2 × 720 = $1152 <= budget.
        let p = plan_backend_placement(&times, &tight, &locs).unwrap();
        assert_eq!(p.backend, Backend::Fpga);
        assert_eq!(p.location, "regional-dc");
        assert_eq!(p.plan.instances, 8);
        assert!((p.monthly_cost - 1152.0).abs() < 1e-6);
    }

    #[test]
    fn metered_energy_flips_the_backend_choice() {
        // One location where the GPU's *instance* price narrowly beats the
        // FPGA's. Without wattage (default --power-policy) the GPU wins;
        // with the arbitration's per-instance watts and a metered $/kWh,
        // the GPU's 75 W draw prices it above the 40 W FPGA.
        let locs = vec![Location {
            name: "metered-dc".into(),
            gpus: 8,
            fpgas: 8,
            cost_per_hour: 0.20,
            fpga_cost_per_hour: 0.21,
            energy_cost_per_kwh: 1.0,
            latency_ms: 10.0,
        }];
        let blind =
            BackendTimes { gpu_secs: Some(0.1), fpga_secs: Some(0.1), ..Default::default() };
        let p = plan_backend_placement(&blind, &req(), &locs).unwrap();
        assert_eq!(p.backend, Backend::Gpu, "instance price alone favors the GPU");

        let metered = BackendTimes {
            gpu_watts: Some(75.0),
            fpga_watts: Some(40.0),
            ..blind
        };
        let p = plan_backend_placement(&metered, &req(), &locs).unwrap();
        assert_eq!(p.backend, Backend::Fpga, "metered electricity flips it");
        // 4 instances × (0.21 + 0.040 × 1.0) $/h × 720 h.
        assert!((p.monthly_cost - 4.0 * 0.25 * 720.0).abs() < 1e-6, "{}", p.monthly_cost);
    }

    #[test]
    fn kwh_budget_flips_the_backend_choice() {
        // A flat-rate location (no metered electricity) where the GPU's
        // instance price narrowly beats the FPGA's: uncapped, the GPU
        // wins on price alone.
        let locs = vec![Location {
            name: "flat-dc".into(),
            gpus: 8,
            fpgas: 8,
            cost_per_hour: 0.20,
            fpga_cost_per_hour: 0.21,
            energy_cost_per_kwh: 0.0,
            latency_ms: 10.0,
        }];
        let times = BackendTimes {
            gpu_secs: Some(0.1),
            fpga_secs: Some(0.1),
            gpu_watts: Some(75.0),
            fpga_watts: Some(40.0),
        };
        let p = plan_backend_placement(&times, &req(), &locs).unwrap();
        assert_eq!(p.backend, Backend::Gpu, "uncapped, instance price favors the GPU");

        // Deployment energy budget: 4 GPU instances draw 4 × 0.075 kW ×
        // 720 h = 216 kWh/month; 4 FPGAs draw 115.2. A 150 kWh cap
        // excludes every GPU deal and ships the FPGAs despite the higher
        // instance price.
        let capped = Requirements { max_kwh_per_month: Some(150.0), ..req() };
        let p = plan_backend_placement(&times, &capped, &locs).unwrap();
        assert_eq!(p.backend, Backend::Fpga, "the kWh budget flips it");
        assert!((p.monthly_cost - 4.0 * 0.21 * 720.0).abs() < 1e-6, "{}", p.monthly_cost);

        // A cap even the FPGAs bust leaves nothing feasible.
        let starved = Requirements { max_kwh_per_month: Some(100.0), ..req() };
        assert!(plan_backend_placement(&times, &starved, &locs).is_err());
    }

    #[test]
    fn estimate_fills_a_backend_the_measurements_left_unpriced() {
        use crate::coordinator::estimate::{BlockPrediction, EstimateDecision, PrunePolicy};

        // The search never measured an FPGA pattern (no pre-check-passing
        // IP core in the measured set), but the estimator predicted one.
        let mut times = BackendTimes { gpu_secs: Some(0.1), ..Default::default() };
        let est = EstimateDecision {
            policy: PrunePolicy::Aggressive,
            gpu_profile: "GeForce GTX 1050 Ti".into(),
            fpga_profile: "Arria 10 GX 1150".into(),
            blocks: vec![
                BlockPrediction {
                    label: "call:fft2d".into(),
                    backend: Backend::Fpga,
                    predicted_secs: 0.25,
                    measured_secs: None,
                    error: None,
                },
                BlockPrediction {
                    label: "call:conv".into(),
                    backend: Backend::Fpga,
                    predicted_secs: 0.2,
                    measured_secs: None,
                    error: None,
                },
                // A GPU prediction must NOT override the measured time.
                BlockPrediction {
                    label: "call:matmul".into(),
                    backend: Backend::Gpu,
                    predicted_secs: 0.5,
                    measured_secs: Some(0.1),
                    error: Some(4.0),
                },
            ],
            mape: None,
        };
        times.fill_from_estimate(&est);
        assert_eq!(times.gpu_secs, Some(0.1), "measured time survives");
        assert_eq!(times.fpga_secs, Some(0.2), "fastest FPGA prediction fills the gap");

        // The borrowed prediction makes an FPGA-only deployment plannable:
        // strip FPGA capacity and GPU capacity in turn to see both paths.
        let mut locs = locations();
        for l in &mut locs {
            l.gpus = 0;
        }
        let p = plan_backend_placement(&times, &req(), &locs).unwrap();
        assert_eq!(p.backend, Backend::Fpga);
        assert_eq!(p.plan.instances, 8, "sized from the predicted 0.2 s/request");
    }

    #[test]
    fn backend_placement_fails_when_no_backend_available() {
        let times = BackendTimes::default();
        assert!(plan_backend_placement(&times, &req(), &locations()).is_err());
        // FPGA-only times with no FPGA capacity anywhere is infeasible too.
        let times =
            BackendTimes { gpu_secs: None, fpga_secs: Some(0.1), ..Default::default() };
        let mut locs = locations();
        for l in &mut locs {
            l.fpgas = 0;
        }
        assert!(plan_backend_placement(&times, &req(), &locs).is_err());
    }

    #[test]
    fn reconfiguration_detects_change() {
        let plan = ResourcePlan { instances: 4, rps_per_instance: 10.0 };
        let current = plan_placement(&plan, &req(), &locations()).unwrap();
        // Regional DC price rises: replan should re-cost (or move).
        let mut locs = locations();
        locs[1].cost_per_hour = 0.55;
        let change = replan_on_change(&plan, &req(), &locs, &current).unwrap();
        assert!(change.is_some());
        // No change: same inputs.
        let same = replan_on_change(&plan, &req(), &locations(), &current).unwrap();
        assert!(same.is_none());
    }
}
