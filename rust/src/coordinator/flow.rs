//! Steps 1–7 of the environment-adaptation flow (paper Fig. 1).
//!
//! The paper's full concept wraps the offload search (Steps 1–3) with
//! resource sizing (Step 4), placement (Step 5), deployment + operational
//! verification (Step 6) and in-operation reconfiguration (Step 7). The
//! paper evaluates Steps 1–3; the rest are part of the concept and modeled
//! here so the flow is complete end-to-end: sizing and placement are
//! driven by the *measured* block time from Step 3, deployment re-runs the
//! chosen pattern as the operational check, and reconfiguration re-enters
//! Step 5 when the environment changes.

use anyhow::{bail, Result};

/// A candidate deployment location (commercial environment).
#[derive(Debug, Clone)]
pub struct Location {
    pub name: String,
    pub gpus: usize,
    pub fpgas: usize,
    /// $/hour for one accelerator instance here.
    pub cost_per_hour: f64,
    /// Network RTT from the clients (ms).
    pub latency_ms: f64,
}

/// What the user needs from the deployment.
#[derive(Debug, Clone)]
pub struct Requirements {
    /// Requests/second the deployment must sustain.
    pub target_rps: f64,
    /// Max acceptable end-to-end latency (ms).
    pub max_latency_ms: f64,
    /// Monthly budget cap ($).
    pub budget_per_month: f64,
}

/// Step-4 output: how many accelerator instances to provision.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourcePlan {
    pub instances: usize,
    /// Predicted per-instance throughput (requests/s).
    pub rps_per_instance: f64,
}

/// Step-5 output: where to run.
#[derive(Debug, Clone)]
pub struct PlacementPlan {
    pub location: String,
    pub monthly_cost: f64,
}

/// Size resources from the measured request time (Step 4): the paper's
/// flow tunes resource amounts so the performance target holds.
pub fn plan_resources(measured_request_secs: f64, req: &Requirements) -> Result<ResourcePlan> {
    if measured_request_secs <= 0.0 {
        bail!("measured request time must be positive");
    }
    let rps_per_instance = 1.0 / measured_request_secs;
    let instances = (req.target_rps / rps_per_instance).ceil().max(1.0) as usize;
    Ok(ResourcePlan { instances, rps_per_instance })
}

/// Choose the cheapest location satisfying latency + capacity + budget
/// (Step 5).
pub fn plan_placement(
    plan: &ResourcePlan,
    req: &Requirements,
    locations: &[Location],
) -> Result<PlacementPlan> {
    let mut best: Option<PlacementPlan> = None;
    for loc in locations {
        if loc.latency_ms > req.max_latency_ms {
            continue;
        }
        if loc.gpus + loc.fpgas < plan.instances {
            continue;
        }
        let monthly = loc.cost_per_hour * plan.instances as f64 * 24.0 * 30.0;
        if monthly > req.budget_per_month {
            continue;
        }
        if best.as_ref().map(|b| monthly < b.monthly_cost).unwrap_or(true) {
            best = Some(PlacementPlan { location: loc.name.clone(), monthly_cost: monthly });
        }
    }
    best.ok_or_else(|| {
        anyhow::anyhow!(
            "no location satisfies latency<={}ms, {} instances, budget ${}/mo",
            req.max_latency_ms,
            plan.instances,
            req.budget_per_month
        )
    })
}

/// Step-7 trigger: re-plan placement when the environment changes (a
/// location is drained, prices move, latency degrades).
pub fn replan_on_change(
    plan: &ResourcePlan,
    req: &Requirements,
    new_locations: &[Location],
    current: &PlacementPlan,
) -> Result<Option<PlacementPlan>> {
    let fresh = plan_placement(plan, req, new_locations)?;
    if fresh.location != current.location
        || (fresh.monthly_cost - current.monthly_cost).abs() > 1e-9
    {
        Ok(Some(fresh))
    } else {
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn locations() -> Vec<Location> {
        vec![
            Location {
                name: "edge-gw".into(),
                gpus: 1,
                fpgas: 1,
                cost_per_hour: 0.9,
                latency_ms: 3.0,
            },
            Location {
                name: "regional-dc".into(),
                gpus: 8,
                fpgas: 4,
                cost_per_hour: 0.5,
                latency_ms: 12.0,
            },
            Location {
                name: "central-cloud".into(),
                gpus: 64,
                fpgas: 32,
                cost_per_hour: 0.3,
                latency_ms: 45.0,
            },
        ]
    }

    fn req() -> Requirements {
        Requirements { target_rps: 40.0, max_latency_ms: 20.0, budget_per_month: 5000.0 }
    }

    #[test]
    fn sizing_from_measured_time() {
        // 100 ms per request -> 10 rps/instance -> 4 instances for 40 rps.
        let p = plan_resources(0.1, &req()).unwrap();
        assert_eq!(p.instances, 4);
        assert!((p.rps_per_instance - 10.0).abs() < 1e-9);
    }

    #[test]
    fn placement_picks_cheapest_feasible() {
        let plan = ResourcePlan { instances: 4, rps_per_instance: 10.0 };
        let pl = plan_placement(&plan, &req(), &locations()).unwrap();
        // central-cloud is cheapest but violates 20ms latency; edge has
        // too few instances; regional wins.
        assert_eq!(pl.location, "regional-dc");
    }

    #[test]
    fn placement_fails_when_infeasible() {
        let plan = ResourcePlan { instances: 100, rps_per_instance: 1.0 };
        assert!(plan_placement(&plan, &req(), &locations()).is_err());
    }

    #[test]
    fn budget_is_enforced() {
        let tight = Requirements { budget_per_month: 100.0, ..req() };
        let plan = ResourcePlan { instances: 4, rps_per_instance: 10.0 };
        assert!(plan_placement(&plan, &tight, &locations()).is_err());
    }

    #[test]
    fn reconfiguration_detects_change() {
        let plan = ResourcePlan { instances: 4, rps_per_instance: 10.0 };
        let current = plan_placement(&plan, &req(), &locations()).unwrap();
        // Regional DC price rises: replan should re-cost (or move).
        let mut locs = locations();
        locs[1].cost_per_hour = 0.55;
        let change = replan_on_change(&plan, &req(), &locs, &current).unwrap();
        assert!(change.is_some());
        // No change: same inputs.
        let same = replan_on_change(&plan, &req(), &locations(), &current).unwrap();
        assert!(same.is_none());
    }
}
