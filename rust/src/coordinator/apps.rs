//! Evaluation applications (paper §5.1).
//!
//! The paper evaluates two applications many IoT users are expected to
//! run — a Fourier-transform app and a matrix-calculation (LU) app — each
//! prepared in two discovery variants:
//!
//! * **lib**  — the code *calls an external library* (NR-style `fft2d` /
//!   `ludcmp`); found by DB name matching (A-1/B-1).
//! * **copy** — the code *copied the library source* and renamed things;
//!   found by the similarity detector (A-2/B-2).
//!
//! Sizes are parameters (the paper used 2048×2048; our default headline
//! size is 256 — see DESIGN.md "Substitutions"). `write_all` materializes
//! the sources under `apps/` for CLI use.

use std::path::Path;

use anyhow::Result;

/// Fourier-transform app, library-call variant (IoT vibration monitoring).
pub fn fft_app_lib(n: usize) -> String {
    format!(
        r#"// IoT vibration monitoring: 2-D FFT of a sensor frame, then band energy.
// The Fourier transform is the Numerical Recipes library routine `fft2d`.
#include <math.h>
#include <nrfft.h>

int N = {n};

void fft2d(double re[], double im[], int n);

int main() {{
    double re[N * N];
    double im[N * N];
    int i, j;
    for (i = 0; i < N; i++) {{
        for (j = 0; j < N; j++) {{
            re[i * N + j] = sin(0.02 * i) + 0.5 * sin(0.31 * i + 0.17 * j);
            im[i * N + j] = 0.0;
        }}
    }}
    fft2d(re, im, N);
    double energy = 0.0;
    for (i = 0; i < N * N; i++) {{
        energy += re[i] * re[i] + im[i] * im[i];
    }}
    printf("spectral energy %g\n", energy);
    return energy / (N * N);
}}
"#
    )
}

/// Fourier-transform app, copied-code variant: the NR routines pasted in
/// and renamed (what the similarity detector must catch).
pub fn fft_app_copy(n: usize) -> String {
    format!(
        r#"// Vibration analysis pipeline. FFT routines adapted from a textbook.
#include <math.h>

int N = {n};

void wave_mix(double samples[], int nn, int direction) {{
    int n, span, m, j, stride, i;
    double angle_step, cr, cr_delta, ci_delta, ci, theta;
    double xr, xi;
    n = nn << 1;
    j = 1;
    for (i = 1; i < n; i += 2) {{
        if (j > i) {{
            xr = samples[j]; samples[j] = samples[i]; samples[i] = xr;
            xr = samples[j + 1]; samples[j + 1] = samples[i + 1]; samples[i + 1] = xr;
        }}
        m = nn;
        while (m >= 2 && j > m) {{
            j -= m;
            m >>= 1;
        }}
        j += m;
    }}
    span = 2;
    while (n > span) {{
        stride = span << 1;
        theta = direction * (6.28318530717959 / span);
        angle_step = sin(0.5 * theta);
        cr_delta = -2.0 * angle_step * angle_step;
        ci_delta = sin(theta);
        cr = 1.0;
        ci = 0.0;
        for (m = 1; m < span; m += 2) {{
            for (i = m; i <= n; i += stride) {{
                j = i + span;
                xr = cr * samples[j] - ci * samples[j + 1];
                xi = cr * samples[j + 1] + ci * samples[j];
                samples[j] = samples[i] - xr;
                samples[j + 1] = samples[i + 1] - xi;
                samples[i] += xr;
                samples[i + 1] += xi;
            }}
            cr = (angle_step = cr) * cr_delta - ci * ci_delta + cr;
            ci = ci * cr_delta + angle_step * ci_delta + ci;
        }}
        span = stride;
    }}
}}

void grid_spectrum(double re[], double im[], int n) {{
    int i, j;
    double line[2 * n + 1];
    for (i = 0; i < n; i++) {{
        for (j = 0; j < n; j++) {{
            line[2 * j + 1] = re[i * n + j];
            line[2 * j + 2] = im[i * n + j];
        }}
        wave_mix(line, n, 1);
        for (j = 0; j < n; j++) {{
            re[i * n + j] = line[2 * j + 1];
            im[i * n + j] = line[2 * j + 2];
        }}
    }}
    for (j = 0; j < n; j++) {{
        for (i = 0; i < n; i++) {{
            line[2 * i + 1] = re[i * n + j];
            line[2 * i + 2] = im[i * n + j];
        }}
        wave_mix(line, n, 1);
        for (i = 0; i < n; i++) {{
            re[i * n + j] = line[2 * i + 1];
            im[i * n + j] = line[2 * i + 2];
        }}
    }}
}}

int main() {{
    double re[N * N];
    double im[N * N];
    int i, j;
    for (i = 0; i < N; i++) {{
        for (j = 0; j < N; j++) {{
            re[i * N + j] = sin(0.02 * i) + 0.5 * sin(0.31 * i + 0.17 * j);
            im[i * N + j] = 0.0;
        }}
    }}
    grid_spectrum(re, im, N);
    double energy = 0.0;
    for (i = 0; i < N * N; i++) {{
        energy += re[i] * re[i] + im[i] * im[i];
    }}
    printf("spectral energy %g\n", energy);
    return energy / (N * N);
}}
"#
    )
}

/// Matrix-calculation app, library-call variant: LU decomposition of a
/// diagonally-dominant matrix via the NR `ludcmp` library.
pub fn lu_app_lib(n: usize) -> String {
    format!(
        r#"// ML preprocessing: LU-factor the feature covariance and report log|det|.
// Decomposition is the Numerical Recipes library routine `ludcmp`.
#include <math.h>
#include <nr.h>

int N = {n};

void ludcmp(double a[], int n);

int main() {{
    double a[N * N];
    int i, j;
    for (i = 0; i < N; i++) {{
        for (j = 0; j < N; j++) {{
            a[i * N + j] = 0.3 * sin(0.01 * (i * j + 1)) + 0.1 * cos(0.05 * (i + 2 * j));
        }}
    }}
    for (i = 0; i < N; i++) {{
        a[i * N + i] = a[i * N + i] + N;
    }}
    ludcmp(a, N);
    double logdet = 0.0;
    for (i = 0; i < N; i++) {{
        logdet += log(fabs(a[i * N + i]));
    }}
    printf("log|det| %g\n", logdet);
    return logdet;
}}
"#
    )
}

/// Matrix-calculation app, copied-code variant: a 2-D-array LU routine
/// pasted from the textbook and renamed.
pub fn lu_app_copy(n: usize) -> String {
    format!(
        r#"// Covariance factorization; decomposition routine adapted from a textbook.
#include <math.h>

int N = {n};

void decompose_grid(double m[][{n}], int n) {{
    int row, col, k;
    double pivot, scale;
    for (k = 0; k < n; k++) {{
        pivot = m[k][k];
        for (row = k + 1; row < n; row++) {{
            scale = m[row][k] / pivot;
            m[row][k] = scale;
            for (col = k + 1; col < n; col++) {{
                m[row][col] = m[row][col] - scale * m[k][col];
            }}
        }}
    }}
}}

int main() {{
    double m[N][N];
    int i, j;
    for (i = 0; i < N; i++) {{
        for (j = 0; j < N; j++) {{
            m[i][j] = 0.3 * sin(0.01 * (i * j + 1)) + 0.1 * cos(0.05 * (i + 2 * j));
        }}
    }}
    for (i = 0; i < N; i++) {{
        m[i][i] = m[i][i] + N;
    }}
    decompose_grid(m, N);
    double logdet = 0.0;
    for (i = 0; i < N; i++) {{
        logdet += log(fabs(m[i][i]));
    }}
    printf("log|det| %g\n", logdet);
    return logdet;
}}
"#
    )
}

/// Dense-matmul pipeline app (quickstart; cuBLAS-analog block via A-1).
pub fn matmul_app(n: usize) -> String {
    format!(
        r#"// Tiny inference pipeline: feature transform = W2 * (W1 * X).
#include <math.h>

int N = {n};

void matmul(double a[], double b[], double c[], int n);

int main() {{
    double w1[N * N];
    double x[N * N];
    double h[N * N];
    int i;
    for (i = 0; i < N * N; i++) {{
        w1[i] = sin(0.001 * i);
        x[i] = cos(0.002 * i);
        h[i] = 0.0;
    }}
    matmul(w1, x, h, N);
    double checksum = 0.0;
    for (i = 0; i < N * N; i++) {{
        checksum += h[i];
    }}
    printf("checksum %g\n", checksum);
    return checksum;
}}
"#
    )
}

/// Sensor-fusion analytics app: **three** replaceable library blocks in
/// one program — FFT the sensor frame (`fft2d`), correlate it against a
/// filter bank (`matmul`), LU-factor the fused covariance (`ludcmp`).
/// The multi-block fixture for the Step-3 pattern search: phase 1
/// measures each block alone, phase 2 combines the winners, and the
/// parallel-verification bench compares serial vs pooled executors on it.
pub fn sensor_fusion_app(n: usize) -> String {
    format!(
        r#"// Sensor fusion: spectrum (NR fft2d) -> filter-bank correlation
// (matmul) -> LU factorization of the fused covariance (NR ludcmp).
#include <math.h>
#include <nr.h>
#include <nrfft.h>

int N = {n};

void fft2d(double re[], double im[], int n);
void ludcmp(double a[], int n);
void matmul(double a[], double b[], double c[], int n);

int main() {{
    double re[N * N];
    double im[N * N];
    double w[N * N];
    double h[N * N];
    double a[N * N];
    int i, j;
    for (i = 0; i < N; i++) {{
        for (j = 0; j < N; j++) {{
            re[i * N + j] = sin(0.02 * i) + 0.5 * sin(0.31 * i + 0.17 * j);
            im[i * N + j] = 0.0;
            w[i * N + j] = cos(0.001 * (i * N + j));
        }}
    }}
    fft2d(re, im, N);
    matmul(w, re, h, N);
    for (i = 0; i < N; i++) {{
        for (j = 0; j < N; j++) {{
            a[i * N + j] = 0.001 * h[i * N + j] / (N * N);
        }}
    }}
    for (i = 0; i < N; i++) {{
        a[i * N + i] = a[i * N + i] + N;
    }}
    ludcmp(a, N);
    double energy = 0.0;
    for (i = 0; i < N * N; i++) {{
        energy += re[i] * re[i] + im[i] * im[i];
    }}
    double logdet = 0.0;
    for (i = 0; i < N; i++) {{
        logdet += log(fabs(a[i * N + i]));
    }}
    printf("fused energy %g log|det| %g\n", energy, logdet);
    return logdet + energy / (N * N * N);
}}
"#
    )
}

/// Dense stencil/map app: heavy elementwise math with no library calls —
/// the workload class where *loop* offloading ([33]) legitimately shines
/// (used by the Fig. 4 bench to show the GA curve with real signal).
pub fn stencil_app(n: usize) -> String {
    format!(
        r#"// Sensor-field smoothing: trig-heavy map + blur + energy.
#include <math.h>

int N = {n};

int main() {{
    double f[N * N];
    double g[N * N];
    int i, j;
    for (i = 0; i < N * N; i++) {{
        f[i] = sin(0.001 * i) * cos(0.002 * i) + sin(0.0005 * i * i);
    }}
    for (i = 1; i < N - 1; i++) {{
        for (j = 1; j < N - 1; j++) {{
            g[i * N + j] = 0.2 * (f[i * N + j] + f[i * N + j - 1] + f[i * N + j + 1]
                + f[i * N + j - N] + f[i * N + j + N]) + sqrt(fabs(f[i * N + j]));
        }}
    }}
    // Small calibration loops: offloading these LOSES (launch + transfer
    // overhead dominates 8 elements) — the GA must learn to leave them on
    // the CPU, which is what makes the Fig. 4 curve climb.
    double cal1[8]; double cal2[8]; double cal3[8]; double cal4[8];
    for (i = 0; i < 8; i++) cal1[i] = sin(0.1 * i);
    for (i = 0; i < 8; i++) cal2[i] = cal1[i] * 2.0;
    for (i = 0; i < 8; i++) cal3[i] = cal2[i] + cal1[i];
    for (i = 0; i < 8; i++) cal4[i] = sqrt(fabs(cal3[i]));
    double s = cal4[7];
    for (i = 0; i < N * N; i++) {{
        s += g[i] * g[i] + exp(-fabs(g[i]));
    }}
    printf("field energy %g
", s);
    return s;
}}
"#
    )
}

/// All evaluation apps: (file name, source).
pub fn all(n: usize) -> Vec<(String, String)> {
    vec![
        (format!("fft_app_lib_{n}.c"), fft_app_lib(n)),
        (format!("fft_app_copy_{n}.c"), fft_app_copy(n)),
        (format!("lu_app_lib_{n}.c"), lu_app_lib(n)),
        (format!("lu_app_copy_{n}.c"), lu_app_copy(n)),
        (format!("matmul_app_{n}.c"), matmul_app(n)),
        (format!("sensor_fusion_app_{n}.c"), sensor_fusion_app(n)),
    ]
}

/// Materialize the app sources under `dir` (CLI `gen-apps`).
pub fn write_all(dir: &Path, n: usize) -> Result<Vec<String>> {
    std::fs::create_dir_all(dir)?;
    let mut names = Vec::new();
    for (name, src) in all(n) {
        std::fs::write(dir.join(&name), src)?;
        names.push(name);
    }
    Ok(names)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::Interp;
    use crate::parser::parse;

    #[test]
    fn all_apps_parse() {
        for (name, src) in all(16) {
            parse(&src).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn copy_variants_run_standalone() {
        // Copy variants carry their implementation; they must run as-is.
        for src in [fft_app_copy(8), lu_app_copy(8)] {
            let prog = parse(&src).unwrap();
            let mut m = Interp::new(&prog).unwrap();
            let v = m.run("main", &[]).unwrap();
            assert!(v.as_num().unwrap().is_finite());
        }
    }

    #[test]
    fn fft_copy_and_lu_copy_agree_with_reference_math() {
        // lu copy at n=8: log|det| of the diagonally-dominant matrix must
        // be close to sum(log(diag)) ≈ 8*log(8+eps) within a broad band.
        let prog = parse(&lu_app_copy(8)).unwrap();
        let mut m = Interp::new(&prog).unwrap();
        let v = m.run("main", &[]).unwrap().as_num().unwrap();
        assert!((v - 8.0 * (8.0f64).ln()).abs() < 2.0, "logdet {v}");
    }

    #[test]
    fn write_all_materializes_files() {
        let dir = std::env::temp_dir().join(format!("fbo-apps-{}", std::process::id()));
        let names = write_all(&dir, 16).unwrap();
        assert_eq!(names.len(), 6);
        for n in names {
            assert!(dir.join(n).exists());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
