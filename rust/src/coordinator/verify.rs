//! Verification-environment pattern search (paper §4.2).
//!
//! "Even if existing know-how says a block can be accelerated, you don't
//! know it is faster *under these conditions* until you measure it."  With
//! k replaceable blocks the implementation measures each block on/off
//! individually, combines the winners, re-measures, and picks the fastest
//! pattern as the solution. This module is that loop: every candidate
//! pattern is an actual transformed program executed in the interpreter
//! with PJRT-backed externals installed.

use std::rc::Rc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::interp::{Interp, Value};
use crate::metrics::{measure, Measurement};
use crate::parser::Program;
use crate::runtime::Engine;
use crate::transform::{self, glue, PlannedReplacement};

/// Verification-run configuration.
#[derive(Debug, Clone)]
pub struct VerifyConfig {
    /// Measured repetitions per pattern (median taken).
    pub reps: usize,
    /// Unmeasured warm-up runs before the measured repetitions.
    pub warmup: usize,
    /// Interpreter fuel per run (guards diverging candidates).
    pub fuel: u64,
    /// Relative tolerance when checking the offloaded result against the
    /// CPU result (f32 artifact vs f64 interpreter).
    pub tolerance: f64,
}

impl Default for VerifyConfig {
    fn default() -> Self {
        VerifyConfig { reps: 3, warmup: 0, fuel: u64::MAX, tolerance: 1e-2 }
    }
}

/// Host<->device traffic observed while measuring one pattern, averaged
/// per run. Captured from [`crate::runtime::EngineStats`] deltas around
/// the measured runs; the backend-arbitration stage uses it to size the
/// FPGA timing model (working set, dispatch count) and to compare FPGA
/// estimates against the *measured* PJRT device seconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DeviceTraffic {
    /// Bytes staged host -> device per run.
    pub bytes_in: u64,
    /// Bytes read device -> host per run.
    pub bytes_out: u64,
    /// Artifact dispatches per run.
    pub dispatches: u64,
    /// Measured wall-clock seconds inside the PJRT engine per run
    /// (staging + device execution + readback).
    pub device_secs: f64,
}

/// Result of measuring one offload pattern.
#[derive(Debug, Clone)]
pub struct PatternResult {
    /// Which blocks were enabled.
    pub enabled: Vec<bool>,
    /// Human-readable pattern label (e.g. `only:call:fft2d`).
    pub label: String,
    /// Measured wall-clock of the whole pattern run.
    pub time: Measurement,
    /// Speedup vs the all-CPU baseline.
    pub speedup: f64,
    /// Did the program produce the same result as the CPU run?
    pub output_ok: bool,
    /// Per-run host<->device traffic observed during measurement.
    pub traffic: DeviceTraffic,
}

/// Full search outcome.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// All-CPU baseline measurement.
    pub baseline: Measurement,
    /// Every measured pattern, per-block ones first (index-aligned with the block list).
    pub tried: Vec<PatternResult>,
    /// Winning pattern (indices into the block list).
    pub best_enabled: Vec<bool>,
    /// Measurement of the winning pattern.
    pub best_time: Measurement,
    /// Speedup of the winning pattern over the baseline.
    pub best_speedup: f64,
}

/// Measure one pattern: transform, install externals, run. Returns the
/// timing, the program's result value, its printed output, and the
/// per-run device traffic observed through the engine.
pub fn measure_pattern(
    prog: &Program,
    entry: &str,
    blocks: &[PlannedReplacement],
    enabled: &[bool],
    engine: &Rc<Engine>,
    cfg: &VerifyConfig,
    label: &str,
) -> Result<(Measurement, Value, String, DeviceTraffic)> {
    let plans: Vec<PlannedReplacement> = blocks
        .iter()
        .zip(enabled)
        .filter(|(_, &on)| on)
        .map(|(b, _)| b.clone())
        .collect();
    let transformed = transform::apply(prog, &plans)?;
    let mut interp = Interp::new(&transformed)?;
    interp.fuel = cfg.fuel;
    for p in &plans {
        let name = transform::dispatch_name(&p.replacement.artifact);
        interp.set_external(&name, glue::build_external(engine.clone(), &p.replacement)?);
        // Pre-compile every size variant of the artifact so XLA compile
        // time (the cuFFT "library load") is not billed to the measured
        // run. Compilation is cached in the engine across patterns.
        for size_variant in engine
            .artifact_names()
            .iter()
            .filter(|n| n.starts_with(&format!("{}_n", p.replacement.artifact)))
        {
            let _ = engine.artifact(size_variant);
        }
    }
    let mut last: Option<Value> = None;
    let mut out_text = String::new();
    let stats_before = engine.stats.borrow().clone();
    let m = measure(label, cfg.warmup, cfg.reps, || {
        interp.reset_run_state()?;
        // Re-install externals (reset clears only run state, not externals;
        // still, keep the contract obvious).
        last = Some(interp.run(entry, &[])?);
        out_text = interp.output.clone();
        Ok(())
    })?;
    let stats_after = engine.stats.borrow().clone();
    // Warmup runs dispatch identically to measured ones, so the per-run
    // average over (warmup + reps) is the per-run traffic.
    let runs = (cfg.warmup + cfg.reps.max(1)) as u64;
    let traffic = DeviceTraffic {
        bytes_in: (stats_after.bytes_in - stats_before.bytes_in) / runs,
        bytes_out: (stats_after.bytes_out - stats_before.bytes_out) / runs,
        dispatches: (stats_after.executions - stats_before.executions) / runs,
        device_secs: (stats_after.exec_secs - stats_before.exec_secs) / runs as f64,
    };
    let v = last.ok_or_else(|| anyhow!("no measured run completed"))?;
    Ok((m, v, out_text, traffic))
}

fn values_close(a: &Value, b: &Value, tol: f64) -> bool {
    match (a.as_num(), b.as_num()) {
        (Ok(x), Ok(y)) => {
            let denom = x.abs().max(y.abs()).max(1e-9);
            ((x - y) / denom).abs() <= tol
        }
        // Non-numeric results: compare only kinds.
        _ => a.type_name() == b.type_name(),
    }
}

/// The paper's search: baseline → each block individually → combine the
/// individually-winning blocks → re-measure → fastest wins.
pub fn search_patterns(
    prog: &Program,
    entry: &str,
    blocks: &[PlannedReplacement],
    engine: &Rc<Engine>,
    cfg: &VerifyConfig,
) -> Result<SearchOutcome> {
    let none = vec![false; blocks.len()];
    let (baseline, base_val, _, _) =
        measure_pattern(prog, entry, blocks, &none, engine, cfg, "all-CPU")?;

    let mut tried = Vec::new();
    let mut best_enabled = none.clone();
    let mut best_time = baseline.clone();

    // Phase 1: individual on/off. A pattern that fails to transform or
    // crashes at run time is recorded as failed (speedup 0), exactly like
    // a miscompiled candidate on the paper's verification machine — it
    // just loses the comparison.
    for i in 0..blocks.len() {
        let mut enabled = none.clone();
        enabled[i] = true;
        let label = format!("only:{}", blocks[i].site.label());
        match measure_pattern(prog, entry, blocks, &enabled, engine, cfg, &label) {
            Ok((m, v, _, traffic)) => {
                let speedup = baseline.secs() / m.secs().max(1e-12);
                let output_ok = values_close(&base_val, &v, cfg.tolerance);
                if output_ok && m.median < best_time.median {
                    best_time = m.clone();
                    best_enabled = enabled.clone();
                }
                tried.push(PatternResult { enabled, label, time: m, speedup, output_ok, traffic });
            }
            Err(e) => {
                tried.push(PatternResult {
                    enabled,
                    label: format!("{label} [failed: {e}]"),
                    time: baseline.clone(),
                    speedup: 0.0,
                    output_ok: false,
                    traffic: DeviceTraffic::default(),
                });
            }
        }
    }

    // Phase 2: combine the individual winners (speedup > 1 AND correct).
    let winners: Vec<usize> = (0..blocks.len())
        .filter(|&i| tried[i].speedup > 1.0 && tried[i].output_ok)
        .collect();
    if winners.len() > 1 {
        let mut enabled = none.clone();
        for &i in &winners {
            enabled[i] = true;
        }
        if let Ok((m, v, _, traffic)) =
            measure_pattern(prog, entry, blocks, &enabled, engine, cfg, "combined-winners")
        {
            let speedup = baseline.secs() / m.secs().max(1e-12);
            let output_ok = values_close(&base_val, &v, cfg.tolerance);
            if output_ok && m.median < best_time.median {
                best_time = m.clone();
                best_enabled = enabled.clone();
            }
            tried.push(PatternResult {
                enabled,
                label: "combined-winners".into(),
                time: m,
                speedup,
                output_ok,
                traffic,
            });
        }
    }

    let best_speedup = baseline.secs() / best_time.secs().max(1e-12);
    Ok(SearchOutcome { baseline, tried, best_enabled, best_time, best_speedup })
}

/// Convenience: run the whole-program baseline (all-CPU) once and return
/// its duration — used by benches.
pub fn baseline_duration(prog: &Program, entry: &str, fuel: u64) -> Result<Duration> {
    let mut interp = Interp::new(prog)?;
    interp.fuel = fuel;
    let t0 = std::time::Instant::now();
    interp.run(entry, &[])?;
    Ok(t0.elapsed())
}
