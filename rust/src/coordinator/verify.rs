//! Verification-environment pattern search (paper §4.2).
//!
//! "Even if existing know-how says a block can be accelerated, you don't
//! know it is faster *under these conditions* until you measure it."  With
//! k replaceable blocks the implementation measures each block on/off
//! individually, combines the winners, re-measures, and picks the fastest
//! pattern as the solution. This module is that loop: every candidate
//! pattern is an actual transformed program executed in the interpreter
//! with PJRT-backed externals installed.
//!
//! The search is structured **plan / measure / reduce** so the independent
//! measurements can be fanned out:
//!
//! * [`VerifyPlan`] enumerates the pattern measurements — the all-CPU
//!   baseline and every phase-1 single-block pattern form one batch of
//!   *independent* measurements; the phase-2 `combined-winners` pattern is
//!   derived from the phase-1 results and measured in a second round.
//! * A [`PatternExecutor`] runs a batch. [`SerialExecutor`] measures the
//!   patterns one after another on a single engine (the paper's serial
//!   Step 3); the service tier's `PooledExecutor` fans them out across the
//!   worker pool's idle sibling engines.
//! * The reduce step ([`VerifyPlan::reduce`]) consumes results
//!   index-aligned with the plan, so the [`SearchOutcome`] — `best_enabled`,
//!   tie-breaks, `tried` ordering — is identical regardless of the order in
//!   which an executor completed the measurements.

use std::rc::Rc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::interp::{ExternalFn, Interp, Value};
use crate::metrics::{measure, Measurement};
use crate::parser::Program;
use crate::runtime::Engine;
use crate::telemetry::TraceEvent;
use crate::transform::{self, glue, PlannedReplacement};

/// Verification-run configuration.
#[derive(Debug, Clone)]
pub struct VerifyConfig {
    /// Measured repetitions per pattern (median taken).
    pub reps: usize,
    /// Unmeasured warm-up runs before the measured repetitions.
    pub warmup: usize,
    /// Interpreter fuel per run (guards diverging candidates).
    pub fuel: u64,
    /// Relative tolerance when checking the offloaded result against the
    /// CPU result (f32 artifact vs f64 interpreter).
    pub tolerance: f64,
}

impl Default for VerifyConfig {
    fn default() -> Self {
        VerifyConfig { reps: 3, warmup: 0, fuel: u64::MAX, tolerance: 1e-2 }
    }
}

/// Host<->device traffic observed while measuring one pattern, averaged
/// per run. Captured from [`crate::runtime::EngineStats`] deltas around
/// the measured runs; the backend-arbitration stage uses it to size the
/// FPGA timing model (working set, dispatch count) and to compare FPGA
/// estimates against the *measured* PJRT device seconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DeviceTraffic {
    /// Bytes staged host -> device per run.
    pub bytes_in: u64,
    /// Bytes read device -> host per run.
    pub bytes_out: u64,
    /// Artifact dispatches per run.
    pub dispatches: u64,
    /// Measured wall-clock seconds inside the PJRT engine per run
    /// (staging + device execution + readback).
    pub device_secs: f64,
    /// Host -> device bytes per run whose transfer was elided because the
    /// value was already device-resident. Zero unless a
    /// [`crate::runtime::DataPlane`] is installed (`--resident-bytes`);
    /// `bytes_in` stays paid-only, so the PCIe arithmetic in
    /// [`crate::coordinator::power::transfer_secs`] automatically credits
    /// the savings.
    pub elided_in: u64,
    /// Device -> host bytes per run elided by residency (zero unless a
    /// data plane is installed). Not included in `bytes_out`.
    pub elided_out: u64,
}

/// One planned pattern measurement: which blocks to enable plus the
/// human-readable label the result is reported under.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PatternSpec {
    /// Per-block on/off mask (index-aligned with the block list).
    pub enabled: Vec<bool>,
    /// Pattern label (`all-CPU`, `only:call:fft2d`, `combined-winners`).
    pub label: String,
}

/// Thread-portable digest of a run's result value — exactly what the
/// correctness check ([`ResultProbe::close_to`]) needs, so the pooled
/// executor can ship it across worker threads (interpreter [`Value`]s
/// hold `Rc` state and cannot leave their engine's thread).
#[derive(Debug, Clone, PartialEq)]
pub struct ResultProbe {
    /// Numeric result, when the run produced one.
    pub num: Option<f64>,
    /// Type name of the result (compared when non-numeric).
    pub type_name: &'static str,
}

impl ResultProbe {
    /// Digest a run's result value.
    pub fn of(v: &Value) -> ResultProbe {
        ResultProbe { num: v.as_num().ok(), type_name: v.type_name() }
    }

    /// Is this result within `tol` (relative) of `other`? Non-numeric
    /// results compare by type name only.
    pub fn close_to(&self, other: &ResultProbe, tol: f64) -> bool {
        match (self.num, other.num) {
            (Some(x), Some(y)) => {
                let denom = x.abs().max(y.abs()).max(1e-9);
                ((x - y) / denom).abs() <= tol
            }
            _ => self.type_name == other.type_name,
        }
    }
}

/// One measured pattern, before correctness/speedup resolution. All
/// fields are plain values (`Send`), so executors may produce them on
/// sibling worker threads.
#[derive(Debug, Clone)]
pub struct MeasuredPattern {
    /// Measured wall-clock of the pattern run.
    pub time: Measurement,
    /// Digest of the program's result value (correctness check input).
    pub probe: ResultProbe,
    /// Captured `printf` output of the last run.
    pub output: String,
    /// Per-run host<->device traffic observed during measurement.
    pub traffic: DeviceTraffic,
}

/// Result of measuring one offload pattern.
#[derive(Debug, Clone)]
pub struct PatternResult {
    /// Which blocks were enabled.
    pub enabled: Vec<bool>,
    /// Human-readable pattern label (e.g. `only:call:fft2d`).
    pub label: String,
    /// Measured wall-clock of the whole pattern run.
    pub time: Measurement,
    /// Speedup vs the all-CPU baseline.
    pub speedup: f64,
    /// Did the program produce the same result as the CPU run?
    pub output_ok: bool,
    /// Per-run host<->device traffic observed during measurement.
    pub traffic: DeviceTraffic,
}

/// Full search outcome.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// All-CPU baseline measurement.
    pub baseline: Measurement,
    /// Every measured pattern, per-block ones first (index-aligned with the block list).
    pub tried: Vec<PatternResult>,
    /// Winning pattern (indices into the block list).
    pub best_enabled: Vec<bool>,
    /// Measurement of the winning pattern.
    pub best_time: Measurement,
    /// Speedup of the winning pattern over the baseline.
    pub best_speedup: f64,
}

/// Structured telemetry events of one Step-3 search: the all-CPU
/// baseline measurement first (no device traffic by construction), then
/// every tried pattern in plan order. Built lazily by the pipeline only
/// when a [`crate::coordinator::StageObserver`] is installed.
pub fn measurement_events(outcome: &SearchOutcome) -> Vec<TraceEvent> {
    // Labels come from the pattern, not the measurement: a failed
    // pattern's `time` is a baseline clone, but its *label* carries the
    // failure text.
    let one = |label: &str, m: &Measurement, traffic: &DeviceTraffic| {
        TraceEvent::PatternMeasured {
            label: label.to_string(),
            reps: m.reps as u64,
            median_ns: m.median.as_nanos() as u64,
            min_ns: m.min.as_nanos() as u64,
            max_ns: m.max.as_nanos() as u64,
            bytes_in: traffic.bytes_in,
            bytes_out: traffic.bytes_out,
            dispatches: traffic.dispatches,
            device_secs: traffic.device_secs,
        }
    };
    let mut out =
        vec![one(&outcome.baseline.label, &outcome.baseline, &DeviceTraffic::default())];
    out.extend(outcome.tried.iter().map(|p| one(&p.label, &p.time, &p.traffic)));
    out
}

/// Everything a [`PatternExecutor`] needs to measure patterns of one
/// program: the (library-linked) program, its entry point, the reconciled
/// block list, and the measurement settings.
#[derive(Debug, Clone, Copy)]
pub struct VerifyContext<'a> {
    /// The library-linked program the patterns transform.
    pub prog: &'a Program,
    /// Entry-point function name.
    pub entry: &'a str,
    /// Accepted replacement plans, in block order.
    pub blocks: &'a [PlannedReplacement],
    /// Measurement settings (reps, warmup, fuel, tolerance).
    pub cfg: &'a VerifyConfig,
    /// Analytic per-block predicted wall seconds (index-aligned with
    /// `blocks`), for executors that order work by expected cost — the
    /// fleet scheduler's LPT partitioning. Empty under the default
    /// estimator configuration: executors must fall back to their own
    /// cost model, keeping default-path dispatch order unchanged.
    pub cost_hints: &'a [f64],
}

/// Runs a batch of *independent* pattern measurements. Implementations
/// may execute the batch in any order — or concurrently on sibling
/// engines — but must return results **index-aligned** with `specs`, so
/// the reduce step is deterministic regardless of completion order.
/// Per-pattern failures are `Err` entries (recorded as failed patterns by
/// the search, exactly like a miscompiled candidate on the paper's
/// verification machine).
pub trait PatternExecutor {
    /// Measure every spec in the batch; one result per spec, in order.
    fn measure(
        &self,
        ctx: &VerifyContext<'_>,
        specs: &[PatternSpec],
    ) -> Vec<Result<MeasuredPattern>>;

    /// Short human label for reports and benches (`serial`, `pooled`).
    fn name(&self) -> &'static str;
}

/// The default executor: measures patterns one after another on a single
/// engine — the paper's serial Step 3.
pub struct SerialExecutor {
    engine: Rc<Engine>,
}

impl SerialExecutor {
    /// Executor over one engine.
    pub fn new(engine: Rc<Engine>) -> Self {
        SerialExecutor { engine }
    }
}

impl PatternExecutor for SerialExecutor {
    fn measure(
        &self,
        ctx: &VerifyContext<'_>,
        specs: &[PatternSpec],
    ) -> Vec<Result<MeasuredPattern>> {
        specs.iter().map(|s| measure_spec(ctx, s, &self.engine)).collect()
    }

    fn name(&self) -> &'static str {
        "serial"
    }
}

/// Measure one planned pattern on an engine (the per-spec body shared by
/// [`SerialExecutor`] and the service tier's pooled workers).
pub fn measure_spec(
    ctx: &VerifyContext<'_>,
    spec: &PatternSpec,
    engine: &Rc<Engine>,
) -> Result<MeasuredPattern> {
    measure_pattern(
        ctx.prog,
        ctx.entry,
        ctx.blocks,
        &spec.enabled,
        engine,
        ctx.cfg,
        &spec.label,
    )
}

/// Measure one pattern: transform, install externals, run. Returns the
/// timing, a digest of the program's result value, its printed output,
/// and the per-run device traffic observed through the engine.
pub fn measure_pattern(
    prog: &Program,
    entry: &str,
    blocks: &[PlannedReplacement],
    enabled: &[bool],
    engine: &Rc<Engine>,
    cfg: &VerifyConfig,
    label: &str,
) -> Result<MeasuredPattern> {
    let plans: Vec<PlannedReplacement> = blocks
        .iter()
        .zip(enabled)
        .filter(|(_, &on)| on)
        .map(|(b, _)| b.clone())
        .collect();
    let transformed = transform::apply(prog, &plans)?;
    let mut interp = Interp::new(&transformed)?;
    interp.fuel = cfg.fuel;
    // Share the engine's data plane (if one is installed) so the bulk
    // loop-offload path classifies its transfers against the same
    // residency map as the PJRT dispatches. `None` by default.
    interp.data_plane = engine.data_plane();
    let mut externals: Vec<(String, ExternalFn)> = Vec::with_capacity(plans.len());
    for p in &plans {
        let name = transform::dispatch_name(&p.replacement.artifact);
        externals.push((name, glue::build_external(engine.clone(), &p.replacement)?));
        // Pre-compile every size variant of the artifact so XLA compile
        // time (the cuFFT "library load") is not billed to the measured
        // run. Compilation is cached in the engine across patterns.
        for size_variant in engine
            .artifact_names()
            .iter()
            .filter(|n| n.starts_with(&format!("{}_n", p.replacement.artifact)))
        {
            let _ = engine.artifact(size_variant);
        }
    }
    let mut last: Option<Value> = None;
    let mut out_text = String::new();
    let stats_before = engine.stats.borrow().clone();
    let m = measure(label, cfg.warmup, cfg.reps, || {
        interp.reset_run_state()?;
        // Re-install the externals after every reset. `reset_run_state`
        // clears only run state today, but the pooled executor re-runs
        // interpreters aggressively — the contract is enforced here, not
        // assumed (see the externals_survive_reset regression test).
        for (name, f) in &externals {
            interp.set_external(name, f.clone());
        }
        last = Some(interp.run(entry, &[])?);
        out_text = interp.output.clone();
        Ok(())
    })?;
    let stats_after = engine.stats.borrow().clone();
    // Warmup runs dispatch identically to measured ones, so the per-run
    // traffic is the delta divided by the exact number of
    // engine-dispatching runs: the warmups plus the measured repetitions
    // *actually performed*. `measure` clamps `reps == 0` to one measured
    // run; deriving the count from the returned `Measurement` keeps this
    // divisor honest instead of re-deriving the clamp here.
    let runs = (cfg.warmup + m.reps) as u64;
    let traffic = DeviceTraffic {
        bytes_in: (stats_after.bytes_in - stats_before.bytes_in) / runs,
        bytes_out: (stats_after.bytes_out - stats_before.bytes_out) / runs,
        dispatches: (stats_after.executions - stats_before.executions) / runs,
        device_secs: (stats_after.exec_secs - stats_before.exec_secs) / runs as f64,
        elided_in: (stats_after.elided_in - stats_before.elided_in) / runs,
        elided_out: (stats_after.elided_out - stats_before.elided_out) / runs,
    };
    let v = last.ok_or_else(|| anyhow!("no measured run completed"))?;
    Ok(MeasuredPattern { time: m, probe: ResultProbe::of(&v), output: out_text, traffic })
}

/// The plan side of the search: enumerates the pattern batches and folds
/// measured results back into a deterministic [`SearchOutcome`].
#[derive(Debug, Clone)]
pub struct VerifyPlan {
    labels: Vec<String>,
}

impl VerifyPlan {
    /// Plan over a reconciled block list.
    pub fn new(blocks: &[PlannedReplacement]) -> VerifyPlan {
        VerifyPlan { labels: blocks.iter().map(|b| b.site.label()).collect() }
    }

    /// Number of replaceable blocks the plan covers.
    pub fn block_count(&self) -> usize {
        self.labels.len()
    }

    /// The first batch of independent measurements: the all-CPU baseline
    /// (index 0) followed by every phase-1 single-block pattern, in block
    /// order.
    pub fn phase1(&self) -> Vec<PatternSpec> {
        let n = self.labels.len();
        let mut specs = Vec::with_capacity(n + 1);
        specs.push(PatternSpec { enabled: vec![false; n], label: "all-CPU".to_string() });
        for (i, label) in self.labels.iter().enumerate() {
            let mut enabled = vec![false; n];
            enabled[i] = true;
            specs.push(PatternSpec { enabled, label: format!("only:{label}") });
        }
        specs
    }

    /// The phase-2 pattern derived from the phase-1 results: combine the
    /// individual winners (speedup > 1 AND correct). `None` when fewer
    /// than two blocks won individually.
    pub fn phase2(&self, phase1: &[PatternResult]) -> Option<PatternSpec> {
        let n = self.labels.len();
        let winners: Vec<usize> = (0..n.min(phase1.len()))
            .filter(|&i| phase1[i].speedup > 1.0 && phase1[i].output_ok)
            .collect();
        if winners.len() > 1 {
            let mut enabled = vec![false; n];
            for &i in &winners {
                enabled[i] = true;
            }
            Some(PatternSpec { enabled, label: "combined-winners".to_string() })
        } else {
            None
        }
    }

    /// Fold one measured (or failed) pattern into a [`PatternResult`]. A
    /// failed measurement is recorded exactly like a miscompiled candidate
    /// on the paper's verification machine — speedup 0, incorrect, the
    /// failure folded into the label — for phase-1 *and* phase-2 patterns
    /// alike.
    pub fn resolve(
        &self,
        spec: &PatternSpec,
        measured: Result<MeasuredPattern>,
        baseline: &Measurement,
        base_probe: &ResultProbe,
        tolerance: f64,
    ) -> PatternResult {
        match measured {
            Ok(m) => {
                let speedup = baseline.secs() / m.time.secs().max(1e-12);
                let output_ok = m.probe.close_to(base_probe, tolerance);
                PatternResult {
                    enabled: spec.enabled.clone(),
                    label: spec.label.clone(),
                    time: m.time,
                    speedup,
                    output_ok,
                    traffic: m.traffic,
                }
            }
            Err(e) => PatternResult {
                enabled: spec.enabled.clone(),
                label: format!("{} [failed: {e}]", spec.label),
                time: baseline.clone(),
                speedup: 0.0,
                output_ok: false,
                traffic: DeviceTraffic::default(),
            },
        }
    }

    /// Deterministic reduce: walk `tried` in plan order (phase-1 block
    /// order, then `combined-winners`) and keep the fastest correct
    /// pattern, ties broken toward the earlier pattern (and toward the
    /// baseline over everything). Because `tried` is index-aligned with
    /// the plan, the outcome is independent of measurement completion
    /// order — serial and pooled executors agree exactly.
    pub fn reduce(&self, baseline: Measurement, tried: Vec<PatternResult>) -> SearchOutcome {
        let mut best_enabled = vec![false; self.labels.len()];
        let mut best_time = baseline.clone();
        for p in &tried {
            if p.output_ok && p.time.median < best_time.median {
                best_time = p.time.clone();
                best_enabled = p.enabled.clone();
            }
        }
        let best_speedup = baseline.secs() / best_time.secs().max(1e-12);
        SearchOutcome { baseline, tried, best_enabled, best_time, best_speedup }
    }
}

/// The paper's search: baseline → each block individually → combine the
/// individually-winning blocks → re-measure → fastest wins. Measures
/// serially on the given engine; [`search_patterns_with`] takes an
/// arbitrary executor.
pub fn search_patterns(
    prog: &Program,
    entry: &str,
    blocks: &[PlannedReplacement],
    engine: &Rc<Engine>,
    cfg: &VerifyConfig,
) -> Result<SearchOutcome> {
    search_patterns_with(prog, entry, blocks, cfg, &SerialExecutor::new(engine.clone()))
}

/// The paper's search over an arbitrary [`PatternExecutor`]: plan the
/// independent batches, have the executor measure them (serially or
/// fanned out), and reduce deterministically. A baseline failure fails
/// the search; any other pattern failure is recorded as a failed
/// [`PatternResult`]. Measures every planned pattern — the
/// estimator-aware entry point is [`search_patterns_full`].
pub fn search_patterns_with(
    prog: &Program,
    entry: &str,
    blocks: &[PlannedReplacement],
    cfg: &VerifyConfig,
    executor: &dyn PatternExecutor,
) -> Result<SearchOutcome> {
    search_patterns_full(prog, entry, blocks, cfg, executor, &[], &[])
}

/// [`search_patterns_with`] plus the analytic estimate's two outputs:
/// `cost_hints` (per-block predicted seconds, handed to the executor via
/// [`VerifyContext`] for cost-ordered dispatch) and `pruned` (per-block
/// mask; `true` withholds the block's phase-1 pattern from measurement
/// entirely, recording it as a pruned [`PatternResult`] — speedup 0,
/// incorrect, the analytic verdict folded into the label — so `tried`
/// stays index-aligned with the block list and a pruned block can never
/// win or join the combined round). Both slices may be empty (the
/// `--prune-policy off` default), in which case the search is exactly
/// [`search_patterns_with`]'s.
pub fn search_patterns_full(
    prog: &Program,
    entry: &str,
    blocks: &[PlannedReplacement],
    cfg: &VerifyConfig,
    executor: &dyn PatternExecutor,
    cost_hints: &[f64],
    pruned: &[bool],
) -> Result<SearchOutcome> {
    let ctx = VerifyContext { prog, entry, blocks, cfg, cost_hints };
    let plan = VerifyPlan::new(blocks);
    // The baseline ships in the same batch as the phase-1 patterns so a
    // pooled executor can overlap it with them (it is the slowest
    // pattern — measuring it alone first would serialize the search's
    // long pole). The trade-off: when the baseline itself fails, the
    // per-block patterns were measured for nothing before the error
    // surfaces below.
    let phase1 = plan.phase1();
    // Analytically-pruned blocks never reach the executor: their specs
    // are withheld from the batch (the baseline, index 0, is never
    // prunable) and resolved synthetically below.
    let is_pruned = |block: usize| pruned.get(block).copied().unwrap_or(false);
    let batch: Vec<PatternSpec> = phase1
        .iter()
        .enumerate()
        .filter(|(i, _)| *i == 0 || !is_pruned(i - 1))
        .map(|(_, s)| s.clone())
        .collect();
    // Estimate-ranked dispatch (ROADMAP PR-9 follow-on): when analytic
    // cost hints exist, hand the executor the predicted-best (cheapest
    // predicted seconds) pattern first so serial executors surface the
    // likely winner early and early-exit heuristics become possible. The
    // baseline keeps position 0, ties keep block order (stable sort), and
    // results are un-permuted back into plan order below — the reduce is
    // provably independent of the dispatch ranking. Empty hints (the
    // default estimator configuration) leave the order untouched.
    let unpruned: Vec<usize> = (0..blocks.len()).filter(|&b| !is_pruned(b)).collect();
    let mut perm: Vec<usize> = (0..batch.len()).collect();
    if !cost_hints.is_empty() {
        let hint = |pos: usize| {
            unpruned
                .get(pos - 1)
                .and_then(|&b| cost_hints.get(b))
                .copied()
                .unwrap_or(f64::INFINITY)
        };
        perm[1..].sort_by(|&a, &b| {
            hint(a).partial_cmp(&hint(b)).unwrap_or(std::cmp::Ordering::Equal)
        });
    }
    let dispatch: Vec<PatternSpec> = perm.iter().map(|&i| batch[i].clone()).collect();
    let raw = executor.measure(&ctx, &dispatch);
    if raw.len() != dispatch.len() {
        bail!(
            "{} executor returned {} results for {} planned patterns",
            executor.name(),
            raw.len(),
            dispatch.len()
        );
    }
    let mut aligned: Vec<Option<Result<MeasuredPattern>>> =
        (0..raw.len()).map(|_| None).collect();
    for (k, r) in raw.into_iter().enumerate() {
        aligned[perm[k]] = Some(r);
    }
    let mut measured: Vec<Result<MeasuredPattern>> =
        aligned.into_iter().map(|r| r.expect("permutation is a bijection")).collect();
    let base = measured
        .remove(0)
        .with_context(|| format!("measuring the all-CPU baseline of {entry:?}"))?;
    let baseline = base.time.clone();
    let base_probe = base.probe.clone();

    let mut results = measured.into_iter();
    let mut tried: Vec<PatternResult> = Vec::with_capacity(phase1.len() - 1);
    for (block, spec) in phase1[1..].iter().enumerate() {
        if is_pruned(block) {
            tried.push(PatternResult {
                enabled: spec.enabled.clone(),
                label: format!("{} [pruned by estimate]", spec.label),
                time: baseline.clone(),
                speedup: 0.0,
                output_ok: false,
                traffic: DeviceTraffic::default(),
            });
        } else {
            let res = results.next().expect("batch is aligned with the unpruned specs");
            tried.push(plan.resolve(spec, res, &baseline, &base_probe, cfg.tolerance));
        }
    }

    if let Some(combined) = plan.phase2(&tried) {
        let res = executor
            .measure(&ctx, std::slice::from_ref(&combined))
            .pop()
            .unwrap_or_else(|| {
                Err(anyhow!(
                    "{} executor returned no result for the combined pattern",
                    executor.name()
                ))
            });
        tried.push(plan.resolve(&combined, res, &baseline, &base_probe, cfg.tolerance));
    }

    Ok(plan.reduce(baseline, tried))
}

/// Convenience: run the whole-program baseline (all-CPU) once and return
/// its duration — used by benches.
pub fn baseline_duration(prog: &Program, entry: &str, fuel: u64) -> Result<Duration> {
    let mut interp = Interp::new(prog)?;
    interp.fuel = fuel;
    let t0 = std::time::Instant::now();
    interp.run(entry, &[])?;
    Ok(t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterndb::PatternDb;
    use crate::transform::Reconciliation;
    use std::cell::RefCell;
    use std::collections::HashMap;

    fn fake_blocks(n: usize) -> Vec<PlannedReplacement> {
        let repl = PatternDb::builtin().libraries[0].replacement.clone();
        (0..n)
            .map(|i| PlannedReplacement {
                site: crate::transform::Site::LibraryCall { callee: format!("blk{i}") },
                replacement: repl.clone(),
                reconciliation: Reconciliation::Exact,
            })
            .collect()
    }

    fn ms(label: &str, millis: u64) -> Measurement {
        Measurement {
            label: label.to_string(),
            median: Duration::from_millis(millis),
            min: Duration::from_millis(millis),
            max: Duration::from_millis(millis),
            reps: 1,
        }
    }

    fn pat(millis: u64) -> MeasuredPattern {
        MeasuredPattern {
            time: ms("x", millis),
            probe: ResultProbe { num: Some(42.0), type_name: "float" },
            output: String::new(),
            traffic: DeviceTraffic::default(),
        }
    }

    /// Executor scripted by label -> milliseconds (or failure). Optionally
    /// runs the batch in reverse order — the results are still returned
    /// index-aligned, which is the determinism contract.
    struct Scripted {
        times: HashMap<String, u64>,
        fail: Vec<String>,
        reverse: bool,
        calls: RefCell<Vec<Vec<String>>>,
    }

    impl Scripted {
        fn new(times: &[(&str, u64)], fail: &[&str], reverse: bool) -> Scripted {
            Scripted {
                times: times.iter().map(|(l, t)| (l.to_string(), *t)).collect(),
                fail: fail.iter().map(|s| s.to_string()).collect(),
                reverse,
                calls: RefCell::new(Vec::new()),
            }
        }

        fn one(&self, spec: &PatternSpec) -> Result<MeasuredPattern> {
            if self.fail.contains(&spec.label) {
                bail!("scripted failure");
            }
            let t = *self
                .times
                .get(&spec.label)
                .unwrap_or_else(|| panic!("unscripted pattern {:?}", spec.label));
            Ok(pat(t))
        }
    }

    impl PatternExecutor for Scripted {
        fn measure(
            &self,
            _ctx: &VerifyContext<'_>,
            specs: &[PatternSpec],
        ) -> Vec<Result<MeasuredPattern>> {
            self.calls.borrow_mut().push(specs.iter().map(|s| s.label.clone()).collect());
            let mut out: Vec<Option<Result<MeasuredPattern>>> =
                specs.iter().map(|_| None).collect();
            let order: Vec<usize> = if self.reverse {
                (0..specs.len()).rev().collect()
            } else {
                (0..specs.len()).collect()
            };
            for i in order {
                out[i] = Some(self.one(&specs[i]));
            }
            out.into_iter().map(|r| r.expect("all specs measured")).collect()
        }

        fn name(&self) -> &'static str {
            "scripted"
        }
    }

    fn run(script: &Scripted, nblocks: usize) -> SearchOutcome {
        let prog = crate::parser::parse("int main() { return 0; }").unwrap();
        let blocks = fake_blocks(nblocks);
        search_patterns_with(&prog, "main", &blocks, &VerifyConfig::default(), script).unwrap()
    }

    #[test]
    fn plan_enumerates_baseline_then_each_block() {
        let plan = VerifyPlan::new(&fake_blocks(3));
        let specs = plan.phase1();
        assert_eq!(specs.len(), 4);
        assert_eq!(specs[0].label, "all-CPU");
        assert_eq!(specs[0].enabled, vec![false, false, false]);
        assert_eq!(specs[1].label, "only:call:blk0");
        assert_eq!(specs[1].enabled, vec![true, false, false]);
        assert_eq!(specs[3].enabled, vec![false, false, true]);
    }

    #[test]
    fn combined_winners_beat_individuals() {
        let s = Scripted::new(
            &[
                ("all-CPU", 100),
                ("only:call:blk0", 50),
                ("only:call:blk1", 60),
                ("only:call:blk2", 200),
                ("combined-winners", 30),
            ],
            &[],
            false,
        );
        let out = run(&s, 3);
        assert_eq!(
            out.tried.iter().map(|p| p.label.as_str()).collect::<Vec<_>>(),
            vec!["only:call:blk0", "only:call:blk1", "only:call:blk2", "combined-winners"]
        );
        // Only blk0+blk1 won individually; the combined pattern enables
        // exactly those and wins overall.
        assert_eq!(out.best_enabled, vec![true, true, false]);
        assert_eq!(out.best_time.median, Duration::from_millis(30));
        assert!((out.best_speedup - 100.0 / 30.0).abs() < 1e-9);
    }

    #[test]
    fn completion_order_does_not_change_the_outcome() {
        let script = [
            ("all-CPU", 100),
            ("only:call:blk0", 55),
            ("only:call:blk1", 55),
            ("only:call:blk2", 90),
            ("combined-winners", 40),
        ];
        let fwd = run(&Scripted::new(&script, &[], false), 3);
        let rev = run(&Scripted::new(&script, &[], true), 3);
        assert_eq!(fwd.best_enabled, rev.best_enabled);
        assert_eq!(
            fwd.tried.iter().map(|p| &p.label).collect::<Vec<_>>(),
            rev.tried.iter().map(|p| &p.label).collect::<Vec<_>>()
        );
        assert_eq!(fwd.best_time.median, rev.best_time.median);
    }

    #[test]
    fn equal_times_tie_break_toward_the_earlier_pattern() {
        let s = Scripted::new(
            &[
                ("all-CPU", 100),
                ("only:call:blk0", 40),
                ("only:call:blk1", 40),
                ("combined-winners", 40),
            ],
            &[],
            false,
        );
        let out = run(&s, 2);
        // Strict `<`: a later equal measurement (blk1, then the combined
        // pattern) never displaces the earlier one — the tie-break the
        // cached decisions depend on.
        assert_eq!(out.best_enabled, vec![true, false]);
        assert_eq!(out.tried.len(), 3);
    }

    #[test]
    fn failed_combined_pattern_is_recorded_not_dropped() {
        let s = Scripted::new(
            &[
                ("all-CPU", 100),
                ("only:call:blk0", 50),
                ("only:call:blk1", 60),
            ],
            &["combined-winners"],
            false,
        );
        let out = run(&s, 2);
        // The phase-2 failure shows up in `tried` exactly like a phase-1
        // failure would: failed label, speedup 0, incorrect.
        assert_eq!(out.tried.len(), 3, "combined failure must be recorded");
        let combined = &out.tried[2];
        assert!(combined.label.starts_with("combined-winners [failed:"), "{}", combined.label);
        assert_eq!(combined.speedup, 0.0);
        assert!(!combined.output_ok);
        assert_eq!(combined.enabled, vec![true, true]);
        // The best pattern falls back to the fastest individual winner.
        assert_eq!(out.best_enabled, vec![true, false]);
    }

    #[test]
    fn failed_phase1_pattern_is_recorded_and_loses() {
        let s = Scripted::new(
            &[("all-CPU", 100), ("only:call:blk1", 60)],
            &["only:call:blk0"],
            false,
        );
        let out = run(&s, 2);
        assert_eq!(out.tried.len(), 2, "one winner -> no combined round");
        assert!(out.tried[0].label.contains("[failed:"));
        assert_eq!(out.best_enabled, vec![false, true]);
    }

    #[test]
    fn baseline_failure_fails_the_search() {
        let s = Scripted::new(&[("only:call:blk0", 10)], &["all-CPU"], false);
        let prog = crate::parser::parse("int main() { return 0; }").unwrap();
        let blocks = fake_blocks(1);
        let err = search_patterns_with(&prog, "main", &blocks, &VerifyConfig::default(), &s)
            .unwrap_err();
        assert!(format!("{err:#}").contains("all-CPU baseline"), "{err:#}");
    }

    #[test]
    fn zero_blocks_reduce_to_the_baseline() {
        let s = Scripted::new(&[("all-CPU", 100)], &[], false);
        let out = run(&s, 0);
        assert!(out.tried.is_empty());
        assert!(out.best_enabled.is_empty());
        assert_eq!(out.best_time.median, Duration::from_millis(100));
        assert!((out.best_speedup - 1.0).abs() < 1e-9);
        // The executor saw exactly one batch: the baseline alone.
        assert_eq!(*s.calls.borrow(), vec![vec!["all-CPU".to_string()]]);
    }

    #[test]
    fn pruned_blocks_are_never_measured_and_never_win() {
        // blk1 is pruned: it is never scripted, so reaching the executor
        // would panic — the assertion on `calls` shows it never did.
        let s = Scripted::new(&[("all-CPU", 100), ("only:call:blk0", 50)], &[], false);
        let prog = crate::parser::parse("int main() { return 0; }").unwrap();
        let blocks = fake_blocks(2);
        let out = search_patterns_full(
            &prog,
            "main",
            &blocks,
            &VerifyConfig::default(),
            &s,
            &[0.05, 0.2],
            &[false, true],
        )
        .unwrap();
        assert_eq!(
            *s.calls.borrow(),
            vec![vec!["all-CPU".to_string(), "only:call:blk0".to_string()]]
        );
        assert_eq!(out.tried.len(), 2, "pruned block still recorded");
        assert_eq!(out.tried[1].label, "only:call:blk1 [pruned by estimate]");
        assert_eq!(out.tried[1].speedup, 0.0);
        assert!(!out.tried[1].output_ok);
        assert_eq!(out.tried[1].enabled, vec![false, true]);
        assert_eq!(out.best_enabled, vec![true, false]);
    }

    #[test]
    fn empty_hints_and_mask_reproduce_the_plain_search() {
        let script: [(&str, u64); 4] = [
            ("all-CPU", 100),
            ("only:call:blk0", 50),
            ("only:call:blk1", 60),
            ("combined-winners", 30),
        ];
        let prog = crate::parser::parse("int main() { return 0; }").unwrap();
        let blocks = fake_blocks(2);
        let plain = search_patterns_with(
            &prog,
            "main",
            &blocks,
            &VerifyConfig::default(),
            &Scripted::new(&script, &[], false),
        )
        .unwrap();
        let full = search_patterns_full(
            &prog,
            "main",
            &blocks,
            &VerifyConfig::default(),
            &Scripted::new(&script, &[], false),
            &[],
            &[],
        )
        .unwrap();
        assert_eq!(plain.best_enabled, full.best_enabled);
        assert_eq!(
            plain.tried.iter().map(|p| &p.label).collect::<Vec<_>>(),
            full.tried.iter().map(|p| &p.label).collect::<Vec<_>>()
        );
        assert_eq!(plain.best_time.median, full.best_time.median);
    }

    #[test]
    fn cost_hints_rank_the_dispatch_and_leave_the_outcome_alone() {
        let script: [(&str, u64); 5] = [
            ("all-CPU", 100),
            ("only:call:blk0", 50),
            ("only:call:blk1", 60),
            ("only:call:blk2", 90),
            ("combined-winners", 30),
        ];
        let prog = crate::parser::parse("int main() { return 0; }").unwrap();
        let blocks = fake_blocks(3);
        // Predicted seconds rank blk1 < blk2 < blk0.
        let ranked = Scripted::new(&script, &[], false);
        let with_hints = search_patterns_full(
            &prog,
            "main",
            &blocks,
            &VerifyConfig::default(),
            &ranked,
            &[0.3, 0.1, 0.2],
            &[],
        )
        .unwrap();
        // The executor saw the baseline first, then the predicted-best
        // pattern, then the rest in predicted order.
        let dispatched: Vec<String> = ranked.calls.borrow()[0].clone();
        assert_eq!(
            dispatched,
            ["all-CPU", "only:call:blk1", "only:call:blk2", "only:call:blk0"]
                .map(String::from)
                .to_vec()
        );
        // ...but the SearchOutcome is the plain (unranked) search's:
        // `tried` in block order, same winner, same times.
        let plain = search_patterns_with(
            &prog,
            "main",
            &blocks,
            &VerifyConfig::default(),
            &Scripted::new(&script, &[], false),
        )
        .unwrap();
        assert_eq!(with_hints.best_enabled, plain.best_enabled);
        assert_eq!(
            with_hints.tried.iter().map(|p| &p.label).collect::<Vec<_>>(),
            plain.tried.iter().map(|p| &p.label).collect::<Vec<_>>()
        );
        assert_eq!(with_hints.best_time.median, plain.best_time.median);
        // Per-pattern results landed back on the right blocks despite the
        // permuted dispatch.
        assert_eq!(with_hints.tried[0].time.median, Duration::from_millis(50));
        assert_eq!(with_hints.tried[1].time.median, Duration::from_millis(60));
        assert_eq!(with_hints.tried[2].time.median, Duration::from_millis(90));
    }

    #[test]
    fn incorrect_output_never_wins() {
        // Fastest pattern, wrong answer: resolve() must mark it incorrect
        // and reduce() must keep the baseline.
        let plan = VerifyPlan::new(&fake_blocks(1));
        let specs = plan.phase1();
        let baseline = ms("all-CPU", 100);
        let base_probe = ResultProbe { num: Some(1.0), type_name: "float" };
        let mut wrong = pat(10);
        wrong.probe = ResultProbe { num: Some(5.0), type_name: "float" };
        let r = plan.resolve(&specs[1], Ok(wrong), &baseline, &base_probe, 1e-2);
        assert!(!r.output_ok);
        let out = plan.reduce(baseline, vec![r]);
        assert_eq!(out.best_enabled, vec![false]);
    }

    #[test]
    fn probe_tolerance_matches_the_old_values_close() {
        let a = ResultProbe { num: Some(100.0), type_name: "float" };
        let b = ResultProbe { num: Some(100.5), type_name: "float" };
        assert!(a.close_to(&b, 1e-2));
        let c = ResultProbe { num: Some(110.0), type_name: "float" };
        assert!(!a.close_to(&c, 1e-2));
        // Non-numeric results compare by kind.
        let x = ResultProbe { num: None, type_name: "array" };
        let y = ResultProbe { num: None, type_name: "array" };
        let z = ResultProbe { num: None, type_name: "struct" };
        assert!(x.close_to(&y, 1e-2));
        assert!(!x.close_to(&z, 1e-2));
    }
}
