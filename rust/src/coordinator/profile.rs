//! Device characteristics profiles for the analytic estimation stage.
//!
//! The function-block proposal (Yamato, *Proposal of Automatic Offloading
//! Method in Mixed Offloading Destination Environment*, arXiv:2004.09883)
//! narrows offload candidates by *suitability* before anything touches
//! hardware, and per-architecture characteristics tables are the concrete
//! shape that narrowing takes: compute units, shared memory, bandwidth,
//! clock, and bus figures per device generation, feeding an analytic
//! speedup estimate per candidate. This module is that table:
//!
//! * [`CpuProfile`] / [`GpuProfile`] / [`FpgaProfile`] — one entry per
//!   device class, with the roofline inputs the estimator consumes;
//! * [`ProfileRegistry`] — several GPU generations and FPGA families
//!   (not one hard-coded card), plus which entry is *active*, i.e.
//!   which device the verification environment actually has;
//! * canonical-JSON codecs so a registry is loadable via
//!   `--device-profile` and foldable into cache fingerprints;
//! * per-profile calibration `scale` factors, fitted from past measured
//!   reps by [`crate::coordinator::estimate::calibrate`].
//!
//! Like the wattage models (`power.rs`) and the HLS chain, profile
//! figures are *modeled* substitutes for datasheet numbers: relative
//! comparisons carry over, absolute seconds are earned through the
//! predicted-vs-measured error reported per block.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::patterndb::json::{self, Json};

/// Characteristics of the all-CPU baseline host.
#[derive(Debug, Clone, PartialEq)]
pub struct CpuProfile {
    /// Host name (diagnostics and fingerprints).
    pub name: String,
    /// Physical cores the interpreter baseline can draw on (the modeled
    /// baseline is single-threaded; cores scale the roofline ceiling the
    /// estimator compares devices against).
    pub cores: u64,
    /// Sustained core clock (Hz).
    pub clock_hz: f64,
    /// Floating-point ops retired per core per cycle.
    pub flops_per_cycle: f64,
    /// Sustained memory bandwidth (bytes/s).
    pub mem_bw_bytes_per_sec: f64,
    /// Calibration scale on the modeled throughput (1.0 = uncalibrated).
    pub scale: f64,
}

impl CpuProfile {
    /// Modeled peak floating-point throughput (flops/s), calibration
    /// applied.
    pub fn peak_flops(&self) -> f64 {
        self.cores as f64 * self.flops_per_cycle * self.clock_hz * self.scale
    }
}

/// Characteristics of one GPU generation (SNIPPETS snippet 3's
/// `GPUCharacteristics`, trimmed to what the roofline estimate consumes).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuProfile {
    /// Card name (diagnostics, fingerprints, `active_gpu` key).
    pub name: String,
    /// Architecture generation (e.g. "Pascal", "Volta", "Ampere").
    pub generation: String,
    /// Streaming multiprocessors.
    pub compute_units: u64,
    /// CUDA-core lanes per SM.
    pub cores_per_unit: u64,
    /// Sustained SM clock (Hz).
    pub clock_hz: f64,
    /// Shared memory per SM (bytes) — bounds the tile sizes the kernel
    /// strategy can assume; small shared memory discounts the roofline.
    pub shared_mem_bytes: u64,
    /// Device memory bandwidth (bytes/s).
    pub mem_bw_bytes_per_sec: f64,
    /// Host<->device PCIe bandwidth (bytes/s).
    pub pcie_bytes_per_sec: f64,
    /// Fixed kernel-launch overhead per offloaded run (s).
    pub launch_latency_secs: f64,
    /// Calibration scale on the modeled throughput (1.0 = uncalibrated).
    pub scale: f64,
}

impl GpuProfile {
    /// Modeled peak floating-point throughput (flops/s): units × lanes ×
    /// 2 (FMA) × clock, calibration applied.
    pub fn peak_flops(&self) -> f64 {
        self.compute_units as f64 * self.cores_per_unit as f64 * 2.0 * self.clock_hz * self.scale
    }
}

/// Characteristics of one FPGA family, mirroring the resource envelope
/// of [`crate::fpga::Device`] plus the streaming-model inputs.
#[derive(Debug, Clone, PartialEq)]
pub struct FpgaProfile {
    /// Card name (diagnostics, fingerprints, `active_fpga` key).
    pub name: String,
    /// Device family (e.g. "Arria10", "Stratix10").
    pub family: String,
    /// Adaptive logic modules available.
    pub alms: u64,
    /// DSP blocks available.
    pub dsps: u64,
    /// M20K BRAM blocks available.
    pub m20ks: u64,
    /// Achievable pipeline clock (Hz).
    pub fmax: f64,
    /// Host<->device PCIe bandwidth (bytes/s).
    pub pcie_bytes_per_sec: f64,
    /// Calibration scale on the modeled clock (1.0 = uncalibrated).
    pub scale: f64,
}

/// The profile registry: every device generation the estimator knows
/// about, plus which GPU and FPGA are *active* (present in the
/// verification environment). Loadable via `--device-profile`; the
/// built-in registry reproduces the paper's hardware plus newer
/// generations so mixed-fleet placement has something to choose between.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileRegistry {
    /// The all-CPU baseline host.
    pub cpu: CpuProfile,
    /// Known GPU generations.
    pub gpus: Vec<GpuProfile>,
    /// Known FPGA families.
    pub fpgas: Vec<FpgaProfile>,
    /// Name of the GPU actually behind the measured PJRT path.
    pub active_gpu: String,
    /// Name of the FPGA actually behind the modeled HLS path.
    pub active_fpga: String,
}

impl ProfileRegistry {
    /// Built-in registry: the paper's measurement hardware active (GTX
    /// 1050 Ti + Arria10 PAC), with newer generations registered for
    /// heterogeneous placement.
    pub fn builtin() -> ProfileRegistry {
        ProfileRegistry {
            cpu: CpuProfile {
                name: "Xeon-class host".to_string(),
                cores: 8,
                clock_hz: 2.4e9,
                flops_per_cycle: 4.0,
                mem_bw_bytes_per_sec: 40.0e9,
                scale: 1.0,
            },
            gpus: vec![
                GpuProfile {
                    name: "GeForce GTX 1050 Ti".to_string(),
                    generation: "Pascal".to_string(),
                    compute_units: 6,
                    cores_per_unit: 128,
                    clock_hz: 1.39e9,
                    shared_mem_bytes: 48 * 1024,
                    mem_bw_bytes_per_sec: 112.0e9,
                    pcie_bytes_per_sec: 6.0e9,
                    launch_latency_secs: 10.0e-6,
                    scale: 1.0,
                },
                GpuProfile {
                    name: "Tesla V100".to_string(),
                    generation: "Volta".to_string(),
                    compute_units: 80,
                    cores_per_unit: 64,
                    clock_hz: 1.53e9,
                    shared_mem_bytes: 96 * 1024,
                    mem_bw_bytes_per_sec: 900.0e9,
                    pcie_bytes_per_sec: 12.0e9,
                    launch_latency_secs: 8.0e-6,
                    scale: 1.0,
                },
                GpuProfile {
                    name: "GeForce RTX 3080".to_string(),
                    generation: "Ampere".to_string(),
                    compute_units: 68,
                    cores_per_unit: 128,
                    clock_hz: 1.71e9,
                    shared_mem_bytes: 128 * 1024,
                    mem_bw_bytes_per_sec: 760.0e9,
                    pcie_bytes_per_sec: 12.0e9,
                    launch_latency_secs: 6.0e-6,
                    scale: 1.0,
                },
            ],
            fpgas: vec![
                FpgaProfile {
                    name: "Intel Arria10 GX 1150".to_string(),
                    family: "Arria10".to_string(),
                    alms: 427_200,
                    dsps: 1_518,
                    m20ks: 2_713,
                    fmax: 240.0e6,
                    pcie_bytes_per_sec: 6.0e9,
                    scale: 1.0,
                },
                FpgaProfile {
                    name: "Intel Stratix10 GX 2800".to_string(),
                    family: "Stratix10".to_string(),
                    alms: 933_120,
                    dsps: 5_760,
                    m20ks: 11_721,
                    fmax: 300.0e6,
                    pcie_bytes_per_sec: 12.0e9,
                    scale: 1.0,
                },
            ],
            active_gpu: "GeForce GTX 1050 Ti".to_string(),
            active_fpga: "Intel Arria10 GX 1150".to_string(),
        }
    }

    /// The active GPU profile (the one the measured PJRT path stands for).
    pub fn gpu(&self) -> Result<&GpuProfile> {
        self.gpus
            .iter()
            .find(|g| g.name == self.active_gpu)
            .with_context(|| format!("active_gpu {:?} is not a registered profile", self.active_gpu))
    }

    /// The active FPGA profile (the one the modeled HLS path stands for).
    pub fn fpga(&self) -> Result<&FpgaProfile> {
        self.fpgas.iter().find(|f| f.name == self.active_fpga).with_context(|| {
            format!("active_fpga {:?} is not a registered profile", self.active_fpga)
        })
    }

    /// Every figure finite and positive, profile names unique, and both
    /// actives resolving to registered entries.
    pub fn validate(&self) -> Result<()> {
        let pos = |v: f64, what: &str, name: &str| -> Result<()> {
            if !v.is_finite() || v <= 0.0 {
                bail!("device profile {name:?}: {what} must be finite and positive, got {v}");
            }
            Ok(())
        };
        let c = &self.cpu;
        pos(c.clock_hz, "clock_hz", &c.name)?;
        pos(c.flops_per_cycle, "flops_per_cycle", &c.name)?;
        pos(c.mem_bw_bytes_per_sec, "mem_bw_bytes_per_sec", &c.name)?;
        pos(c.scale, "scale", &c.name)?;
        if c.cores == 0 {
            bail!("device profile {:?}: cores must be positive", c.name);
        }
        if self.gpus.is_empty() || self.fpgas.is_empty() {
            bail!("device profile registry needs at least one GPU and one FPGA entry");
        }
        for g in &self.gpus {
            pos(g.clock_hz, "clock_hz", &g.name)?;
            pos(g.mem_bw_bytes_per_sec, "mem_bw_bytes_per_sec", &g.name)?;
            pos(g.pcie_bytes_per_sec, "pcie_bytes_per_sec", &g.name)?;
            pos(g.scale, "scale", &g.name)?;
            if g.compute_units == 0 || g.cores_per_unit == 0 || g.shared_mem_bytes == 0 {
                bail!("device profile {:?}: zero-sized compute/shared-memory figures", g.name);
            }
            if !g.launch_latency_secs.is_finite() || g.launch_latency_secs < 0.0 {
                bail!("device profile {:?}: launch latency must be non-negative", g.name);
            }
        }
        for f in &self.fpgas {
            pos(f.fmax, "fmax", &f.name)?;
            pos(f.pcie_bytes_per_sec, "pcie_bytes_per_sec", &f.name)?;
            pos(f.scale, "scale", &f.name)?;
            if f.alms == 0 || f.dsps == 0 || f.m20ks == 0 {
                bail!("device profile {:?}: zero-sized resource envelope", f.name);
            }
        }
        let mut names: Vec<&str> = self
            .gpus
            .iter()
            .map(|g| g.name.as_str())
            .chain(self.fpgas.iter().map(|f| f.name.as_str()))
            .collect();
        names.sort_unstable();
        if names.windows(2).any(|w| w[0] == w[1]) {
            bail!("device profile names must be unique");
        }
        self.gpu()?;
        self.fpga()?;
        Ok(())
    }

    /// Stable digest blob for the cache fingerprints: every figure of
    /// every profile plus the active selections, in fixed order.
    pub fn fingerprint_blob(&self) -> String {
        let c = &self.cpu;
        let mut out = format!(
            "cpu:{}/{}/{}/{}/{}/{}",
            c.name, c.cores, c.clock_hz, c.flops_per_cycle, c.mem_bw_bytes_per_sec, c.scale
        );
        for g in &self.gpus {
            out.push_str(&format!(
                "|gpu:{}/{}/{}/{}/{}/{}/{}/{}/{}/{}",
                g.name,
                g.generation,
                g.compute_units,
                g.cores_per_unit,
                g.clock_hz,
                g.shared_mem_bytes,
                g.mem_bw_bytes_per_sec,
                g.pcie_bytes_per_sec,
                g.launch_latency_secs,
                g.scale
            ));
        }
        for f in &self.fpgas {
            out.push_str(&format!(
                "|fpga:{}/{}/{}/{}/{}/{}/{}/{}",
                f.name, f.family, f.alms, f.dsps, f.m20ks, f.fmax, f.pcie_bytes_per_sec, f.scale
            ));
        }
        out.push_str(&format!("|active:{}/{}", self.active_gpu, self.active_fpga));
        out
    }

    /// Load a registry from a `--device-profile` JSON file and validate it.
    pub fn load(path: &Path) -> Result<ProfileRegistry> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading --device-profile {}", path.display()))?;
        let reg = Self::from_json_str(&text)
            .with_context(|| format!("parsing --device-profile {}", path.display()))?;
        reg.validate()?;
        Ok(reg)
    }

    /// Canonical pretty JSON of the registry (the `--device-profile`
    /// on-disk format; also what `fbo calibrate` emits back).
    pub fn to_json_string(&self) -> String {
        json::to_string_pretty(&registry_to_json(self))
    }

    /// Inverse of [`ProfileRegistry::to_json_string`].
    pub fn from_json_str(s: &str) -> Result<ProfileRegistry> {
        registry_from_json(&json::parse(s)?)
    }
}

// ----------------------------------------------------------- JSON codec

fn cpu_to_json(c: &CpuProfile) -> Json {
    Json::obj(vec![
        ("name", Json::str(&c.name)),
        ("cores", Json::num(c.cores as f64)),
        ("clock_hz", Json::num(c.clock_hz)),
        ("flops_per_cycle", Json::num(c.flops_per_cycle)),
        ("mem_bw_bytes_per_sec", Json::num(c.mem_bw_bytes_per_sec)),
        ("scale", Json::num(c.scale)),
    ])
}

fn cpu_from_json(v: &Json) -> Result<CpuProfile> {
    Ok(CpuProfile {
        name: v.get("name")?.as_str()?.to_string(),
        cores: v.get("cores")?.as_f64()? as u64,
        clock_hz: v.get("clock_hz")?.as_f64()?,
        flops_per_cycle: v.get("flops_per_cycle")?.as_f64()?,
        mem_bw_bytes_per_sec: v.get("mem_bw_bytes_per_sec")?.as_f64()?,
        scale: v.get("scale")?.as_f64()?,
    })
}

fn gpu_to_json(g: &GpuProfile) -> Json {
    Json::obj(vec![
        ("name", Json::str(&g.name)),
        ("generation", Json::str(&g.generation)),
        ("compute_units", Json::num(g.compute_units as f64)),
        ("cores_per_unit", Json::num(g.cores_per_unit as f64)),
        ("clock_hz", Json::num(g.clock_hz)),
        ("shared_mem_bytes", Json::num(g.shared_mem_bytes as f64)),
        ("mem_bw_bytes_per_sec", Json::num(g.mem_bw_bytes_per_sec)),
        ("pcie_bytes_per_sec", Json::num(g.pcie_bytes_per_sec)),
        ("launch_latency_secs", Json::num(g.launch_latency_secs)),
        ("scale", Json::num(g.scale)),
    ])
}

fn gpu_from_json(v: &Json) -> Result<GpuProfile> {
    Ok(GpuProfile {
        name: v.get("name")?.as_str()?.to_string(),
        generation: v.get("generation")?.as_str()?.to_string(),
        compute_units: v.get("compute_units")?.as_f64()? as u64,
        cores_per_unit: v.get("cores_per_unit")?.as_f64()? as u64,
        clock_hz: v.get("clock_hz")?.as_f64()?,
        shared_mem_bytes: v.get("shared_mem_bytes")?.as_f64()? as u64,
        mem_bw_bytes_per_sec: v.get("mem_bw_bytes_per_sec")?.as_f64()?,
        pcie_bytes_per_sec: v.get("pcie_bytes_per_sec")?.as_f64()?,
        launch_latency_secs: v.get("launch_latency_secs")?.as_f64()?,
        scale: v.get("scale")?.as_f64()?,
    })
}

fn fpga_to_json(f: &FpgaProfile) -> Json {
    Json::obj(vec![
        ("name", Json::str(&f.name)),
        ("family", Json::str(&f.family)),
        ("alms", Json::num(f.alms as f64)),
        ("dsps", Json::num(f.dsps as f64)),
        ("m20ks", Json::num(f.m20ks as f64)),
        ("fmax", Json::num(f.fmax)),
        ("pcie_bytes_per_sec", Json::num(f.pcie_bytes_per_sec)),
        ("scale", Json::num(f.scale)),
    ])
}

fn fpga_from_json(v: &Json) -> Result<FpgaProfile> {
    Ok(FpgaProfile {
        name: v.get("name")?.as_str()?.to_string(),
        family: v.get("family")?.as_str()?.to_string(),
        alms: v.get("alms")?.as_f64()? as u64,
        dsps: v.get("dsps")?.as_f64()? as u64,
        m20ks: v.get("m20ks")?.as_f64()? as u64,
        fmax: v.get("fmax")?.as_f64()?,
        pcie_bytes_per_sec: v.get("pcie_bytes_per_sec")?.as_f64()?,
        scale: v.get("scale")?.as_f64()?,
    })
}

/// Serialize a registry (stage artifacts and the `--device-profile` file).
pub fn registry_to_json(r: &ProfileRegistry) -> Json {
    Json::obj(vec![
        ("format", Json::str("fbo-device-profiles-v1")),
        ("cpu", cpu_to_json(&r.cpu)),
        ("gpus", Json::Arr(r.gpus.iter().map(gpu_to_json).collect())),
        ("fpgas", Json::Arr(r.fpgas.iter().map(fpga_to_json).collect())),
        ("active_gpu", Json::str(&r.active_gpu)),
        ("active_fpga", Json::str(&r.active_fpga)),
    ])
}

/// Inverse of [`registry_to_json`].
pub fn registry_from_json(v: &Json) -> Result<ProfileRegistry> {
    let format = v.get("format")?.as_str()?;
    if format != "fbo-device-profiles-v1" {
        bail!("unsupported device-profile format {format:?} (want fbo-device-profiles-v1)");
    }
    Ok(ProfileRegistry {
        cpu: cpu_from_json(v.get("cpu")?)?,
        gpus: v.get("gpus")?.as_arr()?.iter().map(gpu_from_json).collect::<Result<_>>()?,
        fpgas: v.get("fpgas")?.as_arr()?.iter().map(fpga_from_json).collect::<Result<_>>()?,
        active_gpu: v.get("active_gpu")?.as_str()?.to_string(),
        active_fpga: v.get("active_fpga")?.as_str()?.to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_validates_and_matches_the_papers_hardware() {
        let r = ProfileRegistry::builtin();
        r.validate().unwrap();
        assert_eq!(r.gpu().unwrap().generation, "Pascal");
        assert_eq!(r.fpga().unwrap().family, "Arria10");
        // The active FPGA mirrors the arbitration's device model.
        let f = r.fpga().unwrap();
        assert_eq!(
            (f.alms, f.dsps, f.m20ks),
            (crate::fpga::ARRIA10_GX.alms, crate::fpga::ARRIA10_GX.dsps, crate::fpga::ARRIA10_GX.m20ks)
        );
        assert_eq!(f.fmax, crate::fpga::ARRIA10_GX.fmax);
        assert!(r.gpus.len() >= 3 && r.fpgas.len() >= 2, "several generations");
    }

    #[test]
    fn validation_rejects_broken_registries() {
        let mut r = ProfileRegistry::builtin();
        r.active_gpu = "missing".into();
        assert!(r.validate().is_err());

        let mut r = ProfileRegistry::builtin();
        r.gpus[0].clock_hz = 0.0;
        assert!(r.validate().is_err());

        let mut r = ProfileRegistry::builtin();
        r.fpgas[1].name = r.fpgas[0].name.clone();
        assert!(r.validate().is_err(), "duplicate names");

        let mut r = ProfileRegistry::builtin();
        r.cpu.scale = f64::NAN;
        assert!(r.validate().is_err());
    }

    #[test]
    fn fingerprint_blob_tracks_every_figure() {
        let base = ProfileRegistry::builtin().fingerprint_blob();
        assert_eq!(ProfileRegistry::builtin().fingerprint_blob(), base, "deterministic");

        let mut r = ProfileRegistry::builtin();
        r.gpus[1].mem_bw_bytes_per_sec += 1.0;
        assert_ne!(r.fingerprint_blob(), base);

        let mut r = ProfileRegistry::builtin();
        r.active_gpu = "Tesla V100".into();
        assert_ne!(r.fingerprint_blob(), base);

        let mut r = ProfileRegistry::builtin();
        r.fpgas[0].scale = 1.25;
        assert_ne!(r.fingerprint_blob(), base, "calibration is fingerprinted");
    }

    #[test]
    fn registry_codec_round_trips_byte_stable() {
        let r = ProfileRegistry::builtin();
        let s = r.to_json_string();
        let back = ProfileRegistry::from_json_str(&s).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.to_json_string(), s, "byte-stable");
        assert!(ProfileRegistry::from_json_str("{\"format\": \"nope\"}").is_err());
    }

    #[test]
    fn peak_flops_orders_the_generations() {
        let r = ProfileRegistry::builtin();
        let pascal = r.gpus[0].peak_flops();
        let volta = r.gpus[1].peak_flops();
        assert!(volta > pascal, "newer generation must model faster");
        assert!(r.cpu.peak_flops() < pascal, "GPU ceiling above host");
    }
}
