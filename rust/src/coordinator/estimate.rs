//! Analytic pre-arbitration: score candidates before measuring them.
//!
//! The paper's Step 3 measures every candidate pattern on real hardware,
//! which is why verification dominates wall-clock even fanned out across
//! a fleet. The function-block proposal (arXiv:2004.09883) narrows
//! candidates by *offload suitability* first; this module is that
//! narrowing, run as the `Estimate` stage between `Discovered`
//! (strictly, `Reconciled`) and `Verified`:
//!
//! * [`block_workload`] — static characterization of a DB-registered
//!   block (flops, bytes, trip count, arithmetic intensity) from the
//!   same CPU-implementation text the FPGA narrowing analyzes;
//! * [`score`] — roofline estimates per block against the *active*
//!   [`ProfileRegistry`] entries: GPU = intensity vs compute/bandwidth
//!   ceilings + PCIe staging, FPGA = the streaming-pipeline arithmetic
//!   the arbitration's HLS model uses (fill + trips/lanes cycles at
//!   `fmax`);
//! * [`PrunePolicy`] — the CLI `--prune-policy` knob deciding which
//!   clearly-hopeless candidates skip measurement. The default `off`
//!   leaves decisions, report bytes, and cache fingerprints exactly as
//!   they were before this stage existed;
//! * [`EstimateDecision`] — the v4-report residue comparing predicted
//!   vs measured seconds per block, the evidence the estimator earns
//!   trust with;
//! * [`calibrate`] — fits per-profile `scale` factors from measured
//!   reps (mined from past decisions in the cache), closing the loop.

use anyhow::{bail, Result};

use crate::analysis;
use crate::parser;
use crate::parser::ast::StmtKind;
use crate::patterndb::json::Json;
use crate::patterndb::{PassModel, PatternDb};
use crate::telemetry::TraceEvent;
use crate::transform::PlannedReplacement;

use super::backend::{Backend, STREAM_LANES};
use super::profile::{FpgaProfile, GpuProfile, ProfileRegistry};
use super::verify::SearchOutcome;

/// Nominal per-dimension problem size assumed when a block's loop bounds
/// are symbolic (the bundled apps run n×n working sets; 64 is the
/// evaluation size).
pub const NOMINAL_N: u64 = 64;

/// How the estimate prunes candidates before measurement
/// (CLI `--prune-policy`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum PrunePolicy {
    /// Estimate and report only — measure everything, exactly as before
    /// this stage existed. The default: decisions, report bytes, and
    /// cache fingerprints are byte-identical to a pipeline without
    /// estimation.
    #[default]
    Off,
    /// Prune a candidate only when its predicted best speedup, inflated
    /// by the safety margin, still loses to the CPU baseline
    /// (`speedup × (1 + margin) < 1`).
    Conservative(f64),
    /// Prune every candidate whose predicted best speedup is below 1.
    Aggressive,
}

impl PrunePolicy {
    /// Canonical rendering (CLI and cache fingerprint): `off`,
    /// `conservative:<margin>`, or `aggressive`.
    pub fn render(&self) -> String {
        match self {
            PrunePolicy::Off => "off".to_string(),
            PrunePolicy::Conservative(m) => format!("conservative:{m}"),
            PrunePolicy::Aggressive => "aggressive".to_string(),
        }
    }

    /// Inverse of [`PrunePolicy::render`].
    pub fn parse(s: &str) -> Result<Self> {
        if let Some(m) = s.strip_prefix("conservative:") {
            let margin: f64 = m.parse().map_err(|_| {
                anyhow::anyhow!("--prune-policy conservative expects a number, got {m:?}")
            })?;
            if !margin.is_finite() || margin < 0.0 {
                bail!("--prune-policy conservative expects a non-negative margin, got {m:?}");
            }
            return Ok(PrunePolicy::Conservative(margin));
        }
        Ok(match s {
            "off" => PrunePolicy::Off,
            "aggressive" => PrunePolicy::Aggressive,
            other => {
                bail!("unknown --prune-policy {other:?} (off|conservative:<margin>|aggressive)")
            }
        })
    }

    /// True for the default (`off`) policy, which must leave decisions,
    /// report bytes, and cache fingerprints untouched.
    pub fn is_default(&self) -> bool {
        matches!(self, PrunePolicy::Off)
    }

    /// Does this policy prune a candidate whose predicted best speedup
    /// is `best_speedup`?
    pub fn prunes(&self, best_speedup: f64) -> bool {
        match self {
            PrunePolicy::Off => false,
            PrunePolicy::Conservative(m) => best_speedup * (1.0 + m) < 1.0,
            PrunePolicy::Aggressive => best_speedup < 1.0,
        }
    }
}

/// Static workload characterization of one DB-registered block.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Workload {
    /// Modeled floating-point ops per run.
    pub flops: f64,
    /// Modeled bytes touched per run (array accesses × 4-byte elements,
    /// the artifact element size).
    pub bytes: f64,
    /// Estimated iterations of the deepest loop nest per run.
    pub iters: u64,
    /// Depth of the deepest loop nest.
    pub depth: u32,
    /// Arithmetic-intensity score: innermost flops/byte ratio × trip
    /// count — the same narrowing score the FPGA path ranks with.
    pub intensity: f64,
}

/// Look up the CPU-implementation text of a DB block, the same way the
/// arbitration's intensity narrowing does: comparison code first, then
/// the library's registered CPU source.
fn block_code<'a>(db: &'a PatternDb, artifact: &str) -> Option<&'a str> {
    db.comparisons
        .iter()
        .find(|c| c.replacement.artifact == artifact)
        .map(|c| c.code.as_str())
        .or_else(|| {
            db.libraries
                .iter()
                .find(|l| l.replacement.artifact == artifact)
                .and_then(|l| l.cpu_impl.as_ref().map(|(code, _)| code.as_str()))
        })
}

/// Characterize a DB-registered block statically: parse its CPU
/// implementation, take the densest loop nest's per-iteration flop and
/// memory counts, and scale by the nest's trip count ([`NOMINAL_N`] per
/// level when bounds are symbolic). Unknown blocks get a zero workload
/// (never estimated to win, never pruned).
pub fn block_workload(db: &PatternDb, artifact: &str) -> Workload {
    let Some(code) = block_code(db, artifact) else { return Workload::default() };
    let Ok(prog) = parser::parse(code) else { return Workload::default() };
    let a = analysis::analyze(&prog);
    let depth = a.loops.iter().map(|l| l.depth + 1).max().unwrap_or(0) as u32;
    let mut best = analysis::IntensityReport::default();
    for f in prog.functions() {
        let Some(body) = &f.body else { continue };
        body.walk(&mut |s| {
            if matches!(s.kind, StmtKind::For { .. }) {
                let r = analysis::intensity_of_loop(s);
                if r.score > best.score || (best.score == 0.0 && r.ratio > best.ratio) {
                    best = r;
                }
            }
        });
    }
    let iters = best.trips.unwrap_or_else(|| NOMINAL_N.saturating_pow(depth.max(1))).max(1);
    Workload {
        flops: best.flops_per_iter as f64 * iters as f64,
        bytes: best.mem_per_iter as f64 * iters as f64 * 4.0,
        iters,
        depth,
        intensity: best.ratio * iters as f64,
    }
}

/// Roofline estimate of one block on one device profile.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceEstimate {
    /// Profile the estimate was computed against.
    pub profile: String,
    /// Modeled on-device execution seconds per run.
    pub exec_secs: f64,
    /// Modeled PCIe staging seconds per run.
    pub transfer_secs: f64,
    /// Predicted speedup vs the modeled CPU baseline.
    pub speedup: f64,
}

impl DeviceEstimate {
    /// Total predicted wall seconds per run (execution + staging).
    pub fn total_secs(&self) -> f64 {
        self.exec_secs + self.transfer_secs
    }
}

/// Analytic estimate of one candidate block across the active profiles.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockEstimate {
    /// Site label of the block (matches the verify pattern labels).
    pub label: String,
    /// Artifact base name of the registered replacement.
    pub artifact: String,
    /// Static workload the estimates were derived from.
    pub workload: Workload,
    /// Modeled CPU-baseline seconds per run.
    pub cpu_secs: f64,
    /// Estimate on the active GPU profile.
    pub gpu: Option<DeviceEstimate>,
    /// Estimate on the active FPGA profile (`None` without a registered
    /// IP core for the artifact).
    pub fpga: Option<DeviceEstimate>,
}

impl BlockEstimate {
    /// The better of the device estimates (higher predicted speedup).
    pub fn best(&self) -> Option<&DeviceEstimate> {
        match (&self.gpu, &self.fpga) {
            (Some(g), Some(f)) => Some(if g.speedup >= f.speedup { g } else { f }),
            (Some(g), None) => Some(g),
            (None, Some(f)) => Some(f),
            (None, None) => None,
        }
    }

    /// Predicted best speedup vs the CPU baseline (0 with no device
    /// estimate — such a block is never predicted to win, never pruned).
    pub fn best_speedup(&self) -> f64 {
        self.best().map(|d| d.speedup).unwrap_or(0.0)
    }

    /// Predicted wall seconds of the block's measured pattern: the best
    /// device's total, or the modeled CPU seconds when nothing offloads.
    /// This is the fleet scheduler's LPT cost hint.
    pub fn predicted_secs(&self) -> f64 {
        self.best().map(|d| d.total_secs()).unwrap_or(self.cpu_secs)
    }

    /// The backend the estimate predicts wins this block.
    pub fn predicted_backend(&self) -> Backend {
        match self.best() {
            Some(d) if self.gpu.as_ref() == Some(d) || self.fpga.is_none() => Backend::Gpu,
            Some(_) => Backend::Fpga,
            None => Backend::Cpu,
        }
    }
}

/// The `Estimate` stage result: every accepted candidate scored against
/// the active device profiles, plus the policy the verify plan will
/// prune under.
#[derive(Debug, Clone, PartialEq)]
pub struct EstimateOutcome {
    /// Pruning policy in force downstream.
    pub policy: PrunePolicy,
    /// Active GPU profile name the scores were computed against.
    pub gpu_profile: String,
    /// Active FPGA profile name the scores were computed against.
    pub fpga_profile: String,
    /// Per-block estimates, aligned with the reconciled accepted blocks.
    pub blocks: Vec<BlockEstimate>,
}

impl EstimateOutcome {
    /// Which blocks the policy prunes from measurement, aligned with
    /// `blocks`. All-false under the default `off` policy.
    pub fn prune_mask(&self) -> Vec<bool> {
        self.blocks.iter().map(|b| self.policy.prunes(b.best_speedup())).collect()
    }

    /// Per-block predicted wall seconds for the fleet scheduler's LPT
    /// cost ordering, aligned with `blocks`.
    pub fn cost_hints(&self) -> Vec<f64> {
        self.blocks.iter().map(|b| b.predicted_secs()).collect()
    }
}

fn cpu_secs(w: &Workload, reg: &ProfileRegistry) -> f64 {
    (w.flops / reg.cpu.peak_flops()).max(w.bytes / (reg.cpu.mem_bw_bytes_per_sec * reg.cpu.scale))
}

fn gpu_estimate(w: &Workload, g: &GpuProfile, cpu: f64) -> DeviceEstimate {
    // Roofline: the kernel is bounded by the compute ceiling or the
    // memory ceiling, whichever binds. Working sets that spill the
    // per-SM shared memory pay a second device-memory round trip (the
    // coarse cost of not tiling).
    let spill = if w.bytes / g.compute_units as f64 > g.shared_mem_bytes as f64 { 2.0 } else { 1.0 };
    let exec = (w.flops / g.peak_flops())
        .max(w.bytes * spill / (g.mem_bw_bytes_per_sec * g.scale))
        + g.launch_latency_secs;
    let transfer = w.bytes / g.pcie_bytes_per_sec;
    DeviceEstimate {
        profile: g.name.clone(),
        exec_secs: exec,
        transfer_secs: transfer,
        speedup: cpu / (exec + transfer).max(1e-12),
    }
}

fn fpga_estimate(
    w: &Workload,
    f: &FpgaProfile,
    pass_model: Option<PassModel>,
    cpu: f64,
) -> DeviceEstimate {
    // The streaming-model arithmetic the arbitration's HLS chain uses
    // (fpga::modeled_exec_secs): pipeline fill + one trip per
    // STREAM_LANES-wide beat of the working set, at the profile's fmax.
    let n = (w.iters as f64).powf(1.0 / w.depth.max(1) as f64).round().max(1.0) as u64;
    let passes = pass_model.unwrap_or(PassModel::Unit).passes(n);
    let trips = (w.iters * passes + STREAM_LANES - 1) / STREAM_LANES;
    let exec = (crate::fpga::PIPELINE_FILL_CYCLES + trips as f64) / (f.fmax * f.scale);
    let transfer = w.bytes / f.pcie_bytes_per_sec;
    DeviceEstimate {
        profile: f.name.clone(),
        exec_secs: exec,
        transfer_secs: transfer,
        speedup: cpu / (exec + transfer).max(1e-12),
    }
}

/// Score every accepted candidate block against the registry's active
/// profiles. Pure and hardware-free: inputs are the DB text, the
/// profile figures, and the policy.
pub fn score(
    db: &PatternDb,
    accepted: &[PlannedReplacement],
    reg: &ProfileRegistry,
    policy: PrunePolicy,
) -> Result<EstimateOutcome> {
    reg.validate()?;
    let gpu = reg.gpu()?;
    let fpga = reg.fpga()?;
    let blocks = accepted
        .iter()
        .map(|plan| {
            let artifact = plan.replacement.artifact.clone();
            let w = block_workload(db, &artifact);
            let cpu = cpu_secs(&w, reg);
            let core = db.fpga_ip_cores.iter().find(|c| c.artifact == artifact);
            BlockEstimate {
                label: plan.site.label(),
                gpu: (w.flops > 0.0).then(|| gpu_estimate(&w, gpu, cpu)),
                fpga: core
                    .filter(|_| w.flops > 0.0)
                    .map(|c| fpga_estimate(&w, fpga, c.pass_model, cpu)),
                artifact,
                workload: w,
                cpu_secs: cpu,
            }
        })
        .collect();
    Ok(EstimateOutcome {
        policy,
        gpu_profile: gpu.name.clone(),
        fpga_profile: fpga.name.clone(),
        blocks,
    })
}

/// Structured telemetry events of one `Estimate` stage: one
/// `estimator-scored` event per device estimate per block. Built lazily
/// by the pipeline only when a [`crate::coordinator::StageObserver`] is
/// installed.
pub fn estimator_events(outcome: &EstimateOutcome) -> Vec<TraceEvent> {
    let mut out = Vec::new();
    for b in &outcome.blocks {
        for (backend, d) in
            [(Backend::Gpu, &b.gpu), (Backend::Fpga, &b.fpga)]
        {
            if let Some(d) = d {
                out.push(TraceEvent::EstimatorScored {
                    label: b.label.clone(),
                    backend: backend.as_str().to_string(),
                    predicted_secs: d.total_secs(),
                    speedup: d.speedup,
                    pruned: outcome.policy.prunes(b.best_speedup()),
                });
            }
        }
    }
    out
}

// ------------------------------------------------- arbitration residue

/// Predicted-vs-measured record of one block (v4 report residue).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockPrediction {
    /// Site label of the block.
    pub label: String,
    /// Backend the estimate predicted would win.
    pub backend: Backend,
    /// Predicted wall seconds of the block's measured pattern.
    pub predicted_secs: f64,
    /// Measured wall seconds of the matching pattern (`None` when the
    /// pattern was pruned or failed — nothing to compare against).
    pub measured_secs: Option<f64>,
    /// Signed relative error `(predicted − measured) / measured`.
    pub error: Option<f64>,
}

/// The estimate residue of one arbitration run under a non-default
/// estimator configuration: which profiles scored, per-block
/// predicted-vs-measured error, and the mean absolute percentage error.
/// Serialized into the v4 report; absent (and the report stays v2/v3)
/// under the default configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct EstimateDecision {
    /// Pruning policy that was in force.
    pub policy: PrunePolicy,
    /// Active GPU profile name.
    pub gpu_profile: String,
    /// Active FPGA profile name.
    pub fpga_profile: String,
    /// Per-block predicted-vs-measured records.
    pub blocks: Vec<BlockPrediction>,
    /// Mean absolute percentage error across blocks with a measurement.
    pub mape: Option<f64>,
}

/// Join the estimate against the measured search outcome: each block's
/// prediction meets its `only:{label}` measured pattern (pruned and
/// failed patterns have no measurement to compare against).
pub fn decision(est: &EstimateOutcome, search: &SearchOutcome) -> EstimateDecision {
    let blocks: Vec<BlockPrediction> = est
        .blocks
        .iter()
        .map(|b| {
            let want = format!("only:{}", b.label);
            let measured = search
                .tried
                .iter()
                .find(|p| p.label == want && p.output_ok)
                .map(|p| p.time.secs());
            let predicted = b.predicted_secs();
            BlockPrediction {
                label: b.label.clone(),
                backend: b.predicted_backend(),
                predicted_secs: predicted,
                measured_secs: measured,
                error: measured.map(|m| (predicted - m) / m.max(1e-12)),
            }
        })
        .collect();
    let errs: Vec<f64> = blocks.iter().filter_map(|b| b.error).map(f64::abs).collect();
    EstimateDecision {
        policy: est.policy,
        gpu_profile: est.gpu_profile.clone(),
        fpga_profile: est.fpga_profile.clone(),
        mape: (!errs.is_empty()).then(|| errs.iter().sum::<f64>() / errs.len() as f64),
        blocks,
    }
}

// ------------------------------------------------------------ calibration

/// One predicted-vs-measured pair mined from a past decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CalibrationSample {
    /// Backend the prediction targeted.
    pub backend: Backend,
    /// Predicted wall seconds at the time of the decision.
    pub predicted_secs: f64,
    /// Measured wall seconds the cache recorded.
    pub measured_secs: f64,
}

/// What a calibration pass did to the registry.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationReport {
    /// Samples that informed the GPU scale.
    pub gpu_samples: usize,
    /// Samples that informed the FPGA scale.
    pub fpga_samples: usize,
    /// New scale on the active GPU profile.
    pub gpu_scale: f64,
    /// New scale on the active FPGA profile.
    pub fpga_scale: f64,
}

/// Extract calibration samples from a past decision's estimate residue.
pub fn samples_from_decision(d: &EstimateDecision) -> Vec<CalibrationSample> {
    d.blocks
        .iter()
        .filter_map(|b| {
            b.measured_secs.map(|m| CalibrationSample {
                backend: b.backend,
                predicted_secs: b.predicted_secs,
                measured_secs: m,
            })
        })
        .filter(|s| s.predicted_secs > 0.0 && s.measured_secs > 0.0)
        .collect()
}

/// Median of predicted/measured ratios — robust against the odd outlier
/// rep the mean would chase.
fn median_ratio(samples: &[&CalibrationSample]) -> Option<f64> {
    if samples.is_empty() {
        return None;
    }
    let mut ratios: Vec<f64> =
        samples.iter().map(|s| s.predicted_secs / s.measured_secs).collect();
    ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(ratios[ratios.len() / 2])
}

/// Bounds on a fitted scale: calibration refines a profile, it must not
/// be able to invert one.
const SCALE_BOUNDS: (f64, f64) = (0.05, 20.0);

/// Fit the active profiles' scale factors from past measured reps: a
/// profile that predicted k× too slow gets its modeled throughput scaled
/// up by the median ratio (and vice versa), clamped to
/// [`SCALE_BOUNDS`]. Returns what changed; profiles without samples keep
/// their scale.
pub fn calibrate(reg: &mut ProfileRegistry, samples: &[CalibrationSample]) -> Result<CalibrationReport> {
    reg.validate()?;
    let fit = |old: f64, med: Option<f64>| -> f64 {
        med.map(|m| (old * m).clamp(SCALE_BOUNDS.0, SCALE_BOUNDS.1)).unwrap_or(old)
    };
    let gpu: Vec<&CalibrationSample> =
        samples.iter().filter(|s| s.backend == Backend::Gpu).collect();
    let fpga: Vec<&CalibrationSample> =
        samples.iter().filter(|s| s.backend == Backend::Fpga).collect();
    let (gm, fm) = (median_ratio(&gpu), median_ratio(&fpga));
    let active_gpu = reg.active_gpu.clone();
    let active_fpga = reg.active_fpga.clone();
    let mut report = CalibrationReport {
        gpu_samples: gpu.len(),
        fpga_samples: fpga.len(),
        gpu_scale: 1.0,
        fpga_scale: 1.0,
    };
    for g in &mut reg.gpus {
        if g.name == active_gpu {
            g.scale = fit(g.scale, gm);
            report.gpu_scale = g.scale;
        }
    }
    for f in &mut reg.fpgas {
        if f.name == active_fpga {
            f.scale = fit(f.scale, fm);
            report.fpga_scale = f.scale;
        }
    }
    Ok(report)
}

// ----------------------------------------------------------- JSON codec

fn device_estimate_to_json(d: &DeviceEstimate) -> Json {
    Json::obj(vec![
        ("profile", Json::str(&d.profile)),
        ("exec_secs", Json::num(d.exec_secs)),
        ("transfer_secs", Json::num(d.transfer_secs)),
        ("speedup", Json::num(d.speedup)),
    ])
}

fn device_estimate_from_json(v: &Json) -> Result<DeviceEstimate> {
    Ok(DeviceEstimate {
        profile: v.get("profile")?.as_str()?.to_string(),
        exec_secs: v.get("exec_secs")?.as_f64()?,
        transfer_secs: v.get("transfer_secs")?.as_f64()?,
        speedup: v.get("speedup")?.as_f64()?,
    })
}

fn workload_to_json(w: &Workload) -> Json {
    Json::obj(vec![
        ("flops", Json::num(w.flops)),
        ("bytes", Json::num(w.bytes)),
        ("iters", Json::num(w.iters as f64)),
        ("depth", Json::num(w.depth as f64)),
        ("intensity", Json::num(w.intensity)),
    ])
}

fn workload_from_json(v: &Json) -> Result<Workload> {
    Ok(Workload {
        flops: v.get("flops")?.as_f64()?,
        bytes: v.get("bytes")?.as_f64()?,
        iters: v.get("iters")?.as_f64()? as u64,
        depth: v.get("depth")?.as_f64()? as u32,
        intensity: v.get("intensity")?.as_f64()?,
    })
}

/// Serialize a stage outcome (the `Estimated` artifact payload).
pub fn outcome_to_json(o: &EstimateOutcome) -> Json {
    Json::obj(vec![
        ("policy", Json::str(&o.policy.render())),
        ("gpu_profile", Json::str(&o.gpu_profile)),
        ("fpga_profile", Json::str(&o.fpga_profile)),
        (
            "blocks",
            Json::Arr(
                o.blocks
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("label", Json::str(&b.label)),
                            ("artifact", Json::str(&b.artifact)),
                            ("workload", workload_to_json(&b.workload)),
                            ("cpu_secs", Json::num(b.cpu_secs)),
                            (
                                "gpu",
                                b.gpu.as_ref().map(device_estimate_to_json).unwrap_or(Json::Null),
                            ),
                            (
                                "fpga",
                                b.fpga.as_ref().map(device_estimate_to_json).unwrap_or(Json::Null),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Inverse of [`outcome_to_json`].
pub fn outcome_from_json(v: &Json) -> Result<EstimateOutcome> {
    Ok(EstimateOutcome {
        policy: PrunePolicy::parse(v.get("policy")?.as_str()?)?,
        gpu_profile: v.get("gpu_profile")?.as_str()?.to_string(),
        fpga_profile: v.get("fpga_profile")?.as_str()?.to_string(),
        blocks: v
            .get("blocks")?
            .as_arr()?
            .iter()
            .map(|b| {
                Ok(BlockEstimate {
                    label: b.get("label")?.as_str()?.to_string(),
                    artifact: b.get("artifact")?.as_str()?.to_string(),
                    workload: workload_from_json(b.get("workload")?)?,
                    cpu_secs: b.get("cpu_secs")?.as_f64()?,
                    gpu: b.opt("gpu").map(device_estimate_from_json).transpose()?,
                    fpga: b.opt("fpga").map(device_estimate_from_json).transpose()?,
                })
            })
            .collect::<Result<_>>()?,
    })
}

/// Serialize the arbitration's estimate residue (v4 report section).
pub fn decision_to_json(d: &EstimateDecision) -> Json {
    Json::obj(vec![
        ("policy", Json::str(&d.policy.render())),
        ("gpu_profile", Json::str(&d.gpu_profile)),
        ("fpga_profile", Json::str(&d.fpga_profile)),
        ("mape", d.mape.map(Json::num).unwrap_or(Json::Null)),
        (
            "blocks",
            Json::Arr(
                d.blocks
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("label", Json::str(&b.label)),
                            ("backend", Json::str(b.backend.as_str())),
                            ("predicted_secs", Json::num(b.predicted_secs)),
                            (
                                "measured_secs",
                                b.measured_secs.map(Json::num).unwrap_or(Json::Null),
                            ),
                            ("error", b.error.map(Json::num).unwrap_or(Json::Null)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Inverse of [`decision_to_json`].
pub fn decision_from_json(v: &Json) -> Result<EstimateDecision> {
    let opt_num =
        |b: &Json, key: &str| -> Result<Option<f64>> { b.opt(key).map(|n| n.as_f64()).transpose() };
    Ok(EstimateDecision {
        policy: PrunePolicy::parse(v.get("policy")?.as_str()?)?,
        gpu_profile: v.get("gpu_profile")?.as_str()?.to_string(),
        fpga_profile: v.get("fpga_profile")?.as_str()?.to_string(),
        mape: opt_num(v, "mape")?,
        blocks: v
            .get("blocks")?
            .as_arr()?
            .iter()
            .map(|b| {
                Ok(BlockPrediction {
                    label: b.get("label")?.as_str()?.to_string(),
                    backend: Backend::parse(b.get("backend")?.as_str()?)?,
                    predicted_secs: b.get("predicted_secs")?.as_f64()?,
                    measured_secs: opt_num(b, "measured_secs")?,
                    error: opt_num(b, "error")?,
                })
            })
            .collect::<Result<_>>()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Measurement;
    use crate::patterndb::json;
    use crate::transform::{Reconciliation, Site};
    use std::time::Duration;

    fn accepted(db: &PatternDb) -> Vec<PlannedReplacement> {
        vec![PlannedReplacement {
            site: Site::LibraryCall { callee: "fft2d".into() },
            replacement: db.libraries[0].replacement.clone(),
            reconciliation: Reconciliation::Exact,
        }]
    }

    #[test]
    fn policy_renders_and_parses() {
        for p in [PrunePolicy::Off, PrunePolicy::Conservative(0.5), PrunePolicy::Aggressive] {
            assert_eq!(PrunePolicy::parse(&p.render()).unwrap(), p);
        }
        assert!(PrunePolicy::Off.is_default());
        assert!(!PrunePolicy::Aggressive.is_default());
        assert!(PrunePolicy::parse("conservative:-1").is_err());
        assert!(PrunePolicy::parse("conservative:much").is_err());
        assert!(PrunePolicy::parse("eager").is_err());
    }

    #[test]
    fn policy_prunes_by_margin() {
        assert!(!PrunePolicy::Off.prunes(0.01), "off never prunes");
        assert!(PrunePolicy::Aggressive.prunes(0.99));
        assert!(!PrunePolicy::Aggressive.prunes(1.01));
        // conservative:1.0 keeps anything predicted within 2x of breaking
        // even, prunes what loses even with the doubled benefit of doubt.
        assert!(!PrunePolicy::Conservative(1.0).prunes(0.6));
        assert!(PrunePolicy::Conservative(1.0).prunes(0.4));
    }

    #[test]
    fn workload_characterizes_the_builtin_blocks() {
        let db = PatternDb::builtin();
        for artifact in ["fft2d", "matmul", "lu_factor"] {
            let w = block_workload(&db, artifact);
            assert!(w.flops > 0.0, "{artifact}: no flops");
            assert!(w.bytes > 0.0, "{artifact}: no bytes");
            assert!(w.depth >= 1 && w.iters >= 1, "{artifact}");
            assert!(w.intensity > 0.0, "{artifact}");
        }
        assert_eq!(block_workload(&db, "unknown"), Workload::default());
    }

    #[test]
    fn score_estimates_every_accepted_block() {
        let db = PatternDb::builtin();
        let reg = ProfileRegistry::builtin();
        let out = score(&db, &accepted(&db), &reg, PrunePolicy::Off).unwrap();
        assert_eq!(out.blocks.len(), 1);
        let b = &out.blocks[0];
        assert_eq!(b.label, "call:fft2d");
        let gpu = b.gpu.as_ref().expect("GPU estimate");
        assert!(gpu.exec_secs > 0.0 && gpu.speedup > 0.0);
        assert_eq!(gpu.profile, "GeForce GTX 1050 Ti");
        assert_eq!(out.prune_mask(), vec![false], "off never prunes");
        assert_eq!(out.cost_hints().len(), 1);
        assert!(out.cost_hints()[0] > 0.0);
    }

    #[test]
    fn faster_profiles_predict_faster_blocks() {
        let db = PatternDb::builtin();
        let mut reg = ProfileRegistry::builtin();
        let pascal = score(&db, &accepted(&db), &reg, PrunePolicy::Off).unwrap();
        reg.active_gpu = "Tesla V100".into();
        let volta = score(&db, &accepted(&db), &reg, PrunePolicy::Off).unwrap();
        let (p, v) =
            (pascal.blocks[0].gpu.as_ref().unwrap(), volta.blocks[0].gpu.as_ref().unwrap());
        assert!(v.total_secs() < p.total_secs(), "Volta {v:?} vs Pascal {p:?}");
    }

    #[test]
    fn decision_joins_predictions_with_measurements() {
        let db = PatternDb::builtin();
        let est =
            score(&db, &accepted(&db), &ProfileRegistry::builtin(), PrunePolicy::Aggressive)
                .unwrap();
        let m = |label: &str, us: u64| Measurement {
            label: label.to_string(),
            median: Duration::from_micros(us),
            min: Duration::from_micros(us),
            max: Duration::from_micros(us),
            reps: 1,
        };
        let search = SearchOutcome {
            baseline: m("all-CPU", 100_000),
            tried: vec![crate::coordinator::verify::PatternResult {
                enabled: vec![true],
                label: "only:call:fft2d".into(),
                time: m("only:call:fft2d", 2_000),
                speedup: 50.0,
                output_ok: true,
                traffic: Default::default(),
            }],
            best_enabled: vec![true],
            best_time: m("only:call:fft2d", 2_000),
            best_speedup: 50.0,
        };
        let d = decision(&est, &search);
        assert_eq!(d.blocks.len(), 1);
        let b = &d.blocks[0];
        assert_eq!(b.measured_secs, Some(0.002));
        let err = b.error.expect("error vs measurement");
        assert!((err - (b.predicted_secs - 0.002) / 0.002).abs() < 1e-9);
        assert_eq!(d.mape, Some(err.abs()));
        // Samples mined from the residue feed calibration.
        let samples = samples_from_decision(&d);
        assert_eq!(samples.len(), 1);
        assert_eq!(samples[0].measured_secs, 0.002);
    }

    #[test]
    fn calibration_moves_scales_toward_measurements() {
        let mut reg = ProfileRegistry::builtin();
        // Predictions uniformly 4x slower than measured: the model
        // underestimates the device, so its throughput scales up 4x.
        let samples: Vec<CalibrationSample> = (0..5)
            .map(|i| CalibrationSample {
                backend: Backend::Gpu,
                predicted_secs: 0.004 + i as f64 * 1e-6,
                measured_secs: 0.001,
            })
            .collect();
        let report = calibrate(&mut reg, &samples).unwrap();
        assert_eq!(report.gpu_samples, 5);
        assert!((report.gpu_scale - 4.0).abs() < 0.01, "scale {}", report.gpu_scale);
        assert_eq!(reg.gpu().unwrap().scale, report.gpu_scale);
        assert_eq!(reg.fpga().unwrap().scale, 1.0, "no FPGA samples, no change");
        // Calibrated profiles predict faster, shrinking the error.
        let db = PatternDb::builtin();
        let planned = vec![PlannedReplacement {
            site: Site::LibraryCall { callee: "fft2d".into() },
            replacement: db.libraries[0].replacement.clone(),
            reconciliation: Reconciliation::Exact,
        }];
        let before = score(&db, &planned, &ProfileRegistry::builtin(), PrunePolicy::Off).unwrap();
        let after = score(&db, &planned, &reg, PrunePolicy::Off).unwrap();
        assert!(
            after.blocks[0].gpu.as_ref().unwrap().exec_secs
                < before.blocks[0].gpu.as_ref().unwrap().exec_secs
        );
        // Clamped: absurd samples cannot invert the profile.
        let absurd = vec![CalibrationSample {
            backend: Backend::Gpu,
            predicted_secs: 1e6,
            measured_secs: 1e-9,
        }];
        let r = calibrate(&mut reg, &absurd).unwrap();
        assert_eq!(r.gpu_scale, SCALE_BOUNDS.1);
    }

    #[test]
    fn outcome_and_decision_codecs_round_trip_byte_stable() {
        let db = PatternDb::builtin();
        let est = score(&db, &accepted(&db), &ProfileRegistry::builtin(), PrunePolicy::Conservative(0.25))
            .unwrap();
        let s = json::to_string_pretty(&outcome_to_json(&est));
        let back = outcome_from_json(&json::parse(&s).unwrap()).unwrap();
        assert_eq!(back, est);
        assert_eq!(json::to_string_pretty(&outcome_to_json(&back)), s, "byte-stable");

        let d = EstimateDecision {
            policy: PrunePolicy::Aggressive,
            gpu_profile: "GeForce GTX 1050 Ti".into(),
            fpga_profile: "Intel Arria10 GX 1150".into(),
            mape: Some(0.4),
            blocks: vec![
                BlockPrediction {
                    label: "call:fft2d".into(),
                    backend: Backend::Gpu,
                    predicted_secs: 0.0015,
                    measured_secs: Some(0.002),
                    error: Some(-0.25),
                },
                BlockPrediction {
                    label: "func:mm".into(),
                    backend: Backend::Cpu,
                    predicted_secs: 0.1,
                    measured_secs: None,
                    error: None,
                },
            ],
        };
        let s = json::to_string_pretty(&decision_to_json(&d));
        let back = decision_from_json(&json::parse(&s).unwrap()).unwrap();
        assert_eq!(back, d);
        assert_eq!(json::to_string_pretty(&decision_to_json(&back)), s);
    }
}
