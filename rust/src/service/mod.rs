//! Offload **service layer**: amortizing the paper's one-time verification
//! cost across many requests.
//!
//! The pipeline behind [`crate::coordinator::Coordinator::offload`] is
//! expensive *by design* — it times every candidate pattern on the
//! verification machine before picking a winner. The companion proposal
//! paper (arXiv:2004.09883) frames that as a one-time cost paid before
//! commercial operation; this module is the tier that actually makes it
//! one-time and serves the result at traffic scale:
//!
//! * [`cache`] — a content-addressed **decision cache** keyed by
//!   (source AST hash, entry point, decision fingerprint), where the
//!   fingerprint digests the pattern DB, the AOT artifact contents, the
//!   policy/verification settings the pipeline runs under, and the
//!   backend target + FPGA device model arbitration decides against. A hit
//!   returns the previously
//!   verified [`crate::coordinator::OffloadReport`] byte-identically,
//!   with no pattern search and no measurement. Entries persist as JSON
//!   next to the artifacts dir and survive restarts. Caching is
//!   **stage-granular**: the pipeline's `Reconciled`, `Verified`, and
//!   `PowerScored` stage artifacts are cached under their own narrower
//!   fingerprints, so a full-decision miss resumes from the deepest
//!   still-valid stage (a verify-settings change replays discovery; a
//!   `--power-policy` change replays the verified measurements without
//!   re-measuring; a backend retarget replays the power scores and only
//!   re-arbitrates). The store is **size-bounded**: a standing
//!   [`CacheBudget`] (bytes and/or entries) is enforced after every
//!   insert with tier-aware LRU eviction — cheap-to-recompute tiers go
//!   first, `verified` measurements last — and `fbo cache gc` / `fbo
//!   cache stats` manage the store offline.
//! * [`pool`] — a **worker pool** running one [`crate::coordinator::Coordinator`]
//!   per thread (the PJRT runtime is deliberately single-threaded state:
//!   `Rc`/`RefCell`), fed by per-worker queues sharded on the cache key
//!   (identical in-flight jobs serialize; the pipeline never runs twice
//!   for one key), with submit/await and batch APIs plus per-service
//!   counters (jobs, cache hits/misses, stage replays, per-stage latency
//!   via the pipeline's [`crate::coordinator::StageObserver`] hook, and
//!   p50/p95 latency). The pool **sheds load** instead of queueing
//!   without bound: per-client token buckets and bounded per-worker
//!   queues ([`AdmissionConfig`]) reject over-limit submits with a
//!   structured [`JobRejected`], and shutdown is drain-then-stop
//!   ([`OffloadService::begin_shutdown`]).
//! * [`verify_exec`] — **parallel pattern-search verification**: with
//!   `verify_parallel > 1` the independent pattern measurements of one
//!   Step-3 search fan out across the pool's idle sibling engines
//!   (measurement sub-jobs interleave with decision jobs on the worker
//!   queues), so one search costs the wall-clock of its slowest pattern
//!   instead of the sum of all patterns. [`MeasurePool`] provides
//!   dedicated measure-only siblings for CLI runs without a service. The
//!   executor never changes the search *outcome* — serial and pooled
//!   decisions are byte-identical, and neither invalidates the other's
//!   cache entries. With `--fleet`, the pooled executor is wrapped by a
//!   [`crate::fleet::FleetExecutor`] that ships whole measurement
//!   batches to remote workers (other machines, other processes) and
//!   falls back to the local pool on any fleet failure — the same
//!   outcome-passivity contract, extended across machines.
//!
//! The pool is fully instrumented by [`crate::telemetry`]: every job id
//! doubles as a trace id (stage spans, pattern measurements, verdicts,
//! cache probes, resume markers), every counter lives in a metrics
//! registry, and [`MetricsHandle`] exposes Prometheus rendering plus
//! stats snapshots from any thread. Telemetry is passive — the
//! [`crate::telemetry::TelemetryConfig`] is excluded from every cache
//! fingerprint, so traced and untraced runs replay each other's
//! decisions byte-identically.
//!
//! Pipeline failures cross the service boundary as the structured
//! [`crate::coordinator::OffloadError`], so callers can route on the
//! failing stage:
//!
//! ```no_run
//! use fbo::coordinator::OffloadError;
//! use fbo::service::{OffloadService, ServiceConfig};
//!
//! # fn main() -> anyhow::Result<()> {
//! let service = OffloadService::start(ServiceConfig::new("artifacts"))?;
//! let handle = service.submit("void ludcmp(double a[], int n);\
//!                              int main() { double a[4]; ludcmp(a, 2); return 0; }", "main");
//! match handle.wait() {
//!     Ok(done) => {
//!         println!("speedup {} (cached: {})", done.report.best_speedup(), done.from_cache);
//!     }
//!     Err(e) => match e.downcast_ref::<OffloadError>() {
//!         Some(stage_err) => {
//!             eprintln!("pipeline failed at the {} stage: {stage_err}", stage_err.stage().as_str());
//!         }
//!         None => eprintln!("service error: {e:#}"),
//!     },
//! }
//! println!("{}", service.stats().render());
//! # Ok(())
//! # }
//! ```
//!
//! CLI: `fbo batch <files...>` and `fbo serve --jobs N`.

pub mod cache;
pub mod pool;
pub mod verify_exec;

pub use cache::{
    parse_byte_size, CacheBudget, CacheKey, CacheStats, CacheTelemetry, CacheTier, CacheUsage,
    DecisionCache, EvictedEntry, GcOutcome, DECISION_FORMAT, TIER_COUNT,
};
pub use pool::{
    AdmissionConfig, CompletedJob, JobHandle, JobRejected, MetricsHandle, OffloadService,
    ServiceConfig, ShedReason, StageStat, StatsSnapshot, WorkerStat,
};
pub use verify_exec::{MeasurePool, PooledExecutor};
