//! Job queue + coordinator worker pool.
//!
//! One [`Coordinator`] per worker thread: the PJRT runtime behind it holds
//! `Rc`/`RefCell` state and is not `Send`, so each coordinator is
//! constructed on its own thread and never leaves it. Jobs (owned source +
//! entry name) are `Send` and flow through one `mpsc` queue per worker;
//! each worker compiles its own copy of the artifacts once and then
//! serves pipeline runs for the life of the service.
//!
//! Every job is checked against the decision cache twice: at submit time
//! (a hit completes without touching the queue) and again on the worker
//! (an identical job may have been verified while this one was queued).
//! Jobs are **sharded onto workers by cache key**, so identical jobs in
//! flight land on the same worker and run in order: the first one
//! verifies, the duplicates behind it hit the cache on their second check
//! and replay the decision byte-identically — the pipeline never runs
//! twice for one key.
//!
//! Caching is **stage-granular**: besides full decisions, workers persist
//! the pipeline's `Reconciled`, `Estimated`, `Verified`, and
//! `PowerScored` stage artifacts under per-stage fingerprints
//! (`StageFingerprints`). A full-decision miss resumes from the deepest
//! valid stage instead of starting over — a `--reps` change replays
//! discovery from the cache and only re-measures; a `--power-policy`
//! change replays the verified measurements and only re-scores +
//! re-arbitrates; a `--target` or FPGA-device change replays the power
//! scores (or, under the default `perf` configuration, the verified
//! measurements — the inert default scores are recomputed rather than
//! persisted) and only re-arbitrates; a `--device-profile` or
//! `--prune-policy` change replays discovery and re-estimates +
//! re-measures (the `Estimated` tier, like the power tier, is only
//! persisted under a non-default estimator configuration — the default
//! estimate decides nothing, so it is recomputed rather than stored).
//! Workers install a [`StageObserver`] so the service counts per-stage
//! latency ([`StatsSnapshot::stages`]).
//!
//! With `verify_parallel > 1`, the Verify stage's independent pattern
//! measurements are fanned out across the pool: **measurement sub-jobs**
//! interleave with decision jobs on the per-worker queues, so idle
//! workers measure patterns for a busy sibling (see
//! [`super::verify_exec`]). The executor choice is deliberately *not*
//! part of any cache fingerprint — serial and pooled searches reduce to
//! the same outcome, so their cached decisions are byte-identical.
//!
//! **Admission control**: the service front-end sheds load instead of
//! queueing without bound. Each client is metered by a token bucket
//! ([`AdmissionConfig::rate_per_client`]) and each worker queue is
//! bounded ([`AdmissionConfig::queue_limit`]); a submit that would
//! breach either limit resolves immediately with a structured
//! [`JobRejected`] carrying the observed queue depth and a retry hint,
//! counted in `fbo_jobs_shed_total{reason}`. Shutdown is drain-then-stop:
//! [`OffloadService::begin_shutdown`] stops admission (subsequent
//! submits shed with [`ShedReason::ShuttingDown`]) while jobs already
//! queued complete normally — the shutdown marker sits behind them in
//! FIFO order — and anything that races past the marker is rejected
//! explicitly rather than dropped.
//!
//! **Telemetry**: every job id doubles as its trace id on the service's
//! [`TraceRecorder`] — stage spans, pattern measurements, power scores,
//! arbitration verdicts, cache-tier probes, resume markers, and
//! measurement fan-outs are recorded per job — and every counter behind
//! [`StatsSnapshot`] lives in the service's metrics [`Registry`]
//! (rendered by [`MetricsHandle::render_prometheus`]). Telemetry is
//! strictly passive: [`TelemetryConfig`] is excluded from every cache
//! fingerprint, so traced and untraced runs replay each other's
//! decisions byte-identically.

use std::cell::{Cell, RefCell};
use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{
    report_json, BackendPolicy, Coordinator, Estimated, OffloadError, OffloadReport,
    PatternExecutor, PowerModel, PowerPolicy, PowerScored, ProfileRegistry, PrunePolicy,
    Reconciled, Stage, StageObserver, Verified, VerifyConfig,
};
use crate::fleet::{FleetEndpoint, FleetExecutor, FleetRegistry, FleetTelemetry};
use crate::fpga;
use crate::metrics;
use crate::patterndb::json::{fnv1a64, Json};
use crate::patterndb::PatternDb;
use crate::telemetry::{
    Counter, Gauge, Histogram, Registry, TelemetryConfig, TraceEvent, TraceRecorder,
};
use crate::transform::InterfacePolicy;

use super::cache::{CacheBudget, CacheKey, CacheTelemetry, CacheTier, DecisionCache};
use super::verify_exec::{self, DispatchSink, ExecStats, MeasureJob, MeasureTx, PooledExecutor};

/// Admission-control settings: how the service sheds load instead of
/// queueing without bound. The default admits everything (the
/// pre-admission behavior) — production deployments bound both knobs.
///
/// Deliberately **not** part of any cache fingerprint: admission decides
/// *whether* a job runs, never what its decision is, so differently
/// throttled services replay each other's cached decisions
/// byte-identically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionConfig {
    /// Max decision jobs queued-or-running per worker before submits
    /// shed with [`ShedReason::QueueFull`]. `0` = unbounded.
    pub queue_limit: usize,
    /// Sustained per-client admission rate in jobs/second, enforced by a
    /// token bucket per client id. `None` = unlimited.
    pub rate_per_client: Option<f64>,
    /// Token-bucket capacity: how many jobs a client may burst above the
    /// sustained rate. Clamped to at least 1.
    pub burst: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { queue_limit: 0, rate_per_client: None, burst: 1.0 }
    }
}

/// Why a submit was shed (the `reason` label of `fbo_jobs_shed_total`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShedReason {
    /// The target worker's queue was at [`AdmissionConfig::queue_limit`].
    QueueFull,
    /// The client's token bucket was empty.
    RateLimited,
    /// The service is draining ([`OffloadService::begin_shutdown`]).
    ShuttingDown,
}

impl ShedReason {
    /// All reasons, index-aligned with the service's shed counters.
    pub const ALL: [ShedReason; 3] =
        [ShedReason::QueueFull, ShedReason::RateLimited, ShedReason::ShuttingDown];

    /// Stable wire name (metric label value).
    pub fn as_str(self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue-full",
            ShedReason::RateLimited => "rate-limited",
            ShedReason::ShuttingDown => "shutting-down",
        }
    }

    fn rank(self) -> usize {
        match self {
            ShedReason::QueueFull => 0,
            ShedReason::RateLimited => 1,
            ShedReason::ShuttingDown => 2,
        }
    }
}

/// Structured shed response: the submit was rejected by admission
/// control, not failed by the pipeline. Callers distinguish sheds from
/// real failures with `err.downcast_ref::<JobRejected>()` and can back
/// off for `retry_after` before resubmitting.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRejected {
    /// Which limit rejected the job.
    pub reason: ShedReason,
    /// Decision jobs queued-or-running on the rejecting queue at shed
    /// time (service-wide depth for rate-limit and shutdown sheds).
    pub queue_depth: u64,
    /// Suggested back-off before resubmitting: token-accrual time for
    /// rate-limit sheds, estimated queue-drain time for queue-full sheds,
    /// zero when the service is shutting down (retrying cannot help).
    pub retry_after: Duration,
}

impl std::fmt::Display for JobRejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "job rejected ({}): queue depth {}, retry after {:.3}s",
            self.reason.as_str(),
            self.queue_depth,
            self.retry_after.as_secs_f64(),
        )
    }
}

impl std::error::Error for JobRejected {}

/// Service construction parameters.
#[derive(Clone)]
pub struct ServiceConfig {
    /// AOT artifact directory (each worker opens its own engine on it).
    pub artifacts: PathBuf,
    /// Decision cache directory. `None` defaults to `decision_cache/`
    /// next to the artifacts dir (when `persist` is on).
    pub cache_dir: Option<PathBuf>,
    /// Persist decisions to disk so they survive restarts.
    pub persist: bool,
    /// Worker-thread count (one coordinator + PJRT engine each).
    pub workers: usize,
    /// Pattern DB shared by all workers; digested (together with `policy`,
    /// `verify`, `similarity_threshold`, `backend_policy`, `device`, and
    /// the artifact contents) into the per-stage cache fingerprints
    /// (`StageFingerprints`).
    pub db: PatternDb,
    /// Interface-reconciliation policy (C-1/C-2 confirmations).
    pub policy: InterfacePolicy,
    /// Verification-measurement settings (Step 3).
    pub verify: VerifyConfig,
    /// Deckard-style similarity threshold for copied-code discovery.
    pub similarity_threshold: f64,
    /// Backend-arbitration policy (CLI `--target`): part of the decision
    /// fingerprint, so a `--target fpga` decision never replays for a
    /// `--target gpu` request.
    pub backend_policy: BackendPolicy,
    /// FPGA device model arbitration runs against: also fingerprinted, so
    /// retargeting the deployment (different card, different fmax)
    /// invalidates every previously verified decision.
    pub device: fpga::Device,
    /// How arbitration weighs power (CLI `--power-policy`). Part of the
    /// power-tier fingerprint: changing it re-scores and re-arbitrates
    /// from the cached `Verified` artifact without re-measuring. The
    /// default (`perf`) contributes nothing to the decision fingerprint,
    /// so pre-power v2 cache entries still replay byte-identically.
    pub power_policy: PowerPolicy,
    /// Per-device wattage models the power stage scores against;
    /// fingerprinted alongside the policy.
    pub power_model: PowerModel,
    /// Device profiles the analytic estimate stage scores against (CLI
    /// `--device-profile`). Part of the estimate-tier fingerprint: a
    /// profile change re-estimates and re-measures from the cached
    /// `Reconciled` artifact. The built-in registry under the default
    /// `--prune-policy off` contributes nothing to any downstream
    /// fingerprint, so pre-estimator cache entries still replay
    /// byte-identically.
    pub profiles: ProfileRegistry,
    /// How the Verify stage consumes the analytic estimate (CLI
    /// `--prune-policy`): `off` (the default) measures every candidate,
    /// `conservative:<margin>`/`aggressive` withhold analytically
    /// hopeless candidates from measurement. Fingerprinted alongside the
    /// profiles.
    pub prune_policy: PrunePolicy,
    /// Resident-set byte budget of the device data plane (CLI
    /// `--resident-bytes`). `0` (the default) keeps residency off. Part
    /// of the verify-tier fingerprint **only when nonzero**: a budget
    /// changes what Step 3 observes (the paid/elided traffic split) and
    /// upgrades the report to v5, so resident decisions never replay for
    /// non-resident requests — while the default `0` contributes nothing
    /// and pre-residency cache entries still replay byte-identically.
    pub resident_bytes: u64,
    /// Patterns measured concurrently inside one Step-3 search (CLI
    /// `--verify-parallel`). `1` (the default) measures serially; above 1,
    /// independent pattern measurements fan out across the pool's idle
    /// sibling workers. Deliberately **not** part of any cache
    /// fingerprint: the executor changes how fast a search runs, never
    /// its outcome, so serial and pooled decisions replay each other
    /// byte-identically.
    pub verify_parallel: usize,
    /// Fleet worker endpoints (CLI `--fleet`), each a `host:port` TCP
    /// address or a `stdio:<command>` child spec (see
    /// [`crate::fleet::FleetEndpoint`]). Empty (the default) keeps every
    /// measurement on the local pool. Deliberately **not** part of any
    /// cache fingerprint: the fleet changes *where* measurements run,
    /// never their outcome, so fleet-backed and local services replay
    /// each other's cached decisions byte-identically.
    pub fleet: Vec<String>,
    /// Trace/metrics settings (CLI `--trace-out`). Deliberately **not**
    /// part of any cache fingerprint: telemetry observes runs, it never
    /// decides them, so traced and untraced services replay each other's
    /// cached decisions byte-identically.
    pub telemetry: TelemetryConfig,
    /// Load-shedding limits (CLI `--queue-limit`, `--rate-limit`,
    /// `--burst`). Like telemetry, never fingerprinted.
    pub admission: AdmissionConfig,
    /// Standing cache size budget (CLI `--cache-max-bytes`,
    /// `--cache-max-entries`), enforced at startup over pre-existing
    /// entries and after every insert with tier-aware LRU eviction
    /// (see [`super::cache`]). Never fingerprinted: eviction changes
    /// what is *cached*, never what a decision *is*.
    pub cache_budget: CacheBudget,
}

impl ServiceConfig {
    /// Defaults over an artifact directory (2 workers, persistent cache).
    pub fn new(artifacts: impl Into<PathBuf>) -> Self {
        ServiceConfig {
            artifacts: artifacts.into(),
            cache_dir: None,
            persist: true,
            workers: 2,
            db: PatternDb::builtin(),
            policy: InterfacePolicy::AutoApprove,
            verify: VerifyConfig::default(),
            similarity_threshold: crate::similarity::DEFAULT_THRESHOLD,
            backend_policy: BackendPolicy::Auto,
            device: fpga::ARRIA10_GX,
            power_policy: PowerPolicy::default(),
            power_model: PowerModel::builtin(),
            profiles: ProfileRegistry::builtin(),
            prune_policy: PrunePolicy::default(),
            resident_bytes: 0,
            verify_parallel: 1,
            fleet: Vec::new(),
            telemetry: TelemetryConfig::default(),
            admission: AdmissionConfig::default(),
            cache_budget: CacheBudget::unlimited(),
        }
    }

    fn effective_cache_dir(&self) -> Option<PathBuf> {
        if !self.persist {
            return None;
        }
        Some(self.cache_dir.clone().unwrap_or_else(|| {
            self.artifacts.parent().unwrap_or_else(|| Path::new(".")).join("decision_cache")
        }))
    }
}

/// One finished offload job.
pub struct CompletedJob {
    /// Job id (unique within one service).
    pub id: u64,
    /// Content-addressed key the decision is cached under.
    pub key: CacheKey,
    /// Entry-point function of the job.
    pub entry: String,
    /// The decoded offload decision.
    pub report: OffloadReport,
    /// Canonical serialized report — byte-identical whether this job ran
    /// the pipeline or replayed a cached decision (shared with the cache,
    /// so replaying is an O(1) clone).
    pub report_json: Arc<str>,
    /// True when the decision came from the cache (no pattern search or
    /// measurement ran for this job).
    pub from_cache: bool,
    /// Deepest pipeline stage replayed from the per-stage cache:
    /// `Some(Stage::PowerScore)` means a cached `PowerScored` artifact was
    /// resumed (only arbitration re-ran), `Some(Stage::Verify)` means the
    /// measurements replayed while power scoring + arbitration re-ran,
    /// `Some(Stage::Estimate)` means discovery and the analytic estimate
    /// replayed while verification re-ran (non-default estimator
    /// configurations only), `Some(Stage::Reconcile)` means discovery
    /// replayed while verification re-ran. `None` when the pipeline ran
    /// from scratch — or never ran at all (`from_cache`).
    pub resumed_from: Option<Stage>,
    /// Submit-to-completion wall clock.
    pub wall: Duration,
}

enum HandleState {
    Ready(Result<CompletedJob>),
    Pending(mpsc::Receiver<Result<CompletedJob>>),
}

/// Await handle for a submitted job.
pub struct JobHandle {
    id: u64,
    state: HandleState,
}

impl JobHandle {
    /// Job id this handle awaits.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the job finishes.
    pub fn wait(self) -> Result<CompletedJob> {
        match self.state {
            HandleState::Ready(r) => r,
            HandleState::Pending(rx) => rx.recv().unwrap_or_else(|_| {
                Err(anyhow!("offload service worker terminated before replying"))
            }),
        }
    }

    /// Non-blocking poll: the finished result, or the handle back if the
    /// job is still running (lets callers stream results as they land).
    pub fn try_wait(self) -> std::result::Result<Result<CompletedJob>, JobHandle> {
        match self.state {
            HandleState::Ready(r) => Ok(r),
            HandleState::Pending(rx) => match rx.try_recv() {
                Ok(r) => Ok(r),
                Err(mpsc::TryRecvError::Empty) => {
                    Err(JobHandle { id: self.id, state: HandleState::Pending(rx) })
                }
                Err(mpsc::TryRecvError::Disconnected) => {
                    Ok(Err(anyhow!("offload service worker terminated before replying")))
                }
            },
        }
    }
}

pub(crate) struct Job {
    id: u64,
    src: String,
    entry: String,
    key: CacheKey,
    submitted_at: Instant,
    reply: mpsc::Sender<Result<CompletedJob>>,
}

/// What flows through a worker's queue: full decision jobs, pattern
/// measurement sub-jobs fanned out by a sibling's Verify stage, and the
/// explicit shutdown marker (required because workers hold clones of
/// each other's senders for fan-out, so channel disconnect alone can
/// never end the pool).
pub(crate) enum WorkerMsg {
    /// A submitted offload job (runs the pipeline / replays the cache).
    Decision(Job),
    /// One pattern measurement fanned out by a sibling worker's search.
    Measure(MeasureJob),
    /// Drain the queue, then exit.
    Shutdown,
}

/// A worker's receive side plus the decision jobs it had to set aside
/// while servicing measurement sub-jobs mid-verify. Shared (same-thread)
/// between the worker loop and its pooled executor.
pub(crate) struct WorkerQueue {
    rx: mpsc::Receiver<WorkerMsg>,
    deferred: VecDeque<Job>,
    shutting_down: bool,
}

impl WorkerQueue {
    fn new(rx: mpsc::Receiver<WorkerMsg>) -> WorkerQueue {
        WorkerQueue { rx, deferred: VecDeque::new(), shutting_down: false }
    }

    /// Next message for the worker loop: deferred decision jobs first (in
    /// arrival order), then the channel. `None` means shut down.
    fn next_blocking(&mut self) -> Option<WorkerMsg> {
        if let Some(job) = self.deferred.pop_front() {
            return Some(WorkerMsg::Decision(job));
        }
        if self.shutting_down {
            return None;
        }
        match self.rx.recv() {
            Ok(WorkerMsg::Shutdown) | Err(_) => None,
            Ok(msg) => Some(msg),
        }
    }

    /// Non-blocking: pop the next measurement sub-job, deferring any
    /// decision jobs encountered (their order is preserved). Called by
    /// the pooled executor while it waits on siblings — the progress
    /// guarantee that keeps mutual fan-out deadlock-free.
    pub(crate) fn try_measure(&mut self) -> Option<MeasureJob> {
        loop {
            match self.rx.try_recv() {
                Ok(WorkerMsg::Measure(job)) => return Some(job),
                Ok(WorkerMsg::Decision(job)) => self.deferred.push_back(job),
                Ok(WorkerMsg::Shutdown) => {
                    self.shutting_down = true;
                    return None;
                }
                Err(_) => return None,
            }
        }
    }
}

/// Help string for `fbo_cache_corrupt_total` — one constant because the
/// counter is registered from two sites (service counters and the
/// cache's [`CacheTelemetry`]) that must resolve to the same instrument.
const CORRUPT_HELP: &str = "Corrupt cache artifacts detected (each degrades to a miss).";

/// Registry-backed service counters. Each handle is an `Arc` into the
/// service's shared [`Registry`], so the same numbers feed `stats()`
/// snapshots and the Prometheus exposition without double bookkeeping.
/// Completion latency lives in a log-linear histogram — O(1) memory for a
/// long-running `serve` process, and the percentile estimates no longer
/// require cloning and sorting a sample window on every snapshot.
struct Counters {
    submitted: Arc<Counter>,
    completed: Arc<Counter>,
    failed: Arc<Counter>,
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
    reconciled_hits: Arc<Counter>,
    estimated_hits: Arc<Counter>,
    verified_hits: Arc<Counter>,
    power_hits: Arc<Counter>,
    dropped_results: Arc<Counter>,
    /// `fbo_jobs_shed_total{reason=...}`, index-aligned with
    /// [`ShedReason::ALL`].
    shed: [Arc<Counter>; 3],
    /// `fbo_cache_corrupt_total` — shared with the cache's attached
    /// [`CacheTelemetry`], so file-level rot (found at open/clear) and
    /// decode-level rot (found at replay) land on one series.
    cache_corrupt: Arc<Counter>,
    queue_depth: Arc<Gauge>,
    job_seconds: Arc<Histogram>,
    /// `fbo_estimator_error` — mean absolute percentage error of the
    /// analytic estimator over the most recent completed job that
    /// carried an estimate residue (non-default `--prune-policy` /
    /// `--device-profile` runs only).
    estimator_error: Arc<Gauge>,
    /// `fbo_residency_elided_bytes_total` — host<->device bytes the
    /// resident data plane elided, summed across completed jobs. Moves
    /// only under a nonzero `--resident-bytes` budget.
    residency_elided_bytes: Arc<Counter>,
    /// `fbo_residency_saved_seconds` — modeled PCIe seconds per run the
    /// last residency-shaped job saved (its v5 transfer credit).
    residency_saved_secs: Arc<Gauge>,
}

impl Counters {
    fn register(reg: &Registry) -> Counters {
        let lookups = |result: &str, tier: &str| {
            reg.counter(
                "fbo_cache_lookups_total",
                "Cache outcomes by tier: full-decision probes and per-stage resume hits.",
                &[("result", result), ("tier", tier)],
            )
        };
        Counters {
            submitted: reg.counter("fbo_jobs_submitted_total", "Offload jobs accepted.", &[]),
            completed: reg.counter(
                "fbo_jobs_completed_total",
                "Offload jobs completed successfully.",
                &[],
            ),
            failed: reg.counter("fbo_jobs_failed_total", "Offload jobs failed.", &[]),
            cache_hits: lookups("hit", "decision"),
            cache_misses: lookups("miss", "decision"),
            reconciled_hits: lookups("hit", "reconciled"),
            estimated_hits: lookups("hit", "estimated"),
            verified_hits: lookups("hit", "verified"),
            power_hits: lookups("hit", "power-scored"),
            dropped_results: reg.counter(
                "fbo_results_dropped_total",
                "Completed results whose submitter stopped waiting.",
                &[],
            ),
            shed: ShedReason::ALL.map(|r| {
                reg.counter(
                    "fbo_jobs_shed_total",
                    "Submits rejected by admission control, by reason.",
                    &[("reason", r.as_str())],
                )
            }),
            cache_corrupt: reg.counter("fbo_cache_corrupt_total", CORRUPT_HELP, &[]),
            queue_depth: reg.gauge(
                "fbo_queue_depth",
                "Decision jobs currently queued or running.",
                &[],
            ),
            job_seconds: reg.histogram(
                "fbo_job_seconds",
                "Submit-to-completion latency of successful jobs.",
                &[],
            ),
            estimator_error: reg.gauge(
                "fbo_estimator_error",
                "Analytic-estimator MAPE over the last completed job with an estimate residue.",
                &[],
            ),
            residency_elided_bytes: reg.counter(
                "fbo_residency_elided_bytes_total",
                "Host<->device bytes elided by the resident data plane.",
                &[],
            ),
            residency_saved_secs: reg.gauge(
                "fbo_residency_saved_seconds",
                "PCIe seconds per run saved by the last residency-shaped job.",
                &[],
            ),
        }
    }
}

/// Per-stage latency totals and histograms, fed by the pipeline's
/// [`StageObserver`] hook from every worker.
struct StageLatencies {
    total_ns: [AtomicU64; 8],
    count: [AtomicU64; 8],
    /// `fbo_stage_seconds{stage=...}` histograms, index-aligned with
    /// [`Stage::ALL`].
    hists: Vec<Arc<Histogram>>,
}

impl StageLatencies {
    fn register(reg: &Registry) -> StageLatencies {
        let hists = Stage::ALL
            .iter()
            .map(|s| {
                reg.histogram(
                    "fbo_stage_seconds",
                    "Wall-clock seconds spent in each pipeline stage.",
                    &[("stage", s.as_str())],
                )
            })
            .collect();
        StageLatencies { total_ns: Default::default(), count: Default::default(), hists }
    }
}

impl StageObserver for StageLatencies {
    fn stage_completed(&self, stage: Stage, wall: Duration) {
        let i = stage.index();
        self.total_ns[i].fetch_add(wall.as_nanos() as u64, Ordering::Relaxed);
        self.count[i].fetch_add(1, Ordering::Relaxed);
        self.hists[i].record(wall);
    }
}

/// Per-job observer installed for every pipeline run: forwards stage
/// completions to the service-wide latency counters and mirrors every
/// span and structured event onto the trace recorder under the job's
/// trace id (a job's id *is* its trace id).
struct JobObserver {
    trace: u64,
    recorder: Arc<TraceRecorder>,
    latencies: Arc<StageLatencies>,
}

impl StageObserver for JobObserver {
    fn stage_completed(&self, stage: Stage, wall: Duration) {
        self.latencies.stage_completed(stage, wall);
        self.recorder.record(
            self.trace,
            TraceEvent::StageCompleted { stage, wall_ns: wall.as_nanos() as u64 },
        );
    }

    fn stage_event(&self, event: &TraceEvent) {
        self.recorder.record(self.trace, event.clone());
    }
}

/// Per-worker utilization counters behind the
/// `fbo_worker_utilization_ratio{worker=...}` gauges.
struct WorkerTelemetry {
    jobs: AtomicU64,
    /// Measurement sub-jobs fanned to this worker by a sibling's pooled
    /// executor — counted separately so the decision-job `jobs` column
    /// stays comparable across pool sizes while the fan-out work this
    /// worker absorbed is still visible per worker.
    measure_jobs: AtomicU64,
    busy_ns: AtomicU64,
    util: Arc<Gauge>,
}

/// One client's token bucket (see [`AdmissionConfig::rate_per_client`]).
struct TokenBucket {
    tokens: f64,
    last: Instant,
}

struct Shared {
    cache: DecisionCache,
    /// Load-shedding limits, fixed at startup.
    admission: AdmissionConfig,
    /// Flipped by [`OffloadService::begin_shutdown`]: subsequent submits
    /// shed with [`ShedReason::ShuttingDown`] while queued jobs drain.
    draining: AtomicBool,
    /// Decision jobs queued-or-running per worker queue, index-aligned
    /// with the pool; the bound [`AdmissionConfig::queue_limit`] checks
    /// against. (The `fbo_queue_depth` gauge is the sum.)
    shard_depth: Vec<AtomicU64>,
    /// Per-client token buckets, lazily created on first submit.
    buckets: Mutex<HashMap<String, TokenBucket>>,
    /// Per-stage cache-key components — see [`decision_fingerprint`].
    fingerprints: StageFingerprints,
    /// Persist/resume the `PowerScored` tier. Off under the default
    /// power configuration: the inert `perf` scores recompute from a
    /// replayed `Verified` in microseconds, and the artifact embeds the
    /// full verified payload — caching it would double per-job cache
    /// storage to save nothing.
    persist_power_tier: bool,
    /// Persist/resume the `Estimated` tier. Off under the default
    /// estimator configuration: the inert default estimate recomputes
    /// from a replayed `Reconciled` in microseconds and decides nothing,
    /// so caching it would cost storage to save nothing.
    persist_estimate_tier: bool,
    counters: Counters,
    latencies: Arc<StageLatencies>,
    /// Parallel-vs-serial pattern-measurement counters, shared by every
    /// worker's pooled executor.
    measure_stats: Arc<ExecStats>,
    /// Trace recorder every job's spans and events land on (ring buffer,
    /// plus the JSONL sink when `--trace-out` is configured).
    recorder: Arc<TraceRecorder>,
    /// Metrics registry behind [`Counters`]/[`StageLatencies`]; rendered
    /// by [`MetricsHandle::render_prometheus`].
    registry: Arc<Registry>,
    /// Per-worker busy/job counters, index-aligned with the worker pool.
    workers_tm: Vec<WorkerTelemetry>,
    /// `fbo_cache_entries`, refreshed on every exposition/snapshot.
    cache_entries_gauge: Arc<Gauge>,
    /// `fbo_cache_bytes` — the cache updates it on every mutation via its
    /// attached [`CacheTelemetry`]; refreshed here too so an exposition
    /// after an external `fbo cache gc` reads current occupancy.
    cache_bytes_gauge: Arc<Gauge>,
    /// `fbo_uptime_seconds`, refreshed on every exposition/snapshot.
    uptime_gauge: Arc<Gauge>,
    started: Instant,
}

/// The five cache-key fingerprints, one per cached pipeline prefix. Each
/// digests exactly the inputs that can change that prefix's output, so a
/// config change invalidates the stages it affects and *only* those: a
/// `--reps` change re-verifies but replays discovery from the cache; a
/// `--power-policy` change re-scores from the cached `Verified` without
/// re-measuring; a `--target` or device change re-arbitrates but replays
/// the power scores.
struct StageFingerprints {
    /// Keys `Reconciled` artifacts: pattern DB + interface policy +
    /// similarity threshold (the Parse/Discover/Reconcile inputs).
    discovery: String,
    /// Keys `Estimated` artifacts: `discovery` plus the device profiles
    /// and the prune policy (the Estimate inputs).
    estimate: String,
    /// Keys `Verified` artifacts: the deepest upstream fingerprint plus
    /// the AOT artifact contents and the verification settings (the
    /// Verify inputs). Under the default estimator configuration
    /// (`--prune-policy off` over the built-in profiles) this chains
    /// directly off `discovery`, reproducing the pre-estimator
    /// fingerprint so existing cache entries keep replaying; any
    /// non-default estimate input chains `estimate` in — pruning changes
    /// which patterns get measured, so it must invalidate the measured
    /// evidence.
    verify: String,
    /// Keys `PowerScored` artifacts: `verify` plus the power policy and
    /// wattage models (the PowerScore inputs).
    power: String,
    /// Keys full decisions: the power tier plus the backend policy and
    /// FPGA device model (the Arbitrate inputs). Under the default power
    /// configuration this chains directly off `verify`, reproducing the
    /// pre-power fingerprint so existing v2 cache entries keep replaying
    /// byte-identically.
    decision: String,
}

fn fnv_hex(blob: &str) -> String {
    format!("{:016x}", fnv1a64(blob.as_bytes()))
}

/// Digest of the Parse/Discover/Reconcile environment: pattern-DB
/// content, the interface policy, and the similarity threshold.
fn discovery_fingerprint(cfg: &ServiceConfig) -> String {
    let policy = match &cfg.policy {
        InterfacePolicy::AutoApprove => "approve".to_string(),
        InterfacePolicy::AutoReject => "reject".to_string(),
        InterfacePolicy::Scripted(answers) => format!("scripted:{answers:?}"),
    };
    fnv_hex(&format!(
        "discover|{}|policy:{policy}|sim:{}",
        cfg.db.fingerprint(),
        cfg.similarity_threshold,
    ))
}

/// True when the estimator configuration is the inert default
/// (`--prune-policy off` over the built-in device profiles): the
/// analytic estimate then decides nothing — no candidate is pruned, no
/// cost hint reorders dispatch, no report byte changes — so it must
/// change no fingerprint either.
fn estimate_is_default(cfg: &ServiceConfig) -> bool {
    cfg.prune_policy.is_default() && cfg.profiles == ProfileRegistry::builtin()
}

/// Digest of the Estimate environment: the discovery fingerprint plus
/// the device-profile registry and the prune policy. Always distinct
/// from the discovery fingerprint (the `estimate|` prefix), so
/// `Estimated` entries never collide with `Reconciled` entries for the
/// same source.
fn estimate_fingerprint(cfg: &ServiceConfig) -> String {
    fnv_hex(&format!(
        "estimate|{}|profiles:{}|prune:{}",
        discovery_fingerprint(cfg),
        cfg.profiles.fingerprint_blob(),
        cfg.prune_policy.render(),
    ))
}

/// Digest of the Verify environment: the deepest upstream fingerprint
/// plus the AOT artifacts measurement runs against (`make artifacts`
/// after a kernel edit must re-verify, never replay measurements taken
/// against the old HLO) and the verification settings.
///
/// Under the **default** estimator configuration the chain deliberately
/// skips the estimate tier and hashes exactly the pre-estimator formula:
/// `--prune-policy off` measurements are byte-identical to measurements
/// taken before the estimate stage existed, so the cache entries they
/// wrote must keep replaying. Any non-default profile or prune policy
/// chains the estimate fingerprint in — pruning changes *which* patterns
/// get measured, so it invalidates the measured evidence.
///
/// A nonzero `--resident-bytes` budget appends a `|resident:<budget>`
/// segment: residency changes what Step 3 observes (the paid/elided
/// traffic split, and the v5 report residue downstream), so resident
/// measurements must never replay for non-resident requests or for a
/// different budget. The default `0` appends nothing — the pre-residency
/// formula, so existing cache entries keep replaying byte-identically.
fn verify_fingerprint(cfg: &ServiceConfig) -> String {
    let upstream = if estimate_is_default(cfg) {
        discovery_fingerprint(cfg)
    } else {
        estimate_fingerprint(cfg)
    };
    let mut blob = format!(
        "verify|{}|artifacts:{}|reps:{}|warmup:{}|fuel:{}|tol:{}",
        upstream,
        artifacts_fingerprint(&cfg.artifacts),
        cfg.verify.reps,
        cfg.verify.warmup,
        cfg.verify.fuel,
        cfg.verify.tolerance,
    );
    if cfg.resident_bytes > 0 {
        blob.push_str(&format!("|resident:{}", cfg.resident_bytes));
    }
    fnv_hex(&blob)
}

/// True when the power configuration is the inert default (`perf` policy
/// over the built-in wattage models): scoring then changes no decision
/// and no report byte, so it must change no fingerprint either.
fn power_is_default(cfg: &ServiceConfig) -> bool {
    cfg.power_policy.is_default() && cfg.power_model == PowerModel::builtin()
}

/// Digest of the PowerScore environment: the verify fingerprint plus the
/// power policy and the wattage models. Always distinct from the verify
/// fingerprint (the `power|` prefix), so `PowerScored` entries never
/// collide with `Verified` entries for the same source.
fn power_fingerprint(cfg: &ServiceConfig) -> String {
    fnv_hex(&format!(
        "power|{}|policy:{}|model:{}",
        verify_fingerprint(cfg),
        cfg.power_policy.render(),
        cfg.power_model.fingerprint_blob(),
    ))
}

/// Digest of the full decision *environment*: the deepest upstream
/// fingerprint plus the backend policy and FPGA device model the Step-3b
/// arbitration targets. Any input changing misses the full-decision cache
/// — a report verified under `--policy reject` must never be replayed for
/// a `--policy approve` request, and a decision arbitrated for one FPGA
/// card must re-arbitrate when the deployment retargets another — while
/// the per-stage entries keyed by the narrower fingerprints above still
/// replay whatever prefix remains valid.
///
/// Under the **default** power configuration the chain deliberately skips
/// the power tier and hashes exactly the pre-power formula: `perf`
/// decisions are byte-identical to decisions made before the power stage
/// existed, so the cache entries they wrote must keep replaying.
fn decision_fingerprint(cfg: &ServiceConfig) -> String {
    let upstream =
        if power_is_default(cfg) { verify_fingerprint(cfg) } else { power_fingerprint(cfg) };
    fnv_hex(&format!(
        "decide|{}|target:{}|device:{}/{}/{}/{}/{}",
        upstream,
        cfg.backend_policy.as_str(),
        cfg.device.name,
        cfg.device.alms,
        cfg.device.dsps,
        cfg.device.m20ks,
        cfg.device.fmax,
    ))
}

fn stage_fingerprints(cfg: &ServiceConfig) -> StageFingerprints {
    StageFingerprints {
        discovery: discovery_fingerprint(cfg),
        estimate: estimate_fingerprint(cfg),
        verify: verify_fingerprint(cfg),
        power: power_fingerprint(cfg),
        decision: decision_fingerprint(cfg),
    }
}

/// Content hash of an artifact directory: manifest bytes plus every
/// `*.hlo.txt`, by name order. Reading ~1 MB once per service start is
/// noise next to compiling the artifacts. A missing/unreadable dir hashes
/// to a distinct value and startup then fails in `Coordinator::open` with
/// the proper error.
fn artifacts_fingerprint(dir: &Path) -> String {
    let manifest = std::fs::read(dir.join("manifest.json")).unwrap_or_default();
    let mut blob = format!("manifest:{:016x}", fnv1a64(&manifest));
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .into_iter()
        .flatten()
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("txt"))
        .collect();
    files.sort();
    for path in files {
        let content = std::fs::read(&path).unwrap_or_default();
        blob.push_str(&format!(
            "|{}:{:016x}",
            path.file_name().and_then(|n| n.to_str()).unwrap_or(""),
            fnv1a64(&content)
        ));
    }
    format!("{:016x}", fnv1a64(blob.as_bytes()))
}

impl Shared {
    /// Count a finished job and close its trace with a
    /// `request-completed` event.
    fn record_completion(&self, id: u64, result: &Result<CompletedJob>) {
        match result {
            Ok(done) => {
                self.counters.completed.inc();
                self.counters.job_seconds.record(done.wall);
                self.recorder.record(
                    id,
                    TraceEvent::RequestCompleted { from_cache: done.from_cache, ok: true },
                );
            }
            Err(_) => {
                self.counters.failed.inc();
                self.recorder
                    .record(id, TraceEvent::RequestCompleted { from_cache: false, ok: false });
            }
        }
    }

    /// Charge `busy` wall-clock (and one decision job or one measurement
    /// sub-job) to a worker's utilization counters.
    fn note_worker_busy(&self, index: usize, busy: Duration, decision: bool) {
        if let Some(w) = self.workers_tm.get(index) {
            w.busy_ns.fetch_add(busy.as_nanos() as u64, Ordering::Relaxed);
            if decision {
                w.jobs.fetch_add(1, Ordering::Relaxed);
            } else {
                w.measure_jobs.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Admit or rate-limit one submit from `client`. `Err` carries the
    /// back-off until the bucket accrues the next token.
    fn admit_client(&self, client: &str) -> std::result::Result<(), Duration> {
        let Some(rate) = self.admission.rate_per_client else {
            return Ok(());
        };
        if rate <= 0.0 {
            // A zero rate admits nothing; the hint is arbitrary but finite.
            return Err(Duration::from_secs(1));
        }
        let burst = self.admission.burst.max(1.0);
        let now = Instant::now();
        let mut buckets = self.buckets.lock().expect("admission bucket lock");
        let b = buckets
            .entry(client.to_string())
            .or_insert(TokenBucket { tokens: burst, last: now });
        b.tokens = (b.tokens + now.duration_since(b.last).as_secs_f64() * rate).min(burst);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            Err(Duration::from_secs_f64((1.0 - b.tokens) / rate))
        }
    }

    /// Estimated drain time of a queue `depth` jobs deep: mean completed
    /// job latency (1s before any completion) times the depth, clamped to
    /// a sane retry window.
    fn retry_hint(&self, depth: u64) -> Duration {
        let h = &self.counters.job_seconds;
        let mean = if h.count() > 0 { h.sum().as_secs_f64() / h.count() as f64 } else { 1.0 };
        let hint = mean * depth.max(1) as f64;
        Duration::from_secs_f64(hint.clamp(0.1, 60.0))
    }

    /// Count one shed and close its trace. The job never entered a
    /// queue, so it is neither completed nor failed — shed is its own
    /// outcome (`submitted == completed + failed + shed + in-flight`).
    fn record_shed(&self, id: u64, rejected: &JobRejected) {
        self.counters.shed[rejected.reason.rank()].inc();
        self.recorder.record(id, TraceEvent::RequestCompleted { from_cache: false, ok: false });
    }

    /// Count a corrupt (undecodable) cache entry discovered at replay
    /// time: warn, bump `fbo_cache_corrupt_total`, and emit the
    /// warn-level `cache-corrupt` trace event under the job's trace.
    fn note_corrupt_entry(&self, trace: u64, key: &CacheKey, what: &str, err: &anyhow::Error) {
        eprintln!(
            "fbo service: ignoring undecodable {what} cache entry {} ({err:#}); recomputing",
            key.file_stem()
        );
        self.counters.cache_corrupt.inc();
        self.recorder.record(
            trace,
            TraceEvent::CacheCorrupt {
                path: format!("{}.json", key.file_stem()),
                detail: format!("undecodable {what} entry: {err:#}"),
            },
        );
    }

    /// Recompute the sampled gauges (cache size, uptime, worker
    /// utilization) so an exposition or snapshot reads current values.
    fn refresh_gauges(&self) {
        self.cache_entries_gauge.set(self.cache.len() as f64);
        self.cache_bytes_gauge.set(self.cache.usage().bytes as f64);
        let uptime = self.started.elapsed().as_secs_f64();
        self.uptime_gauge.set(uptime);
        for w in &self.workers_tm {
            let busy = Duration::from_nanos(w.busy_ns.load(Ordering::Relaxed)).as_secs_f64();
            w.util.set(busy / uptime.max(1e-9));
        }
    }

    /// Point-in-time counters; backs both [`OffloadService::stats`] and
    /// [`MetricsHandle::snapshot`].
    fn snapshot(&self) -> StatsSnapshot {
        let c = &self.counters;
        let lat = &self.latencies;
        let stages = Stage::ALL
            .iter()
            .map(|s| {
                let i = s.index();
                StageStat {
                    stage: s.as_str(),
                    count: lat.count[i].load(Ordering::Relaxed),
                    total: Duration::from_nanos(lat.total_ns[i].load(Ordering::Relaxed)),
                    p50: lat.hists[i].quantile(0.5),
                    p95: lat.hists[i].quantile(0.95),
                }
            })
            .collect();
        let uptime = self.started.elapsed();
        let workers = self
            .workers_tm
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let busy = Duration::from_nanos(w.busy_ns.load(Ordering::Relaxed));
                WorkerStat {
                    worker: i,
                    jobs: w.jobs.load(Ordering::Relaxed),
                    measure_jobs: w.measure_jobs.load(Ordering::Relaxed),
                    busy,
                    utilization: busy.as_secs_f64() / uptime.as_secs_f64().max(1e-9),
                }
            })
            .collect();
        let cache_usage = self.cache.usage();
        StatsSnapshot {
            submitted: c.submitted.get(),
            completed: c.completed.get(),
            failed: c.failed.get(),
            jobs_shed: c.shed.iter().map(|s| s.get()).sum(),
            cache_hits: c.cache_hits.get(),
            cache_misses: c.cache_misses.get(),
            reconciled_replays: c.reconciled_hits.get(),
            estimated_replays: c.estimated_hits.get(),
            verified_replays: c.verified_hits.get(),
            power_replays: c.power_hits.get(),
            cache_entries: cache_usage.entries as u64,
            cache_bytes: cache_usage.bytes,
            cache_evictions: self.cache.stats().evictions_total(),
            cache_corrupt: c.cache_corrupt.get(),
            patterns_parallel: self.measure_stats.fanned_out.load(Ordering::Relaxed),
            patterns_serial: self.measure_stats.local.load(Ordering::Relaxed),
            dropped_results: c.dropped_results.get(),
            queue_depth: c.queue_depth.get().max(0.0) as u64,
            latency_p50: c.job_seconds.quantile(0.5),
            latency_p95: c.job_seconds.quantile(0.95),
            stages,
            workers,
        }
    }

    /// Cache probe. `None` means "run the pipeline": either a genuine miss
    /// or an undecodable entry — a damaged decision file must cost one
    /// re-verification (which overwrites it), never fail the key forever.
    /// Only a successfully decoded replay counts as a hit.
    fn try_cached(
        &self,
        id: u64,
        key: &CacheKey,
        entry: &str,
        started: Instant,
    ) -> Option<CompletedJob> {
        let bytes = self.cache.lookup(key);
        self.recorder.record(
            id,
            TraceEvent::CacheProbe { tier: "decision".to_string(), hit: bytes.is_some() },
        );
        let bytes: Arc<str> = bytes?;
        match report_json::report_from_str(&bytes) {
            Ok(report) => {
                self.counters.cache_hits.inc();
                Some(CompletedJob {
                    id,
                    key: key.clone(),
                    entry: entry.to_string(),
                    report,
                    report_json: bytes,
                    from_cache: true,
                    resumed_from: None,
                    wall: started.elapsed(),
                })
            }
            Err(e) => {
                self.note_corrupt_entry(id, key, "decision", &e);
                None
            }
        }
    }

    /// Per-stage cache probe: `None` means "recompute the stage" — either
    /// a genuine miss or an undecodable entry (a damaged stage file costs
    /// one recomputation, which overwrites it, never fails the key).
    fn try_stage<T>(
        &self,
        trace: u64,
        key: &CacheKey,
        decode: fn(&str) -> Result<T>,
        what: &str,
    ) -> Option<T> {
        let bytes = self.cache.lookup(key);
        self.recorder
            .record(trace, TraceEvent::CacheProbe { tier: what.to_string(), hit: bytes.is_some() });
        let bytes = bytes?;
        match decode(&bytes) {
            Ok(artifact) => Some(artifact),
            Err(e) => {
                self.note_corrupt_entry(trace, key, what, &e);
                None
            }
        }
    }

    /// Persist a stage artifact under its cache tier. Stage entries are a
    /// cache warm-up, not the product: failing to write one degrades
    /// resume, never the job.
    fn persist_stage(&self, key: &CacheKey, tier: CacheTier, payload: &str) {
        if let Err(e) = self.cache.insert_tier(key, tier, payload) {
            eprintln!("fbo service: failed to persist stage entry {}: {e:#}", key.file_stem());
        }
    }
}

/// Point-in-time service counters. Latency percentiles are estimated
/// from the service's log-linear histograms (nearest-rank on bucket
/// upper bounds: at most one sub-bucket of error, ≤ 25% relative),
/// which keeps a long-running `serve` process O(1) in memory.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    /// Jobs accepted.
    pub submitted: u64,
    /// Jobs completed successfully.
    pub completed: u64,
    /// Jobs failed (bad source, missing entry, pipeline error).
    pub failed: u64,
    /// Submits rejected by admission control ([`JobRejected`]): neither
    /// completed nor failed — shed before any work ran.
    pub jobs_shed: u64,
    /// Jobs answered from the decision cache.
    pub cache_hits: u64,
    /// Jobs that ran (at least part of) the pipeline.
    pub cache_misses: u64,
    /// Full-decision misses that resumed from a cached `Reconciled`
    /// artifact: discovery replayed, verification re-ran (e.g. after a
    /// `--reps` change or regenerated artifacts).
    pub reconciled_replays: u64,
    /// Full-decision misses that resumed from a cached `Estimated`
    /// artifact: discovery and the analytic estimate replayed,
    /// verification re-ran (non-default estimator configurations only —
    /// e.g. after a `--reps` change under an active `--prune-policy`).
    pub estimated_replays: u64,
    /// Full-decision misses that resumed from a cached `Verified`
    /// artifact: power scoring and arbitration re-ran, no re-measurement
    /// (e.g. after a `--power-policy` change).
    pub verified_replays: u64,
    /// Full-decision misses that resumed from a cached `PowerScored`
    /// artifact: only arbitration re-ran (e.g. after a `--target` or
    /// device-model change under a non-default power policy).
    pub power_replays: u64,
    /// Cache entries currently held — full decisions *and* per-stage
    /// artifacts (a scratch pipeline run writes one of each tier).
    pub cache_entries: u64,
    /// Total cache payload bytes currently held (`fbo_cache_bytes`).
    pub cache_bytes: u64,
    /// Entries evicted by tier-aware LRU budget enforcement, all tiers.
    pub cache_evictions: u64,
    /// Corrupt cache artifacts detected (`fbo_cache_corrupt_total`).
    pub cache_corrupt: u64,
    /// Pattern measurements fanned out to an idle sibling worker's engine
    /// (only nonzero with `verify_parallel > 1`).
    pub patterns_parallel: u64,
    /// Pattern measurements run inline on the verifying worker's own
    /// engine (every measurement, when `verify_parallel` is 1).
    pub patterns_serial: u64,
    /// Completed results whose submitter dropped the [`JobHandle`]
    /// before the worker replied.
    pub dropped_results: u64,
    /// Decision jobs currently queued or running.
    pub queue_depth: u64,
    /// Median completion latency (histogram estimate).
    pub latency_p50: Option<Duration>,
    /// 95th-percentile completion latency (histogram estimate).
    pub latency_p95: Option<Duration>,
    /// Per-stage latency totals across every pipeline stage the service
    /// ran (replayed stages don't re-run, so they don't count here).
    pub stages: Vec<StageStat>,
    /// Per-worker job counts and utilization, index-aligned with the
    /// worker pool.
    pub workers: Vec<WorkerStat>,
}

/// Aggregate latency of one pipeline stage across a service's lifetime.
#[derive(Debug, Clone)]
pub struct StageStat {
    /// Stage name (see [`Stage::as_str`]).
    pub stage: &'static str,
    /// How many times the stage ran.
    pub count: u64,
    /// Total wall-clock spent in the stage.
    pub total: Duration,
    /// Median stage latency (histogram estimate).
    pub p50: Option<Duration>,
    /// 95th-percentile stage latency (histogram estimate).
    pub p95: Option<Duration>,
}

/// One worker's share of the service's load.
#[derive(Debug, Clone)]
pub struct WorkerStat {
    /// Worker index (thread `fbo-worker-{worker}`).
    pub worker: usize,
    /// Decision jobs this worker ran.
    pub jobs: u64,
    /// Measurement sub-jobs fanned to this worker by a sibling's
    /// `verify_parallel` search (zero when `verify_parallel` is 1).
    pub measure_jobs: u64,
    /// Wall-clock spent on jobs (decision + measurement sub-jobs).
    pub busy: Duration,
    /// `busy` over service uptime.
    pub utilization: f64,
}

impl StatsSnapshot {
    /// One-line human rendering (CLI `batch`/`serve` output).
    pub fn render(&self) -> String {
        let fmt = |d: Option<Duration>| {
            d.map(metrics::fmt_duration).unwrap_or_else(|| "-".to_string())
        };
        let mut line = format!(
            "jobs: {} submitted, {} completed, {} failed | cache: {} hits / {} misses ({} entries) | latency p50 {} p95 {}",
            self.submitted,
            self.completed,
            self.failed,
            self.cache_hits,
            self.cache_misses,
            self.cache_entries,
            fmt(self.latency_p50),
            fmt(self.latency_p95),
        );
        let replays = self.reconciled_replays
            + self.estimated_replays
            + self.verified_replays
            + self.power_replays;
        if replays > 0 {
            line.push_str(&format!(
                " | stage replays: {} reconciled, {} estimated, {} verified, {} power-scored",
                self.reconciled_replays,
                self.estimated_replays,
                self.verified_replays,
                self.power_replays
            ));
        }
        if self.patterns_parallel + self.patterns_serial > 0 {
            line.push_str(&format!(
                " | verify patterns: {} parallel, {} serial",
                self.patterns_parallel, self.patterns_serial
            ));
        }
        let ran: Vec<String> = self
            .stages
            .iter()
            .filter(|s| s.count > 0)
            .map(|s| {
                format!(
                    "{} {}x{}",
                    s.stage,
                    s.count,
                    metrics::fmt_duration(s.total / s.count.max(1) as u32)
                )
            })
            .collect();
        if !ran.is_empty() {
            line.push_str(&format!(" | stage mean: {}", ran.join(", ")));
        }
        if self.jobs_shed > 0 {
            line.push_str(&format!(" | {} shed", self.jobs_shed));
        }
        if self.cache_evictions > 0 || self.cache_corrupt > 0 {
            line.push_str(&format!(
                " | cache: {} evicted, {} corrupt",
                self.cache_evictions, self.cache_corrupt
            ));
        }
        if self.queue_depth > 0 || self.dropped_results > 0 {
            line.push_str(&format!(
                " | queue depth {}, {} dropped results",
                self.queue_depth, self.dropped_results
            ));
        }
        line
    }

    /// Multi-line human rendering (CLI `stats --format text`): the
    /// one-line summary plus per-stage percentiles and per-worker
    /// utilization.
    pub fn render_full(&self) -> String {
        let fmt =
            |d: Option<Duration>| d.map(metrics::fmt_duration).unwrap_or_else(|| "-".to_string());
        let mut out = self.render();
        for s in self.stages.iter().filter(|s| s.count > 0) {
            out.push_str(&format!(
                "\n  stage {:<11} {:>4} runs, total {}, p50 {}, p95 {}",
                s.stage,
                s.count,
                metrics::fmt_duration(s.total),
                fmt(s.p50),
                fmt(s.p95),
            ));
        }
        for w in &self.workers {
            out.push_str(&format!(
                "\n  worker {} {} jobs + {} measure sub-jobs, busy {}, utilization {:.1}%",
                w.worker,
                w.jobs,
                w.measure_jobs,
                metrics::fmt_duration(w.busy),
                w.utilization * 100.0,
            ));
        }
        out
    }

    /// Canonical JSON rendering (CLI `stats --format json`), format tag
    /// `fbo-stats-v1`.
    pub fn to_json(&self) -> Json {
        let count = |n: u64| Json::num(n as f64);
        let dur = |d: Duration| Json::num(d.as_secs_f64());
        let opt_dur = |d: Option<Duration>| d.map(dur).unwrap_or(Json::Null);
        Json::obj(vec![
            ("format", Json::str("fbo-stats-v1")),
            ("submitted", count(self.submitted)),
            ("completed", count(self.completed)),
            ("failed", count(self.failed)),
            ("jobs_shed", count(self.jobs_shed)),
            ("cache_hits", count(self.cache_hits)),
            ("cache_misses", count(self.cache_misses)),
            ("reconciled_replays", count(self.reconciled_replays)),
            ("estimated_replays", count(self.estimated_replays)),
            ("verified_replays", count(self.verified_replays)),
            ("power_replays", count(self.power_replays)),
            ("cache_entries", count(self.cache_entries)),
            ("cache_bytes", count(self.cache_bytes)),
            ("cache_evictions", count(self.cache_evictions)),
            ("cache_corrupt", count(self.cache_corrupt)),
            ("patterns_parallel", count(self.patterns_parallel)),
            ("patterns_serial", count(self.patterns_serial)),
            ("dropped_results", count(self.dropped_results)),
            ("queue_depth", count(self.queue_depth)),
            ("latency_p50_secs", opt_dur(self.latency_p50)),
            ("latency_p95_secs", opt_dur(self.latency_p95)),
            (
                "stages",
                Json::Arr(
                    self.stages
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("stage", Json::str(s.stage)),
                                ("count", count(s.count)),
                                ("total_secs", dur(s.total)),
                                ("p50_secs", opt_dur(s.p50)),
                                ("p95_secs", opt_dur(s.p95)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "workers",
                Json::Arr(
                    self.workers
                        .iter()
                        .map(|w| {
                            Json::obj(vec![
                                ("worker", count(w.worker as u64)),
                                ("jobs", count(w.jobs)),
                                ("measure_jobs", count(w.measure_jobs)),
                                ("busy_secs", dur(w.busy)),
                                ("utilization", Json::num(w.utilization)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The offload service: decision cache + worker pool over the paper's
/// pipeline. See the [module docs](self) and [`crate::service`].
pub struct OffloadService {
    shared: Arc<Shared>,
    /// One queue per worker; jobs are sharded onto them by cache key.
    txs: Option<Vec<mpsc::Sender<WorkerMsg>>>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl OffloadService {
    /// Start the worker pool. Blocks until every worker has opened its
    /// engine (so artifact problems surface here, not on first submit).
    pub fn start(cfg: ServiceConfig) -> Result<OffloadService> {
        if cfg.workers == 0 {
            bail!("service needs at least one worker");
        }
        let cache = match cfg.effective_cache_dir() {
            Some(dir) => DecisionCache::open(&dir)?,
            None => DecisionCache::in_memory(),
        };
        let registry = Arc::new(Registry::new());
        let recorder = Arc::new(match &cfg.telemetry.trace_out {
            Some(path) => TraceRecorder::with_sink(cfg.telemetry.ring_capacity, path)
                .context("opening trace sink")?,
            None => TraceRecorder::new(cfg.telemetry.ring_capacity),
        });
        let cache_bytes_gauge = registry.gauge(
            "fbo_cache_bytes",
            "Total payload bytes held by the decision cache.",
            &[],
        );
        cache.attach_telemetry(CacheTelemetry {
            evictions: CacheTier::ALL.map(|t| {
                registry.counter(
                    "fbo_cache_evictions_total",
                    "Entries evicted by tier-aware LRU budget enforcement, by tier.",
                    &[("tier", t.as_str())],
                )
            }),
            corrupt: registry.counter("fbo_cache_corrupt_total", CORRUPT_HELP, &[]),
            bytes: cache_bytes_gauge.clone(),
            recorder: recorder.clone(),
        });
        // The standing budget applies to pre-existing entries too: a
        // restart under a tighter budget trims the inherited cache before
        // serving (and every insert re-enforces it afterward).
        cache.set_budget(cfg.cache_budget);
        if !cfg.cache_budget.is_unlimited() {
            cache.gc(cfg.cache_budget, false).context("startup cache gc")?;
        }
        let workers_tm = (0..cfg.workers)
            .map(|i| WorkerTelemetry {
                jobs: AtomicU64::new(0),
                measure_jobs: AtomicU64::new(0),
                busy_ns: AtomicU64::new(0),
                util: registry.gauge(
                    "fbo_worker_utilization_ratio",
                    "Fraction of service uptime each worker spent on jobs.",
                    &[("worker", &i.to_string())],
                ),
            })
            .collect();
        let shared = Arc::new(Shared {
            cache,
            admission: cfg.admission,
            draining: AtomicBool::new(false),
            shard_depth: (0..cfg.workers).map(|_| AtomicU64::new(0)).collect(),
            buckets: Mutex::new(HashMap::new()),
            fingerprints: stage_fingerprints(&cfg),
            persist_power_tier: !power_is_default(&cfg),
            persist_estimate_tier: !estimate_is_default(&cfg),
            counters: Counters::register(&registry),
            latencies: Arc::new(StageLatencies::register(&registry)),
            measure_stats: Arc::new(ExecStats::default()),
            recorder,
            workers_tm,
            cache_entries_gauge: registry.gauge(
                "fbo_cache_entries",
                "Cache entries held (full decisions plus stage artifacts).",
                &[],
            ),
            cache_bytes_gauge,
            uptime_gauge: registry.gauge(
                "fbo_uptime_seconds",
                "Seconds since the service started.",
                &[],
            ),
            registry,
            started: Instant::now(),
        });
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let nworkers = cfg.workers;
        let mut txs = Vec::with_capacity(nworkers);
        let mut rxs = Vec::with_capacity(nworkers);
        for _ in 0..nworkers {
            let (tx, rx) = mpsc::channel::<WorkerMsg>();
            txs.push(tx);
            rxs.push(rx);
        }
        let mut workers = Vec::with_capacity(nworkers);
        for (i, rx) in rxs.into_iter().enumerate() {
            let shared = shared.clone();
            let cfg = cfg.clone();
            let ready = ready_tx.clone();
            // Every worker holds the full sender list so its pooled
            // executor can fan measurement sub-jobs to idle siblings.
            let all_txs = txs.clone();
            let handle = std::thread::Builder::new()
                .name(format!("fbo-worker-{i}"))
                .spawn(move || worker_main(cfg, shared, rx, all_txs, i, ready))
                .context("spawning service worker")?;
            workers.push(handle);
        }
        drop(ready_tx);
        for _ in 0..nworkers {
            let started = ready_rx
                .recv()
                .map_err(|_| anyhow!("service worker died during startup"))
                .and_then(|r| r.context("service worker startup"));
            if let Err(e) = started {
                // Workers hold each other's senders, so dropping `txs`
                // alone would leave the healthy ones blocked forever:
                // shut them down explicitly before bailing.
                for tx in &txs {
                    let _ = tx.send(WorkerMsg::Shutdown);
                }
                for w in workers {
                    let _ = w.join();
                }
                return Err(e);
            }
        }
        Ok(OffloadService { shared, txs: Some(txs), workers, next_id: AtomicU64::new(1) })
    }

    /// Convenience: start with defaults over an artifact dir.
    pub fn open(artifacts: impl Into<PathBuf>) -> Result<OffloadService> {
        Self::start(ServiceConfig::new(artifacts))
    }

    /// Submit one job as the anonymous `"default"` client. Returns
    /// immediately; a cache hit (or an unparseable source) resolves the
    /// handle without touching the queue.
    pub fn submit(&self, src: &str, entry: &str) -> JobHandle {
        self.submit_as(src, entry, "default")
    }

    /// Submit one job attributed to `client` for per-client rate
    /// limiting. Admission runs before any pipeline work: a draining
    /// service, an empty token bucket, or a full target queue resolves
    /// the handle immediately with a [`JobRejected`] (recoverable via
    /// `err.downcast_ref::<JobRejected>()`). Cache hits bypass the queue
    /// bound — replaying a decision costs no worker time — but not the
    /// rate limit or the drain check.
    pub fn submit_as(&self, src: &str, entry: &str, client: &str) -> JobHandle {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.shared.counters.submitted.inc();
        // The request-started event fires before key computation so even
        // unparseable and shed submissions leave a complete trace.
        self.shared.recorder.record(id, TraceEvent::RequestStarted { entry: entry.to_string() });
        let started = Instant::now();

        let service_depth = self.shared.counters.queue_depth.get().max(0.0) as u64;
        if self.shared.draining.load(Ordering::SeqCst) || self.txs.is_none() {
            return self.shed_handle(id, ShedReason::ShuttingDown, service_depth, Duration::ZERO);
        }
        if let Err(retry_after) = self.shared.admit_client(client) {
            return self.shed_handle(id, ShedReason::RateLimited, service_depth, retry_after);
        }

        let key = match CacheKey::compute(src, entry, &self.shared.fingerprints.decision) {
            Ok(k) => k,
            // Key computation fails only when the source does not parse.
            // Surface that as the same structured Parse-stage error the
            // pipeline itself would produce, so callers can
            // `downcast_ref::<OffloadError>()` uniformly (the module doc
            // example relies on this).
            Err(e) => {
                let err = OffloadError::Parse {
                    entry: entry.to_string(),
                    message: format!("{e:#}"),
                };
                return self.ready_handle(id, Err(err.into()));
            }
        };
        if let Some(done) = self.shared.try_cached(id, &key, entry, started) {
            return self.ready_handle(id, Ok(done));
        }

        let (reply_tx, reply_rx) = mpsc::channel();
        // Shard by key: identical jobs serialize through one worker, so a
        // queued duplicate replays the first one's decision instead of
        // re-running the pipeline.
        let Some(txs) = &self.txs else {
            return self.shed_handle(id, ShedReason::ShuttingDown, service_depth, Duration::ZERO);
        };
        let shard = (fnv1a64(key.file_stem().as_bytes()) % txs.len() as u64) as usize;
        // Bound the target queue. `fetch_update` makes the
        // check-and-increment atomic against concurrent submitters (the
        // worker's decrement can only free room, never oversubscribe).
        let limit = self.shared.admission.queue_limit;
        let admitted =
            self.shared.shard_depth[shard].fetch_update(Ordering::SeqCst, Ordering::SeqCst, |d| {
                if limit > 0 && d >= limit as u64 {
                    None
                } else {
                    Some(d + 1)
                }
            });
        if let Err(d) = admitted {
            return self.shed_handle(id, ShedReason::QueueFull, d, self.shared.retry_hint(d));
        }
        let job = Job {
            id,
            src: src.to_string(),
            entry: entry.to_string(),
            key,
            submitted_at: started,
            reply: reply_tx,
        };
        match txs[shard].send(WorkerMsg::Decision(job)) {
            Ok(()) => {
                self.shared.counters.queue_depth.add(1.0);
                JobHandle { id, state: HandleState::Pending(reply_rx) }
            }
            Err(_) => {
                self.shared.shard_depth[shard].fetch_sub(1, Ordering::SeqCst);
                self.shed_handle(id, ShedReason::ShuttingDown, service_depth, Duration::ZERO)
            }
        }
    }

    /// Submit a batch of `(source, entry)` jobs; handles resolve
    /// independently as workers finish.
    pub fn submit_batch(&self, jobs: &[(String, String)]) -> Vec<JobHandle> {
        jobs.iter().map(|(src, entry)| self.submit(src, entry)).collect()
    }

    /// Submit a batch and block for every result, in submission order.
    pub fn run_batch(&self, jobs: &[(String, String)]) -> Vec<Result<CompletedJob>> {
        self.submit_batch(jobs).into_iter().map(JobHandle::wait).collect()
    }

    /// Current counters (jobs, cache traffic, latency percentiles).
    pub fn stats(&self) -> StatsSnapshot {
        self.shared.snapshot()
    }

    /// A `Send + Sync` view of this service's telemetry: Prometheus
    /// rendering for a scrape endpoint and stats snapshots for periodic
    /// printers. The handle keeps the shared state alive, so it stays
    /// valid across (and after) the service's own shutdown.
    pub fn metrics(&self) -> MetricsHandle {
        MetricsHandle { shared: self.shared.clone() }
    }

    /// The trace recorder every job's spans and events land on.
    pub fn recorder(&self) -> &Arc<TraceRecorder> {
        &self.shared.recorder
    }

    /// The decision cache (benches clear it to measure cold starts).
    pub fn cache(&self) -> &DecisionCache {
        &self.shared.cache
    }

    /// Fingerprint keying this service's full decisions (pattern DB +
    /// policies + verification settings + arbitration target).
    pub fn decision_fingerprint(&self) -> &str {
        &self.shared.fingerprints.decision
    }

    /// Begin drain-then-stop shutdown without blocking: admission closes
    /// immediately (subsequent submits shed with
    /// [`ShedReason::ShuttingDown`]) and a shutdown marker is queued
    /// behind every already-admitted job, which completes normally.
    /// Idempotent; [`OffloadService::shutdown`] (or drop) still joins the
    /// workers.
    pub fn begin_shutdown(&self) {
        // `swap` makes concurrent callers race safely: exactly one sends
        // the markers.
        if !self.shared.draining.swap(true, Ordering::SeqCst) {
            if let Some(txs) = &self.txs {
                for tx in txs {
                    let _ = tx.send(WorkerMsg::Shutdown);
                }
            }
        }
    }

    /// Drain the queue and join every worker.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn ready_handle(&self, id: u64, result: Result<CompletedJob>) -> JobHandle {
        self.shared.record_completion(id, &result);
        JobHandle { id, state: HandleState::Ready(result) }
    }

    /// Resolve a submit that admission rejected: count the shed, close
    /// the trace, and hand back a ready handle carrying the structured
    /// [`JobRejected`].
    fn shed_handle(
        &self,
        id: u64,
        reason: ShedReason,
        queue_depth: u64,
        retry_after: Duration,
    ) -> JobHandle {
        let rejected = JobRejected { reason, queue_depth, retry_after };
        self.shared.record_shed(id, &rejected);
        JobHandle { id, state: HandleState::Ready(Err(anyhow::Error::new(rejected))) }
    }

    fn shutdown_inner(&mut self) {
        // Workers hold clones of each other's senders (measurement
        // fan-out), so closing the service's own senders is not enough to
        // disconnect the queues: tell each worker explicitly. Queued jobs
        // drain first — the marker sits behind them in FIFO order.
        self.begin_shutdown();
        self.txs.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Workers are gone; flush whatever the trace sink still buffers.
        if let Err(e) = self.shared.recorder.flush() {
            eprintln!("fbo service: failed to flush trace sink: {e:#}");
        }
    }
}

/// Cloneable, thread-safe view of a running service's telemetry.
///
/// [`OffloadService`] itself is deliberately not `Sync` (each worker owns
/// a thread-bound engine); this handle carries only the `Send + Sync`
/// shared state, so the metrics HTTP endpoint and the periodic stats
/// printer can read from other threads while the service runs.
#[derive(Clone)]
pub struct MetricsHandle {
    shared: Arc<Shared>,
}

impl MetricsHandle {
    /// Render the Prometheus text exposition (version 0.0.4), refreshing
    /// the sampled gauges first.
    pub fn render_prometheus(&self) -> String {
        self.shared.refresh_gauges();
        self.shared.registry.render()
    }

    /// Point-in-time counters — same data as [`OffloadService::stats`].
    pub fn snapshot(&self) -> StatsSnapshot {
        self.shared.snapshot()
    }
}

impl Drop for OffloadService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn worker_main(
    cfg: ServiceConfig,
    shared: Arc<Shared>,
    rx: mpsc::Receiver<WorkerMsg>,
    all_txs: Vec<mpsc::Sender<WorkerMsg>>,
    index: usize,
    ready: mpsc::Sender<Result<()>>,
) {
    // The queue is shared (same thread) between this loop and the pooled
    // executor, which services measurement sub-jobs while it waits on
    // siblings mid-verify.
    let queue = Rc::new(RefCell::new(WorkerQueue::new(rx)));
    // Names the trace of the decision job this worker is currently
    // running (0 = idle), so the executor's fan-out events land on it.
    let current_trace = Rc::new(Cell::new(0u64));
    // Built on this thread, never crosses it (PJRT state is not Send).
    let coordinator = match Coordinator::open(&cfg.artifacts) {
        Ok(mut c) => {
            c.policy = cfg.policy;
            c.verify = cfg.verify;
            c.similarity_threshold = cfg.similarity_threshold;
            c.backend_policy = cfg.backend_policy;
            c.device = cfg.device;
            c.power_policy = cfg.power_policy;
            c.power_model = cfg.power_model.clone();
            c.profiles = cfg.profiles.clone();
            c.prune_policy = cfg.prune_policy;
            c.resident_bytes = cfg.resident_bytes;
            // Fan independent pattern measurements out to the sibling
            // workers when configured; with `verify_parallel == 1` the
            // executor measures everything locally (and still feeds the
            // parallel-vs-serial counters). The sibling list is rotated
            // to start after this worker, so concurrent searches with a
            // fan-out width below the pool size spread across different
            // siblings instead of all hammering worker 0.
            let siblings: Vec<MeasureTx> = if cfg.verify_parallel > 1 {
                (1..all_txs.len())
                    .map(|off| MeasureTx::Worker(all_txs[(index + off) % all_txs.len()].clone()))
                    .collect()
            } else {
                Vec::new()
            };
            c.executor = Some(Rc::new(PooledExecutor::new(
                c.engine.clone(),
                siblings,
                cfg.verify_parallel.max(1),
                Some(queue.clone()),
                shared.measure_stats.clone(),
                Some(DispatchSink {
                    recorder: shared.recorder.clone(),
                    trace: current_trace.clone(),
                }),
            )));
            // With `--fleet`, wrap the pooled executor in a fleet
            // scheduler: capable patterns ship to remote measurement
            // workers, everything else — and every fleet failure — falls
            // back to the executor above, so decisions stay
            // byte-identical with or without a fleet. Each service
            // worker holds its own connections (TCP sessions or spawned
            // children), mirroring the one-engine-per-thread model.
            if !cfg.fleet.is_empty() {
                let mut endpoints = Vec::new();
                for spec in &cfg.fleet {
                    match FleetEndpoint::parse(spec) {
                        Ok(e) => endpoints.push(e),
                        Err(e) => eprintln!("fleet: ignoring endpoint {spec:?}: {e:#}"),
                    }
                }
                let fleet = FleetRegistry::connect(&endpoints);
                for r in fleet.rejected() {
                    eprintln!("fleet: {r}");
                }
                let fallback: Rc<dyn PatternExecutor> =
                    c.executor.take().expect("pooled executor installed above");
                let telemetry = FleetTelemetry::new(
                    shared.registry.clone(),
                    shared.recorder.clone(),
                    current_trace.clone(),
                );
                c.executor =
                    Some(Rc::new(FleetExecutor::new(fleet, fallback).with_telemetry(telemetry)));
            }
            c.db = cfg.db;
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    loop {
        let msg = {
            let mut q = queue.borrow_mut();
            q.next_blocking()
        };
        match msg {
            // next_blocking maps Shutdown to None; the explicit variant
            // arm only keeps the match exhaustive.
            None | Some(WorkerMsg::Shutdown) => break,
            Some(WorkerMsg::Measure(job)) => {
                let t0 = Instant::now();
                verify_exec::run_measure_job(&coordinator.engine, job);
                shared.note_worker_busy(index, t0.elapsed(), false);
            }
            Some(WorkerMsg::Decision(job)) => {
                shared.counters.queue_depth.add(-1.0);
                shared.shard_depth[index].fetch_sub(1, Ordering::SeqCst);
                let t0 = Instant::now();
                current_trace.set(job.id);
                let result = run_job(&coordinator, &shared, &job);
                current_trace.set(0);
                shared.note_worker_busy(index, t0.elapsed(), true);
                shared.record_completion(job.id, &result);
                if job.reply.send(result).is_err() {
                    shared.counters.dropped_results.inc();
                }
            }
        }
    }
    // Drain-then-stop postlude: queued jobs completed above (the marker
    // sat behind them in FIFO order), but a submit can race the marker
    // onto the queue. Reject those explicitly — a structured
    // `JobRejected` beats a dropped reply channel — and drop any stray
    // measurement sub-jobs (their fan-out coordinator sees the
    // disconnect and falls back to measuring locally).
    loop {
        let msg = {
            let mut q = queue.borrow_mut();
            q.deferred.pop_front().map(WorkerMsg::Decision).or_else(|| q.rx.try_recv().ok())
        };
        match msg {
            None => break,
            Some(WorkerMsg::Decision(job)) => {
                shared.counters.queue_depth.add(-1.0);
                shared.shard_depth[index].fetch_sub(1, Ordering::SeqCst);
                let rejected = JobRejected {
                    reason: ShedReason::ShuttingDown,
                    queue_depth: 0,
                    retry_after: Duration::ZERO,
                };
                shared.record_shed(job.id, &rejected);
                if job.reply.send(Err(anyhow::Error::new(rejected))).is_err() {
                    shared.counters.dropped_results.inc();
                }
            }
            Some(WorkerMsg::Measure(_)) | Some(WorkerMsg::Shutdown) => {}
        }
    }
}

fn run_job(c: &Coordinator, shared: &Shared, job: &Job) -> Result<CompletedJob> {
    // Second cache check: an identical job may have been verified while
    // this one sat in the queue.
    if let Some(done) = shared.try_cached(job.id, &job.key, &job.entry, job.submitted_at) {
        return Ok(done);
    }
    shared.counters.cache_misses.inc();

    let observer: Arc<dyn StageObserver> = Arc::new(JobObserver {
        trace: job.id,
        recorder: shared.recorder.clone(),
        latencies: shared.latencies.clone(),
    });
    let req = c.request(&job.src, &job.entry).with_observer(observer);

    // Resume from the deepest valid per-stage entry. The stage keys share
    // the job's (source, entry) components but use the narrower
    // per-prefix fingerprints, so a config change invalidates exactly the
    // stages it affects: a full-decision miss can still replay discovery,
    // verification, or even the power scores from a previous run.
    let reconciled_key = job.key.with_fingerprint(&shared.fingerprints.discovery);
    let estimated_key = job.key.with_fingerprint(&shared.fingerprints.estimate);
    let verified_key = job.key.with_fingerprint(&shared.fingerprints.verify);
    let power_key = job.key.with_fingerprint(&shared.fingerprints.power);

    let mut resumed_from = None;
    // Obtain the Verified artifact: replay the deepest valid stage entry
    // or run the missing prefix (persisting what it produced).
    let resume_verified = |resumed_from: &mut Option<Stage>| -> Result<Verified> {
        match shared.try_stage(job.id, &verified_key, Verified::from_json_str, "verified") {
            Some(v) => {
                shared.counters.verified_hits.inc();
                *resumed_from = Some(Stage::Verify);
                Ok(v)
            }
            None => {
                // The Estimated tier sits between Reconciled and
                // Verified, but (like the power tier) only exists under a
                // non-default estimator configuration — the default
                // estimate decides nothing and is recomputed instead.
                let estimated = if shared.persist_estimate_tier {
                    shared.try_stage(job.id, &estimated_key, Estimated::from_json_str, "estimated")
                } else {
                    None
                };
                let estimated = match estimated {
                    Some(e) => {
                        shared.counters.estimated_hits.inc();
                        *resumed_from = Some(Stage::Estimate);
                        e
                    }
                    None => {
                        let reconciled = match shared.try_stage(
                            job.id,
                            &reconciled_key,
                            Reconciled::from_json_str,
                            "reconciled",
                        ) {
                            Some(r) => {
                                shared.counters.reconciled_hits.inc();
                                *resumed_from = Some(Stage::Reconcile);
                                r
                            }
                            None => {
                                let r = req.parse()?.discover(&req)?.reconcile(&req)?;
                                shared.persist_stage(
                                    &reconciled_key,
                                    CacheTier::Reconciled,
                                    &r.to_json_string(),
                                );
                                r
                            }
                        };
                        let e = reconciled.estimate(&req)?;
                        if shared.persist_estimate_tier {
                            shared.persist_stage(
                                &estimated_key,
                                CacheTier::Estimated,
                                &e.to_json_string(),
                            );
                        }
                        e
                    }
                };
                let v = estimated.verify(&req)?;
                shared.persist_stage(&verified_key, CacheTier::Verified, &v.to_json_string());
                Ok(v)
            }
        }
    };

    // The power tier is only consulted/persisted under a non-default
    // power configuration — the default `perf` scores are inert, so that
    // path arbitrates straight off the Verified artifact (one clone, the
    // pre-power cost) instead of materializing a throwaway PowerScored.
    let report = if shared.persist_power_tier {
        let scored =
            match shared.try_stage(job.id, &power_key, PowerScored::from_json_str, "power-scored") {
                Some(p) => {
                    shared.counters.power_hits.inc();
                    resumed_from = Some(Stage::PowerScore);
                    p
                }
                None => {
                    let p = resume_verified(&mut resumed_from)?.power_score(&req)?;
                    shared.persist_stage(&power_key, CacheTier::PowerScored, &p.to_json_string());
                    p
                }
            };
        scored.arbitrate(&req)?.report()
    } else {
        resume_verified(&mut resumed_from)?.arbitrate(&req)?.report()
    };

    // Surface the estimator's predicted-vs-measured error when the run
    // carried an estimate residue (non-default estimator configurations).
    if let Some(mape) = report.arbitration.estimate.as_ref().and_then(|e| e.mape) {
        shared.counters.estimator_error.set(mape);
    }
    // Likewise the residency credit: only residency-shaped jobs (nonzero
    // `--resident-bytes`) attach the residue, so the series stay flat —
    // and absent from any fingerprint — under the default config.
    if let Some(res) = &report.arbitration.residency {
        let elided: u64 = res.blocks.iter().map(|b| b.elided_in + b.elided_out).sum();
        shared.counters.residency_elided_bytes.add(elided);
        shared.counters.residency_saved_secs.set(res.total_saved_transfer_secs);
    }

    let report_json: Arc<str> = Arc::from(report_json::report_to_string(&report));
    // The verified decision is the product; failing to persist it degrades
    // the cache (and is reported), but must not fail the job.
    if let Err(e) = shared.cache.insert(&job.key, &report_json) {
        eprintln!("fbo service: failed to persist decision {}: {e:#}", job.key.file_stem());
    }
    if let Some(stage) = resumed_from {
        shared.recorder.record(job.id, TraceEvent::Resumed { from: stage });
    }
    Ok(CompletedJob {
        id: job.id,
        key: job.key.clone(),
        entry: job.entry.clone(),
        report,
        report_json,
        from_cache: false,
        resumed_from,
        wall: job.submitted_at.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults() {
        let cfg = ServiceConfig::new("some/artifacts");
        assert_eq!(cfg.workers, 2);
        assert!(cfg.persist);
        assert_eq!(
            cfg.effective_cache_dir().unwrap(),
            PathBuf::from("some/decision_cache"),
            "default cache dir sits next to the artifacts dir"
        );
        let mut ephemeral = cfg.clone();
        ephemeral.persist = false;
        assert!(ephemeral.effective_cache_dir().is_none());
        let mut explicit = cfg;
        explicit.cache_dir = Some(PathBuf::from("/tmp/x"));
        assert_eq!(explicit.effective_cache_dir().unwrap(), PathBuf::from("/tmp/x"));
    }

    #[test]
    fn fingerprint_tracks_backend_policy_and_device() {
        let cfg = ServiceConfig::new("some/artifacts");
        let base = decision_fingerprint(&cfg);

        let mut retargeted = cfg.clone();
        retargeted.backend_policy = BackendPolicy::Fpga;
        assert_ne!(decision_fingerprint(&retargeted), base, "--target must invalidate");

        let mut redeviced = cfg.clone();
        redeviced.device = fpga::Device { fmax: 300.0e6, ..fpga::ARRIA10_GX };
        assert_ne!(decision_fingerprint(&redeviced), base, "device model must invalidate");

        assert_eq!(decision_fingerprint(&cfg.clone()), base, "must be deterministic");
    }

    #[test]
    fn zero_workers_rejected() {
        let mut cfg = ServiceConfig::new("artifacts");
        cfg.workers = 0;
        assert!(OffloadService::start(cfg).is_err());
    }

    #[test]
    fn stats_render_handles_empty() {
        let s = StatsSnapshot {
            submitted: 0,
            completed: 0,
            failed: 0,
            jobs_shed: 0,
            cache_hits: 0,
            cache_misses: 0,
            reconciled_replays: 0,
            estimated_replays: 0,
            verified_replays: 0,
            power_replays: 0,
            cache_entries: 0,
            cache_bytes: 0,
            cache_evictions: 0,
            cache_corrupt: 0,
            patterns_parallel: 0,
            patterns_serial: 0,
            dropped_results: 0,
            queue_depth: 0,
            latency_p50: None,
            latency_p95: None,
            stages: Vec::new(),
            workers: Vec::new(),
        };
        let line = s.render();
        assert!(line.contains("0 submitted"));
        assert!(line.contains("p50 -"));
        assert!(!line.contains("stage"), "idle services render no stage segments: {line}");
        assert!(!line.contains("verify patterns"), "{line}");
        assert!(!line.contains("queue depth"), "{line}");
        assert_eq!(s.render_full(), line, "nothing ran, nothing to expand");
        let mut busy = s;
        busy.patterns_parallel = 4;
        busy.patterns_serial = 2;
        busy.queue_depth = 3;
        busy.dropped_results = 1;
        let line = busy.render();
        assert!(line.contains("verify patterns: 4 parallel, 2 serial"));
        assert!(line.contains("queue depth 3, 1 dropped results"));
        let json = busy.to_json().to_string_compact();
        assert!(json.contains("\"format\":\"fbo-stats-v1\""), "{json}");
        assert!(json.contains("\"queue_depth\":3"), "{json}");
        assert!(json.contains("\"latency_p50_secs\":null"), "{json}");
    }

    #[test]
    fn verify_parallel_never_touches_the_fingerprints() {
        // The executor changes how fast a search runs, never its outcome:
        // a decision verified serially must replay byte-identically for a
        // pooled request (and vice versa), so no fingerprint may fold
        // `verify_parallel` in.
        let cfg = ServiceConfig::new("some/artifacts");
        let base = stage_fingerprints(&cfg);
        let mut pooled = cfg.clone();
        pooled.verify_parallel = 4;
        let fp = stage_fingerprints(&pooled);
        assert_eq!(fp.discovery, base.discovery);
        assert_eq!(fp.estimate, base.estimate);
        assert_eq!(fp.verify, base.verify);
        assert_eq!(fp.decision, base.decision);
    }

    #[test]
    fn telemetry_config_never_touches_the_fingerprints() {
        // Telemetry observes runs, it never decides them: a traced
        // service must replay untraced decisions byte-identically (and
        // vice versa), so no fingerprint may fold the telemetry config in.
        let cfg = ServiceConfig::new("some/artifacts");
        let base = stage_fingerprints(&cfg);
        let mut traced = cfg.clone();
        traced.telemetry.trace_out = Some(PathBuf::from("/tmp/offload.trace.jsonl"));
        traced.telemetry.ring_capacity = 7;
        let fp = stage_fingerprints(&traced);
        assert_eq!(fp.discovery, base.discovery);
        assert_eq!(fp.estimate, base.estimate);
        assert_eq!(fp.verify, base.verify);
        assert_eq!(fp.power, base.power);
        assert_eq!(fp.decision, base.decision);
    }

    #[test]
    fn admission_and_budget_never_touch_the_fingerprints() {
        // Admission decides *whether* a job runs and the budget decides
        // what stays *cached*; neither changes what a decision *is*, so a
        // throttled, budget-bounded service must replay an unbounded
        // service's decisions byte-identically (and vice versa).
        let cfg = ServiceConfig::new("some/artifacts");
        let base = stage_fingerprints(&cfg);
        let mut bounded = cfg.clone();
        bounded.admission =
            AdmissionConfig { queue_limit: 2, rate_per_client: Some(10.0), burst: 5.0 };
        bounded.cache_budget = CacheBudget { max_bytes: Some(4096), max_entries: Some(8) };
        let fp = stage_fingerprints(&bounded);
        assert_eq!(fp.discovery, base.discovery);
        assert_eq!(fp.estimate, base.estimate);
        assert_eq!(fp.verify, base.verify);
        assert_eq!(fp.power, base.power);
        assert_eq!(fp.decision, base.decision);
    }

    #[test]
    fn fleet_config_never_touches_the_fingerprints() {
        // The fleet changes *where* measurements run, never their
        // outcome: a decision verified locally must replay
        // byte-identically for a fleet-backed request (and vice versa),
        // so no fingerprint may fold the endpoint list in.
        let cfg = ServiceConfig::new("some/artifacts");
        let base = stage_fingerprints(&cfg);
        let mut fleeted = cfg.clone();
        fleeted.fleet = vec!["worker1:7070".into(), "stdio:fbo worker --stdio".into()];
        let fp = stage_fingerprints(&fleeted);
        assert_eq!(fp.discovery, base.discovery);
        assert_eq!(fp.estimate, base.estimate);
        assert_eq!(fp.verify, base.verify);
        assert_eq!(fp.power, base.power);
        assert_eq!(fp.decision, base.decision);
    }

    #[test]
    fn resident_budget_keys_the_verify_tier_only_when_nonzero() {
        // The byte-identical-replay contract across the residency PR:
        // `--resident-bytes 0` (the default) appends nothing, so the
        // verify fingerprint — and everything chained off it — hashes
        // exactly the pre-residency formula and old cache entries keep
        // replaying. A nonzero budget changes what Step 3 observes (the
        // paid/elided traffic split and the v5 residue), so it must key
        // its own entries, and a different budget keys different ones.
        let cfg = ServiceConfig::new("some/artifacts");
        assert_eq!(cfg.resident_bytes, 0, "residency must be off by default");
        let base = stage_fingerprints(&cfg);
        let pre_residency = fnv_hex(&format!(
            "verify|{}|artifacts:{}|reps:{}|warmup:{}|fuel:{}|tol:{}",
            discovery_fingerprint(&cfg),
            artifacts_fingerprint(&cfg.artifacts),
            cfg.verify.reps,
            cfg.verify.warmup,
            cfg.verify.fuel,
            cfg.verify.tolerance,
        ));
        assert_eq!(base.verify, pre_residency);

        let mut resident = cfg.clone();
        resident.resident_bytes = 64 << 20;
        let fp = stage_fingerprints(&resident);
        assert_eq!(fp.discovery, base.discovery, "residency is a verify-time concern");
        assert_eq!(fp.estimate, base.estimate);
        assert_ne!(fp.verify, base.verify, "a budget must invalidate measurements");
        assert_ne!(fp.decision, base.decision, "and the decisions built on them");

        let mut rebudgeted = resident.clone();
        rebudgeted.resident_bytes = 128 << 20;
        assert_ne!(stage_fingerprints(&rebudgeted).verify, fp.verify);
    }

    #[test]
    fn worker_table_renders_measure_sub_jobs() {
        // The worker table must account for fan-out consistently: a
        // worker that only absorbed measurement sub-jobs still shows its
        // work (and its busy time), without inflating the decision-job
        // column that `submitted == completed + failed + shed` audits
        // against.
        let mut s = StatsSnapshot {
            submitted: 0,
            completed: 0,
            failed: 0,
            jobs_shed: 0,
            cache_hits: 0,
            cache_misses: 0,
            reconciled_replays: 0,
            estimated_replays: 0,
            verified_replays: 0,
            power_replays: 0,
            cache_entries: 0,
            cache_bytes: 0,
            cache_evictions: 0,
            cache_corrupt: 0,
            patterns_parallel: 0,
            patterns_serial: 0,
            dropped_results: 0,
            queue_depth: 0,
            latency_p50: None,
            latency_p95: None,
            stages: Vec::new(),
            workers: Vec::new(),
        };
        s.workers = vec![
            WorkerStat {
                worker: 0,
                jobs: 2,
                measure_jobs: 0,
                busy: Duration::from_secs(3),
                utilization: 0.5,
            },
            WorkerStat {
                worker: 1,
                jobs: 0,
                measure_jobs: 5,
                busy: Duration::from_secs(1),
                utilization: 0.25,
            },
        ];
        let full = s.render_full();
        assert!(full.contains("worker 0 2 jobs + 0 measure sub-jobs"), "{full}");
        assert!(full.contains("worker 1 0 jobs + 5 measure sub-jobs"), "{full}");
        let json = s.to_json().to_string_compact();
        assert!(json.contains("\"measure_jobs\":5"), "{json}");
    }

    #[test]
    fn shed_reasons_have_stable_wire_names() {
        assert_eq!(
            ShedReason::ALL.map(ShedReason::as_str),
            ["queue-full", "rate-limited", "shutting-down"]
        );
        for (i, r) in ShedReason::ALL.iter().enumerate() {
            assert_eq!(r.rank(), i, "ranks must align with ALL (shed counter indexing)");
        }
        let rejected = JobRejected {
            reason: ShedReason::QueueFull,
            queue_depth: 7,
            retry_after: Duration::from_millis(250),
        };
        assert_eq!(
            format!("{rejected}"),
            "job rejected (queue-full): queue depth 7, retry after 0.250s"
        );
        // Sheds surface through anyhow; callers must be able to get the
        // structured rejection back out.
        let err = anyhow::Error::new(rejected.clone());
        assert_eq!(err.downcast_ref::<JobRejected>(), Some(&rejected));
    }

    #[test]
    fn stage_fingerprints_isolate_their_inputs() {
        let cfg = ServiceConfig::new("some/artifacts");
        let base = stage_fingerprints(&cfg);

        // A verification-settings change invalidates verify + decision but
        // leaves discovery intact: that is what lets the pool replay
        // discovery from the cache while re-running verification.
        let mut reps = cfg.clone();
        reps.verify.reps += 1;
        let fp = stage_fingerprints(&reps);
        assert_eq!(fp.discovery, base.discovery);
        assert_eq!(fp.estimate, base.estimate, "estimate sits upstream of verification settings");
        assert_ne!(fp.verify, base.verify);
        assert_ne!(fp.decision, base.decision);

        // A backend retarget invalidates only the decision: verified
        // measurements (and power scores) replay, arbitration re-runs.
        let mut target = cfg.clone();
        target.backend_policy = BackendPolicy::Fpga;
        let fp = stage_fingerprints(&target);
        assert_eq!(fp.discovery, base.discovery);
        assert_eq!(fp.estimate, base.estimate);
        assert_eq!(fp.verify, base.verify);
        assert_eq!(fp.power, base.power);
        assert_ne!(fp.decision, base.decision);

        // A power-policy change invalidates the power tier and the
        // decision, but the verified measurements replay: no re-measuring
        // for a wattage question.
        let mut ppw = cfg.clone();
        ppw.power_policy = PowerPolicy::PerfPerWatt;
        let fp = stage_fingerprints(&ppw);
        assert_eq!(fp.discovery, base.discovery);
        assert_eq!(fp.verify, base.verify);
        assert_ne!(fp.power, base.power);
        assert_ne!(fp.decision, base.decision);

        // So does editing the wattage model itself.
        let mut model = cfg.clone();
        model.power_model.fpga.active_watts += 5.0;
        let fp = stage_fingerprints(&model);
        assert_eq!(fp.verify, base.verify);
        assert_ne!(fp.power, base.power);
        assert_ne!(fp.decision, base.decision);

        // A prune-policy change invalidates the estimate tier and
        // everything downstream of it — pruning changes which patterns
        // get measured — while discovery still replays.
        let mut pruned = cfg.clone();
        pruned.prune_policy = PrunePolicy::Conservative(0.5);
        let fp = stage_fingerprints(&pruned);
        assert_eq!(fp.discovery, base.discovery);
        assert_ne!(fp.estimate, base.estimate);
        assert_ne!(fp.verify, base.verify);
        assert_ne!(fp.power, base.power);
        assert_ne!(fp.decision, base.decision);

        // An interface-policy change invalidates everything.
        let mut policy = cfg.clone();
        policy.policy = InterfacePolicy::AutoReject;
        let fp = stage_fingerprints(&policy);
        assert_ne!(fp.discovery, base.discovery);
        assert_ne!(fp.estimate, base.estimate);
        assert_ne!(fp.verify, base.verify);
        assert_ne!(fp.power, base.power);
        assert_ne!(fp.decision, base.decision);
    }

    #[test]
    fn default_power_config_reproduces_the_pre_power_decision_fingerprint() {
        // The byte-identical-replay contract across the power PR: under
        // the default (`perf` + built-in model) configuration the decision
        // fingerprint hashes exactly the pre-power formula, chaining off
        // the verify tier, so v2 cache entries written before the power
        // stage existed still replay. (The power *tier* key is distinct —
        // `PowerScored` entries can never collide with `Verified` ones.)
        let cfg = ServiceConfig::new("some/artifacts");
        assert!(power_is_default(&cfg));
        let pre_power = fnv_hex(&format!(
            "decide|{}|target:{}|device:{}/{}/{}/{}/{}",
            verify_fingerprint(&cfg),
            cfg.backend_policy.as_str(),
            cfg.device.name,
            cfg.device.alms,
            cfg.device.dsps,
            cfg.device.m20ks,
            cfg.device.fmax,
        ));
        assert_eq!(decision_fingerprint(&cfg), pre_power);
        let fp = stage_fingerprints(&cfg);
        assert_ne!(fp.power, fp.verify, "power tier must key its own entries");

        // Any non-default power input leaves the compatibility path.
        let mut ppw = cfg.clone();
        ppw.power_policy = PowerPolicy::Cap(50.0);
        assert!(!power_is_default(&ppw));
        assert_ne!(decision_fingerprint(&ppw), pre_power);
    }

    #[test]
    fn default_estimate_config_reproduces_the_pre_estimate_verify_fingerprint() {
        // The byte-identical-replay contract across the estimator PR:
        // under the default (`off` pruning + built-in profiles)
        // configuration the verify fingerprint hashes exactly the
        // pre-estimator formula, chaining off discovery, so verified
        // artifacts and decisions written before the estimate stage
        // existed still replay. (The estimate *tier* key is distinct —
        // `Estimated` entries can never collide with `Reconciled` ones.)
        let cfg = ServiceConfig::new("some/artifacts");
        assert!(estimate_is_default(&cfg));
        let pre_estimate = fnv_hex(&format!(
            "verify|{}|artifacts:{}|reps:{}|warmup:{}|fuel:{}|tol:{}",
            discovery_fingerprint(&cfg),
            artifacts_fingerprint(&cfg.artifacts),
            cfg.verify.reps,
            cfg.verify.warmup,
            cfg.verify.fuel,
            cfg.verify.tolerance,
        ));
        assert_eq!(verify_fingerprint(&cfg), pre_estimate);
        let fp = stage_fingerprints(&cfg);
        assert_ne!(fp.estimate, fp.discovery, "estimate tier must key its own entries");

        // Any non-default estimator input leaves the compatibility path:
        // the verify chain re-anchors on the estimate fingerprint.
        let mut pruned = cfg.clone();
        pruned.prune_policy = PrunePolicy::Conservative(0.5);
        assert!(!estimate_is_default(&pruned));
        assert_ne!(verify_fingerprint(&pruned), pre_estimate);
        assert_eq!(verify_fingerprint(&pruned), {
            fnv_hex(&format!(
                "verify|{}|artifacts:{}|reps:{}|warmup:{}|fuel:{}|tol:{}",
                estimate_fingerprint(&pruned),
                artifacts_fingerprint(&pruned.artifacts),
                pruned.verify.reps,
                pruned.verify.warmup,
                pruned.verify.fuel,
                pruned.verify.tolerance,
            ))
        });
    }
}
