//! Job queue + coordinator worker pool.
//!
//! One [`Coordinator`] per worker thread: the PJRT runtime behind it holds
//! `Rc`/`RefCell` state and is not `Send`, so each coordinator is
//! constructed on its own thread and never leaves it. Jobs (owned source +
//! entry name) are `Send` and flow through one `mpsc` queue per worker;
//! each worker compiles its own copy of the artifacts once and then
//! serves pipeline runs for the life of the service.
//!
//! Every job is checked against the decision cache twice: at submit time
//! (a hit completes without touching the queue) and again on the worker
//! (an identical job may have been verified while this one was queued).
//! Jobs are **sharded onto workers by cache key**, so identical jobs in
//! flight land on the same worker and run in order: the first one
//! verifies, the duplicates behind it hit the cache on their second check
//! and replay the decision byte-identically — the pipeline never runs
//! twice for one key.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{report_json, BackendPolicy, Coordinator, OffloadReport, VerifyConfig};
use crate::fpga;
use crate::metrics;
use crate::patterndb::json::fnv1a64;
use crate::patterndb::PatternDb;
use crate::transform::InterfacePolicy;

use super::cache::{CacheKey, DecisionCache};

/// Service construction parameters.
#[derive(Clone)]
pub struct ServiceConfig {
    /// AOT artifact directory (each worker opens its own engine on it).
    pub artifacts: PathBuf,
    /// Decision cache directory. `None` defaults to `decision_cache/`
    /// next to the artifacts dir (when `persist` is on).
    pub cache_dir: Option<PathBuf>,
    /// Persist decisions to disk so they survive restarts.
    pub persist: bool,
    /// Worker-thread count (one coordinator + PJRT engine each).
    pub workers: usize,
    /// Pattern DB shared by all workers; digested (together with `policy`,
    /// `verify`, `similarity_threshold`, `backend_policy`, `device`, and
    /// the artifact contents) into the cache key's decision fingerprint.
    pub db: PatternDb,
    /// Interface-reconciliation policy (C-1/C-2 confirmations).
    pub policy: InterfacePolicy,
    /// Verification-measurement settings (Step 3).
    pub verify: VerifyConfig,
    /// Deckard-style similarity threshold for copied-code discovery.
    pub similarity_threshold: f64,
    /// Backend-arbitration policy (CLI `--target`): part of the decision
    /// fingerprint, so a `--target fpga` decision never replays for a
    /// `--target gpu` request.
    pub backend_policy: BackendPolicy,
    /// FPGA device model arbitration runs against: also fingerprinted, so
    /// retargeting the deployment (different card, different fmax)
    /// invalidates every previously verified decision.
    pub device: fpga::Device,
}

impl ServiceConfig {
    /// Defaults over an artifact directory (2 workers, persistent cache).
    pub fn new(artifacts: impl Into<PathBuf>) -> Self {
        ServiceConfig {
            artifacts: artifacts.into(),
            cache_dir: None,
            persist: true,
            workers: 2,
            db: PatternDb::builtin(),
            policy: InterfacePolicy::AutoApprove,
            verify: VerifyConfig::default(),
            similarity_threshold: crate::similarity::DEFAULT_THRESHOLD,
            backend_policy: BackendPolicy::Auto,
            device: fpga::ARRIA10_GX,
        }
    }

    fn effective_cache_dir(&self) -> Option<PathBuf> {
        if !self.persist {
            return None;
        }
        Some(self.cache_dir.clone().unwrap_or_else(|| {
            self.artifacts.parent().unwrap_or_else(|| Path::new(".")).join("decision_cache")
        }))
    }
}

/// One finished offload job.
pub struct CompletedJob {
    /// Job id (unique within one service).
    pub id: u64,
    /// Content-addressed key the decision is cached under.
    pub key: CacheKey,
    /// Entry-point function of the job.
    pub entry: String,
    /// The decoded offload decision.
    pub report: OffloadReport,
    /// Canonical serialized report — byte-identical whether this job ran
    /// the pipeline or replayed a cached decision (shared with the cache,
    /// so replaying is an O(1) clone).
    pub report_json: Arc<str>,
    /// True when the decision came from the cache (no pattern search or
    /// measurement ran for this job).
    pub from_cache: bool,
    /// Submit-to-completion wall clock.
    pub wall: Duration,
}

enum HandleState {
    Ready(Result<CompletedJob>),
    Pending(mpsc::Receiver<Result<CompletedJob>>),
}

/// Await handle for a submitted job.
pub struct JobHandle {
    id: u64,
    state: HandleState,
}

impl JobHandle {
    /// Job id this handle awaits.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the job finishes.
    pub fn wait(self) -> Result<CompletedJob> {
        match self.state {
            HandleState::Ready(r) => r,
            HandleState::Pending(rx) => rx.recv().unwrap_or_else(|_| {
                Err(anyhow!("offload service worker terminated before replying"))
            }),
        }
    }

    /// Non-blocking poll: the finished result, or the handle back if the
    /// job is still running (lets callers stream results as they land).
    pub fn try_wait(self) -> std::result::Result<Result<CompletedJob>, JobHandle> {
        match self.state {
            HandleState::Ready(r) => Ok(r),
            HandleState::Pending(rx) => match rx.try_recv() {
                Ok(r) => Ok(r),
                Err(mpsc::TryRecvError::Empty) => {
                    Err(JobHandle { id: self.id, state: HandleState::Pending(rx) })
                }
                Err(mpsc::TryRecvError::Disconnected) => {
                    Ok(Err(anyhow!("offload service worker terminated before replying")))
                }
            },
        }
    }
}

struct Job {
    id: u64,
    src: String,
    entry: String,
    key: CacheKey,
    submitted_at: Instant,
    reply: mpsc::Sender<Result<CompletedJob>>,
}

/// Latency samples kept for the percentile counters: a sliding window so a
/// long-running `serve` process stays O(1) in memory no matter how many
/// jobs it has answered.
const LATENCY_WINDOW: usize = 4096;

#[derive(Default)]
struct LatencyRing {
    buf: Vec<u64>,
    next: usize,
}

impl LatencyRing {
    fn record(&mut self, ns: u64) {
        if self.buf.len() < LATENCY_WINDOW {
            self.buf.push(ns);
        } else {
            self.buf[self.next] = ns; // overwrite the oldest sample
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
    }
}

#[derive(Default)]
struct Counters {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    latencies_ns: Mutex<LatencyRing>,
}

struct Shared {
    cache: DecisionCache,
    /// Third cache-key component: everything besides the source and entry
    /// that determines the decision — see [`decision_fingerprint`].
    decision_fingerprint: String,
    counters: Counters,
}

/// Digest of the decision *environment*: pattern-DB content, the AOT
/// artifacts verification measures against, the interface policy and
/// verification settings the pipeline runs under, and the backend policy
/// + FPGA device model the Step-3b arbitration targets. Any of these
/// changes the decision a run would produce, so any of them changing must
/// miss the cache — a report verified under `--policy reject` must never
/// be replayed for a `--policy approve` request, regenerated artifacts
/// (`make artifacts` after a kernel edit) must re-verify rather than
/// replay measurements taken against the old HLO, and a decision
/// arbitrated for one FPGA card must re-arbitrate when the deployment
/// retargets another.
fn decision_fingerprint(cfg: &ServiceConfig) -> String {
    let policy = match &cfg.policy {
        InterfacePolicy::AutoApprove => "approve".to_string(),
        InterfacePolicy::AutoReject => "reject".to_string(),
        InterfacePolicy::Scripted(answers) => format!("scripted:{answers:?}"),
    };
    let blob = format!(
        "{}|artifacts:{}|policy:{policy}|reps:{}|warmup:{}|fuel:{}|tol:{}|sim:{}\
         |target:{}|device:{}/{}/{}/{}/{}",
        cfg.db.fingerprint(),
        artifacts_fingerprint(&cfg.artifacts),
        cfg.verify.reps,
        cfg.verify.warmup,
        cfg.verify.fuel,
        cfg.verify.tolerance,
        cfg.similarity_threshold,
        cfg.backend_policy.as_str(),
        cfg.device.name,
        cfg.device.alms,
        cfg.device.dsps,
        cfg.device.m20ks,
        cfg.device.fmax,
    );
    format!("{:016x}", fnv1a64(blob.as_bytes()))
}

/// Content hash of an artifact directory: manifest bytes plus every
/// `*.hlo.txt`, by name order. Reading ~1 MB once per service start is
/// noise next to compiling the artifacts. A missing/unreadable dir hashes
/// to a distinct value and startup then fails in `Coordinator::open` with
/// the proper error.
fn artifacts_fingerprint(dir: &Path) -> String {
    let manifest = std::fs::read(dir.join("manifest.json")).unwrap_or_default();
    let mut blob = format!("manifest:{:016x}", fnv1a64(&manifest));
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .into_iter()
        .flatten()
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("txt"))
        .collect();
    files.sort();
    for path in files {
        let content = std::fs::read(&path).unwrap_or_default();
        blob.push_str(&format!(
            "|{}:{:016x}",
            path.file_name().and_then(|n| n.to_str()).unwrap_or(""),
            fnv1a64(&content)
        ));
    }
    format!("{:016x}", fnv1a64(blob.as_bytes()))
}

impl Shared {
    fn record_outcome(&self, result: &Result<CompletedJob>) {
        match result {
            Ok(done) => {
                self.counters.completed.fetch_add(1, Ordering::Relaxed);
                self.counters
                    .latencies_ns
                    .lock()
                    .expect("latency lock")
                    .record(done.wall.as_nanos() as u64);
            }
            Err(_) => {
                self.counters.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Cache probe. `None` means "run the pipeline": either a genuine miss
    /// or an undecodable entry — a damaged decision file must cost one
    /// re-verification (which overwrites it), never fail the key forever.
    /// Only a successfully decoded replay counts as a hit.
    fn try_cached(
        &self,
        id: u64,
        key: &CacheKey,
        entry: &str,
        started: Instant,
    ) -> Option<CompletedJob> {
        let bytes: Arc<str> = self.cache.lookup(key)?;
        match report_json::report_from_str(&bytes) {
            Ok(report) => {
                self.counters.cache_hits.fetch_add(1, Ordering::Relaxed);
                Some(CompletedJob {
                    id,
                    key: key.clone(),
                    entry: entry.to_string(),
                    report,
                    report_json: bytes,
                    from_cache: true,
                    wall: started.elapsed(),
                })
            }
            Err(e) => {
                eprintln!(
                    "fbo service: ignoring undecodable cache entry {} ({e:#}); re-verifying",
                    key.file_stem()
                );
                None
            }
        }
    }
}

/// Point-in-time service counters. Latency percentiles are computed over
/// a sliding window of the most recent 4096 completed jobs.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    /// Jobs accepted.
    pub submitted: u64,
    /// Jobs completed successfully.
    pub completed: u64,
    /// Jobs failed (bad source, missing entry, pipeline error).
    pub failed: u64,
    /// Jobs answered from the decision cache.
    pub cache_hits: u64,
    /// Jobs that ran the full pipeline.
    pub cache_misses: u64,
    /// Decisions currently cached.
    pub cache_entries: u64,
    /// Median completion latency over the sliding window.
    pub latency_p50: Option<Duration>,
    /// 95th-percentile completion latency over the sliding window.
    pub latency_p95: Option<Duration>,
}

impl StatsSnapshot {
    /// One-line human rendering (CLI `batch`/`serve` output).
    pub fn render(&self) -> String {
        let fmt = |d: Option<Duration>| {
            d.map(metrics::fmt_duration).unwrap_or_else(|| "-".to_string())
        };
        format!(
            "jobs: {} submitted, {} completed, {} failed | cache: {} hits / {} misses ({} entries) | latency p50 {} p95 {}",
            self.submitted,
            self.completed,
            self.failed,
            self.cache_hits,
            self.cache_misses,
            self.cache_entries,
            fmt(self.latency_p50),
            fmt(self.latency_p95),
        )
    }
}

/// The offload service: decision cache + worker pool over the paper's
/// pipeline. See the [module docs](self) and [`crate::service`].
pub struct OffloadService {
    shared: Arc<Shared>,
    /// One queue per worker; jobs are sharded onto them by cache key.
    txs: Option<Vec<mpsc::Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl OffloadService {
    /// Start the worker pool. Blocks until every worker has opened its
    /// engine (so artifact problems surface here, not on first submit).
    pub fn start(cfg: ServiceConfig) -> Result<OffloadService> {
        if cfg.workers == 0 {
            bail!("service needs at least one worker");
        }
        let cache = match cfg.effective_cache_dir() {
            Some(dir) => DecisionCache::open(&dir)?,
            None => DecisionCache::in_memory(),
        };
        let shared = Arc::new(Shared {
            cache,
            decision_fingerprint: decision_fingerprint(&cfg),
            counters: Counters::default(),
        });
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let nworkers = cfg.workers;
        let mut txs = Vec::with_capacity(nworkers);
        let mut workers = Vec::with_capacity(nworkers);
        for i in 0..nworkers {
            let (tx, rx) = mpsc::channel::<Job>();
            txs.push(tx);
            let shared = shared.clone();
            let cfg = cfg.clone();
            let ready = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("fbo-worker-{i}"))
                .spawn(move || worker_main(cfg, shared, rx, ready))
                .context("spawning service worker")?;
            workers.push(handle);
        }
        drop(ready_tx);
        for _ in 0..nworkers {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("service worker died during startup"))?
                .context("service worker startup")?;
        }
        Ok(OffloadService { shared, txs: Some(txs), workers, next_id: AtomicU64::new(1) })
    }

    /// Convenience: start with defaults over an artifact dir.
    pub fn open(artifacts: impl Into<PathBuf>) -> Result<OffloadService> {
        Self::start(ServiceConfig::new(artifacts))
    }

    /// Submit one job. Returns immediately; a cache hit (or an unparseable
    /// source) resolves the handle without touching the queue.
    pub fn submit(&self, src: &str, entry: &str) -> JobHandle {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.shared.counters.submitted.fetch_add(1, Ordering::Relaxed);
        let started = Instant::now();

        let key = match CacheKey::compute(src, entry, &self.shared.decision_fingerprint) {
            Ok(k) => k,
            Err(e) => return self.ready_handle(id, Err(e)),
        };
        if let Some(done) = self.shared.try_cached(id, &key, entry, started) {
            return self.ready_handle(id, Ok(done));
        }

        let (reply_tx, reply_rx) = mpsc::channel();
        // Shard by key: identical jobs serialize through one worker, so a
        // queued duplicate replays the first one's decision instead of
        // re-running the pipeline.
        let Some(txs) = &self.txs else {
            return self.ready_handle(id, Err(anyhow!("offload service is shut down")));
        };
        let shard = (fnv1a64(key.file_stem().as_bytes()) % txs.len() as u64) as usize;
        let job = Job {
            id,
            src: src.to_string(),
            entry: entry.to_string(),
            key,
            submitted_at: started,
            reply: reply_tx,
        };
        match txs[shard].send(job) {
            Ok(()) => JobHandle { id, state: HandleState::Pending(reply_rx) },
            Err(_) => self.ready_handle(id, Err(anyhow!("offload service is shut down"))),
        }
    }

    /// Submit a batch of `(source, entry)` jobs; handles resolve
    /// independently as workers finish.
    pub fn submit_batch(&self, jobs: &[(String, String)]) -> Vec<JobHandle> {
        jobs.iter().map(|(src, entry)| self.submit(src, entry)).collect()
    }

    /// Submit a batch and block for every result, in submission order.
    pub fn run_batch(&self, jobs: &[(String, String)]) -> Vec<Result<CompletedJob>> {
        self.submit_batch(jobs).into_iter().map(JobHandle::wait).collect()
    }

    /// Current counters (jobs, cache traffic, latency percentiles).
    pub fn stats(&self) -> StatsSnapshot {
        let c = &self.shared.counters;
        let durations: Vec<Duration> = {
            let ring = c.latencies_ns.lock().expect("latency lock");
            ring.buf.iter().map(|&n| Duration::from_nanos(n)).collect()
        };
        StatsSnapshot {
            submitted: c.submitted.load(Ordering::Relaxed),
            completed: c.completed.load(Ordering::Relaxed),
            failed: c.failed.load(Ordering::Relaxed),
            cache_hits: c.cache_hits.load(Ordering::Relaxed),
            cache_misses: c.cache_misses.load(Ordering::Relaxed),
            cache_entries: self.shared.cache.len() as u64,
            latency_p50: metrics::percentile(&durations, 50.0),
            latency_p95: metrics::percentile(&durations, 95.0),
        }
    }

    /// The decision cache (benches clear it to measure cold starts).
    pub fn cache(&self) -> &DecisionCache {
        &self.shared.cache
    }

    /// Fingerprint keying this service's decisions (pattern DB + policy +
    /// verification settings).
    pub fn decision_fingerprint(&self) -> &str {
        &self.shared.decision_fingerprint
    }

    /// Drain the queue and join every worker.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn ready_handle(&self, id: u64, result: Result<CompletedJob>) -> JobHandle {
        self.shared.record_outcome(&result);
        JobHandle { id, state: HandleState::Ready(result) }
    }

    fn shutdown_inner(&mut self) {
        self.txs.take(); // closing the queues ends every worker loop
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for OffloadService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn worker_main(
    cfg: ServiceConfig,
    shared: Arc<Shared>,
    rx: mpsc::Receiver<Job>,
    ready: mpsc::Sender<Result<()>>,
) {
    // Built on this thread, never crosses it (PJRT state is not Send).
    let coordinator = match Coordinator::open(&cfg.artifacts) {
        Ok(mut c) => {
            c.db = cfg.db;
            c.policy = cfg.policy;
            c.verify = cfg.verify;
            c.similarity_threshold = cfg.similarity_threshold;
            c.backend_policy = cfg.backend_policy;
            c.device = cfg.device;
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    // This worker owns its shard's queue outright; recv() erroring means
    // the service dropped the sender — shutdown.
    while let Ok(job) = rx.recv() {
        let result = run_job(&coordinator, &shared, &job);
        shared.record_outcome(&result);
        let _ = job.reply.send(result);
    }
}

fn run_job(c: &Coordinator, shared: &Shared, job: &Job) -> Result<CompletedJob> {
    // Second cache check: an identical job may have been verified while
    // this one sat in the queue.
    if let Some(done) = shared.try_cached(job.id, &job.key, &job.entry, job.submitted_at) {
        return Ok(done);
    }
    shared.counters.cache_misses.fetch_add(1, Ordering::Relaxed);
    let report = c.offload(&job.src, &job.entry)?;
    let report_json: Arc<str> = Arc::from(report_json::report_to_string(&report));
    // The verified decision is the product; failing to persist it degrades
    // the cache (and is reported), but must not fail the job.
    if let Err(e) = shared.cache.insert(&job.key, &report_json) {
        eprintln!("fbo service: failed to persist decision {}: {e:#}", job.key.file_stem());
    }
    Ok(CompletedJob {
        id: job.id,
        key: job.key.clone(),
        entry: job.entry.clone(),
        report,
        report_json,
        from_cache: false,
        wall: job.submitted_at.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults() {
        let cfg = ServiceConfig::new("some/artifacts");
        assert_eq!(cfg.workers, 2);
        assert!(cfg.persist);
        assert_eq!(
            cfg.effective_cache_dir().unwrap(),
            PathBuf::from("some/decision_cache"),
            "default cache dir sits next to the artifacts dir"
        );
        let mut ephemeral = cfg.clone();
        ephemeral.persist = false;
        assert!(ephemeral.effective_cache_dir().is_none());
        let mut explicit = cfg;
        explicit.cache_dir = Some(PathBuf::from("/tmp/x"));
        assert_eq!(explicit.effective_cache_dir().unwrap(), PathBuf::from("/tmp/x"));
    }

    #[test]
    fn fingerprint_tracks_backend_policy_and_device() {
        let cfg = ServiceConfig::new("some/artifacts");
        let base = decision_fingerprint(&cfg);

        let mut retargeted = cfg.clone();
        retargeted.backend_policy = BackendPolicy::Fpga;
        assert_ne!(decision_fingerprint(&retargeted), base, "--target must invalidate");

        let mut redeviced = cfg.clone();
        redeviced.device = fpga::Device { fmax: 300.0e6, ..fpga::ARRIA10_GX };
        assert_ne!(decision_fingerprint(&redeviced), base, "device model must invalidate");

        assert_eq!(decision_fingerprint(&cfg.clone()), base, "must be deterministic");
    }

    #[test]
    fn zero_workers_rejected() {
        let mut cfg = ServiceConfig::new("artifacts");
        cfg.workers = 0;
        assert!(OffloadService::start(cfg).is_err());
    }

    #[test]
    fn stats_render_handles_empty() {
        let s = StatsSnapshot {
            submitted: 0,
            completed: 0,
            failed: 0,
            cache_hits: 0,
            cache_misses: 0,
            cache_entries: 0,
            latency_p50: None,
            latency_p95: None,
        };
        let line = s.render();
        assert!(line.contains("0 submitted"));
        assert!(line.contains("p50 -"));
    }
}
