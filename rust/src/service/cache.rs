//! Content-addressed decision cache.
//!
//! The pipeline's output for a given (source, entry, pattern DB) is a
//! *verified decision*: which blocks to offload and the measured evidence.
//! The companion proposal paper frames the verification cost as one-time,
//! paid before commercial operation — this cache is the mechanism that
//! makes it one-time. Keys are content-addressed:
//!
//! * **source hash** — FNV-1a 64 over the *parsed and re-printed* program,
//!   so whitespace- and comment-only edits (and `//`-comment churn from
//!   code generators) hit the same entry while any semantic change misses;
//! * **entry point** — the same source offloaded from a different entry is
//!   a different decision;
//! * **decision fingerprint** — the service digests the pattern DB, the
//!   AOT artifact contents, its policy/verification settings, the power
//!   inputs (`--power-policy` + wattage models, when non-default), and
//!   the backend-arbitration inputs (`--target` policy + FPGA device
//!   model) into this component (see `service::pool`), so any DB change
//!   (new replacement, edited usage recipe), regenerated artifacts,
//!   config change (`--policy`, `--reps`), power-policy change, backend
//!   retarget, or device-model change invalidates every previously
//!   verified decision.
//!
//! Values are canonical [`crate::coordinator::report_json`] strings, held
//! in memory and (optionally) persisted one JSON file per entry so
//! decisions survive restarts. Because both the report codec and this
//! module print through the canonical JSON writer, a warm read returns
//! **byte-identical** output to the freshly computed serialization.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::parser;
use crate::patterndb::json::{self, fnv1a64, Json};

/// Format tag of a persisted cache entry.
pub const DECISION_FORMAT: &str = "fbo-decision-v1";

/// Content-addressed key of one offload decision.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// 16-hex FNV-1a 64 of the canonically printed AST.
    pub source_hash: String,
    /// Entry-point function name.
    pub entry: String,
    /// 16-hex digest of the decision environment. The service passes a
    /// combined digest of [`crate::patterndb::PatternDb::fingerprint`]
    /// and its policy/verification settings; a bare DB fingerprint works
    /// too when policy/config invalidation is not needed.
    pub db_fingerprint: String,
}

impl CacheKey {
    /// Compute the key for an application source. Parses the source (the
    /// only non-trivial cost, microseconds at app scale) and hashes the
    /// canonical re-print, so formatting and comments never affect the key.
    pub fn compute(src: &str, entry: &str, db_fingerprint: &str) -> Result<CacheKey> {
        let prog = parser::parse(src).context("computing cache key: source must parse")?;
        let printed = parser::print_program(&prog);
        Ok(CacheKey {
            source_hash: format!("{:016x}", fnv1a64(printed.as_bytes())),
            entry: entry.to_string(),
            db_fingerprint: db_fingerprint.to_string(),
        })
    }

    /// The same (source, entry) under a different environment fingerprint.
    /// The service derives its per-stage keys from the submit-time key
    /// this way: `Reconciled` and `Verified` stage artifacts are cached
    /// under narrower fingerprints than the full decision, so a config
    /// change invalidates exactly the pipeline stages it affects.
    pub fn with_fingerprint(&self, fingerprint: &str) -> CacheKey {
        CacheKey {
            source_hash: self.source_hash.clone(),
            entry: self.entry.clone(),
            db_fingerprint: fingerprint.to_string(),
        }
    }

    /// Stable file stem for the persisted entry (digest of all three
    /// components; the full key is also stored inside the file).
    pub fn file_stem(&self) -> String {
        let blob = format!("{}|{}|{}", self.source_hash, self.entry, self.db_fingerprint);
        format!("{:016x}", fnv1a64(blob.as_bytes()))
    }
}

/// Monotonic traffic counters of one [`DecisionCache`] — the telemetry
/// registry's `fbo_cache_*` series read them. Counting is the cache's
/// only side effect of being observed; lookups and inserts behave
/// identically with or without anyone reading these.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    /// Total lookups served (hits + misses).
    pub lookups: u64,
    /// Lookups that found an entry.
    pub hits: u64,
    /// Entries stored (re-inserts of the same key included).
    pub inserts: u64,
}

/// Thread-safe decision store: in-memory map + optional JSON-per-entry
/// persistence directory. Values are `Arc<str>` so a warm hit hands out
/// the serialized report with an O(1) clone instead of copying multi-KB
/// JSON under the map lock.
pub struct DecisionCache {
    dir: Option<PathBuf>,
    entries: Mutex<HashMap<CacheKey, Arc<str>>>,
    tmp_seq: AtomicU64,
    lookups: AtomicU64,
    hits: AtomicU64,
    inserts: AtomicU64,
}

impl DecisionCache {
    /// A purely in-memory cache (tests, ephemeral runs).
    pub fn in_memory() -> Self {
        DecisionCache {
            dir: None,
            entries: Mutex::new(HashMap::new()),
            tmp_seq: AtomicU64::new(0),
            lookups: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
        }
    }

    /// Open (creating if needed) a persistent cache directory and load
    /// every existing entry. Corrupt or foreign files are skipped — a
    /// damaged entry costs one re-verification, never a failed start.
    pub fn open(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating decision cache dir {}", dir.display()))?;
        let mut entries = HashMap::new();
        for e in std::fs::read_dir(dir)
            .with_context(|| format!("reading decision cache dir {}", dir.display()))?
        {
            let path = e?.path();
            if path.extension().and_then(|x| x.to_str()) != Some("json") {
                continue;
            }
            if let Ok((key, report)) = load_entry(&path) {
                entries.insert(key, report);
            }
        }
        Ok(DecisionCache {
            dir: Some(dir.to_path_buf()),
            entries: Mutex::new(entries),
            tmp_seq: AtomicU64::new(0),
            lookups: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
        })
    }

    /// The persistence directory, if any.
    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// Number of cached decisions.
    pub fn len(&self) -> usize {
        self.entries.lock().expect("decision cache lock").len()
    }

    /// True when no decisions are cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fetch the serialized report for a key, if present (O(1) `Arc` clone).
    pub fn lookup(&self, key: &CacheKey) -> Option<Arc<str>> {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let found = self.entries.lock().expect("decision cache lock").get(key).cloned();
        if found.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        found
    }

    /// Snapshot of the monotonic traffic counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            lookups: self.lookups.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
        }
    }

    /// Store a serialized decision under a key (persisting it if the cache
    /// is disk-backed). `report_json` must be a canonical serialization —
    /// a full report or a pipeline stage artifact (the service caches
    /// both); the write is tmp-file + rename so concurrent readers
    /// of the directory never observe a torn entry. The in-memory map is
    /// updated first — a failed disk write degrades persistence, never
    /// in-process serving.
    pub fn insert(&self, key: &CacheKey, report_json: &str) -> Result<()> {
        self.inserts.fetch_add(1, Ordering::Relaxed);
        self.entries
            .lock()
            .expect("decision cache lock")
            .insert(key.clone(), Arc::from(report_json));
        if let Some(dir) = &self.dir {
            let report = json::parse(report_json)
                .context("decision cache insert: report must be valid JSON")?;
            let wrapper = Json::obj(vec![
                ("format", Json::str(DECISION_FORMAT)),
                ("source_hash", Json::str(&key.source_hash)),
                ("entry", Json::str(&key.entry)),
                ("db_fingerprint", Json::str(&key.db_fingerprint)),
                ("report", report),
            ]);
            let stem = key.file_stem();
            let seq = self.tmp_seq.fetch_add(1, Ordering::Relaxed);
            let tmp = dir.join(format!(".{stem}.{}.{seq}.tmp", std::process::id()));
            let path = dir.join(format!("{stem}.json"));
            std::fs::write(&tmp, json::to_string_pretty(&wrapper))
                .with_context(|| format!("writing decision entry {}", tmp.display()))?;
            std::fs::rename(&tmp, &path)
                .with_context(|| format!("publishing decision entry {}", path.display()))?;
        }
        Ok(())
    }

    /// Drop every cached decision (memory and disk). Used by benches to
    /// build a guaranteed-cold cache. Only files that actually parse as
    /// [`DECISION_FORMAT`] entries are removed — foreign `.json` files
    /// that `open` deliberately skips are left alone, mirroring that
    /// tolerance on the write side. A *corrupt* entry of our own is
    /// indistinguishable from a foreign file and is also left behind;
    /// that is harmless — `open` skips it and the next verification of
    /// its key overwrites it via the tmp-file + rename in `insert`.
    pub fn clear(&self) -> Result<()> {
        self.entries.lock().expect("decision cache lock").clear();
        if let Some(dir) = &self.dir {
            for e in std::fs::read_dir(dir)? {
                let path = e?.path();
                if path.extension().and_then(|x| x.to_str()) != Some("json") {
                    continue;
                }
                if load_entry(&path).is_ok() {
                    std::fs::remove_file(&path)
                        .with_context(|| format!("removing {}", path.display()))?;
                }
            }
        }
        Ok(())
    }
}

fn load_entry(path: &Path) -> Result<(CacheKey, Arc<str>)> {
    let src = std::fs::read_to_string(path)?;
    let v = json::parse(&src)?;
    if v.get("format")?.as_str()? != DECISION_FORMAT {
        bail!("not a decision entry");
    }
    let key = CacheKey {
        source_hash: v.get("source_hash")?.as_str()?.to_string(),
        entry: v.get("entry")?.as_str()?.to_string(),
        db_fingerprint: v.get("db_fingerprint")?.as_str()?.to_string(),
    };
    // Re-print the report subtree standalone: the canonical writer
    // reproduces exactly the bytes `insert` was given.
    let report = json::to_string_pretty(v.get("report")?);
    Ok((key, Arc::from(report)))
}

#[cfg(test)]
mod tests {
    use super::*;

    const FP: &str = "00000000deadbeef";

    #[test]
    fn key_is_insensitive_to_whitespace_and_comments() {
        let a = "int main() { return 40 + 2; }";
        let b = "// a comment\nint   main(  )   {\n\n  /* block\n comment */ return 40 + 2;\n}\n";
        let ka = CacheKey::compute(a, "main", FP).unwrap();
        let kb = CacheKey::compute(b, "main", FP).unwrap();
        assert_eq!(ka, kb);
    }

    #[test]
    fn key_tracks_semantics_entry_and_db() {
        let base = CacheKey::compute("int main() { return 1; }", "main", FP).unwrap();
        let edited = CacheKey::compute("int main() { return 2; }", "main", FP).unwrap();
        assert_ne!(base.source_hash, edited.source_hash);
        let other_entry = CacheKey::compute("int main() { return 1; }", "other", FP).unwrap();
        assert_ne!(base, other_entry);
        assert_eq!(base.source_hash, other_entry.source_hash);
        let other_db =
            CacheKey::compute("int main() { return 1; }", "main", "ffffffff00000000").unwrap();
        assert_ne!(base, other_db);
        assert_ne!(base.file_stem(), other_db.file_stem());
    }

    #[test]
    fn unparseable_source_has_no_key() {
        assert!(CacheKey::compute("int f( {", "main", FP).is_err());
    }

    #[test]
    fn in_memory_insert_lookup() {
        let c = DecisionCache::in_memory();
        let k = CacheKey::compute("int main() { return 0; }", "main", FP).unwrap();
        assert!(c.lookup(&k).is_none());
        c.insert(&k, r#"{"x": 1}"#).unwrap();
        assert_eq!(&*c.lookup(&k).unwrap(), r#"{"x": 1}"#);
        assert_eq!(c.len(), 1);
        // Traffic counters saw the miss, the hit, and the insert.
        assert_eq!(c.stats(), CacheStats { lookups: 2, hits: 1, inserts: 1 });
        c.clear().unwrap();
        assert!(c.is_empty());
        assert_eq!(c.stats().inserts, 1, "clear drops entries, not counters");
    }

    #[test]
    fn persistent_entries_survive_reopen() {
        let dir = std::env::temp_dir().join(format!("fbo-cache-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let k = CacheKey::compute("int main() { return 7; }", "main", FP).unwrap();
        // Canonical bytes: what report_to_string would produce.
        let body = json::to_string_pretty(&json::parse(r#"{"b": [1, 2], "a": "x"}"#).unwrap());
        {
            let c = DecisionCache::open(&dir).unwrap();
            c.insert(&k, &body).unwrap();
        }
        let c = DecisionCache::open(&dir).unwrap();
        assert_eq!(c.len(), 1);
        assert_eq!(&*c.lookup(&k).unwrap(), body, "reloaded entry must be byte-identical");
        // Corrupt files are skipped, not fatal.
        std::fs::write(dir.join("junk.json"), "{ not json").unwrap();
        let c = DecisionCache::open(&dir).unwrap();
        assert_eq!(c.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn clear_spares_foreign_json_files() {
        let dir = std::env::temp_dir().join(format!("fbo-cacheclear-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = DecisionCache::open(&dir).unwrap();
        let k = CacheKey::compute("int main() { return 7; }", "main", FP).unwrap();
        c.insert(&k, r#"{"x": 1}"#).unwrap();
        // A foreign config file someone dropped next to the entries (valid
        // JSON, wrong format tag) and a non-JSON note: `open` skips both,
        // so `clear` must not delete them either.
        let foreign = dir.join("deploy-notes.json");
        std::fs::write(&foreign, r#"{"format": "ops-notes", "owner": "sre"}"#).unwrap();
        let note = dir.join("README.txt");
        std::fs::write(&note, "hands off").unwrap();
        c.clear().unwrap();
        assert!(c.is_empty());
        assert!(foreign.exists(), "foreign .json must survive clear()");
        assert!(note.exists());
        assert!(
            !dir.join(format!("{}.json", k.file_stem())).exists(),
            "our entry must be removed"
        );
        // Reopening sees the same world clear() left behind: no entries.
        let c = DecisionCache::open(&dir).unwrap();
        assert!(c.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
