//! Content-addressed decision cache with tier-aware, size-bounded eviction.
//!
//! The pipeline's output for a given (source, entry, pattern DB) is a
//! *verified decision*: which blocks to offload and the measured evidence.
//! The companion proposal paper frames the verification cost as one-time,
//! paid before commercial operation — this cache is the mechanism that
//! makes it one-time. Keys are content-addressed:
//!
//! * **source hash** — FNV-1a 64 over the *parsed and re-printed* program,
//!   so whitespace- and comment-only edits (and `//`-comment churn from
//!   code generators) hit the same entry while any semantic change misses;
//! * **entry point** — the same source offloaded from a different entry is
//!   a different decision;
//! * **decision fingerprint** — the service digests the pattern DB, the
//!   AOT artifact contents, its policy/verification settings, the power
//!   inputs (`--power-policy` + wattage models, when non-default), and
//!   the backend-arbitration inputs (`--target` policy + FPGA device
//!   model) into this component (see `service::pool`), so any DB change
//!   (new replacement, edited usage recipe), regenerated artifacts,
//!   config change (`--policy`, `--reps`), power-policy change, backend
//!   retarget, or device-model change invalidates every previously
//!   verified decision.
//!
//! Values are canonical [`crate::coordinator::report_json`] strings, held
//! in memory and (optionally) persisted one JSON file per entry so
//! decisions survive restarts. Because both the report codec and this
//! module print through the canonical JSON writer, a warm read returns
//! **byte-identical** output to the freshly computed serialization.
//!
//! # Eviction
//!
//! Entries carry a [`CacheTier`] recording what they cost to recompute.
//! When a [`CacheBudget`] is set (or [`DecisionCache::gc`] is called), the
//! cache evicts in *tier priority then LRU* order: reconciled artifacts
//! (milliseconds of static analysis) go first, then analytic estimates
//! (profile arithmetic over the reconciled blocks), then power scores
//! (arithmetic over existing measurements), then full decisions
//! (re-arbitration over cached verified evidence), and verified
//! measurements — the tier that embodies real benchmark time — go last.
//!
//! # Crash consistency
//!
//! Entry files are the *authoritative* store: each is published with a
//! tmp-file + atomic rename, so a reader (or a crash) never observes a
//! torn entry. The on-disk index (`index.json`) is an *advisory* sidecar
//! persisting LRU recency across restarts; it is also written atomically,
//! and [`DecisionCache::open`] reconciles it against the files that
//! actually exist: index rows pointing at deleted files are dropped,
//! files missing from the index load with the oldest possible recency.
//! A crash at any point between eviction steps therefore costs at most
//! stale recency — never a corrupted surviving entry.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::parser;
use crate::patterndb::json::{self, fnv1a64, Json};
use crate::telemetry::metrics::{Counter, Gauge};
use crate::telemetry::trace::{TraceEvent, TraceRecorder};

/// Format tag of a persisted cache entry.
pub const DECISION_FORMAT: &str = "fbo-decision-v1";

/// Format tag of the persisted recency index.
pub const INDEX_FORMAT: &str = "fbo-cache-index-v1";

/// File name of the recency index inside a cache directory. Entry files
/// are 16-hex stems, so the name can never collide with an entry.
pub const INDEX_FILE: &str = "index.json";

/// Number of cache tiers (the length of [`CacheTier::ALL`]).
pub const TIER_COUNT: usize = 5;

/// What a cached artifact costs to recompute — the eviction priority.
///
/// Declaration order *is* eviction order: `Reconciled` is dropped first,
/// `Verified` last. The ordering mirrors the recompute cost ladder: a
/// reconciliation is a static-analysis pass, a power score is arithmetic
/// over existing measurements, a decision is re-arbitration over cached
/// verified evidence, and a verified artifact embodies real measurement
/// wall-clock that cannot be recovered any cheaper than re-benchmarking.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum CacheTier {
    /// Pattern-discovery + reconciliation output (cheapest to redo).
    Reconciled,
    /// Analytic device-profile estimates (arithmetic over the reconciled
    /// blocks — no measurement evidence involved).
    Estimated,
    /// Power-scored measurement set (arithmetic over verified evidence).
    PowerScored,
    /// Full arbitrated decision (re-derivable from verified evidence).
    Decision,
    /// Verified measurement evidence (hours of virtual benchmark time).
    Verified,
}

impl CacheTier {
    /// All tiers, in eviction-priority order (first evicted → last).
    pub const ALL: [CacheTier; TIER_COUNT] = [
        CacheTier::Reconciled,
        CacheTier::Estimated,
        CacheTier::PowerScored,
        CacheTier::Decision,
        CacheTier::Verified,
    ];

    /// Position in the eviction order: 0 = evicted first.
    pub fn rank(self) -> usize {
        match self {
            CacheTier::Reconciled => 0,
            CacheTier::Estimated => 1,
            CacheTier::PowerScored => 2,
            CacheTier::Decision => 3,
            CacheTier::Verified => 4,
        }
    }

    /// Stable wire name — matches the `tier` label of the service's
    /// `CacheProbe` trace events and the `fbo_cache_*` metric labels.
    pub fn as_str(self) -> &'static str {
        match self {
            CacheTier::Reconciled => "reconciled",
            CacheTier::Estimated => "estimated",
            CacheTier::PowerScored => "power-scored",
            CacheTier::Decision => "decision",
            CacheTier::Verified => "verified",
        }
    }

    /// Inverse of [`CacheTier::as_str`].
    pub fn parse(s: &str) -> Option<CacheTier> {
        CacheTier::ALL.into_iter().find(|t| t.as_str() == s)
    }
}

/// Size limits for a [`DecisionCache`]. `None` fields are unlimited; the
/// default budget is fully unlimited (the pre-eviction behavior).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheBudget {
    /// Max total payload bytes kept after enforcement.
    pub max_bytes: Option<u64>,
    /// Max entry count kept after enforcement.
    pub max_entries: Option<usize>,
}

impl CacheBudget {
    /// No limits — eviction never triggers.
    pub fn unlimited() -> CacheBudget {
        CacheBudget::default()
    }

    /// True when neither limit is set.
    pub fn is_unlimited(&self) -> bool {
        self.max_bytes.is_none() && self.max_entries.is_none()
    }

    /// True when the given usage is within both limits.
    pub fn admits(&self, bytes: u64, entries: usize) -> bool {
        bytes <= self.max_bytes.unwrap_or(u64::MAX)
            && entries <= self.max_entries.unwrap_or(usize::MAX)
    }
}

/// Parse a human byte size: a plain integer, optionally suffixed with
/// `k`/`kb`, `m`/`mb`, or `g`/`gb` (powers of 1024, case-insensitive).
/// Used by `fbo cache gc --max-bytes` and the service budget flags.
pub fn parse_byte_size(s: &str) -> Result<u64> {
    let t = s.trim().to_ascii_lowercase();
    let (digits, mult) = if let Some(d) = t.strip_suffix("kb").or_else(|| t.strip_suffix('k')) {
        (d, 1u64 << 10)
    } else if let Some(d) = t.strip_suffix("mb").or_else(|| t.strip_suffix('m')) {
        (d, 1u64 << 20)
    } else if let Some(d) = t.strip_suffix("gb").or_else(|| t.strip_suffix('g')) {
        (d, 1u64 << 30)
    } else {
        (t.as_str(), 1u64)
    };
    let n: u64 = digits
        .trim()
        .parse()
        .map_err(|_| anyhow!("invalid byte size {s:?} (expected e.g. 4096, 64k, 10m, 1g)"))?;
    n.checked_mul(mult).ok_or_else(|| anyhow!("byte size {s:?} overflows"))
}

/// Monotonic traffic counters of one [`DecisionCache`] — the telemetry
/// registry's `fbo_cache_*` series read them. Counting is the cache's
/// only side effect of being observed; lookups and inserts behave
/// identically with or without anyone reading these.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    /// Total lookups served (hits + misses).
    pub lookups: u64,
    /// Lookups that found an entry.
    pub hits: u64,
    /// Entries stored (re-inserts of the same key included).
    pub inserts: u64,
    /// Evictions per tier, indexed by [`CacheTier::rank`].
    pub evictions: [u64; TIER_COUNT],
    /// Corrupt entries (or indexes) detected — files that claim to be
    /// ours (or are unreadable as JSON at all) but cannot be loaded.
    pub corrupt: u64,
}

impl CacheStats {
    /// Total evictions across all tiers.
    pub fn evictions_total(&self) -> u64 {
        self.evictions.iter().sum()
    }
}

/// Point-in-time occupancy of a [`DecisionCache`], taken under the map
/// lock so bytes/entries are mutually consistent (unlike counter reads,
/// which can interleave with a concurrent insert).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheUsage {
    /// Total payload bytes currently held.
    pub bytes: u64,
    /// Total entries currently held.
    pub entries: usize,
    /// Payload bytes per tier, indexed by [`CacheTier::rank`].
    pub tier_bytes: [u64; TIER_COUNT],
    /// Entry counts per tier, indexed by [`CacheTier::rank`].
    pub tier_entries: [usize; TIER_COUNT],
}

/// One entry removed (or, in a dry run, *selected* for removal) by
/// [`DecisionCache::gc`] or budget enforcement.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EvictedEntry {
    /// The evicted key.
    pub key: CacheKey,
    /// Its tier at eviction time.
    pub tier: CacheTier,
    /// Its payload size.
    pub bytes: u64,
}

/// Outcome of one [`DecisionCache::gc`] pass.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GcOutcome {
    /// True when nothing was actually removed (`--dry-run`).
    pub dry_run: bool,
    /// Payload bytes before the pass.
    pub bytes_before: u64,
    /// Payload bytes after the pass (equals `bytes_before` on dry runs).
    pub bytes_after: u64,
    /// Entry count before the pass.
    pub entries_before: usize,
    /// Entry count after the pass.
    pub entries_after: usize,
    /// Entries removed (or selected), in eviction order: tier priority
    /// first ([`CacheTier::rank`] ascending), least-recently-used first
    /// within a tier.
    pub evicted: Vec<EvictedEntry>,
}

/// Registry-backed instruments a service attaches to its cache so
/// eviction, corruption, and occupancy surface in `/metrics` and the
/// trace stream. Constructed by `service::pool` from its [`crate::telemetry::metrics::Registry`];
/// the cache's own atomic counters in [`CacheStats`] work with or
/// without an attachment.
pub struct CacheTelemetry {
    /// `fbo_cache_evictions_total{tier=...}`, indexed by [`CacheTier::rank`].
    pub evictions: [Arc<Counter>; TIER_COUNT],
    /// `fbo_cache_corrupt_total`.
    pub corrupt: Arc<Counter>,
    /// `fbo_cache_bytes` gauge.
    pub bytes: Arc<Gauge>,
    /// Destination for warn-level `cache-corrupt` trace events.
    pub recorder: Arc<TraceRecorder>,
}

/// Content-addressed key of one offload decision.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// 16-hex FNV-1a 64 of the canonically printed AST.
    pub source_hash: String,
    /// Entry-point function name.
    pub entry: String,
    /// 16-hex digest of the decision environment. The service passes a
    /// combined digest of [`crate::patterndb::PatternDb::fingerprint`]
    /// and its policy/verification settings; a bare DB fingerprint works
    /// too when policy/config invalidation is not needed.
    pub db_fingerprint: String,
}

impl CacheKey {
    /// Compute the key for an application source. Parses the source (the
    /// only non-trivial cost, microseconds at app scale) and hashes the
    /// canonical re-print, so formatting and comments never affect the key.
    pub fn compute(src: &str, entry: &str, db_fingerprint: &str) -> Result<CacheKey> {
        let prog = parser::parse(src).context("computing cache key: source must parse")?;
        let printed = parser::print_program(&prog);
        Ok(CacheKey {
            source_hash: format!("{:016x}", fnv1a64(printed.as_bytes())),
            entry: entry.to_string(),
            db_fingerprint: db_fingerprint.to_string(),
        })
    }

    /// The same (source, entry) under a different environment fingerprint.
    /// The service derives its per-stage keys from the submit-time key
    /// this way: `Reconciled` and `Verified` stage artifacts are cached
    /// under narrower fingerprints than the full decision, so a config
    /// change invalidates exactly the pipeline stages it affects.
    pub fn with_fingerprint(&self, fingerprint: &str) -> CacheKey {
        CacheKey {
            source_hash: self.source_hash.clone(),
            entry: self.entry.clone(),
            db_fingerprint: fingerprint.to_string(),
        }
    }

    /// Stable file stem for the persisted entry (digest of all three
    /// components; the full key is also stored inside the file).
    pub fn file_stem(&self) -> String {
        let blob = format!("{}|{}|{}", self.source_hash, self.entry, self.db_fingerprint);
        format!("{:016x}", fnv1a64(blob.as_bytes()))
    }
}

struct Entry {
    payload: Arc<str>,
    tier: CacheTier,
    /// Logical LRU clock stamp: larger = used more recently. Stamps come
    /// from one monotonic counter shared by inserts and lookups, so they
    /// are unique and eviction within a tier has a total order.
    last_used: u64,
}

struct CacheState {
    entries: HashMap<CacheKey, Entry>,
    /// Running sum of payload lengths — kept exact by insert/evict so
    /// budget checks never rescan the map.
    bytes: u64,
}

/// Thread-safe decision store: in-memory map + optional JSON-per-entry
/// persistence directory. Values are `Arc<str>` so a warm hit hands out
/// the serialized report with an O(1) clone instead of copying multi-KB
/// JSON under the map lock.
///
/// Lock order (when both are needed): the state lock is taken before the
/// telemetry lock, never the reverse.
pub struct DecisionCache {
    dir: Option<PathBuf>,
    state: Mutex<CacheState>,
    budget: Mutex<CacheBudget>,
    telemetry: Mutex<Option<CacheTelemetry>>,
    /// Corruption seen before a [`CacheTelemetry`] was attached (e.g.
    /// during `open`); drained into the attachment so nothing is lost.
    pending_corrupt: Mutex<Vec<(String, String)>>,
    use_seq: AtomicU64,
    tmp_seq: AtomicU64,
    lookups: AtomicU64,
    hits: AtomicU64,
    inserts: AtomicU64,
    evictions: [AtomicU64; TIER_COUNT],
    corrupt: AtomicU64,
}

impl DecisionCache {
    fn new_inner(dir: Option<PathBuf>) -> Self {
        DecisionCache {
            dir,
            state: Mutex::new(CacheState { entries: HashMap::new(), bytes: 0 }),
            budget: Mutex::new(CacheBudget::unlimited()),
            telemetry: Mutex::new(None),
            pending_corrupt: Mutex::new(Vec::new()),
            use_seq: AtomicU64::new(1),
            tmp_seq: AtomicU64::new(0),
            lookups: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            evictions: Default::default(),
            corrupt: AtomicU64::new(0),
        }
    }

    /// A purely in-memory cache (tests, ephemeral runs).
    pub fn in_memory() -> Self {
        DecisionCache::new_inner(None)
    }

    /// Open (creating if needed) a persistent cache directory and load
    /// every existing entry. Corrupt files are skipped *and counted*
    /// (see [`CacheStats::corrupt`]) — a damaged entry costs one
    /// re-verification, never a failed start; foreign `.json` files that
    /// don't claim our format tag are skipped silently. Recency is
    /// restored from the advisory index when present: index rows whose
    /// file no longer exists are dropped, files the index doesn't know
    /// load as least-recently-used. Entries written before tiers existed
    /// (no `tier` field) load as [`CacheTier::Decision`].
    pub fn open(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating decision cache dir {}", dir.display()))?;
        let cache = DecisionCache::new_inner(Some(dir.to_path_buf()));
        let recency = match read_index(dir) {
            Ok(map) => map,
            Err(e) => {
                cache.note_corrupt(
                    &dir.join(INDEX_FILE).display().to_string(),
                    &format!("unreadable cache index (recency reset): {e}"),
                );
                HashMap::new()
            }
        };
        let mut max_stamp = 0u64;
        {
            let mut st = cache.state.lock().expect("decision cache lock");
            for e in std::fs::read_dir(dir)
                .with_context(|| format!("reading decision cache dir {}", dir.display()))?
            {
                let path = e?.path();
                if path.extension().and_then(|x| x.to_str()) != Some("json") {
                    continue;
                }
                if path.file_name().and_then(|x| x.to_str()) == Some(INDEX_FILE) {
                    continue;
                }
                match classify_entry(&path) {
                    Loaded::Ours { key, payload, tier } => {
                        let stamp =
                            recency.get(&key.file_stem()).copied().unwrap_or_default();
                        max_stamp = max_stamp.max(stamp);
                        st.bytes += payload.len() as u64;
                        st.entries.insert(key, Entry { payload, tier, last_used: stamp });
                    }
                    Loaded::Foreign => {}
                    Loaded::Corrupt(why) => {
                        cache.note_corrupt(&path.display().to_string(), &why);
                    }
                }
            }
        }
        cache.use_seq.store(max_stamp + 1, Ordering::Relaxed);
        Ok(cache)
    }

    /// The persistence directory, if any.
    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// Number of cached decisions.
    pub fn len(&self) -> usize {
        self.state.lock().expect("decision cache lock").entries.len()
    }

    /// True when no decisions are cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The standing budget enforced after every insert.
    pub fn budget(&self) -> CacheBudget {
        *self.budget.lock().expect("cache budget lock")
    }

    /// Set the standing budget. Enforcement happens on the *next* insert;
    /// call [`DecisionCache::gc`] to apply it immediately.
    pub fn set_budget(&self, budget: CacheBudget) {
        *self.budget.lock().expect("cache budget lock") = budget;
    }

    /// Attach registry-backed instruments (idempotent in effect: the
    /// service attaches once at startup). Corruption seen before the
    /// attachment — typically during [`DecisionCache::open`] — is drained
    /// into the counters and trace stream so startup rot is visible too.
    pub fn attach_telemetry(&self, telemetry: CacheTelemetry) {
        // Lock order: state before telemetry (usage read releases the
        // state lock before the telemetry lock is taken).
        let usage = self.usage();
        let pending: Vec<(String, String)> =
            std::mem::take(&mut *self.pending_corrupt.lock().expect("cache corrupt lock"));
        for (what, why) in &pending {
            telemetry.corrupt.inc();
            telemetry
                .recorder
                .record(0, TraceEvent::CacheCorrupt { path: what.clone(), detail: why.clone() });
        }
        telemetry.bytes.set(usage.bytes as f64);
        for (rank, c) in telemetry.evictions.iter().enumerate() {
            c.add(self.evictions[rank].load(Ordering::Relaxed));
        }
        *self.telemetry.lock().expect("cache telemetry lock") = Some(telemetry);
    }

    /// Fetch the serialized report for a key, if present (O(1) `Arc`
    /// clone). A hit refreshes the entry's LRU recency.
    pub fn lookup(&self, key: &CacheKey) -> Option<Arc<str>> {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let mut st = self.state.lock().expect("decision cache lock");
        if let Some(e) = st.entries.get_mut(key) {
            e.last_used = self.use_seq.fetch_add(1, Ordering::Relaxed);
            self.hits.fetch_add(1, Ordering::Relaxed);
            Some(e.payload.clone())
        } else {
            None
        }
    }

    /// Snapshot of the monotonic traffic counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            lookups: self.lookups.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            evictions: [
                self.evictions[0].load(Ordering::Relaxed),
                self.evictions[1].load(Ordering::Relaxed),
                self.evictions[2].load(Ordering::Relaxed),
                self.evictions[3].load(Ordering::Relaxed),
                self.evictions[4].load(Ordering::Relaxed),
            ],
            corrupt: self.corrupt.load(Ordering::Relaxed),
        }
    }

    /// Consistent occupancy snapshot (bytes and entries, total and per
    /// tier), taken under the map lock. Between two observations the
    /// cache never exceeds its budget *as seen through this method* —
    /// budget enforcement runs inside the same lock as the insert that
    /// could breach it.
    pub fn usage(&self) -> CacheUsage {
        let st = self.state.lock().expect("decision cache lock");
        let mut u = CacheUsage {
            bytes: st.bytes,
            entries: st.entries.len(),
            ..CacheUsage::default()
        };
        for e in st.entries.values() {
            u.tier_bytes[e.tier.rank()] += e.payload.len() as u64;
            u.tier_entries[e.tier.rank()] += 1;
        }
        u
    }

    /// Snapshot of every entry: key, tier, and payload, in no particular
    /// order. `fbo calibrate` walks this to fit device-profile scale
    /// factors against the cached decisions' predicted-vs-measured
    /// residues. Payloads are `Arc<str>` clones (O(1) each); the map lock
    /// is held only for the copy-out, so a concurrent insert at worst
    /// misses the snapshot. Recency is deliberately *not* refreshed —
    /// enumeration is an audit, not a use, and must not perturb LRU
    /// eviction order.
    pub fn entries_snapshot(&self) -> Vec<(CacheKey, CacheTier, Arc<str>)> {
        let st = self.state.lock().expect("decision cache lock");
        st.entries.iter().map(|(k, e)| (k.clone(), e.tier, e.payload.clone())).collect()
    }

    /// Store a full-decision entry ([`CacheTier::Decision`]) — see
    /// [`DecisionCache::insert_tier`].
    pub fn insert(&self, key: &CacheKey, report_json: &str) -> Result<()> {
        self.insert_tier(key, CacheTier::Decision, report_json)
    }

    /// Store a serialized artifact under a key and tier (persisting it if
    /// the cache is disk-backed). `report_json` must be a canonical
    /// serialization — a full report or a pipeline stage artifact (the
    /// service caches both); the write is tmp-file + rename so concurrent
    /// readers of the directory never observe a torn entry. The in-memory
    /// map is updated first — a failed disk write degrades persistence,
    /// never in-process serving. If a standing [`CacheBudget`] is set,
    /// it is enforced before returning: the call may evict other entries
    /// (or, when the budget is smaller than this single artifact, the
    /// just-inserted one — the budget invariant always wins).
    pub fn insert_tier(&self, key: &CacheKey, tier: CacheTier, report_json: &str) -> Result<()> {
        self.inserts.fetch_add(1, Ordering::Relaxed);
        let mut st = self.state.lock().expect("decision cache lock");
        let payload: Arc<str> = Arc::from(report_json);
        let stamp = self.use_seq.fetch_add(1, Ordering::Relaxed);
        st.bytes += payload.len() as u64;
        if let Some(old) =
            st.entries.insert(key.clone(), Entry { payload, tier, last_used: stamp })
        {
            st.bytes -= old.payload.len() as u64;
        }
        if let Some(dir) = &self.dir {
            self.write_entry_file(dir, key, tier, report_json)?;
        }
        let budget = self.budget();
        if !budget.admits(st.bytes, st.entries.len()) {
            self.evict_to_budget(&mut st, budget);
        }
        if self.dir.is_some() {
            self.write_index_locked(&st)?;
        }
        self.publish_bytes(st.bytes);
        Ok(())
    }

    /// Evict down to `budget` in tier-priority-then-LRU order. With
    /// `dry_run`, report what *would* be evicted without removing
    /// anything. Eviction removes each victim's entry file before the
    /// index is rewritten; because surviving files are never touched and
    /// both the files and the index are written atomically, a crash
    /// between any two steps leaves at worst a stale index row (dropped
    /// on the next open) — never a corrupted survivor.
    pub fn gc(&self, budget: CacheBudget, dry_run: bool) -> Result<GcOutcome> {
        let mut st = self.state.lock().expect("decision cache lock");
        let bytes_before = st.bytes;
        let entries_before = st.entries.len();
        let evicted = if dry_run {
            select_victims(&st, budget)
                .into_iter()
                .map(|key| {
                    let e = &st.entries[&key];
                    EvictedEntry { tier: e.tier, bytes: e.payload.len() as u64, key }
                })
                .collect()
        } else {
            let evicted = self.evict_to_budget(&mut st, budget);
            if self.dir.is_some() {
                self.write_index_locked(&st)?;
            }
            evicted
        };
        self.publish_bytes(st.bytes);
        Ok(GcOutcome {
            dry_run,
            bytes_before,
            bytes_after: st.bytes,
            entries_before,
            entries_after: st.entries.len(),
            evicted,
        })
    }

    /// Drop every cached decision (memory and disk). Used by benches to
    /// build a guaranteed-cold cache. Only files that actually parse as
    /// [`DECISION_FORMAT`] entries are removed — foreign `.json` files
    /// that `open` deliberately skips are left alone, mirroring that
    /// tolerance on the write side. A *corrupt* file is also left behind
    /// but is **counted** (`fbo_cache_corrupt_total` plus a warn-level
    /// `cache-corrupt` trace event) so rot is visible to operators; the
    /// next verification of its key overwrites it via the tmp-file +
    /// rename in [`DecisionCache::insert_tier`].
    pub fn clear(&self) -> Result<()> {
        let mut st = self.state.lock().expect("decision cache lock");
        st.entries.clear();
        st.bytes = 0;
        if let Some(dir) = &self.dir {
            for e in std::fs::read_dir(dir)? {
                let path = e?.path();
                if path.extension().and_then(|x| x.to_str()) != Some("json") {
                    continue;
                }
                if path.file_name().and_then(|x| x.to_str()) == Some(INDEX_FILE) {
                    continue;
                }
                match classify_entry(&path) {
                    Loaded::Ours { .. } => {
                        std::fs::remove_file(&path)
                            .with_context(|| format!("removing {}", path.display()))?;
                    }
                    Loaded::Foreign => {}
                    Loaded::Corrupt(why) => {
                        self.note_corrupt(&path.display().to_string(), &why);
                    }
                }
            }
            self.write_index_locked(&st)?;
        }
        self.publish_bytes(st.bytes);
        Ok(())
    }

    /// Remove victims until `budget` is satisfied; the caller holds the
    /// state lock and rewrites the index afterwards.
    fn evict_to_budget(&self, st: &mut CacheState, budget: CacheBudget) -> Vec<EvictedEntry> {
        let victims = select_victims(st, budget);
        let mut evicted = Vec::with_capacity(victims.len());
        for key in victims {
            let e = st.entries.remove(&key).expect("selected victim must exist");
            st.bytes -= e.payload.len() as u64;
            if let Some(dir) = &self.dir {
                // A missing file is exactly the post-state eviction wants;
                // other errors (permissions) leave an orphan that the next
                // open re-adopts — safe either way, so neither is fatal.
                let _ = std::fs::remove_file(dir.join(format!("{}.json", key.file_stem())));
            }
            self.note_eviction(e.tier);
            evicted.push(EvictedEntry { key, tier: e.tier, bytes: e.payload.len() as u64 });
        }
        evicted
    }

    fn write_entry_file(
        &self,
        dir: &Path,
        key: &CacheKey,
        tier: CacheTier,
        report_json: &str,
    ) -> Result<()> {
        let report = json::parse(report_json)
            .context("decision cache insert: report must be valid JSON")?;
        let wrapper = Json::obj(vec![
            ("format", Json::str(DECISION_FORMAT)),
            ("source_hash", Json::str(&key.source_hash)),
            ("entry", Json::str(&key.entry)),
            ("db_fingerprint", Json::str(&key.db_fingerprint)),
            ("tier", Json::str(tier.as_str())),
            ("report", report),
        ]);
        let stem = key.file_stem();
        let path = dir.join(format!("{stem}.json"));
        self.publish_atomic(dir, &stem, &path, &json::to_string_pretty(&wrapper))
    }

    fn write_index_locked(&self, st: &CacheState) -> Result<()> {
        let dir = self.dir.as_ref().expect("index write requires a directory");
        let mut rows: Vec<(String, &Entry)> =
            st.entries.iter().map(|(k, e)| (k.file_stem(), e)).collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        let entries = rows
            .into_iter()
            .map(|(stem, e)| {
                Json::obj(vec![
                    ("stem", Json::str(stem)),
                    ("tier", Json::str(e.tier.as_str())),
                    ("last_used", Json::num(e.last_used as f64)),
                    ("bytes", Json::num(e.payload.len() as f64)),
                ])
            })
            .collect();
        let index = Json::obj(vec![
            ("format", Json::str(INDEX_FORMAT)),
            ("entries", Json::Arr(entries)),
        ]);
        let path = dir.join(INDEX_FILE);
        self.publish_atomic(dir, "index", &path, &json::to_string_pretty(&index))
    }

    /// Tmp-file + rename publication — the only way bytes reach the
    /// cache directory, so readers never observe a torn file.
    fn publish_atomic(&self, dir: &Path, stem: &str, path: &Path, body: &str) -> Result<()> {
        let seq = self.tmp_seq.fetch_add(1, Ordering::Relaxed);
        let tmp = dir.join(format!(".{stem}.{}.{seq}.tmp", std::process::id()));
        std::fs::write(&tmp, body)
            .with_context(|| format!("writing cache file {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publishing cache file {}", path.display()))?;
        Ok(())
    }

    /// Count a corrupt artifact and surface it: warn on stderr, bump
    /// `fbo_cache_corrupt_total`, and emit a `cache-corrupt` trace event
    /// (buffered until a [`CacheTelemetry`] is attached).
    fn note_corrupt(&self, what: &str, why: &str) {
        self.corrupt.fetch_add(1, Ordering::Relaxed);
        eprintln!("[fbo] warning: corrupt cache artifact {what}: {why}");
        {
            let tel = self.telemetry.lock().expect("cache telemetry lock");
            if let Some(t) = &*tel {
                t.corrupt.inc();
                t.recorder.record(
                    0,
                    TraceEvent::CacheCorrupt { path: what.to_string(), detail: why.to_string() },
                );
                return;
            }
        }
        self.pending_corrupt
            .lock()
            .expect("cache corrupt lock")
            .push((what.to_string(), why.to_string()));
    }

    fn note_eviction(&self, tier: CacheTier) {
        self.evictions[tier.rank()].fetch_add(1, Ordering::Relaxed);
        if let Some(t) = &*self.telemetry.lock().expect("cache telemetry lock") {
            t.evictions[tier.rank()].inc();
        }
    }

    fn publish_bytes(&self, bytes: u64) {
        if let Some(t) = &*self.telemetry.lock().expect("cache telemetry lock") {
            t.bytes.set(bytes as f64);
        }
    }
}

/// Victim keys for bringing `st` within `budget`, in eviction order:
/// tier priority ascending ([`CacheTier::rank`]), then least recently
/// used first. Stops as soon as both limits are satisfied.
fn select_victims(st: &CacheState, budget: CacheBudget) -> Vec<CacheKey> {
    if budget.admits(st.bytes, st.entries.len()) {
        return Vec::new();
    }
    let mut order: Vec<(usize, u64, u64, CacheKey)> = st
        .entries
        .iter()
        .map(|(k, e)| (e.tier.rank(), e.last_used, e.payload.len() as u64, k.clone()))
        .collect();
    order.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    let mut bytes = st.bytes;
    let mut count = st.entries.len();
    let mut victims = Vec::new();
    for (_, _, size, key) in order {
        if budget.admits(bytes, count) {
            break;
        }
        bytes -= size;
        count -= 1;
        victims.push(key);
    }
    victims
}

enum Loaded {
    Ours { key: CacheKey, payload: Arc<str>, tier: CacheTier },
    Foreign,
    Corrupt(String),
}

/// Classify one `.json` file in the cache directory. *Foreign* files —
/// valid JSON that doesn't carry our format tag — are tolerated silently
/// (operators park notes next to entries; `clear` spares them). A file
/// that is not valid JSON at all, or that claims [`DECISION_FORMAT`] but
/// can't be loaded, is *corrupt*: it degrades to a cache miss and is
/// counted so rot is visible.
fn classify_entry(path: &Path) -> Loaded {
    let src = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => return Loaded::Corrupt(format!("unreadable: {e}")),
    };
    let v = match json::parse(&src) {
        Ok(v) => v,
        Err(e) => return Loaded::Corrupt(format!("invalid JSON: {e}")),
    };
    match v.opt("format").and_then(|f| f.as_str().ok()) {
        Some(DECISION_FORMAT) => {}
        _ => return Loaded::Foreign,
    }
    match parse_ours(&v) {
        Ok(loaded) => loaded,
        Err(e) => Loaded::Corrupt(format!("malformed entry: {e:#}")),
    }
}

fn parse_ours(v: &Json) -> Result<Loaded> {
    let key = CacheKey {
        source_hash: v.get("source_hash")?.as_str()?.to_string(),
        entry: v.get("entry")?.as_str()?.to_string(),
        db_fingerprint: v.get("db_fingerprint")?.as_str()?.to_string(),
    };
    // Entries written before tiers existed carry no tier field: they are
    // full decisions (stage artifacts gained persistence together with
    // tiers), so Decision is the faithful default.
    let tier = match v.opt("tier") {
        None => CacheTier::Decision,
        Some(t) => {
            let name = t.as_str()?;
            CacheTier::parse(name).ok_or_else(|| anyhow!("unknown cache tier {name:?}"))?
        }
    };
    // Re-print the report subtree standalone: the canonical writer
    // reproduces exactly the bytes `insert` was given.
    let payload: Arc<str> = Arc::from(json::to_string_pretty(v.get("report")?));
    Ok(Loaded::Ours { key, payload, tier })
}

/// Recency map (`file stem -> last_used`) from the advisory index, or an
/// error when the index exists but cannot be read (corrupt index: the
/// caller counts it and proceeds with recency reset — entry files are
/// authoritative, so no payload is ever lost to a bad index).
fn read_index(dir: &Path) -> Result<HashMap<String, u64>> {
    let path = dir.join(INDEX_FILE);
    let src = match std::fs::read_to_string(&path) {
        Ok(s) => s,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(HashMap::new()),
        Err(e) => bail!("unreadable index: {e}"),
    };
    let v = json::parse(&src).context("index is not valid JSON")?;
    if v.get("format")?.as_str()? != INDEX_FORMAT {
        bail!("not a cache index");
    }
    let mut recency = HashMap::new();
    for row in v.get("entries")?.as_arr()? {
        let stem = row.get("stem")?.as_str()?.to_string();
        let last_used = row.get("last_used")?.as_f64()? as u64;
        recency.insert(stem, last_used);
    }
    Ok(recency)
}

#[cfg(test)]
mod tests {
    use super::*;

    const FP: &str = "00000000deadbeef";

    fn key(tag: u32) -> CacheKey {
        CacheKey {
            source_hash: format!("{tag:016x}"),
            entry: "main".to_string(),
            db_fingerprint: FP.to_string(),
        }
    }

    #[test]
    fn key_is_insensitive_to_whitespace_and_comments() {
        let a = "int main() { return 40 + 2; }";
        let b = "// a comment\nint   main(  )   {\n\n  /* block\n comment */ return 40 + 2;\n}\n";
        let ka = CacheKey::compute(a, "main", FP).unwrap();
        let kb = CacheKey::compute(b, "main", FP).unwrap();
        assert_eq!(ka, kb);
    }

    #[test]
    fn key_tracks_semantics_entry_and_db() {
        let base = CacheKey::compute("int main() { return 1; }", "main", FP).unwrap();
        let edited = CacheKey::compute("int main() { return 2; }", "main", FP).unwrap();
        assert_ne!(base.source_hash, edited.source_hash);
        let other_entry = CacheKey::compute("int main() { return 1; }", "other", FP).unwrap();
        assert_ne!(base, other_entry);
        assert_eq!(base.source_hash, other_entry.source_hash);
        let other_db =
            CacheKey::compute("int main() { return 1; }", "main", "ffffffff00000000").unwrap();
        assert_ne!(base, other_db);
        assert_ne!(base.file_stem(), other_db.file_stem());
    }

    #[test]
    fn unparseable_source_has_no_key() {
        assert!(CacheKey::compute("int f( {", "main", FP).is_err());
    }

    #[test]
    fn tier_names_round_trip_and_order() {
        for t in CacheTier::ALL {
            assert_eq!(CacheTier::parse(t.as_str()), Some(t));
        }
        assert_eq!(CacheTier::parse("bogus"), None);
        // Eviction priority: cheap-to-recompute first, verified last.
        assert!(CacheTier::Reconciled < CacheTier::Estimated);
        assert!(CacheTier::Estimated < CacheTier::PowerScored);
        assert!(CacheTier::PowerScored < CacheTier::Decision);
        assert!(CacheTier::Decision < CacheTier::Verified);
    }

    #[test]
    fn byte_sizes_parse() {
        assert_eq!(parse_byte_size("4096").unwrap(), 4096);
        assert_eq!(parse_byte_size("64k").unwrap(), 64 << 10);
        assert_eq!(parse_byte_size("64KB").unwrap(), 64 << 10);
        assert_eq!(parse_byte_size("10m").unwrap(), 10 << 20);
        assert_eq!(parse_byte_size("1g").unwrap(), 1 << 30);
        assert!(parse_byte_size("ten").is_err());
        assert!(parse_byte_size("1t").is_err());
    }

    #[test]
    fn in_memory_insert_lookup() {
        let c = DecisionCache::in_memory();
        let k = CacheKey::compute("int main() { return 0; }", "main", FP).unwrap();
        assert!(c.lookup(&k).is_none());
        c.insert(&k, r#"{"x": 1}"#).unwrap();
        assert_eq!(&*c.lookup(&k).unwrap(), r#"{"x": 1}"#);
        assert_eq!(c.len(), 1);
        // Traffic counters saw the miss, the hit, and the insert.
        assert_eq!(
            c.stats(),
            CacheStats { lookups: 2, hits: 1, inserts: 1, ..CacheStats::default() }
        );
        c.clear().unwrap();
        assert!(c.is_empty());
        assert_eq!(c.stats().inserts, 1, "clear drops entries, not counters");
    }

    #[test]
    fn eviction_prefers_cheap_tiers_then_lru() {
        let c = DecisionCache::in_memory();
        // Two entries per tier; payloads are 10 bytes each.
        let body = r#"{"x": 111}"#;
        let mut tags = 0u32;
        let mut keys = Vec::new();
        for tier in CacheTier::ALL {
            for _ in 0..2 {
                let k = key(tags);
                tags += 1;
                c.insert_tier(&k, tier, body).unwrap();
                keys.push((k, tier));
            }
        }
        // Touch the FIRST entry of every tier: the untouched second entry
        // becomes the LRU victim within its tier.
        for (k, _) in keys.iter().step_by(2) {
            assert!(c.lookup(k).is_some());
        }
        let before = c.usage();
        assert_eq!(before.entries, 10);
        // Budget for 5 entries: evicts 5 in order reconciled(LRU),
        // reconciled(touched), estimated(LRU), estimated(touched),
        // power-scored(LRU).
        let out =
            c.gc(CacheBudget { max_bytes: None, max_entries: Some(5) }, false).unwrap();
        assert_eq!(out.entries_before, 10);
        assert_eq!(out.entries_after, 5);
        let evicted: Vec<(CacheKey, CacheTier)> =
            out.evicted.iter().map(|e| (e.key.clone(), e.tier)).collect();
        assert_eq!(
            evicted,
            vec![
                (keys[1].0.clone(), CacheTier::Reconciled),
                (keys[0].0.clone(), CacheTier::Reconciled),
                (keys[3].0.clone(), CacheTier::Estimated),
                (keys[2].0.clone(), CacheTier::Estimated),
                (keys[5].0.clone(), CacheTier::PowerScored),
            ]
        );
        // Verified entries are never evicted while cheaper tiers remain.
        assert!(c.lookup(&keys[8].0).is_some());
        assert!(c.lookup(&keys[9].0).is_some());
        assert_eq!(c.stats().evictions, [2, 2, 1, 0, 0]);
    }

    #[test]
    fn snapshot_enumerates_without_touching_recency() {
        let c = DecisionCache::in_memory();
        c.insert_tier(&key(1), CacheTier::Decision, r#"{"x": 1}"#).unwrap();
        c.insert_tier(&key(2), CacheTier::Verified, r#"{"x": 2}"#).unwrap();
        let mut snap = c.entries_snapshot();
        snap.sort_by(|a, b| a.0.source_hash.cmp(&b.0.source_hash));
        assert_eq!(snap.len(), 2);
        assert_eq!((&snap[0].0, snap[0].1), (&key(1), CacheTier::Decision));
        assert_eq!(&*snap[0].2, r#"{"x": 1}"#);
        assert_eq!(snap[1].1, CacheTier::Verified);
        // Enumeration must not count as use: key(1) is still the LRU
        // victim even after the snapshot walked it.
        let out = c.gc(CacheBudget { max_bytes: None, max_entries: Some(1) }, true).unwrap();
        assert_eq!(out.evicted[0].key, key(1));
        assert_eq!(c.stats().lookups, 0, "snapshot is not a lookup");
    }

    #[test]
    fn standing_budget_enforced_on_insert() {
        let c = DecisionCache::in_memory();
        c.set_budget(CacheBudget { max_bytes: Some(25), max_entries: None });
        let body = r#"{"x": 111}"#; // 10 canonical bytes
        c.insert_tier(&key(1), CacheTier::Verified, body).unwrap();
        c.insert_tier(&key(2), CacheTier::Verified, body).unwrap();
        assert_eq!(c.usage().bytes, 20);
        // Third insert breaches 25 bytes: the LRU verified entry goes.
        c.insert_tier(&key(3), CacheTier::Verified, body).unwrap();
        let u = c.usage();
        assert!(u.bytes <= 25, "budget must hold after insert, got {}", u.bytes);
        assert_eq!(u.entries, 2);
        assert!(c.lookup(&key(1)).is_none(), "oldest entry evicted");
        assert!(c.lookup(&key(3)).is_some(), "newest entry kept");
    }

    #[test]
    fn gc_dry_run_reports_without_deleting() {
        let dir = std::env::temp_dir().join(format!("fbo-cachedry-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = DecisionCache::open(&dir).unwrap();
        c.insert_tier(&key(1), CacheTier::Reconciled, r#"{"x": 1}"#).unwrap();
        c.insert_tier(&key(2), CacheTier::Verified, r#"{"x": 2}"#).unwrap();
        let out = c.gc(CacheBudget { max_bytes: None, max_entries: Some(1) }, true).unwrap();
        assert!(out.dry_run);
        assert_eq!(out.evicted.len(), 1);
        assert_eq!(out.evicted[0].tier, CacheTier::Reconciled);
        assert_eq!(out.entries_after, 2, "dry run must not evict");
        assert_eq!(c.len(), 2);
        assert!(dir.join(format!("{}.json", key(1).file_stem())).exists());
        assert_eq!(c.stats().evictions_total(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn persistent_entries_survive_reopen() {
        let dir = std::env::temp_dir().join(format!("fbo-cache-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let k = CacheKey::compute("int main() { return 7; }", "main", FP).unwrap();
        // Canonical bytes: what report_to_string would produce.
        let body = json::to_string_pretty(&json::parse(r#"{"b": [1, 2], "a": "x"}"#).unwrap());
        {
            let c = DecisionCache::open(&dir).unwrap();
            c.insert(&k, &body).unwrap();
        }
        let c = DecisionCache::open(&dir).unwrap();
        assert_eq!(c.len(), 1);
        assert_eq!(&*c.lookup(&k).unwrap(), body, "reloaded entry must be byte-identical");
        // Corrupt files are skipped — and now counted — not fatal.
        std::fs::write(dir.join("junk.json"), "{ not json").unwrap();
        let c = DecisionCache::open(&dir).unwrap();
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().corrupt, 1, "invalid-JSON file must be counted");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recency_survives_reopen_via_index() {
        let dir = std::env::temp_dir().join(format!("fbo-cachelru-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let c = DecisionCache::open(&dir).unwrap();
            c.insert_tier(&key(1), CacheTier::Verified, r#"{"x": 1}"#).unwrap();
            c.insert_tier(&key(2), CacheTier::Verified, r#"{"x": 2}"#).unwrap();
            // key(1) is older by insertion but freshly used: the index
            // must persist that, so after reopen key(2) is the victim.
            assert!(c.lookup(&key(1)).is_some());
        }
        let c = DecisionCache::open(&dir).unwrap();
        let out = c.gc(CacheBudget { max_bytes: None, max_entries: Some(1) }, false).unwrap();
        assert_eq!(out.evicted.len(), 1);
        assert_eq!(out.evicted[0].key, key(2), "LRU order must survive reopen");
        assert!(c.lookup(&key(1)).is_some());
        assert!(!dir.join(format!("{}.json", key(2).file_stem())).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tier_less_legacy_entries_load_as_decisions() {
        let dir = std::env::temp_dir().join(format!("fbo-cachelegacy-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let k = key(9);
        // A pre-tier entry: same wrapper, no "tier" field, no index.
        let wrapper = Json::obj(vec![
            ("format", Json::str(DECISION_FORMAT)),
            ("source_hash", Json::str(&k.source_hash)),
            ("entry", Json::str(&k.entry)),
            ("db_fingerprint", Json::str(&k.db_fingerprint)),
            ("report", json::parse(r#"{"x": 1}"#).unwrap()),
        ]);
        std::fs::write(
            dir.join(format!("{}.json", k.file_stem())),
            json::to_string_pretty(&wrapper),
        )
        .unwrap();
        let c = DecisionCache::open(&dir).unwrap();
        assert_eq!(c.stats().corrupt, 0);
        assert!(c.lookup(&k).is_some());
        assert_eq!(c.usage().tier_entries[CacheTier::Decision.rank()], 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn clear_spares_foreign_json_files() {
        let dir = std::env::temp_dir().join(format!("fbo-cacheclear-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = DecisionCache::open(&dir).unwrap();
        let k = CacheKey::compute("int main() { return 7; }", "main", FP).unwrap();
        c.insert(&k, r#"{"x": 1}"#).unwrap();
        // A foreign config file someone dropped next to the entries (valid
        // JSON, wrong format tag) and a non-JSON note: `open` skips both,
        // so `clear` must not delete them either.
        let foreign = dir.join("deploy-notes.json");
        std::fs::write(&foreign, r#"{"format": "ops-notes", "owner": "sre"}"#).unwrap();
        let note = dir.join("README.txt");
        std::fs::write(&note, "hands off").unwrap();
        c.clear().unwrap();
        assert!(c.is_empty());
        assert!(foreign.exists(), "foreign .json must survive clear()");
        assert!(note.exists());
        assert!(
            !dir.join(format!("{}.json", k.file_stem())).exists(),
            "our entry must be removed"
        );
        assert_eq!(c.stats().corrupt, 0, "foreign files are tolerated, not corrupt");
        // Reopening sees the same world clear() left behind: no entries.
        let c = DecisionCache::open(&dir).unwrap();
        assert!(c.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
