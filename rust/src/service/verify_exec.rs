//! Pooled pattern-measurement execution: fan the independent pattern
//! measurements of one Step-3 search across sibling PJRT engines.
//!
//! The paper measures every offload pattern serially in the verification
//! environment, and the per-stage latency counters show that Step 3
//! dominates end-to-end wall time. The baseline and the phase-1
//! single-block patterns of one search are *independent* measurements
//! (see [`crate::coordinator::VerifyPlan`]), so the service can run them
//! concurrently — one per engine — and pay the wall-clock of the slowest
//! pattern instead of the sum of all patterns.
//!
//! Two sources of sibling engines exist:
//!
//! * the decision worker pool itself ([`super::pool`]): measurement
//!   sub-jobs are interleaved with decision jobs on the per-worker
//!   queues, so idle workers measure patterns for busy ones;
//! * a dedicated [`MeasurePool`] of measure-only workers, used by the
//!   CLI (`--verify-parallel N` on `fbo offload` / `fbo stages`) where
//!   no decision pool exists.
//!
//! Either way the executor returns results **index-aligned** with the
//! planned batch, so the reduced `SearchOutcome` — and therefore the
//! cached decision bytes — are identical to the serial executor's.
//!
//! ## Deadlock freedom
//!
//! Two pool workers can be inside the Verify stage at the same time and
//! fan patterns out *to each other*. While a worker waits for sibling
//! results it keeps servicing the measurement sub-jobs arriving on its
//! own queue (decision jobs are deferred, preserving their order), so a
//! cycle of mutually-waiting workers always makes progress. If a sibling
//! disappears without replying (service shutdown mid-search), the reply
//! channel disconnects and the remaining patterns are measured locally.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::verify::{self, MeasuredPattern, PatternSpec, VerifyContext};
use crate::coordinator::{PatternExecutor, VerifyConfig};
use crate::parser::Program;
use crate::runtime::Engine;
use crate::telemetry::{TraceEvent, TraceRecorder};
use crate::transform::PlannedReplacement;

/// One pattern-measurement sub-job shipped to a sibling worker. The
/// search context is `Arc`-shared across the batch (cloned once per
/// search, not once per pattern); everything is plain owned data, so the
/// job crosses threads even though the engines executing it never do.
pub(crate) struct MeasureJob {
    pub(crate) program: Arc<Program>,
    pub(crate) entry: Arc<str>,
    pub(crate) blocks: Arc<[PlannedReplacement]>,
    pub(crate) cfg: Arc<VerifyConfig>,
    pub(crate) spec: PatternSpec,
    pub(crate) index: usize,
    pub(crate) reply: mpsc::Sender<(usize, Result<MeasuredPattern>)>,
}

// MeasureJob must stay Send: it is the one value that crosses worker
// threads. (The engines and interpreters never do.)
#[allow(dead_code)]
fn assert_measure_job_is_send() {
    fn assert_send<T: Send>() {}
    assert_send::<MeasureJob>();
}

/// Execute one measurement sub-job on this thread's engine and reply.
/// A dropped reply receiver (the requesting search already finished or
/// fell back) is not an error.
pub(crate) fn run_measure_job(engine: &Rc<Engine>, job: MeasureJob) {
    let ctx = VerifyContext {
        prog: &job.program,
        entry: &job.entry,
        blocks: &job.blocks,
        cfg: &job.cfg,
        // Hints order dispatch on the requesting side; a sub-job is one
        // already-dealt spec, so they carry nothing here.
        cost_hints: &[],
    };
    let result = verify::measure_spec(&ctx, &job.spec, engine);
    let _ = job.reply.send((job.index, result));
}

/// What flows to a dedicated measure-only worker: jobs, or the explicit
/// shutdown marker. The marker is required because executors hold sender
/// clones that can outlive the [`MeasurePool`], so channel disconnect
/// alone cannot end the workers (joining on it would deadlock).
pub(crate) enum DedicatedMsg {
    /// One pattern measurement to run.
    Job(MeasureJob),
    /// Finish the queued jobs, then exit.
    Shutdown,
}

/// A sibling engine's inbox: either a decision worker's interleaved queue
/// or a dedicated measure-only worker.
#[derive(Clone)]
pub(crate) enum MeasureTx {
    /// A decision worker of the service pool (measure jobs interleave
    /// with decision jobs on its queue).
    Worker(mpsc::Sender<super::pool::WorkerMsg>),
    /// A measure-only worker of a [`MeasurePool`].
    Dedicated(mpsc::Sender<DedicatedMsg>),
}

impl MeasureTx {
    /// Send a job; hands it back if the sibling is gone so the caller can
    /// run it locally.
    fn send(&self, job: MeasureJob) -> std::result::Result<(), MeasureJob> {
        match self {
            MeasureTx::Worker(tx) => {
                tx.send(super::pool::WorkerMsg::Measure(job)).map_err(|e| match e.0 {
                    super::pool::WorkerMsg::Measure(j) => j,
                    _ => unreachable!("only measure jobs are sent through MeasureTx"),
                })
            }
            MeasureTx::Dedicated(tx) => tx.send(DedicatedMsg::Job(job)).map_err(|e| match e.0 {
                DedicatedMsg::Job(j) => j,
                DedicatedMsg::Shutdown => {
                    unreachable!("only measure jobs are sent through MeasureTx")
                }
            }),
        }
    }
}

/// Counters shared by every pooled executor of one service: how many
/// patterns were fanned out to a sibling engine vs measured inline on
/// the requesting thread. Feeds `StatsSnapshot`.
#[derive(Default)]
pub(crate) struct ExecStats {
    pub(crate) fanned_out: AtomicU64,
    pub(crate) local: AtomicU64,
}

/// Telemetry tap of one decision worker's executor: a shared cell names
/// the trace the worker is currently running a job for (0 = none), and
/// every measurement batch records one fan-out event under it. Strictly
/// passive — it observes how the batch was dealt, never changes it.
pub(crate) struct DispatchSink {
    pub(crate) recorder: Arc<TraceRecorder>,
    pub(crate) trace: Rc<Cell<u64>>,
}

impl DispatchSink {
    fn record(&self, fanned: u64, local: u64) {
        let trace = self.trace.get();
        if trace != 0 {
            self.recorder.record(trace, TraceEvent::MeasureDispatch { fanned, local });
        }
    }
}

/// A [`PatternExecutor`] that fans independent pattern measurements out
/// across sibling engines, keeping the requesting thread's engine busy
/// with its own share. Built by the service pool (one per decision
/// worker) or by [`MeasurePool::executor`] for CLI use. The executor
/// changes only how fast the batch measures — the reduced outcome is
/// byte-identical to the serial executor's.
pub struct PooledExecutor {
    engine: Rc<Engine>,
    siblings: Vec<MeasureTx>,
    max_inflight: usize,
    /// The owning decision worker's queue, serviced while waiting so
    /// mutually-fanning workers cannot deadlock. `None` outside the pool.
    queue: Option<Rc<RefCell<super::pool::WorkerQueue>>>,
    stats: Arc<ExecStats>,
    /// Trace tap for fan-out events. `None` outside the service pool.
    sink: Option<DispatchSink>,
}

impl PooledExecutor {
    pub(crate) fn new(
        engine: Rc<Engine>,
        siblings: Vec<MeasureTx>,
        max_inflight: usize,
        queue: Option<Rc<RefCell<super::pool::WorkerQueue>>>,
        stats: Arc<ExecStats>,
        sink: Option<DispatchSink>,
    ) -> PooledExecutor {
        PooledExecutor { engine, siblings, max_inflight, queue, stats, sink }
    }

    /// Patterns measured concurrently at most (the local engine plus the
    /// usable siblings), i.e. the effective `--verify-parallel`.
    pub fn width(&self) -> usize {
        if self.siblings.is_empty() {
            1
        } else {
            self.max_inflight.clamp(1, self.siblings.len() + 1)
        }
    }

    fn measure_local(
        &self,
        ctx: &VerifyContext<'_>,
        spec: &PatternSpec,
    ) -> Result<MeasuredPattern> {
        verify::measure_spec(ctx, spec, &self.engine)
    }
}

impl PatternExecutor for PooledExecutor {
    fn measure(
        &self,
        ctx: &VerifyContext<'_>,
        specs: &[PatternSpec],
    ) -> Vec<Result<MeasuredPattern>> {
        let n = specs.len();
        let width = self.width();
        if n <= 1 || width <= 1 {
            self.stats.local.fetch_add(n as u64, Ordering::Relaxed);
            if let Some(s) = &self.sink {
                s.record(0, n as u64);
            }
            return specs.iter().map(|s| self.measure_local(ctx, s)).collect();
        }

        // Deal the batch round-robin across (local engine, siblings…),
        // bounded by the configured width. Slot 0 stays local; a send to
        // a vanished sibling falls back to the local share. The search
        // context is cloned once for the whole batch and Arc-shared by
        // every job.
        let program = Arc::new(ctx.prog.clone());
        let entry: Arc<str> = Arc::from(ctx.entry);
        let blocks: Arc<[PlannedReplacement]> = ctx.blocks.to_vec().into();
        let cfg = Arc::new(ctx.cfg.clone());
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut local: VecDeque<usize> = VecDeque::new();
        let mut outstanding = 0usize;
        for (i, spec) in specs.iter().enumerate() {
            let slot = i % width;
            if slot == 0 {
                local.push_back(i);
                continue;
            }
            let job = MeasureJob {
                program: program.clone(),
                entry: entry.clone(),
                blocks: blocks.clone(),
                cfg: cfg.clone(),
                spec: spec.clone(),
                index: i,
                reply: reply_tx.clone(),
            };
            match self.siblings[slot - 1].send(job) {
                Ok(()) => outstanding += 1,
                Err(job) => local.push_back(job.index),
            }
        }
        drop(reply_tx);
        self.stats.fanned_out.fetch_add(outstanding as u64, Ordering::Relaxed);
        self.stats.local.fetch_add((n - outstanding) as u64, Ordering::Relaxed);
        let mut fanned = outstanding as u64;

        let mut results: Vec<Option<Result<MeasuredPattern>>> =
            specs.iter().map(|_| None).collect();
        let mut disconnected = false;
        loop {
            while let Ok((i, r)) = reply_rx.try_recv() {
                results[i] = Some(r);
                outstanding -= 1;
            }
            // Our own share first: the local engine is a full participant.
            if let Some(i) = local.pop_front() {
                results[i] = Some(self.measure_local(ctx, &specs[i]));
                continue;
            }
            if outstanding == 0 {
                break;
            }
            // While waiting on siblings, service the measurement sub-jobs
            // arriving on our own queue (decision jobs are deferred) —
            // the progress guarantee that makes mutual fan-out safe. The
            // short timeout exists only to re-poll that queue; without
            // one (the dedicated MeasurePool path) block outright.
            if let Some(q) = &self.queue {
                let sub = q.borrow_mut().try_measure();
                if let Some(job) = sub {
                    run_measure_job(&self.engine, job);
                    continue;
                }
                match reply_rx.recv_timeout(Duration::from_millis(1)) {
                    Ok((i, r)) => {
                        results[i] = Some(r);
                        outstanding -= 1;
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            } else {
                match reply_rx.recv() {
                    Ok((i, r)) => {
                        results[i] = Some(r);
                        outstanding -= 1;
                    }
                    Err(_) => {
                        disconnected = true;
                        break;
                    }
                }
            }
        }
        if disconnected {
            // A sibling shut down without replying: measure whatever is
            // still missing on the local engine — slower, never wrong —
            // and move those patterns from the fanned-out counter to the
            // local one so the stats report what actually happened.
            for (i, slot) in results.iter_mut().enumerate() {
                if slot.is_none() {
                    *slot = Some(self.measure_local(ctx, &specs[i]));
                    self.stats.fanned_out.fetch_sub(1, Ordering::Relaxed);
                    self.stats.local.fetch_add(1, Ordering::Relaxed);
                    fanned -= 1;
                }
            }
        }
        if let Some(s) = &self.sink {
            s.record(fanned, n as u64 - fanned);
        }
        results.into_iter().map(|r| r.expect("every planned pattern has a result")).collect()
    }

    fn name(&self) -> &'static str {
        "pooled"
    }
}

/// A pool of measure-only workers, each owning its own PJRT engine over
/// the same artifact directory — the sibling source for CLI runs
/// (`--verify-parallel N` on `fbo offload` / `fbo stages`), where no
/// decision worker pool exists. Workers exit when the pool (and every
/// executor built from it) is dropped.
pub struct MeasurePool {
    txs: Vec<mpsc::Sender<DedicatedMsg>>,
    workers: Vec<JoinHandle<()>>,
}

impl MeasurePool {
    /// Start `workers` measure-only workers over an artifact directory.
    /// Blocks until every worker has opened its engine, so artifact
    /// problems surface here.
    pub fn start(artifacts: &Path, workers: usize) -> Result<MeasurePool> {
        if workers == 0 {
            bail!("measure pool needs at least one worker");
        }
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, rx) = mpsc::channel::<DedicatedMsg>();
            txs.push(tx);
            let dir: PathBuf = artifacts.to_path_buf();
            let ready = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("fbo-measure-{i}"))
                .spawn(move || measure_worker_main(dir, rx, ready))
                .context("spawning measure worker")?;
            handles.push(handle);
        }
        drop(ready_tx);
        let mut pool = MeasurePool { txs, workers: handles };
        for _ in 0..workers {
            let started = ready_rx
                .recv()
                .map_err(|_| anyhow!("measure worker died during startup"))
                .and_then(|r| r.context("measure worker startup"));
            if let Err(e) = started {
                pool.stop();
                return Err(e);
            }
        }
        Ok(pool)
    }

    /// Number of measure workers in the pool.
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Build a pooled executor fanning out to this pool's workers, with
    /// `engine` as the requesting thread's local engine. `max_inflight`
    /// caps concurrently measured patterns (local engine included).
    pub fn executor(&self, engine: Rc<Engine>, max_inflight: usize) -> PooledExecutor {
        PooledExecutor::new(
            engine,
            self.txs.iter().cloned().map(MeasureTx::Dedicated).collect(),
            max_inflight,
            None,
            Arc::new(ExecStats::default()),
            None,
        )
    }

    fn stop(&mut self) {
        // Executors hold clones of these senders and can outlive the
        // pool, so waiting for channel disconnect would deadlock the
        // join: tell each worker to exit explicitly (queued jobs drain
        // first — the marker sits behind them in FIFO order).
        for tx in self.txs.drain(..) {
            let _ = tx.send(DedicatedMsg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for MeasurePool {
    fn drop(&mut self) {
        self.stop();
    }
}

fn measure_worker_main(
    artifacts: PathBuf,
    rx: mpsc::Receiver<DedicatedMsg>,
    ready: mpsc::Sender<Result<()>>,
) {
    // Built on this thread, never crosses it (PJRT state is not Send).
    let engine = match Engine::open(&artifacts) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(msg) = rx.recv() {
        match msg {
            DedicatedMsg::Job(job) => run_measure_job(&engine, job),
            DedicatedMsg::Shutdown => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_worker_pool_rejected() {
        assert!(MeasurePool::start(Path::new("artifacts"), 0).is_err());
    }

    #[test]
    fn missing_artifacts_fail_pool_startup() {
        let err = match MeasurePool::start(Path::new("/nonexistent/fbo-artifacts"), 2) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("startup must fail without artifacts"),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn width_is_bounded_by_siblings_and_cap() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let pool = MeasurePool::start(&dir, 3).unwrap();
        let engine = Engine::open(&dir).unwrap();
        assert_eq!(pool.executor(engine.clone(), 2).width(), 2, "cap below pool size");
        assert_eq!(pool.executor(engine.clone(), 16).width(), 4, "pool size + local engine");
        assert_eq!(pool.executor(engine, 1).width(), 1);
    }
}
