//! Step-3 transformation: replace function blocks and reconcile interfaces.
//!
//! * **C-1** — a matched library call site is redirected to the external
//!   dispatch name `__fb_<artifact>`; signatures match by construction
//!   (the DB registered both sides), so only the glue is generated.
//! * **C-2** — a similarity-matched *local* function has its body replaced
//!   with a call to the external dispatch, so every existing call site
//!   flows through the replacement. Because similarity matching gives no
//!   interface guarantee, [`reconcile`] compares signatures first:
//!   float/double mismatches auto-cast, droppable optional parameters are
//!   dropped silently, anything else requires user confirmation through an
//!   [`InterfacePolicy`] (the paper asks the offload requester).
//!
//! The host glue itself ([`glue`]) interprets the DB usage recipe and
//! bridges interpreter values ↔ PJRT buffers.

pub mod glue;

use anyhow::{bail, Result};

use crate::parser::ast::*;
use crate::parser::{FuncDef, Program};
use crate::patterndb::{Replacement, Signature};

/// External dispatch name for a replacement artifact.
pub fn dispatch_name(artifact: &str) -> String {
    format!("__fb_{artifact}")
}

/// How interface-change confirmations are answered (paper: ask the user).
#[derive(Debug, Clone)]
pub enum InterfacePolicy {
    /// Approve every interface adaptation (batch/CI mode).
    AutoApprove,
    /// Reject everything that is not automatic (strict mode).
    AutoReject,
    /// Scripted answers, consumed in order; falls back to reject.
    Scripted(Vec<bool>),
}

impl InterfacePolicy {
    fn ask(&mut self, _question: &str) -> bool {
        match self {
            InterfacePolicy::AutoApprove => true,
            InterfacePolicy::AutoReject => false,
            InterfacePolicy::Scripted(answers) => {
                if answers.is_empty() {
                    false
                } else {
                    answers.remove(0)
                }
            }
        }
    }
}

/// Outcome of reconciling one block's interface (C-1 / C-2).
#[derive(Debug, Clone, PartialEq)]
pub enum Reconciliation {
    /// Interfaces agree exactly — C-1 path, no user involvement.
    Exact,
    /// Only float↔double casts needed — automatic (paper: "may proceed
    /// without user confirmation").
    AutoCast,
    /// Caller has extra *optional* parameters that are dropped — automatic.
    DropOptional(Vec<usize>),
    /// Structural change confirmed by the user.
    Confirmed(String),
    /// User declined / policy rejected — block is not offloaded.
    Rejected(String),
}

impl Reconciliation {
    /// True when the reconciliation did not reject the block.
    pub fn accepted(&self) -> bool {
        !matches!(self, Reconciliation::Rejected(_))
    }

    /// Caller-argument indices to keep, given the caller arity.
    pub fn kept_args(&self, caller_arity: usize) -> Vec<usize> {
        match self {
            Reconciliation::DropOptional(dropped) => {
                (0..caller_arity).filter(|i| !dropped.contains(i)).collect()
            }
            _ => (0..caller_arity).collect(),
        }
    }
}

fn base_scalar(ty: &str) -> &str {
    ty.trim_end_matches("[]").trim_end_matches('*')
}

fn is_array(ty: &str) -> bool {
    ty.ends_with("[]") || ty.ends_with('*')
}

fn types_compatible(a: &str, b: &str) -> bool {
    if is_array(a) != is_array(b) {
        return false;
    }
    let (sa, sb) = (base_scalar(a), base_scalar(b));
    let float_like = |s: &str| matches!(s, "float" | "double");
    let int_like = |s: &str| matches!(s, "int" | "long" | "char");
    sa == sb || (float_like(sa) && float_like(sb)) || (int_like(sa) && int_like(sb))
}

/// Compare a caller-side signature against the replacement's (C-2 core).
pub fn reconcile(
    caller: &Signature,
    replacement: &Signature,
    policy: &mut InterfacePolicy,
) -> Reconciliation {
    // Case 1: arities equal — check types positionally.
    if caller.params.len() == replacement.params.len() {
        let mut needs_cast = false;
        for (c, r) in caller.params.iter().zip(&replacement.params) {
            if c.ty == r.ty {
                continue;
            }
            if types_compatible(&c.ty, &r.ty) {
                needs_cast = true;
            } else {
                let q = format!(
                    "parameter {:?} has type {} but the replacement expects {} — adapt?",
                    c.name, c.ty, r.ty
                );
                return if policy.ask(&q) {
                    Reconciliation::Confirmed(q)
                } else {
                    Reconciliation::Rejected(q)
                };
            }
        }
        return if needs_cast { Reconciliation::AutoCast } else { Reconciliation::Exact };
    }

    // Case 2: caller has MORE params — drop trailing ones. Optional-marked
    // extras with a matching required prefix drop silently (paper: "may be
    // treated as absent without asking"); otherwise the user is asked, and
    // on approval the extras are still dropped (the adaptation the user
    // just approved).
    if caller.params.len() > replacement.params.len() {
        let extra: Vec<usize> = (replacement.params.len()..caller.params.len()).collect();
        let all_extra_optional = extra.iter().all(|&i| caller.params[i].optional);
        let prefix_ok = caller.params[..replacement.params.len()]
            .iter()
            .zip(&replacement.params)
            .all(|(c, r)| types_compatible(&c.ty, &r.ty));
        if all_extra_optional && prefix_ok {
            return Reconciliation::DropOptional(extra);
        }
        let q = format!(
            "caller has {} parameters, replacement takes {} — drop extras?",
            caller.params.len(),
            replacement.params.len()
        );
        return if policy.ask(&q) {
            Reconciliation::DropOptional(extra)
        } else {
            Reconciliation::Rejected(q)
        };
    }

    // Case 3: caller has FEWER params than the replacement requires; our
    // glue cannot synthesize missing required arguments, so the block is
    // not offloadable (the paper would ask the user to change the caller —
    // out of scope for automatic transformation).
    Reconciliation::Rejected(format!(
        "caller supplies {} arguments but replacement requires {}",
        caller.params.len(),
        replacement.required_count()
    ))
}

/// Extract the declared signature of an AST function (C-2 caller side).
pub fn signature_of(f: &FuncDef) -> Signature {
    Signature {
        params: f
            .params
            .iter()
            .map(|p| crate::patterndb::ParamSpec {
                name: p.name.clone(),
                ty: type_string(&p.ty, p.array_dims),
                optional: false,
            })
            .collect(),
        ret: type_string(&f.ret, 0),
    }
}

fn type_string(ty: &Ty, array_dims: usize) -> String {
    let base = match ty {
        Ty::Base(b) => b.name().to_string(),
        Ty::Struct(n) => format!("struct {n}"),
        Ty::Ptr(inner) => return format!("{}[]", type_string(inner, 0).trim_end_matches("[]")),
    };
    if array_dims > 0 {
        format!("{base}{}", "[]".repeat(array_dims).replace("[][]", "[]"))
    } else {
        base
    }
}

/// One planned block replacement.
#[derive(Debug, Clone)]
pub struct PlannedReplacement {
    /// Where the block lives.
    pub site: Site,
    /// The accelerator implementation to install.
    pub replacement: Replacement,
    /// How the interfaces were reconciled.
    pub reconciliation: Reconciliation,
}

/// Replacement site: a call expression (C-1) or a defined function (C-2).
#[derive(Debug, Clone, PartialEq)]
pub enum Site {
    /// All call sites to this external library name.
    LibraryCall { callee: String },
    /// The body of this locally defined function.
    FunctionBody { function: String },
}

impl Site {
    /// Short label (`call:{name}` / `func:{name}`) for reports.
    pub fn label(&self) -> String {
        match self {
            Site::LibraryCall { callee } => format!("call:{callee}"),
            Site::FunctionBody { function } => format!("func:{function}"),
        }
    }
}

/// Apply a set of planned replacements to a program, producing the
/// transformed AST (the paper's generated execution file).
pub fn apply(prog: &Program, plans: &[PlannedReplacement]) -> Result<Program> {
    let mut out = prog.clone();
    for plan in plans {
        if !plan.reconciliation.accepted() {
            continue;
        }
        match &plan.site {
            Site::LibraryCall { callee } => {
                let target = dispatch_name(&plan.replacement.artifact);
                let mut replaced = 0usize;
                for item in &mut out.items {
                    if let Item::Func(f) = item {
                        if let Some(body) = &mut f.body {
                            replaced += rewrite_calls(body, callee, &target, &plan.reconciliation);
                        }
                    }
                }
                if replaced == 0 {
                    bail!("no call sites of {callee:?} found to replace");
                }
            }
            Site::FunctionBody { function } => {
                let target = dispatch_name(&plan.replacement.artifact);
                let f = out
                    .items
                    .iter_mut()
                    .find_map(|i| match i {
                        Item::Func(f) if &f.name == function => Some(f),
                        _ => None,
                    })
                    .ok_or_else(|| anyhow::anyhow!("function {function:?} not found"))?;
                replace_body_with_dispatch(f, &target, &plan.reconciliation);
            }
        }
    }
    Ok(out)
}

/// Rewrite `callee(args...)` to `target(kept args...)` everywhere under `s`.
fn rewrite_calls(s: &mut Stmt, callee: &str, target: &str, rec: &Reconciliation) -> usize {
    let mut n = 0;
    rewrite_stmt_exprs(s, &mut |e| {
        if let ExprKind::Call(name, args) = &mut e.kind {
            if name == callee {
                let keep = rec.kept_args(args.len());
                if keep.len() != args.len() {
                    let mut kept = Vec::with_capacity(keep.len());
                    for (i, a) in args.drain(..).enumerate() {
                        if keep.contains(&i) {
                            kept.push(a);
                        }
                    }
                    *args = kept;
                }
                *name = target.to_string();
                n += 1;
            }
        }
    });
    n
}

/// Replace a function's body with a single dispatch call forwarding its
/// (kept) parameters.
fn replace_body_with_dispatch(f: &mut FuncDef, target: &str, rec: &Reconciliation) {
    let keep = rec.kept_args(f.params.len());
    let args: Vec<Expr> = keep
        .iter()
        .map(|&i| Expr {
            id: NodeId(u32::MAX - i as u32),
            span: f.span,
            kind: ExprKind::Ident(f.params[i].name.clone()),
        })
        .collect();
    let call = Expr {
        id: NodeId(u32::MAX - 1000),
        span: f.span,
        kind: ExprKind::Call(target.to_string(), args),
    };
    let body = Stmt {
        id: NodeId(u32::MAX - 1001),
        span: f.span,
        kind: StmtKind::Block(vec![Stmt {
            id: NodeId(u32::MAX - 1002),
            span: f.span,
            kind: StmtKind::Expr(call),
        }]),
    };
    f.body = Some(body);
}

/// Visit every expression (mutably) under a statement.
fn rewrite_stmt_exprs(s: &mut Stmt, f: &mut impl FnMut(&mut Expr)) {
    fn expr_walk(e: &mut Expr, f: &mut impl FnMut(&mut Expr)) {
        f(e);
        match &mut e.kind {
            ExprKind::Binary(_, a, b) | ExprKind::Assign(_, a, b) => {
                expr_walk(a, f);
                expr_walk(b, f);
            }
            ExprKind::Unary(_, a)
            | ExprKind::PostIncDec(a, _)
            | ExprKind::Cast(_, a)
            | ExprKind::Member(a, _) => expr_walk(a, f),
            ExprKind::Ternary(c, t, e2) => {
                expr_walk(c, f);
                expr_walk(t, f);
                expr_walk(e2, f);
            }
            ExprKind::Call(_, args) => {
                for a in args {
                    expr_walk(a, f);
                }
            }
            ExprKind::Index(a, i) => {
                expr_walk(a, f);
                expr_walk(i, f);
            }
            _ => {}
        }
    }
    match &mut s.kind {
        StmtKind::Block(stmts) => {
            for st in stmts {
                rewrite_stmt_exprs(st, f);
            }
        }
        StmtKind::Decl(decls) => {
            for d in decls {
                for dim in &mut d.dims {
                    expr_walk(dim, f);
                }
                if let Some(init) = &mut d.init {
                    expr_walk(init, f);
                }
            }
        }
        StmtKind::Expr(e) => expr_walk(e, f),
        StmtKind::If(c, t, e) => {
            expr_walk(c, f);
            rewrite_stmt_exprs(t, f);
            if let Some(e) = e {
                rewrite_stmt_exprs(e, f);
            }
        }
        StmtKind::For { init, cond, step, body } => {
            if let Some(i) = init {
                rewrite_stmt_exprs(i, f);
            }
            if let Some(c) = cond {
                expr_walk(c, f);
            }
            if let Some(st) = step {
                expr_walk(st, f);
            }
            rewrite_stmt_exprs(body, f);
        }
        StmtKind::While(c, b) => {
            expr_walk(c, f);
            rewrite_stmt_exprs(b, f);
        }
        StmtKind::DoWhile(b, c) => {
            rewrite_stmt_exprs(b, f);
            expr_walk(c, f);
        }
        StmtKind::Return(Some(e)) => expr_walk(e, f),
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parser::parse;
    use crate::patterndb::{PatternDb, Signature};

    fn sig(params: &[(&str, &str)], ret: &str) -> Signature {
        Signature::new(params, ret)
    }

    #[test]
    fn exact_signatures_are_c1() {
        let s = sig(&[("a", "double[]"), ("n", "int")], "void");
        let mut p = InterfacePolicy::AutoReject;
        assert_eq!(reconcile(&s, &s.clone(), &mut p), Reconciliation::Exact);
    }

    #[test]
    fn float_double_auto_casts_without_confirmation() {
        let caller = sig(&[("a", "float[]"), ("n", "int")], "void");
        let repl = sig(&[("a", "double[]"), ("n", "int")], "void");
        // AutoReject policy: if this asked the user, it would be Rejected.
        let mut p = InterfacePolicy::AutoReject;
        assert_eq!(reconcile(&caller, &repl, &mut p), Reconciliation::AutoCast);
    }

    #[test]
    fn optional_extras_dropped_silently() {
        let caller = sig(&[("a", "double[]"), ("n", "int"), ("work", "double[]")], "void")
            .with_optional("work");
        let repl = sig(&[("a", "double[]"), ("n", "int")], "void");
        let mut p = InterfacePolicy::AutoReject;
        let r = reconcile(&caller, &repl, &mut p);
        assert_eq!(r, Reconciliation::DropOptional(vec![2]));
        assert_eq!(r.kept_args(3), vec![0, 1]);
    }

    #[test]
    fn structural_mismatch_requires_confirmation() {
        let caller = sig(&[("a", "double[]"), ("flag", "double[]")], "void");
        let repl = sig(&[("a", "double[]"), ("n", "int")], "void");
        let mut yes = InterfacePolicy::AutoApprove;
        assert!(matches!(reconcile(&caller, &repl, &mut yes), Reconciliation::Confirmed(_)));
        let mut no = InterfacePolicy::AutoReject;
        assert!(matches!(reconcile(&caller, &repl, &mut no), Reconciliation::Rejected(_)));
    }

    #[test]
    fn scripted_policy_consumes_answers() {
        let caller = sig(&[("a", "double[]"), ("b", "double[]")], "void");
        let repl = sig(&[("a", "double[]"), ("n", "int")], "void");
        let mut p = InterfacePolicy::Scripted(vec![true, false]);
        assert!(matches!(reconcile(&caller, &repl, &mut p), Reconciliation::Confirmed(_)));
        assert!(matches!(reconcile(&caller, &repl, &mut p), Reconciliation::Rejected(_)));
        // Exhausted script rejects.
        assert!(matches!(reconcile(&caller, &repl, &mut p), Reconciliation::Rejected(_)));
    }

    #[test]
    fn confirmed_arity_mismatch_drops_extras() {
        let caller = sig(&[("a", "double[]"), ("n", "int"), ("dbg", "int")], "void");
        let repl = sig(&[("a", "double[]"), ("n", "int")], "void");
        let mut p = InterfacePolicy::AutoApprove;
        let r = reconcile(&caller, &repl, &mut p);
        assert_eq!(r, Reconciliation::DropOptional(vec![2]));
        let mut p = InterfacePolicy::AutoReject;
        assert!(matches!(reconcile(&caller, &repl, &mut p), Reconciliation::Rejected(_)));
    }

    #[test]
    fn too_few_args_rejected() {
        let caller = sig(&[("a", "double[]")], "void");
        let repl = sig(&[("a", "double[]"), ("n", "int")], "void");
        let mut p = InterfacePolicy::AutoApprove;
        assert!(matches!(reconcile(&caller, &repl, &mut p), Reconciliation::Rejected(_)));
    }

    const APP: &str = "
        void fft2d(double re[], double im[], int n);
        int main() {
            double re[16][16]; double im[16][16];
            fft2d(re, im, 16);
            fft2d(im, re, 16);
            return 0;
        }";

    #[test]
    fn c1_call_rewrite_redirects_all_sites() {
        let prog = parse(APP).unwrap();
        let db = PatternDb::builtin();
        let rec = db.find_library("fft2d").unwrap();
        let plan = PlannedReplacement {
            site: Site::LibraryCall { callee: "fft2d".into() },
            replacement: rec.replacement.clone(),
            reconciliation: Reconciliation::Exact,
        };
        let out = apply(&prog, &[plan]).unwrap();
        let printed = crate::parser::print_program(&out);
        assert!(printed.contains("__fb_fft2d(re, im, 16)"));
        assert!(printed.contains("__fb_fft2d(im, re, 16)"));
        assert!(!printed.contains(" fft2d(re"));
    }

    #[test]
    fn c2_body_replacement_forwards_params() {
        let prog = parse(
            "void my_decomp(double a[], int n) {
                for (int k = 0; k < n; k++) a[k] = a[k] + 1.0;
             }
             int main() { double a[4]; my_decomp(a, 2); return 0; }",
        )
        .unwrap();
        let db = PatternDb::builtin();
        let rec = &db.comparisons[1]; // nr-ludcmp
        let plan = PlannedReplacement {
            site: Site::FunctionBody { function: "my_decomp".into() },
            replacement: rec.replacement.clone(),
            reconciliation: Reconciliation::Exact,
        };
        let out = apply(&prog, &[plan]).unwrap();
        let printed = crate::parser::print_program(&out);
        assert!(
            printed.contains("void my_decomp(double a[], int n) {\n    __fb_lu_factor(a, n);\n}"),
            "printed:\n{printed}"
        );
    }

    #[test]
    fn rejected_plan_is_a_noop() {
        let prog = parse(APP).unwrap();
        let db = PatternDb::builtin();
        let plan = PlannedReplacement {
            site: Site::LibraryCall { callee: "fft2d".into() },
            replacement: db.find_library("fft2d").unwrap().replacement.clone(),
            reconciliation: Reconciliation::Rejected("user said no".into()),
        };
        let out = apply(&prog, &[plan]).unwrap();
        assert_eq!(crate::parser::print_program(&out), crate::parser::print_program(&prog));
    }

    #[test]
    fn signature_extraction() {
        let prog = parse("double solve(double a[], int n, float tol) { return 0.0; }").unwrap();
        let f = prog.find_function("solve").unwrap();
        let s = signature_of(f);
        assert_eq!(s.params[0].ty, "double[]");
        assert_eq!(s.params[1].ty, "int");
        assert_eq!(s.params[2].ty, "float");
        assert_eq!(s.ret, "double");
    }

    #[test]
    fn missing_call_site_errors() {
        let prog = parse("int main() { return 0; }").unwrap();
        let db = PatternDb::builtin();
        let plan = PlannedReplacement {
            site: Site::LibraryCall { callee: "fft2d".into() },
            replacement: db.find_library("fft2d").unwrap().replacement.clone(),
            reconciliation: Reconciliation::Exact,
        };
        assert!(apply(&prog, &[plan]).is_err());
    }
}
