//! Host-side glue: bridge interpreter values ↔ PJRT artifact buffers.
//!
//! The pattern DB registers a **usage recipe** with every replacement (the
//! paper: "usage methods are also registered" with the executable). The
//! recipe is a `;`-separated list of tokens over the replacement-signature
//! parameter names:
//!
//! ```text
//! in:a:n*n      read-only buffer argument `a`, length n*n
//! inout:b:n*m   buffer copied to the device and written back
//! out:c:n*n     output-only buffer (contents replaced)
//! size:n        scalar that selects the artifact size variant
//! ```
//!
//! Artifact inputs are fed in token order (`in`/`inout`), artifact outputs
//! map back onto `inout`/`out` tokens in order — mirroring how cuFFT/cuBLAS
//! host code stages device buffers around a library call.

use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::interp::eval::ExternalFn;
use crate::interp::Value;
use crate::patterndb::Replacement;
use crate::runtime::Engine;

/// Buffer transfer mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Host -> device only.
    In,
    /// Host -> device and back.
    InOut,
    /// Device -> host only.
    Out,
}

/// One buffer binding in a usage recipe.
#[derive(Debug, Clone)]
pub struct BufSpec {
    /// Transfer direction.
    pub mode: Mode,
    /// Signature parameter the buffer binds to.
    pub param: String,
    /// Length expression: product of scalar-param names / integer literals.
    pub len_factors: Vec<String>,
}

/// Parsed usage recipe.
#[derive(Debug, Clone)]
pub struct UsageSpec {
    /// Buffer bindings, in artifact input order.
    pub bufs: Vec<BufSpec>,
    /// Scalar parameter holding the problem size `n`.
    pub size_param: String,
}

impl UsageSpec {
    /// Parse a `mode:param:len;...;size:param` recipe string.
    pub fn parse(usage: &str) -> Result<Self> {
        let mut bufs = Vec::new();
        let mut size_param = None;
        for token in usage.split(';').filter(|t| !t.is_empty()) {
            let parts: Vec<&str> = token.split(':').collect();
            match parts.as_slice() {
                ["size", name] => size_param = Some(name.to_string()),
                [mode, name, len] => {
                    let mode = match *mode {
                        "in" => Mode::In,
                        "inout" => Mode::InOut,
                        "out" => Mode::Out,
                        other => bail!("unknown usage mode {other:?}"),
                    };
                    bufs.push(BufSpec {
                        mode,
                        param: name.to_string(),
                        len_factors: len.split('*').map(|s| s.trim().to_string()).collect(),
                    });
                }
                other => bail!("malformed usage token {other:?}"),
            }
        }
        Ok(UsageSpec {
            bufs,
            size_param: size_param.ok_or_else(|| anyhow!("usage recipe missing size:<param>"))?,
        })
    }
}

/// Build the external dispatch function for one replacement.
///
/// The returned closure is installed into the interpreter under
/// [`super::dispatch_name`]; at call time its arguments correspond
/// positionally to the replacement signature.
pub fn build_external(engine: Rc<Engine>, repl: &Replacement) -> Result<ExternalFn> {
    let usage = UsageSpec::parse(&repl.usage)?;
    let params: Vec<String> = repl.signature.params.iter().map(|p| p.name.clone()).collect();
    let artifact_base = repl.artifact.clone();
    let label = repl.name.clone();

    Ok(Rc::new(move |args: &[Value]| -> Result<Value> {
        if args.len() != params.len() {
            bail!(
                "{label}: dispatch expected {} args ({}), got {}",
                params.len(),
                params.join(", "),
                args.len()
            );
        }
        let arg_of = |name: &str| -> Result<&Value> {
            let i = params
                .iter()
                .position(|p| p == name)
                .ok_or_else(|| anyhow!("{label}: usage references unknown param {name:?}"))?;
            Ok(&args[i])
        };
        // Scalars for length expressions + size selection.
        let scalar = |name: &str| -> Result<i64> { arg_of(name)?.as_int() };

        let n = scalar(&usage.size_param)? as usize;
        let artifact = engine.sized_artifact_name(&artifact_base, n)?;

        let eval_len = |factors: &[String]| -> Result<usize> {
            let mut len = 1usize;
            for f in factors {
                let v = if let Ok(c) = f.parse::<usize>() { c } else { scalar(f)? as usize };
                len = len
                    .checked_mul(v)
                    .ok_or_else(|| anyhow!("{label}: length overflow in usage recipe"))?;
            }
            Ok(len)
        };

        // Stage inputs (token order == artifact input order).
        let mut inputs = Vec::new();
        for b in usage.bufs.iter().filter(|b| b.mode != Mode::Out) {
            let v = arg_of(&b.param)?;
            let slice = v.as_arr().map_err(|_| {
                anyhow!("{label}: argument {:?} must be an array", b.param)
            })?;
            let want = eval_len(&b.len_factors)?;
            if slice.len() != want {
                bail!(
                    "{label}: buffer {:?} has {} elements, usage expects {}",
                    b.param,
                    slice.len(),
                    want
                );
            }
            inputs.push(slice.to_vec_f32());
        }

        let outputs = engine.execute(&artifact, &inputs)?;

        // Write outputs back (inout + out tokens, in order).
        let out_bufs: Vec<&BufSpec> =
            usage.bufs.iter().filter(|b| b.mode != Mode::In).collect();
        if outputs.len() != out_bufs.len() {
            bail!(
                "{label}: artifact produced {} outputs, usage expects {}",
                outputs.len(),
                out_bufs.len()
            );
        }
        for (out, spec) in outputs.iter().zip(out_bufs) {
            let slice = arg_of(&spec.param)?.as_arr()?;
            slice.copy_from_f32(out)?;
        }
        Ok(Value::Void)
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::Slice;
    use crate::patterndb::PatternDb;
    use std::path::PathBuf;

    fn engine() -> Rc<Engine> {
        Engine::open(&PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")).unwrap()
    }

    #[test]
    fn usage_parsing() {
        let u = UsageSpec::parse("in:a:n*n;inout:b:n*8;size:n").unwrap();
        assert_eq!(u.bufs.len(), 2);
        assert_eq!(u.bufs[0].mode, Mode::In);
        assert_eq!(u.bufs[1].mode, Mode::InOut);
        assert_eq!(u.bufs[1].len_factors, vec!["n", "8"]);
        assert_eq!(u.size_param, "n");
        assert!(UsageSpec::parse("in:a:n").is_err()); // no size
        assert!(UsageSpec::parse("bad:a:n;size:n").is_err());
    }

    #[test]
    fn fft_dispatch_roundtrip() {
        let db = PatternDb::builtin();
        let repl = &db.find_library("fft2d").unwrap().replacement;
        let f = build_external(engine(), repl).unwrap();
        let n = 64usize;
        // Impulse at origin.
        let re = Slice::zeros(&[n, n], false);
        re.set(0, 1.0).unwrap();
        let im = Slice::zeros(&[n, n], false);
        f(&[Value::Arr(re.clone()), Value::Arr(im.clone()), Value::Int(n as i64)]).unwrap();
        // Spectrum of an impulse is all-ones.
        for i in 0..n * n {
            assert!((re.get(i).unwrap() - 1.0).abs() < 1e-3);
            assert!(im.get(i).unwrap().abs() < 1e-3);
        }
    }

    #[test]
    fn lu_dispatch_roundtrip() {
        let db = PatternDb::builtin();
        let repl = &db.find_library("ludcmp").unwrap().replacement;
        let f = build_external(engine(), repl).unwrap();
        let n = 64usize;
        let a = Slice::zeros(&[n * n], false);
        for i in 0..n {
            for j in 0..n {
                a.set(i * n + j, if i == j { n as f64 } else { 0.5 }).unwrap();
            }
        }
        let orig = a.to_vec();
        f(&[Value::Arr(a.clone()), Value::Int(n as i64)]).unwrap();
        // Verify L@U == A on a few entries.
        let lu = a.to_vec();
        let l = |i: usize, k: usize| {
            if k < i { lu[i * n + k] } else if k == i { 1.0 } else { 0.0 }
        };
        let u = |k: usize, j: usize| if k <= j { lu[k * n + j] } else { 0.0 };
        for &(i, j) in &[(0, 0), (5, 3), (3, 5), (63, 63), (17, 40)] {
            let mut s = 0.0;
            for k in 0..n {
                s += l(i, k) * u(k, j);
            }
            assert!((s - orig[i * n + j]).abs() < 1e-2, "({i},{j}): {s} vs {}", orig[i * n + j]);
        }
    }

    #[test]
    fn wrong_buffer_length_is_an_error() {
        let db = PatternDb::builtin();
        let repl = &db.find_library("fft2d").unwrap().replacement;
        let f = build_external(engine(), repl).unwrap();
        let re = Slice::zeros(&[16], false);
        let im = Slice::zeros(&[16], false);
        let err = f(&[Value::Arr(re), Value::Arr(im), Value::Int(64)]).unwrap_err();
        assert!(err.to_string().contains("elements"), "{err}");
    }

    #[test]
    fn missing_size_variant_is_an_error() {
        let db = PatternDb::builtin();
        let repl = &db.find_library("fft2d").unwrap().replacement;
        let f = build_external(engine(), repl).unwrap();
        let re = Slice::zeros(&[9], false);
        let im = Slice::zeros(&[9], false);
        assert!(f(&[Value::Arr(re), Value::Arr(im), Value::Int(3)]).is_err());
    }

    #[test]
    fn wrong_arity_is_an_error() {
        let db = PatternDb::builtin();
        let repl = &db.find_library("fft2d").unwrap().replacement;
        let f = build_external(engine(), repl).unwrap();
        assert!(f(&[Value::Int(3)]).is_err());
    }
}
