//! Pretty-printer for the mini-C AST.
//!
//! Used to (a) show users the transformed source after function-block
//! replacement (the paper's Step 3 emits modified C code), and (b) close
//! the parse∘print round-trip property the parser tests rely on.

use super::ast::*;
use std::fmt::Write;

/// Render a whole program.
pub fn print_program(p: &Program) -> String {
    let mut out = String::new();
    for inc in &p.includes {
        let _ = writeln!(out, "#include <{inc}>");
    }
    for item in &p.items {
        match item {
            Item::Struct(s) => print_struct(&mut out, s),
            Item::Func(f) => print_func(&mut out, f),
            Item::Global(decls) => {
                let mut line = String::new();
                print_decls(&mut line, decls);
                let _ = writeln!(out, "{line}");
            }
        }
        out.push('\n');
    }
    out
}

fn print_struct(out: &mut String, s: &StructDef) {
    let _ = writeln!(out, "struct {} {{", s.name);
    for f in &s.fields {
        let mut dims = String::new();
        for d in &f.dims {
            let _ = write!(dims, "[{}]", print_expr(d));
        }
        let _ = writeln!(out, "    {} {}{};", f.ty, f.name, dims);
    }
    let _ = writeln!(out, "}};");
}

fn print_func(out: &mut String, f: &FuncDef) {
    let params: Vec<String> = f
        .params
        .iter()
        .map(|p| {
            let arr = "[]".repeat(p.array_dims);
            format!("{} {}{arr}", p.ty, p.name)
        })
        .collect();
    let _ = write!(out, "{} {}({})", f.ret, f.name, params.join(", "));
    match &f.body {
        None => {
            let _ = writeln!(out, ";");
        }
        Some(body) => {
            out.push(' ');
            print_stmt(out, body, 0);
        }
    }
}

fn indent(out: &mut String, level: usize) {
    for _ in 0..level {
        out.push_str("    ");
    }
}

fn print_decls(out: &mut String, decls: &[VarDecl]) {
    // A decl statement shares one base type; print comma-joined.
    let first = &decls[0];
    let _ = write!(out, "{} ", first.ty);
    for (i, d) in decls.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(out, "{}", d.name);
        for dim in &d.dims {
            let _ = write!(out, "[{}]", print_expr(dim));
        }
        if let Some(init) = &d.init {
            let _ = write!(out, " = {}", print_expr(init));
        }
    }
    out.push(';');
}

/// Print one statement at the given indent level.
pub fn print_stmt(out: &mut String, s: &Stmt, level: usize) {
    match &s.kind {
        StmtKind::Block(stmts) => {
            out.push_str("{\n");
            for st in stmts {
                indent(out, level + 1);
                print_stmt(out, st, level + 1);
                out.push('\n');
            }
            indent(out, level);
            out.push('}');
        }
        StmtKind::Decl(decls) => print_decls(out, decls),
        StmtKind::Expr(e) => {
            let _ = write!(out, "{};", print_expr(e));
        }
        StmtKind::If(cond, then, els) => {
            let _ = write!(out, "if ({}) ", print_expr(cond));
            print_stmt(out, then, level);
            if let Some(e) = els {
                out.push_str(" else ");
                print_stmt(out, e, level);
            }
        }
        StmtKind::For { init, cond, step, body } => {
            out.push_str("for (");
            match init {
                Some(i) => {
                    let mut s = String::new();
                    print_stmt(&mut s, i, 0);
                    out.push_str(s.trim_end_matches(';'));
                    out.push(';');
                }
                None => out.push(';'),
            }
            if let Some(c) = cond {
                let _ = write!(out, " {}", print_expr(c));
            }
            out.push(';');
            if let Some(st) = step {
                let _ = write!(out, " {}", print_expr(st));
            }
            out.push_str(") ");
            print_stmt(out, body, level);
        }
        StmtKind::While(cond, body) => {
            let _ = write!(out, "while ({}) ", print_expr(cond));
            print_stmt(out, body, level);
        }
        StmtKind::DoWhile(body, cond) => {
            out.push_str("do ");
            print_stmt(out, body, level);
            let _ = write!(out, " while ({});", print_expr(cond));
        }
        StmtKind::Return(e) => match e {
            Some(e) => {
                let _ = write!(out, "return {};", print_expr(e));
            }
            None => out.push_str("return;"),
        },
        StmtKind::Break => out.push_str("break;"),
        StmtKind::Continue => out.push_str("continue;"),
        StmtKind::Empty => out.push(';'),
    }
}

/// Render an expression with full parenthesization (precedence-safe).
pub fn print_expr(e: &Expr) -> String {
    match &e.kind {
        ExprKind::IntLit(v) => v.to_string(),
        ExprKind::FloatLit(v) => {
            if v.fract() == 0.0 && v.abs() < 1e15 {
                format!("{v:.1}")
            } else {
                format!("{v}")
            }
        }
        ExprKind::StrLit(s) => format!("{s:?}"),
        ExprKind::CharLit(c) => format!("'{}'", c.escape_default()),
        ExprKind::Ident(n) => n.clone(),
        ExprKind::Binary(op, a, b) => {
            format!("({} {} {})", print_expr(a), op.symbol(), print_expr(b))
        }
        ExprKind::Unary(op, a) => {
            let sym = match op {
                UnOp::Neg => "-",
                UnOp::Not => "!",
                UnOp::BitNot => "~",
                UnOp::Deref => "*",
                UnOp::Addr => "&",
                UnOp::PreInc => "++",
                UnOp::PreDec => "--",
            };
            format!("({sym}{})", print_expr(a))
        }
        ExprKind::PostIncDec(a, inc) => {
            format!("({}{})", print_expr(a), if *inc { "++" } else { "--" })
        }
        ExprKind::Assign(op, l, r) => {
            // Parenthesized: assignments can appear inside expressions
            // (`(wtemp = wr) * wpr` in NR code) and must re-parse the same.
            format!("({} {} {})", print_expr(l), op.symbol(), print_expr(r))
        }
        ExprKind::Ternary(c, t, els) => {
            format!("({} ? {} : {})", print_expr(c), print_expr(t), print_expr(els))
        }
        ExprKind::Call(name, args) => {
            let a: Vec<String> = args.iter().map(print_expr).collect();
            format!("{name}({})", a.join(", "))
        }
        ExprKind::Index(a, i) => format!("{}[{}]", print_expr(a), print_expr(i)),
        ExprKind::Member(a, f) => format!("{}.{f}", print_expr(a)),
        ExprKind::Cast(ty, a) => format!("(({ty}) {})", print_expr(a)),
        ExprKind::SizeOf(ty) => format!("sizeof({ty})"),
    }
}
