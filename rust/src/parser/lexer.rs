//! Hand-written lexer for the mini-C front end.
//!
//! Skips `//` and `/* */` comments and `#...` preprocessor lines (the
//! analyzer treats `#include <x.h>` headers as *library hints*, so the set
//! of included headers is returned alongside the token stream).

use super::token::{Span, Tok, Token};
use anyhow::{bail, Result};

/// Lexer output: tokens plus the names of `#include`d headers (library
/// hints consumed by analysis pass A-1).
#[derive(Debug, Clone)]
pub struct LexOutput {
    /// The lexed token stream (ends with `Tok::Eof`).
    pub tokens: Vec<Token>,
    /// Headers named by `#include` lines, in order.
    pub includes: Vec<String>,
}

/// Streaming lexer over raw source bytes.
pub struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: u32,
    col: u32,
}

impl<'a> Lexer<'a> {
    /// New lexer over a source string.
    pub fn new(src: &'a str) -> Self {
        Lexer { src: src.as_bytes(), pos: 0, line: 1, col: 1 }
    }

    fn peek(&self) -> u8 {
        *self.src.get(self.pos).unwrap_or(&0)
    }

    fn peek2(&self) -> u8 {
        *self.src.get(self.pos + 1).unwrap_or(&0)
    }

    fn bump(&mut self) -> u8 {
        let c = self.peek();
        self.pos += 1;
        if c == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        c
    }

    fn span(&self) -> Span {
        Span { line: self.line, col: self.col }
    }

    /// Lex the whole input.
    pub fn lex(mut self) -> Result<LexOutput> {
        let mut tokens = Vec::new();
        let mut includes = Vec::new();
        loop {
            self.skip_trivia(&mut includes)?;
            let span = self.span();
            if self.pos >= self.src.len() {
                tokens.push(Token { kind: Tok::Eof, span });
                break;
            }
            let kind = self.next_tok()?;
            tokens.push(Token { kind, span });
        }
        Ok(LexOutput { tokens, includes })
    }

    fn skip_trivia(&mut self, includes: &mut Vec<String>) -> Result<()> {
        loop {
            match self.peek() {
                b' ' | b'\t' | b'\r' | b'\n' => {
                    self.bump();
                }
                b'/' if self.peek2() == b'/' => {
                    while self.pos < self.src.len() && self.peek() != b'\n' {
                        self.bump();
                    }
                }
                b'/' if self.peek2() == b'*' => {
                    let start = self.span();
                    self.bump();
                    self.bump();
                    loop {
                        if self.pos >= self.src.len() {
                            bail!("unterminated block comment at {start}");
                        }
                        if self.peek() == b'*' && self.peek2() == b'/' {
                            self.bump();
                            self.bump();
                            break;
                        }
                        self.bump();
                    }
                }
                b'#' => {
                    // Preprocessor line; record `#include` targets.
                    let mut line = String::new();
                    while self.pos < self.src.len() && self.peek() != b'\n' {
                        line.push(self.bump() as char);
                    }
                    if let Some(rest) = line.strip_prefix("#include") {
                        let name: String = rest
                            .trim()
                            .trim_matches(|c| c == '<' || c == '>' || c == '"')
                            .to_string();
                        if !name.is_empty() {
                            includes.push(name);
                        }
                    }
                }
                _ => return Ok(()),
            }
        }
    }

    fn next_tok(&mut self) -> Result<Tok> {
        let c = self.peek();
        if c.is_ascii_alphabetic() || c == b'_' {
            return Ok(self.ident());
        }
        if c.is_ascii_digit() || (c == b'.' && self.peek2().is_ascii_digit()) {
            return self.number();
        }
        match c {
            b'"' => return self.string(),
            b'\'' => return self.char_lit(),
            _ => {}
        }
        let span = self.span();
        self.bump();
        let two = |l: &mut Self, next: u8, a: Tok, b: Tok| {
            if l.peek() == next {
                l.bump();
                a
            } else {
                b
            }
        };
        Ok(match c {
            b'(' => Tok::LParen,
            b')' => Tok::RParen,
            b'{' => Tok::LBrace,
            b'}' => Tok::RBrace,
            b'[' => Tok::LBracket,
            b']' => Tok::RBracket,
            b';' => Tok::Semi,
            b',' => Tok::Comma,
            b'?' => Tok::Question,
            b':' => Tok::Colon,
            b'~' => Tok::Tilde,
            b'.' => Tok::Dot,
            b'+' => {
                if self.peek() == b'+' {
                    self.bump();
                    Tok::PlusPlus
                } else {
                    two(self, b'=', Tok::PlusAssign, Tok::Plus)
                }
            }
            b'-' => {
                if self.peek() == b'-' {
                    self.bump();
                    Tok::MinusMinus
                } else if self.peek() == b'>' {
                    self.bump();
                    Tok::Arrow
                } else {
                    two(self, b'=', Tok::MinusAssign, Tok::Minus)
                }
            }
            b'*' => two(self, b'=', Tok::StarAssign, Tok::Star),
            b'/' => two(self, b'=', Tok::SlashAssign, Tok::Slash),
            b'%' => two(self, b'=', Tok::PercentAssign, Tok::Percent),
            b'=' => two(self, b'=', Tok::Eq, Tok::Assign),
            b'!' => two(self, b'=', Tok::Ne, Tok::Not),
            b'<' => {
                if self.peek() == b'<' {
                    self.bump();
                    if self.peek() == b'=' {
                        self.bump();
                        Tok::ShlAssign
                    } else {
                        Tok::Shl
                    }
                } else {
                    two(self, b'=', Tok::Le, Tok::Lt)
                }
            }
            b'>' => {
                if self.peek() == b'>' {
                    self.bump();
                    if self.peek() == b'=' {
                        self.bump();
                        Tok::ShrAssign
                    } else {
                        Tok::Shr
                    }
                } else {
                    two(self, b'=', Tok::Ge, Tok::Gt)
                }
            }
            b'&' => two(self, b'&', Tok::AndAnd, Tok::Amp),
            b'|' => two(self, b'|', Tok::OrOr, Tok::Pipe),
            b'^' => Tok::Caret,
            other => bail!("unexpected character {:?} at {span}", other as char),
        })
    }

    fn ident(&mut self) -> Tok {
        let mut s = String::new();
        while self.peek().is_ascii_alphanumeric() || self.peek() == b'_' {
            s.push(self.bump() as char);
        }
        Tok::keyword(&s).unwrap_or(Tok::Ident(s))
    }

    fn number(&mut self) -> Result<Tok> {
        let span = self.span();
        let mut s = String::new();
        let mut is_float = false;
        // Hex literals.
        if self.peek() == b'0' && (self.peek2() == b'x' || self.peek2() == b'X') {
            self.bump();
            self.bump();
            let mut h = String::new();
            while self.peek().is_ascii_hexdigit() {
                h.push(self.bump() as char);
            }
            let v = i64::from_str_radix(&h, 16)
                .map_err(|e| anyhow::anyhow!("bad hex literal at {span}: {e}"))?;
            return Ok(Tok::IntLit(v));
        }
        while self.peek().is_ascii_digit() {
            s.push(self.bump() as char);
        }
        if self.peek() == b'.' {
            is_float = true;
            s.push(self.bump() as char);
            while self.peek().is_ascii_digit() {
                s.push(self.bump() as char);
            }
        }
        if self.peek() == b'e' || self.peek() == b'E' {
            is_float = true;
            s.push(self.bump() as char);
            if self.peek() == b'+' || self.peek() == b'-' {
                s.push(self.bump() as char);
            }
            while self.peek().is_ascii_digit() {
                s.push(self.bump() as char);
            }
        }
        // Suffixes (f, L, u) are consumed and ignored.
        while matches!(self.peek(), b'f' | b'F' | b'l' | b'L' | b'u' | b'U') {
            if matches!(self.peek(), b'f' | b'F') {
                is_float = true;
            }
            self.bump();
        }
        if is_float {
            Ok(Tok::FloatLit(s.parse().map_err(|e| {
                anyhow::anyhow!("bad float literal {s:?} at {span}: {e}")
            })?))
        } else {
            Ok(Tok::IntLit(s.parse().map_err(|e| {
                anyhow::anyhow!("bad int literal {s:?} at {span}: {e}")
            })?))
        }
    }

    fn string(&mut self) -> Result<Tok> {
        let span = self.span();
        self.bump(); // opening quote
        let mut s = String::new();
        loop {
            if self.pos >= self.src.len() {
                bail!("unterminated string literal at {span}");
            }
            match self.bump() {
                b'"' => break,
                b'\\' => {
                    let esc = self.bump();
                    s.push(match esc {
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        b'0' => '\0',
                        b'\\' => '\\',
                        b'"' => '"',
                        other => other as char,
                    });
                }
                c => s.push(c as char),
            }
        }
        Ok(Tok::StrLit(s))
    }

    fn char_lit(&mut self) -> Result<Tok> {
        let span = self.span();
        self.bump(); // opening quote
        let c = match self.bump() {
            b'\\' => match self.bump() {
                b'n' => '\n',
                b't' => '\t',
                b'0' => '\0',
                other => other as char,
            },
            c => c as char,
        };
        if self.bump() != b'\'' {
            bail!("unterminated char literal at {span}");
        }
        Ok(Tok::CharLit(c))
    }
}

/// Convenience: lex a source string.
pub fn lex(src: &str) -> Result<LexOutput> {
    Lexer::new(src).lex()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).unwrap().tokens.into_iter().map(|t| t.kind).collect()
    }

    #[test]
    fn lexes_arithmetic() {
        assert_eq!(
            kinds("a = b + 2;"),
            vec![
                Tok::Ident("a".into()),
                Tok::Assign,
                Tok::Ident("b".into()),
                Tok::Plus,
                Tok::IntLit(2),
                Tok::Semi,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn lexes_floats_and_suffixes() {
        assert_eq!(kinds("1.5 2e3 7f 0x10"), vec![
            Tok::FloatLit(1.5),
            Tok::FloatLit(2000.0),
            Tok::FloatLit(7.0),
            Tok::IntLit(16),
            Tok::Eof
        ]);
    }

    #[test]
    fn skips_comments() {
        assert_eq!(
            kinds("x /* mid */ y // tail\nz"),
            vec![
                Tok::Ident("x".into()),
                Tok::Ident("y".into()),
                Tok::Ident("z".into()),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn collects_includes() {
        let out = lex("#include <math.h>\n#include \"nr.h\"\nint x;").unwrap();
        assert_eq!(out.includes, vec!["math.h", "nr.h"]);
    }

    #[test]
    fn two_char_operators() {
        assert_eq!(
            kinds("a += b == c && d++ >= --e >> 1"),
            vec![
                Tok::Ident("a".into()),
                Tok::PlusAssign,
                Tok::Ident("b".into()),
                Tok::Eq,
                Tok::Ident("c".into()),
                Tok::AndAnd,
                Tok::Ident("d".into()),
                Tok::PlusPlus,
                Tok::Ge,
                Tok::MinusMinus,
                Tok::Ident("e".into()),
                Tok::Shr,
                Tok::IntLit(1),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn arrow_and_member() {
        assert_eq!(
            kinds("p->x.y"),
            vec![
                Tok::Ident("p".into()),
                Tok::Arrow,
                Tok::Ident("x".into()),
                Tok::Dot,
                Tok::Ident("y".into()),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn string_escapes() {
        assert_eq!(
            kinds(r#""a\nb""#),
            vec![Tok::StrLit("a\nb".into()), Tok::Eof]
        );
    }

    #[test]
    fn spans_track_lines() {
        let out = lex("x\n  y").unwrap();
        assert_eq!(out.tokens[0].span.line, 1);
        assert_eq!(out.tokens[1].span.line, 2);
        assert_eq!(out.tokens[1].span.col, 3);
    }

    #[test]
    fn rejects_unterminated_string() {
        assert!(lex("\"abc").is_err());
    }

    #[test]
    fn rejects_unterminated_comment() {
        assert!(lex("/* abc").is_err());
    }
}
