//! Mini-C front end: lexer, AST, recursive-descent parser, pretty-printer.
//!
//! This is the substrate behind the paper's Step 1 (code analysis): the
//! published system used LLVM/Clang's libClang; we parse a self-contained C
//! subset rich enough for Numerical-Recipes-style numeric applications.
//! See DESIGN.md "Substitutions".

pub mod ast;
pub mod lexer;
pub mod parse;
pub mod print;
pub mod token;

pub use ast::*;
pub use parse::{parse, parse_expr};
pub use print::{print_expr, print_program};
pub use token::{Span, Tok, Token};

#[cfg(test)]
mod tests {
    use super::*;

    const FFT_SNIPPET: &str = r#"
        #include <math.h>
        void four1(double data[], int nn, int isign) {
            int n, mmax, m, j, istep, i;
            double wtemp, wr, wpr, wpi, wi, theta;
            n = nn << 1;
            j = 1;
            for (i = 1; i < n; i += 2) {
                if (j > i) {
                    wtemp = data[j]; data[j] = data[i]; data[i] = wtemp;
                }
                m = nn;
                while (m >= 2 && j > m) { j -= m; m >>= 1; }
                j += m;
            }
            mmax = 2;
            while (n > mmax) {
                istep = mmax << 1;
                theta = isign * (6.28318530717959 / mmax);
                wtemp = sin(0.5 * theta);
                wpr = -2.0 * wtemp * wtemp;
                wpi = sin(theta);
                wr = 1.0;
                wi = 0.0;
                for (m = 1; m < mmax; m += 2) {
                    for (i = m; i <= n; i += istep) {
                        j = i + mmax;
                        data[j] = data[i] - (wr * data[j] - wi * data[j + 1]);
                    }
                    wr = (wtemp = wr) * wpr - wi * wpi + wr;
                    wi = wi * wpr + wtemp * wpi + wi;
                }
                mmax = istep;
            }
        }
    "#;

    #[test]
    fn parses_numerical_recipes_style_code() {
        let prog = parse(FFT_SNIPPET).unwrap();
        let f = prog.find_function("four1").unwrap();
        assert_eq!(f.params.len(), 3);
        assert_eq!(f.params[0].array_dims, 1);
        assert!(f.body.is_some());
        assert_eq!(prog.includes, vec!["math.h"]);
    }

    #[test]
    fn parses_structs() {
        let prog = parse(
            "struct Vec { double x; double y; int tags[4]; };
             double norm(struct Vec v) { return v.x * v.x + v.y * v.y; }",
        )
        .unwrap();
        let s = prog.structs().next().unwrap();
        assert_eq!(s.name, "Vec");
        assert_eq!(s.fields.len(), 3);
        assert_eq!(s.fields[2].dims.len(), 1);
    }

    #[test]
    fn parses_extern_prototype_as_bodyless() {
        let prog = parse("void fft2d(double re[], double im[], int n);").unwrap();
        let f = prog.find_function("fft2d").unwrap();
        assert!(f.body.is_none());
    }

    #[test]
    fn parses_multidim_arrays_and_globals() {
        let prog = parse("double grid[16][16]; int n = 4, m = 5;").unwrap();
        assert_eq!(prog.items.len(), 2);
        match &prog.items[0] {
            Item::Global(d) => assert_eq!(d[0].dims.len(), 2),
            other => panic!("expected global, got {other:?}"),
        }
        match &prog.items[1] {
            Item::Global(d) => {
                assert_eq!(d.len(), 2);
                assert!(d[0].init.is_some());
            }
            other => panic!("expected global, got {other:?}"),
        }
    }

    #[test]
    fn expression_precedence() {
        let e = parse_expr("a + b * c").unwrap();
        // Must parse as a + (b * c).
        match &e.kind {
            ExprKind::Binary(BinOp::Add, _, rhs) => match &rhs.kind {
                ExprKind::Binary(BinOp::Mul, _, _) => {}
                other => panic!("rhs not mul: {other:?}"),
            },
            other => panic!("not add at root: {other:?}"),
        }
    }

    #[test]
    fn assignment_is_right_associative() {
        let e = parse_expr("a = b = 1").unwrap();
        match &e.kind {
            ExprKind::Assign(AssignOp::Set, _, rhs) => {
                assert!(matches!(rhs.kind, ExprKind::Assign(..)));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn ternary_and_cast() {
        let e = parse_expr("(float) (a > 0 ? a : -a)").unwrap();
        assert!(matches!(e.kind, ExprKind::Cast(..)));
    }

    #[test]
    fn postfix_chains() {
        let e = parse_expr("m[i][j].w++").unwrap();
        assert!(matches!(e.kind, ExprKind::PostIncDec(..)));
    }

    #[test]
    fn round_trip_print_parse() {
        let prog = parse(FFT_SNIPPET).unwrap();
        let printed = print_program(&prog);
        let reparsed = parse(&printed).unwrap();
        // Node ids/spans differ; compare re-printed forms instead.
        assert_eq!(printed, print_program(&reparsed));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("int f( {").is_err());
        assert!(parse("double x = ;").is_err());
        assert!(parse_expr("a +").is_err());
    }

    #[test]
    fn for_without_init_cond_step() {
        let prog = parse("void f() { for (;;) { break; } }").unwrap();
        let f = prog.find_function("f").unwrap();
        let mut fors = 0;
        f.body.as_ref().unwrap().walk(&mut |s| {
            if matches!(s.kind, StmtKind::For { .. }) {
                fors += 1;
            }
        });
        assert_eq!(fors, 1);
    }

    #[test]
    fn node_ids_are_unique() {
        let prog = parse(FFT_SNIPPET).unwrap();
        let mut seen = std::collections::HashSet::new();
        for f in prog.functions() {
            if let Some(b) = &f.body {
                b.walk(&mut |s| {
                    assert!(seen.insert(s.id), "duplicate stmt id {}", s.id);
                });
            }
        }
    }
}
