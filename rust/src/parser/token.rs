//! Token set for the mini-C front end.
//!
//! The analyzer front end (paper Step 1) consumes C/C++ source; we parse a
//! C subset rich enough for Numerical-Recipes-style numeric code: functions,
//! structs, multi-dimensional arrays, the full C expression grammar, and the
//! control statements that matter for loop analysis.

use std::fmt;

/// Source location (1-based line / column) of a token or AST node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Span {
    /// 1-based source line.
    pub line: u32,
    /// 1-based source column.
    pub col: u32,
}

impl fmt::Display for Span {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.line, self.col)
    }
}

/// A lexed token with its source location.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    /// Token kind.
    pub kind: Tok,
    /// Source location.
    pub span: Span,
}

/// Token kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    // Literals / identifiers.
    /// Identifier.
    Ident(String),
    /// Integer literal.
    IntLit(i64),
    /// Floating literal.
    FloatLit(f64),
    /// String literal.
    StrLit(String),
    /// Character literal.
    CharLit(char),

    // Keywords.
    /// `int`.
    KwInt,
    /// `float`.
    KwFloat,
    /// `double`.
    KwDouble,
    /// `char`.
    KwChar,
    /// `long`.
    KwLong,
    /// `void`.
    KwVoid,
    /// `struct`.
    KwStruct,
    /// `if`.
    KwIf,
    /// `else`.
    KwElse,
    /// `for`.
    KwFor,
    /// `while`.
    KwWhile,
    /// `do`.
    KwDo,
    /// `return`.
    KwReturn,
    /// `break`.
    KwBreak,
    /// `continue`.
    KwContinue,
    /// `const`.
    KwConst,
    /// `static`.
    KwStatic,
    /// `extern`.
    KwExtern,
    /// `unsigned`.
    KwUnsigned,
    /// `sizeof`.
    KwSizeof,

    // Punctuation.
    /// `(`.
    LParen,
    /// `)`.
    RParen,
    /// `{`.
    LBrace,
    /// `}`.
    RBrace,
    /// `[`.
    LBracket,
    /// `]`.
    RBracket,
    /// `;`.
    Semi,
    /// `,`.
    Comma,
    /// `.`.
    Dot,
    /// `->`.
    Arrow, // ->
    /// `?`.
    Question,
    /// `:`.
    Colon,

    // Operators.
    /// `=`.
    Assign,       // =
    /// `+=`.
    PlusAssign,   // +=
    /// `-=`.
    MinusAssign,  // -=
    /// `*=`.
    StarAssign,   // *=
    /// `/=`.
    SlashAssign,  // /=
    /// `%=`.
    PercentAssign,// %=
    /// `+`.
    Plus,
    /// `-`.
    Minus,
    /// `*`.
    Star,
    /// `/`.
    Slash,
    /// `%`.
    Percent,
    /// `++`.
    PlusPlus,
    /// `--`.
    MinusMinus,
    /// `==`.
    Eq,  // ==
    /// `!=`.
    Ne,  // !=
    /// `<`.
    Lt,
    /// `>`.
    Gt,
    /// `<=`.
    Le,
    /// `>=`.
    Ge,
    /// `&&`.
    AndAnd,
    /// `||`.
    OrOr,
    /// `!`.
    Not,
    /// `&`.
    Amp,
    /// `|`.
    Pipe,
    /// `^`.
    Caret,
    /// `~`.
    Tilde,
    /// `<<`.
    Shl,
    /// `>>`.
    Shr,
    /// `<<=`.
    ShlAssign, // <<=
    /// `>>=`.
    ShrAssign, // >>=

    /// End of input.
    Eof,
}

impl Tok {
    /// Keyword lookup for the lexer.
    pub fn keyword(s: &str) -> Option<Tok> {
        Some(match s {
            "int" => Tok::KwInt,
            "float" => Tok::KwFloat,
            "double" => Tok::KwDouble,
            "char" => Tok::KwChar,
            "long" => Tok::KwLong,
            "void" => Tok::KwVoid,
            "struct" => Tok::KwStruct,
            "if" => Tok::KwIf,
            "else" => Tok::KwElse,
            "for" => Tok::KwFor,
            "while" => Tok::KwWhile,
            "do" => Tok::KwDo,
            "return" => Tok::KwReturn,
            "break" => Tok::KwBreak,
            "continue" => Tok::KwContinue,
            "const" => Tok::KwConst,
            "static" => Tok::KwStatic,
            "extern" => Tok::KwExtern,
            "unsigned" => Tok::KwUnsigned,
            "sizeof" => Tok::KwSizeof,
            _ => return None,
        })
    }

    /// True for tokens that can begin a type name.
    pub fn starts_type(&self) -> bool {
        matches!(
            self,
            Tok::KwInt
                | Tok::KwFloat
                | Tok::KwDouble
                | Tok::KwChar
                | Tok::KwLong
                | Tok::KwVoid
                | Tok::KwStruct
                | Tok::KwConst
                | Tok::KwStatic
                | Tok::KwExtern
                | Tok::KwUnsigned
        )
    }
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Tok::Ident(s) => write!(f, "{s}"),
            Tok::IntLit(v) => write!(f, "{v}"),
            Tok::FloatLit(v) => write!(f, "{v}"),
            Tok::StrLit(s) => write!(f, "{s:?}"),
            Tok::CharLit(c) => write!(f, "{c:?}"),
            other => write!(f, "{other:?}"),
        }
    }
}
