//! Recursive-descent parser for the mini-C subset.
//!
//! Grammar follows C's expression precedence exactly; declarations cover
//! scalars, multi-dimensional arrays, pointers-as-array-handles, structs,
//! and function definitions / extern prototypes.

use super::ast::*;
use super::lexer::{lex, LexOutput};
use super::token::{Span, Tok, Token};
use anyhow::{bail, Result};

/// Recursive-descent parser state over a lexed token stream.
pub struct Parser {
    toks: Vec<Token>,
    pos: usize,
    next_id: u32,
    includes: Vec<String>,
}

impl Parser {
    /// New parser over lexer output.
    pub fn new(out: LexOutput) -> Self {
        Parser { toks: out.tokens, pos: 0, next_id: 0, includes: out.includes }
    }

    fn id(&mut self) -> NodeId {
        let id = NodeId(self.next_id);
        self.next_id += 1;
        id
    }

    fn peek(&self) -> &Tok {
        &self.toks[self.pos.min(self.toks.len() - 1)].kind
    }

    fn peek_at(&self, off: usize) -> &Tok {
        &self.toks[(self.pos + off).min(self.toks.len() - 1)].kind
    }

    fn span(&self) -> Span {
        self.toks[self.pos.min(self.toks.len() - 1)].span
    }

    fn bump(&mut self) -> Tok {
        let t = self.toks[self.pos.min(self.toks.len() - 1)].kind.clone();
        if self.pos < self.toks.len() - 1 {
            self.pos += 1;
        }
        t
    }

    fn eat(&mut self, tok: &Tok) -> bool {
        if self.peek() == tok {
            self.bump();
            true
        } else {
            false
        }
    }

    fn expect(&mut self, tok: &Tok) -> Result<()> {
        if self.eat(tok) {
            Ok(())
        } else {
            bail!("expected {tok} but found {} at {}", self.peek(), self.span())
        }
    }

    fn expect_ident(&mut self) -> Result<String> {
        match self.bump() {
            Tok::Ident(s) => Ok(s),
            other => bail!("expected identifier, found {other} at {}", self.span()),
        }
    }

    // ------------------------------------------------------------ types

    fn at_type(&self) -> bool {
        self.peek().starts_type()
    }

    /// Parse a base type (qualifiers are accepted and discarded).
    fn parse_base_type(&mut self) -> Result<Ty> {
        while matches!(
            self.peek(),
            Tok::KwConst | Tok::KwStatic | Tok::KwExtern | Tok::KwUnsigned
        ) {
            self.bump();
        }
        let ty = match self.bump() {
            Tok::KwInt => Ty::Base(BaseTy::Int),
            Tok::KwLong => {
                // `long long`, `long int` collapse to long.
                while matches!(self.peek(), Tok::KwLong | Tok::KwInt) {
                    self.bump();
                }
                Ty::Base(BaseTy::Long)
            }
            Tok::KwChar => Ty::Base(BaseTy::Char),
            Tok::KwFloat => Ty::Base(BaseTy::Float),
            Tok::KwDouble => Ty::Base(BaseTy::Double),
            Tok::KwVoid => Ty::Base(BaseTy::Void),
            Tok::KwStruct => Ty::Struct(self.expect_ident()?),
            other => bail!("expected type, found {other} at {}", self.span()),
        };
        Ok(ty)
    }

    fn parse_ptr_suffix(&mut self, mut ty: Ty) -> Ty {
        while self.eat(&Tok::Star) {
            ty = Ty::Ptr(Box::new(ty));
        }
        ty
    }

    // ------------------------------------------------------------ program

    /// Parse a whole translation unit.
    pub fn parse_program(&mut self) -> Result<Program> {
        let mut items = Vec::new();
        while self.peek() != &Tok::Eof {
            items.push(self.parse_item()?);
        }
        Ok(Program { items, includes: std::mem::take(&mut self.includes) })
    }

    fn parse_item(&mut self) -> Result<Item> {
        // struct definition: `struct Name { ... };`
        if self.peek() == &Tok::KwStruct && matches!(self.peek_at(2), Tok::LBrace) {
            return Ok(Item::Struct(self.parse_struct_def()?));
        }
        let span = self.span();
        let base = self.parse_base_type()?;
        let ty = self.parse_ptr_suffix(base);
        let name = self.expect_ident()?;
        if self.peek() == &Tok::LParen {
            return Ok(Item::Func(self.parse_func_rest(span, ty, name)?));
        }
        // Global variable(s).
        let decls = self.parse_decl_rest(span, ty, name)?;
        Ok(Item::Global(decls))
    }

    fn parse_struct_def(&mut self) -> Result<StructDef> {
        let span = self.span();
        let id = self.id();
        self.expect(&Tok::KwStruct)?;
        let name = self.expect_ident()?;
        self.expect(&Tok::LBrace)?;
        let mut fields = Vec::new();
        while !self.eat(&Tok::RBrace) {
            let fspan = self.span();
            let base = self.parse_base_type()?;
            loop {
                let fty = self.parse_ptr_suffix(base.clone());
                let fname = self.expect_ident()?;
                let mut dims = Vec::new();
                while self.eat(&Tok::LBracket) {
                    dims.push(self.parse_expr()?);
                    self.expect(&Tok::RBracket)?;
                }
                fields.push(VarDecl {
                    id: self.id(),
                    span: fspan,
                    ty: fty,
                    name: fname,
                    dims,
                    init: None,
                });
                if !self.eat(&Tok::Comma) {
                    break;
                }
            }
            self.expect(&Tok::Semi)?;
        }
        self.expect(&Tok::Semi)?;
        Ok(StructDef { id, span, name, fields })
    }

    fn parse_func_rest(&mut self, span: Span, ret: Ty, name: String) -> Result<FuncDef> {
        let id = self.id();
        self.expect(&Tok::LParen)?;
        let mut params = Vec::new();
        if !self.eat(&Tok::RParen) {
            loop {
                if self.peek() == &Tok::KwVoid && self.peek_at(1) == &Tok::RParen {
                    self.bump();
                    break;
                }
                let base = self.parse_base_type()?;
                let ty = self.parse_ptr_suffix(base);
                let pname = self.expect_ident()?;
                let mut array_dims = 0usize;
                while self.eat(&Tok::LBracket) {
                    // Dimension expressions in parameters are ignored
                    // (arrays decay to handles).
                    if self.peek() != &Tok::RBracket {
                        self.parse_expr()?;
                    }
                    self.expect(&Tok::RBracket)?;
                    array_dims += 1;
                }
                params.push(Param { ty, name: pname, array_dims });
                if !self.eat(&Tok::Comma) {
                    break;
                }
            }
            self.expect(&Tok::RParen)?;
        }
        let body = if self.eat(&Tok::Semi) {
            None
        } else {
            Some(self.parse_block()?)
        };
        Ok(FuncDef { id, span, ret, name, params, body })
    }

    /// Rest of a declaration after `ty name` has been consumed.
    fn parse_decl_rest(&mut self, span: Span, ty: Ty, name: String) -> Result<Vec<VarDecl>> {
        let mut decls = Vec::new();
        let mut cur_name = name;
        let mut cur_ty = ty.clone();
        loop {
            let mut dims = Vec::new();
            while self.eat(&Tok::LBracket) {
                dims.push(self.parse_expr()?);
                self.expect(&Tok::RBracket)?;
            }
            let init = if self.eat(&Tok::Assign) {
                Some(self.parse_assign()?)
            } else {
                None
            };
            decls.push(VarDecl {
                id: self.id(),
                span,
                ty: cur_ty.clone(),
                name: cur_name,
                dims,
                init,
            });
            if !self.eat(&Tok::Comma) {
                break;
            }
            cur_ty = self.parse_ptr_suffix(ty.clone());
            cur_name = self.expect_ident()?;
        }
        self.expect(&Tok::Semi)?;
        Ok(decls)
    }

    // ------------------------------------------------------------ statements

    /// Parse a `{ ... }` block.
    pub fn parse_block(&mut self) -> Result<Stmt> {
        let span = self.span();
        let id = self.id();
        self.expect(&Tok::LBrace)?;
        let mut stmts = Vec::new();
        while !self.eat(&Tok::RBrace) {
            stmts.push(self.parse_stmt()?);
        }
        Ok(Stmt { id, span, kind: StmtKind::Block(stmts) })
    }

    fn parse_stmt(&mut self) -> Result<Stmt> {
        let span = self.span();
        match self.peek() {
            Tok::LBrace => self.parse_block(),
            Tok::Semi => {
                let id = self.id();
                self.bump();
                Ok(Stmt { id, span, kind: StmtKind::Empty })
            }
            Tok::KwIf => {
                let id = self.id();
                self.bump();
                self.expect(&Tok::LParen)?;
                let cond = self.parse_expr()?;
                self.expect(&Tok::RParen)?;
                let then = Box::new(self.parse_stmt()?);
                let els = if self.eat(&Tok::KwElse) {
                    Some(Box::new(self.parse_stmt()?))
                } else {
                    None
                };
                Ok(Stmt { id, span, kind: StmtKind::If(cond, then, els) })
            }
            Tok::KwFor => {
                let id = self.id();
                self.bump();
                self.expect(&Tok::LParen)?;
                let init = if self.eat(&Tok::Semi) {
                    None
                } else if self.at_type() {
                    let dspan = self.span();
                    let did = self.id();
                    let base = self.parse_base_type()?;
                    let ty = self.parse_ptr_suffix(base);
                    let name = self.expect_ident()?;
                    let decls = self.parse_decl_rest(dspan, ty, name)?;
                    Some(Box::new(Stmt { id: did, span: dspan, kind: StmtKind::Decl(decls) }))
                } else {
                    let e = self.parse_expr()?;
                    self.expect(&Tok::Semi)?;
                    let eid = self.id();
                    Some(Box::new(Stmt { id: eid, span, kind: StmtKind::Expr(e) }))
                };
                let cond = if self.peek() == &Tok::Semi { None } else { Some(self.parse_expr()?) };
                self.expect(&Tok::Semi)?;
                let step =
                    if self.peek() == &Tok::RParen { None } else { Some(self.parse_expr()?) };
                self.expect(&Tok::RParen)?;
                let body = Box::new(self.parse_stmt()?);
                Ok(Stmt { id, span, kind: StmtKind::For { init, cond, step, body } })
            }
            Tok::KwWhile => {
                let id = self.id();
                self.bump();
                self.expect(&Tok::LParen)?;
                let cond = self.parse_expr()?;
                self.expect(&Tok::RParen)?;
                let body = Box::new(self.parse_stmt()?);
                Ok(Stmt { id, span, kind: StmtKind::While(cond, body) })
            }
            Tok::KwDo => {
                let id = self.id();
                self.bump();
                let body = Box::new(self.parse_stmt()?);
                self.expect(&Tok::KwWhile)?;
                self.expect(&Tok::LParen)?;
                let cond = self.parse_expr()?;
                self.expect(&Tok::RParen)?;
                self.expect(&Tok::Semi)?;
                Ok(Stmt { id, span, kind: StmtKind::DoWhile(body, cond) })
            }
            Tok::KwReturn => {
                let id = self.id();
                self.bump();
                let e = if self.peek() == &Tok::Semi { None } else { Some(self.parse_expr()?) };
                self.expect(&Tok::Semi)?;
                Ok(Stmt { id, span, kind: StmtKind::Return(e) })
            }
            Tok::KwBreak => {
                let id = self.id();
                self.bump();
                self.expect(&Tok::Semi)?;
                Ok(Stmt { id, span, kind: StmtKind::Break })
            }
            Tok::KwContinue => {
                let id = self.id();
                self.bump();
                self.expect(&Tok::Semi)?;
                Ok(Stmt { id, span, kind: StmtKind::Continue })
            }
            t if t.starts_type() => {
                let id = self.id();
                let base = self.parse_base_type()?;
                let ty = self.parse_ptr_suffix(base);
                let name = self.expect_ident()?;
                let decls = self.parse_decl_rest(span, ty, name)?;
                Ok(Stmt { id, span, kind: StmtKind::Decl(decls) })
            }
            _ => {
                let id = self.id();
                let e = self.parse_expr()?;
                self.expect(&Tok::Semi)?;
                Ok(Stmt { id, span, kind: StmtKind::Expr(e) })
            }
        }
    }

    // ------------------------------------------------------------ expressions

    /// Parse one expression (assignment precedence and below).
    pub fn parse_expr(&mut self) -> Result<Expr> {
        self.parse_assign()
    }

    fn parse_assign(&mut self) -> Result<Expr> {
        let span = self.span();
        let lhs = self.parse_ternary()?;
        let op = match self.peek() {
            Tok::Assign => AssignOp::Set,
            Tok::PlusAssign => AssignOp::Add,
            Tok::MinusAssign => AssignOp::Sub,
            Tok::StarAssign => AssignOp::Mul,
            Tok::SlashAssign => AssignOp::Div,
            Tok::PercentAssign => AssignOp::Rem,
            Tok::ShlAssign => AssignOp::Shl,
            Tok::ShrAssign => AssignOp::Shr,
            _ => return Ok(lhs),
        };
        self.bump();
        let rhs = self.parse_assign()?;
        Ok(Expr {
            id: self.id(),
            span,
            kind: ExprKind::Assign(op, Box::new(lhs), Box::new(rhs)),
        })
    }

    fn parse_ternary(&mut self) -> Result<Expr> {
        let span = self.span();
        let cond = self.parse_binary(0)?;
        if self.eat(&Tok::Question) {
            let then = self.parse_expr()?;
            self.expect(&Tok::Colon)?;
            let els = self.parse_ternary()?;
            Ok(Expr {
                id: self.id(),
                span,
                kind: ExprKind::Ternary(Box::new(cond), Box::new(then), Box::new(els)),
            })
        } else {
            Ok(cond)
        }
    }

    fn bin_op_prec(tok: &Tok) -> Option<(BinOp, u8)> {
        Some(match tok {
            Tok::OrOr => (BinOp::Or, 1),
            Tok::AndAnd => (BinOp::And, 2),
            Tok::Pipe => (BinOp::BitOr, 3),
            Tok::Caret => (BinOp::BitXor, 4),
            Tok::Amp => (BinOp::BitAnd, 5),
            Tok::Eq => (BinOp::Eq, 6),
            Tok::Ne => (BinOp::Ne, 6),
            Tok::Lt => (BinOp::Lt, 7),
            Tok::Gt => (BinOp::Gt, 7),
            Tok::Le => (BinOp::Le, 7),
            Tok::Ge => (BinOp::Ge, 7),
            Tok::Shl => (BinOp::Shl, 8),
            Tok::Shr => (BinOp::Shr, 8),
            Tok::Plus => (BinOp::Add, 9),
            Tok::Minus => (BinOp::Sub, 9),
            Tok::Star => (BinOp::Mul, 10),
            Tok::Slash => (BinOp::Div, 10),
            Tok::Percent => (BinOp::Rem, 10),
            _ => return None,
        })
    }

    fn parse_binary(&mut self, min_prec: u8) -> Result<Expr> {
        let span = self.span();
        let mut lhs = self.parse_unary()?;
        while let Some((op, prec)) = Self::bin_op_prec(self.peek()) {
            if prec < min_prec {
                break;
            }
            self.bump();
            let rhs = self.parse_binary(prec + 1)?;
            lhs = Expr {
                id: self.id(),
                span,
                kind: ExprKind::Binary(op, Box::new(lhs), Box::new(rhs)),
            };
        }
        Ok(lhs)
    }

    fn parse_unary(&mut self) -> Result<Expr> {
        let span = self.span();
        let op = match self.peek() {
            Tok::Minus => Some(UnOp::Neg),
            Tok::Not => Some(UnOp::Not),
            Tok::Tilde => Some(UnOp::BitNot),
            Tok::Star => Some(UnOp::Deref),
            Tok::Amp => Some(UnOp::Addr),
            Tok::PlusPlus => Some(UnOp::PreInc),
            Tok::MinusMinus => Some(UnOp::PreDec),
            Tok::Plus => {
                self.bump(); // unary plus is a no-op
                return self.parse_unary();
            }
            Tok::KwSizeof => {
                self.bump();
                self.expect(&Tok::LParen)?;
                let base = self.parse_base_type()?;
                let ty = self.parse_ptr_suffix(base);
                self.expect(&Tok::RParen)?;
                return Ok(Expr { id: self.id(), span, kind: ExprKind::SizeOf(ty) });
            }
            // Cast: `(type) expr`.
            Tok::LParen if self.peek_at(1).starts_type() => {
                self.bump();
                let base = self.parse_base_type()?;
                let ty = self.parse_ptr_suffix(base);
                self.expect(&Tok::RParen)?;
                let inner = self.parse_unary()?;
                return Ok(Expr {
                    id: self.id(),
                    span,
                    kind: ExprKind::Cast(ty, Box::new(inner)),
                });
            }
            _ => None,
        };
        if let Some(op) = op {
            self.bump();
            let inner = self.parse_unary()?;
            return Ok(Expr { id: self.id(), span, kind: ExprKind::Unary(op, Box::new(inner)) });
        }
        self.parse_postfix()
    }

    fn parse_postfix(&mut self) -> Result<Expr> {
        let span = self.span();
        let mut e = self.parse_primary()?;
        loop {
            match self.peek() {
                Tok::LBracket => {
                    self.bump();
                    let idx = self.parse_expr()?;
                    self.expect(&Tok::RBracket)?;
                    e = Expr {
                        id: self.id(),
                        span,
                        kind: ExprKind::Index(Box::new(e), Box::new(idx)),
                    };
                }
                Tok::Dot => {
                    self.bump();
                    let field = self.expect_ident()?;
                    e = Expr { id: self.id(), span, kind: ExprKind::Member(Box::new(e), field) };
                }
                Tok::Arrow => {
                    self.bump();
                    let field = self.expect_ident()?;
                    // p->x is (*p).x; deref of struct handle is the handle.
                    e = Expr { id: self.id(), span, kind: ExprKind::Member(Box::new(e), field) };
                }
                Tok::PlusPlus => {
                    self.bump();
                    e = Expr { id: self.id(), span, kind: ExprKind::PostIncDec(Box::new(e), true) };
                }
                Tok::MinusMinus => {
                    self.bump();
                    e = Expr {
                        id: self.id(),
                        span,
                        kind: ExprKind::PostIncDec(Box::new(e), false),
                    };
                }
                _ => break,
            }
        }
        Ok(e)
    }

    fn parse_primary(&mut self) -> Result<Expr> {
        let span = self.span();
        match self.bump() {
            Tok::IntLit(v) => Ok(Expr { id: self.id(), span, kind: ExprKind::IntLit(v) }),
            Tok::FloatLit(v) => Ok(Expr { id: self.id(), span, kind: ExprKind::FloatLit(v) }),
            Tok::StrLit(s) => Ok(Expr { id: self.id(), span, kind: ExprKind::StrLit(s) }),
            Tok::CharLit(c) => Ok(Expr { id: self.id(), span, kind: ExprKind::CharLit(c) }),
            Tok::Ident(name) => {
                if self.peek() == &Tok::LParen {
                    self.bump();
                    let mut args = Vec::new();
                    if !self.eat(&Tok::RParen) {
                        loop {
                            args.push(self.parse_assign()?);
                            if !self.eat(&Tok::Comma) {
                                break;
                            }
                        }
                        self.expect(&Tok::RParen)?;
                    }
                    Ok(Expr { id: self.id(), span, kind: ExprKind::Call(name, args) })
                } else {
                    Ok(Expr { id: self.id(), span, kind: ExprKind::Ident(name) })
                }
            }
            Tok::LParen => {
                let e = self.parse_expr()?;
                self.expect(&Tok::RParen)?;
                Ok(e)
            }
            other => bail!("unexpected token {other} at {span}"),
        }
    }
}

/// Parse a full translation unit.
pub fn parse(src: &str) -> Result<Program> {
    let out = lex(src)?;
    Parser::new(out).parse_program()
}

/// Parse a single expression (testing / tooling convenience).
pub fn parse_expr(src: &str) -> Result<Expr> {
    let out = lex(src)?;
    let mut p = Parser::new(out);
    let e = p.parse_expr()?;
    if p.peek() != &Tok::Eof {
        bail!("trailing tokens after expression: {}", p.peek());
    }
    Ok(e)
}
