//! AST for the mini-C front end.
//!
//! Every statement and expression carries a [`NodeId`] (stable within one
//! parse) so analysis passes, the similarity detector, and the transformer
//! can refer to program points without holding references into the tree.

use super::token::Span;
use std::fmt;

/// Stable identifier of an AST node within one parsed program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// Scalar base types. All floating math is evaluated in f64 by the
/// interpreter (C promotion rules for `float` are "compute in double").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BaseTy {
    Int,
    Long,
    Char,
    Float,
    Double,
    Void,
}

impl BaseTy {
    pub fn is_float(self) -> bool {
        matches!(self, BaseTy::Float | BaseTy::Double)
    }
    pub fn name(self) -> &'static str {
        match self {
            BaseTy::Int => "int",
            BaseTy::Long => "long",
            BaseTy::Char => "char",
            BaseTy::Float => "float",
            BaseTy::Double => "double",
            BaseTy::Void => "void",
        }
    }
}

/// A (possibly struct / pointer) type.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Ty {
    Base(BaseTy),
    Struct(String),
    /// `T*` — in this subset pointers are array handles.
    Ptr(Box<Ty>),
}

impl Ty {
    pub fn base(&self) -> Option<BaseTy> {
        match self {
            Ty::Base(b) => Some(*b),
            Ty::Ptr(inner) => inner.base(),
            Ty::Struct(_) => None,
        }
    }
    pub fn is_ptr(&self) -> bool {
        matches!(self, Ty::Ptr(_))
    }
}

impl fmt::Display for Ty {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Ty::Base(b) => write!(f, "{}", b.name()),
            Ty::Struct(n) => write!(f, "struct {n}"),
            Ty::Ptr(t) => write!(f, "{t}*"),
        }
    }
}

/// Binary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    Eq,
    Ne,
    Lt,
    Gt,
    Le,
    Ge,
    And,
    Or,
    BitAnd,
    BitOr,
    BitXor,
    Shl,
    Shr,
}

impl BinOp {
    pub fn symbol(self) -> &'static str {
        use BinOp::*;
        match self {
            Add => "+",
            Sub => "-",
            Mul => "*",
            Div => "/",
            Rem => "%",
            Eq => "==",
            Ne => "!=",
            Lt => "<",
            Gt => ">",
            Le => "<=",
            Ge => ">=",
            And => "&&",
            Or => "||",
            BitAnd => "&",
            BitOr => "|",
            BitXor => "^",
            Shl => "<<",
            Shr => ">>",
        }
    }
    pub fn is_arith(self) -> bool {
        matches!(self, BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Div | BinOp::Rem)
    }
    pub fn is_comparison(self) -> bool {
        matches!(
            self,
            BinOp::Eq | BinOp::Ne | BinOp::Lt | BinOp::Gt | BinOp::Le | BinOp::Ge
        )
    }
}

/// Unary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnOp {
    Neg,
    Not,
    BitNot,
    /// `*p` — array deref (index 0 in this subset).
    Deref,
    /// `&x` — address-of; arrays decay to themselves.
    Addr,
    PreInc,
    PreDec,
}

/// Assignment operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AssignOp {
    Set,
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    Shl,
    Shr,
}

impl AssignOp {
    pub fn symbol(self) -> &'static str {
        match self {
            AssignOp::Set => "=",
            AssignOp::Add => "+=",
            AssignOp::Sub => "-=",
            AssignOp::Mul => "*=",
            AssignOp::Div => "/=",
            AssignOp::Rem => "%=",
            AssignOp::Shl => "<<=",
            AssignOp::Shr => ">>=",
        }
    }
}

/// Expressions.
#[derive(Debug, Clone, PartialEq)]
pub struct Expr {
    pub id: NodeId,
    pub span: Span,
    pub kind: ExprKind,
}

#[derive(Debug, Clone, PartialEq)]
pub enum ExprKind {
    IntLit(i64),
    FloatLit(f64),
    StrLit(String),
    CharLit(char),
    Ident(String),
    Binary(BinOp, Box<Expr>, Box<Expr>),
    Unary(UnOp, Box<Expr>),
    /// Postfix `x++` / `x--` (op distinguishes which).
    PostIncDec(Box<Expr>, bool /* inc */),
    Assign(AssignOp, Box<Expr>, Box<Expr>),
    Ternary(Box<Expr>, Box<Expr>, Box<Expr>),
    Call(String, Vec<Expr>),
    Index(Box<Expr>, Box<Expr>),
    Member(Box<Expr>, String),
    Cast(Ty, Box<Expr>),
    /// `sizeof(type)` — evaluated to a constant byte size.
    SizeOf(Ty),
}

impl Expr {
    /// Walk this expression tree, calling `f` on every node (pre-order).
    pub fn walk<'a>(&'a self, f: &mut impl FnMut(&'a Expr)) {
        f(self);
        match &self.kind {
            ExprKind::Binary(_, a, b) | ExprKind::Assign(_, a, b) => {
                a.walk(f);
                b.walk(f);
            }
            ExprKind::Unary(_, a)
            | ExprKind::PostIncDec(a, _)
            | ExprKind::Cast(_, a)
            | ExprKind::Member(a, _) => a.walk(f),
            ExprKind::Ternary(c, t, e) => {
                c.walk(f);
                t.walk(f);
                e.walk(f);
            }
            ExprKind::Call(_, args) => {
                for a in args {
                    a.walk(f);
                }
            }
            ExprKind::Index(a, i) => {
                a.walk(f);
                i.walk(f);
            }
            _ => {}
        }
    }
}

/// A declared variable (local or global).
#[derive(Debug, Clone, PartialEq)]
pub struct VarDecl {
    pub id: NodeId,
    pub span: Span,
    pub ty: Ty,
    pub name: String,
    /// Array dimensions, outermost first. Empty for scalars.
    pub dims: Vec<Expr>,
    pub init: Option<Expr>,
}

/// Statements.
#[derive(Debug, Clone, PartialEq)]
pub struct Stmt {
    pub id: NodeId,
    pub span: Span,
    pub kind: StmtKind,
}

#[derive(Debug, Clone, PartialEq)]
pub enum StmtKind {
    Decl(Vec<VarDecl>),
    Expr(Expr),
    Block(Vec<Stmt>),
    If(Expr, Box<Stmt>, Option<Box<Stmt>>),
    For {
        init: Option<Box<Stmt>>,
        cond: Option<Expr>,
        step: Option<Expr>,
        body: Box<Stmt>,
    },
    While(Expr, Box<Stmt>),
    DoWhile(Box<Stmt>, Expr),
    Return(Option<Expr>),
    Break,
    Continue,
    Empty,
}

impl Stmt {
    /// Walk all statements in this subtree (pre-order), including self.
    pub fn walk<'a>(&'a self, f: &mut impl FnMut(&'a Stmt)) {
        f(self);
        match &self.kind {
            StmtKind::Block(stmts) => {
                for s in stmts {
                    s.walk(f);
                }
            }
            StmtKind::If(_, t, e) => {
                t.walk(f);
                if let Some(e) = e {
                    e.walk(f);
                }
            }
            StmtKind::For { init, body, .. } => {
                if let Some(i) = init {
                    i.walk(f);
                }
                body.walk(f);
            }
            StmtKind::While(_, b) | StmtKind::DoWhile(b, _) => b.walk(f),
            _ => {}
        }
    }

    /// Walk every expression contained in this subtree.
    pub fn walk_exprs<'a>(&'a self, f: &mut impl FnMut(&'a Expr)) {
        self.walk(&mut |s| match &s.kind {
            StmtKind::Decl(ds) => {
                for d in ds {
                    for dim in &d.dims {
                        dim.walk(f);
                    }
                    if let Some(init) = &d.init {
                        init.walk(f);
                    }
                }
            }
            StmtKind::Expr(e) | StmtKind::Return(Some(e)) => e.walk(f),
            StmtKind::If(c, _, _) | StmtKind::While(c, _) | StmtKind::DoWhile(_, c) => {
                c.walk(f)
            }
            StmtKind::For { cond, step, .. } => {
                if let Some(c) = cond {
                    c.walk(f);
                }
                if let Some(st) = step {
                    st.walk(f);
                }
            }
            _ => {}
        });
    }
}

/// Function parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    pub ty: Ty,
    pub name: String,
    /// Declared as an array parameter (`float a[]`, `float a[n][m]`).
    pub array_dims: usize,
}

/// Function definition or extern declaration (no body).
#[derive(Debug, Clone, PartialEq)]
pub struct FuncDef {
    pub id: NodeId,
    pub span: Span,
    pub ret: Ty,
    pub name: String,
    pub params: Vec<Param>,
    /// `None` for extern declarations — these are A-1 library-call targets.
    pub body: Option<Stmt>,
}

/// Struct definition.
#[derive(Debug, Clone, PartialEq)]
pub struct StructDef {
    pub id: NodeId,
    pub span: Span,
    pub name: String,
    pub fields: Vec<VarDecl>,
}

/// Top-level items.
#[derive(Debug, Clone, PartialEq)]
pub enum Item {
    Func(FuncDef),
    Struct(StructDef),
    Global(Vec<VarDecl>),
}

/// A parsed translation unit.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Program {
    pub items: Vec<Item>,
    /// `#include` hints from the lexer (used by analysis A-1).
    pub includes: Vec<String>,
}

impl Program {
    pub fn functions(&self) -> impl Iterator<Item = &FuncDef> {
        self.items.iter().filter_map(|i| match i {
            Item::Func(f) => Some(f),
            _ => None,
        })
    }

    pub fn structs(&self) -> impl Iterator<Item = &StructDef> {
        self.items.iter().filter_map(|i| match i {
            Item::Struct(s) => Some(s),
            _ => None,
        })
    }

    pub fn find_function(&self, name: &str) -> Option<&FuncDef> {
        self.functions().find(|f| f.name == name)
    }

    /// Names of functions *defined* (with bodies) in this unit.
    pub fn defined_names(&self) -> Vec<&str> {
        self.functions()
            .filter(|f| f.body.is_some())
            .map(|f| f.name.as_str())
            .collect()
    }
}
