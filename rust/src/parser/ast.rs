//! AST for the mini-C front end.
//!
//! Every statement and expression carries a [`NodeId`] (stable within one
//! parse) so analysis passes, the similarity detector, and the transformer
//! can refer to program points without holding references into the tree.

use super::token::Span;
use std::fmt;

/// Stable identifier of an AST node within one parsed program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// Scalar base types. All floating math is evaluated in f64 by the
/// interpreter (C promotion rules for `float` are "compute in double").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BaseTy {
    /// `int`.
    Int,
    /// `long`.
    Long,
    /// `char`.
    Char,
    /// `float`.
    Float,
    /// `double`.
    Double,
    /// `void`.
    Void,
}

impl BaseTy {
    /// True for `float` / `double`.
    pub fn is_float(self) -> bool {
        matches!(self, BaseTy::Float | BaseTy::Double)
    }
    /// C spelling of the type.
    pub fn name(self) -> &'static str {
        match self {
            BaseTy::Int => "int",
            BaseTy::Long => "long",
            BaseTy::Char => "char",
            BaseTy::Float => "float",
            BaseTy::Double => "double",
            BaseTy::Void => "void",
        }
    }
}

/// A (possibly struct / pointer) type.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Ty {
    /// A scalar type.
    Base(BaseTy),
    /// A named struct type.
    Struct(String),
    /// `T*` — in this subset pointers are array handles.
    Ptr(Box<Ty>),
}

impl Ty {
    /// The scalar base type, if any (through pointers).
    pub fn base(&self) -> Option<BaseTy> {
        match self {
            Ty::Base(b) => Some(*b),
            Ty::Ptr(inner) => inner.base(),
            Ty::Struct(_) => None,
        }
    }
    /// True for pointer types.
    pub fn is_ptr(&self) -> bool {
        matches!(self, Ty::Ptr(_))
    }
}

impl fmt::Display for Ty {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Ty::Base(b) => write!(f, "{}", b.name()),
            Ty::Struct(n) => write!(f, "struct {n}"),
            Ty::Ptr(t) => write!(f, "{t}*"),
        }
    }
}

/// Binary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinOp {
    /// `+`.
    Add,
    /// `-`.
    Sub,
    /// `*`.
    Mul,
    /// `/`.
    Div,
    /// `%`.
    Rem,
    /// `==`.
    Eq,
    /// `!=`.
    Ne,
    /// `<`.
    Lt,
    /// `>`.
    Gt,
    /// `<=`.
    Le,
    /// `>=`.
    Ge,
    /// `&&`.
    And,
    /// `||`.
    Or,
    /// `&`.
    BitAnd,
    /// `|`.
    BitOr,
    /// `^`.
    BitXor,
    /// `<<`.
    Shl,
    /// `>>`.
    Shr,
}

impl BinOp {
    /// C spelling of the operator.
    pub fn symbol(self) -> &'static str {
        use BinOp::*;
        match self {
            Add => "+",
            Sub => "-",
            Mul => "*",
            Div => "/",
            Rem => "%",
            Eq => "==",
            Ne => "!=",
            Lt => "<",
            Gt => ">",
            Le => "<=",
            Ge => ">=",
            And => "&&",
            Or => "||",
            BitAnd => "&",
            BitOr => "|",
            BitXor => "^",
            Shl => "<<",
            Shr => ">>",
        }
    }
    /// True for `+ - * / %` (the intensity counter's flop set).
    pub fn is_arith(self) -> bool {
        matches!(self, BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Div | BinOp::Rem)
    }
    /// True for `== != < > <= >=`.
    pub fn is_comparison(self) -> bool {
        matches!(
            self,
            BinOp::Eq | BinOp::Ne | BinOp::Lt | BinOp::Gt | BinOp::Le | BinOp::Ge
        )
    }
}

/// Unary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnOp {
    /// `-x`.
    Neg,
    /// `!x`.
    Not,
    /// `~x`.
    BitNot,
    /// `*p` — array deref (index 0 in this subset).
    Deref,
    /// `&x` — address-of; arrays decay to themselves.
    Addr,
    /// `++x`.
    PreInc,
    /// `--x`.
    PreDec,
}

/// Assignment operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AssignOp {
    /// `=`.
    Set,
    /// `+=`.
    Add,
    /// `-=`.
    Sub,
    /// `*=`.
    Mul,
    /// `/=`.
    Div,
    /// `%=`.
    Rem,
    /// `<<=`.
    Shl,
    /// `>>=`.
    Shr,
}

impl AssignOp {
    /// C spelling of the operator.
    pub fn symbol(self) -> &'static str {
        match self {
            AssignOp::Set => "=",
            AssignOp::Add => "+=",
            AssignOp::Sub => "-=",
            AssignOp::Mul => "*=",
            AssignOp::Div => "/=",
            AssignOp::Rem => "%=",
            AssignOp::Shl => "<<=",
            AssignOp::Shr => ">>=",
        }
    }
}

/// Expressions.
#[derive(Debug, Clone, PartialEq)]
pub struct Expr {
    /// Stable node id within the parse.
    pub id: NodeId,
    /// Source location.
    pub span: Span,
    /// The expression itself.
    pub kind: ExprKind,
}

#[derive(Debug, Clone, PartialEq)]
/// Expression kinds.
pub enum ExprKind {
    /// Integer literal.
    IntLit(i64),
    /// Floating literal.
    FloatLit(f64),
    /// String literal.
    StrLit(String),
    /// Character literal.
    CharLit(char),
    /// Variable reference.
    Ident(String),
    /// Binary operation.
    Binary(BinOp, Box<Expr>, Box<Expr>),
    /// Unary operation.
    Unary(UnOp, Box<Expr>),
    /// Postfix `x++` / `x--` (op distinguishes which).
    PostIncDec(Box<Expr>, bool /* inc */),
    /// Assignment (plain or compound).
    Assign(AssignOp, Box<Expr>, Box<Expr>),
    /// `c ? t : e`.
    Ternary(Box<Expr>, Box<Expr>, Box<Expr>),
    /// Function call by name.
    Call(String, Vec<Expr>),
    /// Array indexing `a[i]`.
    Index(Box<Expr>, Box<Expr>),
    /// Struct member access `s.f` / `p->f`.
    Member(Box<Expr>, String),
    /// `(T)x` cast.
    Cast(Ty, Box<Expr>),
    /// `sizeof(type)` — evaluated to a constant byte size.
    SizeOf(Ty),
}

impl Expr {
    /// Walk this expression tree, calling `f` on every node (pre-order).
    pub fn walk<'a>(&'a self, f: &mut impl FnMut(&'a Expr)) {
        f(self);
        match &self.kind {
            ExprKind::Binary(_, a, b) | ExprKind::Assign(_, a, b) => {
                a.walk(f);
                b.walk(f);
            }
            ExprKind::Unary(_, a)
            | ExprKind::PostIncDec(a, _)
            | ExprKind::Cast(_, a)
            | ExprKind::Member(a, _) => a.walk(f),
            ExprKind::Ternary(c, t, e) => {
                c.walk(f);
                t.walk(f);
                e.walk(f);
            }
            ExprKind::Call(_, args) => {
                for a in args {
                    a.walk(f);
                }
            }
            ExprKind::Index(a, i) => {
                a.walk(f);
                i.walk(f);
            }
            _ => {}
        }
    }
}

/// A declared variable (local or global).
#[derive(Debug, Clone, PartialEq)]
pub struct VarDecl {
    /// Stable node id within the parse.
    pub id: NodeId,
    /// Source location.
    pub span: Span,
    /// Declared type.
    pub ty: Ty,
    /// Variable name.
    pub name: String,
    /// Array dimensions, outermost first. Empty for scalars.
    pub dims: Vec<Expr>,
    /// Initializer expression, if any.
    pub init: Option<Expr>,
}

/// Statements.
#[derive(Debug, Clone, PartialEq)]
pub struct Stmt {
    /// Stable node id within the parse.
    pub id: NodeId,
    /// Source location.
    pub span: Span,
    /// The statement itself.
    pub kind: StmtKind,
}

#[derive(Debug, Clone, PartialEq)]
/// Statement kinds.
pub enum StmtKind {
    /// Variable declaration(s).
    Decl(Vec<VarDecl>),
    /// Expression statement.
    Expr(Expr),
    /// `{ ... }` block.
    Block(Vec<Stmt>),
    /// `if` / `else`.
    If(Expr, Box<Stmt>, Option<Box<Stmt>>),
    /// `for` loop (any clause may be absent).
    For {
        init: Option<Box<Stmt>>,
        cond: Option<Expr>,
        step: Option<Expr>,
        body: Box<Stmt>,
    },
    /// `while` loop.
    While(Expr, Box<Stmt>),
    /// `do ... while` loop.
    DoWhile(Box<Stmt>, Expr),
    /// `return`.
    Return(Option<Expr>),
    /// `break`.
    Break,
    /// `continue`.
    Continue,
    /// Empty statement (`;`).
    Empty,
}

impl Stmt {
    /// Walk all statements in this subtree (pre-order), including self.
    pub fn walk<'a>(&'a self, f: &mut impl FnMut(&'a Stmt)) {
        f(self);
        match &self.kind {
            StmtKind::Block(stmts) => {
                for s in stmts {
                    s.walk(f);
                }
            }
            StmtKind::If(_, t, e) => {
                t.walk(f);
                if let Some(e) = e {
                    e.walk(f);
                }
            }
            StmtKind::For { init, body, .. } => {
                if let Some(i) = init {
                    i.walk(f);
                }
                body.walk(f);
            }
            StmtKind::While(_, b) | StmtKind::DoWhile(b, _) => b.walk(f),
            _ => {}
        }
    }

    /// Walk every expression contained in this subtree.
    pub fn walk_exprs<'a>(&'a self, f: &mut impl FnMut(&'a Expr)) {
        self.walk(&mut |s| match &s.kind {
            StmtKind::Decl(ds) => {
                for d in ds {
                    for dim in &d.dims {
                        dim.walk(f);
                    }
                    if let Some(init) = &d.init {
                        init.walk(f);
                    }
                }
            }
            StmtKind::Expr(e) | StmtKind::Return(Some(e)) => e.walk(f),
            StmtKind::If(c, _, _) | StmtKind::While(c, _) | StmtKind::DoWhile(_, c) => {
                c.walk(f)
            }
            StmtKind::For { cond, step, .. } => {
                if let Some(c) = cond {
                    c.walk(f);
                }
                if let Some(st) = step {
                    st.walk(f);
                }
            }
            _ => {}
        });
    }
}

/// Function parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    /// Declared type.
    pub ty: Ty,
    /// Parameter name.
    pub name: String,
    /// Declared as an array parameter (`float a[]`, `float a[n][m]`).
    pub array_dims: usize,
}

/// Function definition or extern declaration (no body).
#[derive(Debug, Clone, PartialEq)]
pub struct FuncDef {
    /// Stable node id within the parse.
    pub id: NodeId,
    /// Source location.
    pub span: Span,
    /// Return type.
    pub ret: Ty,
    /// Function name.
    pub name: String,
    /// Parameters, in order.
    pub params: Vec<Param>,
    /// `None` for extern declarations — these are A-1 library-call targets.
    pub body: Option<Stmt>,
}

/// Struct definition.
#[derive(Debug, Clone, PartialEq)]
pub struct StructDef {
    /// Stable node id within the parse.
    pub id: NodeId,
    /// Source location.
    pub span: Span,
    /// Struct name.
    pub name: String,
    /// Field declarations.
    pub fields: Vec<VarDecl>,
}

/// Top-level items.
#[derive(Debug, Clone, PartialEq)]
pub enum Item {
    /// A function definition or extern declaration.
    Func(FuncDef),
    /// A struct definition.
    Struct(StructDef),
    /// Global variable declaration(s).
    Global(Vec<VarDecl>),
}

/// A parsed translation unit.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Program {
    /// Top-level items in source order.
    pub items: Vec<Item>,
    /// `#include` hints from the lexer (used by analysis A-1).
    pub includes: Vec<String>,
}

impl Program {
    /// Iterate all functions (defined and extern).
    pub fn functions(&self) -> impl Iterator<Item = &FuncDef> {
        self.items.iter().filter_map(|i| match i {
            Item::Func(f) => Some(f),
            _ => None,
        })
    }

    /// Iterate all struct definitions.
    pub fn structs(&self) -> impl Iterator<Item = &StructDef> {
        self.items.iter().filter_map(|i| match i {
            Item::Struct(s) => Some(s),
            _ => None,
        })
    }

    /// Find a function by name.
    pub fn find_function(&self, name: &str) -> Option<&FuncDef> {
        self.functions().find(|f| f.name == name)
    }

    /// Names of functions *defined* (with bodies) in this unit.
    pub fn defined_names(&self) -> Vec<&str> {
        self.functions()
            .filter(|f| f.body.is_some())
            .map(|f| f.name.as_str())
            .collect()
    }
}
